//! Determinism of the parallel plane tick: `Noc::tick` with thread fan-out
//! (`TickMode::Parallel`, and the `Auto` heuristic) must produce
//! byte-identical per-plane statistics, delivery orders, *and* delivery
//! cycles to the sequential fallback over randomized multi-plane workloads
//! on meshes up to 16x16.  The six planes share no state, so this is a
//! structural invariant — this suite is what keeps it that way.

use std::sync::Arc;

use espsim::noc::{
    Coord, DestList, MeshParams, MeshStats, Message, MsgKind, Noc, Plane, TickMode, NUM_PLANES,
};
use espsim::util::Prng;

/// One scheduled send of a workload.
#[derive(Clone)]
struct WSend {
    cycle: u64,
    plane: usize,
    src: Coord,
    msg: Message,
}

/// A full delivery trace entry: (cycle, plane, tile, seq, payload head).
type Delivery = (u64, usize, Coord, u32, Option<u8>);

fn seq_of(m: &Message) -> u32 {
    match m.kind {
        MsgKind::P2pData { seq, .. } => seq,
        _ => panic!("unexpected kind"),
    }
}

/// Run `sends` to quiescence, draining deliveries every cycle.  Returns the
/// delivery trace, the per-plane stats, and the quiesce cycle.
fn run(
    mode: TickMode,
    p: MeshParams,
    sends: &[WSend],
) -> (Vec<Delivery>, [MeshStats; NUM_PLANES], u64) {
    let mut noc = Noc::new(p);
    noc.set_tick_mode(mode);
    let mut trace = Vec::new();
    let mut next = 0usize;
    let mut t = 0u64;
    loop {
        while next < sends.len() && sends[next].cycle == t {
            let s = &sends[next];
            noc.send(Plane::ALL[s.plane], s.src, s.msg.clone());
            next += 1;
        }
        noc.tick(t);
        t += 1;
        for (pi, plane) in Plane::ALL.iter().enumerate() {
            for y in 0..p.height {
                for x in 0..p.width {
                    while let Some(m) = noc.recv(*plane, (y, x)) {
                        trace.push((t, pi, (y, x), seq_of(&m), m.payload.first().copied()));
                    }
                }
            }
        }
        if next == sends.len() && noc.is_idle() {
            break;
        }
        assert!(t < 2_000_000, "noc did not drain in {mode:?}");
    }
    (trace, noc.stats(), t)
}

fn random_workload(rng: &mut Prng, w: u8, h: u8, msgs: u64) -> Vec<WSend> {
    let mut sends = Vec::new();
    for seq in 0..msgs {
        let src = (rng.below(h as u64) as u8, rng.below(w as u64) as u8);
        let mut dests = DestList::new();
        let mut uniq: Vec<Coord> = Vec::new();
        for _ in 0..rng.range(1, 8) {
            let d = (rng.below(h as u64) as u8, rng.below(w as u64) as u8);
            if !uniq.contains(&d) {
                uniq.push(d);
                dests.push(d);
            }
        }
        sends.push(WSend {
            cycle: rng.range(0, 60),
            plane: rng.below(NUM_PLANES as u64) as usize,
            src,
            msg: Message::multicast(
                src,
                dests,
                MsgKind::P2pData { seq: seq as u32, prod_slot: 0 },
                Arc::new(vec![seq as u8; rng.range(0, 2000) as usize]),
            ),
        });
    }
    sends.sort_by_key(|s| (s.cycle, s.plane));
    sends
}

#[test]
fn parallel_tick_matches_sequential_on_random_multi_plane_workloads() {
    let mut rng = Prng::new(0xDE7E_2141);
    for case in 0..6 {
        let w = rng.range(4, 16) as u8;
        let h = rng.range(4, 16) as u8;
        let p = MeshParams {
            width: w,
            height: h,
            flit_bytes: *rng.pick(&[8u32, 16, 32]),
            queue_depth: rng.range(2, 4) as usize,
        };
        let sends = random_workload(&mut rng, w, h, rng.range(8, 24));
        let seq = run(TickMode::Sequential, p, &sends);
        let par = run(TickMode::Parallel, p, &sends);
        let auto = run(TickMode::Auto, p, &sends);
        assert_eq!(seq.0, par.0, "case {case}: delivery trace diverged (parallel)");
        assert_eq!(seq.1, par.1, "case {case}: per-plane stats diverged (parallel)");
        assert_eq!(seq.2, par.2, "case {case}: quiesce cycle diverged (parallel)");
        assert_eq!(seq.0, auto.0, "case {case}: delivery trace diverged (auto)");
        assert_eq!(seq.1, auto.1, "case {case}: per-plane stats diverged (auto)");
        assert_eq!(seq.2, auto.2, "case {case}: quiesce cycle diverged (auto)");
    }
}

#[test]
fn parallel_tick_matches_sequential_on_a_busy_16x16() {
    // Force every plane heavily busy on the full 16x16 mesh so the Auto
    // heuristic actually fans out and the fan-out path sees deep queues.
    let p = MeshParams { width: 16, height: 16, flit_bytes: 16, queue_depth: 4 };
    let mut rng = Prng::new(0xB16_B057);
    let mut sends = Vec::new();
    let mut seq = 0u32;
    for plane in 0..NUM_PLANES {
        for _ in 0..12 {
            let src = (rng.below(16) as u8, rng.below(16) as u8);
            let mut dests = DestList::new();
            let mut uniq: Vec<Coord> = Vec::new();
            for _ in 0..rng.range(4, 16) {
                let d = (rng.below(16) as u8, rng.below(16) as u8);
                if !uniq.contains(&d) {
                    uniq.push(d);
                    dests.push(d);
                }
            }
            sends.push(WSend {
                cycle: rng.range(0, 10),
                plane,
                src,
                msg: Message::multicast(
                    src,
                    dests,
                    MsgKind::P2pData { seq, prod_slot: 0 },
                    Arc::new(vec![seq as u8; 4096]),
                ),
            });
            seq += 1;
        }
    }
    sends.sort_by_key(|s| (s.cycle, s.plane));
    let a = run(TickMode::Sequential, p, &sends);
    let b = run(TickMode::Parallel, p, &sends);
    assert_eq!(a.0.len(), b.0.len());
    assert_eq!(a, b, "parallel 16x16 run diverged from sequential");
}
