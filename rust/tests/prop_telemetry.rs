//! Telemetry properties (DESIGN.md §telemetry):
//!
//! 1. **Telemetry is invisible**: arming the counters changes no cycle
//!    and no statistic — the SoC-level twin of `prop_fault.rs`'s
//!    empty-plan zero-cost invariant, and at the scenario level the
//!    non-telemetry Outcome fields are identical across every sched and
//!    NoC tick mode, armed or not.
//! 2. **Counters reconcile**: per-plane forwarded grids total exactly the
//!    plane's `flit_hops`, per-router stall never exceeds the elapsed
//!    cycles (with per-port detail at least as large), plane active
//!    ticks never exceed the run, and every tile's busy/sleeping/parked
//!    breakdown sums to the elapsed cycles.
//! 3. **Snapshots are deterministic**: repeat runs produce equal
//!    `TelemetryReport`s, and the farm returns the same snapshot as a
//!    serial run (the CI gate `cmp`s two independent dump files).
//! 4. The dump document of a real run **validates against the v1
//!    schema** end to end, hotspots sorted most-stalled first.
//! 5. **Stalls charge the egress port**: a stalled flit's cycles land on
//!    the port it *wanted*, not the port it *arrived on*, so hotspot
//!    dominant-port labels name the contended link under YX and flipped
//!    routing orientations too.

use std::sync::Arc;

use espsim::coordinator::farm::run_farm;
use espsim::coordinator::scenario::{Outcome, Pattern, Platform, Scenario};
use espsim::coordinator::workloads::{Dataflow, EdgePolicy, Shape};
use espsim::noc::{
    Dir, Mesh, MeshParams, Message, MsgKind, Orientation, RouteTable, TickMode, NUM_PLANES,
};
use espsim::sched::SchedMode;
use espsim::telemetry::{dump_document, validate_document, PLANE_NAMES};
use espsim::{Soc, SocConfig};

/// A 4x4 all-to-all shuffle on the 8x8 mesh: four producer streams merge
/// into every consumer, so some router is guaranteed to arbitrate two
/// eligible head flits for the same output and record a stall.
fn shuffle_scenario() -> Scenario {
    let mut s = Scenario::new(
        "shuffle4x4",
        Pattern::AllToAllShuffle { producers: 4, consumers: 4 },
        Platform::Mesh8x8,
    );
    s.bytes = 16 << 10;
    s.telemetry = true;
    s
}

/// The outcome's debug print with the telemetry snapshot masked out —
/// what must stay byte-identical when the counters are toggled.
fn fingerprint_sans_telemetry(o: &Outcome) -> String {
    let mut o = o.clone();
    o.telemetry = None;
    format!("{o:?}")
}

#[test]
fn telemetry_is_invisible_at_the_soc_level() {
    // The zero-cost contract: a telemetry-armed SoC simulates every
    // cycle and statistic byte-identically to one that never allocated a
    // counter (the counters only ever observe, never arbitrate).
    let run = |telemetry: bool| {
        let mut cfg = SocConfig::paper_3x4();
        cfg.telemetry = telemetry;
        let mut soc = Soc::new(cfg).unwrap();
        let g = Dataflow::generate(Shape::Diamond(3), 16 << 10, 4096, 7);
        let cycles = g.run(&mut soc, EdgePolicy::P2p).unwrap();
        (cycles, format!("{:?}", soc.report()))
    };
    assert_eq!(run(false), run(true));
}

#[test]
fn armed_scenarios_match_unarmed_across_sched_and_tick_modes() {
    let mut base =
        Scenario::new("chain", Pattern::P2pChain { stages: 3 }, Platform::Mesh8x8);
    base.bytes = 8 << 10;
    let reference = fingerprint_sans_telemetry(&base.run().unwrap());
    for sched in [SchedMode::Worklist, SchedMode::FullScan] {
        for tick in [TickMode::Sequential, TickMode::Parallel, TickMode::Auto] {
            let mut s = base.clone();
            s.telemetry = true;
            s.sched = sched;
            s.tick_mode = tick;
            let o = s.run().unwrap();
            assert!(o.telemetry.is_some(), "{sched:?}/{tick:?}: armed run lost its snapshot");
            assert_eq!(
                reference,
                fingerprint_sans_telemetry(&o),
                "{sched:?}/{tick:?}: telemetry perturbed the simulation"
            );
        }
    }
}

#[test]
fn counters_reconcile_and_dumps_validate() {
    let s = shuffle_scenario();
    let o = s.run().unwrap();
    let tr = o.telemetry.as_ref().expect("armed run carries a snapshot");
    let n = tr.width as usize * tr.height as usize;
    assert_eq!(tr.planes.len(), NUM_PLANES);
    for (p, pt) in tr.planes.iter().enumerate() {
        assert_eq!(pt.stall.len(), n, "plane {p} stall grid");
        assert_eq!(pt.stall_dir.len(), n, "plane {p} stall_dir grid");
        assert_eq!(pt.forwarded.len(), n, "plane {p} forwarded grid");
        assert_eq!(pt.forks.len(), n, "plane {p} forks grid");
        assert_eq!(pt.occ_sum.len(), n, "plane {p} occupancy grid");
        assert!(pt.active_ticks <= tr.cycles, "plane {p} active beyond the run");
        // The gated stall/fork counters live next to the ungated forward
        // counter: its grid must total exactly the plane's flit-hops.
        assert_eq!(
            pt.forwarded.iter().sum::<u64>(),
            o.plane_flits[p],
            "plane {p} ({}): forwarded grid disagrees with flit_hops",
            PLANE_NAMES[p]
        );
        for r in 0..n {
            assert!(pt.stall[r] <= tr.cycles, "plane {p} router {r}: stall beyond the run");
            let per_port: u64 = pt.stall_dir[r].iter().sum();
            assert!(
                per_port >= pt.stall[r],
                "plane {p} router {r}: port detail lost stalled cycles"
            );
        }
    }
    assert_eq!(tr.tiles.len(), n);
    for (i, c) in tr.tiles.iter().enumerate() {
        assert_eq!(
            c.busy + c.sleeping + c.parked,
            tr.cycles,
            "tile {i}: breakdown does not cover the run"
        );
    }
    assert!(tr.total_stall() > 0, "a 4x4 shuffle must contend somewhere");
    assert!(tr.max_router_stall() <= tr.cycles);
    let hotspots = tr.hotspots(usize::MAX);
    assert!(!hotspots.is_empty());
    assert!(
        hotspots.windows(2).all(|w| w[0].stall >= w[1].stall),
        "hotspots not sorted most-stalled first"
    );
    let doc = dump_document(vec![("shuffle4x4_mesh_8x8".to_string(), tr.to_json())]);
    validate_document(&doc).unwrap();
}

#[test]
fn stalls_charge_the_egress_port_under_yx_routing() {
    // Two multi-flit streams converge on router (2,1) under YX routing:
    // one descends column 0 and turns east (entering on the West port),
    // the other descends column 2 and turns west (entering on the East
    // port), and both want the Local egress.  The loser's stalled cycles
    // must be charged to the port it *wanted* — Local — so the hotspot
    // dominant-port label names the contended link whatever the
    // orientation.  Input-port attribution would light the East/West
    // bits instead.
    let p = MeshParams { width: 3, height: 3, flit_bytes: 8, queue_depth: 4 };
    let mut mesh = Mesh::new(p);
    mesh.set_route_table(Arc::new(RouteTable::closed_form(Orientation::Yx, 3, 3)));
    mesh.set_telemetry(true);
    let payload = Arc::new(vec![0u8; 512]);
    for (seq, src) in [(0u32, (0u8, 0u8)), (1, (0, 2))] {
        mesh.send(
            src,
            Message::data(src, (2, 1), MsgKind::P2pData { seq, prod_slot: 0 }, payload.clone()),
        );
    }
    let mut t = 0u64;
    while !mesh.is_idle() {
        mesh.tick(t);
        t += 1;
        assert!(t < 100_000, "mesh did not drain");
    }
    let tm = mesh.telemetry().expect("armed mesh carries counters");
    let r = 2 * 3 + 1; // router (2,1), row-major
    assert!(tm.stall[r] > 0, "converging streams must contend at (2,1)");
    let dirs = tm.stall_dir[r];
    assert!(dirs[Dir::Local.idx()] > 0, "stalls must charge the contended Local egress");
    for d in [Dir::North, Dir::South, Dir::East, Dir::West] {
        assert_eq!(
            dirs[d.idx()],
            0,
            "router (2,1): {d:?} port charged — input-port attribution leaked back in"
        );
    }
    // The per-port reconciliation invariant holds under egress
    // attribution too: every recorded stall tick sets at least one bit.
    for r in 0..9 {
        let per_port: u64 = tm.stall_dir[r].iter().sum();
        assert!(per_port >= tm.stall[r], "router {r}: port detail lost stalled cycles");
    }
}

#[test]
fn snapshots_are_deterministic_and_farm_equals_serial() {
    let mut chain =
        Scenario::new("chain", Pattern::P2pChain { stages: 3 }, Platform::Mesh8x8);
    chain.bytes = 8 << 10;
    chain.telemetry = true;
    let mut fanout = Scenario::new(
        "fanout",
        Pattern::MulticastFanout { consumers: 4 },
        Platform::Mesh8x8,
    );
    fanout.bytes = 8 << 10;
    fanout.telemetry = true;
    let batch = vec![chain, fanout];
    let snapshots = |jobs: usize| {
        run_farm(&batch, jobs)
            .results
            .into_iter()
            .map(|r| r.outcome.unwrap().telemetry.expect("armed run carries a snapshot"))
            .collect::<Vec<_>>()
    };
    let serial = snapshots(1);
    // Repeat run: byte-for-byte the same counters (the CI gate cmp's two
    // independently produced dump files).
    assert_eq!(serial, snapshots(1), "repeat serial run diverged");
    // Farm run: worker threads change wall-clock only, never a counter.
    assert_eq!(serial, snapshots(4), "farmed run diverged from serial");
    let entries = batch
        .iter()
        .zip(&serial)
        .map(|(s, tr)| (format!("{}_{}", s.name, s.platform.code()), tr.to_json()));
    let doc = dump_document(entries);
    validate_document(&doc).unwrap();
    let reparsed = espsim::util::Json::parse(&doc.to_string()).unwrap();
    assert_eq!(reparsed.to_string(), doc.to_string(), "dump serialization unstable");
}
