//! Farm equivalence property: a batch run on the thread-pooled simulation
//! farm (`--jobs N`) must produce a byte-identical record set to a serial
//! run (`--jobs 1`) — same cycles, speedup, byte counters, and invocation
//! spans in the same input order.  Only the wall-clock family (`wall_s`,
//! `cycles_per_sec`/`sim_cycles_per_sec`, `sims_per_sec`) may differ, and
//! none of it appears in an `Outcome`, so the Outcome Debug string is the
//! byte-identity fingerprint (same trick as `scenario_determinism.rs`).
//!
//! The property is exercised across the two SoC scheduler modes and the
//! plane-tick modes, plus a seeded Monte-Carlo expansion, because those
//! are exactly the axes `sweep-farm` crosses in CI.

use espsim::coordinator::farm::{expand_seeds, run_farm};
use espsim::coordinator::scenario::{builtin_scenarios, Platform, Scenario};
use espsim::noc::TickMode;
use espsim::sched::SchedMode;

/// Builtin registry on the paper platform, shrunk so the full axis cross
/// stays fast in CI.
fn batch(sched: SchedMode, tick: TickMode) -> Vec<Scenario> {
    let mut v = builtin_scenarios(Platform::Paper3x4);
    for s in &mut v {
        s.bytes = 8 << 10;
        s.sched = sched;
        s.tick_mode = tick;
    }
    v
}

/// Serial reference vs farmed run: every slot's Outcome must match
/// byte-for-byte, in input order.
fn assert_farm_matches_serial(scenarios: &[Scenario], jobs: usize, what: &str) {
    let serial = run_farm(scenarios, 1);
    let farmed = run_farm(scenarios, jobs);
    assert_eq!(serial.results.len(), scenarios.len(), "{what}: serial lost slots");
    assert_eq!(farmed.results.len(), scenarios.len(), "{what}: farm lost slots");
    for (i, (a, b)) in serial.results.iter().zip(&farmed.results).enumerate() {
        let a = a.outcome.as_ref().unwrap_or_else(|e| panic!("{what}: serial slot {i}: {e:#}"));
        let b = b.outcome.as_ref().unwrap_or_else(|e| panic!("{what}: farmed slot {i}: {e:#}"));
        assert_eq!(a.name, scenarios[i].name, "{what}: slot {i} out of input order");
        assert_eq!(
            format!("{a:?}"),
            format!("{b:?}"),
            "{what}: slot {i} ({}) diverged between jobs=1 and jobs={jobs}",
            scenarios[i].name
        );
    }
}

#[test]
fn farmed_outcomes_match_serial_across_sched_and_tick_modes() {
    for (sched, tick) in [
        (SchedMode::Worklist, TickMode::Auto),
        (SchedMode::FullScan, TickMode::Sequential),
        (SchedMode::Worklist, TickMode::Sequential),
    ] {
        let scenarios = batch(sched, tick);
        assert_farm_matches_serial(&scenarios, 4, &format!("{sched:?}/{tick:?}"));
    }
}

#[test]
fn farmed_outcomes_match_serial_on_a_seeded_expansion() {
    // The sweep-farm shape: seed replicas multiply the batch, and the
    // per-replica seeds must land in the same slots either way.
    let scenarios = expand_seeds(&batch(SchedMode::Worklist, TickMode::Auto), 2);
    assert_eq!(scenarios.len(), builtin_scenarios(Platform::Paper3x4).len() * 2);
    assert_farm_matches_serial(&scenarios, 4, "seeds=2");
}

#[test]
fn farmed_outcomes_match_serial_with_more_jobs_than_sims() {
    // Surplus workers exit cleanly without stealing or duplicating slots.
    let mut scenarios = batch(SchedMode::Worklist, TickMode::Auto);
    scenarios.truncate(2);
    assert_farm_matches_serial(&scenarios, 8, "surplus workers");
}
