//! NoC integration: multicast behaviour across platform shapes and
//! bitwidths, plus cross-plane isolation — at the level a socket sees.

use std::sync::Arc;

use espsim::noc::{
    bits_per_dest, header_dest_capacity, header_dest_capacity_for, header_meta_bits, DestList,
    Mesh, MeshParams, Message, MsgKind, Noc, Plane,
};

fn params(width: u8, height: u8, bitwidth: u32) -> MeshParams {
    MeshParams { width, height, flit_bytes: bitwidth / 8, queue_depth: 4 }
}

fn drain(m: &mut Mesh, max: u64) {
    let mut t = 0;
    while !m.is_idle() {
        m.tick(t);
        t += 1;
        assert!(t < max, "mesh did not drain");
    }
}

#[test]
fn multicast_to_nine_tiles_on_3x4() {
    // The paper's platform: 3 rows x 4 cols; one producer multicasts to
    // every accelerator tile (9 of them).
    let mut m = Mesh::new(params(4, 3, 256));
    let tiles: Vec<(u8, u8)> = (0..3u8)
        .flat_map(|y| (0..4u8).map(move |x| (y, x)))
        .filter(|&c| c != (0, 1) && c != (0, 0) && c != (0, 3))
        .collect();
    let dests = DestList::from_slice(&tiles);
    let payload = Arc::new((0..4096u32).map(|i| i as u8).collect::<Vec<u8>>());
    m.send(
        (0, 1),
        Message::multicast(
            (0, 1),
            dests,
            MsgKind::P2pData { seq: 0, prod_slot: 0 },
            payload.clone(),
        ),
    );
    drain(&mut m, 20_000);
    for &c in tiles.iter() {
        let got = m.recv(c).unwrap_or_else(|| panic!("missing delivery at {c:?}"));
        assert_eq!(*got.payload, *payload);
    }
}

#[test]
fn bitwidth_throughput_scales() {
    // Same 64 KB transfer on a 64-bit vs 256-bit NoC: the wide NoC must be
    // ~4x faster (flit count scales with bitwidth).
    let mut cycles = Vec::new();
    for bits in [64u32, 256] {
        let mut m = Mesh::new(params(3, 3, bits));
        m.send(
            (0, 0),
            Message::data(
                (0, 0),
                (2, 2),
                MsgKind::P2pData { seq: 0, prod_slot: 0 },
                Arc::new(vec![0u8; 64 << 10]),
            ),
        );
        let mut t = 0;
        while !m.is_idle() {
            m.tick(t);
            t += 1;
            assert!(t < 100_000);
        }
        cycles.push(t);
    }
    let ratio = cycles[0] as f64 / cycles[1] as f64;
    assert!((3.5..4.5).contains(&ratio), "64b/256b cycle ratio {ratio}");
}

#[test]
fn header_capacity_bounds_match_paper() {
    // The paper's §4 table — pinned so the generalized encoding can never
    // silently drift on the meshes the paper synthesizes.
    assert_eq!(header_dest_capacity(64), 5);
    assert_eq!(header_dest_capacity(128), 14);
    assert_eq!(header_dest_capacity(256), 16);
    // Every mesh shape up to 8x8 shares that encoding exactly.
    for (w, h) in [(2u8, 2u8), (3, 3), (4, 3), (5, 4), (8, 8)] {
        assert_eq!(header_dest_capacity_for(64, w, h), 5, "{w}x{h}");
        assert_eq!(header_dest_capacity_for(128, w, h), 14, "{w}x{h}");
        assert_eq!(header_dest_capacity_for(256, w, h), 16, "{w}x{h}");
    }
}

#[test]
fn header_capacity_recomputed_on_16x16() {
    // 16x16 coordinates cost 9 bits per destination (4+4+1) and 31 header
    // metadata bits: the recomputed capacities the wide-mesh support must
    // keep reproducing.
    assert_eq!(bits_per_dest(16, 16), 9);
    assert_eq!(header_meta_bits(16, 16), 31);
    assert_eq!(header_dest_capacity_for(64, 16, 16), 3);
    assert_eq!(header_dest_capacity_for(128, 16, 16), 10);
    assert_eq!(header_dest_capacity_for(256, 16, 16), 16); // 25 encodable, capped
    // 9x9 already needs the 4-bit fields.
    assert_eq!(header_dest_capacity_for(64, 9, 9), 3);
}

#[test]
fn multicast_spans_a_16x16_mesh() {
    // A 16-destination multicast across the full 16x16 mesh: every
    // destination delivered exactly once, corners included.
    let mut m = Mesh::new(params(16, 16, 256));
    let tiles: Vec<(u8, u8)> = (0..16u8)
        .map(|i| match i % 4 {
            0 => (i, 15),
            1 => (15, i),
            2 => (i, i),
            _ => (15 - i, 1 + (i % 8)),
        })
        .collect();
    let mut uniq: Vec<(u8, u8)> = Vec::new();
    for t in tiles {
        if !uniq.contains(&t) && t != (0, 0) {
            uniq.push(t);
        }
    }
    let dests = DestList::from_slice(&uniq);
    let payload = Arc::new((0..2048u32).map(|i| i as u8).collect::<Vec<u8>>());
    m.send(
        (0, 0),
        Message::multicast(
            (0, 0),
            dests,
            MsgKind::P2pData { seq: 0, prod_slot: 0 },
            payload.clone(),
        ),
    );
    drain(&mut m, 100_000);
    for &c in &uniq {
        let got = m.recv(c).unwrap_or_else(|| panic!("missing delivery at {c:?}"));
        assert_eq!(*got.payload, *payload, "at {c:?}");
        assert!(m.recv(c).is_none(), "duplicate at {c:?}");
    }
}

#[test]
fn planes_carry_concurrent_traffic_independently() {
    let mut noc = Noc::new(params(3, 3, 256));
    // Flood one plane; a single message on another plane must not be
    // delayed beyond its intrinsic latency.
    for i in 0..8u32 {
        noc.send(
            Plane::DmaRsp,
            (0, 0),
            Message::data(
                (0, 0),
                (2, 2),
                MsgKind::P2pData { seq: i, prod_slot: 0 },
                Arc::new(vec![0; 4096]),
            ),
        );
    }
    noc.send(Plane::Misc, (0, 0), Message::ctrl((0, 0), (2, 2), MsgKind::Irq { acc: 1 }));
    let mut t = 0;
    let mut irq_at = None;
    while irq_at.is_none() {
        noc.tick(t);
        t += 1;
        if noc.has_rx(Plane::Misc, (2, 2)) {
            irq_at = Some(t);
        }
        assert!(t < 10_000);
    }
    assert!(irq_at.unwrap() <= 10, "misc plane stalled behind bulk data: {irq_at:?}");
}

#[test]
fn two_multicasts_from_different_sources_interleave_safely() {
    let mut m = Mesh::new(params(4, 3, 256));
    let d1 = DestList::from_slice(&[(2, 1), (2, 2), (2, 3)]);
    let d2 = DestList::from_slice(&[(2, 1), (2, 2), (0, 0)]);
    m.send(
        (0, 0),
        Message::multicast(
            (0, 0),
            d1,
            MsgKind::P2pData { seq: 0, prod_slot: 0 },
            Arc::new(vec![1; 512]),
        ),
    );
    m.send(
        (0, 3),
        Message::multicast(
            (0, 3),
            d2,
            MsgKind::P2pData { seq: 0, prod_slot: 1 },
            Arc::new(vec![2; 512]),
        ),
    );
    drain(&mut m, 10_000);
    // (2,1) and (2,2) receive both, each exactly once per source.
    for c in [(2u8, 1u8), (2, 2)] {
        let mut got = Vec::new();
        while let Some(msg) = m.recv(c) {
            got.push(msg.payload[0]);
        }
        got.sort();
        assert_eq!(got, vec![1, 2], "at {c:?}");
    }
    assert_eq!(m.recv((0, 0)).unwrap().payload[0], 2);
    assert_eq!(m.recv((2, 3)).unwrap().payload[0], 1);
}

#[test]
fn multicast_flit_hop_savings_grow_with_fanout() {
    // In-network forking: hops(multicast) / hops(serial unicasts) shrinks
    // as destinations share longer path prefixes.
    // Destinations sharing a long XY path prefix (same far column) so the
    // in-network fork happens late and the savings are large.
    let payload = Arc::new(vec![0u8; 2048]);
    let dests: Vec<(u8, u8)> = vec![(0, 3), (1, 3), (2, 3)];
    let mut mc = Mesh::new(params(4, 3, 256));
    mc.send(
        (0, 0),
        Message::multicast(
            (0, 0),
            DestList::from_slice(&dests),
            MsgKind::P2pData { seq: 0, prod_slot: 0 },
            payload.clone(),
        ),
    );
    drain(&mut mc, 50_000);
    let mut uc = Mesh::new(params(4, 3, 256));
    for &d in &dests {
        uc.send(
            (0, 0),
            Message::data((0, 0), d, MsgKind::P2pData { seq: 0, prod_slot: 0 }, payload.clone()),
        );
    }
    drain(&mut uc, 50_000);
    assert!(
        (mc.stats.flit_hops as f64) < 0.6 * uc.stats.flit_hops as f64,
        "multicast {} vs unicast {} hops",
        mc.stats.flit_hops,
        uc.stats.flit_hops
    );
}
