//! Routing-orientation properties (DESIGN.md §routing orientations):
//!
//! 1. For **every orientation**, a materialized route table built with
//!    nothing dead drives the mesh bit-exactly like that orientation's
//!    closed-form fast path — same idleness, same flit-hops, same
//!    per-tile delivery sequences, every cycle.
//! 2. The explicit closed-form **XY** table is byte-identical to the
//!    default mesh (the pre-orientation pristine path): the orientation
//!    plumbing costs existing XY runs nothing.
//! 3. **Oriented runs are deterministic** across NoC tick modes and SoC
//!    scheduler modes: XY, YX and mixed plane assignments all produce
//!    byte-identical outcomes whichever engine drives them.
//! 4. An orientation-crossed batch on the **simulation farm** matches a
//!    serial run slot-for-slot (the `sweep-farm --orientation all` axis).

use std::sync::Arc;

use espsim::coordinator::farm::run_farm;
use espsim::coordinator::scenario::{
    builtin_scenarios, OrientationMode, Pattern, Platform, Scenario,
};
use espsim::noc::{
    Coord, DestList, Mesh, MeshParams, Message, MsgKind, Orientation, RouteTable, TickMode,
};
use espsim::sched::SchedMode;
use espsim::util::Prng;

fn msg_seq(m: &Message) -> u32 {
    match m.kind {
        MsgKind::P2pData { seq, .. } => seq,
        _ => panic!("unexpected kind"),
    }
}

/// Drive the same sends on two meshes in lockstep, asserting cycle-level
/// equality of idleness, flit-hops and delivery sequences.  `left` is
/// `None` for the untouched default mesh (the pristine-XY fast path);
/// otherwise both sides get their table installed explicitly.
fn run_lockstep(
    what: &str,
    p: MeshParams,
    mut sends: Vec<(u64, Coord, Message)>,
    left: Option<Arc<RouteTable>>,
    right: Arc<RouteTable>,
) {
    sends.sort_by_key(|s| s.0);
    let mut a = Mesh::new(p);
    if let Some(table) = left {
        a.set_route_table(table);
    }
    let mut b = Mesh::new(p);
    b.set_route_table(right);
    let mut next = 0usize;
    let mut t = 0u64;
    loop {
        while next < sends.len() && sends[next].0 == t {
            let (_, src, msg) = &sends[next];
            a.send(*src, msg.clone());
            b.send(*src, msg.clone());
            next += 1;
        }
        a.tick(t);
        b.tick(t);
        t += 1;
        assert_eq!(a.is_idle(), b.is_idle(), "{what}: idleness diverged at cycle {t}");
        assert_eq!(
            a.stats.flit_hops, b.stats.flit_hops,
            "{what}: flit-hops diverged at cycle {t}"
        );
        for y in 0..p.height {
            for x in 0..p.width {
                let c = (y, x);
                loop {
                    match (a.recv(c), b.recv(c)) {
                        (None, None) => break,
                        (Some(m), Some(n)) => {
                            assert_eq!(
                                msg_seq(&m),
                                msg_seq(&n),
                                "{what}: delivery order diverged at {c:?} cycle {t}"
                            );
                        }
                        (m, n) => panic!(
                            "{what}: delivery presence diverged at {c:?} cycle {t}: \
                             left={:?} right={:?}",
                            m.map(|m| msg_seq(&m)),
                            n.map(|m| msg_seq(&m))
                        ),
                    }
                }
            }
        }
        if next == sends.len() && a.is_idle() && b.is_idle() {
            break;
        }
        assert!(t < 2_000_000, "{what}: meshes did not drain");
    }
    assert_eq!(a.stats.delivered, b.stats.delivered, "{what}: delivered total");
    assert_eq!(a.stats.injected, b.stats.injected, "{what}: injected total");
    assert_eq!(a.stats.busy_cycles, b.stats.busy_cycles, "{what}: busy cycles");
}

/// A random multicast workload on a `w` x `h` mesh, identical in shape to
/// the `prop_fault` generator so the two property suites cover the same
/// traffic space.
fn random_sends(rng: &mut Prng, w: u8, h: u8) -> Vec<(u64, Coord, Message)> {
    let n_msgs = rng.range(1, 12);
    let mut sends = Vec::new();
    for seq in 0..n_msgs {
        let src = (rng.below(h as u64) as u8, rng.below(w as u64) as u8);
        let mut dests = DestList::new();
        let mut uniq: Vec<Coord> = Vec::new();
        for _ in 0..rng.range(1, 8) {
            let d = (rng.below(h as u64) as u8, rng.below(w as u64) as u8);
            if !uniq.contains(&d) {
                uniq.push(d);
                dests.push(d);
            }
        }
        let len = rng.range(0, 3000) as usize;
        sends.push((
            rng.range(0, 60),
            src,
            Message::multicast(
                src,
                dests,
                MsgKind::P2pData { seq: seq as u32, prod_slot: 0 },
                Arc::new(vec![rng.next_u64() as u8; len]),
            ),
        ));
    }
    sends
}

#[test]
fn prop_materialized_clean_table_matches_closed_form_per_orientation() {
    // Property 1, and the heart of the orientation claim: the zero-memory
    // closed-form regimes compute exactly the paths the BFS materializes
    // on a healthy mesh — YX included, where the closed form is new.
    let mut rng = Prng::new(0x0B1E_47ED_5EED);
    for orient in Orientation::ALL {
        for case in 0..8 {
            let w = rng.range(2, 8) as u8;
            let h = rng.range(2, 8) as u8;
            let p = MeshParams {
                width: w,
                height: h,
                flit_bytes: *rng.pick(&[8u32, 16, 32]),
                queue_depth: rng.range(2, 5) as usize,
            };
            let sends = random_sends(&mut rng, w, h);
            run_lockstep(
                &format!("{orient:?} case {case}"),
                p,
                sends,
                Some(Arc::new(RouteTable::closed_form(orient, w, h))),
                Arc::new(RouteTable::build_oriented(orient, w, h, &[], &[])),
            );
        }
    }
}

#[test]
fn prop_closed_form_xy_is_byte_identical_to_the_default_mesh() {
    // Property 2: the XY regression pin.  A mesh that never heard of
    // orientations and one with the explicit closed-form XY table must be
    // indistinguishable cycle-by-cycle — this is what keeps every pre-PR
    // XY result byte-identical.
    let mut rng = Prng::new(0x5EED_0F_C1);
    for case in 0..8 {
        let w = rng.range(2, 8) as u8;
        let h = rng.range(2, 8) as u8;
        let p = MeshParams {
            width: w,
            height: h,
            flit_bytes: *rng.pick(&[8u32, 16, 32]),
            queue_depth: rng.range(2, 5) as usize,
        };
        let sends = random_sends(&mut rng, w, h);
        run_lockstep(
            &format!("xy pin case {case}"),
            p,
            sends,
            None,
            Arc::new(RouteTable::closed_form(Orientation::Xy, w, h)),
        );
    }
}

/// One oriented scenario run rendered as a stable string (the same trick
/// as `prop_fault` and `farm_equivalence`: no wall-clock ever lands in an
/// `Outcome`, so its Debug print is a byte-identity fingerprint).
fn fingerprint(s: &Scenario) -> String {
    match s.run() {
        Ok(o) => format!("ok: {o:?}"),
        Err(e) => format!("err: {e:#}"),
    }
}

fn oriented_scenario(mode: OrientationMode) -> Scenario {
    let mut s = Scenario::new(
        "shuffle3x3",
        Pattern::AllToAllShuffle { producers: 3, consumers: 3 },
        Platform::Mesh8x8,
    );
    s.bytes = 8 << 10;
    s.oriented(mode)
}

#[test]
fn oriented_runs_are_deterministic_across_tick_modes() {
    for mode in OrientationMode::ALL {
        let mut s = oriented_scenario(mode);
        s.tick_mode = TickMode::Sequential;
        let reference = fingerprint(&s);
        assert!(reference.starts_with("ok"), "{}: {reference}", s.name);
        for tick in [TickMode::Parallel, TickMode::Auto] {
            s.tick_mode = tick;
            assert_eq!(reference, fingerprint(&s), "{}: {tick:?} diverged", s.name);
        }
    }
}

#[test]
fn oriented_runs_are_deterministic_across_sched_modes() {
    for mode in OrientationMode::ALL {
        let mut s = oriented_scenario(mode);
        s.sched = SchedMode::Worklist;
        let reference = fingerprint(&s);
        s.sched = SchedMode::FullScan;
        assert_eq!(reference, fingerprint(&s), "{}: full_scan diverged", s.name);
    }
}

#[test]
fn farmed_oriented_outcomes_match_serial() {
    // Property 4: the exact batch shape `sweep-farm --orientation all`
    // builds — every builtin scenario crossed with every orientation mode
    // — must be farm/serial byte-identical in input order.
    let mut crossed = Vec::new();
    for s in &builtin_scenarios(Platform::Paper3x4) {
        for mode in OrientationMode::ALL {
            let mut c = s.oriented(mode);
            c.bytes = 8 << 10;
            crossed.push(c);
        }
    }
    let serial = run_farm(&crossed, 1);
    let farmed = run_farm(&crossed, 4);
    assert_eq!(serial.results.len(), crossed.len(), "serial lost slots");
    assert_eq!(farmed.results.len(), crossed.len(), "farm lost slots");
    for (i, (a, b)) in serial.results.iter().zip(&farmed.results).enumerate() {
        let a = a.outcome.as_ref().unwrap_or_else(|e| panic!("serial slot {i}: {e:#}"));
        let b = b.outcome.as_ref().unwrap_or_else(|e| panic!("farmed slot {i}: {e:#}"));
        assert_eq!(a.name, crossed[i].name, "slot {i} out of input order");
        assert_eq!(
            format!("{a:?}"),
            format!("{b:?}"),
            "slot {i} ({}) diverged between jobs=1 and jobs=4",
            crossed[i].name
        );
    }
}
