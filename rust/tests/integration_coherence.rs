//! Coherence-based synchronization on the full SoC (paper §3,
//! *Accelerator Synchronization*): MESI traffic over the three coherence
//! planes between the CPU's L1 and accelerator-tile L2s, flag
//! producer/consumer patterns, and the latency comparison against the
//! IRQ path that motivates the feature.

use espsim::config::SocConfig;
use espsim::coordinator::Soc;
use espsim::noc::Plane;
use espsim::sync::FlagRegion;
use espsim::tile::HostOp;

fn coherent_soc() -> Soc {
    let mut cfg = SocConfig::small_3x3();
    cfg.acc.l2_enabled = true;
    Soc::new(cfg).unwrap()
}

#[test]
fn cpu_flag_set_and_spin_roundtrip() {
    let mut soc = coherent_soc();
    let flags = FlagRegion::new(0x1000, 4, 64);
    // CPU sets flag 0 then spins on it (trivially satisfied once the
    // store completes) — exercises GetM + GetS against the directory.
    soc.push_host_script(vec![
        HostOp::SetFlag { addr: flags.addr(0), val: 7 },
        HostOp::WaitFlag { addr: flags.addr(0), val: 7 },
    ]);
    soc.run(100_000).unwrap();
    // The store reached the coherence system: directory data is current
    // after the CPU's line is recalled; read through the backdoor after a
    // writeback would require eviction, so check via the CPU cache state.
    assert!(soc.cpu_mut().l1.quiescent());
}

#[test]
fn coherence_planes_carry_traffic() {
    let mut soc = coherent_soc();
    let flags = FlagRegion::new(0x2000, 2, 64);
    soc.push_host_script(vec![
        HostOp::SetFlag { addr: flags.addr(0), val: 1 },
        HostOp::SetFlag { addr: flags.addr(1), val: 2 },
    ]);
    soc.run(100_000).unwrap();
    let report = soc.report();
    assert!(
        report.planes[Plane::CohReq.idx()].delivered > 0,
        "GetM requests must ride the coherence-request plane"
    );
    assert!(
        report.planes[Plane::CohRsp.idx()].delivered > 0,
        "data grants must ride the coherence-response plane"
    );
}

/// Accelerator-side L2 participates: poke a flag through an accelerator
/// tile's cache directly (unit-style, but through the full NoC + memory
/// tile + directory).
#[test]
fn accelerator_l2_and_cpu_l1_share_a_flag() {
    let mut soc = coherent_soc();
    let addr = 0x3000u64;

    // Accelerator tile (acc 0) stores through its L2 by driving the cache
    // controller directly while the SoC ticks.
    let (tile_idx, _) = (soc.cfg.index_of(soc.acc_location(0).0), 0);
    let mut stored = false;
    let mut cpu_saw = None;
    for _ in 0..200_000 {
        {
            let espsim::tile::Tile::Acc(acc) = &mut soc.tiles[tile_idx] else { panic!() };
            let l2 = acc.l2.as_mut().expect("l2 enabled");
            if !stored {
                stored = l2.store(addr, 99);
            }
        }
        {
            let cpu_coord = soc.cfg.cpu_tile();
            let cpu_idx = soc.cfg.index_of(cpu_coord);
            let espsim::tile::Tile::Cpu(cpu) = &mut soc.tiles[cpu_idx] else { panic!() };
            if stored && cpu_saw.is_none() {
                cpu_saw = cpu.l1.load(addr);
            }
        }
        soc.tick();
        if cpu_saw == Some(99) {
            break;
        }
    }
    assert_eq!(cpu_saw, Some(99), "CPU L1 must observe the accelerator's coherent store");
}

/// The paper's motivation: a coherent flag handoff is cheaper than an IRQ
/// round trip through the host.
#[test]
fn flag_sync_cheaper_than_irq_roundtrip() {
    // Flag path: producer store -> consumer invalidation + refetch.
    // Measured as cycles for the CPU to see a flag set by an acc L2.
    let mut soc = coherent_soc();
    let addr = 0x4000u64;
    let tile_idx = soc.cfg.index_of(soc.acc_location(0).0);
    // Warm the consumer (CPU) copy so the handoff is inval + refetch.
    let cpu_idx = soc.cfg.index_of(soc.cfg.cpu_tile());
    let mut warmed = false;
    for _ in 0..10_000 {
        let espsim::tile::Tile::Cpu(cpu) = &mut soc.tiles[cpu_idx] else { panic!() };
        if cpu.l1.load(addr).is_some() {
            warmed = true;
            break;
        }
        soc.tick();
    }
    assert!(warmed);
    // Producer stores; count cycles until CPU sees it.
    let mut stored = false;
    let mut cycles = 0u64;
    for _ in 0..100_000 {
        {
            let espsim::tile::Tile::Acc(acc) = &mut soc.tiles[tile_idx] else { panic!() };
            if !stored {
                stored = acc.l2.as_mut().unwrap().store(addr, 1);
            }
        }
        {
            let espsim::tile::Tile::Cpu(cpu) = &mut soc.tiles[cpu_idx] else { panic!() };
            if stored && cpu.l1.load(addr) == Some(1) {
                break;
            }
        }
        soc.tick();
        cycles += 1;
    }
    // IRQ path cost: NoC traversal + the host's IRQ service overhead.
    let irq_cost = soc.cfg.host.irq_overhead as u64 + 10;
    assert!(
        cycles < irq_cost,
        "coherent flag handoff ({cycles} cy) should beat the IRQ path (~{irq_cost} cy)"
    );
}
