//! Property tests over the whole coordinator: randomized dataflow apps
//! (random placements, fan-outs, sizes, burst shapes, platforms) must
//! always quiesce with intact data; simulations must be deterministic.

use espsim::accel::traffic_gen::TgenArgs;
use espsim::config::SocConfig;
use espsim::coordinator::{App, Invocation, Soc};
use espsim::util::Prng;

const IN: u64 = 0x10_0000;

fn pattern(rng: &mut Prng, n: usize) -> Vec<u8> {
    rng.bytes(n)
}

/// Random producer + fan-out apps on random platforms: every consumer's
/// output must equal the producer's input and the SoC must quiesce.
#[test]
fn prop_random_fanout_apps_always_verify() {
    let mut rng = Prng::new(0xC0FFEE);
    for case in 0..25 {
        let cfg = if rng.chance(0.5) { SocConfig::paper_3x4() } else { SocConfig::small_3x3() };
        let max_fanout = (cfg.acc_sockets().len() - 1).min(cfg.mcast_capacity());
        let n = rng.range(1, max_fanout as u64) as usize;
        let bursts = rng.range(1, 8) as u32;
        let prod_burst = *rng.pick(&[1024u32, 2048, 4096]);
        let cons_burst = *rng.pick(&[512u32, 1024, 4096]);
        let total_lcm = 4096 * bursts; // divisible by all burst choices
        let mut soc = Soc::new(cfg).unwrap();
        let data = pattern(&mut rng, total_lcm as usize);
        soc.write_mem(IN, &data);
        let mut invs = vec![Invocation::tgen(
            0,
            TgenArgs {
                total_bytes: total_lcm,
                burst_bytes: prod_burst,
                rd_user: 0,
                wr_user: n as u16,
                vaddr_in: IN,
                vaddr_out: 0,
            },
        )];
        for c in 0..n {
            invs.push(
                Invocation::tgen(
                    (c + 1) as u16,
                    TgenArgs {
                        total_bytes: total_lcm,
                        burst_bytes: cons_burst,
                        rd_user: 1,
                        wr_user: 0,
                        vaddr_in: 0,
                        vaddr_out: 0x100_0000 + c as u64 * 0x20_0000,
                    },
                )
                .with_src(1, 0),
            );
        }
        App::new().phase(invs).launch(&mut soc).unwrap();
        soc.run(200_000_000).unwrap_or_else(|e| {
            panic!("case {case} (n={n} bursts={bursts} pb={prod_burst} cb={cons_burst}): {e}")
        });
        for c in 0..n {
            assert_eq!(
                soc.read_mem(0x100_0000 + c as u64 * 0x20_0000, total_lcm as usize),
                data,
                "case {case} consumer {c}"
            );
        }
    }
}

/// Identical app + config => identical cycle count and identical reports.
#[test]
fn prop_soc_runs_are_deterministic() {
    let run = |seed: u64| {
        let mut rng = Prng::new(seed);
        let mut soc = Soc::new(SocConfig::paper_3x4()).unwrap();
        let total = 16 << 10;
        soc.write_mem(IN, &rng.bytes(total));
        let invs = vec![
            Invocation::tgen(
                0,
                TgenArgs {
                    total_bytes: total as u32,
                    burst_bytes: 4096,
                    rd_user: 0,
                    wr_user: 2,
                    vaddr_in: IN,
                    vaddr_out: 0,
                },
            ),
            Invocation::tgen(
                1,
                TgenArgs {
                    total_bytes: total as u32,
                    burst_bytes: 2048,
                    rd_user: 1,
                    wr_user: 0,
                    vaddr_in: 0,
                    vaddr_out: 0x100_0000,
                },
            )
            .with_src(1, 0),
            Invocation::tgen(
                2,
                TgenArgs {
                    total_bytes: total as u32,
                    burst_bytes: 4096,
                    rd_user: 1,
                    wr_user: 0,
                    vaddr_in: 0,
                    vaddr_out: 0x120_0000,
                },
            )
            .with_src(1, 0),
        ];
        App::new().phase(invs).launch(&mut soc).unwrap();
        let cycles = soc.run(100_000_000).unwrap();
        let report = soc.report();
        (cycles, report.total_flit_hops(), report.mem.read_bytes, report.cpu.reg_writes)
    };
    assert_eq!(run(11), run(11));
    assert_eq!(run(23), run(23));
}

/// Phase barriers are respected: in a 2-phase app, no phase-2 invocation
/// starts before every phase-1 invocation ends.
#[test]
fn prop_phase_barriers_order_invocations() {
    let mut rng = Prng::new(0x5EED);
    for _ in 0..10 {
        let mut soc = Soc::new(SocConfig::paper_3x4()).unwrap();
        let total = 4096 * rng.range(1, 4) as u32;
        soc.write_mem(IN, &rng.bytes(total as usize));
        let mk = |acc: u16, out: u64| {
            Invocation::tgen(
                acc,
                TgenArgs {
                    total_bytes: total,
                    burst_bytes: 4096,
                    rd_user: 0,
                    wr_user: 0,
                    vaddr_in: IN,
                    vaddr_out: out,
                },
            )
        };
        let p1: Vec<_> = (0..3).map(|i| mk(i, 0x100_0000 + i as u64 * 0x20_0000)).collect();
        let p2: Vec<_> = (3..5).map(|i| mk(i, 0x100_0000 + i as u64 * 0x20_0000)).collect();
        App::new().phase(p1).phase(p2).launch(&mut soc).unwrap();
        soc.run(100_000_000).unwrap();
        let report = soc.report();
        let phase1_end =
            report.invocations.iter().filter(|(a, _, _)| *a < 3).map(|(_, _, e)| *e).max().unwrap();
        let phase2_start = report
            .invocations
            .iter()
            .filter(|(a, _, _)| *a >= 3)
            .map(|(_, s, _)| *s)
            .min()
            .unwrap();
        assert!(
            phase2_start > phase1_end,
            "phase 2 started at {phase2_start} before phase 1 ended at {phase1_end}"
        );
    }
}

/// Random dataflow DAGs (chains/trees/diamonds/random) lowered to both
/// edge policies always quiesce and verify.
#[test]
fn prop_random_dataflow_graphs_run_both_policies() {
    use espsim::coordinator::workloads::{Dataflow, EdgePolicy, Shape};
    for seed in 0..6u64 {
        let shapes = [Shape::Chain(4), Shape::Tree(6), Shape::Diamond(4), Shape::Random(8)];
        let shape = shapes[seed as usize % shapes.len()];
        let g = Dataflow::generate(shape, 16 << 10, 4096, seed);
        let mut soc = Soc::new(SocConfig::paper_3x4()).unwrap();
        g.run(&mut soc, EdgePolicy::Memory)
            .unwrap_or_else(|e| panic!("seed {seed} {shape:?} memory: {e}"));
        let p2p_ok =
            g.nodes.iter().all(|n| n.inputs.len() <= 1 || g.fanout(n.id) == 0);
        if p2p_ok {
            let mut soc = Soc::new(SocConfig::paper_3x4()).unwrap();
            g.run(&mut soc, EdgePolicy::P2p)
                .unwrap_or_else(|e| panic!("seed {seed} {shape:?} p2p: {e}"));
        }
    }
}
