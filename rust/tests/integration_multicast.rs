//! Multicast integration on the full SoC: fan-out correctness, header
//! capacity limits, NoC traffic accounting, and the in-network-fork
//! advantage over serial unicasts.

use espsim::accel::traffic_gen::TgenArgs;
use espsim::config::SocConfig;
use espsim::coordinator::experiments::{run_multicast, Fig6Options};
use espsim::coordinator::{App, Invocation, Soc};
use espsim::noc::Plane;

const IN: u64 = 0x10_0000;

fn pattern(n: usize) -> Vec<u8> {
    (0..n).map(|i| (i as u64).wrapping_mul(2654435761) as u8).collect()
}

/// 1 producer multicasting to `n` consumers on the paper platform; returns
/// (cycles, report).
fn fanout(n: usize, total: u32) -> (u64, espsim::coordinator::Report) {
    let mut soc = Soc::new(SocConfig::paper_3x4()).unwrap();
    let data = pattern(total as usize);
    soc.write_mem(IN, &data);
    let mut invs = vec![Invocation::tgen(
        0,
        TgenArgs {
            total_bytes: total,
            burst_bytes: 4096,
            rd_user: 0,
            wr_user: n as u16,
            vaddr_in: IN,
            vaddr_out: 0,
        },
    )];
    for c in 0..n {
        invs.push(
            Invocation::tgen(
                (c + 1) as u16,
                TgenArgs {
                    total_bytes: total,
                    burst_bytes: 4096,
                    rd_user: 1,
                    wr_user: 0,
                    vaddr_in: 0,
                    vaddr_out: 0x100_0000 + c as u64 * 0x20_0000,
                },
            )
            .with_src(1, 0),
        );
    }
    App::new().phase(invs).launch(&mut soc).unwrap();
    let cycles = soc.run(100_000_000).unwrap();
    for c in 0..n {
        assert_eq!(
            soc.read_mem(0x100_0000 + c as u64 * 0x20_0000, total as usize),
            data,
            "consumer {c}"
        );
    }
    (cycles, soc.report())
}

#[test]
fn fanout_2_8_16_all_verify() {
    for n in [2usize, 8, 16] {
        fanout(n, 16 << 10);
    }
}

#[test]
fn multicast_messages_counted() {
    let (_, report) = fanout(4, 16 << 10);
    let (_, prod) = &report.sockets[0];
    // 4 bursts, each one multicast message to 4 consumers.
    assert_eq!(prod.p2p_write_bytes, 4 * (16 << 10) as u64);
    let consumed: u64 = report.sockets.iter().skip(1).map(|(_, s)| s.p2p_read_bytes).sum();
    assert_eq!(consumed, 4 * (16 << 10) as u64);
}

#[test]
fn fanout_cost_is_sublinear_in_consumers() {
    // In-network forking: DmaRsp-plane flit-hops grow far slower than the
    // consumer count (serial unicasts would scale linearly).
    let (_, r2) = fanout(2, 32 << 10);
    let (_, r16) = fanout(16, 32 << 10);
    let h2 = r2.planes[Plane::DmaRsp.idx()].flit_hops as f64;
    let h16 = r16.planes[Plane::DmaRsp.idx()].flit_hops as f64;
    assert!(
        h16 / h2 < 4.0,
        "8x consumers must cost << 8x hops with in-network fork: {h2} -> {h16}"
    );
}

#[test]
fn exceeding_mcast_capacity_is_rejected() {
    let mut opts = Fig6Options::default();
    opts.soc.noc.bitwidth = 64; // capacity 5
    assert!(run_multicast(6, 4096, &opts).is_err());
    assert!(run_multicast(5, 4096, &opts).is_ok());
}

#[test]
fn unicast_equals_fanout_one() {
    // wr_user == 1 is plain (enhanced) P2P: still verifies.
    fanout(1, 8 << 10);
}
