//! Runtime integration: AOT-compiled JAX/Pallas artifacts loaded through
//! PJRT and executed as accelerator datapaths — numerics verified against
//! the python-side oracle dumps, both standalone and inside a simulated
//! accelerator invocation.
//!
//! Requires `make artifacts` (skipped gracefully when absent so cargo test
//! works before the first build).


use espsim::accel::{matmul_cycles, stage_program, DpCall, DpKind, Xfer};
use espsim::config::SocConfig;
use espsim::coordinator::{App, Invocation, ProgramKind, Soc};
use espsim::runtime::Runtime;

fn runtime() -> Option<Runtime> {
    let dir = Runtime::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Runtime::open(dir).unwrap())
}

#[test]
fn stage0_matches_oracle_shapes() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("stage0_linear_relu").unwrap();
    let m = rt.manifest().pipeline.clone();
    let x = rt.load_f32_tensor("input_x").unwrap();
    let w0 = rt.load_f32_tensor("w0").unwrap();
    let b0 = rt.load_f32_tensor("b0").unwrap();
    let out = exe.execute_f32(&[&x, &w0, &b0]).unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].len(), m.batch * m.d_hid);
    // relu output: non-negative, not all zero.
    assert!(out[0].iter().all(|&v| v >= 0.0));
    assert!(out[0].iter().any(|&v| v > 0.0));
}

#[test]
fn full_pipeline_on_host_matches_expected() {
    // Chain the compiled stages on the host (no SoC): stage0 -> 4 heads ->
    // combiner must equal the jax oracle's expected_out dump.
    let Some(rt) = runtime() else { return };
    let m = rt.manifest().pipeline.clone();
    let x = rt.load_f32_tensor("input_x").unwrap();
    let stage0 = rt.load("stage0_linear_relu").unwrap();
    let head = rt.load("stage_head").unwrap();
    let comb = rt.load("stage_combiner").unwrap();

    let y = stage0
        .execute_f32(&[&x, &rt.load_f32_tensor("w0").unwrap(), &rt.load_f32_tensor("b0").unwrap()])
        .unwrap()
        .remove(0);
    let mut heads = Vec::new();
    for h in 0..m.n_heads {
        let wh = rt.load_f32_tensor(&format!("wh{h}")).unwrap();
        let bh = rt.load_f32_tensor(&format!("bh{h}")).unwrap();
        heads.push(head.execute_f32(&[&y, &wh, &bh]).unwrap().remove(0));
    }
    // Concatenate along features: row-major (batch, n_heads * d_head).
    let mut cat = vec![0f32; m.batch * m.n_heads * m.d_head];
    for b in 0..m.batch {
        for (h, hv) in heads.iter().enumerate() {
            let dst = b * m.n_heads * m.d_head + h * m.d_head;
            cat[dst..dst + m.d_head]
                .copy_from_slice(&hv[b * m.d_head..(b + 1) * m.d_head]);
        }
    }
    let out = comb
        .execute_f32(&[
            &cat,
            &rt.load_f32_tensor("wc").unwrap(),
            &rt.load_f32_tensor("bc").unwrap(),
        ])
        .unwrap()
        .remove(0);
    let expected = rt.load_f32_tensor("expected_out").unwrap();
    assert_eq!(out.len(), expected.len());
    let max_err = out
        .iter()
        .zip(&expected)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_err < 1e-3, "pipeline numerics diverge: max abs err {max_err}");
}

/// The three-layer story end-to-end: a compiled Pallas stage runs as the
/// datapath of a *simulated accelerator invocation* — weights DMA'd from
/// simulated DRAM into the PLM, RunDp executing the PJRT artifact, output
/// DMA'd back to simulated DRAM.
#[test]
fn compiled_stage_as_accelerator_datapath() {
    let Some(rt) = runtime() else { return };
    let m = rt.manifest().pipeline.clone();
    let exe = rt.load("stage0_linear_relu").unwrap();

    let mut cfg = SocConfig::small_3x3();
    cfg.acc.plm_bytes = 1 << 20; // fit x + w0 + b0 + out
    cfg.acc.max_burst_bytes = 16 << 10;
    let mut soc = Soc::new(cfg).unwrap();

    let as_bytes = |v: &[f32]| v.iter().flat_map(|f| f.to_le_bytes()).collect::<Vec<u8>>();
    let x = rt.load_f32_tensor("input_x").unwrap();
    let w0 = rt.load_f32_tensor("w0").unwrap();
    let b0 = rt.load_f32_tensor("b0").unwrap();
    let x_b = as_bytes(&x);
    let w_b = as_bytes(&w0);
    let b_b = as_bytes(&b0);
    soc.write_mem(0x10_0000, &x_b);
    soc.write_mem(0x20_0000, &w_b);
    soc.write_mem(0x30_0000, &b_b);

    // PLM layout: x @ 0, w @ |x|, b @ |x|+|w|, out after that.
    let (xo, wo, bo) = (0u32, x_b.len() as u32, (x_b.len() + w_b.len()) as u32);
    let oo = bo + b_b.len() as u32;
    let out_len = (m.batch * m.d_hid * 4) as u32;
    let dp = DpCall {
        kind: DpKind::Xla(exe),
        inputs: vec![(xo, x_b.len() as u32), (wo, w_b.len() as u32), (bo, b_b.len() as u32)],
        out_offset: oo,
        cycles: matmul_cycles(m.batch as u64, m.d_in as u64, m.d_hid as u64, 256),
    };
    let prog = stage_program(
        &[
            Xfer { vaddr: 0x10_0000, plm: xo, len: x_b.len() as u32, user: 0 },
            Xfer { vaddr: 0x20_0000, plm: wo, len: w_b.len() as u32, user: 0 },
            Xfer { vaddr: 0x30_0000, plm: bo, len: b_b.len() as u32, user: 0 },
        ],
        &[0],
        &[Xfer { vaddr: 0x40_0000, plm: oo, len: out_len, user: 0 }],
        16 << 10,
    );
    let mut inv = Invocation::tgen(
        0,
        espsim::accel::TgenArgs {
            total_bytes: 0,
            burst_bytes: 1,
            rd_user: 0,
            wr_user: 0,
            vaddr_in: 0,
            vaddr_out: 0,
        },
    );
    inv.program = ProgramKind::Custom(prog);
    inv.args = [0; 8];
    inv.dp_calls = vec![dp];
    App::new().phase(vec![inv]).launch(&mut soc).unwrap();
    let cycles = soc.run(50_000_000).unwrap();

    // Compare against running the artifact directly.
    let rt2 = Runtime::open(Runtime::default_dir()).unwrap();
    let want = rt2
        .load("stage0_linear_relu")
        .unwrap()
        .execute_f32(&[&x, &w0, &b0])
        .unwrap()
        .remove(0);
    let got_bytes = soc.read_mem(0x40_0000, out_len as usize);
    let got: Vec<f32> = got_bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    assert_eq!(got, want, "datapath output through the simulated SoC");
    // Timing includes the analytic MXU estimate.
    assert!(cycles > dp_cycles_floor(&m), "compute cycles charged");
}

fn dp_cycles_floor(m: &espsim::runtime::PipelineMeta) -> u64 {
    matmul_cycles(m.batch as u64, m.d_in as u64, m.d_hid as u64, 256)
}
