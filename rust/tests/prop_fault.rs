//! Fault-model properties (DESIGN.md §fault model):
//!
//! 1. A **materialized** route table built with nothing dead drives the
//!    mesh bit-exactly like the closed-form XY fast path — same idleness,
//!    same flit-hops, same per-tile delivery sequences, every cycle.
//! 2. An **empty fault plan** is cycle-exact with no plan at all (the
//!    zero-cost no-fault invariant at the SoC level).
//! 3. **Fault-injected runs are deterministic**: the same scenario, fault
//!    plan and seed produce byte-identical outcomes — whether the run
//!    completes degraded or fails with a diagnosed cause — across repeat
//!    runs and NoC tick modes.
//! 4. Every builtin scenario pattern on a **harvested 16x16 mesh** (one
//!    row disabled down to its bridge tile) either completes or fails
//!    with a structural diagnostic — never the quiesce watchdog.
//! 5. The **payload sink digest** is a pure function of delivered bytes:
//!    scheduler mode, plane-tick mode and the recovery path (replay ring
//!    plus wedge drain) must all reproduce the healthy digest whenever a
//!    run completes.

use std::sync::Arc;

use espsim::coordinator::scenario::{builtin_scenarios, Pattern, Platform, Scenario};
use espsim::coordinator::workloads::{Dataflow, EdgePolicy, Shape};
use espsim::noc::{
    Coord, DestList, Mesh, MeshParams, Message, MsgKind, RouteTable, TickMode,
};
use espsim::sched::SchedMode;
use espsim::util::Prng;
use espsim::{FaultPlan, QuiesceError, Soc, SocConfig};

fn msg_seq(m: &Message) -> u32 {
    match m.kind {
        MsgKind::P2pData { seq, .. } => seq,
        _ => panic!("unexpected kind"),
    }
}

/// Run the same sends on a pristine-XY mesh and on one driving a
/// materialized (but fault-free) route table, in lockstep, asserting
/// cycle-level equality of idleness, flit-hops and delivery sequences.
fn run_table_equiv(case: usize, p: MeshParams, mut sends: Vec<(u64, Coord, Message)>) {
    sends.sort_by_key(|s| s.0);
    let mut xy = Mesh::new(p);
    let mut tab = Mesh::new(p);
    tab.set_route_table(Arc::new(RouteTable::build(p.width, p.height, &[], &[])));
    let mut next = 0usize;
    let mut t = 0u64;
    loop {
        while next < sends.len() && sends[next].0 == t {
            let (_, src, msg) = &sends[next];
            xy.send(*src, msg.clone());
            tab.send(*src, msg.clone());
            next += 1;
        }
        xy.tick(t);
        tab.tick(t);
        t += 1;
        assert_eq!(xy.is_idle(), tab.is_idle(), "case {case}: idleness diverged at cycle {t}");
        assert_eq!(
            xy.stats.flit_hops, tab.stats.flit_hops,
            "case {case}: flit-hops diverged at cycle {t}"
        );
        for y in 0..p.height {
            for x in 0..p.width {
                let c = (y, x);
                loop {
                    match (xy.recv(c), tab.recv(c)) {
                        (None, None) => break,
                        (Some(a), Some(b)) => {
                            assert_eq!(
                                msg_seq(&a),
                                msg_seq(&b),
                                "case {case}: delivery order diverged at {c:?} cycle {t}"
                            );
                        }
                        (a, b) => panic!(
                            "case {case}: delivery presence diverged at {c:?} cycle {t}: \
                             xy={:?} table={:?}",
                            a.map(|m| msg_seq(&m)),
                            b.map(|m| msg_seq(&m))
                        ),
                    }
                }
            }
        }
        if next == sends.len() && xy.is_idle() && tab.is_idle() {
            break;
        }
        assert!(t < 2_000_000, "case {case}: meshes did not drain");
    }
    assert_eq!(xy.stats.delivered, tab.stats.delivered, "case {case}: delivered total");
    assert_eq!(xy.stats.injected, tab.stats.injected, "case {case}: injected total");
    assert_eq!(xy.stats.busy_cycles, tab.stats.busy_cycles, "case {case}: busy cycles");
}

#[test]
fn prop_materialized_clean_table_drives_the_mesh_exactly_like_xy() {
    let mut rng = Prng::new(0x7AB1E_5EED);
    for case in 0..24 {
        let w = rng.range(2, 8) as u8;
        let h = rng.range(2, 8) as u8;
        let p = MeshParams {
            width: w,
            height: h,
            flit_bytes: *rng.pick(&[8u32, 16, 32]),
            queue_depth: rng.range(2, 5) as usize,
        };
        let n_msgs = rng.range(1, 12);
        let mut sends = Vec::new();
        for seq in 0..n_msgs {
            let src = (rng.below(h as u64) as u8, rng.below(w as u64) as u8);
            let mut dests = DestList::new();
            let mut uniq: Vec<Coord> = Vec::new();
            for _ in 0..rng.range(1, 8) {
                let d = (rng.below(h as u64) as u8, rng.below(w as u64) as u8);
                if !uniq.contains(&d) {
                    uniq.push(d);
                    dests.push(d);
                }
            }
            let len = rng.range(0, 3000) as usize;
            sends.push((
                rng.range(0, 60),
                src,
                Message::multicast(
                    src,
                    dests,
                    MsgKind::P2pData { seq: seq as u32, prod_slot: 0 },
                    Arc::new(vec![rng.next_u64() as u8; len]),
                ),
            ));
        }
        run_table_equiv(case, p, sends);
    }
}

#[test]
fn empty_fault_plan_is_cycle_exact_with_no_plan() {
    // The zero-cost invariant at the SoC level: installing a plan with no
    // events must not perturb a single cycle or statistic.
    let run = |plan: Option<FaultPlan>| {
        let mut soc = Soc::new(SocConfig::paper_3x4()).unwrap();
        if let Some(p) = plan {
            soc.set_fault_plan(p);
        }
        let g = Dataflow::generate(Shape::Diamond(3), 16 << 10, 4096, 7);
        let cycles = g.run(&mut soc, EdgePolicy::P2p).unwrap();
        (cycles, format!("{:?}", soc.report()))
    };
    assert_eq!(run(None), run(Some(FaultPlan::none())));
}

#[test]
fn link_storms_are_deterministic_draws() {
    let a = FaultPlan::link_storm(0xBEEF, 5, 8, 8, (1, 10_000));
    let b = FaultPlan::link_storm(0xBEEF, 5, 8, 8, (1, 10_000));
    assert_eq!(format!("{:?}", a.events()), format!("{:?}", b.events()));
    assert_eq!(a.len(), 5);
    // A different seed draws a different storm (overwhelmingly likely on
    // a 8x8 mesh with 112 candidate links and a 10k-cycle window).
    let c = FaultPlan::link_storm(0xBEEF + 1, 5, 8, 8, (1, 10_000));
    assert_ne!(format!("{:?}", a.events()), format!("{:?}", c.events()));
}

/// One faulted scenario run rendered as a stable string: the full
/// `Outcome` debug print on success, the full error chain on failure.
/// Either way the bytes must be identical run-to-run.
fn faulted_fingerprint(s: &Scenario) -> String {
    match s.run() {
        Ok(o) => format!("ok: {o:?}"),
        Err(e) => format!("err: {e:#}"),
    }
}

#[test]
fn faulted_runs_are_deterministic() {
    for (links, fault_seed) in [(2u8, 0xBEEFu64), (4, 17)] {
        let mut s = Scenario::new(
            "fanout",
            Pattern::MulticastFanout { consumers: 4 },
            Platform::Mesh8x8,
        );
        s.bytes = 8 << 10;
        let s = s.degraded(&[], links, fault_seed);
        let first = faulted_fingerprint(&s);
        assert_eq!(first, faulted_fingerprint(&s), "{}: repeat run diverged", s.name);
    }
}

#[test]
fn faulted_runs_are_deterministic_across_tick_modes() {
    let mut s =
        Scenario::new("chain", Pattern::P2pChain { stages: 3 }, Platform::Mesh8x8);
    s.bytes = 8 << 10;
    let mut s = s.degraded(&[1], 3, 0xF00D);
    s.tick_mode = TickMode::Sequential;
    let reference = faulted_fingerprint(&s);
    for mode in [TickMode::Parallel, TickMode::Auto] {
        s.tick_mode = mode;
        assert_eq!(reference, faulted_fingerprint(&s), "{}: {mode:?} diverged", s.name);
    }
}

#[test]
fn payload_digests_agree_across_sched_tick_modes_and_recovery() {
    // Healthy digest as the reference, then every scheduler x tick-mode
    // combination must reproduce it — and so must a degraded run with the
    // replay ring armed, whenever it completes at all (a diagnosed
    // failure is legitimate; a wrong digest never is).
    let mut base =
        Scenario::new("fanout", Pattern::MulticastFanout { consumers: 4 }, Platform::Mesh8x8);
    base.bytes = 8 << 10;
    let healthy = base.run().expect("healthy reference run").sink_digest;
    for sched in [SchedMode::FullScan, SchedMode::Worklist] {
        for tick in [TickMode::Sequential, TickMode::Parallel, TickMode::Auto] {
            let mut s = base.clone();
            s.sched = sched;
            s.tick_mode = tick;
            let o = s.run().expect("healthy run");
            assert_eq!(
                o.sink_digest, healthy,
                "{}: {sched:?}/{tick:?} moved the healthy digest",
                s.name
            );
            let r = s.degraded(&[], 2, 0xBEEF).recovery(16 << 10);
            if let Ok(o) = r.run() {
                assert_eq!(
                    o.sink_digest, healthy,
                    "{}: {sched:?}/{tick:?} recovered run delivered corrupt payloads",
                    r.name
                );
            }
        }
    }
}

#[test]
fn every_pattern_survives_a_harvested_row_on_the_16x16_mesh() {
    // One full row harvested down to its bridge tile: the mesh stays
    // connected and every live socket stays reachable, so each builtin
    // pattern must either complete or fail with a structural diagnostic
    // (socket budget, reachability) — the quiesce watchdog would mean a
    // hang, which the harvest validation rules exist to prevent.
    for mut s in builtin_scenarios(Platform::Mesh16x16) {
        s.bytes = 4 << 10;
        s.burst_bytes = 4 << 10;
        let s = s.degraded(&[7], 0, 1);
        match s.run() {
            Ok(o) => {
                assert!(o.cycles > 0, "{}: empty run", s.name);
                assert_eq!(o.dropped_flits, 0, "{}: drops without fault injection", s.name);
            }
            Err(e) => {
                assert!(
                    e.downcast_ref::<QuiesceError>().is_none(),
                    "{}: watchdog fired instead of a structural diagnostic: {e:#}",
                    s.name
                );
                let msg = format!("{e:#}");
                assert!(
                    msg.contains("sockets") || msg.contains("reach"),
                    "{}: diagnostic does not name the structural cause: {msg}",
                    s.name
                );
            }
        }
    }
}
