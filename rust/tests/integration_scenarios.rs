//! End-to-end tests of the declarative scenario subsystem: the builtin
//! registry runs every communication pattern against its DMA-only
//! baseline, scenario files load from JSON, and the bench-compare gate
//! flags doctored regressions with a failing report (the library half of
//! the CI `perf-gate` job's nonzero exit).

use espsim::coordinator::scenario::{builtin_scenarios, Pattern, Platform, Scenario};
use espsim::noc::Plane;
use espsim::util::bench::{compare, CompareOpts};
use espsim::util::Json;

/// Small transfers keep the debug-mode (`cargo test -q`) wall time
/// bounded; the CLI default (64 KiB) runs in the release-mode perf gate.
fn small(mut s: Scenario) -> Scenario {
    s.bytes = 16 << 10;
    s
}

#[test]
fn builtin_registry_runs_every_pattern_on_the_paper_platform() {
    let scenarios = builtin_scenarios(Platform::Paper3x4);
    assert!(scenarios.len() >= 5);
    for s in scenarios.into_iter().map(small) {
        let o = s.run().unwrap_or_else(|e| panic!("{}: {e:#}", s.name));
        assert!(o.cycles > 0 && o.baseline_cycles > 0, "{} measured nothing", s.name);
        assert!(
            o.p2p_bytes > 0,
            "{}: every optimized lowering moves P2P/multicast traffic",
            s.name
        );
        assert!(o.total_flits() > 0, "{}: NoC must carry traffic", s.name);
        assert!(
            o.speedup() > 0.5,
            "{}: optimized lowering pathologically slow ({} vs {})",
            s.name,
            o.cycles,
            o.baseline_cycles
        );
    }
}

#[test]
fn chain_and_fanout_beat_their_dma_baselines() {
    for name in ["chain4", "fanout8"] {
        let s = small(
            builtin_scenarios(Platform::Paper3x4).into_iter().find(|s| s.name == name).unwrap(),
        );
        let o = s.run().unwrap();
        assert!(
            o.speedup() > 1.0,
            "{name}: optimized {} should beat DMA-only {}",
            o.cycles,
            o.baseline_cycles
        );
    }
}

#[test]
fn coherent_phases_ride_the_coherence_planes() {
    let s = small(
        builtin_scenarios(Platform::Paper3x4)
            .into_iter()
            .find(|s| matches!(s.pattern, Pattern::CoherentPhases { .. }))
            .unwrap(),
    );
    let o = s.run().unwrap();
    assert!(
        o.plane_flits[Plane::CohReq.idx()] > 0,
        "flag barriers must put GetM/GetS traffic on the coherence-request plane"
    );
    assert!(o.plane_flits[Plane::CohRsp.idx()] > 0, "and grants on the response plane");
    // The bulk data still rides the DMA planes.
    assert!(o.plane_flits[Plane::DmaRsp.idx()] > 0);
}

#[test]
fn mesh16_platform_runs_a_scenario() {
    let mut s = Scenario::new(
        "chain4_16",
        Pattern::P2pChain { stages: 4 },
        Platform::Mesh16x16,
    );
    s.bytes = 16 << 10;
    let o = s.run().unwrap();
    assert!(o.cycles > 0 && o.p2p_bytes > 0);
}

#[test]
fn scenario_files_load_and_reject_garbage() {
    let dir = std::env::temp_dir().join(format!("espsim_scn_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("scenarios.json");

    // A file covering a custom subset, written from the typed form.
    let subset = vec![
        small(Scenario::new("c2", Pattern::P2pChain { stages: 2 }, Platform::Paper3x4)),
        small(Scenario::new(
            "sh22",
            Pattern::AllToAllShuffle { producers: 2, consumers: 2 },
            Platform::Paper3x4,
        )),
    ];
    let doc = format!(
        "{{\"scenarios\":[{}]}}",
        subset.iter().map(|s| s.to_json().to_string()).collect::<Vec<_>>().join(",")
    );
    std::fs::write(&path, doc).unwrap();
    let loaded = Scenario::load_file(&path).unwrap();
    assert_eq!(loaded, subset);
    // Loaded scenarios actually run.
    let o = loaded[0].run().unwrap();
    assert!(o.cycles > 0);

    // Unknown pattern and empty lists are rejected.
    let bad = "{\"scenarios\":[{\"name\":\"x\",\"pattern\":\"warp\",\"platform\":\"paper_3x4\"}]}";
    std::fs::write(&path, bad).unwrap();
    assert!(Scenario::load_file(&path).is_err());
    std::fs::write(&path, "{\"scenarios\":[]}").unwrap();
    assert!(Scenario::load_file(&path).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

/// The acceptance check for the perf gate: feed `compare` a doctored
/// regression built from a *real* scenario measurement and require a
/// failing report (which `espsim compare` turns into a nonzero exit).
#[test]
fn bench_compare_fails_a_doctored_scenario_regression() {
    let s = small(builtin_scenarios(Platform::Paper3x4).remove(0));
    let o = s.run().unwrap();
    let rec = |cycles: u64, speedup: f64| {
        format!(
            "{{\"records\":[{{\"bench\":\"scenarios_8x8\",\"point\":\"{}\",\
             \"cycles\":{cycles},\"wall_s\":0.1,\"speedup\":{speedup}}}]}}",
            s.name
        )
    };
    let baseline = Json::parse(&rec(o.cycles, o.speedup())).unwrap();
    let honest = compare(&baseline, &baseline, &CompareOpts::default());
    assert!(honest.passed(), "identical rerun must pass the gate");
    // Doctor the fresh run: +25% cycles, -25% speedup.
    let doctored =
        Json::parse(&rec(o.cycles + o.cycles / 4, o.speedup() * 0.75)).unwrap();
    let r = compare(&baseline, &doctored, &CompareOpts::default());
    assert!(!r.passed(), "doctored regression must fail the gate");
    assert!(r.regressions.iter().any(|x| x.metric == "cycles"));
    assert!(r.regressions.iter().any(|x| x.metric == "speedup"));
}

/// Regression test for the completion-0 pollution bug: a degraded sweep
/// that records a failed point (`completed: 0`, placeholder `cycles: 0`)
/// must not poison the `cycles` namespace of later compares.  Before the
/// fix, a doctored baseline holding such a record made ANY healthy fresh
/// measurement look like an unbounded cycles regression (`fresh > 0 *
/// (1 + tol)`), and a fresh failure silently *passed* the
/// higher-is-worse check.
#[test]
fn bench_compare_treats_completion0_records_as_completion_not_cycles() {
    let s = small(builtin_scenarios(Platform::Paper3x4).remove(0));
    let o = s.run().unwrap();
    let healthy = Json::parse(&format!(
        "{{\"records\":[{{\"bench\":\"scenarios_8x8_faults\",\"point\":\"{}\",\
         \"cycles\":{},\"wall_s\":0.1,\"speedup\":{},\"completed\":1}}]}}",
        s.name,
        o.cycles,
        o.speedup()
    ))
    .unwrap();
    let failed = Json::parse(&format!(
        "{{\"records\":[{{\"bench\":\"scenarios_8x8_faults\",\"point\":\"{}\",\
         \"cycles\":0,\"wall_s\":0.1,\"completed\":0,\"failure\":\"quiesce timeout\"}}]}}",
        s.name
    ))
    .unwrap();
    // Doctored baseline with the failed record: the healthy fresh run is
    // an improvement (a point started completing), never a regression.
    let r = compare(&failed, &healthy, &CompareOpts::default());
    assert!(r.passed(), "healthy fresh vs failed baseline must pass: {}", r.render());
    assert!(r.regressions.is_empty());
    // The reverse — a point that used to complete stops completing — is a
    // real regression, reported as `completed`, not as a cycles artifact.
    let r = compare(&healthy, &failed, &CompareOpts::default());
    assert!(!r.passed(), "a point that stops completing must fail the gate");
    assert!(r.regressions.iter().all(|x| x.metric == "completed"), "{}", r.render());
}

/// Regression test for the silent-skip bug: a baseline bench section the
/// fresh run never executed used to vanish into `skipped_benches` with a
/// green exit, so a renamed or dropped bench could evade the gate
/// forever.  `--strict` (CI mode) turns that into a failure; the default
/// stays permissive because the scheduler cross-check compares
/// deliberately partial documents.
#[test]
fn bench_compare_strict_fails_when_a_baseline_bench_never_ran() {
    let both = Json::parse(
        "{\"records\":[\
         {\"bench\":\"scenarios_8x8\",\"point\":\"a\",\"cycles\":100,\"wall_s\":0.1},\
         {\"bench\":\"scenarios_16x16\",\"point\":\"a\",\"cycles\":200,\"wall_s\":0.1}]}",
    )
    .unwrap();
    let only8 = Json::parse(
        "{\"records\":[\
         {\"bench\":\"scenarios_8x8\",\"point\":\"a\",\"cycles\":100,\"wall_s\":0.1}]}",
    )
    .unwrap();
    let lax = compare(&both, &only8, &CompareOpts::default());
    assert!(lax.passed(), "default mode keeps skipping permissive");
    assert_eq!(lax.skipped_benches, vec!["scenarios_16x16".to_string()]);
    let strict = CompareOpts { strict: true, ..CompareOpts::default() };
    let r = compare(&both, &only8, &strict);
    assert!(!r.passed(), "strict mode must fail on a never-ran bench section");
    assert!(r.render().contains("SKIPPED scenarios_16x16"), "{}", r.render());
}
