//! The scaled Fig. 6 sweep on the 16x16 platform: 32 packed consumers and
//! transfers out to 4 MB, end-to-end verified (every consumer's output must
//! equal the producer's input), with every point recorded to
//! `BENCH_noc.json` — so each `cargo test` run refreshes the large-mesh
//! perf baseline alongside the bench-produced records.

use espsim::coordinator::experiments::{run_fig6_point, run_multicast, Fig6Options};
use espsim::util::bench::{time_once, BenchJson};

#[test]
fn fig6_16x16_32_consumers_up_to_4mb_sweep() {
    // Two points keep the debug-mode (`cargo test -q`) wall time bounded:
    // the 32-consumer 1 MB plateau point and the headline 32-consumer 4 MB
    // point; the full grid lives in `fig6_speedup -- --mesh16` (release).
    let opts = Fig6Options::mesh_16x16();
    let mut sink = BenchJson::from_args("fig6_16x16_test");
    for (n, bytes) in [(32usize, 1u32 << 20), (32, 4 << 20)] {
        let (p, wall) = time_once(|| {
            run_fig6_point(n, bytes, &opts)
                .unwrap_or_else(|e| panic!("{n} consumers, {bytes} bytes: {e}"))
        });
        // The multicast+P2P path must beat the sequential shared-memory
        // baseline at every scaled operating point (data verified inside).
        assert!(
            p.speedup() > 1.0,
            "{n} consumers, {bytes} bytes: speedup {:.2} <= 1",
            p.speedup()
        );
        sink.record(
            &format!("fig6_16x16_{n}c_{bytes}B"),
            p.baseline_cycles + p.multicast_cycles,
            wall,
        );
    }
    assert_eq!(sink.len(), 2);
    sink.finish();
}

#[test]
fn fig6_16x16_more_consumers_than_header_capacity_needs_packing() {
    // 32 consumers exceed the 16-destination header on their own; packing
    // two consumer sockets per tile is what makes the transaction fit.
    let packed = Fig6Options::mesh_16x16();
    assert!(run_multicast(32, 64 << 10, &packed).is_ok());
    let unpacked = Fig6Options { pack_consumers: false, ..Fig6Options::mesh_16x16() };
    assert!(
        run_multicast(32, 64 << 10, &unpacked).is_err(),
        "32 unpacked consumers must exceed the 16-destination header"
    );
}

#[test]
fn fig6_16x16_speedup_grows_with_consumers() {
    // The paper's headline trend extends past its 16-consumer axis: the
    // sequential baseline scales linearly with N while one multicast per
    // burst serves all N, so 32 consumers must beat 4.
    let opts = Fig6Options::mesh_16x16();
    let few = run_fig6_point(4, 256 << 10, &opts).unwrap();
    let many = run_fig6_point(32, 256 << 10, &opts).unwrap();
    assert!(
        many.speedup() > few.speedup(),
        "32-consumer speedup {:.2} should exceed 4-consumer {:.2}",
        many.speedup(),
        few.speedup()
    );
}
