//! Golden equivalence: the activity-driven mesh must be **cycle-for-cycle
//! identical** to the seed model's straightforward full-scan scheduler.
//!
//! [`reference`] retains the seed implementation verbatim (per-router
//! `VecDeque` port queues, flits carrying `DestList` + `Arc<Message>`,
//! whole-mesh plan scans, per-router round-robin pointers).  Random
//! unicast/multicast workloads on random mesh shapes run in lockstep on
//! both models; every cycle we assert identical idleness, identical
//! cumulative flit-hops, and identical per-tile delivery sequences (which
//! pins per-message latency *and* delivery order), and at the end identical
//! delivered/injected/busy-cycle counters and quiesce time.

use std::collections::VecDeque;
use std::sync::Arc;

use espsim::noc::routing::neighbor;
use espsim::noc::{
    partition_dests, Coord, DestList, Dir, Mesh, MeshParams, Message, MsgKind, Noc, Plane,
    TickMode, NUM_PLANES,
};
use espsim::util::Prng;

/// The seed mesh model, retained as the golden reference.
mod reference {
    use super::*;

    #[derive(Clone)]
    pub struct RefFlit {
        pub is_head: bool,
        pub is_tail: bool,
        pub dests: DestList,
        pub msg: Arc<Message>,
    }

    #[derive(Clone)]
    pub struct Stamped {
        pub flit: RefFlit,
        pub arrived: u64,
    }

    pub struct RefRouter {
        pub coord: Coord,
        pub inq: [VecDeque<Stamped>; 5],
        pub out_alloc: [Option<u8>; 5],
        pub in_branches: [u8; 5],
        pub in_buffered: [bool; 5],
        pub branch_q: [VecDeque<Stamped>; 5],
        pub rr: u8,
        pub occupancy: u32,
    }

    impl RefRouter {
        fn new(coord: Coord) -> Self {
            Self {
                coord,
                inq: Default::default(),
                out_alloc: [None; 5],
                in_branches: [0; 5],
                in_buffered: [false; 5],
                branch_q: Default::default(),
                rr: 0,
                occupancy: 0,
            }
        }
    }

    struct RefMove {
        router: usize,
        in_port: usize,
        out_mask: u8,
        branch_dests: [DestList; 5],
    }

    #[derive(Default)]
    struct Inject {
        queue: VecDeque<Arc<Message>>,
        cur: Option<(Arc<Message>, u32, u32)>,
    }

    /// Seed-model plane: plan/apply over every router, every cycle.
    pub struct RefMesh {
        p: MeshParams,
        routers: Vec<RefRouter>,
        inject: Vec<Inject>,
        eject: Vec<VecDeque<Arc<Message>>>,
        planned: Vec<[u8; 5]>,
        work: u64,
        inject_msgs: u64,
        pub flit_hops: u64,
        pub delivered: u64,
        pub injected: u64,
        pub busy_cycles: u64,
    }

    impl RefMesh {
        pub fn new(p: MeshParams) -> Self {
            let n = p.width as usize * p.height as usize;
            let mut routers = Vec::with_capacity(n);
            for y in 0..p.height {
                for x in 0..p.width {
                    routers.push(RefRouter::new((y, x)));
                }
            }
            Self {
                p,
                routers,
                inject: (0..n).map(|_| Inject::default()).collect(),
                eject: (0..n).map(|_| VecDeque::new()).collect(),
                planned: vec![[0; 5]; n],
                work: 0,
                inject_msgs: 0,
                flit_hops: 0,
                delivered: 0,
                injected: 0,
                busy_cycles: 0,
            }
        }

        fn idx(&self, c: Coord) -> usize {
            c.0 as usize * self.p.width as usize + c.1 as usize
        }

        pub fn send(&mut self, tile: Coord, msg: Message) {
            let i = self.idx(tile);
            self.inject[i].queue.push_back(Arc::new(msg));
            self.work += 1;
            self.inject_msgs += 1;
        }

        pub fn recv(&mut self, tile: Coord) -> Option<Arc<Message>> {
            let i = self.idx(tile);
            self.eject[i].pop_front()
        }

        pub fn is_idle(&self) -> bool {
            self.work == 0
        }

        fn flit_count(&self, msg: &Message) -> u32 {
            1 + (msg.payload.len() as u32).div_ceil(self.p.flit_bytes)
        }

        pub fn tick(&mut self, now: u64) {
            if self.work == 0 {
                return;
            }
            self.planned.iter_mut().for_each(|p| *p = [0; 5]);
            let mut moved = false;

            // Injection: stream one flit per tile into the local port.
            if self.inject_msgs > 0 {
                for i in 0..self.routers.len() {
                    if self.routers[i].inq[Dir::Local.idx()].len() >= self.p.queue_depth {
                        continue;
                    }
                    if self.inject[i].cur.is_none() {
                        if let Some(msg) = self.inject[i].queue.pop_front() {
                            let total = self.flit_count(&msg);
                            self.inject[i].cur = Some((msg, 0, total));
                        }
                    }
                    if let Some((msg, next, total)) = self.inject[i].cur.take() {
                        let flit = RefFlit {
                            is_head: next == 0,
                            is_tail: next + 1 == total,
                            dests: msg.dests,
                            msg: msg.clone(),
                        };
                        self.routers[i].inq[Dir::Local.idx()]
                            .push_back(Stamped { flit, arrived: now });
                        self.injected += 1;
                        self.work += 1;
                        self.routers[i].occupancy += 1;
                        moved = true;
                        if next + 1 < total {
                            self.inject[i].cur = Some((msg, next + 1, total));
                        } else {
                            self.work -= 1;
                            self.inject_msgs -= 1;
                        }
                    }
                }
            }

            // Plan.
            let mut drains: Vec<(usize, usize)> = Vec::new();
            let mut moves: Vec<RefMove> = Vec::new();
            for r in 0..self.routers.len() {
                let router = &self.routers[r];
                if router.occupancy == 0 {
                    continue;
                }
                let mut out_busy = [false; 5];
                let mut claimed = [false; 5];
                for d in Dir::ALL {
                    let o = d.idx();
                    let Some(sf) = router.branch_q[o].front() else { continue };
                    if sf.arrived >= now {
                        continue;
                    }
                    if d != Dir::Local {
                        let nc = neighbor(router.coord, d, self.p.width, self.p.height)
                            .expect("fork branch routes off mesh edge");
                        let ni = self.idx(nc);
                        let np = d.opposite().idx();
                        if self.routers[ni].inq[np].len() + self.planned[ni][np] as usize
                            >= self.p.queue_depth
                        {
                            continue;
                        }
                        self.planned[ni][np] += 1;
                    }
                    out_busy[o] = true;
                    drains.push((r, o));
                }
                for k in 0..5 {
                    let in_port = (router.rr as usize + k) % 5;
                    let Some(sf) = router.inq[in_port].front() else { continue };
                    if sf.arrived >= now {
                        continue;
                    }
                    let flit = &sf.flit;
                    let is_fork_body = !flit.is_head && router.in_buffered[in_port];
                    let (mask, branch_dests) = if flit.is_head {
                        partition_dests(router.coord, &flit.dests)
                    } else {
                        (router.in_branches[in_port], Default::default())
                    };
                    if mask == 0 {
                        continue;
                    }
                    let is_fork = mask.count_ones() > 1 || is_fork_body;
                    if is_fork {
                        if flit.is_head {
                            let clash = Dir::ALL.iter().any(|d| {
                                let o = d.idx();
                                mask & (1 << o) != 0
                                    && (router.out_alloc[o].is_some() || claimed[o])
                            });
                            if clash {
                                continue;
                            }
                            for o in 0..5 {
                                if mask & (1 << o) != 0 {
                                    claimed[o] = true;
                                }
                            }
                        }
                        moves.push(RefMove { router: r, in_port, out_mask: mask, branch_dests });
                        continue;
                    }
                    let o = mask.trailing_zeros() as usize;
                    let d = Dir::ALL[o];
                    if out_busy[o] {
                        continue;
                    }
                    if flit.is_head && (router.out_alloc[o].is_some() || claimed[o]) {
                        continue;
                    }
                    if d != Dir::Local {
                        let nc = neighbor(router.coord, d, self.p.width, self.p.height)
                            .expect("route off mesh edge");
                        let ni = self.idx(nc);
                        let np = d.opposite().idx();
                        if self.routers[ni].inq[np].len() + self.planned[ni][np] as usize
                            >= self.p.queue_depth
                        {
                            continue;
                        }
                        self.planned[ni][np] += 1;
                    }
                    out_busy[o] = true;
                    if flit.is_head {
                        claimed[o] = true;
                    }
                    moves.push(RefMove { router: r, in_port, out_mask: mask, branch_dests });
                }
            }

            // Apply: replication-buffer drains.
            for &(r, o) in &drains {
                let Stamped { flit, .. } =
                    self.routers[r].branch_q[o].pop_front().expect("planned drain");
                self.work -= 1;
                self.routers[r].occupancy -= 1;
                let coord = self.routers[r].coord;
                self.flit_hops += 1;
                let d = Dir::ALL[o];
                if d == Dir::Local {
                    if flit.is_tail {
                        self.eject[r].push_back(flit.msg.clone());
                        self.delivered += 1;
                    }
                } else {
                    let nc = neighbor(coord, d, self.p.width, self.p.height).unwrap();
                    let ni = self.idx(nc);
                    self.routers[ni].inq[d.opposite().idx()]
                        .push_back(Stamped { flit: flit.clone(), arrived: now });
                    self.work += 1;
                    self.routers[ni].occupancy += 1;
                }
                if flit.is_tail {
                    self.routers[r].out_alloc[o] = None;
                }
                moved = true;
            }

            // Apply: input-port moves.
            for m in &moves {
                let Stamped { flit, .. } =
                    self.routers[m.router].inq[m.in_port].pop_front().expect("planned flit");
                self.work -= 1;
                self.routers[m.router].occupancy -= 1;
                let coord = self.routers[m.router].coord;
                let is_head = flit.is_head;
                let is_tail = flit.is_tail;
                let is_fork = m.out_mask.count_ones() > 1
                    || self.routers[m.router].in_buffered[m.in_port];
                if is_fork {
                    for d in Dir::ALL {
                        let o = d.idx();
                        if m.out_mask & (1 << o) == 0 {
                            continue;
                        }
                        let mut fwd = flit.clone();
                        if is_head {
                            fwd.dests = m.branch_dests[o];
                        }
                        self.routers[m.router].branch_q[o]
                            .push_back(Stamped { flit: fwd, arrived: now });
                        self.work += 1;
                        self.routers[m.router].occupancy += 1;
                    }
                    let router = &mut self.routers[m.router];
                    if is_head {
                        for o in 0..5 {
                            if m.out_mask & (1 << o) != 0 {
                                router.out_alloc[o] = Some(m.in_port as u8);
                            }
                        }
                        if !is_tail {
                            router.in_branches[m.in_port] = m.out_mask;
                            router.in_buffered[m.in_port] = true;
                        }
                    } else if is_tail {
                        router.in_branches[m.in_port] = 0;
                        router.in_buffered[m.in_port] = false;
                    }
                    moved = true;
                    continue;
                }
                let o = m.out_mask.trailing_zeros() as usize;
                let d = Dir::ALL[o];
                self.flit_hops += 1;
                if d == Dir::Local {
                    if is_tail {
                        self.eject[m.router].push_back(flit.msg.clone());
                        self.delivered += 1;
                    }
                } else {
                    let nc = neighbor(coord, d, self.p.width, self.p.height).unwrap();
                    let ni = self.idx(nc);
                    let mut fwd = flit.clone();
                    if is_head {
                        fwd.dests = m.branch_dests[o];
                    }
                    self.routers[ni].inq[d.opposite().idx()]
                        .push_back(Stamped { flit: fwd, arrived: now });
                    self.work += 1;
                    self.routers[ni].occupancy += 1;
                }
                let router = &mut self.routers[m.router];
                if is_head && !is_tail {
                    router.in_branches[m.in_port] = m.out_mask;
                    router.out_alloc[o] = Some(m.in_port as u8);
                } else if is_tail && !is_head {
                    router.in_branches[m.in_port] = 0;
                    router.out_alloc[o] = None;
                }
                moved = true;
            }

            for r in &mut self.routers {
                r.rr = (r.rr + 1) % 5;
            }
            if moved {
                self.busy_cycles += 1;
            }
        }
    }
}

use reference::RefMesh;

/// One scheduled message of a workload.
struct Send {
    cycle: u64,
    src: Coord,
    msg: Message,
}

fn msg_seq(m: &Message) -> u32 {
    match m.kind {
        MsgKind::P2pData { seq, .. } => seq,
        _ => panic!("unexpected kind"),
    }
}

/// Run `sends` on both models in lockstep, asserting cycle-level equality.
fn run_equiv(case: usize, p: MeshParams, mut sends: Vec<Send>) {
    sends.sort_by_key(|s| s.cycle);
    let mut opt = Mesh::new(p);
    let mut gold = RefMesh::new(p);
    let mut next = 0usize;
    let mut t = 0u64;
    let total = sends.len();
    let mut delivered_pairs = 0u64;
    loop {
        while next < sends.len() && sends[next].cycle == t {
            let s = &sends[next];
            opt.send(s.src, s.msg.clone());
            gold.send(s.src, s.msg.clone());
            next += 1;
        }
        opt.tick(t);
        gold.tick(t);
        t += 1;
        assert_eq!(
            opt.is_idle(),
            gold.is_idle(),
            "case {case}: idleness diverged at cycle {t}"
        );
        assert_eq!(
            opt.stats.flit_hops, gold.flit_hops,
            "case {case}: flit-hops diverged at cycle {t}"
        );
        // Per-tile delivery sequences: same messages, same order, same cycle
        // (this pins per-message latency exactly, not just the multiset).
        for y in 0..p.height {
            for x in 0..p.width {
                let c = (y, x);
                loop {
                    match (opt.recv(c), gold.recv(c)) {
                        (None, None) => break,
                        (Some(a), Some(b)) => {
                            assert_eq!(
                                msg_seq(&a),
                                msg_seq(&b),
                                "case {case}: delivery order diverged at {c:?} cycle {t}"
                            );
                            assert_eq!(a.src, b.src, "case {case}: src diverged");
                            assert_eq!(*a.payload, *b.payload, "case {case}: payload diverged");
                            delivered_pairs += 1;
                        }
                        (a, b) => panic!(
                            "case {case}: delivery presence diverged at {c:?} cycle {t}: \
                             opt={:?} gold={:?}",
                            a.map(|m| msg_seq(&m)),
                            b.map(|m| msg_seq(&m))
                        ),
                    }
                }
            }
        }
        if next == sends.len() && opt.is_idle() && gold.is_idle() {
            break;
        }
        assert!(t < 4_000_000, "case {case}: meshes did not drain ({total} sends)");
    }
    assert_eq!(opt.stats.delivered, gold.delivered, "case {case}: delivered total");
    assert_eq!(opt.stats.injected, gold.injected, "case {case}: injected total");
    assert_eq!(opt.stats.busy_cycles, gold.busy_cycles, "case {case}: busy cycles");
    assert_eq!(opt.stats.delivered, delivered_pairs, "case {case}: drained everything");
}

#[test]
fn prop_equivalent_on_random_workloads() {
    let mut rng = Prng::new(0x5EED_CAFE);
    for case in 0..40 {
        let w = rng.range(2, 5) as u8;
        let h = rng.range(2, 5) as u8;
        let p = MeshParams {
            width: w,
            height: h,
            flit_bytes: *rng.pick(&[8u32, 16, 32]),
            queue_depth: rng.range(2, 5) as usize,
        };
        let n_msgs = rng.range(1, 14);
        let mut sends = Vec::new();
        for seq in 0..n_msgs {
            let src = (rng.below(h as u64) as u8, rng.below(w as u64) as u8);
            let fanout = rng.range(1, 6) as usize;
            let mut dests = DestList::new();
            let mut uniq: Vec<Coord> = Vec::new();
            for _ in 0..fanout {
                let d = (rng.below(h as u64) as u8, rng.below(w as u64) as u8);
                if !uniq.contains(&d) {
                    uniq.push(d);
                    dests.push(d);
                }
            }
            // Occasionally duplicate a destination: the header dedups at
            // delivery (one copy per tile) and both models must agree.
            if rng.chance(0.2) {
                dests.push(*rng.pick(&uniq));
            }
            let len = rng.range(0, 4000) as usize;
            let payload = Arc::new(vec![rng.next_u64() as u8; len]);
            sends.push(Send {
                cycle: rng.range(0, 60),
                src,
                msg: Message::multicast(
                    src,
                    dests,
                    MsgKind::P2pData { seq: seq as u32, prod_slot: 0 },
                    payload,
                ),
            });
        }
        run_equiv(case, p, sends);
    }
}

#[test]
fn prop_equivalent_under_heavy_contention() {
    // Every tile floods one hotspot with multi-flit packets through tiny
    // queues: maximal backpressure, arbitration, and wormhole interleaving.
    let mut rng = Prng::new(0xC047E57);
    for (case, &depth) in [2usize, 3].iter().enumerate() {
        let p = MeshParams { width: 4, height: 3, flit_bytes: 8, queue_depth: depth };
        let mut sends = Vec::new();
        let mut seq = 0u32;
        for y in 0..3u8 {
            for x in 0..4u8 {
                for _ in 0..2 {
                    let len = rng.range(1, 300) as usize;
                    sends.push(Send {
                        cycle: rng.range(0, 8),
                        src: (y, x),
                        msg: Message::data(
                            (y, x),
                            (1, 2),
                            MsgKind::P2pData { seq, prod_slot: 0 },
                            Arc::new(vec![seq as u8; len]),
                        ),
                    });
                    seq += 1;
                }
            }
        }
        run_equiv(100 + case, p, sends);
    }
}

#[test]
fn prop_equivalent_on_large_meshes() {
    // The generalized coordinate bound: random 9..=16-wide meshes, random
    // multicast workloads, still cycle-for-cycle identical to the seed
    // full-scan scheduler.
    let mut rng = Prng::new(0x1616_5EED);
    for case in 0..8 {
        let w = rng.range(9, 16) as u8;
        let h = rng.range(9, 16) as u8;
        let p = MeshParams {
            width: w,
            height: h,
            flit_bytes: *rng.pick(&[16u32, 32]),
            queue_depth: rng.range(2, 4) as usize,
        };
        let n_msgs = rng.range(2, 10);
        let mut sends = Vec::new();
        for seq in 0..n_msgs {
            let src = (rng.below(h as u64) as u8, rng.below(w as u64) as u8);
            let fanout = rng.range(1, 12) as usize;
            let mut dests = DestList::new();
            let mut uniq: Vec<Coord> = Vec::new();
            for _ in 0..fanout {
                let d = (rng.below(h as u64) as u8, rng.below(w as u64) as u8);
                if !uniq.contains(&d) {
                    uniq.push(d);
                    dests.push(d);
                }
            }
            let len = rng.range(0, 2500) as usize;
            sends.push(Send {
                cycle: rng.range(0, 80),
                src,
                msg: Message::multicast(
                    src,
                    dests,
                    MsgKind::P2pData { seq: seq as u32, prod_slot: 0 },
                    Arc::new(vec![rng.next_u64() as u8; len]),
                ),
            });
        }
        run_equiv(300 + case, p, sends);
    }
}

#[test]
fn prop_noc_equivalent_under_mixed_plane_activity() {
    // Six reference planes vs one Noc with traffic spread across all six
    // planes at once, in every tick-scheduling mode: per-plane idleness,
    // flit-hops, and per-tile delivery sequences must stay identical.
    let mut rng = Prng::new(0xA11_6_9_16);
    for (case, &mode) in
        [TickMode::Sequential, TickMode::Parallel, TickMode::Auto].iter().enumerate()
    {
        let w = rng.range(9, 14) as u8;
        let h = rng.range(9, 14) as u8;
        let p = MeshParams { width: w, height: h, flit_bytes: 16, queue_depth: 3 };
        let mut noc = Noc::new(p);
        noc.set_tick_mode(mode);
        let mut golds: Vec<RefMesh> = (0..NUM_PLANES).map(|_| RefMesh::new(p)).collect();
        let mut sends: Vec<(u64, usize, Send)> = Vec::new();
        for seq in 0..20u32 {
            let plane = rng.below(NUM_PLANES as u64) as usize;
            let src = (rng.below(h as u64) as u8, rng.below(w as u64) as u8);
            let mut dests = DestList::new();
            let mut uniq: Vec<Coord> = Vec::new();
            for _ in 0..rng.range(1, 6) {
                let d = (rng.below(h as u64) as u8, rng.below(w as u64) as u8);
                if !uniq.contains(&d) {
                    uniq.push(d);
                    dests.push(d);
                }
            }
            let msg = Message::multicast(
                src,
                dests,
                MsgKind::P2pData { seq, prod_slot: 0 },
                Arc::new(vec![seq as u8; rng.range(0, 1200) as usize]),
            );
            sends.push((rng.range(0, 50), plane, Send { cycle: 0, src, msg }));
        }
        sends.sort_by_key(|(cycle, plane, _)| (*cycle, *plane));
        let mut next = 0usize;
        let mut t = 0u64;
        loop {
            while next < sends.len() && sends[next].0 == t {
                let (_, plane, s) = &sends[next];
                noc.send(Plane::ALL[*plane], s.src, s.msg.clone());
                golds[*plane].send(s.src, s.msg.clone());
                next += 1;
            }
            noc.tick(t);
            for g in &mut golds {
                g.tick(t);
            }
            t += 1;
            let stats = noc.stats();
            for (pi, g) in golds.iter_mut().enumerate() {
                assert_eq!(
                    stats[pi].flit_hops, g.flit_hops,
                    "case {case} ({mode:?}): plane {pi} hops diverged at cycle {t}"
                );
                for y in 0..h {
                    for x in 0..w {
                        let c = (y, x);
                        loop {
                            match (noc.recv(Plane::ALL[pi], c), g.recv(c)) {
                                (None, None) => break,
                                (Some(a), Some(b)) => {
                                    assert_eq!(
                                        msg_seq(&a),
                                        msg_seq(&b),
                                        "case {case}: plane {pi} order diverged at {c:?}"
                                    );
                                }
                                (a, b) => panic!(
                                    "case {case}: plane {pi} delivery diverged at {c:?} \
                                     cycle {t}: noc={:?} gold={:?}",
                                    a.map(|m| msg_seq(&m)),
                                    b.map(|m| msg_seq(&m))
                                ),
                            }
                        }
                    }
                }
            }
            assert_eq!(
                noc.is_idle(),
                golds.iter().all(|g| g.is_idle()),
                "case {case}: idleness diverged at cycle {t}"
            );
            if next == sends.len() && noc.is_idle() {
                break;
            }
            assert!(t < 2_000_000, "case {case}: did not drain");
        }
        let stats = noc.stats();
        for (pi, g) in golds.iter().enumerate() {
            assert_eq!(stats[pi].delivered, g.delivered, "case {case}: plane {pi} delivered");
            assert_eq!(stats[pi].injected, g.injected, "case {case}: plane {pi} injected");
            assert_eq!(
                stats[pi].busy_cycles, g.busy_cycles,
                "case {case}: plane {pi} busy cycles"
            );
        }
    }
}

#[test]
fn prop_equivalent_on_wide_multicasts() {
    // Max-fanout multicasts (up to the 16-dest header cap) from a single
    // producer, mirroring the paper's Fig. 6 traffic shape.
    let mut rng = Prng::new(0xFA70);
    for case in 0..8 {
        let p = MeshParams { width: 5, height: 4, flit_bytes: 16, queue_depth: 4 };
        let mut dests = DestList::new();
        let mut uniq = Vec::new();
        let fanout = rng.range(8, 16);
        for _ in 0..fanout {
            let d = (rng.below(4) as u8, rng.below(5) as u8);
            if !uniq.contains(&d) {
                uniq.push(d);
                dests.push(d);
            }
        }
        let mut sends = Vec::new();
        for seq in 0..3u32 {
            sends.push(Send {
                cycle: seq as u64 * rng.range(1, 20),
                src: (0, 0),
                msg: Message::multicast(
                    (0, 0),
                    dests,
                    MsgKind::P2pData { seq, prod_slot: 0 },
                    Arc::new(vec![seq as u8; rng.range(100, 2000) as usize]),
                ),
            });
        }
        run_equiv(200 + case, p, sends);
    }
}
