//! DMA path integration on the full SoC: single invocations streaming
//! through memory, burst-size sweeps, TLB behaviour, and DMA statistics.

use espsim::accel::traffic_gen::TgenArgs;
use espsim::config::SocConfig;
use espsim::coordinator::{App, Invocation, Soc};

const IN: u64 = 0x10_0000;
const OUT: u64 = 0x30_0000;

fn pattern(n: usize) -> Vec<u8> {
    (0..n).map(|i| (i as u64).wrapping_mul(0x61C8_8647) as u8).collect()
}

fn stream_through_memory(total: u32, burst: u32, cfg: SocConfig) -> (u64, Soc) {
    let mut soc = Soc::new(cfg).unwrap();
    let data = pattern(total as usize);
    soc.write_mem(IN, &data);
    let inv = Invocation::tgen(
        0,
        TgenArgs {
            total_bytes: total,
            burst_bytes: burst,
            rd_user: 0,
            wr_user: 0,
            vaddr_in: IN,
            vaddr_out: OUT,
        },
    );
    App::new().phase(vec![inv]).launch(&mut soc).unwrap();
    let cycles = soc.run(50_000_000).unwrap();
    assert_eq!(soc.read_mem(OUT, total as usize), data, "stream corrupted");
    (cycles, soc)
}

#[test]
fn single_burst_roundtrip() {
    stream_through_memory(4096, 4096, SocConfig::small_3x3());
}

#[test]
fn many_bursts_roundtrip() {
    stream_through_memory(128 << 10, 4096, SocConfig::small_3x3());
}

#[test]
fn small_bursts_roundtrip() {
    stream_through_memory(16 << 10, 512, SocConfig::small_3x3());
}

#[test]
fn larger_bursts_are_faster() {
    // Per-burst overheads (request round trip) amortize with burst size.
    let (c_small, _) = stream_through_memory(64 << 10, 1024, SocConfig::small_3x3());
    let (c_large, _) = stream_through_memory(64 << 10, 4096, SocConfig::small_3x3());
    assert!(c_large < c_small, "4KB bursts {c_large} !< 1KB bursts {c_small}");
}

#[test]
fn dma_stats_account_all_bytes() {
    let total = 32 << 10;
    let (_, mut soc) = stream_through_memory(total, 4096, SocConfig::small_3x3());
    let report = soc.report();
    assert_eq!(report.mem.read_bytes, total as u64);
    assert_eq!(report.mem.write_bytes, total as u64);
    let (_, s0) = &report.sockets[0];
    assert_eq!(s0.dma_read_bytes, total as u64);
    assert_eq!(s0.dma_write_bytes, total as u64);
    assert_eq!(s0.p2p_read_bytes + s0.p2p_write_bytes, 0);
    assert_eq!(report.cpu.irqs, 1);
    assert_eq!(report.invocations.len(), 1);
}

#[test]
fn wide_noc_streams_faster() {
    let mut narrow = SocConfig::small_3x3();
    narrow.noc.bitwidth = 64;
    let (c_narrow, _) = stream_through_memory(64 << 10, 4096, narrow);
    let (c_wide, _) = stream_through_memory(64 << 10, 4096, SocConfig::small_3x3());
    assert!(
        c_wide < c_narrow,
        "256-bit NoC {c_wide} should beat 64-bit {c_narrow} on bulk DMA"
    );
}

#[test]
fn coherent_dma_mode_hits_llc() {
    // dma_through_llc: a second pass over the same data hits the LLC and
    // completes faster than the cold pass.
    let mut cfg = SocConfig::small_3x3();
    cfg.mem.dma_through_llc = true;
    let mut soc = Soc::new(cfg).unwrap();
    let total = 32 << 10;
    let data = pattern(total);
    soc.write_mem(IN, &data);
    let inv = |out| {
        Invocation::tgen(
            0,
            TgenArgs {
                total_bytes: total as u32,
                burst_bytes: 4096,
                rd_user: 0,
                wr_user: 0,
                vaddr_in: IN,
                vaddr_out: out,
            },
        )
    };
    App::new().phase(vec![inv(OUT)]).phase(vec![inv(OUT + 0x10_0000)]).launch(&mut soc).unwrap();
    soc.run(50_000_000).unwrap();
    let report = soc.report();
    assert!(report.mem.llc_hits > 0, "second pass should hit the LLC");
    let inv1 = report.invocations[0];
    let inv2 = report.invocations[1];
    assert!(
        inv2.2 - inv2.1 < inv1.2 - inv1.1,
        "warm invocation {} !< cold invocation {}",
        inv2.2 - inv2.1,
        inv1.2 - inv1.1
    );
}
