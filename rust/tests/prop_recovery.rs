//! Fault-recovery properties (DESIGN.md §fault recovery):
//!
//! 1. **Replay off is free**: `recovery(0)` is an identity copy, and runs
//!    with the ring disarmed keep every recovery counter at zero — the
//!    healthy hot path is byte-identical to the pre-recovery simulator.
//! 2. **Replay on a healthy run changes nothing**: same cycles, same
//!    per-plane flit counts, same payload digest; the ring only buffers.
//! 3. **16x16 link storms with replay armed** either complete with the
//!    healthy run's sink digest (true recovery) or fail with an
//!    *explained* diagnosis — a latched socket fault (replay window
//!    exceeded / dead-link blackhole) or a forensic dump proving the storm
//!    hit traffic.  An unexplained hang means a wedged worm the drain
//!    failed to retire, which is exactly the bug this suite guards.
//! 4. **Drained routers return to service**: severing a worm mid-stream
//!    retires the downstream allocations and the same routers then deliver
//!    fresh traffic.

use std::sync::Arc;

use espsim::coordinator::scenario::{builtin_scenarios, Pattern, Platform, Scenario};
use espsim::noc::{Dir, Mesh, MeshParams, Message, MsgKind, RouteTable};
use espsim::QuiesceError;

fn chain(platform: Platform) -> Scenario {
    let mut s = Scenario::new("chain", Pattern::P2pChain { stages: 3 }, platform);
    s.bytes = 8 << 10;
    s
}

#[test]
fn recovery_zero_is_an_identity_copy() {
    let s = chain(Platform::Mesh8x8);
    let off = s.recovery(0);
    assert_eq!(s.name, off.name, "recovery(0) must not rename the scenario");
    let a = s.run().expect("healthy run");
    let b = off.run().expect("healthy run via recovery(0)");
    assert_eq!(format!("{a:?}"), format!("{b:?}"), "recovery(0) perturbed the outcome");
    assert_eq!(a.replayed_bytes, 0);
    assert_eq!(a.drained_worms, 0);
    assert!(!a.recovered);
}

#[test]
fn armed_replay_ring_is_invisible_on_a_healthy_run() {
    let s = chain(Platform::Mesh8x8);
    let a = s.run().expect("healthy run");
    let c = s.recovery(64 << 10).run().expect("healthy run with replay armed");
    assert_eq!(a.cycles, c.cycles, "replay ring perturbed healthy timing");
    assert_eq!(a.plane_flits, c.plane_flits, "replay ring injected traffic");
    assert_eq!(a.sink_digest, c.sink_digest, "replay ring corrupted payloads");
    assert_eq!(c.replayed_bytes, 0, "nothing stalled, nothing to replay");
    assert!(!c.recovered);
}

#[test]
fn link_storms_with_replay_complete_with_healthy_digests_or_diagnose() {
    // Every builtin pattern, 16x16 platform, a 3-link storm, replay armed.
    // Whatever the storm hits, the run must end in one of two explained
    // states; a quiesce failure whose dump shows neither a diagnosed
    // socket fault nor dropped traffic would be an undrained wedge.
    for mut s in builtin_scenarios(Platform::Mesh16x16) {
        s.bytes = 4 << 10;
        s.burst_bytes = 4 << 10;
        let healthy =
            s.run().unwrap_or_else(|e| panic!("{}: healthy run failed: {e:#}", s.name));
        let storm = s.degraded(&[], 3, 0xD1CE).recovery(16 << 10);
        match storm.run() {
            Ok(o) => {
                assert_eq!(
                    o.sink_digest, healthy.sink_digest,
                    "{}: recovered run delivered corrupt payloads",
                    storm.name
                );
                assert!(o.cycles > 0, "{}: empty run", storm.name);
                // `recovered` is exactly "the replay path retransmitted".
                assert_eq!(o.recovered, o.replayed_bytes > 0, "{}", storm.name);
            }
            Err(e) => {
                // A non-watchdog error is a structural diagnosis and thus
                // explained by construction; a watchdog error must carry a
                // diagnosed cause or dropped-traffic evidence in its dump.
                if e.downcast_ref::<QuiesceError>().is_some() {
                    let msg = format!("{e:#}");
                    assert!(
                        msg.contains("replay window exceeded")
                            || msg.contains("blackhole")
                            || msg.contains("flits dropped"),
                        "{}: unexplained hang (wedge the drain missed?): {msg}",
                        storm.name
                    );
                }
            }
        }
    }
}

#[test]
fn storm_failures_are_deterministic_with_replay_armed() {
    // The recovery path sits on the fault path, so it inherits the fault
    // model's determinism obligation: byte-identical outcome or error,
    // run to run.
    let s = chain(Platform::Mesh8x8).degraded(&[], 4, 17).recovery(4 << 10);
    let fp = |s: &Scenario| match s.run() {
        Ok(o) => format!("ok: {o:?}"),
        Err(e) => format!("err: {e:#}"),
    };
    assert_eq!(fp(&s), fp(&s), "{}: repeat storm run diverged", s.name);
}

#[test]
fn drained_routers_accept_fresh_traffic() {
    // Mesh-level restatement of the drain guarantee through the public
    // API: cut a worm mid-stream, wait for the drain, then route a fresh
    // message through the previously wedged segment.
    let mut m = Mesh::new(MeshParams { width: 6, height: 1, flit_bytes: 8, queue_depth: 4 });
    m.send(
        (0, 0),
        Message::data(
            (0, 0),
            (0, 5),
            MsgKind::P2pData { seq: 0, prod_slot: 0 },
            Arc::new(vec![9u8; 512]),
        ),
    );
    for t in 0..12 {
        m.tick(t);
    }
    m.set_route_table(Arc::new(RouteTable::build(6, 1, &[], &[((0, 1), Dir::East)])));
    let mut t = 12;
    while !m.is_idle() {
        m.tick(t);
        t += 1;
        assert!(t < 2000, "severed worm wedged the mesh");
    }
    assert!(m.stats.drained_worms > 0, "no worm drained after the cut");
    assert!(m.stats.dropped_flits > 0, "severed flits were not retired");
    // The far segment is back in service end to end.
    m.send(
        (0, 2),
        Message::data(
            (0, 2),
            (0, 5),
            MsgKind::P2pData { seq: 1, prod_slot: 0 },
            Arc::new(vec![5u8; 64]),
        ),
    );
    while !m.is_idle() {
        m.tick(t);
        t += 1;
        assert!(t < 4000, "post-drain segment did not drain");
    }
    let got = m.recv((0, 5)).expect("post-drain delivery");
    assert!(matches!(got.kind, MsgKind::P2pData { seq: 1, .. }));
    assert!(got.payload.iter().all(|&x| x == 5));
}
