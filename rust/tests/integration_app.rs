//! End-to-end integration: full SoC runs of the Fig. 6 workloads at small
//! sizes, verifying cycle-accurate completion AND data integrity through
//! the whole stack (host script -> reg writes over the NoC -> ISA programs
//! -> socket DMA/P2P/multicast -> memory tile -> verification).

use espsim::config::SocConfig;
use espsim::coordinator::experiments::{
    run_baseline, run_fig6_point, run_multicast, Fig6Options,
};

fn opts() -> Fig6Options {
    Fig6Options::default()
}

#[test]
fn baseline_single_consumer_4kb() {
    let cycles = run_baseline(1, 4096, &opts()).expect("baseline runs and verifies");
    assert!(cycles > 0);
}

#[test]
fn p2p_unicast_single_consumer_4kb() {
    let cycles = run_multicast(1, 4096, &opts()).expect("unicast P2P runs and verifies");
    assert!(cycles > 0);
}

#[test]
fn multicast_four_consumers_16kb() {
    let cycles = run_multicast(4, 16 << 10, &opts()).expect("multicast runs and verifies");
    assert!(cycles > 0);
}

#[test]
fn multicast_sixteen_consumers_4kb() {
    run_multicast(16, 4096, &opts()).expect("max fan-out runs and verifies");
}

#[test]
fn p2p_beats_baseline_at_4kb() {
    let p = run_fig6_point(1, 4096, &opts()).unwrap();
    assert!(
        p.speedup() > 1.0,
        "P2P should beat shared memory: baseline {} vs multicast {}",
        p.baseline_cycles,
        p.multicast_cycles
    );
}

#[test]
fn multicast_speedup_grows_with_consumers() {
    let p1 = run_fig6_point(1, 16 << 10, &opts()).unwrap();
    let p8 = run_fig6_point(8, 16 << 10, &opts()).unwrap();
    assert!(
        p8.speedup() > p1.speedup(),
        "more consumers, more speedup: {} vs {}",
        p8.speedup(),
        p1.speedup()
    );
}

#[test]
fn speedup_grows_with_data_size() {
    // The size trend is strongest at low fan-out (at high N the sequential
    // baseline is already invocation-dominated at every size).
    let small = run_fig6_point(1, 4 << 10, &opts()).unwrap();
    let large = run_fig6_point(1, 64 << 10, &opts()).unwrap();
    assert!(
        large.speedup() > small.speedup(),
        "burst pipelining should help larger data: {} vs {}",
        large.speedup(),
        small.speedup()
    );
}

#[test]
fn single_buffered_ablation_is_slower() {
    let mut single = opts();
    single.single_buffered = true;
    let db = run_multicast(2, 32 << 10, &opts()).unwrap();
    let sb = run_multicast(2, 32 << 10, &single).unwrap();
    assert!(sb > db, "double buffering must help: single {sb} vs double {db}");
}

#[test]
fn runs_are_deterministic() {
    let a = run_fig6_point(2, 8 << 10, &opts()).unwrap();
    let b = run_fig6_point(2, 8 << 10, &opts()).unwrap();
    assert_eq!(a.baseline_cycles, b.baseline_cycles);
    assert_eq!(a.multicast_cycles, b.multicast_cycles);
}

#[test]
fn works_on_small_3x3_platform() {
    let mut o = opts();
    o.soc = SocConfig::small_3x3();
    run_fig6_point(2, 8 << 10, &o).expect("3x3 platform runs");
}

#[test]
fn narrow_noc_64bit_multicast() {
    let mut o = opts();
    o.soc.noc.bitwidth = 64;
    let p = run_fig6_point(4, 8 << 10, &o).expect("64-bit NoC supports up to 5 dests");
    assert!(p.speedup() > 0.5);
}
