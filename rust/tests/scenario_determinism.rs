//! Scenario determinism: the same scenario + seed + tick mode must produce
//! a byte-identical `Outcome` — cycles, per-plane flit/delivery stats,
//! byte counters, and invocation spans (the scenario-level delivery trace)
//! — on repeated runs AND across the sequential/parallel/auto plane-tick
//! modes.  This is what makes the recorded `BENCH_noc.json` numbers
//! gateable: any nondeterminism here would turn the CI perf gate into a
//! coin flip.

use espsim::coordinator::scenario::{builtin_scenarios, Pattern, Platform, Scenario};
use espsim::noc::TickMode;

/// Debug formatting covers every Outcome field, so string equality is the
/// byte-identical check.
fn fingerprint(s: &Scenario) -> String {
    format!("{:?}", s.run().unwrap_or_else(|e| panic!("{}: {e:#}", s.name)))
}

#[test]
fn outcomes_identical_across_tick_modes_and_reruns() {
    for mut s in builtin_scenarios(Platform::Paper3x4) {
        s.bytes = 8 << 10;
        let mut prints = Vec::new();
        for mode in [TickMode::Sequential, TickMode::Parallel, TickMode::Auto] {
            s.tick_mode = mode;
            let a = fingerprint(&s);
            let b = fingerprint(&s);
            assert_eq!(a, b, "{}: rerun diverged in {mode:?}", s.name);
            prints.push(a);
        }
        assert_eq!(prints[0], prints[1], "{}: parallel != sequential", s.name);
        assert_eq!(prints[0], prints[2], "{}: auto != sequential", s.name);
    }
}

#[test]
fn outcomes_identical_across_tick_modes_on_the_16x16_platform() {
    // One representative multi-plane scenario at scale: the coherent
    // pipeline exercises coherence + DMA + misc planes together, which is
    // where parallel plane ticking could plausibly diverge.
    let mut s = Scenario::new(
        "coh2_16",
        Pattern::CoherentPhases { stages: 2 },
        Platform::Mesh16x16,
    );
    s.bytes = 8 << 10;
    let mut prints = Vec::new();
    for mode in [TickMode::Sequential, TickMode::Parallel, TickMode::Auto] {
        s.tick_mode = mode;
        prints.push(fingerprint(&s));
    }
    assert_eq!(prints[0], prints[1], "parallel != sequential");
    assert_eq!(prints[0], prints[2], "auto != sequential");
}

#[test]
fn generated_graph_scenarios_depend_only_on_the_seed() {
    // The shuffle pattern goes through the dataflow generator: same seed
    // same graph; different seeds may differ but must still run.
    let mut a = Scenario::new(
        "sh",
        Pattern::AllToAllShuffle { producers: 2, consumers: 2 },
        Platform::Paper3x4,
    );
    a.bytes = 8 << 10;
    let mut b = a.clone();
    assert_eq!(fingerprint(&a), fingerprint(&b), "same seed, same outcome");
    b.seed = 999;
    let o = b.run().unwrap();
    assert!(o.cycles > 0);
}
