//! Flexible-P2P integration on the full SoC: mixed per-burst modes and
//! mismatched producer/consumer burst shapes — the paper's §3 claims that
//! have no figure of their own.

use espsim::accel::traffic_gen::TgenArgs;
use espsim::config::SocConfig;
use espsim::coordinator::{App, Invocation, ProgramKind, Soc};

const IN: u64 = 0x10_0000;
const OUT: u64 = 0x20_0000;

fn pattern(bytes: usize) -> Vec<u8> {
    (0..bytes).map(|i| (i as u64 * 37 % 251) as u8).collect()
}

/// Producer streams with one burst size, consumer pulls with another; the
/// length-carrying requests reconcile them (equal totals).
fn run_mismatched(prod_burst: u32, cons_burst: u32, total: u32) -> anyhow::Result<()> {
    let mut soc = Soc::new(SocConfig::small_3x3())?;
    let data = pattern(total as usize);
    soc.write_mem(IN, &data);
    let producer = Invocation::tgen(
        0,
        TgenArgs {
            total_bytes: total,
            burst_bytes: prod_burst,
            rd_user: 0,
            wr_user: 1,
            vaddr_in: IN,
            vaddr_out: 0,
        },
    );
    let consumer = Invocation::tgen(
        1,
        TgenArgs {
            total_bytes: total,
            burst_bytes: cons_burst,
            rd_user: 1,
            wr_user: 0,
            vaddr_in: 0,
            vaddr_out: OUT,
        },
    )
    .with_src(1, 0);
    App::new().phase(vec![producer, consumer]).launch(&mut soc)?;
    soc.run(10_000_000)?;
    anyhow::ensure!(soc.read_mem(OUT, total as usize) == data, "data mismatch");
    Ok(())
}

#[test]
fn equal_burst_shapes() {
    run_mismatched(4096, 4096, 16 << 10).unwrap();
}

#[test]
fn producer_larger_bursts() {
    // Producer 4 KB bursts, consumer 1 KB bursts.
    run_mismatched(4096, 1024, 16 << 10).unwrap();
}

#[test]
fn consumer_larger_bursts() {
    // Producer 1 KB bursts, consumer 4 KB bursts.
    run_mismatched(1024, 4096, 16 << 10).unwrap();
}

#[test]
fn coprime_burst_shapes() {
    // 512 B vs 2 KB over 8 KB total.
    run_mismatched(512, 2048, 8 << 10).unwrap();
}

/// One invocation mixing DMA reads (from memory) and a P2P-sourced read:
/// the consumer's first half comes from the producer, the second half
/// from memory — per-burst `user` switching within a single invocation.
#[test]
fn mixed_mode_within_one_invocation() {
    use espsim::accel::{stage_program, Xfer};

    let mut soc = Soc::new(SocConfig::small_3x3()).unwrap();
    let half = 8 << 10;
    let p2p_part = pattern(half);
    let mem_part: Vec<u8> = (0..half).map(|i| (i % 199) as u8).collect();
    soc.write_mem(IN, &p2p_part); // producer streams this
    soc.write_mem(IN + half as u64, &mem_part); // consumer DMAs this

    let producer = Invocation::tgen(
        0,
        TgenArgs {
            total_bytes: half as u32,
            burst_bytes: 4096,
            rd_user: 0,
            wr_user: 1,
            vaddr_in: IN,
            vaddr_out: 0,
        },
    );
    // Custom consumer: read half via P2P (user 1), half via DMA (user 0),
    // then write everything to OUT.
    let prog = stage_program(
        &[
            Xfer { vaddr: 0, plm: 0, len: half as u32, user: 1 },
            Xfer { vaddr: IN + half as u64, plm: half as u32, len: half as u32, user: 0 },
        ],
        &[],
        &[Xfer { vaddr: OUT, plm: 0, len: 2 * half as u32, user: 0 }],
        4096,
    );
    let mut consumer = Invocation::tgen(
        1,
        TgenArgs {
            total_bytes: 0,
            burst_bytes: 1,
            rd_user: 0,
            wr_user: 0,
            vaddr_in: 0,
            vaddr_out: 0,
        },
    )
    .with_src(1, 0);
    consumer.program = ProgramKind::Custom(prog);
    consumer.args = [0; 8];

    App::new().phase(vec![producer, consumer]).launch(&mut soc).unwrap();
    soc.run(10_000_000).unwrap();
    assert_eq!(soc.read_mem(OUT, half), p2p_part, "P2P half");
    assert_eq!(soc.read_mem(OUT + half as u64, half), mem_part, "DMA half");
}

/// Chained P2P: A -> B -> C, each stage pulling from the previous, only
/// the tail writing to memory (a 3-stage pipeline in one phase).
#[test]
fn three_stage_p2p_chain() {
    let mut soc = Soc::new(SocConfig::small_3x3()).unwrap();
    let total = 32 << 10;
    let data = pattern(total);
    soc.write_mem(IN, &data);
    let a = Invocation::tgen(
        0,
        TgenArgs {
            total_bytes: total as u32,
            burst_bytes: 4096,
            rd_user: 0,
            wr_user: 1,
            vaddr_in: IN,
            vaddr_out: 0,
        },
    );
    let b = Invocation::tgen(
        1,
        TgenArgs {
            total_bytes: total as u32,
            burst_bytes: 4096,
            rd_user: 1,
            wr_user: 1,
            vaddr_in: 0,
            vaddr_out: 0,
        },
    )
    .with_src(1, 0);
    let c = Invocation::tgen(
        2,
        TgenArgs {
            total_bytes: total as u32,
            burst_bytes: 4096,
            rd_user: 1,
            wr_user: 0,
            vaddr_in: 0,
            vaddr_out: OUT,
        },
    )
    .with_src(1, 1);
    App::new().phase(vec![a, b, c]).launch(&mut soc).unwrap();
    soc.run(10_000_000).unwrap();
    assert_eq!(soc.read_mem(OUT, total), data);
}
