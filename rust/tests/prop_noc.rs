//! Property tests for the NoC (in-tree PRNG; proptest is unavailable
//! offline).  Each property runs across many randomized cases with a
//! deterministic seed so failures reproduce exactly.

use std::collections::HashMap;
use std::sync::Arc;

use espsim::noc::{
    hop_count, partition_dests, xy_dir, DestList, Dir, Mesh, MeshParams, Message, MsgKind,
};
use espsim::util::Prng;

#[test]
fn prop_xy_routing_always_terminates_and_matches_hop_count() {
    let mut rng = Prng::new(0xA11CE);
    for _ in 0..2000 {
        let w = rng.range(2, 8) as u8;
        let h = rng.range(2, 8) as u8;
        let src = (rng.below(h as u64) as u8, rng.below(w as u64) as u8);
        let dst = (rng.below(h as u64) as u8, rng.below(w as u64) as u8);
        let mut cur = src;
        let mut steps = 0;
        while cur != dst {
            let dir = xy_dir(cur, dst);
            assert_ne!(dir, Dir::Local);
            cur = match dir {
                Dir::North => (cur.0 - 1, cur.1),
                Dir::South => (cur.0 + 1, cur.1),
                Dir::East => (cur.0, cur.1 + 1),
                Dir::West => (cur.0, cur.1 - 1),
                Dir::Local => unreachable!(),
            };
            steps += 1;
            assert!(steps <= 14, "path too long");
        }
        assert_eq!(steps, hop_count(src, dst));
    }
}

#[test]
fn prop_partition_covers_each_dest_exactly_once() {
    let mut rng = Prng::new(0xBEEF);
    for _ in 0..2000 {
        let w = rng.range(2, 8) as u8;
        let h = rng.range(2, 8) as u8;
        let cur = (rng.below(h as u64) as u8, rng.below(w as u64) as u8);
        let n = rng.range(1, 16) as usize;
        let mut dests = DestList::new();
        for _ in 0..n {
            dests.push((rng.below(h as u64) as u8, rng.below(w as u64) as u8));
        }
        let (mask, parts) = partition_dests(cur, &dests);
        let total: usize = parts.iter().map(|p| p.len()).sum();
        assert_eq!(total, dests.len(), "every dest in exactly one branch");
        for (i, p) in parts.iter().enumerate() {
            assert_eq!(!p.is_empty(), mask & (1 << i) != 0, "mask consistent");
            for d in p.iter() {
                assert_eq!(xy_dir(cur, d).idx(), i, "dest in its own direction's branch");
            }
        }
    }
}

/// Random multi-message workloads: every message is delivered to every
/// destination exactly once with an intact payload, and the mesh drains
/// (no deadlock, no loss) — under random mesh shapes, bitwidths, queue
/// depths and payload sizes.
#[test]
fn prop_random_workloads_deliver_exactly_once() {
    let mut rng = Prng::new(0xD00D);
    for case in 0..60 {
        let w = rng.range(2, 6) as u8;
        let h = rng.range(2, 6) as u8;
        let p = MeshParams {
            width: w,
            height: h,
            flit_bytes: *rng.pick(&[8u32, 16, 32]),
            queue_depth: rng.range(2, 6) as usize,
        };
        let mut mesh = Mesh::new(p);
        // expected[tile] -> list of (seq, payload byte, len)
        let mut expected: HashMap<(u8, u8), Vec<(u32, u8, usize)>> = HashMap::new();
        let n_msgs = rng.range(1, 12);
        for seq in 0..n_msgs {
            let src = (rng.below(h as u64) as u8, rng.below(w as u64) as u8);
            let fanout = rng.range(1, 5) as usize;
            let mut dests = DestList::new();
            let mut seen = Vec::new();
            for _ in 0..fanout {
                let d = (rng.below(h as u64) as u8, rng.below(w as u64) as u8);
                if !seen.contains(&d) {
                    seen.push(d);
                    dests.push(d);
                }
            }
            let fill = rng.next_u64() as u8;
            let len = rng.range(1, 6000) as usize;
            mesh.send(
                src,
                Message::multicast(
                    src,
                    dests,
                    MsgKind::P2pData { seq: seq as u32, prod_slot: 0 },
                    Arc::new(vec![fill; len]),
                ),
            );
            for d in seen {
                expected.entry(d).or_default().push((seq as u32, fill, len));
            }
        }
        let mut t = 0;
        while !mesh.is_idle() {
            mesh.tick(t);
            t += 1;
            assert!(t < 2_000_000, "case {case}: mesh did not drain");
        }
        for (tile, mut want) in expected {
            let mut got = Vec::new();
            while let Some(msg) = mesh.recv(tile) {
                let MsgKind::P2pData { seq, .. } = msg.kind else { panic!() };
                assert!(msg.payload.iter().all(|&b| b == msg.payload[0]), "payload corrupt");
                got.push((seq, msg.payload[0], msg.payload.len()));
            }
            want.sort();
            got.sort();
            assert_eq!(got, want, "case {case} tile {tile:?}");
        }
    }
}

/// Determinism: the same workload produces identical flit-hop counts and
/// drain times on every run.
#[test]
fn prop_mesh_is_deterministic() {
    for seed in [1u64, 7, 42] {
        let run = |seed: u64| {
            let mut rng = Prng::new(seed);
            let mut mesh =
                Mesh::new(MeshParams { width: 4, height: 3, flit_bytes: 32, queue_depth: 4 });
            for seq in 0..10u32 {
                let src = (rng.below(3) as u8, rng.below(4) as u8);
                let dst = (rng.below(3) as u8, rng.below(4) as u8);
                mesh.send(
                    src,
                    Message::data(
                        src,
                        dst,
                        MsgKind::P2pData { seq, prod_slot: 0 },
                        Arc::new(vec![0; rng.range(1, 2000) as usize]),
                    ),
                );
            }
            let mut t = 0;
            while !mesh.is_idle() {
                mesh.tick(t);
                t += 1;
            }
            (t, mesh.stats.flit_hops)
        };
        assert_eq!(run(seed), run(seed));
    }
}
