//! Scheduler equivalence: the activity-driven SoC scheduler (tile
//! worklists + wake-queue + idle-cycle fast-forward) must be
//! cycle-for-cycle identical to the retained full-scan reference model —
//! byte-identical `Outcome` and `Report` (cycles, per-plane flit and
//! delivery counts, socket/memory/host statistics, invocation spans) for
//! every scenario pattern, platform, seed and NoC tick mode, including
//! fast-forwarded runs on the 257-tile 16x16 platform.
//!
//! Debug formatting covers every field of `Outcome` and `Report`, so
//! string equality is the byte-identical check (the same convention as
//! `scenario_determinism.rs`).

use espsim::accel::traffic_gen::TgenArgs;
use espsim::config::SocConfig;
use espsim::coordinator::scenario::{builtin_scenarios, Pattern, Platform, Scenario};
use espsim::coordinator::workloads::{Dataflow, EdgePolicy, Shape};
use espsim::coordinator::{App, Invocation, Soc};
use espsim::noc::TickMode;
use espsim::sched::SchedMode;
use espsim::util::bench::time_once;

/// Run `s` under both schedulers and assert byte-identical outcomes.
fn assert_equiv(mut s: Scenario) {
    s.sched = SchedMode::FullScan;
    let reference =
        format!("{:?}", s.run().unwrap_or_else(|e| panic!("{} full-scan: {e:#}", s.name)));
    s.sched = SchedMode::Worklist;
    let worklist =
        format!("{:?}", s.run().unwrap_or_else(|e| panic!("{} worklist: {e:#}", s.name)));
    assert_eq!(reference, worklist, "{}: schedulers diverged", s.name);
}

#[test]
fn every_pattern_matches_the_reference_on_paper_3x4() {
    for mut s in builtin_scenarios(Platform::Paper3x4) {
        s.bytes = 8 << 10;
        assert_equiv(s);
    }
}

#[test]
fn every_pattern_matches_the_reference_on_the_8x8_mesh() {
    for mut s in builtin_scenarios(Platform::Mesh8x8) {
        s.bytes = 8 << 10;
        assert_equiv(s);
    }
}

#[test]
fn every_pattern_matches_the_reference_on_the_16x16_mesh() {
    // The 257-tile platform is where fast-forward does real work: most
    // tiles are provably idle in every scenario, and the coherent-flag
    // barriers put the whole SoC to sleep between phases.  One burst per
    // edge keeps the full-scan reference affordable in debug builds.
    for mut s in builtin_scenarios(Platform::Mesh16x16) {
        s.bytes = 4 << 10;
        s.burst_bytes = 4 << 10;
        assert_equiv(s);
    }
}

#[test]
fn equivalence_holds_across_noc_tick_modes() {
    // The two scheduler axes (tile scheduling, plane-tick threading) must
    // compose: every combination produces the same bytes.
    let mut s =
        Scenario::new("coh2", Pattern::CoherentPhases { stages: 2 }, Platform::Mesh8x8);
    s.bytes = 8 << 10;
    let mut prints = Vec::new();
    for mode in [TickMode::Sequential, TickMode::Parallel, TickMode::Auto] {
        s.tick_mode = mode;
        for sched in [SchedMode::FullScan, SchedMode::Worklist] {
            s.sched = sched;
            prints.push(format!("{:?}", s.run().unwrap()));
        }
    }
    for p in &prints[1..] {
        assert_eq!(&prints[0], p, "a tick-mode x scheduler combination diverged");
    }
}

#[test]
fn equivalence_holds_across_seeds() {
    for seed in [1u64, 7, 99] {
        let mut s = Scenario::new(
            "shuffle",
            Pattern::AllToAllShuffle { producers: 3, consumers: 3 },
            Platform::Paper3x4,
        );
        s.bytes = 8 << 10;
        s.seed = seed;
        assert_equiv(s);
    }
}

/// Full `Report` equivalence at the `Soc` level: covers host statistics
/// (IRQ arrival log, done_at), memory-tile and socket counters that the
/// scenario `Outcome` only aggregates.
#[test]
fn full_reports_match_for_a_p2p_dataflow() {
    let run = |mode| {
        let mut soc = Soc::new(SocConfig::paper_3x4()).unwrap();
        soc.set_sched_mode(mode);
        let g = Dataflow::generate(Shape::Diamond(3), 16 << 10, 4096, 7);
        let cycles = g.run(&mut soc, EdgePolicy::P2p).unwrap();
        (cycles, format!("{:?}", soc.report()))
    };
    assert_eq!(run(SchedMode::FullScan), run(SchedMode::Worklist));
}

#[test]
fn full_reports_match_for_a_flag_barrier_app() {
    let run = |mode| {
        let mut soc = Soc::new(SocConfig::paper_3x4()).unwrap();
        soc.set_sched_mode(mode);
        let inv = Invocation::tgen(
            0,
            TgenArgs {
                total_bytes: 8 << 10,
                burst_bytes: 4 << 10,
                rd_user: 0,
                wr_user: 0,
                vaddr_in: 0,
                vaddr_out: 64 << 10,
            },
        );
        App::new().phase_with_flag_barrier(vec![inv], 0x8000, 1).launch(&mut soc).unwrap();
        let cycles = soc.run(100_000_000).unwrap();
        (cycles, format!("{:?}", soc.report()))
    };
    let a = run(SchedMode::FullScan);
    let b = run(SchedMode::Worklist);
    assert_eq!(a, b);
    assert!(a.1.contains("irq_log: [("), "report must carry the IRQ arrival trace");
}

#[test]
fn worklist_beats_full_scan_5x_on_the_16x16_barrier_pipeline() {
    // The headline acceptance number: on the 257-tile platform the
    // coherence-barrier pipeline spends most simulated cycles with a
    // handful of live tiles (or none, during flag/DRAM waits), so the
    // worklist scheduler should deliver at least a 5x wall-clock speedup
    // at unchanged simulated cycle counts.  Cycle equality is asserted
    // unconditionally (deterministic); the wall-clock floor is a timing
    // measurement, so it only *gates* when ESPSIM_ENFORCE_SCHED_SPEEDUP
    // is set — the CI large-mesh job runs this test release-mode on a
    // single thread with that variable, while ordinary `cargo test`
    // (debug, parallel siblings on a shared runner) just reports it.
    let mut s =
        Scenario::new("coh16", Pattern::CoherentPhases { stages: 2 }, Platform::Mesh16x16);
    s.bytes = 4 << 10;
    s.burst_bytes = 4 << 10;
    // Best-of-three on each side: scheduler noise on a shared CI runner
    // can only inflate a single measurement, and the minimum is the
    // closest observable to the true per-scheduler cost.
    let best = |s: &Scenario| {
        (0..3)
            .map(|_| time_once(|| s.run().unwrap()))
            .min_by(|a, b| a.1.total_cmp(&b.1))
            .unwrap()
    };
    s.sched = SchedMode::FullScan;
    let (scan, scan_wall) = best(&s);
    s.sched = SchedMode::Worklist;
    let (wl, wl_wall) = best(&s);
    assert_eq!(
        (scan.cycles, scan.baseline_cycles),
        (wl.cycles, wl.baseline_cycles),
        "simulated cycles must be unchanged"
    );
    let speedup = scan_wall / wl_wall.max(1e-12);
    println!(
        "sched speedup {speedup:.1}x (full-scan {scan_wall:.3}s, worklist {wl_wall:.3}s)"
    );
    if std::env::var_os("ESPSIM_ENFORCE_SCHED_SPEEDUP").is_some() {
        assert!(
            speedup >= 5.0,
            "worklist speedup {speedup:.1}x < 5x (full-scan {scan_wall:.3}s, \
             worklist {wl_wall:.3}s)"
        );
    }
}
