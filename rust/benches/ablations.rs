//! Ablations over the design choices DESIGN.md calls out:
//!
//! 1. double- vs single-buffered traffic generators (burst pipelining);
//! 2. burst-size sweep (per-burst overhead amortization);
//! 3. NoC bitwidth on a fixed multicast workload (64/128/256);
//! 4. sequential vs concurrent baseline host model;
//! 5. multicast fork vs serial unicast NoC cost (flit-hops);
//! 6. coherence-flag sync vs IRQ round trip latency;
//! 9. serial vs thread-pooled simulation farm (sims/sec scaling);
//! 10. routing orientation, XY vs mixed request/response planes
//!     (congestion A/B on the 16x16 shuffle and halo scenarios).
//!
//! ```text
//! cargo bench --bench ablations
//! ```

#![allow(clippy::field_reassign_with_default)]

use espsim::config::SocConfig;
use espsim::coordinator::experiments::{run_fig6_point, run_multicast, Fig6Options};
use espsim::coordinator::farm::{expand_seeds, run_farm};
use espsim::coordinator::scenario::{
    builtin_scenarios, OrientationMode, Pattern, Platform, Scenario,
};
use espsim::coordinator::Soc;
use espsim::noc::{DestList, Mesh, MeshParams, Message, MsgKind};
use espsim::sched::SchedMode;
use espsim::telemetry::PLANE_NAMES;
use espsim::util::bench::{fmt_secs, measure, time_once, BenchJson, Table};
use espsim::util::Json;
use std::sync::Arc;

fn buffering(sink: &mut BenchJson) {
    println!("== ablation 1: traffic-generator buffering (8 consumers) ==");
    let t = Table::new(&["bytes", "double-buf", "single-buf", "penalty"], &[10, 12, 12, 9]);
    for bytes in [16u32 << 10, 128 << 10] {
        // The perf-tracking anchor point (128 KB row = the acceptance
        // metric): measured with a warm-up + median so the recorded
        // cycles/sec is not skewed by first-run cold costs.
        let (db, db_t) = measure(3, || run_multicast(8, bytes, &Fig6Options::default()).unwrap());
        sink.record(&format!("ablation1_mcast_8c_{bytes}B"), db, db_t.median_s);
        let mut o = Fig6Options::default();
        o.single_buffered = true;
        let (sb, sb_t) = measure(3, || run_multicast(8, bytes, &o).unwrap());
        sink.record(&format!("ablation1_mcast_single_8c_{bytes}B"), sb, sb_t.median_s);
        t.row(&[
            format!("{bytes}"),
            format!("{db}"),
            format!("{sb}"),
            format!("{:.2}x", sb as f64 / db as f64),
        ]);
    }
}

fn burst_size(sink: &mut BenchJson) {
    println!("\n== ablation 2: burst size (4 consumers, 64 KB) ==");
    let t = Table::new(&["burst", "baseline-cy", "multicast-cy", "speedup"], &[8, 12, 12, 8]);
    for burst in [512u32, 1024, 2048, 4096] {
        let mut o = Fig6Options::default();
        o.burst_bytes = burst;
        let (p, wall) = time_once(|| run_fig6_point(4, 64 << 10, &o).unwrap());
        sink.record(
            &format!("ablation2_burst{burst}_4c_64KB"),
            p.baseline_cycles + p.multicast_cycles,
            wall,
        );
        t.row(&[
            format!("{burst}"),
            format!("{}", p.baseline_cycles),
            format!("{}", p.multicast_cycles),
            format!("{:.2}x", p.speedup()),
        ]);
    }
}

fn bitwidth(sink: &mut BenchJson) {
    println!("\n== ablation 3: NoC bitwidth (4 consumers, 64 KB) ==");
    let t = Table::new(
        &["bitwidth", "mcast-cap", "baseline-cy", "multicast-cy", "speedup"],
        &[8, 9, 12, 12, 8],
    );
    for bits in [64u32, 128, 256] {
        let mut o = Fig6Options::default();
        o.soc.noc.bitwidth = bits;
        let (p, wall) = time_once(|| run_fig6_point(4, 64 << 10, &o).unwrap());
        sink.record(
            &format!("ablation3_{bits}bit_4c_64KB"),
            p.baseline_cycles + p.multicast_cycles,
            wall,
        );
        t.row(&[
            format!("{bits}"),
            format!("{}", o.soc.mcast_capacity()),
            format!("{}", p.baseline_cycles),
            format!("{}", p.multicast_cycles),
            format!("{:.2}x", p.speedup()),
        ]);
    }
}

fn host_model() {
    println!("\n== ablation 4: baseline host model (4 KB) ==");
    let t = Table::new(&["consumers", "sequential", "concurrent"], &[9, 11, 11]);
    for n in [1usize, 4, 16] {
        let seq = run_fig6_point(n, 4096, &Fig6Options::default()).unwrap();
        let mut o = Fig6Options::default();
        o.baseline_sequential = false;
        let conc = run_fig6_point(n, 4096, &o).unwrap();
        t.row(&[
            format!("{n}"),
            format!("{:.2}x", seq.speedup()),
            format!("{:.2}x", conc.speedup()),
        ]);
    }
}

fn fork_vs_unicast() {
    println!("\n== ablation 5: in-network fork vs serial unicasts (32 KB payload) ==");
    let t = Table::new(
        &["fanout", "mcast-hops", "unicast-hops", "saving"],
        &[7, 11, 12, 8],
    );
    let payload = Arc::new(vec![0u8; 32 << 10]);
    for fanout in [2usize, 4, 8] {
        // Spread across rows 1 and 2 so every fanout has distinct tiles.
        let uniq: Vec<(u8, u8)> =
            (0..fanout).map(|i| (1 + (i / 4) as u8, (i % 4) as u8)).collect();
        let mk = || Mesh::new(MeshParams { width: 4, height: 3, flit_bytes: 32, queue_depth: 4 });
        let mut mc = mk();
        mc.send(
            (0, 0),
            Message::multicast(
                (0, 0),
                DestList::from_slice(&uniq),
                MsgKind::P2pData { seq: 0, prod_slot: 0 },
                payload.clone(),
            ),
        );
        let mut t_ = 0;
        while !mc.is_idle() {
            mc.tick(t_);
            t_ += 1;
        }
        let mut uc = mk();
        for &d in &uniq {
            uc.send(
                (0, 0),
                Message::data(
                    (0, 0),
                    d,
                    MsgKind::P2pData { seq: 0, prod_slot: 0 },
                    payload.clone(),
                ),
            );
        }
        let mut t2 = 0;
        while !uc.is_idle() {
            uc.tick(t2);
            t2 += 1;
        }
        t.row(&[
            format!("{}", uniq.len()),
            format!("{}", mc.stats.flit_hops),
            format!("{}", uc.stats.flit_hops),
            {
                let saved = 1.0 - mc.stats.flit_hops as f64 / uc.stats.flit_hops as f64;
                format!("{:.0}%", saved * 100.0)
            },
        ]);
    }
}

fn sync_latency() {
    println!("\n== ablation 6: coherent-flag sync vs IRQ round trip ==");
    let mut cfg = SocConfig::small_3x3();
    cfg.acc.l2_enabled = true;
    let host = cfg.host;
    let mut soc = Soc::new(cfg.clone()).unwrap();
    let addr = 0x5000u64;
    let tile_idx = soc.cfg.index_of(soc.acc_location(0).0);
    let cpu_idx = soc.cfg.index_of(soc.cfg.cpu_tile());
    // Warm the consumer copy.
    loop {
        let espsim::tile::Tile::Cpu(cpu) = &mut soc.tiles[cpu_idx] else { panic!() };
        if cpu.l1.load(addr).is_some() {
            break;
        }
        soc.tick();
    }
    let mut stored = false;
    let mut cycles = 0u64;
    loop {
        {
            let espsim::tile::Tile::Acc(acc) = &mut soc.tiles[tile_idx] else { panic!() };
            if !stored {
                stored = acc.l2.as_mut().unwrap().store(addr, 1);
            }
        }
        {
            let espsim::tile::Tile::Cpu(cpu) = &mut soc.tiles[cpu_idx] else { panic!() };
            if stored && cpu.l1.load(addr) == Some(1) {
                break;
            }
        }
        soc.tick();
        cycles += 1;
        assert!(cycles < 100_000);
    }
    let irq = host.irq_overhead as u64 + 10;
    println!("  coherent flag handoff: {cycles} cycles");
    println!("  IRQ path (NoC + host service): ~{irq} cycles");
    println!("  -> flag sync is {:.1}x cheaper", irq as f64 / cycles as f64);
}

fn workload_shapes() {
    use espsim::coordinator::workloads::{Dataflow, EdgePolicy, Shape};
    println!("\n== ablation 7: dataflow shapes, memory-staged vs P2P edges (64 KB) ==");
    let t = Table::new(&["shape", "memory-cy", "p2p-cy", "speedup"], &[12, 11, 9, 8]);
    let shapes: [(&str, Shape); 4] = [
        ("chain-4", Shape::Chain(4)),
        ("tree-8", Shape::Tree(8)),
        ("diamond-4", Shape::Diamond(4)),
        ("random-10", Shape::Random(10)),
    ];
    for (name, shape) in shapes {
        let g = Dataflow::generate(shape, 64 << 10, 4096, 7);
        let mut soc = Soc::new(SocConfig::paper_3x4()).unwrap();
        let mem = g.run(&mut soc, EdgePolicy::Memory).unwrap();
        // Random DAGs may have interior multi-input nodes the tgen P2P
        // lowering doesn't support; fall back to memory-only for those.
        let p2p_ok = g
            .nodes
            .iter()
            .all(|n| n.inputs.len() <= 1 || g.fanout(n.id) == 0);
        if !p2p_ok {
            t.row(&[name.into(), format!("{mem}"), "n/a".into(), "-".into()]);
            continue;
        }
        let mut soc = Soc::new(SocConfig::paper_3x4()).unwrap();
        let p2p = g.run(&mut soc, EdgePolicy::P2p).unwrap();
        t.row(&[
            name.into(),
            format!("{mem}"),
            format!("{p2p}"),
            format!("{:.2}x", mem as f64 / p2p as f64),
        ]);
    }
}

fn sched_scan_vs_worklist(sink: &mut BenchJson) {
    println!("\n== ablation 8: full-scan vs activity-driven SoC scheduler ==");
    println!("   (coherence-barrier pipeline, both lowerings; cycles must be identical)");
    let t = Table::new(
        &["platform", "sim-cycles", "full-scan", "worklist", "speedup"],
        &[9, 11, 10, 10, 8],
    );
    for (name, platform) in [("8x8", Platform::Mesh8x8), ("16x16", Platform::Mesh16x16)] {
        let mut s = Scenario::new(
            "coherent_pipeline3",
            Pattern::CoherentPhases { stages: 3 },
            platform,
        );
        s.sched = SchedMode::FullScan;
        let (scan, scan_wall) = time_once(|| s.run().unwrap());
        s.sched = SchedMode::Worklist;
        let (wl, wl_wall) = time_once(|| s.run().unwrap());
        assert_eq!(
            (scan.cycles, scan.baseline_cycles),
            (wl.cycles, wl.baseline_cycles),
            "schedulers diverged on {name}"
        );
        let sim_cycles = wl.cycles + wl.baseline_cycles;
        let speedup = scan_wall / wl_wall.max(1e-12);
        sink.record(&format!("ablation8_sched_fullscan_{name}"), sim_cycles, scan_wall);
        sink.record_with(
            &format!("ablation8_sched_worklist_{name}"),
            sim_cycles,
            wl_wall,
            &[
                ("sched_speedup", Json::Num(speedup)),
                ("sim_cycles_per_sec", Json::Num(sim_cycles as f64 / wl_wall.max(1e-12))),
            ],
        );
        t.row(&[
            name.to_string(),
            format!("{sim_cycles}"),
            fmt_secs(scan_wall),
            fmt_secs(wl_wall),
            format!("{speedup:.1}x"),
        ]);
    }
}

fn farm_scaling(sink: &mut BenchJson) {
    println!("\n== ablation 9: simulation farm, serial vs thread pool ==");
    println!("   (8x8 registry x 4 seeds; outcomes must be byte-identical)");
    let mut registry = builtin_scenarios(Platform::Mesh8x8);
    for s in &mut registry {
        s.bytes = 8 << 10;
    }
    let batch = expand_seeds(&registry, 4);
    let serial = run_farm(&batch, 1);
    let farmed = run_farm(&batch, 0); // one worker per core
    for (i, (a, b)) in serial.results.iter().zip(&farmed.results).enumerate() {
        let (a, b) = (a.outcome.as_ref().unwrap(), b.outcome.as_ref().unwrap());
        assert_eq!(
            format!("{a:?}"),
            format!("{b:?}"),
            "farm diverged from serial on slot {i} ({})",
            batch[i].name
        );
    }
    let t = Table::new(&["jobs", "sims", "wall", "sims/sec", "scaling"], &[6, 6, 9, 10, 8]);
    for run in [&serial, &farmed] {
        t.row(&[
            format!("{}", run.jobs),
            format!("{}", run.completed()),
            fmt_secs(run.wall_s),
            format!("{:.2}", run.sims_per_sec()),
            format!("{:.2}x", run.sims_per_sec() / serial.sims_per_sec().max(1e-12)),
        ]);
    }
    // Same batch either way, so the recorded sim-cycle total is identical
    // and only the wall-clock family (sims_per_sec) distinguishes them.
    let sim_cycles: u64 = serial
        .results
        .iter()
        .filter_map(|r| r.outcome.as_ref().ok())
        .map(|o| o.cycles + o.baseline_cycles)
        .sum();
    for (label, run) in [("serial", &serial), ("farm", &farmed)] {
        sink.record_with(
            &format!("ablation9_farm_{label}_8x8x4seeds"),
            sim_cycles,
            run.wall_s,
            &[
                ("sims_per_sec", Json::Num(run.sims_per_sec())),
                ("jobs", Json::from(run.jobs as u64)),
            ],
        );
    }
}

fn orientation_ab(sink: &mut BenchJson) {
    println!("\n== ablation 10: routing orientation, XY vs mixed planes (16x16) ==");
    println!("   (telemetry-armed congestion A/B on the all-to-all shuffle and halo ring)");
    let t = Table::new(
        &["scenario", "cycles", "stall-cy", "peak-stall", "peak-occ"],
        &[26, 10, 10, 10, 12],
    );
    // Per-plane stall keys ride along in the bench record so a shifted
    // hotspot shows up next to the cycles it cost.
    let stall_keys: Vec<String> = PLANE_NAMES.iter().map(|n| format!("stall_{n}")).collect();
    let bases = [
        Scenario::new(
            "shuffle4x4",
            Pattern::AllToAllShuffle { producers: 4, consumers: 4 },
            Platform::Mesh16x16,
        ),
        Scenario::new("halo_ring8", Pattern::HaloExchange { nodes: 8 }, Platform::Mesh16x16),
    ];
    for base in bases {
        // (mode, cycles, peak-router stall) per arm, XY first, for the
        // summary line below the table.
        let mut arms: Vec<(OrientationMode, u64, u64)> = Vec::new();
        for mode in [OrientationMode::Xy, OrientationMode::Mixed] {
            let mut s = base.oriented(mode);
            s.telemetry = true;
            let (o, wall) = time_once(|| s.run().unwrap());
            let tr = o.telemetry.as_ref().unwrap();
            let peak_occ =
                tr.planes.iter().flat_map(|p| p.occ_sum.iter().copied()).max().unwrap_or(0);
            let mut extras = vec![
                ("orientation", Json::from(mode.code())),
                ("stall_cycles", Json::from(tr.total_stall())),
                ("hotspot_stall", Json::from(tr.max_router_stall())),
                ("peak_occupancy", Json::from(peak_occ)),
            ];
            for (pi, p) in tr.planes.iter().enumerate() {
                extras.push((stall_keys[pi].as_str(), Json::from(p.stall.iter().sum::<u64>())));
            }
            let point = format!("ablation10_orient_{}_16x16", s.name);
            sink.record_with(&point, o.cycles, wall, &extras);
            t.row(&[
                s.name.clone(),
                format!("{}", o.cycles),
                format!("{}", tr.total_stall()),
                format!("{}", tr.max_router_stall()),
                format!("{peak_occ}"),
            ]);
            arms.push((mode, o.cycles, tr.max_router_stall()));
        }
        let (_, _, xy_peak) = arms[0];
        let (_, _, mx_peak) = arms[1];
        println!(
            "  {}: peak-router stall {} (xy) -> {} (mixed), {:+.1}%",
            base.name,
            xy_peak,
            mx_peak,
            (mx_peak as f64 / xy_peak.max(1) as f64 - 1.0) * 100.0
        );
    }
}

fn main() {
    let mut sink = BenchJson::from_args("ablations");
    buffering(&mut sink);
    burst_size(&mut sink);
    bitwidth(&mut sink);
    host_model();
    fork_vs_unicast();
    sync_latency();
    workload_shapes();
    sched_scan_vs_worklist(&mut sink);
    farm_scaling(&mut sink);
    orientation_ab(&mut sink);
    sink.finish();
}
