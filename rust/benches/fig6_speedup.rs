//! Fig. 6 regeneration: speedup of multicast P2P over the shared-memory
//! baseline, sweeping consumer count x data size on the paper's 3x4
//! platform (17 traffic generators, 256-bit NoC).  Prints the same grid
//! the paper plots, the paper's anchor values, and the simulator's
//! wall-clock throughput.
//!
//! ```text
//! cargo bench --bench fig6_speedup [-- --quick]
//! ```

use espsim::coordinator::experiments::{
    paper_consumer_counts, paper_data_sizes, run_fig6_point, Fig6Options,
};
use espsim::util::bench::{fmt_secs, measure, BenchJson, Table};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    // A --quick run must not overwrite the full sweep's perf-trajectory
    // records, so it gets its own bench section in BENCH_noc.json.
    let mut sink =
        BenchJson::from_args(if quick { "fig6_speedup_quick" } else { "fig6_speedup" });
    let opts = Fig6Options::default();
    let sizes = if quick { vec![4 << 10, 64 << 10] } else { paper_data_sizes() };

    println!("== Fig. 6: multicast speedup vs shared-memory baseline ==");
    println!("platform: 3x4 mesh, 256-bit NoC, 4 KB bursts, sequential baseline\n");

    let t = Table::new(
        &["consumers", "bytes", "baseline-cy", "multicast-cy", "speedup", "sim-time"],
        &[9, 10, 12, 12, 8, 9],
    );
    let mut total_sim_cycles = 0u64;
    let mut total_wall = 0.0f64;
    for &n in &paper_consumer_counts() {
        for &bytes in &sizes {
            let iters = if bytes >= (1 << 20) { 1 } else { 3 };
            let (p, timing) = measure(iters, || run_fig6_point(n, bytes, &opts).unwrap());
            total_sim_cycles += p.baseline_cycles + p.multicast_cycles;
            total_wall += timing.median_s;
            sink.record(
                &format!("fig6_{n}c_{bytes}B"),
                p.baseline_cycles + p.multicast_cycles,
                timing.median_s,
            );
            t.row(&[
                format!("{n}"),
                format!("{bytes}"),
                format!("{}", p.baseline_cycles),
                format!("{}", p.multicast_cycles),
                format!("{:.2}x", p.speedup()),
                fmt_secs(timing.median_s),
            ]);
        }
    }

    println!("\npaper anchors (read off Fig. 6):");
    println!("  1 consumer,  4 KB: 1.72x   (72% speedup)");
    println!("  16 consumers, 4 KB: 2.20x  (120% speedup)");
    println!("  16 consumers, 1 MB: 3.03x  (203% speedup, plateau at 1 MB)");
    println!("\nsimulator throughput: {:.1} M simulated cycles / wall-second",
        total_sim_cycles as f64 / total_wall.max(1e-9) / 1e6);
    sink.record("fig6_total", total_sim_cycles, total_wall);
    sink.finish();
}
