//! Fig. 6 regeneration: speedup of multicast P2P over the shared-memory
//! baseline, sweeping consumer count x data size on the paper's 3x4
//! platform (17 traffic generators, 256-bit NoC).  Prints the same grid
//! the paper plots, the paper's anchor values, and the simulator's
//! wall-clock throughput.
//!
//! `--mesh16` runs the scaled sweep instead: a 16x16 mesh, consumers
//! packed two per tile up to 32, and transfers out to 4 MB — the
//! past-the-paper operating points the generalized coordinate encoding
//! unlocks.
//!
//! ```text
//! cargo bench --bench fig6_speedup [-- --quick] [-- --mesh16]
//! ```

use espsim::coordinator::experiments::{
    extended_consumer_counts, extended_data_sizes, paper_consumer_counts, paper_data_sizes,
    quick_data_sizes, quick_extended_data_sizes, run_fig6_point, Fig6Options,
};
use espsim::util::bench::{fmt_secs, measure, BenchJson, Table};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let mesh16 = std::env::args().any(|a| a == "--mesh16");
    // A --quick run must not overwrite the full sweep's perf-trajectory
    // records, so each variant gets its own bench section in BENCH_noc.json.
    let bench_name = match (mesh16, quick) {
        (false, false) => "fig6_speedup",
        (false, true) => "fig6_speedup_quick",
        (true, false) => "fig6_speedup_16x16",
        (true, true) => "fig6_speedup_16x16_quick",
    };
    let mut sink = BenchJson::from_args(bench_name);
    let opts = if mesh16 { Fig6Options::mesh_16x16() } else { Fig6Options::default() };
    let consumers = if mesh16 { extended_consumer_counts() } else { paper_consumer_counts() };
    let sizes = match (mesh16, quick) {
        (false, false) => paper_data_sizes(),
        (false, true) => quick_data_sizes(),
        (true, false) => extended_data_sizes(),
        (true, true) => quick_extended_data_sizes(),
    };

    println!("== Fig. 6: multicast speedup vs shared-memory baseline ==");
    if mesh16 {
        println!("platform: 16x16 mesh, 256-bit NoC, consumers packed 2/tile, 4 KB bursts\n");
    } else {
        println!("platform: 3x4 mesh, 256-bit NoC, 4 KB bursts, sequential baseline\n");
    }

    let t = Table::new(
        &["consumers", "bytes", "baseline-cy", "multicast-cy", "speedup", "sim-time"],
        &[9, 10, 12, 12, 8, 9],
    );
    let mut total_sim_cycles = 0u64;
    let mut total_wall = 0.0f64;
    for &n in &consumers {
        for &bytes in &sizes {
            let iters = if bytes >= (1 << 20) { 1 } else { 3 };
            let (p, timing) = measure(iters, || run_fig6_point(n, bytes, &opts).unwrap());
            total_sim_cycles += p.baseline_cycles + p.multicast_cycles;
            total_wall += timing.median_s;
            sink.record(
                &format!("fig6_{n}c_{bytes}B"),
                p.baseline_cycles + p.multicast_cycles,
                timing.median_s,
            );
            t.row(&[
                format!("{n}"),
                format!("{bytes}"),
                format!("{}", p.baseline_cycles),
                format!("{}", p.multicast_cycles),
                format!("{:.2}x", p.speedup()),
                fmt_secs(timing.median_s),
            ]);
        }
    }

    if !mesh16 {
        println!("\npaper anchors (read off Fig. 6):");
        println!("  1 consumer,  4 KB: 1.72x   (72% speedup)");
        println!("  16 consumers, 4 KB: 2.20x  (120% speedup)");
        println!("  16 consumers, 1 MB: 3.03x  (203% speedup, plateau at 1 MB)");
    }
    println!("\nsimulator throughput: {:.1} M simulated cycles / wall-second",
        total_sim_cycles as f64 / total_wall.max(1e-9) / 1e6);
    sink.record("fig6_total", total_sim_cycles, total_wall);
    sink.finish();
}
