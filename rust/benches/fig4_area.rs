//! Fig. 4 regeneration: NoC-router area vs bitwidth x multicast
//! destinations, from the calibrated analytic model, with the paper's
//! anchor values printed side by side.
//!
//! ```text
//! cargo bench --bench fig4_area
//! ```

use espsim::area::{fig4_sweep, RouterAreaModel};
use espsim::util::bench::{fmt_secs, measure, BenchJson, Table};

fn main() {
    println!("== Fig. 4: router area (um^2, 12nm-calibrated model) ==\n");

    // The figure's series: one row per destination count, one column per
    // bitwidth (None where the header cannot encode that many).
    let model = RouterAreaModel::calibrated();
    let t = Table::new(&["max-dests", "64-bit", "128-bit", "256-bit"], &[9, 10, 10, 10]);
    for dests in 0..=16usize {
        let cell = |bits: u32| {
            model
                .area(bits, dests)
                .map(|a| format!("{a:.0}"))
                .unwrap_or_else(|| "-".to_string())
        };
        t.row(&[format!("{dests}"), cell(64), cell(128), cell(256)]);
    }

    println!("\npaper anchors vs model:");
    let anchors = [(64u32, 0usize, 3620.0), (128, 0, 6230.0), (256, 0, 11520.0)];
    for (bits, dests, paper) in anchors {
        let got = model.area(bits, dests).unwrap();
        println!(
            "  {bits:>3}-bit, {dests:>2} dests: paper {paper:>8.0}  model {got:>8.0}  ({:+.1}%)",
            (got / paper - 1.0) * 100.0
        );
    }
    println!("  per-destination cost: paper ~200 um^2, model {:.0} um^2", model.per_dest);
    for (bits, dests) in [(64u32, 4usize), (128, 8), (256, 16)] {
        let ov = model.overhead(bits, dests).unwrap() * 100.0;
        println!("  {bits:>3}-bit with {dests:>2} dests: +{ov:.1}% area (paper: <30%)");
    }

    // Timing of the sweep itself (the "synthesis" replacement).
    let (points, timing) = measure(50, || fig4_sweep().len());
    println!(
        "\nsweep of {points} configurations evaluated in {} (median of {} iters)",
        fmt_secs(timing.median_s),
        timing.iters
    );
    // "cycles" here counts evaluated configurations (the analytic model has
    // no simulated time); recorded for trajectory tracking all the same.
    let mut sink = BenchJson::from_args("fig4_area");
    sink.record("fig4_sweep", points as u64, timing.median_s);
    sink.finish();
}
