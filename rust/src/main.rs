//! `espsim` CLI: run the paper's experiments from the command line.
//!
//! ```text
//! espsim area                          # Fig. 4 router-area sweep
//! espsim run --consumers 8 --kb 64     # one Fig. 6 point (both variants)
//! espsim sweep [--config soc.json]     # the full Fig. 6 grid
//! espsim scenarios --jobs 8            # scenario registry on the farm
//! espsim sweep-farm --seeds 100        # Monte-Carlo scenario/seed sweep
//! espsim scenarios --telemetry t.json  # congestion heatmaps + hotspots
//! espsim telemetry-check t.json        # validate a telemetry dump
//! espsim config                        # print the default SoC config JSON
//! ```

use anyhow::{anyhow, bail, ensure, Context, Result};
use espsim::area::fig4_sweep;
use espsim::config::SocConfig;
use espsim::coordinator::experiments::{
    extended_consumer_counts, extended_data_sizes, paper_consumer_counts, paper_data_sizes,
    run_fig6_point, Fig6Options,
};
use espsim::coordinator::farm::{expand_seeds, run_farm, FarmRun};
use espsim::coordinator::scenario::{builtin_scenarios, OrientationMode, Platform, Scenario};
use espsim::noc::TickMode;
use espsim::sched::SchedMode;
use espsim::telemetry::{dump_document, validate_document};
use espsim::util::bench::{fmt_secs, BenchJson, CompareOpts, Table};
use espsim::util::Json;

const USAGE: &str = "\
espsim — ESP multicast-NoC paper reproduction

USAGE:
  espsim area
      Fig. 4: router area sweep (bitwidth x multicast destinations).
  espsim run [--consumers N] [--kb K] [--single-buffered] [--config PATH]
      One Fig. 6 point: multicast vs shared-memory baseline.
  espsim sweep [--config PATH] [--mesh16]
      The full Fig. 6 grid (consumers x data sizes); --mesh16 runs the
      scaled 16x16 sweep (32 packed consumers, 4 MB transfers).
  espsim scenarios [--filter NAME] [--mesh16] [--bytes N] [--file PATH]
                   [--sched MODE] [--orientation MODE|all]
                   [--harvest ROWS] [--faults N[:SEED]] [--replay W]
                   [--jobs N] [--seeds K] [--telemetry OUT] [--list] [--json]
      Run the declarative scenario registry (P2P chains, multicast
      fan-outs, scatter-gather, all-to-all shuffles, halo exchanges,
      coherence-barrier pipelines) against the DMA-only baseline and
      record each point into BENCH_noc.json.  Default platform is the
      8x8 mesh; --mesh16 selects the 16x16 platform; --file runs
      scenarios from a JSON config instead of the builtin registry.
      --sched picks the SoC tile scheduler (\"worklist\", the default, or
      the \"full_scan\" reference) — simulated cycles are identical in
      both, so the CI perf gate cross-checks the two documents.
      --orientation picks the per-plane routing orientation (\"xy\", the
      default; \"yx\"; \"mixed\", which splits request planes XY and
      response planes YX; or \"all\" to run every mode) — unlike --sched
      this axis changes the simulated cycles, and non-XY runs suffix
      their bench points +yx / +mixed.
      --harvest disables the listed mesh rows (comma-separated; each
      keeps a bridge tile so the mesh stays routable) and --faults
      kills N random links mid-run from a seeded deterministic plan.
      Degraded sweeps record completion 0/1, drop and retry counts per
      scenario instead of aborting on the first failure.
      --replay W arms W-byte producer-side P2P replay rings (the
      recovery axis): a sub-request lost to a killed link is
      retransmitted from the ring at the consumer's resume offset
      instead of being diagnosed as latched corruption, points gain a
      +replayW suffix and the bench section a _replay suffix, and
      degraded records carry recovered / replayed_bytes /
      drained_worms next to the drop and retry counts.
      --jobs runs the batch on the simulation farm (N worker threads;
      0 = one per core; default 1 = serial) and --seeds fans each
      scenario out to K seeded replicas.  Results are collected by
      input index, so cycles/speedup records are byte-identical to a
      serial run; every record additionally carries the batch's
      sims_per_sec farm throughput.
      --telemetry OUT arms the per-router congestion counters on every
      scenario and writes OUT as a JSON document of per-plane heatmaps
      (stall / forwarded / fork / occupancy grids), per-tile
      busy/sleeping/parked cycle breakdowns and a top-8 hotspot list
      (schema espsim-telemetry-v1); each bench record then also carries
      stall_cycles, hotspot_stall and mcast_forks totals.  Simulated
      cycles are byte-identical with and without the flag.
  espsim sweep-farm [--filter NAME] [--mesh16] [--bytes N] [--file PATH]
                    [--sched MODE|all] [--ticks MODE|all]
                    [--orientation MODE|all]
                    [--harvest ROWS] [--faults N[:SEED]] [--replay W]
                    [--jobs N] [--seeds K] [--telemetry OUT]
                    [--list] [--json]
      Monte-Carlo sweep on the simulation farm: cross the scenario
      registry with the sched-mode axis (--sched all), the NoC
      tick-mode axis (--ticks all), the routing-orientation axis
      (--orientation all), the degraded-mesh axes, and K seeded
      replicas per point (default 8), then run the whole batch
      across the thread pool (--jobs, default 0 = one per core).
      Records land in the sweep_farm_* bench sections with a +seedN
      (and +sched/+tick/+yx/+mixed) suffix per point.
  espsim compare BASELINE FRESH [--tol-cycles F] [--tol-speedup F]
                 [--tol-throughput F] [--strict] [--warn-only]
      Diff a fresh bench document against a committed baseline with
      per-metric tolerances; exits nonzero on regression (the CI perf
      gate).  Tolerances are fractions (default 0.02 cycles, 0.05
      speedup; throughput ungated unless requested).  --strict
      additionally fails when the baseline has bench sections the
      fresh run never executed (CI mode — a renamed bench cannot
      quietly evade the gate); completion-0 records from degraded
      sweeps are compared on completion, never on their placeholder
      perf metrics.
  espsim telemetry-check FILE
      Validate a --telemetry dump: schema tag, mesh-shaped grids for
      every plane and the tile breakdown, counter bounds (per-router
      stalls never exceed elapsed cycles) and hotspot fields.  Exits
      nonzero on a malformed document (the CI telemetry gate).
  espsim config
      Print the default SoC configuration as JSON.
";

/// Minimal flag parser: `--key value` and boolean `--key`.
struct Args {
    rest: Vec<String>,
}

impl Args {
    fn new() -> Self {
        Self { rest: std::env::args().skip(1).collect() }
    }

    fn subcommand(&mut self) -> Option<String> {
        if self.rest.first().map(|a| !a.starts_with("--")).unwrap_or(false) {
            Some(self.rest.remove(0))
        } else {
            None
        }
    }

    /// Next positional (non-flag) argument, or an error naming it.
    fn positional(&mut self, what: &str) -> Result<String> {
        self.subcommand().ok_or_else(|| anyhow!("missing {what} argument\n\n{USAGE}"))
    }

    fn flag(&mut self, name: &str) -> bool {
        if let Some(i) = self.rest.iter().position(|a| a == name) {
            self.rest.remove(i);
            true
        } else {
            false
        }
    }

    fn value(&mut self, name: &str) -> Result<Option<String>> {
        if let Some(i) = self.rest.iter().position(|a| a == name) {
            if i + 1 >= self.rest.len() {
                bail!("{name} requires a value");
            }
            self.rest.remove(i);
            Ok(Some(self.rest.remove(i)))
        } else {
            Ok(None)
        }
    }

    fn finish(self) -> Result<()> {
        if let Some(a) = self.rest.first() {
            bail!("unrecognized argument {a:?}\n\n{USAGE}");
        }
        Ok(())
    }
}

fn load_opts(config: Option<String>) -> Result<Fig6Options> {
    let mut opts = Fig6Options::default();
    if let Some(path) = config {
        opts.soc = SocConfig::load(path)?;
    }
    Ok(opts)
}

/// Flags shared by `scenarios` and `sweep-farm`: scenario source,
/// platform, transfer shape, degraded-mesh axes, and farm sizing.
struct ScenarioOpts {
    list: bool,
    mesh16: bool,
    filter: Option<String>,
    file: Option<String>,
    bytes: Option<u32>,
    harvest_rows: Vec<u8>,
    fault_links: u8,
    fault_seed: u64,
    replay_window: u32,
    jobs: usize,
    seeds: u64,
    telemetry: Option<String>,
}

impl ScenarioOpts {
    /// Parse the shared flags; the two subcommands differ only in their
    /// farm defaults (`scenarios` stays serial/one-seed unless asked).
    fn parse(args: &mut Args, default_jobs: usize, default_seeds: u64) -> Result<Self> {
        let list = args.flag("--list");
        let mesh16 = args.flag("--mesh16");
        let _json = args.flag("--json"); // re-detected by BenchJson
        let filter = args.value("--filter")?;
        let file = args.value("--file")?;
        let bytes: Option<u32> = args.value("--bytes")?.map(|v| v.parse()).transpose()?;
        let jobs: usize =
            args.value("--jobs")?.map(|v| v.parse()).transpose()?.unwrap_or(default_jobs);
        let seeds: u64 =
            args.value("--seeds")?.map(|v| v.parse()).transpose()?.unwrap_or(default_seeds);
        ensure!(seeds >= 1, "--seeds needs at least one replica per scenario");
        let telemetry = args.value("--telemetry")?;
        let harvest_rows: Vec<u8> = match args.value("--harvest")? {
            Some(v) => v
                .split(',')
                .map(|r| {
                    r.trim().parse::<u8>().map_err(|_| {
                        anyhow!("--harvest expects comma-separated row numbers, got {r:?}")
                    })
                })
                .collect::<Result<_>>()?,
            None => Vec::new(),
        };
        let (fault_links, fault_seed): (u8, u64) = match args.value("--faults")? {
            Some(v) => {
                let (n, seed) = match v.split_once(':') {
                    Some((n, s)) => (
                        n,
                        s.parse::<u64>().map_err(|_| {
                            anyhow!("--faults seed must be an integer, got {s:?}")
                        })?,
                    ),
                    None => (v.as_str(), 0xDEAD),
                };
                let n: u8 =
                    n.parse().map_err(|_| anyhow!("--faults expects N or N:SEED, got {v:?}"))?;
                ensure!(n > 0, "--faults needs at least one link to kill");
                (n, seed)
            }
            None => (0, 1),
        };
        let replay_window: u32 =
            args.value("--replay")?.map(|v| v.parse()).transpose()?.unwrap_or(0);
        ensure!(
            !(mesh16 && file.is_some()),
            "--mesh16 selects the builtin registry's platform; scenario files carry their own"
        );
        Ok(Self {
            list,
            mesh16,
            filter,
            file,
            bytes,
            harvest_rows,
            fault_links,
            fault_seed,
            replay_window,
            jobs,
            seeds,
            telemetry,
        })
    }

    fn degraded(&self) -> bool {
        !self.harvest_rows.is_empty() || self.fault_links > 0
    }

    /// The base scenario list: registry or file, filtered, resized, and
    /// lowered onto the degraded mesh when a degraded axis is set.
    fn scenarios(&self) -> Result<Vec<Scenario>> {
        let platform = if self.mesh16 { Platform::Mesh16x16 } else { Platform::Mesh8x8 };
        let mut scenarios = match &self.file {
            Some(path) => Scenario::load_file(path)?,
            None => builtin_scenarios(platform),
        };
        if let Some(f) = &self.filter {
            scenarios.retain(|s| s.name.contains(f.as_str()));
        }
        if let Some(b) = self.bytes {
            for s in &mut scenarios {
                s.bytes = b;
            }
        }
        if self.degraded() {
            for s in &mut scenarios {
                *s = s.degraded(&self.harvest_rows, self.fault_links, self.fault_seed);
            }
        }
        if self.replay_window > 0 {
            // The recovery axis composes with the degraded axes above:
            // `recovery` suffixes +replayW after +harvestR/+faultsN.
            for s in &mut scenarios {
                *s = s.recovery(self.replay_window);
            }
        }
        if self.telemetry.is_some() {
            // The flag survives seed expansion and axis crossing: both
            // clone the base scenario, so every replica records counters.
            for s in &mut scenarios {
                s.telemetry = true;
            }
        }
        ensure!(!scenarios.is_empty(), "no scenarios match");
        Ok(scenarios)
    }

    /// Bench section name: `{prefix}_{platform}[_harvest][_faults]`.
    fn bench_name(&self, prefix: &str) -> String {
        let mut name = match (&self.file, self.mesh16) {
            (Some(_), _) => format!("{prefix}_custom"),
            (None, false) => format!("{prefix}_8x8"),
            (None, true) => format!("{prefix}_16x16"),
        };
        if !self.harvest_rows.is_empty() {
            name.push_str("_harvest");
        }
        if self.fault_links > 0 {
            name.push_str("_faults");
        }
        if self.replay_window > 0 {
            name.push_str("_replay");
        }
        name
    }
}

/// `--sched` axis: a single mode (the default is the worklist scheduler)
/// or, for `sweep-farm`, `all` to cross both.
fn sched_axis(args: &mut Args) -> Result<Vec<SchedMode>> {
    Ok(match args.value("--sched")? {
        None => vec![SchedMode::default()],
        Some(c) if c == "all" => vec![SchedMode::Worklist, SchedMode::FullScan],
        Some(c) => vec![SchedMode::from_code(&c)
            .ok_or_else(|| anyhow!("unknown --sched {c:?} (worklist, full_scan, all)"))?],
    })
}

/// `--ticks` axis: a single NoC plane-tick mode or `all` to cross the
/// three (results are identical in every mode; the axis exists to farm
/// the equivalence surface itself).
fn tick_axis(args: &mut Args) -> Result<Vec<TickMode>> {
    Ok(match args.value("--ticks")? {
        None => vec![TickMode::Auto],
        Some(c) if c == "all" => vec![TickMode::Sequential, TickMode::Parallel, TickMode::Auto],
        Some(c) => vec![TickMode::from_code(&c)
            .ok_or_else(|| anyhow!("unknown --ticks {c:?} (sequential, parallel, auto, all)"))?],
    })
}

/// `--orientation` axis: one routing-orientation mode (`xy`, the
/// default; `yx`; `mixed`) or `all` to cross the three.  Unlike the
/// sched and tick axes this one changes the simulated cycles — it is
/// the congestion A/B the orientation ablation measures — so non-XY
/// points carry a `+yx` / `+mixed` name suffix.
fn orientation_axis(args: &mut Args) -> Result<Vec<OrientationMode>> {
    Ok(match args.value("--orientation")? {
        None => vec![OrientationMode::default()],
        Some(c) if c == "all" => OrientationMode::ALL.to_vec(),
        Some(c) => vec![OrientationMode::from_code(&c)
            .ok_or_else(|| anyhow!("unknown --orientation {c:?} (xy, yx, mixed, all)"))?],
    })
}

fn list_scenarios(scenarios: &[Scenario]) {
    for s in scenarios {
        println!("{:32} {:20} {:10} {:>8} B", s.name, s.pattern.code(), s.platform.code(), s.bytes);
    }
}

/// Run a batch on the simulation farm and record/print the results in
/// input order (the farm already collected them by index).  On a
/// degraded mesh a failing scenario becomes a completion-0 record with
/// its cause; on a pristine mesh the first failure *by input order* is
/// returned — but only after the whole batch was measured and the sink
/// finished, so the CI artifact keeps the partial record set.  When
/// `telemetry` names a path, every outcome's congestion snapshot is
/// collected into a single `espsim-telemetry-v1` heatmap document.
fn run_batch(
    scenarios: &[Scenario],
    jobs: usize,
    bench_name: &str,
    degraded: bool,
    telemetry: Option<&str>,
) -> Result<()> {
    let farm = run_farm(scenarios, jobs);
    let completed = farm.completed();
    let sims_per_sec = farm.sims_per_sec();
    let FarmRun { results, wall_s: farm_wall, jobs } = farm;
    let sims = results.len();
    let mut sink = BenchJson::from_args(bench_name);
    let t = Table::new(
        &["scenario", "pattern", "optimized", "dma-only", "speedup", "p2p-KiB", "wall"],
        &[28, 18, 12, 12, 8, 8, 9],
    );
    let mut failure: Option<anyhow::Error> = None;
    let mut telem_entries: Vec<(String, Json)> = Vec::new();
    for (s, res) in scenarios.iter().zip(results) {
        let wall = res.wall_s;
        let o = match res.outcome {
            Ok(o) => o,
            Err(e) if degraded => {
                // On a degraded mesh, a scenario that cannot finish is
                // itself a data point (completed=0 plus the cause), not a
                // reason to abort the sweep.  The `completed` tag is what
                // tells `util::bench::compare` to skip the placeholder
                // perf metrics below instead of gating on them.
                let cause = format!("{e:#}");
                sink.record_with(
                    &format!("{}_{}", s.name, s.platform.code()),
                    0,
                    wall,
                    &[
                        ("completed", Json::from(0u64)),
                        ("failure", Json::from(cause.as_str())),
                        ("pattern", Json::from(s.pattern.code())),
                        ("platform", Json::from(s.platform.code())),
                        ("sims_per_sec", Json::Num(sims_per_sec)),
                    ],
                );
                t.row(&[
                    s.name.clone(),
                    s.pattern.code().to_string(),
                    "FAILED".to_string(),
                    "-".to_string(),
                    "-".to_string(),
                    "-".to_string(),
                    fmt_secs(wall),
                ]);
                continue;
            }
            Err(e) => {
                // Pristine-mesh failures are bugs: no record, but the rest
                // of the batch already ran, so keep reporting it and
                // propagate the first error (by input order) at the end.
                if failure.is_none() {
                    failure = Some(e);
                }
                t.row(&[
                    s.name.clone(),
                    s.pattern.code().to_string(),
                    "FAILED".to_string(),
                    "-".to_string(),
                    "-".to_string(),
                    "-".to_string(),
                    fmt_secs(wall),
                ]);
                continue;
            }
        };
        // `wall` covers BOTH lowerings, so the simulator-throughput
        // metric must too (the default cycles/wall would understate it);
        // the extras override replaces it with total simulated cycles per
        // wall-second, the fig6 bench convention.  `sim_cycles_per_sec`
        // is the same number under the name the scheduler-speedup gate
        // reads, and `sims_per_sec` is the farm's batch throughput — the
        // only record fields allowed to differ between `--jobs 1` and
        // `--jobs N` are this wall-clock family.
        let total_cps = (o.cycles + o.baseline_cycles) as f64 / wall.max(1e-12);
        let point = format!("{}_{}", s.name, s.platform.code());
        let mut extras = vec![
            ("cycles_per_sec", Json::Num(total_cps)),
            ("sim_cycles_per_sec", Json::Num(total_cps)),
            ("sims_per_sec", Json::Num(sims_per_sec)),
            ("baseline_cycles", Json::from(o.baseline_cycles)),
            ("speedup", Json::Num(o.speedup())),
            ("p2p_bytes", Json::from(o.p2p_bytes)),
            ("dma_bytes", Json::from(o.dma_bytes)),
            ("flit_hops", Json::from(o.total_flits())),
            ("pattern", Json::from(s.pattern.code())),
            ("platform", Json::from(s.platform.code())),
        ];
        if degraded {
            extras.push(("completed", Json::from(1u64)));
            extras.push(("dropped_flits", Json::from(o.dropped_flits)));
            extras.push(("socket_retries", Json::from(o.socket_retries)));
            extras.push(("recovered", Json::from(o.recovered as u64)));
            extras.push(("replayed_bytes", Json::from(o.replayed_bytes)));
            extras.push(("drained_worms", Json::from(o.drained_worms)));
        }
        if let Some(tr) = &o.telemetry {
            // Hotspot totals ride along in the bench record so a
            // congestion shift shows up next to the cycles it cost.
            extras.push(("stall_cycles", Json::from(tr.total_stall())));
            extras.push(("hotspot_stall", Json::from(tr.max_router_stall())));
            extras.push(("mcast_forks", Json::from(tr.total_forks())));
            telem_entries.push((point.clone(), tr.to_json()));
        }
        sink.record_with(&point, o.cycles, wall, &extras);
        t.row(&[
            s.name.clone(),
            s.pattern.code().to_string(),
            format!("{}", o.cycles),
            format!("{}", o.baseline_cycles),
            format!("{:.2}x", o.speedup()),
            format!("{}", o.p2p_bytes >> 10),
            fmt_secs(wall),
        ]);
    }
    sink.finish();
    if let Some(path) = telemetry {
        let doc = dump_document(telem_entries);
        let mut text = doc.to_string();
        text.push('\n');
        std::fs::write(path, text).with_context(|| format!("writing telemetry dump {path}"))?;
        println!("telemetry: wrote {path}");
    }
    println!(
        "farm: {completed}/{sims} sims in {} ({jobs} jobs, {sims_per_sec:.2} sims/sec)",
        fmt_secs(farm_wall)
    );
    match failure {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

fn main() -> Result<()> {
    let mut args = Args::new();
    let cmd = args.subcommand().ok_or_else(|| anyhow!("missing subcommand\n\n{USAGE}"))?;
    match cmd.as_str() {
        "area" => {
            args.finish()?;
            println!("{:>8} {:>10} {:>12} {:>10}", "bits", "max-dests", "area(um^2)", "overhead");
            for p in fig4_sweep() {
                println!(
                    "{:>8} {:>10} {:>12.0} {:>9.1}%",
                    p.bitwidth,
                    p.max_dests,
                    p.area_um2,
                    p.overhead * 100.0
                );
            }
        }
        "run" => {
            let consumers: usize =
                args.value("--consumers")?.map(|v| v.parse()).transpose()?.unwrap_or(4);
            let kb: u32 = args.value("--kb")?.map(|v| v.parse()).transpose()?.unwrap_or(64);
            let single = args.flag("--single-buffered");
            let config = args.value("--config")?;
            args.finish()?;
            let mut opts = load_opts(config)?;
            opts.single_buffered = single;
            let p = run_fig6_point(consumers, kb * 1024, &opts)?;
            println!(
                "consumers={} size={}KiB baseline={}cy multicast={}cy speedup={:.2}x",
                p.consumers,
                kb,
                p.baseline_cycles,
                p.multicast_cycles,
                p.speedup()
            );
        }
        "sweep" => {
            let mesh16 = args.flag("--mesh16");
            let config = args.value("--config")?;
            args.finish()?;
            // --mesh16 implies the scaled platform (256 MiB DRAM, packed
            // consumers); a user config would silently undo what the
            // 32-consumer / 4 MB grid needs, so refuse the combination.
            ensure!(
                !(mesh16 && config.is_some()),
                "--mesh16 selects the scaled 16x16 platform; it cannot be combined with --config"
            );
            let opts = if mesh16 { Fig6Options::mesh_16x16() } else { load_opts(config)? };
            let consumers =
                if mesh16 { extended_consumer_counts() } else { paper_consumer_counts() };
            let sizes = if mesh16 { extended_data_sizes() } else { paper_data_sizes() };
            println!(
                "{:>10} {:>10} {:>12} {:>12} {:>8}",
                "consumers", "bytes", "baseline", "multicast", "speedup"
            );
            for &n in &consumers {
                for &bytes in &sizes {
                    let p = run_fig6_point(n, bytes, &opts)?;
                    println!(
                        "{:>10} {:>10} {:>12} {:>12} {:>7.2}x",
                        n,
                        bytes,
                        p.baseline_cycles,
                        p.multicast_cycles,
                        p.speedup()
                    );
                }
            }
        }
        "scenarios" => {
            let sched = args
                .value("--sched")?
                .map(|code| {
                    SchedMode::from_code(&code)
                        .ok_or_else(|| anyhow!("unknown --sched {code:?} (worklist, full_scan)"))
                })
                .transpose()?;
            let orients = orientation_axis(&mut args)?;
            // Serial, single-seed defaults: without --jobs/--seeds the
            // command behaves (and records) exactly as before the farm.
            let o = ScenarioOpts::parse(&mut args, 1, 1)?;
            args.finish()?;
            let mut scenarios = o.scenarios()?;
            if let Some(m) = sched {
                for s in &mut scenarios {
                    s.sched = m;
                }
            }
            // Cross with the orientation axis; `oriented` suffixes the
            // name for non-XY modes so every bench point stays unique.
            let scenarios: Vec<Scenario> =
                scenarios.iter().flat_map(|s| orients.iter().map(|&om| s.oriented(om))).collect();
            let scenarios = expand_seeds(&scenarios, o.seeds);
            if o.list {
                list_scenarios(&scenarios);
                return Ok(());
            }
            run_batch(
                &scenarios,
                o.jobs,
                &o.bench_name("scenarios"),
                o.degraded(),
                o.telemetry.as_deref(),
            )?;
        }
        "sweep-farm" => {
            let scheds = sched_axis(&mut args)?;
            let ticks = tick_axis(&mut args)?;
            let orients = orientation_axis(&mut args)?;
            // Farm defaults: one worker per core, 8 seeded replicas.
            let o = ScenarioOpts::parse(&mut args, 0, 8)?;
            args.finish()?;
            let mut crossed = Vec::new();
            for s in &o.scenarios()? {
                for &om in &orients {
                    for &sched in &scheds {
                        for &tick in &ticks {
                            // `oriented` already suffixes +yx/+mixed.
                            let mut c = s.oriented(om);
                            c.sched = sched;
                            c.tick_mode = tick;
                            // Suffix a swept axis so bench points stay unique.
                            if scheds.len() > 1 {
                                c.name = format!("{}+{}", c.name, sched.code());
                            }
                            if ticks.len() > 1 {
                                c.name = format!("{}+{}", c.name, tick.code());
                            }
                            crossed.push(c);
                        }
                    }
                }
            }
            let scenarios = expand_seeds(&crossed, o.seeds);
            if o.list {
                list_scenarios(&scenarios);
                return Ok(());
            }
            run_batch(
                &scenarios,
                o.jobs,
                &o.bench_name("sweep_farm"),
                o.degraded(),
                o.telemetry.as_deref(),
            )?;
        }
        "compare" => {
            let warn_only = args.flag("--warn-only");
            let mut opts =
                CompareOpts { strict: args.flag("--strict"), ..CompareOpts::default() };
            if let Some(v) = args.value("--tol-cycles")? {
                opts.tol_cycles = v.parse()?;
            }
            if let Some(v) = args.value("--tol-speedup")? {
                opts.tol_speedup = v.parse()?;
            }
            if let Some(v) = args.value("--tol-throughput")? {
                opts.tol_throughput = Some(v.parse()?);
            }
            let baseline = args.positional("BASELINE")?;
            let fresh = args.positional("FRESH")?;
            args.finish()?;
            let report = espsim::util::bench::compare_files(&baseline, &fresh, &opts)?;
            print!("{}", report.render());
            if !report.passed() {
                if warn_only {
                    eprintln!("perf gate: regressions found (warn-only mode, not failing)");
                } else {
                    bail!("perf gate: fresh run regressed against {baseline}");
                }
            }
        }
        "telemetry-check" => {
            let path = args.positional("FILE")?;
            args.finish()?;
            let text = std::fs::read_to_string(&path)
                .with_context(|| format!("reading telemetry dump {path}"))?;
            let doc = Json::parse(&text).with_context(|| format!("parsing {path}"))?;
            validate_document(&doc).with_context(|| format!("validating {path}"))?;
            let n = doc.req("scenarios")?.as_obj()?.len();
            println!("{path}: ok ({n} scenarios, schema espsim-telemetry-v1)");
        }
        "config" => {
            args.finish()?;
            println!("{}", SocConfig::paper_3x4().to_json());
        }
        "--help" | "-h" | "help" => println!("{USAGE}"),
        other => bail!("unknown subcommand {other:?}\n\n{USAGE}"),
    }
    Ok(())
}
