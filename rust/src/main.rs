//! `espsim` CLI: run the paper's experiments from the command line.
//!
//! ```text
//! espsim area                          # Fig. 4 router-area sweep
//! espsim run --consumers 8 --kb 64     # one Fig. 6 point (both variants)
//! espsim sweep [--config soc.json]     # the full Fig. 6 grid
//! espsim config                        # print the default SoC config JSON
//! ```

use anyhow::{anyhow, bail, ensure, Result};
use espsim::area::fig4_sweep;
use espsim::config::SocConfig;
use espsim::coordinator::experiments::{
    extended_consumer_counts, extended_data_sizes, paper_consumer_counts, paper_data_sizes,
    run_fig6_point, Fig6Options,
};

const USAGE: &str = "\
espsim — ESP multicast-NoC paper reproduction

USAGE:
  espsim area
      Fig. 4: router area sweep (bitwidth x multicast destinations).
  espsim run [--consumers N] [--kb K] [--single-buffered] [--config PATH]
      One Fig. 6 point: multicast vs shared-memory baseline.
  espsim sweep [--config PATH] [--mesh16]
      The full Fig. 6 grid (consumers x data sizes); --mesh16 runs the
      scaled 16x16 sweep (32 packed consumers, 4 MB transfers).
  espsim config
      Print the default SoC configuration as JSON.
";

/// Minimal flag parser: `--key value` and boolean `--key`.
struct Args {
    rest: Vec<String>,
}

impl Args {
    fn new() -> Self {
        Self { rest: std::env::args().skip(1).collect() }
    }

    fn subcommand(&mut self) -> Option<String> {
        if self.rest.first().map(|a| !a.starts_with("--")).unwrap_or(false) {
            Some(self.rest.remove(0))
        } else {
            None
        }
    }

    fn flag(&mut self, name: &str) -> bool {
        if let Some(i) = self.rest.iter().position(|a| a == name) {
            self.rest.remove(i);
            true
        } else {
            false
        }
    }

    fn value(&mut self, name: &str) -> Result<Option<String>> {
        if let Some(i) = self.rest.iter().position(|a| a == name) {
            if i + 1 >= self.rest.len() {
                bail!("{name} requires a value");
            }
            self.rest.remove(i);
            Ok(Some(self.rest.remove(i)))
        } else {
            Ok(None)
        }
    }

    fn finish(self) -> Result<()> {
        if let Some(a) = self.rest.first() {
            bail!("unrecognized argument {a:?}\n\n{USAGE}");
        }
        Ok(())
    }
}

fn load_opts(config: Option<String>) -> Result<Fig6Options> {
    let mut opts = Fig6Options::default();
    if let Some(path) = config {
        opts.soc = SocConfig::load(path)?;
    }
    Ok(opts)
}

fn main() -> Result<()> {
    let mut args = Args::new();
    let cmd = args.subcommand().ok_or_else(|| anyhow!("missing subcommand\n\n{USAGE}"))?;
    match cmd.as_str() {
        "area" => {
            args.finish()?;
            println!("{:>8} {:>10} {:>12} {:>10}", "bits", "max-dests", "area(um^2)", "overhead");
            for p in fig4_sweep() {
                println!(
                    "{:>8} {:>10} {:>12.0} {:>9.1}%",
                    p.bitwidth,
                    p.max_dests,
                    p.area_um2,
                    p.overhead * 100.0
                );
            }
        }
        "run" => {
            let consumers: usize =
                args.value("--consumers")?.map(|v| v.parse()).transpose()?.unwrap_or(4);
            let kb: u32 = args.value("--kb")?.map(|v| v.parse()).transpose()?.unwrap_or(64);
            let single = args.flag("--single-buffered");
            let config = args.value("--config")?;
            args.finish()?;
            let mut opts = load_opts(config)?;
            opts.single_buffered = single;
            let p = run_fig6_point(consumers, kb * 1024, &opts)?;
            println!(
                "consumers={} size={}KiB baseline={}cy multicast={}cy speedup={:.2}x",
                p.consumers,
                kb,
                p.baseline_cycles,
                p.multicast_cycles,
                p.speedup()
            );
        }
        "sweep" => {
            let mesh16 = args.flag("--mesh16");
            let config = args.value("--config")?;
            args.finish()?;
            // --mesh16 implies the scaled platform (256 MiB DRAM, packed
            // consumers); a user config would silently undo what the
            // 32-consumer / 4 MB grid needs, so refuse the combination.
            ensure!(
                !(mesh16 && config.is_some()),
                "--mesh16 selects the scaled 16x16 platform; it cannot be combined with --config"
            );
            let opts = if mesh16 { Fig6Options::mesh_16x16() } else { load_opts(config)? };
            let consumers =
                if mesh16 { extended_consumer_counts() } else { paper_consumer_counts() };
            let sizes = if mesh16 { extended_data_sizes() } else { paper_data_sizes() };
            println!(
                "{:>10} {:>10} {:>12} {:>12} {:>8}",
                "consumers", "bytes", "baseline", "multicast", "speedup"
            );
            for &n in &consumers {
                for &bytes in &sizes {
                    let p = run_fig6_point(n, bytes, &opts)?;
                    println!(
                        "{:>10} {:>10} {:>12} {:>12} {:>7.2}x",
                        n,
                        bytes,
                        p.baseline_cycles,
                        p.multicast_cycles,
                        p.speedup()
                    );
                }
            }
        }
        "config" => {
            args.finish()?;
            println!("{}", SocConfig::paper_3x4().to_json());
        }
        "--help" | "-h" | "help" => println!("{USAGE}"),
        other => bail!("unknown subcommand {other:?}\n\n{USAGE}"),
    }
    Ok(())
}
