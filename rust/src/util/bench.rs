//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! Runs each measurement several times, reports min/median/mean wall-clock
//! alongside whatever domain metric (simulated cycles, speedup) the bench
//! computes, and prints aligned tables the EXPERIMENTS.md results are
//! copied from.

use std::time::Instant;

/// Timing summary of repeated runs.
#[derive(Debug, Clone, Copy)]
pub struct Timing {
    /// Fastest iteration, seconds.
    pub min_s: f64,
    /// Median iteration, seconds.
    pub median_s: f64,
    /// Mean iteration, seconds.
    pub mean_s: f64,
    /// Iterations.
    pub iters: usize,
}

/// Measure `f` `iters` times (after one warm-up) and summarize.
pub fn measure<T>(iters: usize, mut f: impl FnMut() -> T) -> (T, Timing) {
    let warm = f();
    let mut times = Vec::with_capacity(iters);
    let mut last = warm;
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        last = f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let timing = Timing {
        min_s: times[0],
        median_s: times[times.len() / 2],
        mean_s: times.iter().sum::<f64>() / times.len() as f64,
        iters: times.len(),
    };
    (last, timing)
}

/// Simple aligned-table printer.
pub struct Table {
    widths: Vec<usize>,
}

impl Table {
    /// Start a table and print its header.
    pub fn new(headers: &[&str], widths: &[usize]) -> Self {
        assert_eq!(headers.len(), widths.len());
        let mut line = String::new();
        for (h, w) in headers.iter().zip(widths) {
            line.push_str(&format!("{h:>w$} "));
        }
        println!("{line}");
        println!("{}", "-".repeat(line.len()));
        Self { widths: widths.to_vec() }
    }

    /// Print one row.
    pub fn row(&self, cells: &[String]) {
        let mut line = String::new();
        for (c, w) in cells.iter().zip(&self.widths) {
            line.push_str(&format!("{c:>w$} "));
        }
        println!("{line}");
    }
}

/// Format seconds human-readably.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.0}us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_runs_and_summarizes() {
        let mut n = 0u64;
        let (last, t) = measure(5, || {
            n += 1;
            n
        });
        assert_eq!(t.iters, 5);
        assert!(last >= 5, "5 iters + warmup");
        assert!(t.min_s <= t.median_s && t.median_s <= t.mean_s * 5.0);
    }

    #[test]
    fn fmt() {
        assert_eq!(fmt_secs(2.0), "2.00s");
        assert_eq!(fmt_secs(0.002), "2.00ms");
        assert_eq!(fmt_secs(0.0000021), "2us");
    }
}
