//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! Runs each measurement several times, reports min/median/mean wall-clock
//! alongside whatever domain metric (simulated cycles, speedup) the bench
//! computes, and prints aligned tables the EXPERIMENTS.md results are
//! copied from.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::Instant;

use crate::util::json::Json;

/// Timing summary of repeated runs.
#[derive(Debug, Clone, Copy)]
pub struct Timing {
    /// Fastest iteration, seconds.
    pub min_s: f64,
    /// Median iteration, seconds.
    pub median_s: f64,
    /// Mean iteration, seconds.
    pub mean_s: f64,
    /// Iterations.
    pub iters: usize,
}

/// Measure `f` `iters` times (after one warm-up) and summarize.
pub fn measure<T>(iters: usize, mut f: impl FnMut() -> T) -> (T, Timing) {
    let warm = f();
    let mut times = Vec::with_capacity(iters);
    let mut last = warm;
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        last = f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let timing = Timing {
        min_s: times[0],
        median_s: times[times.len() / 2],
        mean_s: times.iter().sum::<f64>() / times.len() as f64,
        iters: times.len(),
    };
    (last, timing)
}

/// Time a single run of `f` (no warm-up): `(result, seconds)`.
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed().as_secs_f64())
}

/// Machine-readable perf sink: each bench `record()`s its measured points
/// and `finish()` merges them into `BENCH_noc.json` — simulated cycles,
/// wall-clock seconds, and simulated cycles per wall-second per point — so
/// successive PRs can track the simulator-throughput trajectory.
///
/// The file is always written (records from *other* benches already in it
/// are preserved; this bench's section is replaced).  `--json` additionally
/// echoes the merged document to stdout; `ESPSIM_BENCH_JSON` overrides the
/// output path.
pub struct BenchJson {
    bench: String,
    path: PathBuf,
    records: Vec<Json>,
    echo: bool,
}

impl BenchJson {
    /// Sink for the bench named `bench`, honoring `--json` / env overrides.
    pub fn from_args(bench: &str) -> Self {
        let path =
            std::env::var("ESPSIM_BENCH_JSON").unwrap_or_else(|_| "BENCH_noc.json".to_string());
        Self {
            bench: bench.to_string(),
            path: PathBuf::from(path),
            records: Vec::new(),
            echo: std::env::args().any(|a| a == "--json"),
        }
    }

    /// Add one measured point: `cycles` simulated in `wall_s` seconds.
    pub fn record(&mut self, point: &str, cycles: u64, wall_s: f64) {
        let mut m = BTreeMap::new();
        m.insert("bench".to_string(), Json::from(self.bench.as_str()));
        m.insert("point".to_string(), Json::from(point));
        m.insert("cycles".to_string(), Json::from(cycles));
        m.insert("wall_s".to_string(), Json::Num(wall_s));
        m.insert("cycles_per_sec".to_string(), Json::Num(cycles as f64 / wall_s.max(1e-12)));
        self.records.push(Json::Obj(m));
    }

    /// Points recorded so far (tests / callers that want a summary line).
    pub fn len(&self) -> usize {
        self.records.len()
    }

    /// Nothing recorded?
    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Merge into the output file, replacing this bench's prior records.
    pub fn finish(self) {
        let mut all: Vec<Json> = Vec::new();
        if let Ok(text) = std::fs::read_to_string(&self.path) {
            if let Ok(doc) = Json::parse(&text) {
                if let Some(Json::Arr(recs)) = doc.get("records") {
                    all.extend(
                        recs.iter()
                            .filter(|r| {
                                r.get("bench").and_then(|b| b.as_str().ok())
                                    != Some(self.bench.as_str())
                            })
                            .cloned(),
                    );
                }
            }
        }
        all.extend(self.records);
        let mut top = BTreeMap::new();
        top.insert("records".to_string(), Json::Arr(all));
        let text = Json::Obj(top).to_string();
        if self.echo {
            println!("{text}");
        }
        match std::fs::write(&self.path, &text) {
            Ok(()) => eprintln!("perf records -> {}", self.path.display()),
            Err(e) => eprintln!("warning: could not write {}: {e}", self.path.display()),
        }
    }
}

/// Simple aligned-table printer.
pub struct Table {
    widths: Vec<usize>,
}

impl Table {
    /// Start a table and print its header.
    pub fn new(headers: &[&str], widths: &[usize]) -> Self {
        assert_eq!(headers.len(), widths.len());
        let mut line = String::new();
        for (h, w) in headers.iter().zip(widths) {
            line.push_str(&format!("{h:>w$} "));
        }
        println!("{line}");
        println!("{}", "-".repeat(line.len()));
        Self { widths: widths.to_vec() }
    }

    /// Print one row.
    pub fn row(&self, cells: &[String]) {
        let mut line = String::new();
        for (c, w) in cells.iter().zip(&self.widths) {
            line.push_str(&format!("{c:>w$} "));
        }
        println!("{line}");
    }
}

/// Format seconds human-readably.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.0}us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_runs_and_summarizes() {
        let mut n = 0u64;
        let (last, t) = measure(5, || {
            n += 1;
            n
        });
        assert_eq!(t.iters, 5);
        assert!(last >= 5, "5 iters + warmup");
        assert!(t.min_s <= t.median_s && t.median_s <= t.mean_s * 5.0);
    }

    #[test]
    fn fmt() {
        assert_eq!(fmt_secs(2.0), "2.00s");
        assert_eq!(fmt_secs(0.002), "2.00ms");
        assert_eq!(fmt_secs(0.0000021), "2us");
    }

    #[test]
    fn time_once_measures_and_returns() {
        let (v, s) = time_once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }

    #[test]
    fn bench_json_merges_per_bench_sections() {
        let dir = std::env::temp_dir().join(format!("espsim_bench_json_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_test.json");
        let mk = |bench: &str| BenchJson {
            bench: bench.to_string(),
            path: path.clone(),
            records: Vec::new(),
            echo: false,
        };
        let mut a = mk("alpha");
        a.record("p1", 1000, 0.5);
        assert_eq!(a.len(), 1);
        a.finish();
        let mut b = mk("beta");
        b.record("p2", 2000, 0.25);
        b.finish();
        // Re-running alpha replaces its record but keeps beta's.
        let mut a2 = mk("alpha");
        a2.record("p1", 3000, 0.5);
        a2.finish();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let recs = doc.get("records").unwrap().as_arr().unwrap();
        assert_eq!(recs.len(), 2);
        let find = |bench: &str| {
            recs.iter()
                .find(|r| r.get("bench").unwrap().as_str().unwrap() == bench)
                .unwrap()
                .clone()
        };
        assert_eq!(find("alpha").get("cycles").unwrap().as_u64().unwrap(), 3000);
        assert_eq!(find("beta").get("cycles").unwrap().as_u64().unwrap(), 2000);
        let cps = find("beta").get("cycles_per_sec").unwrap().as_f64().unwrap();
        assert!((cps - 8000.0).abs() < 1e-6);
        std::fs::remove_dir_all(&dir).ok();
    }
}
