//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! Runs each measurement several times, reports min/median/mean wall-clock
//! alongside whatever domain metric (simulated cycles, speedup) the bench
//! computes, and prints aligned tables the EXPERIMENTS.md results are
//! copied from.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::Instant;

use crate::util::json::Json;

/// Timing summary of repeated runs.
#[derive(Debug, Clone, Copy)]
pub struct Timing {
    /// Fastest iteration, seconds.
    pub min_s: f64,
    /// Median iteration, seconds.
    pub median_s: f64,
    /// Mean iteration, seconds.
    pub mean_s: f64,
    /// Iterations.
    pub iters: usize,
}

/// Measure `f` `iters` times (after one warm-up) and summarize.
pub fn measure<T>(iters: usize, mut f: impl FnMut() -> T) -> (T, Timing) {
    let warm = f();
    let mut times = Vec::with_capacity(iters);
    let mut last = warm;
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        last = f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let timing = Timing {
        min_s: times[0],
        median_s: times[times.len() / 2],
        mean_s: times.iter().sum::<f64>() / times.len() as f64,
        iters: times.len(),
    };
    (last, timing)
}

/// Time a single run of `f` (no warm-up): `(result, seconds)`.
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed().as_secs_f64())
}

/// Machine-readable perf sink: each bench `record()`s its measured points
/// and `finish()` merges them into `BENCH_noc.json` — simulated cycles,
/// wall-clock seconds, and simulated cycles per wall-second per point — so
/// successive PRs can track the simulator-throughput trajectory.
///
/// The file is always written (records from *other* benches already in it
/// are preserved; this bench's section is replaced).  `--json` additionally
/// echoes the merged document to stdout; `ESPSIM_BENCH_JSON` overrides the
/// output path.
pub struct BenchJson {
    bench: String,
    path: PathBuf,
    records: Vec<Json>,
    echo: bool,
}

impl BenchJson {
    /// Sink for the bench named `bench`, honoring `--json` / env overrides.
    pub fn from_args(bench: &str) -> Self {
        let path =
            std::env::var("ESPSIM_BENCH_JSON").unwrap_or_else(|_| "BENCH_noc.json".to_string());
        Self {
            bench: bench.to_string(),
            path: PathBuf::from(path),
            records: Vec::new(),
            echo: std::env::args().any(|a| a == "--json"),
        }
    }

    /// Add one measured point: `cycles` simulated in `wall_s` seconds.
    pub fn record(&mut self, point: &str, cycles: u64, wall_s: f64) {
        self.record_with(point, cycles, wall_s, &[]);
    }

    /// Like [`BenchJson::record`], with extra domain metrics attached to
    /// the record (e.g. `baseline_cycles`, `speedup`).  Extra keys override
    /// the standard fields on collision, so a caller can substitute its own
    /// notion of e.g. `cycles_per_sec`.
    pub fn record_with(&mut self, point: &str, cycles: u64, wall_s: f64, extra: &[(&str, Json)]) {
        let mut m = BTreeMap::new();
        m.insert("bench".to_string(), Json::from(self.bench.as_str()));
        m.insert("point".to_string(), Json::from(point));
        m.insert("cycles".to_string(), Json::from(cycles));
        m.insert("wall_s".to_string(), Json::Num(wall_s));
        m.insert("cycles_per_sec".to_string(), Json::Num(cycles as f64 / wall_s.max(1e-12)));
        for (k, v) in extra {
            m.insert((*k).to_string(), v.clone());
        }
        self.records.push(Json::Obj(m));
    }

    /// Points recorded so far (tests / callers that want a summary line).
    pub fn len(&self) -> usize {
        self.records.len()
    }

    /// Nothing recorded?
    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Merge into the output file, replacing this bench's prior records.
    pub fn finish(self) {
        let mut all: Vec<Json> = Vec::new();
        if let Ok(text) = std::fs::read_to_string(&self.path) {
            if let Ok(doc) = Json::parse(&text) {
                if let Some(Json::Arr(recs)) = doc.get("records") {
                    all.extend(
                        recs.iter()
                            .filter(|r| {
                                r.get("bench").and_then(|b| b.as_str().ok())
                                    != Some(self.bench.as_str())
                            })
                            .cloned(),
                    );
                }
            }
        }
        all.extend(self.records);
        let mut top = BTreeMap::new();
        top.insert("records".to_string(), Json::Arr(all));
        let text = Json::Obj(top).to_string();
        if self.echo {
            println!("{text}");
        }
        match std::fs::write(&self.path, &text) {
            Ok(()) => eprintln!("perf records -> {}", self.path.display()),
            Err(e) => eprintln!("warning: could not write {}: {e}", self.path.display()),
        }
    }
}

/// Per-metric tolerances for [`compare`]: the allowed fractional
/// *worsening* of each metric before a point counts as a regression.
/// Simulated `cycles` are deterministic, so their tolerance is tight;
/// wall-clock throughput is machine noise and is not gated unless asked.
#[derive(Debug, Clone, Copy)]
pub struct CompareOpts {
    /// Allowed fractional increase in simulated `cycles` (0.02 = +2%).
    pub tol_cycles: f64,
    /// Allowed fractional drop in `speedup` (recorded by scenario runs).
    pub tol_speedup: f64,
    /// Also gate `cycles_per_sec` (simulator throughput): allowed
    /// fractional drop.  `None` (the default) skips the metric, since CI
    /// runners vary too much for wall-clock to gate merges.
    pub tol_throughput: Option<f64>,
    /// Fail when the baseline holds bench sections the fresh run never
    /// executed.  Off by default (a partial local rerun should compare
    /// cleanly against a full baseline); the CI perf gate turns it on so
    /// a renamed or dropped bench cannot quietly evade the gate by
    /// landing in `skipped_benches`.
    pub strict: bool,
}

impl Default for CompareOpts {
    fn default() -> Self {
        Self { tol_cycles: 0.02, tol_speedup: 0.05, tol_throughput: None, strict: false }
    }
}

/// One metric of one point that got worse past its tolerance.
#[derive(Debug, Clone)]
pub struct Regression {
    /// Bench section the point belongs to.
    pub bench: String,
    /// Point name.
    pub point: String,
    /// Metric that regressed (`cycles`, `speedup`, `cycles_per_sec`).
    pub metric: &'static str,
    /// Committed baseline value.
    pub baseline: f64,
    /// Freshly measured value.
    pub fresh: f64,
}

impl Regression {
    /// Fractional change, signed so that positive = worse for the metric.
    pub fn worsening(&self) -> f64 {
        if self.baseline == 0.0 {
            return 0.0;
        }
        let delta = (self.fresh - self.baseline) / self.baseline;
        if self.metric == "cycles" {
            delta
        } else {
            -delta
        }
    }
}

/// Outcome of diffing a fresh bench document against a committed baseline.
#[derive(Debug, Default)]
pub struct CompareReport {
    /// Points present in both documents and checked metric-by-metric.
    pub points_checked: usize,
    /// Metrics that got worse past tolerance.
    pub regressions: Vec<Regression>,
    /// `bench/point` entries the baseline has but the fresh run lost
    /// (a silently dropped measurement is treated as a failure).
    pub missing_points: Vec<String>,
    /// Fresh points with no baseline yet (informational).
    pub new_points: usize,
    /// Baseline bench sections the fresh run did not execute at all;
    /// skipped rather than failed so a partial run (e.g. the scenario
    /// gate) can be compared against a full baseline — unless
    /// [`CompareOpts::strict`] turned skipping into failure.
    pub skipped_benches: Vec<String>,
    /// Was this comparison run in strict mode (skipped benches fail)?
    pub strict: bool,
}

impl CompareReport {
    /// Did the fresh run hold the baseline?
    pub fn passed(&self) -> bool {
        self.regressions.is_empty()
            && self.missing_points.is_empty()
            && !(self.strict && !self.skipped_benches.is_empty())
    }

    /// Human-readable summary (one line per finding).
    pub fn render(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = writeln!(
            s,
            "bench-compare: {} points checked, {} regressions, {} missing, {} new",
            self.points_checked,
            self.regressions.len(),
            self.missing_points.len(),
            self.new_points
        );
        for r in &self.regressions {
            let _ = writeln!(
                s,
                "  REGRESSION {}/{}: {} {} -> {} ({:+.1}% worse)",
                r.bench,
                r.point,
                r.metric,
                r.baseline,
                r.fresh,
                r.worsening() * 100.0
            );
        }
        for m in &self.missing_points {
            let _ = writeln!(s, "  MISSING {m} (in baseline, absent from fresh run)");
        }
        // Skipped sections are always reported, pass or fail: a renamed
        // bench must be visible in the gate's output either way.
        for b in &self.skipped_benches {
            if self.strict {
                let _ = writeln!(s, "  SKIPPED {b}: baseline section never ran fresh (strict)");
            } else {
                let _ = writeln!(s, "  skipped bench {b}: absent from fresh run (not gated)");
            }
        }
        if self.points_checked == 0 && self.passed() {
            let _ = writeln!(
                s,
                "  baseline has no overlapping records yet (bootstrap): run the benches and \
                 commit the produced BENCH_noc.json to arm the gate"
            );
        }
        s
    }
}

/// Index a bench document's records by `(bench, point)`.
fn index_records(doc: &Json) -> Vec<((String, String), &Json)> {
    let mut out = Vec::new();
    if let Some(Json::Arr(recs)) = doc.get("records") {
        for r in recs {
            if let (Some(Ok(b)), Some(Ok(p))) =
                (r.get("bench").map(|v| v.as_str()), r.get("point").map(|v| v.as_str()))
            {
                out.push(((b.to_string(), p.to_string()), r));
            }
        }
    }
    out
}

/// Diff a fresh bench document against a committed baseline with per-metric
/// tolerances.  Both documents use the [`BenchJson`] schema
/// (`{"records": [{"bench", "point", "cycles", ...}]}`).  Baseline bench
/// sections absent from the fresh document are skipped; baseline *points*
/// of an executed bench must all reappear.  This is the library half of the
/// CI perf gate; `espsim compare` is the nonzero-exit wrapper around it.
pub fn compare(baseline: &Json, fresh: &Json, opts: &CompareOpts) -> CompareReport {
    let base = index_records(baseline);
    let fresh_idx = index_records(fresh);
    let fresh_benches: std::collections::BTreeSet<&str> =
        fresh_idx.iter().map(|((b, _), _)| b.as_str()).collect();
    let mut report = CompareReport { strict: opts.strict, ..CompareReport::default() };

    let metric = |r: &Json, key: &str| r.get(key).and_then(|v| v.as_f64().ok());
    // Degraded sweeps tag records with `completed` (1 = ran to the end,
    // 0 = structured failure whose `cycles`/`speedup` are placeholders,
    // not measurements).  Untagged records are healthy by definition.
    let completed = |r: &Json| match metric(r, "completed") {
        Some(c) => c != 0.0,
        None => true,
    };
    for ((bench, point), brec) in &base {
        if !fresh_benches.contains(bench.as_str()) {
            if !report.skipped_benches.contains(bench) {
                report.skipped_benches.push(bench.clone());
            }
            continue;
        }
        let Some((_, frec)) = fresh_idx.iter().find(|(k, _)| &k.0 == bench && &k.1 == point)
        else {
            report.missing_points.push(format!("{bench}/{point}"));
            continue;
        };
        report.points_checked += 1;
        let (b_done, f_done) = (completed(brec), completed(frec));
        if b_done && !f_done {
            // A point that used to complete and now fails is a
            // regression in its own right (gated at zero tolerance).
            report.regressions.push(Regression {
                bench: bench.clone(),
                point: point.clone(),
                metric: "completed",
                baseline: 1.0,
                fresh: 0.0,
            });
        }
        if !b_done || !f_done {
            // A completion-0 record's perf metrics are placeholders:
            // comparing a healthy run's cycles against a baseline 0 (or
            // vice versa) would report a huge spurious regression — or
            // mask a real one — so the perf checks skip such points
            // entirely on either side.
            continue;
        }
        // Recovery sweeps additionally tag records with `recovered` (1 =
        // the replay path retransmitted and the run still finished).  A
        // point that recovered in the baseline and no longer does means
        // the replay/drain machinery regressed — gated at zero tolerance,
        // exactly like `completed`.
        if let (Some(b), Some(f)) = (metric(brec, "recovered"), metric(frec, "recovered")) {
            if b != 0.0 && f == 0.0 {
                report.regressions.push(Regression {
                    bench: bench.clone(),
                    point: point.clone(),
                    metric: "recovered",
                    baseline: b,
                    fresh: f,
                });
            }
        }
        let mut check = |name: &'static str, tol: f64, higher_is_worse: bool| {
            match (metric(brec, name), metric(frec, name)) {
                (Some(b), Some(f)) => {
                    let bad = if higher_is_worse {
                        f > b * (1.0 + tol)
                    } else {
                        f < b * (1.0 - tol)
                    };
                    if bad {
                        report.regressions.push(Regression {
                            bench: bench.clone(),
                            point: point.clone(),
                            metric: name,
                            baseline: b,
                            fresh: f,
                        });
                    }
                }
                // A gated metric the baseline has but the fresh record
                // dropped is a silent un-gating, not a pass.
                (Some(_), None) => {
                    report.missing_points.push(format!("{bench}/{point} metric {name}"));
                }
                (None, _) => {}
            }
        };
        check("cycles", opts.tol_cycles, true);
        check("speedup", opts.tol_speedup, false);
        if let Some(t) = opts.tol_throughput {
            check("cycles_per_sec", t, false);
        }
    }
    let base_keys: std::collections::BTreeSet<&(String, String)> =
        base.iter().map(|(k, _)| k).collect();
    report.new_points = fresh_idx.iter().filter(|(k, _)| !base_keys.contains(k)).count();
    report
}

/// [`compare`] over files on disk.
pub fn compare_files(
    baseline: impl AsRef<std::path::Path>,
    fresh: impl AsRef<std::path::Path>,
    opts: &CompareOpts,
) -> anyhow::Result<CompareReport> {
    let read = |p: &std::path::Path| -> anyhow::Result<Json> {
        let text = std::fs::read_to_string(p)
            .map_err(|e| anyhow::anyhow!("read {}: {e}", p.display()))?;
        Json::parse(&text).map_err(|e| anyhow::anyhow!("parse {}: {e}", p.display()))
    };
    Ok(compare(&read(baseline.as_ref())?, &read(fresh.as_ref())?, opts))
}

/// Simple aligned-table printer.
pub struct Table {
    widths: Vec<usize>,
}

impl Table {
    /// Start a table and print its header.
    pub fn new(headers: &[&str], widths: &[usize]) -> Self {
        assert_eq!(headers.len(), widths.len());
        let mut line = String::new();
        for (h, w) in headers.iter().zip(widths) {
            line.push_str(&format!("{h:>w$} "));
        }
        println!("{line}");
        println!("{}", "-".repeat(line.len()));
        Self { widths: widths.to_vec() }
    }

    /// Print one row.
    pub fn row(&self, cells: &[String]) {
        let mut line = String::new();
        for (c, w) in cells.iter().zip(&self.widths) {
            line.push_str(&format!("{c:>w$} "));
        }
        println!("{line}");
    }
}

/// Format seconds human-readably.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.0}us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_runs_and_summarizes() {
        let mut n = 0u64;
        let (last, t) = measure(5, || {
            n += 1;
            n
        });
        assert_eq!(t.iters, 5);
        assert!(last >= 5, "5 iters + warmup");
        assert!(t.min_s <= t.median_s && t.median_s <= t.mean_s * 5.0);
    }

    #[test]
    fn fmt() {
        assert_eq!(fmt_secs(2.0), "2.00s");
        assert_eq!(fmt_secs(0.002), "2.00ms");
        assert_eq!(fmt_secs(0.0000021), "2us");
    }

    #[test]
    fn time_once_measures_and_returns() {
        let (v, s) = time_once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }

    fn doc(records: &str) -> Json {
        Json::parse(&format!("{{\"records\":[{records}]}}")).unwrap()
    }

    fn rec(bench: &str, point: &str, cycles: u64, speedup: f64) -> String {
        format!(
            "{{\"bench\":\"{bench}\",\"point\":\"{point}\",\"cycles\":{cycles},\
             \"wall_s\":0.1,\"cycles_per_sec\":{},\"speedup\":{speedup}}}",
            cycles as f64 / 0.1
        )
    }

    #[test]
    fn compare_passes_identical_and_improved_runs() {
        let base = doc(&rec("s", "p1", 1000, 2.0));
        let same = compare(&base, &base, &CompareOpts::default());
        assert!(same.passed());
        assert_eq!(same.points_checked, 1);
        // Fewer cycles and more speedup are improvements, not regressions.
        let better = doc(&rec("s", "p1", 900, 2.5));
        assert!(compare(&base, &better, &CompareOpts::default()).passed());
    }

    #[test]
    fn compare_flags_doctored_cycle_regression() {
        let base = doc(&rec("s", "p1", 1000, 2.0));
        let slower = doc(&rec("s", "p1", 1100, 2.0)); // +10% > 2% tolerance
        let r = compare(&base, &slower, &CompareOpts::default());
        assert!(!r.passed());
        assert_eq!(r.regressions.len(), 1);
        assert_eq!(r.regressions[0].metric, "cycles");
        assert!(r.regressions[0].worsening() > 0.09);
        assert!(r.render().contains("REGRESSION s/p1"));
        // Within tolerance passes.
        let noise = doc(&rec("s", "p1", 1010, 2.0)); // +1% < 2%
        assert!(compare(&base, &noise, &CompareOpts::default()).passed());
    }

    #[test]
    fn compare_flags_speedup_drops_and_missing_points() {
        let base = doc(&format!("{},{}", rec("s", "p1", 1000, 2.0), rec("s", "p2", 500, 3.0)));
        let degraded = doc(&rec("s", "p1", 1000, 1.5)); // speedup -25%, p2 gone
        let r = compare(&base, &degraded, &CompareOpts::default());
        assert!(!r.passed());
        assert_eq!(r.regressions.len(), 1);
        assert_eq!(r.regressions[0].metric, "speedup");
        assert_eq!(r.missing_points, vec!["s/p2".to_string()]);
    }

    #[test]
    fn compare_flags_gated_metrics_dropped_from_fresh_records() {
        let base = doc(&rec("s", "p1", 1000, 2.0));
        // Same point, but the fresh record stopped emitting `speedup`.
        let fresh = Json::parse(
            "{\"records\":[{\"bench\":\"s\",\"point\":\"p1\",\"cycles\":1000,\"wall_s\":0.1}]}",
        )
        .unwrap();
        let r = compare(&base, &fresh, &CompareOpts::default());
        assert!(!r.passed(), "silently un-gated metric must fail");
        assert!(r.missing_points.iter().any(|m| m.contains("metric speedup")));
    }

    #[test]
    fn compare_skips_benches_absent_from_fresh_and_counts_new_points() {
        let base = doc(&format!("{},{}", rec("fig6", "a", 900, 1.7), rec("s", "p1", 1000, 2.0)));
        let fresh = doc(&format!("{},{}", rec("s", "p1", 1000, 2.0), rec("s", "p9", 400, 1.1)));
        let r = compare(&base, &fresh, &CompareOpts::default());
        assert!(r.passed(), "fig6 not rerun -> skipped, not failed");
        assert_eq!(r.skipped_benches, vec!["fig6".to_string()]);
        assert_eq!(r.new_points, 1);
        assert_eq!(r.points_checked, 1);
    }

    /// A degraded-sweep failure record: completion 0, placeholder cycles.
    fn failed_rec(bench: &str, point: &str) -> String {
        format!(
            "{{\"bench\":\"{bench}\",\"point\":\"{point}\",\"cycles\":0,\"wall_s\":0.1,\
             \"cycles_per_sec\":0,\"completed\":0,\"failure\":\"did not quiesce\"}}"
        )
    }

    /// A degraded-sweep success record: completion 1 plus real metrics.
    fn done_rec(bench: &str, point: &str, cycles: u64, speedup: f64) -> String {
        let r = rec(bench, point, cycles, speedup);
        format!("{},\"completed\":1}}", &r[..r.len() - 1])
    }

    #[test]
    fn compare_skips_perf_metrics_of_completion0_baseline_records() {
        // A doctored baseline where a degraded point failed (cycles=0):
        // a now-healthy fresh run must NOT read as a +inf cycle
        // regression, and the completion recovery is not a failure.
        let base = doc(&failed_rec("s", "p1"));
        let fresh = doc(&done_rec("s", "p1", 120_000, 2.0));
        let r = compare(&base, &fresh, &CompareOpts::default());
        assert!(r.passed(), "{}", r.render());
        assert_eq!(r.points_checked, 1);
        assert!(r.regressions.is_empty(), "placeholder cycles must not be gated");
    }

    #[test]
    fn compare_flags_a_fresh_completion0_record_as_a_regression() {
        // The other direction: a point that completed in the baseline and
        // fails fresh is a regression on `completed` — and its
        // placeholder cycles (0 < baseline) must not mask it as a pass.
        for baseline in [doc(&done_rec("s", "p1", 1000, 2.0)), doc(&rec("s", "p1", 1000, 2.0))] {
            let fresh = doc(&failed_rec("s", "p1"));
            let r = compare(&baseline, &fresh, &CompareOpts::default());
            assert!(!r.passed());
            assert_eq!(r.regressions.len(), 1, "{}", r.render());
            assert_eq!(r.regressions[0].metric, "completed");
            assert!(r.regressions[0].worsening() > 0.99, "completed 1->0 is 100% worse");
            // No spurious cycles/speedup findings from the placeholders.
            assert!(r.missing_points.is_empty(), "{}", r.render());
        }
        // Still-failing points are stable, not a new regression.
        let both = doc(&failed_rec("s", "p1"));
        assert!(compare(&both, &both, &CompareOpts::default()).passed());
    }

    /// A recovery-sweep record: completed, with a `recovered` tag.
    fn recovered_rec(bench: &str, point: &str, recovered: u64) -> String {
        let r = done_rec(bench, point, 1000, 2.0);
        format!("{},\"recovered\":{recovered}}}", &r[..r.len() - 1])
    }

    #[test]
    fn compare_gates_recovery_rate_at_zero_tolerance() {
        // A point the baseline recovered (replayed and still completed)
        // must keep recovering: 1 -> 0 is a regression even though both
        // runs completed and every perf metric is identical.
        let base = doc(&recovered_rec("s", "p1", 1));
        let fresh = doc(&recovered_rec("s", "p1", 0));
        let r = compare(&base, &fresh, &CompareOpts::default());
        assert!(!r.passed());
        assert_eq!(r.regressions.len(), 1, "{}", r.render());
        assert_eq!(r.regressions[0].metric, "recovered");
        // Same tag passes; gaining recovery passes; untagged baselines
        // (pristine sweeps) never see the gate.
        assert!(compare(&base, &base, &CompareOpts::default()).passed());
        assert!(compare(&fresh, &base, &CompareOpts::default()).passed());
        let plain = doc(&done_rec("s", "p1", 1000, 2.0));
        assert!(compare(&plain, &fresh, &CompareOpts::default()).passed());
    }

    #[test]
    fn compare_strict_fails_on_skipped_benches() {
        let base = doc(&format!("{},{}", rec("fig6", "a", 900, 1.7), rec("s", "p1", 1000, 2.0)));
        let fresh = doc(&rec("s", "p1", 1000, 2.0));
        let lax = compare(&base, &fresh, &CompareOpts::default());
        assert!(lax.passed(), "default mode keeps skipping");
        assert!(lax.render().contains("skipped bench fig6"), "{}", lax.render());
        let strict = CompareOpts { strict: true, ..CompareOpts::default() };
        let r = compare(&base, &fresh, &strict);
        assert!(!r.passed(), "strict mode must fail on a skipped section");
        assert_eq!(r.skipped_benches, vec!["fig6".to_string()]);
        assert!(r.render().contains("SKIPPED fig6"), "{}", r.render());
        // With every section rerun, strict passes.
        let full = doc(&format!("{},{}", rec("fig6", "a", 900, 1.7), rec("s", "p1", 1000, 2.0)));
        assert!(compare(&base, &full, &strict).passed());
    }

    #[test]
    fn compare_empty_baseline_bootstraps_green() {
        let base = Json::parse("{\"records\":[]}").unwrap();
        let fresh = doc(&rec("s", "p1", 1000, 2.0));
        let r = compare(&base, &fresh, &CompareOpts::default());
        assert!(r.passed());
        assert_eq!(r.points_checked, 0);
        assert!(r.render().contains("bootstrap"));
    }

    #[test]
    fn compare_throughput_gated_only_on_request() {
        let base = doc(&rec("s", "p1", 1000, 2.0));
        // Same cycles, halved wall-clock throughput.
        let fresh = Json::parse(
            "{\"records\":[{\"bench\":\"s\",\"point\":\"p1\",\"cycles\":1000,\
             \"wall_s\":0.2,\"cycles_per_sec\":5000,\"speedup\":2.0}]}",
        )
        .unwrap();
        assert!(compare(&base, &fresh, &CompareOpts::default()).passed());
        let gated = CompareOpts { tol_throughput: Some(0.2), ..CompareOpts::default() };
        let r = compare(&base, &fresh, &gated);
        assert!(!r.passed());
        assert_eq!(r.regressions[0].metric, "cycles_per_sec");
    }

    #[test]
    fn record_with_attaches_extra_metrics() {
        let dir = std::env::temp_dir().join(format!("espsim_bench_extra_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_extra.json");
        let mut s = BenchJson {
            bench: "scen".to_string(),
            path: path.clone(),
            records: Vec::new(),
            echo: false,
        };
        s.record_with("p", 100, 0.5, &[("speedup", Json::Num(1.5))]);
        s.finish();
        let d = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let r = &d.get("records").unwrap().as_arr().unwrap()[0];
        assert_eq!(r.get("speedup").unwrap().as_f64().unwrap(), 1.5);
        assert_eq!(r.get("cycles").unwrap().as_u64().unwrap(), 100);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bench_json_merges_per_bench_sections() {
        let dir = std::env::temp_dir().join(format!("espsim_bench_json_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_test.json");
        let mk = |bench: &str| BenchJson {
            bench: bench.to_string(),
            path: path.clone(),
            records: Vec::new(),
            echo: false,
        };
        let mut a = mk("alpha");
        a.record("p1", 1000, 0.5);
        assert_eq!(a.len(), 1);
        a.finish();
        let mut b = mk("beta");
        b.record("p2", 2000, 0.25);
        b.finish();
        // Re-running alpha replaces its record but keeps beta's.
        let mut a2 = mk("alpha");
        a2.record("p1", 3000, 0.5);
        a2.finish();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let recs = doc.get("records").unwrap().as_arr().unwrap();
        assert_eq!(recs.len(), 2);
        let find = |bench: &str| {
            recs.iter()
                .find(|r| r.get("bench").unwrap().as_str().unwrap() == bench)
                .unwrap()
                .clone()
        };
        assert_eq!(find("alpha").get("cycles").unwrap().as_u64().unwrap(), 3000);
        assert_eq!(find("beta").get("cycles").unwrap().as_u64().unwrap(), 2000);
        let cps = find("beta").get("cycles_per_sec").unwrap().as_f64().unwrap();
        assert!((cps - 8000.0).abs() < 1e-6);
        std::fs::remove_dir_all(&dir).ok();
    }
}
