//! Minimal JSON parser/writer (offline substrate — no external crates).
//!
//! Covers the JSON subset that `artifacts/manifest.json` and the SoC
//! config files use: objects, arrays, strings (with standard escapes),
//! numbers, booleans, null.  Strict enough to reject malformed documents,
//! small enough to audit.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field or error.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing field {key:?}"))
    }

    /// As f64.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    /// As u64 (must be a non-negative integer).
    pub fn as_u64(&self) -> Result<u64> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("expected non-negative integer, got {n}");
        }
        Ok(n as u64)
    }

    /// As string slice.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    /// As array slice.
    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    /// As object map.
    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors.
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, got {:?}", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected {:?} at byte {}", c as char, self.i),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        c => bail!("bad escape \\{:?}", c as char),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                c => {
                    // Re-assemble a UTF-8 sequence.
                    let start = self.i - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    if start + len > self.b.len() {
                        bail!("truncated UTF-8");
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..start + len])?);
                    self.i = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| anyhow!("bad number {text:?}: {e}"))?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
            "artifacts": {
                "a": {"file": "a.hlo.txt",
                      "inputs": [{"shape": [32, 256], "dtype": "float32"}],
                      "outputs": [{"shape": [32, 256], "dtype": "float32"}]}
            },
            "pipeline": {"batch": 32, "tensors": {"x": [32, 256]}}
        }"#;
        let j = Json::parse(doc).unwrap();
        let a = j.req("artifacts").unwrap().req("a").unwrap();
        assert_eq!(a.req("file").unwrap().as_str().unwrap(), "a.hlo.txt");
        let shape = a.req("inputs").unwrap().as_arr().unwrap()[0].req("shape").unwrap();
        assert_eq!(shape.as_arr().unwrap()[1].as_u64().unwrap(), 256);
        assert_eq!(j.req("pipeline").unwrap().req("batch").unwrap().as_u64().unwrap(), 32);
    }

    #[test]
    fn roundtrip() {
        let doc = r#"{"a":[1,2.5,-3],"b":"x\"y\n","c":true,"d":null,"e":{}}"#;
        let j = Json::parse(doc).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn escapes_and_unicode() {
        let j = Json::parse(r#""A\t\\ é""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "A\t\\ é");
        let j = Json::parse("\"caf\u{00e9}\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "café");
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,", "{\"a\":}", "tru", "1.2.3", "\"unterminated", "{}x", ""] {
            assert!(Json::parse(bad).is_err(), "{bad:?}");
        }
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("42").unwrap().as_u64().unwrap(), 42);
        assert_eq!(Json::parse("-1.5").unwrap().as_f64().unwrap(), -1.5);
        assert_eq!(Json::parse("1e3").unwrap().as_f64().unwrap(), 1000.0);
        assert!(Json::parse("-1").unwrap().as_u64().is_err());
        assert!(Json::parse("1.5").unwrap().as_u64().is_err());
    }
}
