//! Deterministic PRNG (SplitMix64) for property tests and workload
//! generation.  No external crates; identical sequences across platforms,
//! which keeps simulations reproducible.

/// SplitMix64 generator.
#[derive(Debug, Clone)]
pub struct Prng {
    state: u64,
}

impl Prng {
    /// Seeded generator.
    pub fn new(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)`.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        self.next_u64() % n
    }

    /// Uniform in `[lo, hi]`.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo + 1)
    }

    /// Coin flip with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        (self.next_u64() as f64 / u64::MAX as f64) < p
    }

    /// Pick one element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Random bytes.
    pub fn bytes(&mut self, n: usize) -> Vec<u8> {
        (0..n).map(|_| self.next_u64() as u8).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Prng::new(7);
        let mut b = Prng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Prng::new(1).next_u64(), Prng::new(2).next_u64());
    }

    #[test]
    fn ranges_in_bounds() {
        let mut r = Prng::new(42);
        for _ in 0..1000 {
            let v = r.range(5, 10);
            assert!((5..=10).contains(&v));
            assert!(r.below(3) < 3);
        }
    }

    #[test]
    fn rough_uniformity() {
        let mut r = Prng::new(1);
        let mut counts = [0u32; 8];
        for _ in 0..8000 {
            counts[r.below(8) as usize] += 1;
        }
        for c in counts {
            assert!((700..1300).contains(&c), "{counts:?}");
        }
    }
}
