//! Offline substrates: JSON, PRNG, benchmarking — the external-crate
//! functionality this repo re-implements so it builds with only the
//! vendored `xla` + `anyhow`.

pub mod bench;
pub mod json;
pub mod prng;

pub use json::Json;
pub use prng::Prng;

/// FNV-1a/64 offset basis — start the scenario payload digest here and
/// fold each sink region in a deterministic order with [`fnv1a64`].
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// Fold `data` into FNV-1a/64 hash state `h` (chainable).
pub fn fnv1a64(mut h: u64, data: &[u8]) -> u64 {
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}
