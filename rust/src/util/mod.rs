//! Offline substrates: JSON, PRNG, benchmarking — the external-crate
//! functionality this repo re-implements so it builds with only the
//! vendored `xla` + `anyhow`.

pub mod bench;
pub mod json;
pub mod prng;

pub use json::Json;
pub use prng::Prng;
