//! The programmable accelerator core: a small in-order engine that fetches
//! the instructions of [`crate::accel::isa`], drives the socket through
//! IDMA/CDMA, and launches the datapath.
//!
//! DMA is asynchronous with respect to the pipeline (the paper's point):
//! `Idma` returns a tag immediately, and the program overlaps further
//! issue/compute with the transfer, joining on `Wdma`/`Cdma`.

use crate::accel::datapath::{self, DpCall};
use crate::accel::isa::{Instr, NUM_REGS};
use crate::sched::Wake;
use crate::socket::{DmaDir, Socket, TAG_NONE};

/// Core execution state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CoreState {
    /// Waiting for a start pulse.
    Idle,
    /// Executing.
    Running,
    /// Program hit `Done`; socket drains and raises the IRQ.
    Finished,
}

/// Core statistics.
#[derive(Debug, Default, Clone)]
pub struct CoreStats {
    /// Instructions retired.
    pub instrs: u64,
    /// Cycles stalled on DMA (Wdma/Idma backpressure).
    pub dma_stall_cycles: u64,
    /// Cycles stalled on the datapath.
    pub dp_stall_cycles: u64,
    /// Cycles the datapath was busy.
    pub dp_busy_cycles: u64,
}

/// One programmable accelerator core.
pub struct AccCore {
    /// Scalar register file.
    pub regs: [u64; NUM_REGS],
    program: Vec<Instr>,
    pc: usize,
    state: CoreState,
    /// Datapath descriptor table (set up by the launcher; indexed by RunDp).
    pub dp_calls: Vec<DpCall>,
    dp_busy_until: u64,
    /// Statistics.
    pub stats: CoreStats,
}

impl AccCore {
    /// Idle core with an empty program.
    pub fn new() -> Self {
        Self {
            regs: [0; NUM_REGS],
            program: Vec::new(),
            pc: 0,
            state: CoreState::Idle,
            dp_calls: Vec::new(),
            dp_busy_until: 0,
            stats: CoreStats::default(),
        }
    }

    /// Load a program (host-side setup; instruction memory write).
    pub fn load_program(&mut self, program: Vec<Instr>) {
        self.program = program;
    }

    /// Current state.
    pub fn state(&self) -> CoreState {
        self.state
    }

    /// Begin an invocation: copy the socket ARG registers into r1..r8,
    /// reset pc.  (r0 is hardwired zero.)
    pub fn start(&mut self, args: &[u64; 8]) {
        self.regs = [0; NUM_REGS];
        for (i, &a) in args.iter().enumerate() {
            self.regs[1 + i] = a;
        }
        self.pc = 0;
        self.state = CoreState::Running;
    }

    /// Acknowledge the Finished state (tile sends the IRQ).
    pub fn acknowledge_finish(&mut self) {
        self.state = CoreState::Idle;
    }

    fn set_reg(&mut self, rd: u8, val: u64) {
        if rd != 0 {
            self.regs[rd as usize] = val;
        }
    }

    /// Execute at most one instruction this cycle.
    ///
    /// The returned [`Wake`] classifies the cycle: `Busy` while the
    /// pipeline can advance by itself, `Sleeping` on a datapath wait
    /// (`RunDp`/`Wdp` against a known busy-until), `Parked` on a `Wdma`
    /// spin — the joined tag completes only through a socket delivery (or
    /// the socket's own timed sends, which the tile aggregates in).  Spin
    /// retries skipped by a parked core would each have re-polled an
    /// unchanged tag, so `dma_stall_cycles`/`dp_stall_cycles` count
    /// *executed* retries and are scheduler-dependent by design.
    pub fn tick(&mut self, now: u64, socket: &mut Socket, plm: &mut [u8]) -> Wake {
        if self.state != CoreState::Running {
            return Wake::Parked;
        }
        let Some(&instr) = self.program.get(self.pc) else {
            panic!("pc {} past end of program", self.pc);
        };
        let mut next_pc = self.pc + 1;
        let mut wake = Wake::Busy;
        match instr {
            Instr::Seti { rd, imm } => self.set_reg(rd, imm as i64 as u64),
            Instr::Add { rd, ra, rb } => {
                self.set_reg(rd, self.regs[ra as usize].wrapping_add(self.regs[rb as usize]))
            }
            Instr::Addi { rd, ra, imm } => {
                self.set_reg(rd, self.regs[ra as usize].wrapping_add(imm as i64 as u64))
            }
            Instr::Idma { rd, dir, vaddr, plm: plm_r, len, user } => {
                let vaddr = self.regs[vaddr as usize];
                let plm_addr = self.regs[plm_r as usize] as u32;
                let len = self.regs[len as usize] as u32;
                let user = self.regs[user as usize] as u16;
                let tag = match dir {
                    DmaDir::Read => socket.submit_read(vaddr, len, user, plm_addr),
                    DmaDir::Write => socket.submit_write(vaddr, len, user, plm_addr),
                };
                match tag {
                    Some(t) => self.set_reg(rd, t as u64),
                    None => {
                        // Control channel full: retry this instruction.
                        self.stats.dma_stall_cycles += 1;
                        next_pc = self.pc;
                    }
                }
            }
            Instr::Cdma { rd, tag } => {
                let t = self.regs[tag as usize];
                let done = t == TAG_NONE as u64 || socket.is_done(t as u32);
                self.set_reg(rd, done as u64);
            }
            Instr::Wdma { tag } => {
                let t = self.regs[tag as usize];
                if !(t == TAG_NONE as u64 || socket.is_done(t as u32)) {
                    self.stats.dma_stall_cycles += 1;
                    next_pc = self.pc; // spin: only a completion can unblock
                    wake = Wake::Parked;
                }
            }
            Instr::RunDp { call } => {
                if now < self.dp_busy_until {
                    self.stats.dp_stall_cycles += 1;
                    next_pc = self.pc; // datapath busy: wait to launch
                    wake = Wake::at(now, self.dp_busy_until);
                } else {
                    let call = self
                        .dp_calls
                        .get(call as usize)
                        .unwrap_or_else(|| panic!("RunDp: no descriptor {call}"))
                        .clone();
                    let busy = datapath::execute(&call, plm);
                    self.dp_busy_until = now + busy;
                    self.stats.dp_busy_cycles += busy;
                }
            }
            Instr::Wdp => {
                if now < self.dp_busy_until {
                    self.stats.dp_stall_cycles += 1;
                    next_pc = self.pc;
                    wake = Wake::at(now, self.dp_busy_until);
                }
            }
            Instr::Blt { ra, rb, off } => {
                if self.regs[ra as usize] < self.regs[rb as usize] {
                    next_pc = (self.pc as i64 + off as i64) as usize;
                }
            }
            Instr::Bge { ra, rb, off } => {
                if self.regs[ra as usize] >= self.regs[rb as usize] {
                    next_pc = (self.pc as i64 + off as i64) as usize;
                }
            }
            Instr::Beq { ra, rb, off } => {
                if self.regs[ra as usize] == self.regs[rb as usize] {
                    next_pc = (self.pc as i64 + off as i64) as usize;
                }
            }
            Instr::Jmp { off } => next_pc = (self.pc as i64 + off as i64) as usize,
            Instr::Done => {
                self.state = CoreState::Finished;
                wake = Wake::Parked; // tile completion logic takes over
            }
        }
        if next_pc != self.pc || matches!(instr, Instr::Jmp { off: 0 }) {
            self.stats.instrs += 1;
        }
        self.pc = next_pc;
        wake
    }
}

impl Default for AccCore {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AccConfig;

    fn harness() -> (AccCore, Socket, Vec<u8>) {
        let mut s = Socket::new((1, 1), 0, 0, AccConfig::default(), (0, 3), (0, 0), 16);
        s.tlb.map_linear(0, 1 << 20);
        (AccCore::new(), s, vec![0u8; 64 << 10])
    }

    fn run(core: &mut AccCore, socket: &mut Socket, plm: &mut Vec<u8>, max: u64) -> u64 {
        use crate::noc::{Message, MsgKind};
        // Fake memory with a small response latency so CDMA can observe an
        // in-flight transaction.
        let mut pending: Vec<(u64, Message)> = Vec::new();
        let mut now = 0;
        while core.state() == CoreState::Running {
            core.tick(now, socket, plm);
            socket.tick(now, plm);
            for (_, msg) in socket.drain_out() {
                match msg.kind {
                    MsgKind::DmaReadReq { len, tag, slot, .. } => pending.push((
                        now + 4,
                        Message::data(
                            (0, 3),
                            (1, 1),
                            MsgKind::DmaReadRsp { tag, slot },
                            std::sync::Arc::new(vec![0xCD; len as usize]),
                        ),
                    )),
                    MsgKind::DmaWriteReq { tag, slot, .. } => pending.push((
                        now + 4,
                        Message::ctrl((0, 3), (1, 1), MsgKind::DmaWriteAck { tag, slot }),
                    )),
                    _ => {}
                }
            }
            let mut i = 0;
            while i < pending.len() {
                if pending[i].0 <= now {
                    let (_, msg) = pending.swap_remove(i);
                    socket.handle_msg(&msg, plm);
                } else {
                    i += 1;
                }
            }
            now += 1;
            assert!(now < max, "program did not finish in {max} cycles");
        }
        now
    }

    #[test]
    fn scalar_ops_and_branches() {
        let (mut core, mut s, mut plm) = harness();
        // sum 0..5 into r2.
        core.load_program(vec![
            Instr::Seti { rd: 1, imm: 0 },  // i
            Instr::Seti { rd: 2, imm: 0 },  // acc
            Instr::Seti { rd: 3, imm: 5 },  // bound
            Instr::Add { rd: 2, ra: 2, rb: 1 },
            Instr::Addi { rd: 1, ra: 1, imm: 1 },
            Instr::Blt { ra: 1, rb: 3, off: -2 },
            Instr::Done,
        ]);
        core.start(&[0; 8]);
        run(&mut core, &mut s, &mut plm, 1000);
        assert_eq!(core.regs[2], 0 + 1 + 2 + 3 + 4);
        assert_eq!(core.state(), CoreState::Finished);
    }

    #[test]
    fn idma_wdma_roundtrip() {
        let (mut core, mut s, mut plm) = harness();
        core.load_program(vec![
            Instr::Seti { rd: 4, imm: 0 },    // vaddr
            Instr::Seti { rd: 5, imm: 256 },  // plm
            Instr::Seti { rd: 6, imm: 512 },  // len
            Instr::Seti { rd: 7, imm: 0 },    // user = mem
            Instr::Idma { rd: 8, dir: DmaDir::Read, vaddr: 4, plm: 5, len: 6, user: 7 },
            Instr::Wdma { tag: 8 },
            Instr::Done,
        ]);
        core.start(&[0; 8]);
        run(&mut core, &mut s, &mut plm, 1000);
        assert_eq!(plm[256], 0xCD);
        assert_eq!(plm[256 + 511], 0xCD);
        assert!(core.stats.instrs >= 7);
    }

    #[test]
    fn cdma_polls_status() {
        let (mut core, mut s, mut plm) = harness();
        core.load_program(vec![
            Instr::Seti { rd: 4, imm: 0 },
            Instr::Seti { rd: 5, imm: 0 },
            Instr::Seti { rd: 6, imm: 64 },
            Instr::Seti { rd: 7, imm: 0 },
            Instr::Idma { rd: 8, dir: DmaDir::Read, vaddr: 4, plm: 5, len: 6, user: 7 },
            Instr::Cdma { rd: 9, tag: 8 }, // immediately after issue: not done
            Instr::Wdma { tag: 8 },
            Instr::Cdma { rd: 10, tag: 8 }, // after join: done
            Instr::Done,
        ]);
        core.start(&[0; 8]);
        run(&mut core, &mut s, &mut plm, 1000);
        assert_eq!(core.regs[9], 0, "CDMA right after IDMA sees in-flight");
        assert_eq!(core.regs[10], 1, "CDMA after WDMA sees done");
    }

    #[test]
    fn datapath_identity_runs() {
        let (mut core, mut s, mut plm) = harness();
        plm[0..4].copy_from_slice(&[1, 2, 3, 4]);
        core.dp_calls = vec![DpCall {
            kind: crate::accel::datapath::DpKind::Identity,
            inputs: vec![(0, 4)],
            out_offset: 100,
            cycles: 10,
        }];
        core.load_program(vec![Instr::RunDp { call: 0 }, Instr::Wdp, Instr::Done]);
        core.start(&[0; 8]);
        let cycles = run(&mut core, &mut s, &mut plm, 1000);
        assert_eq!(&plm[100..104], &[1, 2, 3, 4]);
        assert!(cycles >= 10, "Wdp stalls for the charged latency");
        assert_eq!(core.stats.dp_busy_cycles, 10);
    }

    #[test]
    fn args_land_in_registers() {
        let (mut core, mut s, mut plm) = harness();
        core.load_program(vec![Instr::Done]);
        core.start(&[11, 22, 33, 44, 55, 66, 77, 88]);
        assert_eq!(core.regs[1], 11);
        assert_eq!(core.regs[8], 88);
        run(&mut core, &mut s, &mut plm, 10);
    }

    #[test]
    fn r0_is_hardwired_zero() {
        let (mut core, mut s, mut plm) = harness();
        core.load_program(vec![Instr::Seti { rd: 0, imm: 42 }, Instr::Done]);
        core.start(&[0; 8]);
        run(&mut core, &mut s, &mut plm, 10);
        assert_eq!(core.regs[0], 0);
    }
}
