//! Programmable accelerators: the example ISA (IDMA/CDMA), the in-order
//! core, datapath backends (identity / compiled JAX-Pallas stages), and
//! program builders (traffic generator, NN stages).

pub mod core;
pub mod datapath;
pub mod isa;
pub mod program;
pub mod traffic_gen;

pub use core::{AccCore, CoreState, CoreStats};
pub use datapath::{matmul_cycles, stream_cycles, DpCall, DpKind};
pub use isa::{decode, encode, Instr};
pub use program::{stage_program, Xfer};
pub use traffic_gen::TgenArgs;
