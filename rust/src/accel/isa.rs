//! The example ISA extension of the paper (§3, *Example ISA*) plus the
//! minimal scalar scaffolding a programmable accelerator needs to drive it.
//!
//! The two paper instructions:
//!
//! - **IDMA** — initiate a DMA transaction: direction, length, word size,
//!   source / number-of-destinations (the `user` field), virtual address in
//!   the accelerator's buffer, and PLM address.  Returns a **tag**.
//! - **CDMA** — check a DMA transaction: takes a tag, returns status, so the
//!   accelerator can overlap DMA with compute and branch on completion.
//!
//! Plus `WDMA` (spin on CDMA until done — the common idiom), datapath
//! launch/wait, and a small scalar RISC subset (set/add/branch) so real
//! loops can be expressed.  Every instruction encodes to one 64-bit word
//! ([`encode`]/[`decode`] round-trip exactly), which is how a RoCC-style
//! extension would carry them.

use crate::socket::DmaDir;

/// Number of scalar registers.
pub const NUM_REGS: usize = 32;

/// One instruction.  All DMA operands come from registers so programs can
/// loop over bursts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Instr {
    /// `rd = imm` (sign-extended).
    Seti { rd: u8, imm: i32 },
    /// `rd = ra + rb`.
    Add { rd: u8, ra: u8, rb: u8 },
    /// `rd = ra + imm` (sign-extended).
    Addi { rd: u8, ra: u8, imm: i32 },
    /// Initiate DMA: `rd = tag`.  Registers: `vaddr`, `plm`, `len`, `user`.
    Idma { rd: u8, dir: DmaDir, vaddr: u8, plm: u8, len: u8, user: u8 },
    /// Check DMA: `rd = 1` if the transaction in register `tag` is done.
    Cdma { rd: u8, tag: u8 },
    /// Wait (spin) until the transaction in register `tag` is done.
    Wdma { tag: u8 },
    /// Launch datapath descriptor `call` (see `DpCall`).
    RunDp { call: u8 },
    /// Wait for the datapath to finish.
    Wdp,
    /// Branch by `off` instructions when `ra < rb`.
    Blt { ra: u8, rb: u8, off: i16 },
    /// Branch by `off` instructions when `ra >= rb`.
    Bge { ra: u8, rb: u8, off: i16 },
    /// Branch by `off` instructions when `ra == rb`.
    Beq { ra: u8, rb: u8, off: i16 },
    /// Unconditional branch by `off` instructions.
    Jmp { off: i16 },
    /// Invocation complete.
    Done,
}

// Opcode bytes.
const OP_SETI: u8 = 0x01;
const OP_ADD: u8 = 0x02;
const OP_ADDI: u8 = 0x03;
const OP_IDMA_R: u8 = 0x04;
const OP_IDMA_W: u8 = 0x05;
const OP_CDMA: u8 = 0x06;
const OP_WDMA: u8 = 0x07;
const OP_RUNDP: u8 = 0x08;
const OP_WDP: u8 = 0x09;
const OP_BLT: u8 = 0x0A;
const OP_BGE: u8 = 0x0B;
const OP_BEQ: u8 = 0x0C;
const OP_JMP: u8 = 0x0D;
const OP_DONE: u8 = 0x0E;

/// Encode an instruction to its 64-bit form:
/// `[63:56] opcode | [55:48] rd | [47:40] ra | [39:32] rb | [31:0] imm`.
pub fn encode(i: Instr) -> u64 {
    let pack = |op: u8, rd: u8, ra: u8, rb: u8, imm: u32| -> u64 {
        ((op as u64) << 56)
            | ((rd as u64) << 48)
            | ((ra as u64) << 40)
            | ((rb as u64) << 32)
            | imm as u64
    };
    match i {
        Instr::Seti { rd, imm } => pack(OP_SETI, rd, 0, 0, imm as u32),
        Instr::Add { rd, ra, rb } => pack(OP_ADD, rd, ra, rb, 0),
        Instr::Addi { rd, ra, imm } => pack(OP_ADDI, rd, ra, 0, imm as u32),
        Instr::Idma { rd, dir, vaddr, plm, len, user } => {
            let op = if dir == DmaDir::Read { OP_IDMA_R } else { OP_IDMA_W };
            // vaddr/plm in ra/rb; len/user packed into imm.
            pack(op, rd, vaddr, plm, ((len as u32) << 8) | user as u32)
        }
        Instr::Cdma { rd, tag } => pack(OP_CDMA, rd, tag, 0, 0),
        Instr::Wdma { tag } => pack(OP_WDMA, 0, tag, 0, 0),
        Instr::RunDp { call } => pack(OP_RUNDP, 0, 0, 0, call as u32),
        Instr::Wdp => pack(OP_WDP, 0, 0, 0, 0),
        Instr::Blt { ra, rb, off } => pack(OP_BLT, 0, ra, rb, off as u16 as u32),
        Instr::Bge { ra, rb, off } => pack(OP_BGE, 0, ra, rb, off as u16 as u32),
        Instr::Beq { ra, rb, off } => pack(OP_BEQ, 0, ra, rb, off as u16 as u32),
        Instr::Jmp { off } => pack(OP_JMP, 0, 0, 0, off as u16 as u32),
        Instr::Done => pack(OP_DONE, 0, 0, 0, 0),
    }
}

/// Decode a 64-bit instruction word.  Returns `None` on an unknown opcode.
pub fn decode(w: u64) -> Option<Instr> {
    let op = (w >> 56) as u8;
    let rd = (w >> 48) as u8;
    let ra = (w >> 40) as u8;
    let rb = (w >> 32) as u8;
    let imm = w as u32;
    Some(match op {
        OP_SETI => Instr::Seti { rd, imm: imm as i32 },
        OP_ADD => Instr::Add { rd, ra, rb },
        OP_ADDI => Instr::Addi { rd, ra, imm: imm as i32 },
        OP_IDMA_R | OP_IDMA_W => Instr::Idma {
            rd,
            dir: if op == OP_IDMA_R { DmaDir::Read } else { DmaDir::Write },
            vaddr: ra,
            plm: rb,
            len: (imm >> 8) as u8,
            user: imm as u8,
        },
        OP_CDMA => Instr::Cdma { rd, tag: ra },
        OP_WDMA => Instr::Wdma { tag: ra },
        OP_RUNDP => Instr::RunDp { call: imm as u8 },
        OP_WDP => Instr::Wdp,
        OP_BLT => Instr::Blt { ra, rb, off: imm as u16 as i16 },
        OP_BGE => Instr::Bge { ra, rb, off: imm as u16 as i16 },
        OP_BEQ => Instr::Beq { ra, rb, off: imm as u16 as i16 },
        OP_JMP => Instr::Jmp { off: imm as u16 as i16 },
        OP_DONE => Instr::Done,
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all_samples() -> Vec<Instr> {
        vec![
            Instr::Seti { rd: 3, imm: -1 },
            Instr::Seti { rd: 31, imm: i32::MAX },
            Instr::Add { rd: 1, ra: 2, rb: 3 },
            Instr::Addi { rd: 4, ra: 5, imm: -4096 },
            Instr::Idma { rd: 6, dir: DmaDir::Read, vaddr: 7, plm: 8, len: 9, user: 10 },
            Instr::Idma { rd: 11, dir: DmaDir::Write, vaddr: 12, plm: 13, len: 14, user: 2 },
            Instr::Cdma { rd: 15, tag: 16 },
            Instr::Wdma { tag: 17 },
            Instr::RunDp { call: 3 },
            Instr::Wdp,
            Instr::Blt { ra: 1, rb: 2, off: -5 },
            Instr::Bge { ra: 3, rb: 4, off: 100 },
            Instr::Beq { ra: 5, rb: 6, off: -32768 },
            Instr::Jmp { off: 32767 },
            Instr::Done,
        ]
    }

    #[test]
    fn encode_decode_roundtrip() {
        for i in all_samples() {
            assert_eq!(decode(encode(i)), Some(i), "{i:?}");
        }
    }

    #[test]
    fn unknown_opcode_decodes_none() {
        assert_eq!(decode(0xFF00_0000_0000_0000), None);
        assert_eq!(decode(0), None);
    }

    #[test]
    fn opcodes_distinct() {
        let mut seen = std::collections::HashSet::new();
        for i in all_samples() {
            seen.insert((encode(i) >> 56) as u8);
        }
        assert!(seen.len() >= 14);
    }
}
