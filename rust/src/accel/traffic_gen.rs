//! The paper's traffic-generator accelerator.
//!
//! "The traffic generator is used to mimic the communication patterns of an
//! accelerator in the SoC, but does not perform any computation.  In
//! particular, our traffic generator accelerator performs the identity
//! function [...] The traffic generator accelerator is capable of loading
//! 4KB of data at a time; hence, larger data set sizes require multiple
//! read and write bursts."
//!
//! [`program`] emits a double-buffered (ping-pong PLM banks, two
//! outstanding transfers) stream loop in the accelerator ISA, which gives
//! the burst-granularity pipelining the paper credits for the speedup
//! growth with data size.  [`program_single_buffered`] is the ablation
//! variant without overlap.
//!
//! Invocation arguments (socket ARG registers -> core r1..r6):
//! `r1 = n_bursts, r2 = burst_bytes, r3 = read user, r4 = write user,
//!  r5 = input vaddr, r6 = output vaddr`.

use crate::accel::isa::Instr;
use crate::socket::DmaDir;

/// Argument-register meanings for the traffic-generator program.
pub mod args {
    /// ARG0: number of bursts.
    pub const N_BURSTS: usize = 0;
    /// ARG1: bytes per burst (<= socket max, 4 KB in the paper).
    pub const BURST_BYTES: usize = 1;
    /// ARG2: read `user` (0 = memory, k = P2P source index).
    pub const RD_USER: usize = 2;
    /// ARG3: write `user` (0 = memory, 1 = unicast, n>=2 = multicast).
    pub const WR_USER: usize = 3;
    /// ARG4: input virtual address.
    pub const VADDR_IN: usize = 4;
    /// ARG5: output virtual address.
    pub const VADDR_OUT: usize = 5;
}

/// Double-buffered stream program (two PLM banks at offsets 0 and
/// `burst_bytes`; up to two reads and two writes outstanding).
pub fn program() -> Vec<Instr> {
    use Instr::*;
    let r = DmaDir::Read;
    let w = DmaDir::Write;
    vec![
        /*  0 */ Seti { rd: 14, imm: -1 },            // wr tag A = NONE
        /*  1 */ Seti { rd: 15, imm: -1 },            // wr tag B = NONE
        /*  2 */ Seti { rd: 11, imm: 0 },             // i = 0
        /*  3 */ Seti { rd: 9, imm: 0 },              // bank A plm offset
        /*  4 */ Add { rd: 10, ra: 0, rb: 2 },        // bank B plm offset
        /*  5 */ Bge { ra: 11, rb: 1, off: 18 },      // -> drain (23)
        // body: burst i via bank A
        /*  6 */ Wdma { tag: 14 },                    // bank A free?
        /*  7 */ Idma { rd: 12, dir: r, vaddr: 5, plm: 9, len: 2, user: 3 },
        /*  8 */ Add { rd: 5, ra: 5, rb: 2 },
        /*  9 */ Addi { rd: 16, ra: 11, imm: 1 },     // i+1
        /* 10 */ Bge { ra: 16, rb: 1, off: 4 },       // no burst i+1 -> 14
        // burst i+1 via bank B (issued while burst i is in flight)
        /* 11 */ Wdma { tag: 15 },
        /* 12 */ Idma { rd: 13, dir: r, vaddr: 5, plm: 10, len: 2, user: 3 },
        /* 13 */ Add { rd: 5, ra: 5, rb: 2 },
        // write-back burst i
        /* 14 */ Wdma { tag: 12 },
        /* 15 */ Idma { rd: 14, dir: w, vaddr: 6, plm: 9, len: 2, user: 4 },
        /* 16 */ Add { rd: 6, ra: 6, rb: 2 },
        /* 17 */ Bge { ra: 16, rb: 1, off: 4 },       // -> 21
        // write-back burst i+1
        /* 18 */ Wdma { tag: 13 },
        /* 19 */ Idma { rd: 15, dir: w, vaddr: 6, plm: 10, len: 2, user: 4 },
        /* 20 */ Add { rd: 6, ra: 6, rb: 2 },
        /* 21 */ Addi { rd: 11, ra: 11, imm: 2 },
        /* 22 */ Blt { ra: 11, rb: 1, off: -16 },     // -> 6
        // drain
        /* 23 */ Wdma { tag: 14 },
        /* 24 */ Wdma { tag: 15 },
        /* 25 */ Done,
    ]
}

/// Single-buffered ablation: strictly read, wait, write, wait per burst.
pub fn program_single_buffered() -> Vec<Instr> {
    use Instr::*;
    let r = DmaDir::Read;
    let w = DmaDir::Write;
    vec![
        /* 0 */ Seti { rd: 11, imm: 0 },
        /* 1 */ Seti { rd: 9, imm: 0 },
        /* 2 */ Bge { ra: 11, rb: 1, off: 9 }, // -> 11
        /* 3 */ Idma { rd: 12, dir: r, vaddr: 5, plm: 9, len: 2, user: 3 },
        /* 4 */ Wdma { tag: 12 },
        /* 5 */ Idma { rd: 14, dir: w, vaddr: 6, plm: 9, len: 2, user: 4 },
        /* 6 */ Wdma { tag: 14 },
        /* 7 */ Add { rd: 5, ra: 5, rb: 2 },
        /* 8 */ Add { rd: 6, ra: 6, rb: 2 },
        /* 9 */ Addi { rd: 11, ra: 11, imm: 1 },
        /* 10 */ Blt { ra: 11, rb: 1, off: -8 }, // -> 2
        /* 11 */ Done,
    ]
}

/// ARG values for a traffic-generator invocation.
#[derive(Debug, Clone, Copy)]
pub struct TgenArgs {
    /// Total bytes to stream through.
    pub total_bytes: u32,
    /// Bytes per burst.
    pub burst_bytes: u32,
    /// Read `user` field.
    pub rd_user: u16,
    /// Write `user` field.
    pub wr_user: u16,
    /// Input virtual address.
    pub vaddr_in: u64,
    /// Output virtual address.
    pub vaddr_out: u64,
}

impl TgenArgs {
    /// Pack into the socket ARG registers.
    pub fn pack(&self) -> [u64; 8] {
        assert_eq!(self.total_bytes % self.burst_bytes, 0, "partial bursts unsupported");
        let mut a = [0u64; 8];
        a[args::N_BURSTS] = (self.total_bytes / self.burst_bytes) as u64;
        a[args::BURST_BYTES] = self.burst_bytes as u64;
        a[args::RD_USER] = self.rd_user as u64;
        a[args::WR_USER] = self.wr_user as u64;
        a[args::VADDR_IN] = self.vaddr_in;
        a[args::VADDR_OUT] = self.vaddr_out;
        a
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn branch_targets_in_range() {
        for prog in [program(), program_single_buffered()] {
            for (pc, i) in prog.iter().enumerate() {
                let off = match i {
                    Instr::Blt { off, .. }
                    | Instr::Bge { off, .. }
                    | Instr::Beq { off, .. }
                    | Instr::Jmp { off } => *off as i64,
                    _ => continue,
                };
                let tgt = pc as i64 + off;
                assert!(
                    tgt >= 0 && (tgt as usize) < prog.len(),
                    "branch at {pc} targets {tgt} (len {})",
                    prog.len()
                );
            }
        }
    }

    #[test]
    fn args_pack() {
        let a = TgenArgs {
            total_bytes: 16384,
            burst_bytes: 4096,
            rd_user: 0,
            wr_user: 2,
            vaddr_in: 0,
            vaddr_out: 16384,
        }
        .pack();
        assert_eq!(a[args::N_BURSTS], 4);
        assert_eq!(a[args::WR_USER], 2);
    }

    #[test]
    #[should_panic(expected = "partial bursts")]
    fn partial_bursts_rejected() {
        TgenArgs {
            total_bytes: 5000,
            burst_bytes: 4096,
            rd_user: 0,
            wr_user: 0,
            vaddr_in: 0,
            vaddr_out: 0,
        }
        .pack();
    }
}
