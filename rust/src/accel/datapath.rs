//! Accelerator datapath backends.
//!
//! A programmable accelerator's datapath is launched by the `RunDp`
//! instruction through a *descriptor table* (`DpCall`): each descriptor
//! names the PLM regions the datapath reads/writes and how many cycles the
//! operation occupies.  Two backends:
//!
//! - [`DpKind::Identity`] — the paper's traffic generator ("writes the same
//!   data as output that it receives as input");
//! - [`DpKind::Xla`] — real compute: an AOT-compiled JAX/Pallas stage
//!   executed via PJRT ([`crate::runtime::Executable`]).  The *numerics*
//!   run for real; the *timing* charged to the simulation is an analytic
//!   cycle count supplied by the descriptor (MXU-style roofline estimate),
//!   since host wall-clock is meaningless to the simulated SoC.

use std::sync::Arc;

use crate::runtime::Executable;

/// What the datapath does for one descriptor.
#[derive(Clone)]
pub enum DpKind {
    /// Copy `len` bytes from the input region to the output region.
    Identity,
    /// Execute a compiled HLO stage; inputs are f32 PLM regions.
    Xla(Arc<Executable>),
}

impl std::fmt::Debug for DpKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DpKind::Identity => write!(f, "Identity"),
            DpKind::Xla(e) => write!(f, "Xla({})", e.name()),
        }
    }
}

/// One datapath descriptor (indexed by `RunDp { call }`).
#[derive(Debug, Clone)]
pub struct DpCall {
    /// Backend.
    pub kind: DpKind,
    /// Input PLM regions: `(offset_bytes, len_bytes)` per artifact input.
    pub inputs: Vec<(u32, u32)>,
    /// Output PLM offset (outputs are written back-to-back from here).
    pub out_offset: u32,
    /// Cycles the datapath is busy (analytic estimate; see DESIGN.md §Perf).
    pub cycles: u64,
}

/// Estimate datapath cycles for a dense `M x K x N` matmul stage on an
/// MXU-like array sustaining `flops_per_cycle` (2 ops per MAC).
pub fn matmul_cycles(m: u64, k: u64, n: u64, flops_per_cycle: u64) -> u64 {
    (2 * m * k * n).div_ceil(flops_per_cycle.max(1))
}

/// Estimate datapath cycles for a streaming op over `bytes` at
/// `words_per_cycle` 4-byte words.
pub fn stream_cycles(bytes: u64, words_per_cycle: u64) -> u64 {
    (bytes / 4).div_ceil(words_per_cycle.max(1))
}

/// Execute a descriptor against the PLM.  Returns the busy time in cycles.
/// Panics on malformed descriptors (launcher bugs, not runtime conditions).
pub fn execute(call: &DpCall, plm: &mut [u8]) -> u64 {
    match &call.kind {
        DpKind::Identity => {
            let (in_off, len) = call.inputs[0];
            let (in_off, len, out) = (in_off as usize, len as usize, call.out_offset as usize);
            plm.copy_within(in_off..in_off + len, out);
        }
        DpKind::Xla(exe) => {
            // Gather f32 inputs from the PLM regions.
            let mut inputs: Vec<Vec<f32>> = Vec::with_capacity(call.inputs.len());
            for &(off, len) in &call.inputs {
                let bytes = &plm[off as usize..(off + len) as usize];
                inputs.push(
                    bytes
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect(),
                );
            }
            let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
            let outs = exe
                .execute_f32(&refs)
                .unwrap_or_else(|e| panic!("datapath {}: {e}", exe.name()));
            let mut off = call.out_offset as usize;
            for out in outs {
                for v in out {
                    plm[off..off + 4].copy_from_slice(&v.to_le_bytes());
                    off += 4;
                }
            }
        }
    }
    call.cycles
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_copies_within_plm() {
        let call = DpCall {
            kind: DpKind::Identity,
            inputs: vec![(0, 16)],
            out_offset: 32,
            cycles: 4,
        };
        let mut plm = (0..64u8).collect::<Vec<_>>();
        let c = execute(&call, &mut plm);
        assert_eq!(c, 4);
        assert_eq!(&plm[32..48], &(0..16u8).collect::<Vec<_>>()[..]);
    }

    #[test]
    fn cycle_estimates() {
        // 32x256x256 matmul on a 256-flop/cycle MXU.
        assert_eq!(matmul_cycles(32, 256, 256, 256), 16384);
        assert_eq!(stream_cycles(4096, 8), 128);
        assert_eq!(matmul_cycles(1, 1, 1, 0), 2, "zero rate clamps to 1");
    }
}
