//! Straight-line stage-program builder for programmable accelerators.
//!
//! NN pipeline stages (and other descriptor-driven invocations) are
//! generated as flat ISA sequences from transfer descriptors: load bursts
//! (with a rolling window of outstanding tags), run the datapath, store
//! bursts.  Burst-level `user` control means a single program can mix
//! memory DMA, P2P pulls, and multicast pushes — the paper's motivating
//! NN example ("fetch model parameters from memory and a previous layer's
//! outputs from another accelerator").

use crate::accel::isa::Instr;
use crate::socket::DmaDir;

/// One transfer descriptor (split into bursts by the builder).
#[derive(Debug, Clone, Copy)]
pub struct Xfer {
    /// Virtual address in the accelerator buffer.
    pub vaddr: u64,
    /// PLM offset.
    pub plm: u32,
    /// Total bytes.
    pub len: u32,
    /// Interface `user` field (read: source; write: destination count).
    pub user: u16,
}

// Scratch registers used by the generated code.
const R_VADDR: u8 = 20;
const R_PLM: u8 = 21;
const R_LEN: u8 = 22;
const R_USER: u8 = 23;
const R_TAGS_RD: [u8; 4] = [24, 25, 26, 27];
const R_TAGS_WR: [u8; 4] = [28, 29, 30, 31];

fn emit_xfers(
    prog: &mut Vec<Instr>,
    xfers: &[Xfer],
    dir: DmaDir,
    max_burst: u32,
    tag_regs: &[u8; 4],
) {
    // Invalidate the tag window.
    for &t in tag_regs {
        prog.push(Instr::Seti { rd: t, imm: -1 });
    }
    let mut slot = 0usize;
    for x in xfers {
        let mut off = 0u32;
        while off < x.len {
            let chunk = (x.len - off).min(max_burst);
            let tag = tag_regs[slot % 4];
            // Wait for the window slot's previous transfer.
            prog.push(Instr::Wdma { tag });
            prog.push(Instr::Seti { rd: R_VADDR, imm: (x.vaddr + off as u64) as i32 });
            prog.push(Instr::Seti { rd: R_PLM, imm: (x.plm + off) as i32 });
            prog.push(Instr::Seti { rd: R_LEN, imm: chunk as i32 });
            prog.push(Instr::Seti { rd: R_USER, imm: x.user as i32 });
            prog.push(Instr::Idma {
                rd: tag,
                dir,
                vaddr: R_VADDR,
                plm: R_PLM,
                len: R_LEN,
                user: R_USER,
            });
            off += chunk;
            slot += 1;
        }
    }
    // Join the window.
    for &t in tag_regs {
        prog.push(Instr::Wdma { tag: t });
    }
}

/// Build a stage program: load `reads`, run datapath descriptors `dp_calls`
/// in order, store `writes`.  Bursts within each phase overlap (window of
/// 4 outstanding transfers).
pub fn stage_program(
    reads: &[Xfer],
    dp_calls: &[u8],
    writes: &[Xfer],
    max_burst: u32,
) -> Vec<Instr> {
    let mut prog = Vec::new();
    if !reads.is_empty() {
        emit_xfers(&mut prog, reads, DmaDir::Read, max_burst, &R_TAGS_RD);
    }
    for &c in dp_calls {
        prog.push(Instr::RunDp { call: c });
        prog.push(Instr::Wdp);
    }
    if !writes.is_empty() {
        emit_xfers(&mut prog, writes, DmaDir::Write, max_burst, &R_TAGS_WR);
    }
    prog.push(Instr::Done);
    prog
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splits_large_transfers_into_bursts() {
        let prog = stage_program(
            &[Xfer { vaddr: 0, plm: 0, len: 10240, user: 0 }],
            &[],
            &[],
            4096,
        );
        let idmas = prog.iter().filter(|i| matches!(i, Instr::Idma { .. })).count();
        assert_eq!(idmas, 3, "10 KB at 4 KB bursts = 3 bursts");
        assert!(matches!(prog.last(), Some(Instr::Done)));
    }

    #[test]
    fn full_stage_shape() {
        let prog = stage_program(
            &[
                Xfer { vaddr: 0, plm: 0, len: 4096, user: 0 },      // weights from mem
                Xfer { vaddr: 8192, plm: 4096, len: 4096, user: 1 }, // input via P2P
            ],
            &[0],
            &[Xfer { vaddr: 16384, plm: 8192, len: 4096, user: 2 }], // multicast out
            4096,
        );
        assert!(prog.iter().any(|i| matches!(i, Instr::RunDp { call: 0 })));
        assert!(prog.iter().any(|i| matches!(i, Instr::Wdp)));
        let users: Vec<u8> = prog
            .iter()
            .filter_map(|i| match i {
                Instr::Idma { user, .. } => Some(*user),
                _ => None,
            })
            .collect();
        assert_eq!(users.len(), 3);
        // Per-burst mode mixing: operand registers differ per transfer; we
        // check the Seti feeding R_USER.
        let user_setis: Vec<i32> = prog
            .iter()
            .filter_map(|i| match i {
                Instr::Seti { rd, imm } if *rd == R_USER => Some(*imm),
                _ => None,
            })
            .collect();
        assert_eq!(user_setis, vec![0, 1, 2]);
    }

    #[test]
    fn empty_stage_is_just_done() {
        let prog = stage_program(&[], &[], &[], 4096);
        assert_eq!(prog, vec![Instr::Done]);
    }
}
