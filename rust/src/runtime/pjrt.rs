//! PJRT runtime: load AOT-compiled HLO artifacts and execute them.
//!
//! This is the only bridge between the rust coordinator and the build-time
//! python world: `python/compile/aot.py` lowers each L2 stage (which embeds
//! the L1 Pallas kernels) to HLO **text** under `artifacts/`, and this module
//! loads the text, compiles it once on the PJRT CPU client, and exposes an
//! `execute` that the accelerator datapath calls when an invocation's compute
//! fires.  Python is never on the simulated request path.
//!
//! Interchange is HLO text, not a serialized `HloModuleProto`: jax >= 0.5
//! emits protos with 64-bit instruction ids which xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see DESIGN.md).
//!
//! Compiled only with the `pjrt` cargo feature (which expects a vendored
//! `xla` crate); the default build substitutes [`super::stub`].

use super::{ArtifactSpec, Manifest};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Context, Result};

/// A compiled HLO artifact plus its I/O contract from `manifest.json`.
pub struct Executable {
    name: String,
    spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Artifact name (e.g. `stage0_linear_relu`).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Input/output shape contract.
    pub fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }

    /// Run the stage on f32 inputs (shape-checked against the manifest);
    /// returns the flattened f32 outputs.
    pub fn execute_f32(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != self.spec.inputs.len() {
            return Err(anyhow!(
                "{}: got {} inputs, artifact wants {}",
                self.name,
                inputs.len(),
                self.spec.inputs.len()
            ));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, spec) in inputs.iter().zip(&self.spec.inputs) {
            let want: usize = spec.shape.iter().product::<i64>() as usize;
            if data.len() != want {
                return Err(anyhow!(
                    "{}: input length {} != manifest {:?}",
                    self.name,
                    data.len(),
                    spec.shape
                ));
            }
            let lit = xla::Literal::vec1(data)
                .reshape(&spec.shape)
                .map_err(|e| anyhow!("{}: reshape to {:?}: {e}", self.name, spec.shape))?;
            literals.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("{}: execute: {e}", self.name))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{}: to_literal: {e}", self.name))?;
        // aot.py lowers with return_tuple=True: outputs arrive as one tuple.
        let elems = result
            .to_tuple()
            .map_err(|e| anyhow!("{}: untuple: {e}", self.name))?;
        let mut outs = Vec::with_capacity(elems.len());
        for (elem, spec) in elems.into_iter().zip(&self.spec.outputs) {
            let v = elem
                .to_vec::<f32>()
                .map_err(|e| anyhow!("{}: to_vec: {e}", self.name))?;
            let want: usize = spec.shape.iter().product::<i64>() as usize;
            if v.len() != want {
                return Err(anyhow!(
                    "{}: output length {} != manifest {:?}",
                    self.name,
                    v.len(),
                    spec.shape
                ));
            }
            outs.push(v);
        }
        Ok(outs)
    }
}

/// Loads `artifacts/manifest.json`, compiles artifacts lazily, and caches the
/// compiled executables.  One registry is shared by every accelerator tile
/// whose datapath runs real compute.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
}

impl Runtime {
    /// Open an artifact directory produced by `make artifacts`.
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e}"))?;
        Ok(Self { client, dir, manifest, cache: Mutex::new(HashMap::new()) })
    }

    /// Default artifact directory relative to the workspace root.
    pub fn default_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// The parsed manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact by name (cached; compile happens once).
    pub fn load(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self
            .manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))?
            .clone();
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e}"))?;
        let exe = Arc::new(Executable { name: name.to_string(), spec, exe });
        self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Read a raw little-endian f32 tensor dumped by `aot.py`.
    pub fn load_f32_tensor(&self, name: &str) -> Result<Vec<f32>> {
        let path = self.dir.join(format!("{name}.f32"));
        let bytes =
            std::fs::read(&path).with_context(|| format!("read {}", path.display()))?;
        if bytes.len() % 4 != 0 {
            return Err(anyhow!("{}: size {} not a multiple of 4", path.display(), bytes.len()));
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_loads() {
        let rt = Runtime::open(Runtime::default_dir()).unwrap();
        assert!(rt.manifest().artifacts.contains_key("stage0_linear_relu"));
        assert!(rt.manifest().artifacts.contains_key("tgen_identity"));
    }

    #[test]
    fn identity_roundtrip() {
        let rt = Runtime::open(Runtime::default_dir()).unwrap();
        let exe = rt.load("tgen_identity").unwrap();
        let x: Vec<f32> = (0..1024).map(|i| i as f32).collect();
        let out = exe.execute_f32(&[&x]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0], x);
    }

    #[test]
    fn rejects_wrong_arity() {
        let rt = Runtime::open(Runtime::default_dir()).unwrap();
        let exe = rt.load("tgen_identity").unwrap();
        assert!(exe.execute_f32(&[]).is_err());
    }

    #[test]
    fn rejects_wrong_shape() {
        let rt = Runtime::open(Runtime::default_dir()).unwrap();
        let exe = rt.load("tgen_identity").unwrap();
        let x = vec![0f32; 7];
        assert!(exe.execute_f32(&[&x]).is_err());
    }

    #[test]
    fn unknown_artifact_errors() {
        let rt = Runtime::open(Runtime::default_dir()).unwrap();
        assert!(rt.load("nope").is_err());
    }
}
