//! `artifacts/manifest.json` schema — written by `python/compile/aot.py`,
//! parsed with the in-tree JSON parser ([`crate::util::json`]).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::Json;

/// Shape + dtype of one tensor crossing the AOT boundary.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    /// Dimensions.
    pub shape: Vec<i64>,
    /// Dtype name (e.g. "float32").
    pub dtype: String,
}

impl TensorSpec {
    fn from_json(j: &Json) -> Result<Self> {
        let shape = j
            .req("shape")?
            .as_arr()?
            .iter()
            .map(|d| Ok(d.as_u64()? as i64))
            .collect::<Result<Vec<_>>>()?;
        let dtype = j.req("dtype")?.as_str()?.to_string();
        Ok(Self { shape, dtype })
    }

    /// Element count.
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<i64>() as usize
    }
}

/// One lowered HLO artifact and its I/O contract.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// File name (relative to the artifact directory).
    pub file: String,
    /// Input tensor specs.
    pub inputs: Vec<TensorSpec>,
    /// Output tensor specs.
    pub outputs: Vec<TensorSpec>,
}

impl ArtifactSpec {
    fn from_json(j: &Json) -> Result<Self> {
        let specs = |key: &str| -> Result<Vec<TensorSpec>> {
            j.req(key)?.as_arr()?.iter().map(TensorSpec::from_json).collect()
        };
        Ok(Self {
            file: j.req("file")?.as_str()?.to_string(),
            inputs: specs("inputs")?,
            outputs: specs("outputs")?,
        })
    }
}

/// Dimensions + dumped tensors of the example NN pipeline.
#[derive(Debug, Clone, Default)]
pub struct PipelineMeta {
    /// Batch size.
    pub batch: usize,
    /// Input feature width.
    pub d_in: usize,
    /// Hidden width.
    pub d_hid: usize,
    /// Parallel heads.
    pub n_heads: usize,
    /// Per-head width.
    pub d_head: usize,
    /// Output width.
    pub d_out: usize,
    /// tensor name -> shape, for the raw `.f32` dumps.
    pub tensors: HashMap<String, Vec<usize>>,
}

impl PipelineMeta {
    fn from_json(j: &Json) -> Result<Self> {
        let dim = |k: &str| -> usize {
            j.get(k).and_then(|v| v.as_u64().ok()).unwrap_or(0) as usize
        };
        let mut tensors = HashMap::new();
        if let Some(t) = j.get("tensors") {
            for (name, shape) in t.as_obj()? {
                let dims = shape
                    .as_arr()?
                    .iter()
                    .map(|d| Ok(d.as_u64()? as usize))
                    .collect::<Result<Vec<_>>>()?;
                tensors.insert(name.clone(), dims);
            }
        }
        Ok(Self {
            batch: dim("batch"),
            d_in: dim("d_in"),
            d_hid: dim("d_hid"),
            n_heads: dim("n_heads"),
            d_head: dim("d_head"),
            d_out: dim("d_out"),
            tensors,
        })
    }
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Artifact name -> spec.
    pub artifacts: HashMap<String, ArtifactSpec>,
    /// Pipeline metadata (empty if absent).
    pub pipeline: PipelineMeta,
}

impl Manifest {
    /// Parse a manifest file.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parse {}", path.display()))
    }

    /// Parse manifest JSON text.
    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text)?;
        let mut artifacts = HashMap::new();
        for (name, spec) in j.req("artifacts")?.as_obj()? {
            artifacts.insert(name.clone(), ArtifactSpec::from_json(spec)?);
        }
        let pipeline = match j.get("pipeline") {
            Some(p) => PipelineMeta::from_json(p)?,
            None => PipelineMeta::default(),
        };
        Ok(Self { artifacts, pipeline })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal() {
        let m = Manifest::parse(
            r#"{"artifacts": {"a": {"file": "a.hlo.txt", "inputs": [{"shape": [2,2], "dtype": "float32"}], "outputs": [{"shape": [2,2], "dtype": "float32"}]}}}"#,
        )
        .unwrap();
        assert_eq!(m.artifacts["a"].inputs[0].shape, vec![2, 2]);
        assert_eq!(m.artifacts["a"].inputs[0].elements(), 4);
        assert!(m.pipeline.tensors.is_empty());
    }

    #[test]
    fn parses_pipeline_meta() {
        let m = Manifest::parse(
            r#"{"artifacts": {}, "pipeline": {"batch": 32, "d_in": 256, "tensors": {"x": [32, 256]}}}"#,
        )
        .unwrap();
        assert_eq!(m.pipeline.batch, 32);
        assert_eq!(m.pipeline.tensors["x"], vec![32, 256]);
    }

    #[test]
    fn missing_file_is_error() {
        assert!(Manifest::load("/nonexistent/manifest.json").is_err());
    }

    #[test]
    fn malformed_is_error() {
        assert!(Manifest::parse("{").is_err());
        assert!(Manifest::parse(r#"{"artifacts": {"a": {"file": 3}}}"#).is_err());
    }
}
