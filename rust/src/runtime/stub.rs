//! No-PJRT runtime backend (the default build).
//!
//! Mirrors the `pjrt` backend's API so the rest of the crate compiles
//! unchanged without the vendored `xla` crate: the manifest loads, raw
//! tensors read back, but compiling an artifact reports that the binary
//! was built without the `pjrt` feature.  Simulation paths that never
//! invoke real compute (traffic generators, the paper's Fig. 4/6
//! experiments) are unaffected; datapath tests that need numerics skip
//! when `Runtime::load` errors, exactly as they skip when `make artifacts`
//! has not run.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use super::{ArtifactSpec, Manifest};

/// An artifact's I/O contract.  Never holds a compiled executable in the
/// stub backend — [`Runtime::load`] always errors, so `execute_f32` is
/// unreachable in practice but keeps the same signature.
pub struct Executable {
    name: String,
    spec: ArtifactSpec,
}

impl Executable {
    /// Artifact name (e.g. `stage0_linear_relu`).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Input/output shape contract.
    pub fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }

    /// Real compute is unavailable without the `pjrt` feature.
    pub fn execute_f32(&self, _inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        Err(anyhow!("{}: espsim was built without the `pjrt` feature", self.name))
    }
}

/// Loads `artifacts/manifest.json` and serves tensor dumps; artifact
/// compilation is unavailable in this backend.
pub struct Runtime {
    dir: PathBuf,
    manifest: Manifest,
}

impl Runtime {
    /// Open an artifact directory produced by `make artifacts`.
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.json"))?;
        Ok(Self { dir, manifest })
    }

    /// Default artifact directory relative to the workspace root.
    pub fn default_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// The parsed manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Backend name (diagnostics).
    pub fn platform(&self) -> String {
        "stub (built without the `pjrt` feature)".to_string()
    }

    /// Compilation needs PJRT: always errors in the stub backend.
    pub fn load(&self, name: &str) -> Result<Arc<Executable>> {
        // Still validate the name so callers get the same "not in
        // manifest" error they would from the real backend.
        self.manifest
            .artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))?;
        Err(anyhow!("artifact {name:?}: espsim was built without the `pjrt` feature"))
    }

    /// Read a raw little-endian f32 tensor dumped by `aot.py`.
    pub fn load_f32_tensor(&self, name: &str) -> Result<Vec<f32>> {
        let path = self.dir.join(format!("{name}.f32"));
        let bytes =
            std::fs::read(&path).with_context(|| format!("read {}", path.display()))?;
        if bytes.len() % 4 != 0 {
            return Err(anyhow!("{}: size {} not a multiple of 4", path.display(), bytes.len()));
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_missing_artifacts_errors_cleanly() {
        assert!(Runtime::open("/definitely/not/a/dir").is_err());
    }

    #[test]
    fn stub_executable_reports_missing_feature() {
        let exe = Executable {
            name: "x".into(),
            spec: ArtifactSpec { file: "x.hlo".into(), inputs: vec![], outputs: vec![] },
        };
        let err = exe.execute_f32(&[]).unwrap_err().to_string();
        assert!(err.contains("pjrt"), "{err}");
        assert_eq!(exe.name(), "x");
        assert!(exe.spec().inputs.is_empty());
    }
}
