//! Runtime: load AOT-compiled HLO artifacts and execute them.
//!
//! Two interchangeable backends expose the same `Runtime`/`Executable`
//! API:
//!
//! - `pjrt` (cargo feature `pjrt`): the real thing — compiles the HLO
//!   text `python/compile/aot.py` dumps under `artifacts/` on the PJRT CPU
//!   client and runs it.  Requires the vendored `xla` crate, which is not
//!   on crates.io; enable the feature only in environments that provide it
//!   (e.g. via a `[patch]`/path dependency).
//! - `stub` (default): manifest parsing and tensor I/O work identically,
//!   but compiling an artifact returns an error.  Everything that does not
//!   touch real compute — the whole NoC/coherence/P2P simulator, the
//!   Fig. 4/6 experiments, the traffic generators — builds and runs with
//!   no external dependencies beyond `anyhow`.

mod manifest;

pub use manifest::{ArtifactSpec, Manifest, PipelineMeta, TensorSpec};

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{Executable, Runtime};

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::{Executable, Runtime};
