//! Application description: dataflow graphs of accelerator invocations and
//! their lowering to host scripts + accelerator programs.
//!
//! An [`App`] is a sequence of *phases*; each phase starts a set of
//! accelerator invocations (host setup is serialized on the CPU, execution
//! is concurrent) and then waits for all of their IRQs.  Data dependencies
//! *within* a phase are expressed through P2P/multicast pull edges — this
//! is exactly how the paper's multicast experiment runs its producer and
//! consumers together, synchronized by the pull protocol rather than the
//! host.

use anyhow::{ensure, Result};

use crate::accel::{traffic_gen, DpCall, Instr};
use crate::socket::{make_reg, pack_src, regs::regno};
use crate::tile::HostOp;

use super::soc::Soc;

/// The program an invocation runs.
#[derive(Debug, Clone)]
pub enum ProgramKind {
    /// Double-buffered traffic-generator stream.
    Tgen,
    /// Single-buffered traffic generator (ablation).
    TgenSingle,
    /// Explicit instruction sequence.
    Custom(Vec<Instr>),
}

/// One accelerator invocation.
#[derive(Debug, Clone)]
pub struct Invocation {
    /// Global accelerator id.
    pub acc: u16,
    /// Program to run.
    pub program: ProgramKind,
    /// ARG registers (program-specific meaning).
    pub args: [u64; 8],
    /// Source-LUT entries: `(lut index, producer accelerator id)`.
    pub srcs: Vec<(u16, u16)>,
    /// Datapath descriptors (for `Custom` programs with `RunDp`).
    pub dp_calls: Vec<DpCall>,
}

impl Invocation {
    /// A traffic-generator invocation.
    pub fn tgen(acc: u16, args: traffic_gen::TgenArgs) -> Self {
        Self {
            acc,
            program: ProgramKind::Tgen,
            args: args.pack(),
            srcs: Vec::new(),
            dp_calls: Vec::new(),
        }
    }

    /// Add a source-LUT entry (consumer side of a P2P edge).
    pub fn with_src(mut self, lut_idx: u16, producer: u16) -> Self {
        self.srcs.push((lut_idx, producer));
        self
    }
}

/// A coherent-flag barrier appended to a phase: after the phase's IRQs the
/// host *publishes* `val` at `addr` with a coherent store and spins until
/// the flag reads back — the paper's coherence-based synchronization (§3)
/// composing with P2P/multicast data movement inside the phase.  The
/// store/load pair rides the three coherence planes (GetM + GetS against
/// the directory), so downstream observers polling the flag line see the
/// epoch flip without an IRQ round-trip through the host.
#[derive(Debug, Clone, Copy)]
pub struct FlagBarrier {
    /// Physical address of the flag word (keep flags a cache line apart).
    pub addr: u64,
    /// Epoch value published at the barrier.
    pub val: u64,
}

/// A phase: invocations started together, joined on their IRQs.
#[derive(Debug, Clone, Default)]
pub struct Phase {
    /// Invocations in this phase.
    pub invocations: Vec<Invocation>,
    /// Optional coherent-flag barrier after the IRQ join.
    pub barrier: Option<FlagBarrier>,
}

/// A multi-phase application.
#[derive(Debug, Clone, Default)]
pub struct App {
    /// Phases, executed in order with an IRQ barrier between them.
    pub phases: Vec<Phase>,
}

impl App {
    /// Empty app.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append a phase.
    pub fn phase(mut self, invocations: Vec<Invocation>) -> Self {
        self.phases.push(Phase { invocations, barrier: None });
        self
    }

    /// Append a phase followed by a coherent-flag barrier: after the
    /// phase's IRQ join the host publishes `val` at `addr` through its L1
    /// and spins until the flag reads back (see [`FlagBarrier`]).
    pub fn phase_with_flag_barrier(
        mut self,
        invocations: Vec<Invocation>,
        addr: u64,
        val: u64,
    ) -> Self {
        self.phases.push(Phase { invocations, barrier: Some(FlagBarrier { addr, val }) });
        self
    }

    /// Validate against a SoC and install everything: accelerator programs
    /// and datapath tables via the setup backdoor, then the host script
    /// (register writes, starts, IRQ waits) that drives the run.
    pub fn launch(&self, soc: &mut Soc) -> Result<()> {
        let host = soc.cfg.host;
        let mcast_cap = soc.cfg.mcast_capacity();
        let mut script: Vec<HostOp> = Vec::new();
        for phase in &self.phases {
            ensure!(!phase.invocations.is_empty(), "empty phase");
            let mut irqs = Vec::new();
            for inv in &phase.invocations {
                ensure!((inv.acc as usize) < soc.acc_count(), "unknown accelerator {}", inv.acc);
                // Multicast fan-out bounded by the NoC header capacity.
                // Consumers are sockets, header destinations are tiles, and
                // slots on one tile share a single delivered copy — so on a
                // dual-socket platform up to 2x the header capacity of
                // consumers can join one transaction (a transaction that
                // still spans more tiles than one header encodes serializes
                // into per-group messages in `socket::p2p`).  On
                // single-socket platforms the sharing factor is 1 and this
                // launch check stays exact.
                let wr_user = inv.args[traffic_gen::args::WR_USER];
                let slot_share = soc.cfg.max_sockets_per_tile();
                ensure!(
                    wr_user as usize <= (slot_share * mcast_cap).max(1),
                    "write user {} exceeds multicast capacity {} (x{} socket slots per tile)",
                    wr_user,
                    mcast_cap,
                    slot_share
                );
                let program = match &inv.program {
                    ProgramKind::Tgen => traffic_gen::program(),
                    ProgramKind::TgenSingle => traffic_gen::program_single_buffered(),
                    ProgramKind::Custom(p) => p.clone(),
                };
                soc.setup_acc(inv.acc, program, inv.dp_calls.clone());
                let (tile, slot) = soc.acc_location(inv.acc);
                // Driver overhead, then the uncached register writes.
                script.push(HostOp::Delay(host.invocation_overhead as u64));
                for (i, &a) in inv.args.iter().enumerate() {
                    script.push(HostOp::WriteReg {
                        tile,
                        reg: make_reg(slot, regno::ARG0 + i as u16),
                        val: a,
                    });
                }
                for &(idx, producer) in &inv.srcs {
                    ensure!(idx >= 1 && idx <= 15, "source LUT index {idx} out of range");
                    let (pc, ps) = soc.acc_location(producer);
                    script.push(HostOp::WriteReg {
                        tile,
                        reg: make_reg(slot, regno::SRC_LUT + idx),
                        val: pack_src(pc, ps),
                    });
                }
                script.push(HostOp::WriteReg { tile, reg: make_reg(slot, regno::CMD), val: 1 });
                irqs.push(inv.acc);
            }
            script.push(HostOp::WaitIrqs(irqs));
            if let Some(b) = phase.barrier {
                script.push(HostOp::SetFlag { addr: b.addr, val: b.val });
                script.push(HostOp::WaitFlag { addr: b.addr, val: b.val });
            }
        }
        soc.push_host_script(script);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SocConfig;

    #[test]
    fn launch_builds_script_and_programs() {
        let mut soc = Soc::new(SocConfig::small_3x3()).unwrap();
        let app = App::new().phase(vec![Invocation::tgen(
            0,
            traffic_gen::TgenArgs {
                total_bytes: 4096,
                burst_bytes: 4096,
                rd_user: 0,
                wr_user: 0,
                vaddr_in: 0,
                vaddr_out: 8192,
            },
        )]);
        app.launch(&mut soc).unwrap();
        assert!(!soc.cpu_mut().done(), "script pending");
    }

    #[test]
    fn flag_barrier_emits_coherent_host_ops() {
        let mut soc = Soc::new(SocConfig::small_3x3()).unwrap();
        let inv = Invocation::tgen(
            0,
            traffic_gen::TgenArgs {
                total_bytes: 4096,
                burst_bytes: 4096,
                rd_user: 0,
                wr_user: 0,
                vaddr_in: 0,
                vaddr_out: 8192,
            },
        );
        let app = App::new().phase_with_flag_barrier(vec![inv], 0x8000, 1);
        assert!(app.phases[0].barrier.is_some());
        app.launch(&mut soc).unwrap();
        // The barrier's store+spin must resolve so the SoC still quiesces.
        soc.run(1_000_000).unwrap();
        let report = soc.report();
        use crate::noc::Plane;
        assert!(
            report.planes[Plane::CohReq.idx()].delivered > 0,
            "flag publish must ride the coherence-request plane"
        );
    }

    #[test]
    fn rejects_unknown_accelerator() {
        let mut soc = Soc::new(SocConfig::small_3x3()).unwrap();
        let app = App::new().phase(vec![Invocation::tgen(
            99,
            traffic_gen::TgenArgs {
                total_bytes: 4096,
                burst_bytes: 4096,
                rd_user: 0,
                wr_user: 0,
                vaddr_in: 0,
                vaddr_out: 0,
            },
        )]);
        assert!(app.launch(&mut soc).is_err());
    }

    #[test]
    fn rejects_oversized_multicast() {
        let mut cfg = SocConfig::paper_3x4();
        cfg.noc.bitwidth = 64; // capacity 5 tiles = at most 10 consumer slots
        let mut soc = Soc::new(cfg).unwrap();
        let mk = |wr_user: u16| {
            App::new().phase(vec![Invocation::tgen(
                0,
                traffic_gen::TgenArgs {
                    total_bytes: 4096,
                    burst_bytes: 4096,
                    rd_user: 0,
                    wr_user,
                    vaddr_in: 0,
                    vaddr_out: 0,
                },
            )])
        };
        assert!(mk(11).launch(&mut soc).is_err(), "11 > 2 x 5");
        assert!(mk(10).launch(&mut soc).is_ok(), "two slots per tile may share a copy");
        // Single-socket platform: no slot sharing, the bound stays exact —
        // an oversized fan-out must fail at launch, not panic at send time.
        let mut cfg = SocConfig::small_3x3();
        cfg.noc.bitwidth = 64; // capacity 5, one socket per tile
        let mut soc = Soc::new(cfg).unwrap();
        assert!(mk(6).launch(&mut soc).is_err(), "6 > 1 x 5 on acc1 tiles");
        assert!(mk(5).launch(&mut soc).is_ok());
    }
}
