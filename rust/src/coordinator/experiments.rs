//! Reusable experiment drivers for the paper's evaluation (shared by
//! `examples/` and `rust/benches/`).
//!
//! The Fig. 6 experiment: a producer traffic-generator creates data
//! consumed by N consumer traffic-generators, comparing **multicast P2P**
//! against the **shared-memory baseline** (producer writes to main memory,
//! then the N consumers read it back), sweeping N and the data size.  Both
//! variants verify end-to-end data integrity: every consumer's output
//! region must equal the producer's input.

use anyhow::{ensure, Result};

use crate::accel::traffic_gen::TgenArgs;
use crate::config::SocConfig;
use crate::coordinator::{App, Invocation, ProgramKind, Soc};
use crate::noc::Coord;

/// DRAM layout for the Fig. 6 workload.
pub mod layout {
    /// Producer input region.
    pub const IN: u64 = 0x0010_0000;
    /// Shared-memory staging region (baseline only).
    pub const MID: u64 = 0x0080_0000;
    /// Consumer output regions, 2 MiB apart.
    pub const OUT_BASE: u64 = 0x0100_0000;
    /// Stride between consumer outputs.
    pub const OUT_STRIDE: u64 = 0x0020_0000;

    /// Output region of consumer `i` (default stride).
    pub fn out(i: usize) -> u64 {
        out_at(i, OUT_STRIDE)
    }

    /// Output region of consumer `i` with an explicit stride.
    pub fn out_at(i: usize, stride: u64) -> u64 {
        OUT_BASE + i as u64 * stride
    }

    /// Stride between consumer outputs for a `bytes`-sized transfer: the
    /// default 2 MiB, grown to the next power of two when a transfer
    /// (e.g. the 16x16 sweep's 4 MiB points) would overrun it.  Transfers
    /// up to 2 MiB keep the historical layout bit-for-bit.
    pub fn stride_for(bytes: u32) -> u64 {
        OUT_STRIDE.max((bytes as u64).next_power_of_two())
    }
}

/// One measured point of the Fig. 6 sweep.
#[derive(Debug, Clone)]
pub struct Fig6Point {
    /// Number of consumers (1 = unicast P2P, no multicast).
    pub consumers: usize,
    /// Bytes produced/consumed.
    pub bytes: u32,
    /// Cycles for the shared-memory baseline.
    pub baseline_cycles: u64,
    /// Cycles for the multicast-P2P version.
    pub multicast_cycles: u64,
}

impl Fig6Point {
    /// Speedup of multicast over the baseline (the paper's y-axis; its
    /// "72% speedup" == 1.72x here).
    pub fn speedup(&self) -> f64 {
        self.baseline_cycles as f64 / self.multicast_cycles as f64
    }
}

/// Options for the Fig. 6 runner.
#[derive(Debug, Clone)]
pub struct Fig6Options {
    /// SoC platform (defaults to the paper's 3x4).
    pub soc: SocConfig,
    /// Burst size (the paper's traffic generator: 4 KB).
    pub burst_bytes: u32,
    /// Use the single-buffered traffic generator (ablation).
    pub single_buffered: bool,
    /// Invoke baseline consumers one at a time (start, wait IRQ, next)
    /// instead of concurrently.  This models a host whose driver
    /// serializes invocations (the paper's Linux-on-CVA6 software stack);
    /// with it the speedup *grows* with the consumer count as in Fig. 6,
    /// while a fully concurrent baseline flattens the trend — see
    /// EXPERIMENTS.md for the comparison.
    pub baseline_sequential: bool,
    /// Check data integrity after each run.
    pub verify: bool,
    /// Simulation cycle budget per run.
    pub max_cycles: u64,
    /// Pack consumers two per tile, skipping the producer's tile: because
    /// two sockets on one tile share a single delivered multicast copy,
    /// fan-outs up to **twice** the header capacity (32 consumers on a
    /// 256-bit NoC) fit one multicast transaction on dual-socket
    /// platforms.  `false` keeps the paper experiments' placement
    /// (consumer `c` is accelerator `c + 1`) bit-for-bit.
    pub pack_consumers: bool,
}

impl Default for Fig6Options {
    fn default() -> Self {
        Self {
            soc: SocConfig::paper_3x4(),
            burst_bytes: 4 << 10,
            single_buffered: false,
            baseline_sequential: true,
            verify: true,
            max_cycles: 500_000_000,
            pack_consumers: false,
        }
    }
}

impl Fig6Options {
    /// The scaled 16x16 sweep configuration: `SocConfig::scaled_16x16`
    /// (17 dual-socket tiles, scaled memory system) with consumers packed
    /// two per tile so the 32-consumer points fit one multicast.
    pub fn mesh_16x16() -> Self {
        Self { soc: SocConfig::scaled_16x16(), pack_consumers: true, ..Self::default() }
    }
}

fn tgen_program(opts: &Fig6Options) -> ProgramKind {
    if opts.single_buffered {
        ProgramKind::TgenSingle
    } else {
        ProgramKind::Tgen
    }
}

/// Write the deterministic, position-dependent input pattern (catches
/// reordering bugs) at [`layout::IN`] and return a copy for verification.
/// Shared with the scenario subsystem so every workload verifies against
/// the same stimulus.
pub fn fill_input(soc: &mut Soc, bytes: u32) -> Vec<u8> {
    let data: Vec<u8> =
        (0..bytes as u64).map(|i| (i.wrapping_mul(0x9E37_79B9) >> 16) as u8).collect();
    soc.write_mem(layout::IN, &data);
    data
}

fn verify_outputs(soc: &mut Soc, consumers: usize, stride: u64, data: &[u8]) -> Result<()> {
    for c in 0..consumers {
        let got = soc.read_mem(layout::out_at(c, stride), data.len());
        ensure!(
            got == data,
            "consumer {c}: output mismatch (first divergence at byte {:?})",
            got.iter().zip(data).position(|(a, b)| a != b)
        );
    }
    Ok(())
}

/// The accelerator ids acting as consumers 0..n.  The default keeps the
/// paper experiments' assignment (consumer `c` = accelerator `c + 1`);
/// with `pack_consumers` the consumers are taken pairwise from dual-socket
/// tiles off the producer's tile, so the destination-*tile* count is
/// `ceil(n / 2)` and fan-outs up to twice the header capacity fit one
/// multicast.
fn consumer_accs(soc: &Soc, consumers: usize, opts: &Fig6Options) -> Result<Vec<u16>> {
    ensure!(consumers + 1 <= soc.acc_count(), "not enough accelerator sockets");
    if !opts.pack_consumers {
        return Ok((1..=consumers as u16).collect());
    }
    let prod_tile = soc.acc_location(0).0;
    let accs: Vec<u16> = (1..soc.acc_count() as u16)
        .filter(|&a| soc.acc_location(a).0 != prod_tile)
        .take(consumers)
        .collect();
    ensure!(
        accs.len() == consumers,
        "only {} accelerator sockets off the producer's tile for {} consumers",
        accs.len(),
        consumers
    );
    Ok(accs)
}

/// Bound the multicast fan-out by what one header can actually encode:
/// the number of distinct destination *tiles* of the transaction.
fn check_mcast_capacity(soc: &Soc, accs: &[u16], opts: &Fig6Options) -> Result<()> {
    if !opts.pack_consumers {
        // Paper placement: one consumer per destination slot.
        ensure!(
            accs.len() <= soc.cfg.mcast_capacity(),
            "{} consumers exceed multicast capacity {}",
            accs.len(),
            soc.cfg.mcast_capacity()
        );
        return Ok(());
    }
    let mut tiles: Vec<Coord> = Vec::new();
    for &a in accs {
        let t = soc.acc_location(a).0;
        if !tiles.contains(&t) {
            tiles.push(t);
        }
    }
    ensure!(
        tiles.len() <= soc.cfg.mcast_capacity(),
        "{} destination tiles exceed multicast capacity {}",
        tiles.len(),
        soc.cfg.mcast_capacity()
    );
    Ok(())
}

/// Bound-check the DRAM layout for this run's transfer size.
fn check_layout(soc: &Soc, consumers: usize, bytes: u32, stride: u64) -> Result<()> {
    ensure!(
        bytes as u64 <= layout::MID - layout::IN,
        "{bytes}-byte transfer overruns the input/staging layout"
    );
    let end = layout::out_at(consumers.saturating_sub(1), stride) + bytes as u64;
    ensure!(
        end <= soc.cfg.mem.dram_bytes,
        "consumer outputs end at {end:#x} beyond DRAM ({:#x}); raise mem.dram_bytes",
        soc.cfg.mem.dram_bytes
    );
    Ok(())
}

/// Run the shared-memory baseline: producer streams IN -> MID through
/// memory; after its IRQ the consumers stream MID -> OUT_i.
pub fn run_baseline(consumers: usize, bytes: u32, opts: &Fig6Options) -> Result<u64> {
    let mut soc = Soc::new(opts.soc.clone())?;
    let accs = consumer_accs(&soc, consumers, opts)?;
    let stride = layout::stride_for(bytes);
    check_layout(&soc, consumers, bytes, stride)?;
    let data = fill_input(&mut soc, bytes);
    let mut producer = Invocation::tgen(
        0,
        TgenArgs {
            total_bytes: bytes,
            burst_bytes: opts.burst_bytes,
            rd_user: 0,
            wr_user: 0,
            vaddr_in: layout::IN,
            vaddr_out: layout::MID,
        },
    );
    producer.program = tgen_program(opts);
    let mut consumer_invs = Vec::new();
    for (c, &acc) in accs.iter().enumerate() {
        let mut inv = Invocation::tgen(
            acc,
            TgenArgs {
                total_bytes: bytes,
                burst_bytes: opts.burst_bytes,
                rd_user: 0,
                wr_user: 0,
                vaddr_in: layout::MID,
                vaddr_out: layout::out_at(c, stride),
            },
        );
        inv.program = tgen_program(opts);
        consumer_invs.push(inv);
    }
    let mut app = App::new().phase(vec![producer]);
    if opts.baseline_sequential {
        for inv in consumer_invs {
            app = app.phase(vec![inv]);
        }
    } else {
        app = app.phase(consumer_invs);
    }
    app.launch(&mut soc)?;
    let cycles = soc.run(opts.max_cycles)?;
    if opts.verify {
        verify_outputs(&mut soc, consumers, stride, &data)?;
    }
    Ok(cycles)
}

/// Run the multicast-P2P version: producer reads IN from memory and
/// multicasts to the N consumers (pull-based), which write OUT_i; all in
/// one phase, synchronized by the P2P protocol.
pub fn run_multicast(consumers: usize, bytes: u32, opts: &Fig6Options) -> Result<u64> {
    let mut soc = Soc::new(opts.soc.clone())?;
    let accs = consumer_accs(&soc, consumers, opts)?;
    check_mcast_capacity(&soc, &accs, opts)?;
    let stride = layout::stride_for(bytes);
    check_layout(&soc, consumers, bytes, stride)?;
    let data = fill_input(&mut soc, bytes);
    let mut invocations = Vec::new();
    let mut producer = Invocation::tgen(
        0,
        TgenArgs {
            total_bytes: bytes,
            burst_bytes: opts.burst_bytes,
            rd_user: 0,
            wr_user: consumers as u16, // 1 = unicast P2P, n >= 2 = multicast
            vaddr_in: layout::IN,
            vaddr_out: 0, // P2P writes don't touch memory
        },
    );
    producer.program = tgen_program(opts);
    invocations.push(producer);
    for (c, &acc) in accs.iter().enumerate() {
        let mut inv = Invocation::tgen(
            acc,
            TgenArgs {
                total_bytes: bytes,
                burst_bytes: opts.burst_bytes,
                rd_user: 1, // LUT entry 1 -> producer
                wr_user: 0,
                vaddr_in: 0,
                vaddr_out: layout::out_at(c, stride),
            },
        )
        .with_src(1, 0);
        inv.program = tgen_program(opts);
        invocations.push(inv);
    }
    App::new().phase(invocations).launch(&mut soc)?;
    let cycles = soc.run(opts.max_cycles)?;
    if opts.verify {
        verify_outputs(&mut soc, consumers, stride, &data)?;
    }
    Ok(cycles)
}

/// Measure one Fig. 6 point (baseline + multicast).
pub fn run_fig6_point(consumers: usize, bytes: u32, opts: &Fig6Options) -> Result<Fig6Point> {
    Ok(Fig6Point {
        consumers,
        bytes,
        baseline_cycles: run_baseline(consumers, bytes, opts)?,
        multicast_cycles: run_multicast(consumers, bytes, opts)?,
    })
}

/// The paper's sweep axes.
pub fn paper_consumer_counts() -> Vec<usize> {
    vec![1, 2, 4, 8, 16]
}

/// Data sizes from one burst (4 KB) to the 1 MB plateau.
pub fn paper_data_sizes() -> Vec<u32> {
    vec![4 << 10, 16 << 10, 64 << 10, 256 << 10, 1 << 20]
}

/// Consumer counts of the scaled 16x16 sweep — past the paper's 16, up to
/// 32 packed consumers (two per destination tile).
pub fn extended_consumer_counts() -> Vec<usize> {
    vec![1, 2, 4, 8, 16, 32]
}

/// Data sizes of the scaled sweep, out to 4 MB past the paper's plateau.
pub fn extended_data_sizes() -> Vec<u32> {
    vec![4 << 10, 64 << 10, 1 << 20, 4 << 20]
}

/// The `--quick` subset of [`paper_data_sizes`] (benches, examples, CI
/// smoke) — kept here so every driver runs the same grid.
pub fn quick_data_sizes() -> Vec<u32> {
    vec![4 << 10, 64 << 10]
}

/// The `--quick --mesh16` subset of [`extended_data_sizes`].
pub fn quick_extended_data_sizes() -> Vec<u32> {
    vec![64 << 10, 1 << 20]
}
