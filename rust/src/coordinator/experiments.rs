//! Reusable experiment drivers for the paper's evaluation (shared by
//! `examples/` and `rust/benches/`).
//!
//! The Fig. 6 experiment: a producer traffic-generator creates data
//! consumed by N consumer traffic-generators, comparing **multicast P2P**
//! against the **shared-memory baseline** (producer writes to main memory,
//! then the N consumers read it back), sweeping N and the data size.  Both
//! variants verify end-to-end data integrity: every consumer's output
//! region must equal the producer's input.

use anyhow::{ensure, Result};

use crate::accel::traffic_gen::TgenArgs;
use crate::config::SocConfig;
use crate::coordinator::{App, Invocation, ProgramKind, Soc};

/// DRAM layout for the Fig. 6 workload.
pub mod layout {
    /// Producer input region.
    pub const IN: u64 = 0x0010_0000;
    /// Shared-memory staging region (baseline only).
    pub const MID: u64 = 0x0080_0000;
    /// Consumer output regions, 2 MiB apart.
    pub const OUT_BASE: u64 = 0x0100_0000;
    /// Stride between consumer outputs.
    pub const OUT_STRIDE: u64 = 0x0020_0000;

    /// Output region of consumer `i`.
    pub fn out(i: usize) -> u64 {
        OUT_BASE + i as u64 * OUT_STRIDE
    }
}

/// One measured point of the Fig. 6 sweep.
#[derive(Debug, Clone)]
pub struct Fig6Point {
    /// Number of consumers (1 = unicast P2P, no multicast).
    pub consumers: usize,
    /// Bytes produced/consumed.
    pub bytes: u32,
    /// Cycles for the shared-memory baseline.
    pub baseline_cycles: u64,
    /// Cycles for the multicast-P2P version.
    pub multicast_cycles: u64,
}

impl Fig6Point {
    /// Speedup of multicast over the baseline (the paper's y-axis; its
    /// "72% speedup" == 1.72x here).
    pub fn speedup(&self) -> f64 {
        self.baseline_cycles as f64 / self.multicast_cycles as f64
    }
}

/// Options for the Fig. 6 runner.
#[derive(Debug, Clone)]
pub struct Fig6Options {
    /// SoC platform (defaults to the paper's 3x4).
    pub soc: SocConfig,
    /// Burst size (the paper's traffic generator: 4 KB).
    pub burst_bytes: u32,
    /// Use the single-buffered traffic generator (ablation).
    pub single_buffered: bool,
    /// Invoke baseline consumers one at a time (start, wait IRQ, next)
    /// instead of concurrently.  This models a host whose driver
    /// serializes invocations (the paper's Linux-on-CVA6 software stack);
    /// with it the speedup *grows* with the consumer count as in Fig. 6,
    /// while a fully concurrent baseline flattens the trend — see
    /// EXPERIMENTS.md for the comparison.
    pub baseline_sequential: bool,
    /// Check data integrity after each run.
    pub verify: bool,
    /// Simulation cycle budget per run.
    pub max_cycles: u64,
}

impl Default for Fig6Options {
    fn default() -> Self {
        Self {
            soc: SocConfig::paper_3x4(),
            burst_bytes: 4 << 10,
            single_buffered: false,
            baseline_sequential: true,
            verify: true,
            max_cycles: 500_000_000,
        }
    }
}

fn tgen_program(opts: &Fig6Options) -> ProgramKind {
    if opts.single_buffered {
        ProgramKind::TgenSingle
    } else {
        ProgramKind::Tgen
    }
}

fn fill_input(soc: &mut Soc, bytes: u32) -> Vec<u8> {
    // Deterministic, position-dependent pattern (catches reordering bugs).
    let data: Vec<u8> =
        (0..bytes as u64).map(|i| (i.wrapping_mul(0x9E37_79B9) >> 16) as u8).collect();
    soc.write_mem(layout::IN, &data);
    data
}

fn verify_outputs(soc: &mut Soc, consumers: usize, data: &[u8]) -> Result<()> {
    for c in 0..consumers {
        let got = soc.read_mem(layout::out(c), data.len());
        ensure!(
            got == data,
            "consumer {c}: output mismatch (first divergence at byte {:?})",
            got.iter().zip(data).position(|(a, b)| a != b)
        );
    }
    Ok(())
}

/// Run the shared-memory baseline: producer streams IN -> MID through
/// memory; after its IRQ the consumers stream MID -> OUT_i.
pub fn run_baseline(consumers: usize, bytes: u32, opts: &Fig6Options) -> Result<u64> {
    let mut soc = Soc::new(opts.soc.clone())?;
    ensure!(consumers + 1 <= soc.acc_count(), "not enough accelerator sockets");
    let data = fill_input(&mut soc, bytes);
    let mut producer = Invocation::tgen(
        0,
        TgenArgs {
            total_bytes: bytes,
            burst_bytes: opts.burst_bytes,
            rd_user: 0,
            wr_user: 0,
            vaddr_in: layout::IN,
            vaddr_out: layout::MID,
        },
    );
    producer.program = tgen_program(opts);
    let mut consumer_invs = Vec::new();
    for c in 0..consumers {
        let mut inv = Invocation::tgen(
            (c + 1) as u16,
            TgenArgs {
                total_bytes: bytes,
                burst_bytes: opts.burst_bytes,
                rd_user: 0,
                wr_user: 0,
                vaddr_in: layout::MID,
                vaddr_out: layout::out(c),
            },
        );
        inv.program = tgen_program(opts);
        consumer_invs.push(inv);
    }
    let mut app = App::new().phase(vec![producer]);
    if opts.baseline_sequential {
        for inv in consumer_invs {
            app = app.phase(vec![inv]);
        }
    } else {
        app = app.phase(consumer_invs);
    }
    app.launch(&mut soc)?;
    let cycles = soc.run(opts.max_cycles)?;
    if opts.verify {
        verify_outputs(&mut soc, consumers, &data)?;
    }
    Ok(cycles)
}

/// Run the multicast-P2P version: producer reads IN from memory and
/// multicasts to the N consumers (pull-based), which write OUT_i; all in
/// one phase, synchronized by the P2P protocol.
pub fn run_multicast(consumers: usize, bytes: u32, opts: &Fig6Options) -> Result<u64> {
    let mut soc = Soc::new(opts.soc.clone())?;
    ensure!(consumers + 1 <= soc.acc_count(), "not enough accelerator sockets");
    ensure!(
        consumers <= soc.cfg.mcast_capacity(),
        "{} consumers exceed multicast capacity {}",
        consumers,
        soc.cfg.mcast_capacity()
    );
    let data = fill_input(&mut soc, bytes);
    let mut invocations = Vec::new();
    let mut producer = Invocation::tgen(
        0,
        TgenArgs {
            total_bytes: bytes,
            burst_bytes: opts.burst_bytes,
            rd_user: 0,
            wr_user: consumers as u16, // 1 = unicast P2P, n >= 2 = multicast
            vaddr_in: layout::IN,
            vaddr_out: 0, // P2P writes don't touch memory
        },
    );
    producer.program = tgen_program(opts);
    invocations.push(producer);
    for c in 0..consumers {
        let mut inv = Invocation::tgen(
            (c + 1) as u16,
            TgenArgs {
                total_bytes: bytes,
                burst_bytes: opts.burst_bytes,
                rd_user: 1, // LUT entry 1 -> producer
                wr_user: 0,
                vaddr_in: 0,
                vaddr_out: layout::out(c),
            },
        )
        .with_src(1, 0);
        inv.program = tgen_program(opts);
        invocations.push(inv);
    }
    App::new().phase(invocations).launch(&mut soc)?;
    let cycles = soc.run(opts.max_cycles)?;
    if opts.verify {
        verify_outputs(&mut soc, consumers, &data)?;
    }
    Ok(cycles)
}

/// Measure one Fig. 6 point (baseline + multicast).
pub fn run_fig6_point(consumers: usize, bytes: u32, opts: &Fig6Options) -> Result<Fig6Point> {
    Ok(Fig6Point {
        consumers,
        bytes,
        baseline_cycles: run_baseline(consumers, bytes, opts)?,
        multicast_cycles: run_multicast(consumers, bytes, opts)?,
    })
}

/// The paper's sweep axes.
pub fn paper_consumer_counts() -> Vec<usize> {
    vec![1, 2, 4, 8, 16]
}

/// Data sizes from one burst (4 KB) to the 1 MB plateau.
pub fn paper_data_sizes() -> Vec<u32> {
    vec![4 << 10, 16 << 10, 64 << 10, 256 << 10, 1 << 20]
}
