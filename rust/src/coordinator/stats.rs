//! Run statistics: one [`Report`] per simulation, printable as a table or
//! serializable for the benchmark harnesses.

use crate::noc::{MeshStats, NUM_PLANES};
use crate::socket::SocketStats;
use crate::tile::cpu::CpuStats;
use crate::tile::MemStats;

/// Aggregated statistics of one run.
#[derive(Debug, Default, Clone)]
pub struct Report {
    /// Final cycle count.
    pub cycles: u64,
    /// Per-plane NoC statistics.
    pub planes: [MeshStats; NUM_PLANES],
    /// Memory-tile statistics.
    pub mem: MemStats,
    /// Host statistics.
    pub cpu: CpuStats,
    /// Per-accelerator socket statistics.
    pub sockets: Vec<(u16, SocketStats)>,
    /// Invocation spans `(acc, start, end)`.
    pub invocations: Vec<(u16, u64, u64)>,
}

impl Report {
    /// Total flit-hops across planes.
    pub fn total_flit_hops(&self) -> u64 {
        self.planes.iter().map(|p| p.flit_hops).sum()
    }

    /// Sum of DMA bytes moved (read + write).
    pub fn dma_bytes(&self) -> u64 {
        self.mem.read_bytes + self.mem.write_bytes
    }

    /// Sum of P2P bytes delivered.
    pub fn p2p_bytes(&self) -> u64 {
        self.sockets.iter().map(|(_, s)| s.p2p_write_bytes).sum()
    }

    /// Flits dropped by fault injection across planes (0 on healthy runs).
    pub fn dropped_flits(&self) -> u64 {
        self.planes.iter().map(|p| p.dropped_flits).sum()
    }

    /// Whole messages refused at injection (unreachable/dead destination).
    pub fn dropped_msgs(&self) -> u64 {
        self.planes.iter().map(|p| p.dropped_msgs).sum()
    }

    /// Socket sub-request retries across accelerators (degraded runs).
    pub fn socket_retries(&self) -> u64 {
        self.sockets.iter().map(|(_, s)| s.retries).sum()
    }

    /// Bytes retransmitted from producer-side replay rings (0 unless
    /// `replay_window` is armed and a re-request actually resumed).
    pub fn replayed_bytes(&self) -> u64 {
        self.sockets.iter().map(|(_, s)| s.replayed_bytes).sum()
    }

    /// Truncated wormhole allocations retired by the fault drain's
    /// downstream walk, across planes (0 on healthy runs).
    pub fn drained_worms(&self) -> u64 {
        self.planes.iter().map(|p| p.drained_worms).sum()
    }

    /// Latency of accelerator `acc`'s first invocation, if logged.
    pub fn invocation_latency(&self, acc: u16) -> Option<u64> {
        self.invocations.iter().find(|(a, _, _)| *a == acc).map(|(_, s, e)| e - s)
    }

    /// Render a human-readable summary.
    pub fn table(&self) -> String {
        use std::fmt::Write;
        let mut s = String::new();
        let _ = writeln!(s, "cycles: {}", self.cycles);
        let names = ["coh-req", "coh-fwd", "coh-rsp", "dma-req", "dma-rsp", "misc"];
        let _ = writeln!(s, "{:8} {:>12} {:>10} {:>10}", "plane", "flit-hops", "delivered", "busy");
        for (n, p) in names.iter().zip(&self.planes) {
            let _ = writeln!(
                s,
                "{:8} {:>12} {:>10} {:>10}",
                n, p.flit_hops, p.delivered, p.busy_cycles
            );
        }
        let _ = writeln!(
            s,
            "mem: {} reads / {} writes, {} B read, {} B written, llc {}h/{}m, dram busy {}",
            self.mem.reads,
            self.mem.writes,
            self.mem.read_bytes,
            self.mem.write_bytes,
            self.mem.llc_hits,
            self.mem.llc_misses,
            self.mem.dram_busy_cycles
        );
        let _ = writeln!(
            s,
            "host: {} reg writes, {} irqs, done at {:?}",
            self.cpu.reg_writes, self.cpu.irqs, self.cpu.done_at
        );
        // Fault-injection counters only appear on degraded runs.
        if self.dropped_flits() + self.dropped_msgs() + self.socket_retries() > 0 {
            let _ = writeln!(
                s,
                "faults: {} flits dropped, {} msgs refused, {} socket retries, \
                 {} worms drained, {} B replayed",
                self.dropped_flits(),
                self.dropped_msgs(),
                self.socket_retries(),
                self.drained_worms(),
                self.replayed_bytes()
            );
        }
        for (acc, st) in &self.sockets {
            if st.bursts == 0 {
                continue;
            }
            let _ = writeln!(
                s,
                "acc{:<3} bursts {:>5}  dma rd/wr {:>9}/{:>9} B  p2p rd/wr {:>9}/{:>9} B",
                acc, st.bursts, st.dma_read_bytes, st.dma_write_bytes, st.p2p_read_bytes,
                st.p2p_write_bytes
            );
        }
        for (acc, start, end) in &self.invocations {
            let _ =
                writeln!(s, "inv acc{:<3} [{start:>8} .. {end:>8}]  {:>8} cy", acc, end - start);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates_and_renders() {
        let mut r = Report { cycles: 100, ..Report::default() };
        r.planes[3].flit_hops = 40;
        r.planes[4].flit_hops = 2;
        r.invocations.push((0, 10, 60));
        assert_eq!(r.total_flit_hops(), 42);
        assert_eq!(r.invocation_latency(0), Some(50));
        assert_eq!(r.invocation_latency(9), None);
        let t = r.table();
        assert!(t.contains("cycles: 100"));
        assert!(t.contains("dma-req"));
    }
}
