//! The batch simulation farm: thread-pooled scenario/seed sweeps.
//!
//! One [`Scenario::run`] is a single-threaded, self-contained simulation:
//! it builds its own [`Soc`]s (NoC, tiles, sockets, route tables, fault
//! plans — all owned data, no shared mutable state), runs both lowerings,
//! and returns an [`Outcome`].  That self-containment is what makes the
//! farm trivial to make correct: independent (scenario, seed, sched-mode,
//! tick-mode, harvest/fault) points are embarrassingly parallel, so
//! [`run_farm`] fans a batch out across a scoped thread pool and a
//! Monte-Carlo sweep of hundreds of seeded replicas ([`expand_seeds`])
//! costs one serial sim's wall-clock per `sims / jobs`.
//!
//! Determinism contract: the result vector is **collected by input index,
//! not by completion order** — `results[i]` is always `scenarios[i]`'s
//! outcome, whatever the worker interleaving was — and each sim is
//! per-run deterministic (`tests/scenario_determinism.rs`), so a farmed
//! batch is byte-identical to a serial one in every [`Outcome`] field.
//! Only wall-clock-derived numbers (`FarmResult::wall_s`, the batch
//! [`FarmRun::sims_per_sec`], and the `cycles_per_sec` family computed
//! from them) may differ between `jobs = 1` and `jobs = N`;
//! `tests/farm_equivalence.rs` pins exactly this split.
//!
//! The `Send` boundary is structural: [`Soc`] and everything it owns are
//! plain owned data (no `Rc`, no `RefCell`, no raw pointers), so `Send`
//! is automatic and the compile-time assertion below turns any future
//! regression (a cached `Rc`, a thread-local handle) into a build error
//! at the declaration site instead of a cryptic one at the spawn.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::scenario::{Outcome, Scenario};
use crate::coordinator::Soc;
use crate::util::bench::time_once;

// Compile-time pin of the farm's `Send`/`Sync` boundary.  The scoped
// spawn in `run_farm` enforces the same bounds, but this names the exact
// types the contract covers — break one and the error lands here.
const _: () = {
    const fn assert_send<T: Send>() {}
    const fn assert_sync<T: Sync>() {}
    assert_send::<Soc>();
    assert_send::<Scenario>();
    assert_sync::<Scenario>();
    assert_send::<Outcome>();
};

/// One slot of a farmed batch: `results[i]` of [`FarmRun`] belongs to
/// `scenarios[i]` of the input, whatever order the workers finished in.
pub struct FarmResult {
    /// The sim's outcome (or its structured failure, kept in-slot so one
    /// bad point cannot poison its neighbors).
    pub outcome: Result<Outcome>,
    /// Wall-clock seconds this one sim took on its worker (both
    /// lowerings) — scheduler-dependent, excluded from equivalence.
    pub wall_s: f64,
}

/// A completed farm batch.
pub struct FarmRun {
    /// Per-sim results, in input order (collected by index).
    pub results: Vec<FarmResult>,
    /// Wall-clock seconds for the whole batch.
    pub wall_s: f64,
    /// Worker threads actually used.
    pub jobs: usize,
}

impl FarmRun {
    /// Sims that ran to completion.
    pub fn completed(&self) -> usize {
        self.results.iter().filter(|r| r.outcome.is_ok()).count()
    }

    /// Farm throughput: completed simulations per wall-second.  This is
    /// the batch-level metric recorded as `sims_per_sec` alongside each
    /// point's `sim_cycles_per_sec` in `BENCH_noc.json`.
    pub fn sims_per_sec(&self) -> f64 {
        self.completed() as f64 / self.wall_s.max(1e-12)
    }
}

/// Resolve a `--jobs` request: `0` means one worker per available core,
/// and a batch never gets more workers than sims.
pub fn effective_jobs(requested: usize, sims: usize) -> usize {
    let jobs = if requested == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        requested
    };
    jobs.clamp(1, sims.max(1))
}

/// Run every scenario of a batch, `jobs` at a time, and collect the
/// results **by input index**.
///
/// `jobs == 0` selects one worker per available core; `jobs == 1` runs
/// in input order on the calling thread (the serial reference the
/// equivalence property compares against).  Workers pull the next
/// unclaimed index from a shared cursor — dynamic load balancing, since
/// a 16x16 coherent pipeline and a 3x4 chain differ by orders of
/// magnitude — and a failing sim occupies its slot as an `Err` without
/// aborting the rest of the batch.
pub fn run_farm(scenarios: &[Scenario], jobs: usize) -> FarmRun {
    let t0 = Instant::now();
    let jobs = effective_jobs(jobs, scenarios.len());
    if jobs <= 1 {
        let results = scenarios
            .iter()
            .map(|s| {
                let (outcome, wall_s) = time_once(|| s.run());
                FarmResult { outcome, wall_s }
            })
            .collect();
        return FarmRun { results, wall_s: t0.elapsed().as_secs_f64(), jobs: 1 };
    }
    let next = AtomicUsize::new(0);
    let mut slots: Vec<(usize, FarmResult)> = std::thread::scope(|scope| {
        let workers: Vec<_> = (0..jobs)
            .map(|_| {
                scope.spawn(|| {
                    let mut mine = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        let Some(s) = scenarios.get(i) else { break };
                        let (outcome, wall_s) = time_once(|| s.run());
                        mine.push((i, FarmResult { outcome, wall_s }));
                    }
                    mine
                })
            })
            .collect();
        workers.into_iter().flat_map(|w| w.join().expect("farm worker panicked")).collect()
    });
    // Every index is claimed exactly once (fetch_add), so sorting the
    // worker-local runs by index reconstructs the input order exactly.
    slots.sort_unstable_by_key(|&(i, _)| i);
    debug_assert!(slots.iter().enumerate().all(|(k, &(i, _))| k == i));
    let results = slots.into_iter().map(|(_, r)| r).collect();
    FarmRun { results, wall_s: t0.elapsed().as_secs_f64(), jobs }
}

/// Expand each scenario into `seeds` seeded replicas for a Monte-Carlo
/// sweep: replica `r` gets workload seed `base + r` (and, on
/// fault-injected scenarios, fault seed `base_fault + r`, so the storm
/// draw varies with the replica too) and a `+seed{N}` name suffix that
/// keeps every bench point distinct.  `seeds <= 1` is the identity — the
/// plain registry keeps its names, so existing baselines stay comparable.
pub fn expand_seeds(scenarios: &[Scenario], seeds: u64) -> Vec<Scenario> {
    if seeds <= 1 {
        return scenarios.to_vec();
    }
    let mut out = Vec::with_capacity(scenarios.len().saturating_mul(seeds as usize));
    for s in scenarios {
        for r in 0..seeds {
            let mut replica = s.clone();
            replica.seed = s.seed.wrapping_add(r);
            if s.fault_links > 0 {
                replica.fault_seed = s.fault_seed.wrapping_add(r);
            }
            replica.name = format!("{}+seed{}", s.name, replica.seed);
            out.push(replica);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scenario::{builtin_scenarios, Pattern, Platform};

    fn small_registry() -> Vec<Scenario> {
        let mut v = builtin_scenarios(Platform::Paper3x4);
        v.truncate(3);
        for s in &mut v {
            s.bytes = 8 << 10;
        }
        v
    }

    #[test]
    fn effective_jobs_resolves_auto_and_caps_at_batch_size() {
        assert_eq!(effective_jobs(1, 100), 1);
        assert_eq!(effective_jobs(7, 3), 3, "never more workers than sims");
        assert_eq!(effective_jobs(4, 0), 1, "empty batch still needs a well-formed count");
        assert!(effective_jobs(0, 100) >= 1, "0 = one worker per core");
    }

    #[test]
    fn expand_seeds_is_identity_at_one_and_distinct_past_it() {
        let base = small_registry();
        assert_eq!(expand_seeds(&base, 1), base);
        assert_eq!(expand_seeds(&base, 0), base);
        let expanded = expand_seeds(&base, 3);
        assert_eq!(expanded.len(), base.len() * 3);
        // Replicas of one scenario differ only in seed (+name); names
        // stay globally unique so bench points never collide.
        let mut names: Vec<&str> = expanded.iter().map(|s| s.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), expanded.len(), "replica names must be unique");
        assert_eq!(expanded[0].seed, base[0].seed);
        assert_eq!(expanded[1].seed, base[0].seed + 1);
        assert_eq!(expanded[1].pattern, base[0].pattern);
        assert!(expanded[1].name.contains("+seed"));
    }

    #[test]
    fn expand_seeds_varies_the_fault_draw_on_degraded_scenarios() {
        let mut s = Scenario::new("t", Pattern::P2pChain { stages: 2 }, Platform::Paper3x4);
        s.fault_links = 2;
        s.fault_seed = 100;
        let replicas = expand_seeds(&[s], 3);
        assert_eq!(replicas[2].fault_seed, 102);
        assert_eq!(replicas[0].fault_seed, 100);
    }

    #[test]
    fn farm_results_arrive_in_input_order_with_surplus_workers() {
        let batch = small_registry();
        let serial = run_farm(&batch, 1);
        let farmed = run_farm(&batch, 16); // more workers than sims
        assert_eq!(serial.jobs, 1);
        assert_eq!(farmed.jobs, batch.len());
        assert_eq!(serial.results.len(), farmed.results.len());
        for (i, (a, b)) in serial.results.iter().zip(&farmed.results).enumerate() {
            let (a, b) = (a.outcome.as_ref().unwrap(), b.outcome.as_ref().unwrap());
            assert_eq!(format!("{a:?}"), format!("{b:?}"), "slot {i} diverged");
            assert_eq!(a.name, batch[i].name, "slot {i} out of order");
        }
        assert!(serial.sims_per_sec() > 0.0 && farmed.sims_per_sec() > 0.0);
    }

    #[test]
    fn a_failing_sim_keeps_its_slot_without_poisoning_neighbors() {
        let mut batch = small_registry();
        batch[1].bytes = 6000; // not a burst multiple: validate() fails
        let run = run_farm(&batch, 3);
        assert_eq!(run.completed(), 2);
        assert!(run.results[0].outcome.is_ok());
        assert!(run.results[2].outcome.is_ok());
        let err = run.results[1].outcome.as_ref().unwrap_err();
        assert!(format!("{err:#}").contains("burst"), "{err:#}");
    }

    #[test]
    fn empty_batch_is_well_formed() {
        let run = run_farm(&[], 4);
        assert!(run.results.is_empty());
        assert_eq!(run.completed(), 0);
        assert_eq!(run.sims_per_sec(), 0.0);
    }
}
