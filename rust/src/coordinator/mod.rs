//! The coordinator: SoC assembly, the cycle loop, application launching,
//! statistics, and the paper's experiment drivers.

pub mod app;
pub mod experiments;
pub mod farm;
pub mod scenario;
pub mod soc;
pub mod stats;
pub mod workloads;

pub use app::{App, FlagBarrier, Invocation, Phase, ProgramKind};
pub use farm::{expand_seeds, run_farm, FarmResult, FarmRun};
pub use scenario::{builtin_scenarios, Outcome, Pattern, Platform, Scenario};
pub use soc::{QuiesceError, QuiesceKind, Soc};
pub use stats::Report;
