//! The coordinator: SoC assembly, the cycle loop, application launching,
//! statistics, and the paper's experiment drivers.

pub mod app;
pub mod experiments;
pub mod soc;
pub mod stats;
pub mod workloads;

pub use app::{App, Invocation, Phase, ProgramKind};
pub use soc::Soc;
pub use stats::Report;
