//! Declarative communication scenarios: workloads as *data*.
//!
//! The paper's evaluation exercises one traffic shape (a producer
//! multicasting to N consumers), but its thesis is *generalized*
//! communication — P2P chains, multicast forwarding, and coherence-based
//! synchronization composing freely.  A [`Scenario`] captures one such
//! composition declaratively: a communication [`Pattern`], a [`Platform`]
//! (the paper's 3x4, a scenario-sized 8x8, or the scaled 16x16), transfer
//! sizes, and a seed.  Running it lowers the pattern onto the existing
//! traffic-generator accelerators/ISA twice — once communication-optimized
//! (P2P / multicast / coherent flags), once DMA-only through main memory —
//! and reports cycles, per-plane NoC traffic, and the speedup over the
//! DMA-only baseline.
//!
//! [`builtin_scenarios`] is the named registry behind `espsim scenarios`;
//! [`Scenario::load_file`] reads additional scenarios from a JSON config.
//! Every run is fully deterministic (same scenario + seed + tick mode ⇒
//! byte-identical [`Outcome`], enforced by `tests/scenario_determinism.rs`),
//! which is what lets CI gate on the recorded numbers via
//! [`crate::util::bench::compare`].

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::accel::traffic_gen::TgenArgs;
use crate::accel::{stage_program, Xfer};
use crate::config::SocConfig;
use crate::coordinator::experiments::{fill_input, layout};
use crate::coordinator::stats::Report;
use crate::coordinator::workloads::{multi_pull_invocation, Dataflow, EdgePolicy, Shape};
use crate::coordinator::{App, Invocation, ProgramKind, Soc};
use crate::fault::FaultPlan;
use crate::noc::{Orientation, TickMode, NUM_PLANES};
use crate::sched::SchedMode;
use crate::telemetry::TelemetryReport;
use crate::util::{fnv1a64, Json, FNV_OFFSET};

/// Evaluation platform a scenario runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Platform {
    /// The paper's 3x4 mesh (18 sockets) — small and fast, used by tests.
    Paper3x4,
    /// An 8x8 mesh with 12 dual-socket accelerator tiles; shares the
    /// paper's header coordinate encoding (3-bit floor).
    Mesh8x8,
    /// The scaled 16x16 platform (9-bit destinations, 256 MiB DRAM).
    Mesh16x16,
}

impl Platform {
    /// Config-file code.
    pub fn code(&self) -> &'static str {
        match self {
            Platform::Paper3x4 => "paper_3x4",
            Platform::Mesh8x8 => "mesh_8x8",
            Platform::Mesh16x16 => "mesh_16x16",
        }
    }

    /// Parse a config-file code.
    pub fn from_code(s: &str) -> Result<Self> {
        Ok(match s {
            "paper_3x4" => Platform::Paper3x4,
            "mesh_8x8" => Platform::Mesh8x8,
            "mesh_16x16" => Platform::Mesh16x16,
            _ => bail!("unknown platform {s:?}"),
        })
    }

    /// The SoC configuration this platform stands for.
    pub fn config(&self) -> SocConfig {
        match self {
            Platform::Paper3x4 => SocConfig::paper_3x4(),
            Platform::Mesh8x8 => SocConfig::scaled_8x8(),
            Platform::Mesh16x16 => SocConfig::scaled_16x16(),
        }
    }
}

/// Scenario-level routing-orientation axis: a named per-plane
/// [`Orientation`] assignment (the full 6-tuple stays a config-level
/// concern; scenarios pick from the assignments worth benchmarking).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum OrientationMode {
    /// Every plane XY — the paper's baseline (byte-exact legacy).
    #[default]
    Xy,
    /// Every plane YX.
    Yx,
    /// Request planes XY, forward/response planes YX — the ttx-rs-style
    /// split that spreads request and response traffic over disjoint
    /// column/row link sets.
    Mixed,
}

impl OrientationMode {
    /// Every mode, in code order.
    pub const ALL: [OrientationMode; 3] =
        [OrientationMode::Xy, OrientationMode::Yx, OrientationMode::Mixed];

    /// Stable short code (JSON field, CLI flag, bench name suffix).
    pub fn code(self) -> &'static str {
        match self {
            OrientationMode::Xy => "xy",
            OrientationMode::Yx => "yx",
            OrientationMode::Mixed => "mixed",
        }
    }

    /// Parse a [`code`](Self::code) back into a mode.
    pub fn from_code(s: &str) -> Option<Self> {
        OrientationMode::ALL.into_iter().find(|m| m.code() == s)
    }

    /// The per-plane assignment ([`crate::noc::Plane::ALL`] order).
    /// `Mixed` keeps CohReq/DmaReq/Misc on XY and flips CohFwd/CohRsp/
    /// DmaRsp to YX, so a request plane and the plane answering it never
    /// contend for the same column links.
    pub fn plane_orientations(self) -> [Orientation; NUM_PLANES] {
        match self {
            OrientationMode::Xy => [Orientation::Xy; NUM_PLANES],
            OrientationMode::Yx => [Orientation::Yx; NUM_PLANES],
            OrientationMode::Mixed => [
                Orientation::Xy, // CohReq
                Orientation::Yx, // CohFwd
                Orientation::Yx, // CohRsp
                Orientation::Xy, // DmaReq
                Orientation::Yx, // DmaRsp
                Orientation::Xy, // Misc
            ],
        }
    }
}

/// A communication pattern: the roles and edges of a workload, independent
/// of platform and transfer size.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pattern {
    /// `stages` accelerators in a pipeline; optimized lowering streams
    /// every edge over unicast P2P in one phase.
    P2pChain {
        /// Pipeline depth (>= 2).
        stages: u8,
    },
    /// One producer multicasting to `consumers` sinks (the paper's Fig. 6
    /// shape, generalized to any platform).
    MulticastFanout {
        /// Fan-out (>= 1; 1 degenerates to unicast P2P).
        consumers: u8,
    },
    /// Source scatters (multicast) to `workers`, which gather (unicast
    /// P2P) into a merging sink — the NN-pipeline diamond.
    ScatterGather {
        /// Parallel workers between source and sink (>= 1).
        workers: u8,
    },
    /// `producers` x `consumers` bipartite shuffle: every producer
    /// multicasts its stream, every consumer merges all producer streams
    /// with interleaved round-robin pulls.
    AllToAllShuffle {
        /// Producer count (>= 1).
        producers: u8,
        /// Consumer count (>= 1).
        consumers: u8,
    },
    /// `nodes` accelerators on a ring exchanging boundary data with both
    /// neighbors (red-black 1D stencil halo: evens push to odd neighbors,
    /// then odds push back while evens drain to memory).
    HaloExchange {
        /// Ring size (even, >= 4).
        nodes: u8,
    },
    /// A `stages`-deep producer/consumer pipeline where each phase moves
    /// data over P2P and the host separates phases with a coherent-flag
    /// barrier ([`crate::coordinator::app::FlagBarrier`]) instead of bare
    /// IRQ joins — coherence-based synchronization composing with P2P.
    CoherentPhases {
        /// Number of P2P phases (each uses two accelerators; >= 1).
        stages: u8,
    },
}

impl Pattern {
    /// Config-file code of the pattern kind.
    pub fn code(&self) -> &'static str {
        match self {
            Pattern::P2pChain { .. } => "p2p_chain",
            Pattern::MulticastFanout { .. } => "multicast_fanout",
            Pattern::ScatterGather { .. } => "scatter_gather",
            Pattern::AllToAllShuffle { .. } => "all_to_all_shuffle",
            Pattern::HaloExchange { .. } => "halo_exchange",
            Pattern::CoherentPhases { .. } => "coherent_phases",
        }
    }

    /// Accelerator sockets the pattern occupies.
    pub fn sockets(&self) -> usize {
        match *self {
            Pattern::P2pChain { stages } => stages as usize,
            Pattern::MulticastFanout { consumers } => consumers as usize + 1,
            Pattern::ScatterGather { workers } => workers as usize + 2,
            Pattern::AllToAllShuffle { producers, consumers } => {
                producers as usize + consumers as usize
            }
            Pattern::HaloExchange { nodes } => nodes as usize,
            Pattern::CoherentPhases { stages } => 2 * stages as usize,
        }
    }
}

/// One declarative workload: pattern + platform + transfer shape.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    /// Registry / report name.
    pub name: String,
    /// Communication pattern.
    pub pattern: Pattern,
    /// Platform to lower onto.
    pub platform: Platform,
    /// Bytes each role streams (multiple of `burst_bytes`, <= 1 MiB).
    pub bytes: u32,
    /// DMA/P2P burst size.
    pub burst_bytes: u32,
    /// Seed for generated graphs (kept in the record for reproducibility).
    pub seed: u64,
    /// Simulation cycle budget per lowering.
    pub max_cycles: u64,
    /// NoC plane-tick scheduling (results are identical in every mode).
    pub tick_mode: TickMode,
    /// SoC tile scheduling (worklist or the full-scan reference; results
    /// are cycle-identical in both — `tests/prop_soc_sched.rs`).
    pub sched: SchedMode,
    /// Degraded-mesh axis: rows harvested down to a bridge tile (see
    /// [`SocConfig::harvest_rows`]).  Empty = pristine mesh.
    pub harvest_rows: Vec<u8>,
    /// Degraded-mesh axis: links killed mid-run by a deterministic
    /// [`FaultPlan::link_storm`].  0 = no fault injection.
    pub fault_links: u8,
    /// Seed of the link storm (independent of the workload `seed` so the
    /// same traffic can be replayed under different fault draws).
    pub fault_seed: u64,
    /// Arm telemetry: the [`Outcome`] then carries a [`TelemetryReport`]
    /// of the optimized lowering.  Purely observational — cycles and flit
    /// statistics are identical either way (`tests/prop_telemetry.rs`).
    pub telemetry: bool,
    /// Routing-orientation axis (XY baseline, all-YX, or the mixed
    /// request-XY/response-YX split).  Unlike `telemetry`, this *does*
    /// change cycles — which is the point of the congestion A/B.
    pub orientation: OrientationMode,
    /// Recovery axis: producer-side P2P replay-ring window in bytes
    /// ([`crate::config::AccConfig::replay_window`]).  0 = off
    /// (byte-exact legacy): a lost chunk is diagnosed, not recovered.
    pub replay_window: u32,
}

/// Cycle window fault events are drawn from: early enough to hit every
/// scenario's live traffic, late enough that warm-up completes.
const FAULT_WINDOW: u64 = 20_000;

/// Socket retry timeout on fault-injected runs — generous against worst
/// case contention so healthy-but-slow responses are not re-requested.
const FAULT_RETRY_TIMEOUT: u32 = 8192;

/// Measured result of one scenario run (both lowerings).
#[derive(Debug, Clone)]
pub struct Outcome {
    /// Scenario name.
    pub name: String,
    /// Platform it ran on.
    pub platform: Platform,
    /// Cycles of the communication-optimized lowering.
    pub cycles: u64,
    /// Cycles of the DMA-only (memory-staged) baseline.
    pub baseline_cycles: u64,
    /// Flit-hops per NoC plane (optimized lowering).
    pub plane_flits: [u64; NUM_PLANES],
    /// Messages delivered per NoC plane (optimized lowering).
    pub plane_delivered: [u64; NUM_PLANES],
    /// P2P/multicast bytes delivered (optimized lowering).
    pub p2p_bytes: u64,
    /// DMA bytes moved at the memory tile (optimized lowering).
    pub dma_bytes: u64,
    /// Invocation spans `(acc, start, end)` of the optimized lowering —
    /// the scenario-level delivery trace the determinism suite pins.
    pub invocation_spans: Vec<(u16, u64, u64)>,
    /// Flits dropped by fault injection (optimized lowering; 0 healthy).
    pub dropped_flits: u64,
    /// Socket sub-request retries (optimized lowering; 0 healthy).
    pub socket_retries: u64,
    /// Bytes retransmitted from producer replay rings (optimized
    /// lowering; 0 unless [`Scenario::replay_window`] armed recovery and
    /// a re-request actually resumed).
    pub replayed_bytes: u64,
    /// Truncated wormhole allocations retired by the fault drain's
    /// downstream walk (optimized lowering; 0 healthy).
    pub drained_worms: u64,
    /// True when the run *survived* injected damage: it completed with
    /// verified sink payloads even though bytes had to be replayed.
    /// Always false when the replay window is off or the run was clean.
    pub recovered: bool,
    /// FNV-1a/64 over every sink's final output region, in node order —
    /// the end-to-end payload-integrity digest (optimized lowering).  A
    /// degraded run that completes must reproduce the healthy digest.
    pub sink_digest: u64,
    /// Congestion/utilization snapshot of the optimized lowering; `None`
    /// unless [`Scenario::telemetry`] armed it.
    pub telemetry: Option<TelemetryReport>,
}

impl Outcome {
    /// Speedup of the optimized lowering over the DMA-only baseline.
    pub fn speedup(&self) -> f64 {
        self.baseline_cycles as f64 / self.cycles as f64
    }

    /// Total flit-hops across planes (optimized lowering).
    pub fn total_flits(&self) -> u64 {
        self.plane_flits.iter().sum()
    }
}

/// Flag words for [`Pattern::CoherentPhases`] live below the data layout.
const FLAG_BASE: u64 = 0x2000;
/// Per-node staging/output regions are 1 MiB apart (bounds `bytes`).
const REGION_STRIDE: u64 = 0x0010_0000;
/// Node staging regions (DMA-only lowerings).
const STAGE_BASE: u64 = 0x0100_0000;
/// Final output regions.
const OUT_BASE: u64 = 0x0200_0000;

fn stage(i: usize) -> u64 {
    STAGE_BASE + i as u64 * REGION_STRIDE
}

fn out(i: usize) -> u64 {
    OUT_BASE + i as u64 * REGION_STRIDE
}

impl Scenario {
    /// A scenario with the default transfer shape (64 KiB in 4 KiB bursts).
    pub fn new(name: &str, pattern: Pattern, platform: Platform) -> Self {
        Self {
            name: name.to_string(),
            pattern,
            platform,
            bytes: 64 << 10,
            burst_bytes: 4 << 10,
            seed: 1,
            max_cycles: 200_000_000,
            tick_mode: TickMode::Auto,
            sched: SchedMode::default(),
            harvest_rows: Vec::new(),
            fault_links: 0,
            fault_seed: 1,
            telemetry: false,
            orientation: OrientationMode::default(),
            replay_window: 0,
        }
    }

    /// Copy with the routing-orientation axis set.  Non-XY modes gain a
    /// `+yx`/`+mixed` name suffix so bench records from different
    /// orientations never share a point namespace.
    pub fn oriented(&self, mode: OrientationMode) -> Self {
        let mut s = self.clone();
        s.orientation = mode;
        if mode != OrientationMode::Xy {
            s.name = format!("{}+{}", s.name, mode.code());
        }
        s
    }

    /// Degraded-mode copy: `rows` harvested, `links` killed mid-run.  The
    /// name gains a `+harvestR`/`+faultsN` suffix so bench records from the
    /// pristine and degraded sweeps never collide.
    pub fn degraded(&self, rows: &[u8], links: u8, fault_seed: u64) -> Self {
        let mut s = self.clone();
        s.harvest_rows = rows.to_vec();
        s.fault_links = links;
        s.fault_seed = fault_seed;
        for &r in rows {
            s.name = format!("{}+harvest{r}", s.name);
        }
        if links > 0 {
            s.name = format!("{}+faults{links}", s.name);
        }
        s
    }

    /// Recovery copy: producer replay rings of `window` bytes armed.  The
    /// name gains a `+replay{W}` suffix so bench records from the
    /// diagnosis-only and recovery sweeps never collide.
    pub fn recovery(&self, window: u32) -> Self {
        let mut s = self.clone();
        s.replay_window = window;
        if window > 0 {
            s.name = format!("{}+replay{window}", s.name);
        }
        s
    }

    /// Structural validation (pattern arity, transfer shape, layout).
    pub fn validate(&self) -> Result<()> {
        ensure!(!self.name.is_empty(), "scenario needs a name");
        ensure!(self.burst_bytes > 0, "burst_bytes must be positive");
        ensure!(
            self.bytes > 0 && self.bytes % self.burst_bytes == 0,
            "bytes ({}) must be a positive multiple of burst_bytes ({})",
            self.bytes,
            self.burst_bytes
        );
        ensure!(
            self.bytes as u64 <= REGION_STRIDE,
            "bytes ({}) exceeds the 1 MiB per-node region stride",
            self.bytes
        );
        let cfg = self.platform.config();
        for &r in &self.harvest_rows {
            ensure!(r < cfg.height, "harvest row {r} outside the {}-row mesh", cfg.height);
        }
        let acc = cfg.acc;
        ensure!(
            self.burst_bytes <= acc.max_burst_bytes,
            "burst_bytes ({}) exceeds the socket burst limit ({})",
            self.burst_bytes,
            acc.max_burst_bytes
        );
        // Merging roles (multi-source pulls, staged multi-reads) hold one
        // full transfer in the PLM; streaming-only patterns are unbounded.
        let staged_in_plm = matches!(
            self.pattern,
            Pattern::ScatterGather { .. }
                | Pattern::AllToAllShuffle { .. }
                | Pattern::HaloExchange { .. }
        );
        ensure!(
            !staged_in_plm || self.bytes <= acc.plm_bytes,
            "bytes ({}) exceeds the {}-byte PLM a merging role stages through",
            self.bytes,
            acc.plm_bytes
        );
        match self.pattern {
            Pattern::P2pChain { stages } => ensure!(stages >= 2, "chain needs >= 2 stages"),
            Pattern::MulticastFanout { consumers } => {
                ensure!(consumers >= 1, "fan-out needs >= 1 consumer")
            }
            Pattern::ScatterGather { workers } => ensure!(workers >= 1, "needs >= 1 worker"),
            Pattern::AllToAllShuffle { producers, consumers } => ensure!(
                producers >= 1 && consumers >= 1,
                "shuffle needs >= 1 producer and consumer"
            ),
            Pattern::HaloExchange { nodes } => ensure!(
                nodes >= 4 && nodes % 2 == 0,
                "halo ring needs an even node count >= 4"
            ),
            Pattern::CoherentPhases { stages } => ensure!(stages >= 1, "needs >= 1 stage"),
        }
        Ok(())
    }

    /// Fresh SoC for one lowering.  Both lowerings get the identical
    /// degraded mesh: the same harvest mask and the same fault plan, so
    /// degraded speedups compare like against like.
    fn soc(&self) -> Result<Soc> {
        let mut cfg = self.platform.config();
        cfg.noc.tick_mode = self.tick_mode;
        cfg.telemetry = self.telemetry;
        cfg.noc.orientations = self.orientation.plane_orientations();
        if !self.harvest_rows.is_empty() {
            cfg.harvest_rows(&self.harvest_rows);
        }
        if self.fault_links > 0 {
            // Fault-injected runs arm the bounded-retry path so a lost
            // sub-request surfaces as a precise socket fault, not a hang.
            cfg.acc.retry_timeout = FAULT_RETRY_TIMEOUT;
        }
        if self.replay_window > 0 {
            cfg.acc.replay_window = self.replay_window;
        }
        let (w, h) = (cfg.width, cfg.height);
        let mut soc = Soc::new(cfg)?;
        soc.set_sched_mode(self.sched);
        if self.fault_links > 0 {
            soc.set_fault_plan(FaultPlan::link_storm(
                self.fault_seed,
                self.fault_links as u32,
                w,
                h,
                (1, FAULT_WINDOW),
            ));
        }
        ensure!(
            self.pattern.sockets() <= soc.acc_count(),
            "pattern {} needs {} sockets, platform {} has {} (after harvest)",
            self.pattern.code(),
            self.pattern.sockets(),
            self.platform.code(),
            soc.acc_count()
        );
        Ok(soc)
    }

    /// Run both lowerings and measure.
    pub fn run(&self) -> Result<Outcome> {
        self.validate()?;
        let r = match self.pattern {
            Pattern::P2pChain { stages } => self.run_dataflow(Shape::Chain(stages)),
            Pattern::MulticastFanout { consumers } => self.run_dataflow(Shape::Tree(consumers)),
            Pattern::ScatterGather { workers } => self.run_dataflow(Shape::Diamond(workers)),
            Pattern::AllToAllShuffle { producers, consumers } => {
                self.run_dataflow(Shape::Bipartite(producers, consumers))
            }
            Pattern::HaloExchange { nodes } => self.run_halo(nodes as usize),
            Pattern::CoherentPhases { stages } => self.run_coherent(stages as usize),
        };
        r.with_context(|| format!("scenario {} on {}", self.name, self.platform.code()))
    }

    fn outcome(
        &self,
        cycles: u64,
        baseline_cycles: u64,
        report: &Report,
        telemetry: Option<TelemetryReport>,
        sink_digest: u64,
    ) -> Outcome {
        let mut plane_flits = [0u64; NUM_PLANES];
        let mut plane_delivered = [0u64; NUM_PLANES];
        for (i, p) in report.planes.iter().enumerate() {
            plane_flits[i] = p.flit_hops;
            plane_delivered[i] = p.delivered;
        }
        let replayed_bytes = report.replayed_bytes();
        Outcome {
            name: self.name.clone(),
            platform: self.platform,
            cycles,
            baseline_cycles,
            plane_flits,
            plane_delivered,
            p2p_bytes: report.p2p_bytes(),
            dma_bytes: report.dma_bytes(),
            invocation_spans: report.invocations.clone(),
            dropped_flits: report.dropped_flits(),
            socket_retries: report.socket_retries(),
            replayed_bytes,
            drained_worms: report.drained_worms(),
            recovered: replayed_bytes > 0,
            sink_digest,
            telemetry,
        }
    }

    /// Fold each `(vaddr, len)` region of `soc` memory into the payload
    /// digest, in slice order.
    fn digest_regions(soc: &mut Soc, regions: &[(u64, u32)]) -> u64 {
        let mut h = FNV_OFFSET;
        for &(vaddr, len) in regions {
            h = fnv1a64(h, &soc.read_mem(vaddr, len as usize));
        }
        h
    }

    /// Graph-shaped patterns ride the dataflow lowering: P2P/multicast
    /// edges for the optimized run, memory staging for the baseline.
    fn run_dataflow(&self, shape: Shape) -> Result<Outcome> {
        let g = Dataflow::generate(shape, self.bytes, self.burst_bytes, self.seed);
        let mut soc = self.soc()?;
        let cycles = g.run_budget(&mut soc, EdgePolicy::P2p, self.max_cycles)?;
        let report = soc.report();
        let telem = soc.telemetry_report();
        let digest = Self::digest_regions(&mut soc, &g.sink_regions());
        // Free the optimized SoC (on the 16x16 platform its DRAM alone is
        // 256 MiB) before building the baseline one: farmed batches hold
        // `jobs` sims in flight, so per-sim peak memory is wall-clock for
        // the whole pool.
        drop(soc);
        let mut base = self.soc()?;
        let baseline = g.run_budget(&mut base, EdgePolicy::Memory, self.max_cycles)?;
        Ok(self.outcome(cycles, baseline, &report, telem, digest))
    }

    /// Red-black halo exchange on a ring of `n` nodes.
    ///
    /// Optimized (2 phases): evens read the input and multicast to both
    /// odd neighbors while odds merge the two incoming streams; then odds
    /// multicast back and evens merge + drain to memory.  Baseline
    /// (3 phases): the same exchanges staged through per-node DRAM regions.
    fn run_halo(&self, n: usize) -> Result<Outcome> {
        let bytes = self.bytes;
        let burst = self.burst_bytes;
        let left = |i: usize| ((i + n - 1) % n) as u16;
        let right = |i: usize| ((i + 1) % n) as u16;

        // --- optimized: P2P/multicast neighbor exchange.
        let mut soc = self.soc()?;
        fill_input(&mut soc, bytes);
        let mut phase_a = Vec::new();
        let mut phase_b = Vec::new();
        for i in 0..n {
            if i % 2 == 0 {
                phase_a.push(Invocation::tgen(
                    i as u16,
                    TgenArgs {
                        total_bytes: bytes,
                        burst_bytes: burst,
                        rd_user: 0,
                        wr_user: 2, // multicast to both odd neighbors
                        vaddr_in: layout::IN,
                        vaddr_out: 0,
                    },
                ));
                let writes = [Xfer { vaddr: out(i), plm: 0, len: bytes, user: 0 }];
                phase_b.push(multi_pull_invocation(
                    i as u16,
                    &[left(i), right(i)],
                    bytes,
                    burst,
                    &writes,
                ));
            } else {
                phase_a.push(multi_pull_invocation(
                    i as u16,
                    &[left(i), right(i)],
                    bytes,
                    burst,
                    &[],
                ));
                // Push the halo held in PLM back to both even neighbors.
                let mut inv = Invocation::tgen(
                    i as u16,
                    TgenArgs {
                        total_bytes: 0,
                        burst_bytes: 1,
                        rd_user: 0,
                        wr_user: 0,
                        vaddr_in: 0,
                        vaddr_out: 0,
                    },
                );
                let writes = [Xfer { vaddr: 0, plm: 0, len: bytes, user: 2 }];
                inv.program = ProgramKind::Custom(stage_program(&[], &[], &writes, burst));
                inv.args = [0; 8];
                phase_b.push(inv);
            }
        }
        App::new().phase(phase_a).phase(phase_b).launch(&mut soc)?;
        let cycles = soc.run(self.max_cycles)?;
        let report = soc.report();
        let telem = soc.telemetry_report();
        // Even nodes drain the merged halos to memory; their output
        // regions are the exchange's end-to-end payload.
        let regions: Vec<(u64, u32)> =
            (0..n).filter(|i| i % 2 == 0).map(|i| (out(i), bytes)).collect();
        let digest = Self::digest_regions(&mut soc, &regions);
        drop(soc); // one SoC at a time: farmed batches run `jobs` sims at once

        // --- baseline: the same exchange staged through DRAM.
        let mut base = self.soc()?;
        fill_input(&mut base, bytes);
        let mem_stream = |acc: usize, vin: u64, vout: u64| {
            Invocation::tgen(
                acc as u16,
                TgenArgs {
                    total_bytes: bytes,
                    burst_bytes: burst,
                    rd_user: 0,
                    wr_user: 0,
                    vaddr_in: vin,
                    vaddr_out: vout,
                },
            )
        };
        let mem_merge = |acc: usize, vout: u64| {
            let reads = [
                Xfer { vaddr: stage(left(acc) as usize), plm: 0, len: bytes, user: 0 },
                Xfer { vaddr: stage(right(acc) as usize), plm: 0, len: bytes, user: 0 },
            ];
            let writes = [Xfer { vaddr: vout, plm: 0, len: bytes, user: 0 }];
            let mut inv = Invocation::tgen(
                acc as u16,
                TgenArgs {
                    total_bytes: 0,
                    burst_bytes: 1,
                    rd_user: 0,
                    wr_user: 0,
                    vaddr_in: 0,
                    vaddr_out: 0,
                },
            );
            inv.program = ProgramKind::Custom(stage_program(&reads, &[], &writes, burst));
            inv.args = [0; 8];
            inv
        };
        let evens = (0..n).filter(|i| i % 2 == 0);
        let odds = (0..n).filter(|i| i % 2 == 1);
        let app = App::new()
            .phase(evens.clone().map(|i| mem_stream(i, layout::IN, stage(i))).collect())
            .phase(odds.map(|i| mem_merge(i, stage(i))).collect())
            .phase(evens.map(|i| mem_merge(i, out(i))).collect());
        app.launch(&mut base)?;
        let baseline = base.run(self.max_cycles)?;
        Ok(self.outcome(cycles, baseline, &report, telem, digest))
    }

    /// `stages` P2P producer/consumer phases separated by coherent-flag
    /// barriers; the baseline is the same pipeline as a DMA-only chain.
    fn run_coherent(&self, stages: usize) -> Result<Outcome> {
        let bytes = self.bytes;
        let burst = self.burst_bytes;

        let mut soc = self.soc()?;
        let data = fill_input(&mut soc, bytes);
        let mut app = App::new();
        for j in 0..stages {
            let prod = (2 * j) as u16;
            let cons = prod + 1;
            let vin = if j == 0 { layout::IN } else { stage(j - 1) };
            let p = Invocation::tgen(
                prod,
                TgenArgs {
                    total_bytes: bytes,
                    burst_bytes: burst,
                    rd_user: 0,
                    wr_user: 1, // unicast P2P to the phase's consumer
                    vaddr_in: vin,
                    vaddr_out: 0,
                },
            );
            let c = Invocation::tgen(
                cons,
                TgenArgs {
                    total_bytes: bytes,
                    burst_bytes: burst,
                    rd_user: 1,
                    wr_user: 0,
                    vaddr_in: 0,
                    vaddr_out: stage(j),
                },
            )
            .with_src(1, prod);
            app = app.phase_with_flag_barrier(vec![p, c], FLAG_BASE + j as u64 * 64, j as u64 + 1);
        }
        app.launch(&mut soc)?;
        let cycles = soc.run(self.max_cycles)?;
        let got = soc.read_mem(stage(stages - 1), bytes as usize);
        ensure!(got == data, "coherent pipeline corrupted its stream");
        let digest = fnv1a64(FNV_OFFSET, &got);
        let report = soc.report();
        let telem = soc.telemetry_report();
        drop(soc); // one SoC at a time: farmed batches run `jobs` sims at once

        // Baseline: the same 2*stages accelerators as a DMA-only chain.
        let g = Dataflow::generate(Shape::Chain(2 * stages as u8), bytes, burst, self.seed);
        let mut base = self.soc()?;
        let baseline = g.run_budget(&mut base, EdgePolicy::Memory, self.max_cycles)?;
        Ok(self.outcome(cycles, baseline, &report, telem, digest))
    }

    /// Serialize to the scenario-file JSON schema.
    pub fn to_json(&self) -> Json {
        use std::collections::BTreeMap;
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::from(self.name.as_str()));
        m.insert("pattern".to_string(), Json::from(self.pattern.code()));
        m.insert("platform".to_string(), Json::from(self.platform.code()));
        m.insert("bytes".to_string(), Json::from(self.bytes as u64));
        m.insert("burst_bytes".to_string(), Json::from(self.burst_bytes as u64));
        m.insert("seed".to_string(), Json::from(self.seed));
        m.insert("max_cycles".to_string(), Json::from(self.max_cycles));
        m.insert("tick_mode".to_string(), Json::from(self.tick_mode.code()));
        m.insert("sched".to_string(), Json::from(self.sched.code()));
        if !self.harvest_rows.is_empty() {
            let rows = self.harvest_rows.iter().map(|&r| Json::from(r as u64)).collect();
            m.insert("harvest_rows".to_string(), Json::Arr(rows));
        }
        if self.fault_links > 0 {
            m.insert("fault_links".to_string(), Json::from(self.fault_links as u64));
            m.insert("fault_seed".to_string(), Json::from(self.fault_seed));
        }
        if self.replay_window > 0 {
            // Absent means off, so pre-recovery scenario files serialize
            // byte-identically.
            m.insert("replay_window".to_string(), Json::from(self.replay_window as u64));
        }
        if self.telemetry {
            // Emitted only when armed, so pre-telemetry scenario files
            // serialize byte-identically.
            m.insert("telemetry".to_string(), Json::from(true));
        }
        if self.orientation != OrientationMode::Xy {
            // Same contract: absent means the XY baseline, so existing
            // scenario files and committed bench records stay valid.
            m.insert("orientation".to_string(), Json::from(self.orientation.code()));
        }
        match self.pattern {
            Pattern::P2pChain { stages } | Pattern::CoherentPhases { stages } => {
                m.insert("stages".to_string(), Json::from(stages as u64));
            }
            Pattern::MulticastFanout { consumers } => {
                m.insert("consumers".to_string(), Json::from(consumers as u64));
            }
            Pattern::ScatterGather { workers } => {
                m.insert("workers".to_string(), Json::from(workers as u64));
            }
            Pattern::AllToAllShuffle { producers, consumers } => {
                m.insert("producers".to_string(), Json::from(producers as u64));
                m.insert("consumers".to_string(), Json::from(consumers as u64));
            }
            Pattern::HaloExchange { nodes } => {
                m.insert("nodes".to_string(), Json::from(nodes as u64));
            }
        }
        Json::Obj(m)
    }

    /// Parse one scenario object of the scenario-file schema; unspecified
    /// transfer-shape fields fall back to the defaults.
    pub fn from_json(j: &Json) -> Result<Self> {
        let name = j.req("name")?.as_str()?;
        let param = |key: &str| -> Result<u8> {
            let v = j.req(key)?.as_u64()?;
            ensure!((1..=u8::MAX as u64).contains(&v), "{key} out of range: {v}");
            Ok(v as u8)
        };
        let pattern = match j.req("pattern")?.as_str()? {
            "p2p_chain" => Pattern::P2pChain { stages: param("stages")? },
            "multicast_fanout" => Pattern::MulticastFanout { consumers: param("consumers")? },
            "scatter_gather" => Pattern::ScatterGather { workers: param("workers")? },
            "all_to_all_shuffle" => Pattern::AllToAllShuffle {
                producers: param("producers")?,
                consumers: param("consumers")?,
            },
            "halo_exchange" => Pattern::HaloExchange { nodes: param("nodes")? },
            "coherent_phases" => Pattern::CoherentPhases { stages: param("stages")? },
            other => bail!("unknown pattern {other:?}"),
        };
        let platform = Platform::from_code(j.req("platform")?.as_str()?)?;
        let mut s = Scenario::new(name, pattern, platform);
        let as_u32 = |v: &Json, key: &str| -> Result<u32> {
            let n = v.as_u64()?;
            u32::try_from(n).map_err(|_| anyhow!("{key} out of range: {n}"))
        };
        if let Some(v) = j.get("bytes") {
            s.bytes = as_u32(v, "bytes")?;
        }
        if let Some(v) = j.get("burst_bytes") {
            s.burst_bytes = as_u32(v, "burst_bytes")?;
        }
        if let Some(v) = j.get("seed") {
            s.seed = v.as_u64()?;
        }
        if let Some(v) = j.get("max_cycles") {
            s.max_cycles = v.as_u64()?;
        }
        if let Some(v) = j.get("tick_mode") {
            let code = v.as_str()?;
            s.tick_mode = TickMode::from_code(code)
                .ok_or_else(|| anyhow!("unknown tick_mode {code:?}"))?;
        }
        if let Some(v) = j.get("sched") {
            let code = v.as_str()?;
            s.sched =
                SchedMode::from_code(code).ok_or_else(|| anyhow!("unknown sched {code:?}"))?;
        }
        if let Some(v) = j.get("harvest_rows") {
            for r in v.as_arr()? {
                let n = r.as_u64()?;
                ensure!(n < 256, "harvest row out of range: {n}");
                s.harvest_rows.push(n as u8);
            }
        }
        if let Some(v) = j.get("fault_links") {
            let n = v.as_u64()?;
            ensure!(n <= u8::MAX as u64, "fault_links out of range: {n}");
            s.fault_links = n as u8;
        }
        if let Some(v) = j.get("fault_seed") {
            s.fault_seed = v.as_u64()?;
        }
        if let Some(v) = j.get("replay_window") {
            s.replay_window = as_u32(v, "replay_window")?;
        }
        if let Some(v) = j.get("telemetry") {
            s.telemetry = v.as_bool()?;
        }
        if let Some(v) = j.get("orientation") {
            let code = v.as_str()?;
            s.orientation = OrientationMode::from_code(code)
                .ok_or_else(|| anyhow!("unknown orientation {code:?}"))?;
        }
        s.validate()?;
        Ok(s)
    }

    /// Load a scenario file: `{"scenarios": [ {...}, ... ]}`.
    pub fn load_file(path: impl AsRef<std::path::Path>) -> Result<Vec<Scenario>> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {}", path.display()))?;
        let doc = Json::parse(&text).with_context(|| format!("parse {}", path.display()))?;
        let list = doc.req("scenarios")?.as_arr()?;
        ensure!(!list.is_empty(), "{}: empty scenario list", path.display());
        list.iter()
            .map(|j| Scenario::from_json(j).with_context(|| format!("in {}", path.display())))
            .collect()
    }
}

/// The named registry behind `espsim scenarios`: one scenario per pattern,
/// parameterized by platform.  Every entry fits all three platforms.
pub fn builtin_scenarios(platform: Platform) -> Vec<Scenario> {
    vec![
        Scenario::new("chain4", Pattern::P2pChain { stages: 4 }, platform),
        Scenario::new("fanout8", Pattern::MulticastFanout { consumers: 8 }, platform),
        Scenario::new("scatter_gather4", Pattern::ScatterGather { workers: 4 }, platform),
        Scenario::new(
            "shuffle4x4",
            Pattern::AllToAllShuffle { producers: 4, consumers: 4 },
            platform,
        ),
        Scenario::new("halo_ring8", Pattern::HaloExchange { nodes: 8 }, platform),
        Scenario::new("coherent_pipeline3", Pattern::CoherentPhases { stages: 3 }, platform),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_is_distinct_and_valid_on_every_platform() {
        for platform in [Platform::Paper3x4, Platform::Mesh8x8, Platform::Mesh16x16] {
            let scenarios = builtin_scenarios(platform);
            assert!(scenarios.len() >= 5, "registry must cover >= 5 patterns");
            let mut codes: Vec<&str> = scenarios.iter().map(|s| s.pattern.code()).collect();
            codes.dedup();
            assert_eq!(codes.len(), scenarios.len(), "patterns must be distinct");
            let accs = platform.config().acc_sockets().len();
            for s in &scenarios {
                s.validate().unwrap_or_else(|e| panic!("{}: {e}", s.name));
                assert!(s.pattern.sockets() <= accs, "{} fits {:?}", s.name, platform);
            }
        }
    }

    #[test]
    fn validation_rejects_malformed_scenarios() {
        let mut s = Scenario::new("bad", Pattern::P2pChain { stages: 1 }, Platform::Paper3x4);
        assert!(s.validate().is_err(), "1-stage chain");
        s.pattern = Pattern::HaloExchange { nodes: 5 };
        assert!(s.validate().is_err(), "odd ring");
        s.pattern = Pattern::P2pChain { stages: 2 };
        s.bytes = 6000; // not a burst multiple
        assert!(s.validate().is_err(), "partial bursts");
        s.bytes = 2 << 20;
        assert!(s.validate().is_err(), "beyond the region stride");
    }

    #[test]
    fn json_roundtrips_every_builtin() {
        for s in builtin_scenarios(Platform::Mesh8x8) {
            let j = s.to_json();
            let s2 = Scenario::from_json(&j).unwrap();
            assert_eq!(s, s2, "{} roundtrip", s.name);
        }
        assert!(Scenario::from_json(&Json::parse("{\"name\":\"x\"}").unwrap()).is_err());
    }

    #[test]
    fn orientation_roundtrips_and_defaults_to_xy() {
        let base = Scenario::new("t", Pattern::P2pChain { stages: 3 }, Platform::Paper3x4);
        // Absent field: the XY baseline, and to_json leaves it out so
        // pre-orientation scenario files serialize byte-identically.
        assert_eq!(base.orientation, OrientationMode::Xy);
        assert!(base.to_json().get("orientation").is_none());
        assert_eq!(Scenario::from_json(&base.to_json()).unwrap().orientation,
                   OrientationMode::Xy);
        for mode in [OrientationMode::Yx, OrientationMode::Mixed] {
            let s = base.oriented(mode);
            assert_eq!(s.name, format!("t+{}", mode.code()), "non-XY modes suffix the name");
            let s2 = Scenario::from_json(&s.to_json()).unwrap();
            assert_eq!(s, s2, "{mode:?} roundtrip");
        }
        assert_eq!(base.oriented(OrientationMode::Xy).name, "t", "XY keeps the bare name");
        let bad = Json::parse(r#"{"name":"x","pattern":"p2p_chain","stages":2,
                                  "platform":"paper_3x4","orientation":"zigzag"}"#)
            .unwrap();
        assert!(Scenario::from_json(&bad).is_err());
    }

    #[test]
    fn orientation_modes_name_every_plane() {
        for mode in OrientationMode::ALL {
            assert_eq!(OrientationMode::from_code(mode.code()), Some(mode));
        }
        let mixed = OrientationMode::Mixed.plane_orientations();
        assert_eq!(mixed.len(), NUM_PLANES);
        assert!(mixed.contains(&Orientation::Xy) && mixed.contains(&Orientation::Yx));
        assert_eq!(OrientationMode::Xy.plane_orientations(), [Orientation::Xy; NUM_PLANES]);
        assert_eq!(OrientationMode::Yx.plane_orientations(), [Orientation::Yx; NUM_PLANES]);
    }

    #[test]
    fn oriented_scenarios_run_and_deliver() {
        // The same chain completes under every orientation mode; cycles may
        // differ (that is the point), deliveries may not.
        let mut s = Scenario::new("t", Pattern::P2pChain { stages: 3 }, Platform::Paper3x4);
        s.bytes = 8 << 10;
        let reference = s.run().unwrap();
        for mode in [OrientationMode::Yx, OrientationMode::Mixed] {
            let o = s.oriented(mode).run().unwrap();
            assert!(o.cycles > 0 && o.baseline_cycles > 0, "{mode:?}");
            assert_eq!(o.p2p_bytes, reference.p2p_bytes, "{mode:?}: payload changed");
            assert_eq!(
                o.plane_delivered, reference.plane_delivered,
                "{mode:?}: delivery counts changed"
            );
        }
    }

    #[test]
    fn degraded_scenario_runs_on_a_harvested_mesh() {
        let mut s = Scenario::new("t", Pattern::P2pChain { stages: 3 }, Platform::Paper3x4);
        s.bytes = 8 << 10;
        let d = s.degraded(&[1], 0, 7);
        assert_eq!(d.name, "t+harvest1");
        let o = d.run().unwrap();
        assert!(o.cycles > 0 && o.baseline_cycles > 0);
        assert_eq!(o.dropped_flits, 0, "harvest alone drops nothing mid-run");
        // The degraded fields survive the JSON roundtrip.
        let d2 = Scenario::from_json(&s.degraded(&[1], 3, 9).to_json()).unwrap();
        assert_eq!(d2.harvest_rows, vec![1]);
        assert_eq!(d2.fault_links, 3);
        assert_eq!(d2.fault_seed, 9);
        assert_eq!(d2.name, "t+harvest1+faults3");
    }

    #[test]
    fn recovery_axis_roundtrips_and_defaults_to_off() {
        let base = Scenario::new("t", Pattern::P2pChain { stages: 3 }, Platform::Paper3x4);
        assert_eq!(base.replay_window, 0);
        assert!(base.to_json().get("replay_window").is_none(), "absent means off");
        let r = base.recovery(1 << 14);
        assert_eq!(r.name, "t+replay16384", "recovery suffixes the name");
        assert_eq!(r.replay_window, 1 << 14);
        let r2 = Scenario::from_json(&r.to_json()).unwrap();
        assert_eq!(r, r2, "recovery roundtrip");
        assert_eq!(base.recovery(0).name, "t", "window 0 keeps the bare name");
    }

    #[test]
    fn replay_on_healthy_run_changes_nothing_and_digests_match() {
        // With no faults injected the replay ring only buffers: cycles,
        // traffic, and the payload digest are identical to replay-off, no
        // byte is ever replayed, and the run does not count as recovered.
        let mut s = Scenario::new("t", Pattern::P2pChain { stages: 3 }, Platform::Paper3x4);
        s.bytes = 8 << 10;
        let off = s.run().unwrap();
        let on = s.recovery(1 << 14).run().unwrap();
        assert_eq!(on.cycles, off.cycles, "healthy hot path must not shift");
        assert_eq!(on.sink_digest, off.sink_digest, "payload digest must match");
        assert_eq!(on.plane_flits, off.plane_flits);
        assert_eq!(on.replayed_bytes, 0);
        assert_eq!(off.drained_worms, 0);
        assert!(!on.recovered && !off.recovered);
    }

    #[test]
    fn chain_scenario_beats_its_dma_baseline() {
        let mut s = Scenario::new("t", Pattern::P2pChain { stages: 3 }, Platform::Paper3x4);
        s.bytes = 8 << 10;
        let o = s.run().unwrap();
        assert!(o.cycles > 0 && o.baseline_cycles > 0);
        assert!(o.speedup() > 1.0, "P2P chain {} vs memory {}", o.cycles, o.baseline_cycles);
        assert!(o.p2p_bytes > 0 && o.total_flits() > 0);
    }
}
