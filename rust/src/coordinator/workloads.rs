//! Synthetic dataflow-workload generator.
//!
//! The paper's evaluation uses a single producer/consumer pattern; real
//! heterogeneous SoCs run *graphs* of accelerator kernels ("workloads can
//! be partitioned across several accelerators to exploit parallelism ...
//! there may also be data dependencies across kernels").  This module
//! generates random-but-reproducible dataflow DAGs (chains, fan-out trees,
//! diamonds) over traffic-generator accelerators, maps them onto a SoC,
//! lowers the edges to DMA / P2P / multicast per a chosen policy, and
//! verifies end-to-end data integrity — the workload half of the benchmark
//! harness, and a stress generator for the communication substrate.

use anyhow::{ensure, Result};

use crate::accel::traffic_gen::TgenArgs;
use crate::accel::{stage_program, Xfer};
#[cfg(test)]
use crate::config::SocConfig;
use crate::coordinator::{App, Invocation, ProgramKind, Soc};
use crate::util::Prng;

/// How graph edges move data.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EdgePolicy {
    /// Every edge staged through main memory (phase per graph level).
    Memory,
    /// Direct P2P / multicast edges within one phase.
    P2p,
}

/// A dataflow node: one traffic-generator invocation.
#[derive(Debug, Clone)]
pub struct Node {
    /// Node id (== accelerator id after mapping).
    pub id: u16,
    /// Producers this node consumes from (empty = reads workload input).
    pub inputs: Vec<u16>,
    /// Topological level (0 = sources).
    pub level: u32,
}

/// A generated dataflow graph.
#[derive(Debug, Clone)]
pub struct Dataflow {
    /// Nodes in topological order.
    pub nodes: Vec<Node>,
    /// Bytes each node streams.
    pub bytes: u32,
    /// Burst size.
    pub burst: u32,
}

/// Graph shapes the generator can emit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Shape {
    /// a -> b -> c -> ...
    Chain(u8),
    /// One source multicasting to `n` sinks.
    Tree(u8),
    /// Source -> n parallel workers -> sink (the NN-pipeline shape).
    Diamond(u8),
    /// `m` producers each feeding all of `n` consumers (the map-reduce
    /// shuffle: every producer multicasts, every consumer merges `m`
    /// streams) — `Bipartite(m, n)`.
    Bipartite(u8, u8),
    /// Random DAG with `n` nodes and random cross-level edges.
    Random(u8),
}

impl Dataflow {
    /// Generate a graph of the given shape; `seed` makes it reproducible.
    pub fn generate(shape: Shape, bytes: u32, burst: u32, seed: u64) -> Self {
        let mut rng = Prng::new(seed);
        let mut nodes = Vec::new();
        match shape {
            Shape::Chain(n) => {
                for i in 0..n as u16 {
                    nodes.push(Node {
                        id: i,
                        inputs: if i == 0 { vec![] } else { vec![i - 1] },
                        level: i as u32,
                    });
                }
            }
            Shape::Tree(n) => {
                nodes.push(Node { id: 0, inputs: vec![], level: 0 });
                for i in 1..=n as u16 {
                    nodes.push(Node { id: i, inputs: vec![0], level: 1 });
                }
            }
            Shape::Diamond(n) => {
                nodes.push(Node { id: 0, inputs: vec![], level: 0 });
                for i in 1..=n as u16 {
                    nodes.push(Node { id: i, inputs: vec![0], level: 1 });
                }
                nodes.push(Node {
                    id: n as u16 + 1,
                    inputs: (1..=n as u16).collect(),
                    level: 2,
                });
            }
            Shape::Bipartite(m, n) => {
                for i in 0..m as u16 {
                    nodes.push(Node { id: i, inputs: vec![], level: 0 });
                }
                for i in 0..n as u16 {
                    nodes.push(Node {
                        id: m as u16 + i,
                        inputs: (0..m as u16).collect(),
                        level: 1,
                    });
                }
            }
            Shape::Random(n) => {
                // Levelized random DAG; every non-source consumes 1..=2
                // producers from the previous level.
                let mut level_of = vec![0u32];
                nodes.push(Node { id: 0, inputs: vec![], level: 0 });
                for i in 1..n as u16 {
                    let level = level_of[rng.below(i as u64) as usize] + 1;
                    let prev: Vec<u16> = (0..i)
                        .filter(|&j| level_of[j as usize] + 1 == level)
                        .collect();
                    let inputs = if prev.is_empty() {
                        vec![]
                    } else {
                        let k = rng.range(1, 2.min(prev.len() as u64)) as usize;
                        let mut ins = Vec::new();
                        while ins.len() < k {
                            let c = *rng.pick(&prev);
                            if !ins.contains(&c) {
                                ins.push(c);
                            }
                        }
                        ins
                    };
                    let level = if inputs.is_empty() { 0 } else { level };
                    level_of.push(level);
                    nodes.push(Node { id: i, inputs, level });
                }
                nodes.sort_by_key(|n| n.level);
                // Re-id in topological order, remapping edges.
                let mut remap = vec![0u16; nodes.len()];
                for (new, n) in nodes.iter().enumerate() {
                    remap[n.id as usize] = new as u16;
                }
                for n in &mut nodes {
                    n.id = remap[n.id as usize];
                    for i in &mut n.inputs {
                        *i = remap[*i as usize];
                    }
                }
            }
        }
        Self { nodes, bytes, burst }
    }

    /// Fan-out of node `id` (how many nodes consume it).
    pub fn fanout(&self, id: u16) -> usize {
        self.nodes.iter().filter(|n| n.inputs.contains(&id)).count()
    }

    /// Number of levels.
    pub fn levels(&self) -> u32 {
        self.nodes.iter().map(|n| n.level).max().unwrap_or(0) + 1
    }

    /// DRAM address of the workload input.
    fn input_addr() -> u64 {
        0x0010_0000
    }

    /// DRAM staging address for node `id`'s output (memory policy).
    fn stage_addr(id: u16) -> u64 {
        0x0100_0000 + id as u64 * 0x0010_0000
    }

    /// DRAM address of sink `id`'s final output.
    fn out_addr(id: u16) -> u64 {
        0x0280_0000 + id as u64 * 0x0010_0000
    }

    /// Final-output DRAM regions `(vaddr, len)` of every sink, in node
    /// order — the scenario layer hashes these after a run for its
    /// end-to-end payload digest (both lowerings write the same regions).
    pub fn sink_regions(&self) -> Vec<(u64, u32)> {
        self.nodes
            .iter()
            .filter(|n| self.fanout(n.id) == 0)
            .map(|n| (Self::out_addr(n.id), self.bytes))
            .collect()
    }

    /// [`Dataflow::run`] with the default 100M-cycle budget.
    pub fn run(&self, soc: &mut Soc, policy: EdgePolicy) -> Result<u64> {
        self.run_budget(soc, policy, 100_000_000)
    }

    /// Lower the graph to an [`App`] under `policy` and run it on `soc`
    /// within `max_cycles`.  Returns total cycles; verifies every sink's
    /// output equals the workload input (traffic generators are identity).
    pub fn run_budget(&self, soc: &mut Soc, policy: EdgePolicy, max_cycles: u64) -> Result<u64> {
        ensure!(self.nodes.len() <= soc.acc_count(), "graph larger than the SoC");
        let data: Vec<u8> =
            (0..self.bytes as u64).map(|i| (i.wrapping_mul(0x9E37_79B9) >> 8) as u8).collect();
        soc.write_mem(Self::input_addr(), &data);

        let mut app = App::new();
        match policy {
            EdgePolicy::Memory => {
                // One phase per level; every edge staged through DRAM.
                for level in 0..self.levels() {
                    let mut phase = Vec::new();
                    for n in self.nodes.iter().filter(|n| n.level == level) {
                        let sink = self.fanout(n.id) == 0;
                        if n.inputs.len() > 1 {
                            // Multi-input node: DMA-read every staged
                            // producer region (the memory-policy mirror of
                            // the P2P multi-pull), then one stream out.
                            // Burst-granular Xfers pinned at PLM offset 0
                            // keep PLM use bounded by the burst size, so
                            // transfers larger than the PLM still stream
                            // (the single-input tgen path never stages
                            // more than two banks either).
                            let bursts = self.bytes.div_ceil(self.burst);
                            let chunk = |b: u32| self.burst.min(self.bytes - b * self.burst);
                            let mut reads = Vec::new();
                            for &p in &n.inputs {
                                for b in 0..bursts {
                                    reads.push(Xfer {
                                        vaddr: Self::stage_addr(p) + (b * self.burst) as u64,
                                        plm: 0,
                                        len: chunk(b),
                                        user: 0,
                                    });
                                }
                            }
                            let vout = if sink {
                                Self::out_addr(n.id)
                            } else {
                                Self::stage_addr(n.id)
                            };
                            let writes: Vec<Xfer> = (0..bursts)
                                .map(|b| Xfer {
                                    vaddr: vout + (b * self.burst) as u64,
                                    plm: 0,
                                    len: chunk(b),
                                    user: 0,
                                })
                                .collect();
                            let mut inv = Invocation::tgen(
                                n.id,
                                TgenArgs {
                                    total_bytes: 0,
                                    burst_bytes: 1,
                                    rd_user: 0,
                                    wr_user: 0,
                                    vaddr_in: 0,
                                    vaddr_out: 0,
                                },
                            );
                            inv.program = ProgramKind::Custom(stage_program(
                                &reads,
                                &[],
                                &writes,
                                self.burst,
                            ));
                            inv.args = [0; 8];
                            phase.push(inv);
                            continue;
                        }
                        let vaddr_in = match n.inputs.first() {
                            None => Self::input_addr(),
                            Some(&p) => Self::stage_addr(p),
                        };
                        phase.push(Invocation::tgen(
                            n.id,
                            TgenArgs {
                                total_bytes: self.bytes,
                                burst_bytes: self.burst,
                                rd_user: 0,
                                wr_user: 0,
                                vaddr_in,
                                vaddr_out: if sink {
                                    Self::out_addr(n.id)
                                } else {
                                    Self::stage_addr(n.id)
                                },
                            },
                        ));
                    }
                    app = app.phase(phase);
                }
            }
            EdgePolicy::P2p => {
                // One phase; edges are pulls (multicast when fan-out > 1).
                let mut phase = Vec::new();
                for n in &self.nodes {
                    let fanout = self.fanout(n.id);
                    let sink = fanout == 0;
                    ensure!(
                        n.inputs.len() <= 1 || sink,
                        "P2P lowering supports multi-input nodes only at sinks"
                    );
                    if n.inputs.len() > 1 {
                        // Multi-input sink: round-robin pulls from every
                        // producer, then one identity stream out.
                        let writes = [Xfer {
                            vaddr: Self::out_addr(n.id),
                            plm: 0,
                            len: self.bytes,
                            user: 0,
                        }];
                        phase.push(multi_pull_invocation(
                            n.id,
                            &n.inputs,
                            self.bytes,
                            self.burst,
                            &writes,
                        ));
                        continue;
                    }
                    let rd_user = if n.inputs.is_empty() { 0 } else { 1 };
                    let mut inv = Invocation::tgen(
                        n.id,
                        TgenArgs {
                            total_bytes: self.bytes,
                            burst_bytes: self.burst,
                            rd_user,
                            wr_user: if sink { 0 } else { fanout as u16 },
                            vaddr_in: if n.inputs.is_empty() {
                                Self::input_addr()
                            } else {
                                0
                            },
                            vaddr_out: if sink { Self::out_addr(n.id) } else { 0 },
                        },
                    );
                    if let Some(&p) = n.inputs.first() {
                        inv = inv.with_src(1, p);
                    }
                    phase.push(inv);
                }
                app = app.phase(phase);
            }
        }
        app.launch(soc)?;
        let cycles = soc.run(max_cycles)?;
        for n in self.nodes.iter().filter(|n| self.fanout(n.id) == 0 && !n.inputs.is_empty()) {
            // Single-input sinks carry the full identity stream.
            if n.inputs.len() == 1 {
                let got = soc.read_mem(Self::out_addr(n.id), self.bytes as usize);
                ensure!(got == data, "sink {} corrupted its stream", n.id);
            }
        }
        Ok(cycles)
    }
}

/// Build a round-robin multi-source pull invocation: a generated program
/// that pulls `bytes` from each of `srcs` (installed as source-LUT entries
/// `1..=srcs.len()`) one burst at a time, *interleaved across sources*,
/// then emits `writes` from the PLM (memory DMA when `user == 0`,
/// P2P/multicast otherwise).  The interleaving matters: draining sources
/// sequentially deadlocks — an unserved producer stops accepting pulls from
/// its other consumers once its bounded write buffer fills, which stalls
/// the producer the consumer IS draining (documented in DESIGN.md
/// §deviations).  Shared by the dataflow lowering's multi-input sinks and
/// the scenario subsystem's shuffle/halo patterns.
pub fn multi_pull_invocation(
    acc: u16,
    srcs: &[u16],
    bytes: u32,
    burst: u32,
    writes: &[Xfer],
) -> Invocation {
    let mut reads = Vec::new();
    for b in 0..bytes.div_ceil(burst) {
        let len = burst.min(bytes - b * burst);
        for i in 0..srcs.len() {
            reads.push(Xfer { vaddr: 0, plm: 0, len, user: (1 + i) as u16 });
        }
    }
    let mut inv = Invocation::tgen(
        acc,
        TgenArgs {
            total_bytes: 0,
            burst_bytes: 1,
            rd_user: 0,
            wr_user: 0,
            vaddr_in: 0,
            vaddr_out: 0,
        },
    );
    inv.program = ProgramKind::Custom(stage_program(&reads, &[], writes, burst));
    inv.args = [0; 8];
    for (i, &p) in srcs.iter().enumerate() {
        inv = inv.with_src((1 + i) as u16, p);
    }
    inv
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_have_expected_structure() {
        let c = Dataflow::generate(Shape::Chain(4), 4096, 4096, 0);
        assert_eq!(c.nodes.len(), 4);
        assert_eq!(c.levels(), 4);
        assert_eq!(c.fanout(0), 1);
        assert_eq!(c.fanout(3), 0);

        let t = Dataflow::generate(Shape::Tree(5), 4096, 4096, 0);
        assert_eq!(t.nodes.len(), 6);
        assert_eq!(t.fanout(0), 5);
        assert_eq!(t.levels(), 2);

        let d = Dataflow::generate(Shape::Diamond(3), 4096, 4096, 0);
        assert_eq!(d.nodes.len(), 5);
        assert_eq!(d.fanout(0), 3);
        assert_eq!(d.nodes.last().unwrap().inputs.len(), 3);
    }

    #[test]
    fn bipartite_shuffle_runs_p2p() {
        let g = Dataflow::generate(Shape::Bipartite(3, 3), 8 << 10, 4096, 0);
        assert_eq!(g.nodes.len(), 6);
        assert_eq!(g.levels(), 2);
        for p in 0..3u16 {
            assert_eq!(g.fanout(p), 3, "every producer feeds every consumer");
        }
        let mut soc = Soc::new(SocConfig::paper_3x4()).unwrap();
        g.run(&mut soc, EdgePolicy::P2p).unwrap();
        let report = soc.report();
        for sink in 3..6u16 {
            let (_, s) = report.sockets.iter().find(|(id, _)| *id == sink).unwrap();
            assert_eq!(s.p2p_read_bytes, 3 * (8 << 10) as u64, "sink {sink} merges 3 streams");
        }
    }

    #[test]
    fn random_dags_are_topological_and_reproducible() {
        for seed in 0..20 {
            let g = Dataflow::generate(Shape::Random(8), 4096, 4096, seed);
            assert_eq!(g.nodes.len(), 8);
            for (i, n) in g.nodes.iter().enumerate() {
                assert_eq!(n.id as usize, i, "ids in topological order");
                for &p in &n.inputs {
                    assert!(p < n.id, "edge {p}->{} not topological", n.id);
                }
            }
            let g2 = Dataflow::generate(Shape::Random(8), 4096, 4096, seed);
            assert_eq!(format!("{g:?}"), format!("{g2:?}"), "reproducible");
        }
    }

    #[test]
    fn chain_runs_both_policies() {
        let g = Dataflow::generate(Shape::Chain(3), 8 << 10, 4096, 1);
        let mut soc = Soc::new(SocConfig::small_3x3()).unwrap();
        let mem = g.run(&mut soc, EdgePolicy::Memory).unwrap();
        let mut soc = Soc::new(SocConfig::small_3x3()).unwrap();
        let p2p = g.run(&mut soc, EdgePolicy::P2p).unwrap();
        assert!(p2p < mem, "P2P chain {p2p} should beat memory staging {mem}");
    }

    #[test]
    fn diamond_runs_p2p_with_multi_input_sink() {
        let g = Dataflow::generate(Shape::Diamond(3), 16 << 10, 4096, 3);
        let mut soc = Soc::new(SocConfig::paper_3x4()).unwrap();
        g.run(&mut soc, EdgePolicy::P2p).unwrap();
        let report = soc.report();
        // The sink (node 4) pulled from all three workers.
        let (_, sink) = report.sockets.iter().find(|(id, _)| *id == 4).unwrap();
        assert_eq!(sink.p2p_read_bytes, 3 * (16 << 10) as u64);
    }

    #[test]
    fn tree_uses_multicast() {
        let g = Dataflow::generate(Shape::Tree(4), 16 << 10, 4096, 2);
        let mut soc = Soc::new(SocConfig::paper_3x4()).unwrap();
        g.run(&mut soc, EdgePolicy::P2p).unwrap();
        let report = soc.report();
        let (_, prod) = &report.sockets[0];
        assert!(prod.p2p_write_bytes > 0, "root multicasts");
    }
}
