//! SoC assembly and the global simulation loop.
//!
//! [`Soc::run`] is **activity-driven**: tiles report a [`Wake`] state from
//! every tick, the scheduler keeps a busy worklist plus a min-heap
//! wake-queue of timed events, NoC deliveries unpark their destination
//! tile, and when nothing is busy and the NoC is idle the loop
//! fast-forwards `now` straight to the next timed wake instead of ticking
//! through provably dead cycles.  [`SchedMode::FullScan`] retains the
//! seed's tick-every-tile loop as the executable reference model;
//! `tests/prop_soc_sched.rs` pins the two cycle-for-cycle identical.
//! DESIGN.md §SoC scheduler documents the wake-state lattice, the legal
//! fast-forward conditions, and the unpark obligations a new tile or
//! socket implementation must meet.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use anyhow::{anyhow, Result};

use crate::accel::{AccCore, DpCall};
use crate::config::{SocConfig, TileKind};
use crate::fault::{FaultKind, FaultPlan};
use crate::noc::{Coord, MeshParams, Noc, Plane};
use crate::sched::{SchedMode, Wake};
use crate::socket::Socket;
use crate::telemetry::{TelemetryReport, TileTelemetry};
use crate::tile::{AccTile, CpuTile, HostOp, IoTile, MemTile, Tile};

use super::stats::Report;

/// Per-tile scheduler state (parallel to [`Soc::tiles`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum St {
    /// On the run list for the next cycle.
    Busy,
    /// Timed wake pending at the recorded cycle.
    Sleeping(u64),
    /// Waiting on a delivery; `blocked` records whether the tile was
    /// non-idle when it parked (so quiescence stays O(active)).
    Parked { blocked: bool },
}

/// The tile worklist + wake-queue behind the activity-driven [`Soc::run`].
struct Sched {
    /// Current state per tile.
    state: Vec<St>,
    /// Tiles to tick next cycle, ascending index order.
    run_list: Vec<u32>,
    /// Timed wakes `(cycle, tile)`.  Entries go stale when a delivery
    /// unparks the tile first; stale entries are skipped lazily on pop.
    heap: BinaryHeap<Reverse<(u64, u32)>>,
    /// Live `Sleeping` tiles (the heap may additionally hold stale
    /// entries).
    sleepers: usize,
    /// Parked tiles that are not idle — tiles whose wait must resolve
    /// before the SoC can quiesce.
    blocked_parked: usize,
    /// Next cycle's run list under construction during a tick.
    scratch: Vec<u32>,
}

impl Sched {
    fn new(tiles: usize) -> Self {
        Self {
            state: vec![St::Parked { blocked: false }; tiles],
            run_list: Vec::new(),
            heap: BinaryHeap::new(),
            sleepers: 0,
            blocked_parked: 0,
            scratch: Vec::new(),
        }
    }

    /// Start (or restart) a worklist run: every tile is ticked on the
    /// first cycle, after which the wake states it reports take over.
    /// This is what makes backdoor mutation between runs safe — the
    /// scheduler assumes nothing about state it did not observe.
    fn reset_all_busy(&mut self) {
        self.heap.clear();
        self.sleepers = 0;
        self.blocked_parked = 0;
        self.scratch.clear();
        self.run_list.clear();
        self.run_list.extend(0..self.state.len() as u32);
        for s in &mut self.state {
            *s = St::Busy;
        }
    }

    /// A delivery (or due timer) makes `i` runnable next cycle.
    fn unpark(&mut self, i: u32) {
        match self.state[i as usize] {
            St::Busy => return,
            St::Sleeping(_) => self.sleepers -= 1,
            St::Parked { blocked } => self.blocked_parked -= blocked as usize,
        }
        self.state[i as usize] = St::Busy;
        if let Err(pos) = self.run_list.binary_search(&i) {
            self.run_list.insert(pos, i);
        }
    }

    /// Move every sleeper due at or before `now` onto the run list.
    fn wake_due(&mut self, now: u64) {
        while let Some(&Reverse((t, i))) = self.heap.peek() {
            if t > now {
                break;
            }
            self.heap.pop();
            if self.state[i as usize] == St::Sleeping(t) {
                self.unpark(i);
            }
        }
    }

    /// Earliest live timed wake, discarding stale heap entries.
    fn next_wake(&mut self) -> Option<u64> {
        while let Some(&Reverse((t, i))) = self.heap.peek() {
            if self.state[i as usize] == St::Sleeping(t) {
                return Some(t);
            }
            self.heap.pop();
        }
        None
    }

    /// Record the wake a tile reported; `idle_if_parked` is the tile's
    /// [`Tile::idle`] (only consulted when it parks).
    fn note(&mut self, i: u32, wake: Wake, idle_if_parked: bool) {
        self.state[i as usize] = match wake {
            Wake::Busy => {
                self.scratch.push(i);
                St::Busy
            }
            Wake::Sleeping { until } => {
                self.heap.push(Reverse((until, i)));
                self.sleepers += 1;
                St::Sleeping(until)
            }
            Wake::Parked => {
                let blocked = !idle_if_parked;
                self.blocked_parked += blocked as usize;
                St::Parked { blocked }
            }
        };
    }
}

/// Why [`Soc::run`] failed to quiesce.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuiesceKind {
    /// The cycle budget ran out while work was still in flight (possibly
    /// a livelock or a runaway workload — the SoC might still finish).
    Budget,
    /// Provable deadlock: nothing can ever run again, yet the SoC is not
    /// idle (only the worklist scheduler can detect this early).
    Deadlock,
}

/// Typed quiesce failure: the seed's one-line message plus a forensic
/// dump.  Carried behind [`anyhow::Error`]; match on it with
/// `err.downcast_ref::<QuiesceError>()`.
#[derive(Debug)]
pub struct QuiesceError {
    /// Budget exhaustion vs provable deadlock.
    pub kind: QuiesceKind,
    /// The cycle budget that was exceeded.
    pub max_cycles: u64,
    /// Multi-line post-mortem: non-idle tiles, socket fault latches,
    /// per-plane queue occupancy, the oldest stalled packet and its next
    /// hop, and a suspected cause.
    pub dump: String,
}

impl std::fmt::Display for QuiesceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // First line is the seed's exact wording — scripts grep for it.
        write!(f, "SoC did not quiesce within {} cycles (deadlock or runaway)", self.max_cycles)?;
        if !self.dump.is_empty() {
            write!(f, "\n{}", self.dump)?;
        }
        Ok(())
    }
}

impl std::error::Error for QuiesceError {}

/// The simulated SoC: tiles + multi-plane NoC + the cycle loop.
pub struct Soc {
    /// Configuration this SoC was built from.
    pub cfg: SocConfig,
    /// The six-plane NoC.
    pub noc: Noc,
    /// Tiles, row-major.
    pub tiles: Vec<Tile>,
    /// Current cycle.
    pub now: u64,
    /// Accelerator id -> (tile index, slot).
    acc_index: Vec<(usize, u8)>,
    /// Full-scan reference probe: index of the tile most recently observed
    /// busy, so the reference quiesce test is O(1) while anything runs.
    busy_tile_hint: usize,
    /// How [`Soc::run`] schedules tile ticks.
    sched_mode: SchedMode,
    /// Worklist scheduler state.
    sched: Sched,
    /// Scheduled mid-run link/router kills (empty on healthy runs).
    fault_plan: FaultPlan,
    /// Next unapplied event in `fault_plan` (events are cycle-sorted).
    fault_next: usize,
    /// Per-tile busy/sleeping/parked accounting, allocated only when
    /// `cfg.telemetry` armed it (the NoC planes arm their counters in
    /// lockstep).  Purely observational — see DESIGN.md §telemetry.
    tile_telem: Option<Box<TileTelemetry>>,
}

impl Soc {
    /// Build an idle SoC from a validated configuration.
    pub fn new(cfg: SocConfig) -> Result<Self> {
        cfg.validate()?;
        let mut noc = Noc::new(MeshParams {
            width: cfg.width,
            height: cfg.height,
            flit_bytes: cfg.flit_bytes(),
            queue_depth: cfg.noc.queue_depth,
        });
        noc.set_tick_mode(cfg.noc.tick_mode);
        // Orientations first: the harvest rebuild below materializes the
        // per-plane tables under whatever orientations are in force.
        noc.set_orientations(cfg.noc.orientations);
        noc.set_harvest(&cfg.harvest);
        if cfg.telemetry {
            noc.set_telemetry(true);
        }
        let mut tiles = Vec::with_capacity(cfg.tiles.len());
        let mut acc_index = Vec::new();
        let mut next_acc: u16 = 0;
        for (i, kind) in cfg.tiles.iter().enumerate() {
            let coord = cfg.coord_of(i);
            if cfg.is_harvested(coord) {
                // Harvested tiles are depopulated: never built, scheduled,
                // or injected into (validate() keeps CPU/Mem/IO alive, and
                // `cfg.acc_sockets()` already skips them, so accelerator
                // numbering stays consistent).
                tiles.push(Tile::Empty);
                continue;
            }
            tiles.push(match kind {
                TileKind::Cpu => {
                    Tile::Cpu(CpuTile::new(coord, cfg.mem_tile(), cfg.host, cfg.mem.line_bytes))
                }
                TileKind::Mem => Tile::Mem(MemTile::new(coord, cfg.mem)),
                TileKind::Io => Tile::Io(IoTile::new(coord)),
                TileKind::Acc { accs } => {
                    let t = AccTile::new(coord, *accs, next_acc, &cfg);
                    for s in 0..*accs {
                        acc_index.push((i, s));
                    }
                    next_acc += *accs as u16;
                    Tile::Acc(t)
                }
                TileKind::Empty => Tile::Empty,
            });
        }
        let sched = Sched::new(tiles.len());
        let tile_telem = cfg.telemetry.then(|| Box::new(TileTelemetry::new(tiles.len())));
        Ok(Self {
            cfg,
            noc,
            tiles,
            now: 0,
            acc_index,
            busy_tile_hint: 0,
            sched_mode: SchedMode::default(),
            sched,
            fault_plan: FaultPlan::none(),
            fault_next: 0,
            tile_telem,
        })
    }

    /// Install a fault-injection plan.  Events fire at the start of their
    /// cycle, before any tile ticks; already-past events fire on the next
    /// cycle boundary.
    pub fn set_fault_plan(&mut self, plan: FaultPlan) {
        self.fault_plan = plan;
        self.fault_next = 0;
    }

    /// Apply every fault event due at or before `now`.  Kills only ever
    /// remove work (queued flits drop, routes rebuild), so applying them
    /// after an idle-cycle fast-forward jump is equivalent to applying
    /// them mid-gap: there was nothing in flight to kill.
    #[cold]
    fn apply_due_faults(&mut self) {
        while let Some(ev) = self.fault_plan.events().get(self.fault_next) {
            if ev.cycle > self.now {
                break;
            }
            match ev.kind {
                FaultKind::Link { at, dir } => self.noc.kill_link(at, dir),
                FaultKind::Router { at } => self.noc.kill_router(at),
            }
            self.fault_next += 1;
        }
    }

    /// Select how [`Soc::run`] schedules tile ticks (results are
    /// cycle-for-cycle identical in both modes).
    pub fn set_sched_mode(&mut self, mode: SchedMode) {
        self.sched_mode = mode;
    }

    /// Current tile-scheduling mode.
    pub fn sched_mode(&self) -> SchedMode {
        self.sched_mode
    }

    /// Number of accelerator sockets.
    pub fn acc_count(&self) -> usize {
        self.acc_index.len()
    }

    /// `(tile coord, slot)` of accelerator `acc`.
    pub fn acc_location(&self, acc: u16) -> (Coord, u8) {
        let (t, s) = self.acc_index[acc as usize];
        (self.cfg.coord_of(t), s)
    }

    /// Mutable access to the memory tile.
    pub fn mem_mut(&mut self) -> &mut MemTile {
        let i = self.cfg.index_of(self.cfg.mem_tile());
        match &mut self.tiles[i] {
            Tile::Mem(m) => m,
            _ => unreachable!("validated config"),
        }
    }

    /// Mutable access to the CPU tile.
    pub fn cpu_mut(&mut self) -> &mut CpuTile {
        let i = self.cfg.index_of(self.cfg.cpu_tile());
        match &mut self.tiles[i] {
            Tile::Cpu(c) => c,
            _ => unreachable!("validated config"),
        }
    }

    /// Mutable access to accelerator `acc`'s socket, core and PLM.
    pub fn acc_mut(&mut self, acc: u16) -> (&mut Socket, &mut AccCore, &mut Vec<u8>) {
        let (t, s) = self.acc_index[acc as usize];
        match &mut self.tiles[t] {
            Tile::Acc(a) => {
                let s = s as usize;
                // Split borrows across the parallel vectors.
                (&mut a.sockets[s], &mut a.cores[s], &mut a.plms[s])
            }
            _ => unreachable!("acc_index points at Acc tiles"),
        }
    }

    /// Backdoor: load an accelerator program + datapath descriptors and map
    /// its virtual buffer linearly over the whole DRAM (identity mapping;
    /// scattered mappings are exercised at the TLB unit level).
    pub fn setup_acc(
        &mut self,
        acc: u16,
        program: Vec<crate::accel::Instr>,
        dp_calls: Vec<DpCall>,
    ) {
        let dram = self.cfg.mem.dram_bytes;
        let (socket, core, _) = self.acc_mut(acc);
        socket.tlb.map_linear(0, dram);
        core.load_program(program);
        core.dp_calls = dp_calls;
    }

    /// Backdoor: write initial data into DRAM.
    pub fn write_mem(&mut self, addr: u64, data: &[u8]) {
        self.mem_mut().write_backdoor(addr, data);
    }

    /// Backdoor: read DRAM.
    pub fn read_mem(&mut self, addr: u64, len: usize) -> Vec<u8> {
        self.mem_mut().read_backdoor(addr, len).to_vec()
    }

    /// Append host operations to the CPU script.
    pub fn push_host_script(&mut self, ops: Vec<HostOp>) {
        self.cpu_mut().push_script(ops);
    }

    /// Advance one cycle, ticking every tile (the full-scan reference
    /// step).  Hand-driven harnesses that mutate [`Soc::tiles`] directly
    /// keep using this; [`Soc::run`] re-seeds its worklist from scratch,
    /// so interleaving manual ticks, backdoor writes and `run` is safe.
    pub fn tick(&mut self) {
        if self.fault_next < self.fault_plan.len() {
            self.apply_due_faults();
        }
        let now = self.now;
        for (i, t) in self.tiles.iter_mut().enumerate() {
            let wake = t.tick(now, &mut self.noc);
            if let Some(tt) = self.tile_telem.as_deref_mut() {
                tt.note(i, now, wake);
            }
        }
        self.noc.tick(now);
        self.now += 1;
    }

    /// One worklist cycle: tick the busy tiles in ascending index order
    /// (order across tiles is unobservable — tiles only interact through
    /// NoC deliveries, which land no earlier than the next cycle — but a
    /// deterministic order keeps runs reproducible), advance the NoC, and
    /// unpark every tile that received a delivery.
    fn tick_scheduled(&mut self) {
        if self.fault_next < self.fault_plan.len() {
            self.apply_due_faults();
        }
        let now = self.now;
        debug_assert!(self.sched.scratch.is_empty());
        let mut cur = std::mem::take(&mut self.sched.run_list);
        for &i in &cur {
            let tile = &mut self.tiles[i as usize];
            let wake = tile.tick(now, &mut self.noc);
            let idle_if_parked = wake != Wake::Parked || tile.idle();
            if let Some(tt) = self.tile_telem.as_deref_mut() {
                tt.note(i as usize, now, wake);
            }
            self.sched.note(i, wake, idle_if_parked);
        }
        cur.clear();
        self.sched.run_list = std::mem::replace(&mut self.sched.scratch, cur);
        self.noc.tick(now);
        let sched = &mut self.sched;
        let cfg = &self.cfg;
        self.noc.for_each_delivered(|c| sched.unpark(cfg.index_of(c) as u32));
        self.now += 1;
    }

    /// Everything drained and the host script finished?
    pub fn idle(&self) -> bool {
        self.noc.is_idle() && self.tiles.iter().all(|t| t.idle())
    }

    /// The per-cycle quiesce probe of the full-scan reference loop: a fast
    /// O(1) reject (NoC work counters, then the tile last seen busy),
    /// deferring to the canonical [`Soc::idle`] only on the rare cycle
    /// where the hinted tile drains — so the steady-state cost is
    /// O(active) rather than O(tiles) every cycle, while idleness has
    /// exactly one definition.
    fn quiesced(&mut self) -> bool {
        if !self.noc.is_idle() {
            return false;
        }
        if let Some(t) = self.tiles.get(self.busy_tile_hint) {
            if !t.idle() {
                return false;
            }
        }
        if self.idle() {
            return true;
        }
        // The hinted tile drained but another is still busy: re-aim.
        if let Some(i) = self.tiles.iter().position(|t| !t.idle()) {
            self.busy_tile_hint = i;
        }
        false
    }

    /// Worklist quiescence, equivalent to [`Soc::idle`] in O(active): a
    /// live sleeper or a blocked parked tile is non-idle by construction,
    /// so only the (small) run list needs the canonical per-tile check.
    fn wl_quiesced(&self) -> bool {
        self.noc.is_idle()
            && self.sched.sleepers == 0
            && self.sched.blocked_parked == 0
            && self.sched.run_list.iter().all(|&i| self.tiles[i as usize].idle())
    }

    /// Run until idle; errors out after `max_cycles`.  The budget is
    /// checked uniformly before every cycle, so `run(0)` never advances:
    /// it returns `Ok(0)` on an already-idle SoC and errors otherwise.
    pub fn run(&mut self, max_cycles: u64) -> Result<u64> {
        match self.sched_mode {
            SchedMode::FullScan => self.run_full_scan(max_cycles),
            SchedMode::Worklist => self.run_worklist(max_cycles),
        }
    }

    /// Build the typed quiesce failure with its forensic dump attached.
    /// Every exit path agrees on the headline wording (the first
    /// [`Display`](std::fmt::Display) line is unchanged from the seed).
    #[cold]
    fn quiesce_err(&self, kind: QuiesceKind, max_cycles: u64) -> anyhow::Error {
        QuiesceError { kind, max_cycles, dump: self.forensic_dump(kind) }.into()
    }

    /// Post-mortem for a failed quiesce: which tiles were still alive,
    /// where queued flits sit, the oldest in-flight packet and the hop it
    /// is stalled at, plus a suspected cause.
    fn forensic_dump(&self, kind: QuiesceKind) -> String {
        use std::fmt::Write as _;
        let mut d = String::new();
        let _ = writeln!(d, "--- quiesce watchdog @ cycle {} ---", self.now);
        // Non-idle tiles (capped: one stuck app can strand a whole mesh).
        let busy: Vec<usize> =
            (0..self.tiles.len()).filter(|&i| !self.tiles[i].idle()).collect();
        let _ = writeln!(d, "non-idle tiles: {}", busy.len());
        for &i in busy.iter().take(8) {
            let t = &self.tiles[i];
            let what = match t {
                Tile::Cpu(_) => "cpu: host script unfinished",
                Tile::Mem(_) => "mem: requests in flight",
                Tile::Io(_) | Tile::Empty => "idle-by-definition (bug)",
                Tile::Acc(_) => "acc: core running or socket not quiescent",
            };
            let _ = writeln!(d, "  {:?}: {what}", self.cfg.coord_of(i));
        }
        // Socket-level fault latches (retry exhaustion diagnoses), plus
        // replay-ring forensics wherever the recovery path was exercised:
        // producer rings are on a *different* socket than the consumer that
        // latched, so the replay lines scan every socket, not just faulted
        // ones.
        for t in &self.tiles {
            if let Tile::Acc(a) = t {
                for s in &a.sockets {
                    if let Some(cause) = s.fault() {
                        let _ = writeln!(
                            d,
                            "socket fault: {cause} ({} retries spent)",
                            s.stats.retries
                        );
                    }
                    let p = &s.p2p;
                    if p.window() > 0 && (p.replayed_bytes + p.window_exceeded > 0) {
                        let _ = write!(
                            d,
                            "replay {:?}.{}: window {} B, {} B replayed, {} resume(s) beyond \
                             window;",
                            s.coord,
                            s.slot,
                            p.window(),
                            p.replayed_bytes,
                            p.window_exceeded
                        );
                        for (c, slot, buffered, sent) in p.replay_state() {
                            let _ = write!(d, " ->{c:?}.{slot} {buffered} B kept @ off {sent}");
                        }
                        let _ = writeln!(d);
                    }
                }
            }
        }
        // Fault-injection counters: distinguishes a storm that actually hit
        // traffic (dropped flits explain a lost, unretryable control write)
        // from a hang with no fault signal at all.
        let noc = self.noc.stats_total();
        if noc.dropped_flits + noc.dropped_msgs + noc.drained_worms > 0 {
            let _ = writeln!(
                d,
                "faults: {} flits dropped, {} msgs refused, {} worms drained",
                noc.dropped_flits, noc.dropped_msgs, noc.drained_worms
            );
        }
        // Per-plane router occupancy.
        for plane in Plane::ALL {
            let occ = self.noc.occupied_routers(plane);
            if occ.is_empty() {
                continue;
            }
            let total: u32 = occ.iter().map(|&(_, n)| n).sum();
            let _ = write!(d, "plane {plane:?}: {total} queued flits at");
            for &(c, n) in occ.iter().take(6) {
                let _ = write!(d, " {c:?}x{n}");
            }
            let _ = writeln!(d);
        }
        // The oldest in-flight packet and where it is stuck.
        let stall = self.noc.oldest_stall();
        if let Some((plane, p)) = &stall {
            let _ = writeln!(
                d,
                "oldest stall: plane {plane:?} packet {:?}->{:?}{} waiting at {:?} port \
                 {:?}{} since cycle {} (next hop {:?}{})",
                p.origin,
                p.dest,
                if p.ndests > 1 { " (multicast)" } else { "" },
                p.at,
                p.port,
                if p.in_branch_buf { " [branch buffer]" } else { "" },
                p.arrived,
                p.next,
                if p.next_dead { ", DEAD LINK" } else { "" },
            );
        }
        // Suspected cause, most specific signal first.
        let socket_fault = self.tiles.iter().any(|t| {
            matches!(t, Tile::Acc(a) if a.sockets.iter().any(|s| s.fault().is_some()))
        });
        let window_exceeded = self.tiles.iter().any(|t| {
            matches!(t, Tile::Acc(a) if a.sockets.iter().any(|s| s.p2p.window_exceeded > 0))
        });
        let cause = if socket_fault && window_exceeded {
            "replay window exceeded (a consumer's resume offset fell behind its producer's \
             ring; see replay state above)"
        } else if socket_fault {
            "dead-link blackhole (socket retries exhausted; see socket fault above)"
        } else if matches!(&stall, Some((_, p)) if p.next_dead) {
            "dead-link blackhole (oldest packet's next hop crosses a killed link)"
        } else if self.noc.is_idle() {
            "deadlock (tiles wait on deliveries with nothing in flight)"
        } else if kind == QuiesceKind::Budget {
            "livelock or runaway (traffic still moving when the budget expired)"
        } else {
            "deadlock (in-flight packets can no longer drain)"
        };
        let _ = write!(d, "suspected cause: {cause}");
        d
    }

    /// The full-scan reference loop: every tile, every cycle.
    fn run_full_scan(&mut self, max_cycles: u64) -> Result<u64> {
        let start = self.now;
        while !self.quiesced() {
            if self.now - start >= max_cycles {
                return Err(self.quiesce_err(QuiesceKind::Budget, max_cycles));
            }
            self.tick();
        }
        Ok(self.now - start)
    }

    /// The activity-driven loop: worklist + wake-queue + fast-forward.
    fn run_worklist(&mut self, max_cycles: u64) -> Result<u64> {
        let start = self.now;
        self.sched.reset_all_busy();
        loop {
            self.sched.wake_due(self.now);
            if self.wl_quiesced() {
                return Ok(self.now - start);
            }
            if self.sched.run_list.is_empty() && self.noc.is_idle() {
                // Idle-cycle fast-forward: no tile can run, nothing is in
                // flight, and deliveries only happen when something runs —
                // every cycle up to the next timed wake is provably dead.
                let Some(t) = self.sched.next_wake() else {
                    // Not quiescent, yet nothing can ever wake: the
                    // full-scan loop would burn the whole budget on this
                    // deadlock, so report it the same way.
                    return Err(self.quiesce_err(QuiesceKind::Deadlock, max_cycles));
                };
                // Checked *before* jumping so a blown budget does not
                // advance `now` past it.
                if t - start >= max_cycles {
                    return Err(self.quiesce_err(QuiesceKind::Budget, max_cycles));
                }
                self.now = t;
                self.sched.wake_due(t);
            }
            if self.now - start >= max_cycles {
                return Err(self.quiesce_err(QuiesceKind::Budget, max_cycles));
            }
            self.tick_scheduled();
        }
    }

    /// Collect a statistics report.
    pub fn report(&mut self) -> Report {
        let mut r = Report { cycles: self.now, planes: self.noc.stats(), ..Report::default() };
        for t in &self.tiles {
            match t {
                Tile::Mem(m) => r.mem = m.stats.clone(),
                Tile::Cpu(c) => r.cpu = c.stats.clone(),
                Tile::Acc(a) => {
                    for s in &a.sockets {
                        r.sockets.push((s.acc_id, s.stats.clone()));
                    }
                    r.invocations.extend(a.invocation_log.iter().copied());
                }
                _ => {}
            }
        }
        r.invocations.sort();
        r.sockets.sort_by_key(|(id, _)| *id);
        r
    }

    /// Telemetry snapshot: the per-plane congestion grids plus the
    /// per-tile cycle breakdown, closed at the current cycle (each tile's
    /// busy+sleeping+parked sums to [`Soc::now`]).  `None` unless the
    /// config armed telemetry.  Non-destructive — the run may continue
    /// and snapshot again later.
    pub fn telemetry_report(&self) -> Option<TelemetryReport> {
        let planes = self.noc.plane_telemetry()?;
        let tiles = self.tile_telem.as_deref()?.snapshot(self.now);
        Some(TelemetryReport {
            width: self.cfg.width,
            height: self.cfg.height,
            cycles: self.now,
            planes,
            tiles,
        })
    }

    /// Locate an accelerator id from a `(coord, slot)` pair.
    pub fn acc_at(&self, coord: Coord, slot: u8) -> Result<u16> {
        let ti = self.cfg.index_of(coord);
        self.acc_index
            .iter()
            .position(|&(t, s)| t == ti && s == slot)
            .map(|i| i as u16)
            .ok_or_else(|| anyhow!("no accelerator at {coord:?} slot {slot}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idle_soc(mode: SchedMode) -> Soc {
        let mut soc = Soc::new(SocConfig::small_3x3()).unwrap();
        soc.set_sched_mode(mode);
        soc
    }

    #[test]
    fn run_zero_budget_is_uniform_across_modes() {
        for mode in [SchedMode::FullScan, SchedMode::Worklist] {
            let mut soc = idle_soc(mode);
            assert_eq!(soc.run(0).unwrap(), 0, "{mode:?}: idle SoC, zero budget");
            assert_eq!(soc.now, 0, "{mode:?}: run(0) must not advance a cycle");
            soc.push_host_script(vec![HostOp::Delay(5)]);
            assert!(soc.run(0).is_err(), "{mode:?}: busy SoC, zero budget");
            assert_eq!(soc.now, 0, "{mode:?}: failed run(0) must not advance");
        }
    }

    #[test]
    fn run_on_idle_soc_counts_zero_cycles() {
        for mode in [SchedMode::FullScan, SchedMode::Worklist] {
            let mut soc = idle_soc(mode);
            assert_eq!(soc.run(1000).unwrap(), 0, "{mode:?}");
        }
    }

    #[test]
    fn fast_forward_matches_full_scan_on_host_delays() {
        // A script of pure delays quiesces at the same cycle in both
        // modes, and the worklist mode records the same done_at even
        // though it fast-forwards across the dead cycles.
        let run = |mode: SchedMode| {
            let mut soc = idle_soc(mode);
            soc.push_host_script(vec![
                HostOp::Delay(100),
                HostOp::Delay(1),
                HostOp::Delay(2345),
            ]);
            let cycles = soc.run(100_000).unwrap();
            (cycles, soc.now, soc.report().cpu.done_at)
        };
        let a = run(SchedMode::FullScan);
        let b = run(SchedMode::Worklist);
        assert_eq!(a, b);
        assert_eq!(a.2, Some(100 + 1 + 2345));
    }

    #[test]
    fn worklist_detects_deadlock_instead_of_burning_the_budget() {
        let mut soc = idle_soc(SchedMode::Worklist);
        // An IRQ wait nothing will ever satisfy.
        soc.push_host_script(vec![HostOp::WaitIrqs(vec![0])]);
        let err = soc.run(1_000_000).unwrap_err();
        let qe = err.downcast_ref::<QuiesceError>().expect("typed quiesce error");
        assert_eq!(qe.kind, QuiesceKind::Deadlock, "worklist proves the deadlock");
        assert_eq!(qe.max_cycles, 1_000_000);
        assert!(qe.dump.contains("suspected cause: deadlock"), "{}", qe.dump);
        // Display keeps the seed's headline (scripts grep for it) and
        // appends the dump.
        let text = err.to_string();
        assert!(text.starts_with("SoC did not quiesce within 1000000 cycles"), "{text}");
        assert!(text.contains("quiesce watchdog"), "{text}");
        // The full-scan reference reports the same failure, as a budget
        // exhaustion (it cannot prove deadlock early).
        let mut soc = idle_soc(SchedMode::FullScan);
        soc.push_host_script(vec![HostOp::WaitIrqs(vec![0])]);
        let err2 = soc.run(10_000).unwrap_err();
        let qe2 = err2.downcast_ref::<QuiesceError>().expect("typed quiesce error");
        assert_eq!(qe2.kind, QuiesceKind::Budget);
        assert!(err2.to_string().contains("did not quiesce"), "{err2}");
    }

    #[test]
    fn harvested_tiles_are_depopulated() {
        let mut cfg = SocConfig::paper_3x4();
        let live_before = cfg.acc_sockets().len();
        // Harvest one accelerator tile (validate() keeps the mesh routable).
        let victim = cfg.acc_sockets()[live_before - 1].0;
        cfg.harvest.push(victim);
        let live_after = cfg.acc_sockets().len();
        assert!(live_after < live_before);
        let soc = Soc::new(cfg).unwrap();
        assert!(matches!(soc.tiles[soc.cfg.index_of(victim)], Tile::Empty));
        assert_eq!(soc.acc_count(), live_after);
        assert!(soc.noc.route_table().router_dead(victim));
    }

    #[test]
    fn fault_plan_fires_during_run_and_watchdog_dumps() {
        use crate::fault::FaultEvent;
        let mut soc = Soc::new(SocConfig::small_3x3()).unwrap();
        soc.set_sched_mode(SchedMode::FullScan);
        // Cut the mem tile's column links mid-run so DMA responses die.
        let mem = soc.cfg.mem_tile();
        soc.set_fault_plan(FaultPlan::new(vec![FaultEvent {
            cycle: 1,
            kind: FaultKind::Router { at: mem },
        }]));
        soc.push_host_script(vec![HostOp::WaitIrqs(vec![0])]);
        let err = soc.run(500).unwrap_err();
        let qe = err.downcast_ref::<QuiesceError>().expect("typed quiesce error");
        assert!(qe.dump.contains("non-idle tiles"), "{}", qe.dump);
        // The router kill happened: routes toward mem are dead.
        assert!(soc.noc.route_table().router_dead(mem));
    }

    #[test]
    fn budget_exhaustion_is_cycle_identical_across_modes() {
        // A 100-cycle delay against a 40-cycle budget: both modes must
        // fail, and neither may run past the budget.
        for mode in [SchedMode::FullScan, SchedMode::Worklist] {
            let mut soc = idle_soc(mode);
            soc.push_host_script(vec![HostOp::Delay(100)]);
            assert!(soc.run(40).is_err(), "{mode:?}");
            assert!(soc.now <= 40, "{mode:?}: ran past the budget to {}", soc.now);
        }
    }
}
