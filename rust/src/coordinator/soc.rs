//! SoC assembly and the global simulation loop.

use anyhow::{anyhow, ensure, Result};

use crate::accel::{AccCore, DpCall};
use crate::config::{SocConfig, TileKind};
use crate::noc::{Coord, MeshParams, Noc};
use crate::socket::Socket;
use crate::tile::{AccTile, CpuTile, HostOp, IoTile, MemTile, Tile};

use super::stats::Report;

/// The simulated SoC: tiles + multi-plane NoC + the cycle loop.
pub struct Soc {
    /// Configuration this SoC was built from.
    pub cfg: SocConfig,
    /// The six-plane NoC.
    pub noc: Noc,
    /// Tiles, row-major.
    pub tiles: Vec<Tile>,
    /// Current cycle.
    pub now: u64,
    /// Accelerator id -> (tile index, slot).
    acc_index: Vec<(usize, u8)>,
    /// Index of the tile most recently observed busy: the quiesce probe
    /// checks it first, so the per-cycle idle test in [`Soc::run`] is O(1)
    /// while anything is still running instead of a full tile scan.
    busy_tile_hint: usize,
}

impl Soc {
    /// Build an idle SoC from a validated configuration.
    pub fn new(cfg: SocConfig) -> Result<Self> {
        cfg.validate()?;
        let mut noc = Noc::new(MeshParams {
            width: cfg.width,
            height: cfg.height,
            flit_bytes: cfg.flit_bytes(),
            queue_depth: cfg.noc.queue_depth,
        });
        noc.set_tick_mode(cfg.noc.tick_mode);
        let mut tiles = Vec::with_capacity(cfg.tiles.len());
        let mut acc_index = Vec::new();
        let mut next_acc: u16 = 0;
        for (i, kind) in cfg.tiles.iter().enumerate() {
            let coord = cfg.coord_of(i);
            tiles.push(match kind {
                TileKind::Cpu => {
                    Tile::Cpu(CpuTile::new(coord, cfg.mem_tile(), cfg.host, cfg.mem.line_bytes))
                }
                TileKind::Mem => Tile::Mem(MemTile::new(coord, cfg.mem)),
                TileKind::Io => Tile::Io(IoTile::new(coord)),
                TileKind::Acc { accs } => {
                    let t = AccTile::new(coord, *accs, next_acc, &cfg);
                    for s in 0..*accs {
                        acc_index.push((i, s));
                    }
                    next_acc += *accs as u16;
                    Tile::Acc(t)
                }
                TileKind::Empty => Tile::Empty,
            });
        }
        Ok(Self { cfg, noc, tiles, now: 0, acc_index, busy_tile_hint: 0 })
    }

    /// Number of accelerator sockets.
    pub fn acc_count(&self) -> usize {
        self.acc_index.len()
    }

    /// `(tile coord, slot)` of accelerator `acc`.
    pub fn acc_location(&self, acc: u16) -> (Coord, u8) {
        let (t, s) = self.acc_index[acc as usize];
        (self.cfg.coord_of(t), s)
    }

    /// Mutable access to the memory tile.
    pub fn mem_mut(&mut self) -> &mut MemTile {
        let i = self.cfg.index_of(self.cfg.mem_tile());
        match &mut self.tiles[i] {
            Tile::Mem(m) => m,
            _ => unreachable!("validated config"),
        }
    }

    /// Mutable access to the CPU tile.
    pub fn cpu_mut(&mut self) -> &mut CpuTile {
        let i = self.cfg.index_of(self.cfg.cpu_tile());
        match &mut self.tiles[i] {
            Tile::Cpu(c) => c,
            _ => unreachable!("validated config"),
        }
    }

    /// Mutable access to accelerator `acc`'s socket, core and PLM.
    pub fn acc_mut(&mut self, acc: u16) -> (&mut Socket, &mut AccCore, &mut Vec<u8>) {
        let (t, s) = self.acc_index[acc as usize];
        match &mut self.tiles[t] {
            Tile::Acc(a) => {
                let s = s as usize;
                // Split borrows across the parallel vectors.
                (&mut a.sockets[s], &mut a.cores[s], &mut a.plms[s])
            }
            _ => unreachable!("acc_index points at Acc tiles"),
        }
    }

    /// Backdoor: load an accelerator program + datapath descriptors and map
    /// its virtual buffer linearly over the whole DRAM (identity mapping;
    /// scattered mappings are exercised at the TLB unit level).
    pub fn setup_acc(
        &mut self,
        acc: u16,
        program: Vec<crate::accel::Instr>,
        dp_calls: Vec<DpCall>,
    ) {
        let dram = self.cfg.mem.dram_bytes;
        let (socket, core, _) = self.acc_mut(acc);
        socket.tlb.map_linear(0, dram);
        core.load_program(program);
        core.dp_calls = dp_calls;
    }

    /// Backdoor: write initial data into DRAM.
    pub fn write_mem(&mut self, addr: u64, data: &[u8]) {
        self.mem_mut().write_backdoor(addr, data);
    }

    /// Backdoor: read DRAM.
    pub fn read_mem(&mut self, addr: u64, len: usize) -> Vec<u8> {
        self.mem_mut().read_backdoor(addr, len).to_vec()
    }

    /// Append host operations to the CPU script.
    pub fn push_host_script(&mut self, ops: Vec<HostOp>) {
        self.cpu_mut().push_script(ops);
    }

    /// Advance one cycle.
    pub fn tick(&mut self) {
        let now = self.now;
        for t in &mut self.tiles {
            t.tick(now, &mut self.noc);
        }
        self.noc.tick(now);
        self.now += 1;
    }

    /// Everything drained and the host script finished?
    pub fn idle(&self) -> bool {
        self.noc.is_idle() && self.tiles.iter().all(|t| t.idle())
    }

    /// The per-cycle quiesce probe behind [`Soc::run`]: a fast O(1) reject
    /// (NoC work counters, then the tile last seen busy), deferring to the
    /// canonical [`Soc::idle`] only on the rare cycle where the hinted
    /// tile drains — so the steady-state cost is O(active) rather than
    /// O(tiles) every cycle, while idleness has exactly one definition.
    fn quiesced(&mut self) -> bool {
        if !self.noc.is_idle() {
            return false;
        }
        if let Some(t) = self.tiles.get(self.busy_tile_hint) {
            if !t.idle() {
                return false;
            }
        }
        if self.idle() {
            return true;
        }
        // The hinted tile drained but another is still busy: re-aim.
        if let Some(i) = self.tiles.iter().position(|t| !t.idle()) {
            self.busy_tile_hint = i;
        }
        false
    }

    /// Run until idle; errors out after `max_cycles`.
    pub fn run(&mut self, max_cycles: u64) -> Result<u64> {
        let start = self.now;
        // Let the first ops enter the system before testing idleness.
        self.tick();
        while !self.quiesced() {
            self.tick();
            ensure!(
                self.now - start < max_cycles,
                "SoC did not quiesce within {max_cycles} cycles (deadlock or runaway)"
            );
        }
        Ok(self.now - start)
    }

    /// Collect a statistics report.
    pub fn report(&mut self) -> Report {
        let mut r = Report { cycles: self.now, planes: self.noc.stats(), ..Report::default() };
        for t in &self.tiles {
            match t {
                Tile::Mem(m) => r.mem = m.stats.clone(),
                Tile::Cpu(c) => r.cpu = c.stats.clone(),
                Tile::Acc(a) => {
                    for s in &a.sockets {
                        r.sockets.push((s.acc_id, s.stats.clone()));
                    }
                    r.invocations.extend(a.invocation_log.iter().copied());
                }
                _ => {}
            }
        }
        r.invocations.sort();
        r.sockets.sort_by_key(|(id, _)| *id);
        r
    }

    /// Locate an accelerator id from a `(coord, slot)` pair.
    pub fn acc_at(&self, coord: Coord, slot: u8) -> Result<u16> {
        let ti = self.cfg.index_of(coord);
        self.acc_index
            .iter()
            .position(|&(t, s)| t == ti && s == slot)
            .map(|i| i as u16)
            .ok_or_else(|| anyhow!("no accelerator at {coord:?} slot {slot}"))
    }
}
