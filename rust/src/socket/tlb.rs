//! Socket TLB: accelerator virtual buffer -> global physical addresses.
//!
//! ESP allocates each accelerator one contiguous *virtual* buffer scattered
//! across large physical pages; the socket's TLB translates per access.  We
//! model a small fully-associative LRU TLB over a per-accelerator page
//! table (set up by the host before the invocation).  A hit costs nothing
//! extra; a miss charges a fixed page-table-walk latency to the transfer
//! that triggered it.

use anyhow::{ensure, Result};

/// Per-accelerator page table + TLB.
#[derive(Debug, Clone)]
pub struct Tlb {
    /// Physical base address of each virtual page (index = vpage).
    page_table: Vec<u64>,
    /// Page size, bytes (power of two).
    page_bytes: u32,
    /// TLB capacity, entries.
    entries: usize,
    /// Cached vpage numbers, most recent last.
    cached: Vec<u32>,
    /// Cycles charged per miss (page-table walk in memory).
    pub miss_penalty: u32,
    /// Stats.
    pub hits: u64,
    /// Stats.
    pub misses: u64,
}

impl Tlb {
    /// Empty TLB with no mappings.
    pub fn new(entries: u16, page_bytes: u32, miss_penalty: u32) -> Self {
        assert!(page_bytes.is_power_of_two());
        Self {
            page_table: Vec::new(),
            page_bytes,
            entries: entries.max(1) as usize,
            cached: Vec::new(),
            miss_penalty,
            hits: 0,
            misses: 0,
        }
    }

    /// Install the page table for this invocation (host-side setup).
    pub fn set_page_table(&mut self, phys_page_bases: Vec<u64>) {
        self.page_table = phys_page_bases;
        self.cached.clear();
    }

    /// Map a contiguous virtual buffer of `len` bytes starting at physical
    /// `phys_base` (convenience for tests and simple launches).
    pub fn map_linear(&mut self, phys_base: u64, len: u64) {
        let pages = len.div_ceil(self.page_bytes as u64);
        self.set_page_table(
            (0..pages).map(|p| phys_base + p * self.page_bytes as u64).collect(),
        );
    }

    /// Translate `vaddr`; returns `(physical address, extra cycles)` where
    /// the extra cycles are the miss penalty (0 on a hit).
    pub fn translate(&mut self, vaddr: u64) -> Result<(u64, u32)> {
        let vpage = (vaddr / self.page_bytes as u64) as u32;
        let off = vaddr % self.page_bytes as u64;
        ensure!(
            (vpage as usize) < self.page_table.len(),
            "vaddr {vaddr:#x} beyond mapped buffer ({} pages)",
            self.page_table.len()
        );
        let phys = self.page_table[vpage as usize] + off;
        if let Some(pos) = self.cached.iter().position(|&p| p == vpage) {
            self.cached.remove(pos);
            self.cached.push(vpage); // refresh LRU
            self.hits += 1;
            Ok((phys, 0))
        } else {
            if self.cached.len() >= self.entries {
                self.cached.remove(0); // evict LRU
            }
            self.cached.push(vpage);
            self.misses += 1;
            Ok((phys, self.miss_penalty))
        }
    }

    /// Bytes remaining in the page containing `vaddr` (transfers must not
    /// cross physical pages in one NoC request).
    pub fn page_remaining(&self, vaddr: u64) -> u32 {
        (self.page_bytes as u64 - (vaddr % self.page_bytes as u64)) as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_map_translates() {
        let mut t = Tlb::new(4, 4096, 50);
        t.map_linear(0x10000, 3 * 4096);
        let (p, miss) = t.translate(0).unwrap();
        assert_eq!(p, 0x10000);
        assert_eq!(miss, 50, "first access misses");
        let (p, miss) = t.translate(100).unwrap();
        assert_eq!(p, 0x10064);
        assert_eq!(miss, 0, "same page hits");
        let (p, _) = t.translate(4096 + 8).unwrap();
        assert_eq!(p, 0x11008);
    }

    #[test]
    fn scattered_pages() {
        let mut t = Tlb::new(4, 4096, 50);
        t.set_page_table(vec![0x8000, 0x2000, 0xF000]);
        assert_eq!(t.translate(0).unwrap().0, 0x8000);
        assert_eq!(t.translate(4096).unwrap().0, 0x2000);
        assert_eq!(t.translate(2 * 4096 + 4095).unwrap().0, 0xFFFF);
    }

    #[test]
    fn out_of_range_errors() {
        let mut t = Tlb::new(4, 4096, 50);
        t.map_linear(0, 4096);
        assert!(t.translate(4096).is_err());
    }

    #[test]
    fn lru_eviction_counts_misses() {
        let mut t = Tlb::new(2, 4096, 50);
        t.map_linear(0, 4 * 4096);
        t.translate(0).unwrap(); // miss, cache {0}
        t.translate(4096).unwrap(); // miss, cache {0,1}
        t.translate(0).unwrap(); // hit, refresh
        t.translate(2 * 4096).unwrap(); // miss, evicts 1
        let (_, m) = t.translate(4096).unwrap(); // miss again
        assert_eq!(m, 50);
        assert_eq!(t.hits, 1);
        assert_eq!(t.misses, 4);
    }

    #[test]
    fn page_remaining() {
        let t = Tlb::new(2, 4096, 0);
        assert_eq!(t.page_remaining(0), 4096);
        assert_eq!(t.page_remaining(4000), 96);
    }
}
