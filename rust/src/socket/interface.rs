//! The updated ESP accelerator interface (Fig. 3 of the paper).
//!
//! Four independent *latency-insensitive* channels connect the accelerator
//! to its socket: **read control**, **read data**, **write control**, and
//! **write data**.  Control channels carry length, word size and the
//! address relative to the accelerator's virtual buffer; the paper adds a
//! `user` field to each control channel:
//!
//! - read channel `user`:  0 = DMA from memory, `k` in 1..N = P2P pull from
//!   the accelerator at index `k` of the socket's source lookup table
//!   (virtualized tile coordinates);
//! - write channel `user`: 0 = DMA to memory, 1 = unicast P2P, `n` in
//!   2..N = multicast to `n` consumers.
//!
//! This gives *per-burst* control over the communication mode — the
//! "flexible P2P" enhancement — instead of one mode per invocation.
//!
//! Channels are modelled as bounded queues with valid/ready semantics: a
//! full queue deasserts `ready` (the producer stalls), an empty queue
//! deasserts `valid` (the consumer stalls), exactly the latency-insensitive
//! contract of the RTL interface.

use std::collections::VecDeque;

/// Transfer direction selector used by the ISA and programs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DmaDir {
    /// Memory/P2P -> PLM.
    Read,
    /// PLM -> memory/P2P/multicast.
    Write,
}

/// Read-control channel beat.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReadCtrl {
    /// Offset within the accelerator's virtual buffer.
    pub vaddr: u64,
    /// Transfer length in bytes.
    pub len: u32,
    /// Word size in bytes (log stride; 4 for f32 streams).
    pub word_bytes: u8,
    /// 0 = memory DMA; 1..N = P2P source index (socket LUT).
    pub user: u16,
    /// Destination offset in the accelerator's PLM.
    pub plm_addr: u32,
    /// Transaction tag assigned by the socket at acceptance.
    pub tag: u32,
}

/// Write-control channel beat.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WriteCtrl {
    /// Offset within the accelerator's virtual buffer.
    pub vaddr: u64,
    /// Transfer length in bytes.
    pub len: u32,
    /// Word size in bytes.
    pub word_bytes: u8,
    /// 0 = memory DMA; 1 = unicast P2P; n>=2 = multicast to n consumers.
    pub user: u16,
    /// Source offset in the accelerator's PLM.
    pub plm_addr: u32,
    /// Transaction tag assigned by the socket at acceptance.
    pub tag: u32,
}

/// A bounded latency-insensitive channel.
#[derive(Debug)]
pub struct LiChannel<T> {
    q: VecDeque<T>,
    cap: usize,
}

impl<T> LiChannel<T> {
    /// Channel with capacity `cap` beats.
    pub fn new(cap: usize) -> Self {
        Self { q: VecDeque::with_capacity(cap), cap }
    }

    /// `ready`: can the producer push this cycle?
    pub fn ready(&self) -> bool {
        self.q.len() < self.cap
    }

    /// `valid`: does the consumer see a beat this cycle?
    pub fn valid(&self) -> bool {
        !self.q.is_empty()
    }

    /// Push a beat; returns false (and drops nothing) when not ready.
    pub fn push(&mut self, v: T) -> bool {
        if !self.ready() {
            return false;
        }
        self.q.push_back(v);
        true
    }

    /// Pop the front beat.
    pub fn pop(&mut self) -> Option<T> {
        self.q.pop_front()
    }

    /// Peek the front beat.
    pub fn front(&self) -> Option<&T> {
        self.q.front()
    }

    /// Beats queued.
    pub fn len(&self) -> usize {
        self.q.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ready_valid_contract() {
        let mut c: LiChannel<u32> = LiChannel::new(2);
        assert!(c.ready() && !c.valid());
        assert!(c.push(1));
        assert!(c.push(2));
        assert!(!c.ready(), "full channel deasserts ready");
        assert!(!c.push(3), "push on full channel is refused");
        assert_eq!(c.pop(), Some(1));
        assert!(c.ready());
        assert_eq!(c.pop(), Some(2));
        assert!(!c.valid());
        assert_eq!(c.pop(), None);
    }

    #[test]
    fn fifo_order() {
        let mut c: LiChannel<ReadCtrl> = LiChannel::new(4);
        for i in 0..3u32 {
            c.push(ReadCtrl {
                vaddr: i as u64,
                len: 64,
                word_bytes: 4,
                user: 0,
                plm_addr: 0,
                tag: i,
            });
        }
        assert_eq!(c.pop().unwrap().tag, 0);
        assert_eq!(c.front().unwrap().tag, 1);
        assert_eq!(c.len(), 2);
    }
}
