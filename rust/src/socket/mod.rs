//! The ESP accelerator socket.
//!
//! The socket decouples the accelerator from the SoC and provides the
//! platform services of Fig. 2: DMA, address translation (TLB),
//! configuration registers, interrupts — plus the paper's enhancements:
//! per-burst selection of memory vs P2P vs multicast through the `user`
//! fields of the latency-insensitive interface, length-carrying P2P
//! requests, and producer-side multicast aggregation.
//!
//! Dataflow per accepted **read** control beat:
//! - `user == 0`: translate, split at page boundaries, issue
//!   [`MsgKind::DmaReadReq`]s on the DMA-request plane; responses fill the
//!   PLM and complete the tag.
//! - `user == k`: resolve `(producer, slot)` through the source LUT and
//!   send a length-carrying [`MsgKind::P2pReq`]; matching
//!   [`MsgKind::P2pData`] payloads fill the PLM in request order.
//!
//! Per accepted **write** control beat:
//! - `user == 0`: copy the PLM region and issue `DmaWriteReq`s;
//!   acknowledgements complete the tag.
//! - `user == n >= 1`: hand the burst to the [`p2p::P2pUnit`], which sends
//!   one (multi-destination when `n >= 2`) `P2pData` message once `n`
//!   consumers have pulled — the tag completes at send time.

pub mod interface;
pub mod p2p;
pub mod regs;
pub mod tlb;

use std::collections::{HashMap, VecDeque};
use std::sync::Arc;

use crate::config::AccConfig;
use crate::noc::{Coord, Message, MsgKind, Plane, RESUME_NONE};
use crate::sched::Wake;

pub use interface::{DmaDir, LiChannel, ReadCtrl, WriteCtrl};
pub use p2p::{cons_participates, P2pUnit};
pub use regs::{make_reg, pack_src, split_reg, Regs, Status};
pub use tlb::Tlb;

/// Sentinel tag meaning "no transaction" (always reported done).
pub const TAG_NONE: u32 = u32::MAX;

/// Dense completion bitset over per-invocation tags.
#[derive(Debug, Default)]
struct TagSet {
    words: Vec<u64>,
}

impl TagSet {
    #[inline]
    fn insert(&mut self, tag: u32) {
        let w = (tag / 64) as usize;
        if w >= self.words.len() {
            self.words.resize(w + 1, 0);
        }
        self.words[w] |= 1 << (tag % 64);
    }

    #[inline]
    fn contains(&self, tag: u32) -> bool {
        let w = (tag / 64) as usize;
        self.words.get(w).is_some_and(|x| x & (1 << (tag % 64)) != 0)
    }

    fn clear(&mut self) {
        self.words.clear();
    }
}

/// Socket statistics.
#[derive(Debug, Default, Clone)]
pub struct SocketStats {
    /// Bytes read from memory via DMA.
    pub dma_read_bytes: u64,
    /// Bytes written to memory via DMA.
    pub dma_write_bytes: u64,
    /// Bytes received over P2P.
    pub p2p_read_bytes: u64,
    /// Bytes sent over P2P/multicast (per destination).
    pub p2p_write_bytes: u64,
    /// Read/write control beats accepted.
    pub bursts: u64,
    /// Sub-requests re-sent after a response timeout (degraded mode only;
    /// always 0 while `retry_timeout == 0`).
    pub retries: u64,
    /// Stale bytes/responses dropped: duplicate answers to retried
    /// requests, plus (with the replay window armed) P2P chunks whose
    /// stream offset is gapped or already-delivered — dropping them is
    /// what keeps a recovered stream exactly in order.
    pub stale_drops: u64,
    /// Bytes retransmitted from the producer-side replay ring (always 0
    /// while `replay_window == 0`; not counted in `p2p_write_bytes`).
    pub replayed_bytes: u64,
}

/// An outstanding P2P pull on the consumer side.
#[derive(Debug)]
struct P2pRead {
    tag: u32,
    plm_addr: u32,
    len: u32,
    received: u32,
    /// Stream offset (bytes pulled from this producer before this txn):
    /// a stalled re-request resumes at exactly `base + received`.
    base: u64,
    /// Retry bookkeeping (meaningful only when `retry_timeout > 0`):
    /// re-request deadline (`u64::MAX` = retry off or given up), number of
    /// re-requests sent, and bytes seen at the last progress check — a
    /// stream that keeps flowing never times out.
    deadline: u64,
    tries: u32,
    last_seen: u32,
}

/// One in-flight DMA sub-request armed for bounded retry: the cloned
/// message is re-sent when its response deadline passes, up to
/// `max_retries` times, after which the socket latches a fault.
#[derive(Debug)]
struct RetryEntry {
    wire: u32,
    deadline: u64,
    tries: u32,
    plane: Plane,
    msg: Message,
}

/// The accelerator socket for one `(tile, slot)`.
pub struct Socket {
    /// Tile coordinate.
    pub coord: Coord,
    /// Socket slot on the tile (0 or 1).
    pub slot: u8,
    /// Global accelerator id (IRQ payload).
    pub acc_id: u16,
    cfg: AccConfig,
    mem_tile: Coord,
    cpu_tile: Coord,
    mcast_capacity: usize,
    /// Configuration registers (written by the host over the misc plane).
    pub regs: Regs,
    /// Address translation for the accelerator's virtual buffer.
    pub tlb: Tlb,
    /// Read-control LI channel (core -> socket).
    rd_ctrl: LiChannel<ReadCtrl>,
    /// Write-control LI channel (core -> socket).
    wr_ctrl: LiChannel<WriteCtrl>,
    next_tag: u32,
    next_wire: u32,
    /// Completion scoreboard, indexed by tag (tags are dense per
    /// invocation, so a bitset beats hashing on the hot CDMA path).
    done: TagSet,
    /// Memory-read subrequests: wire tag -> (txn tag, plm offset, len).
    mem_rd_sub: HashMap<u32, (u32, u32, u32)>,
    /// Memory-write subrequests: wire tag -> txn tag.
    mem_wr_sub: HashMap<u32, u32>,
    /// Outstanding bytes per read txn.
    rd_remaining: HashMap<u32, u32>,
    /// Outstanding acks per write txn.
    wr_remaining: HashMap<u32, u32>,
    /// Sub-requests armed for bounded retry (empty while
    /// `retry_timeout == 0`: the healthy path never touches this).
    retry_q: Vec<RetryEntry>,
    /// Latched blackhole diagnosis: set when a request exhausts its
    /// retries, after which the socket parks and the quiesce watchdog
    /// quotes this string as the failure cause.
    fault: Option<String>,
    /// Consumer-side P2P pulls, FIFO per (producer, slot).
    p2p_rd: HashMap<(Coord, u8), VecDeque<P2pRead>>,
    /// Cumulative bytes requested per producer this invocation (stream
    /// offsets for resume-carrying re-requests).
    p2p_rd_pos: HashMap<(Coord, u8), u64>,
    /// Outstanding consumer-side pulls (cheap quiescence check).
    p2p_rd_outstanding: u32,
    /// Producer-side P2P/multicast unit.
    pub p2p: P2pUnit,
    /// Messages delayed by TLB-walk penalties: (ready cycle, plane, msg).
    delayed: Vec<(u64, Plane, Message)>,
    out: Vec<(Plane, Message)>,
    /// Statistics.
    pub stats: SocketStats,
}

impl Socket {
    /// Build a socket.
    pub fn new(
        coord: Coord,
        slot: u8,
        acc_id: u16,
        cfg: AccConfig,
        mem_tile: Coord,
        cpu_tile: Coord,
        mcast_capacity: usize,
    ) -> Self {
        let tlb = Tlb::new(cfg.tlb_entries, cfg.page_bytes, 0);
        let replay_window = cfg.replay_window;
        Self {
            coord,
            slot,
            acc_id,
            cfg,
            mem_tile,
            cpu_tile,
            mcast_capacity,
            regs: Regs::default(),
            tlb,
            rd_ctrl: LiChannel::new(4),
            wr_ctrl: LiChannel::new(4),
            next_tag: 0,
            next_wire: 0,
            done: TagSet::default(),
            mem_rd_sub: HashMap::new(),
            mem_wr_sub: HashMap::new(),
            rd_remaining: HashMap::new(),
            wr_remaining: HashMap::new(),
            retry_q: Vec::new(),
            fault: None,
            p2p_rd: HashMap::new(),
            p2p_rd_pos: HashMap::new(),
            p2p_rd_outstanding: 0,
            p2p: P2pUnit::with_window(replay_window),
            delayed: Vec::new(),
            out: Vec::new(),
            stats: SocketStats::default(),
        }
    }

    /// Set the TLB miss penalty (page-table walk cost; usually the memory
    /// round-trip latency).
    pub fn set_tlb_miss_penalty(&mut self, cycles: u32) {
        self.tlb.miss_penalty = cycles;
    }

    fn alloc_tag(&mut self) -> u32 {
        let t = self.next_tag;
        self.next_tag += 1;
        t
    }

    fn alloc_wire(&mut self) -> u32 {
        let t = self.next_wire;
        self.next_wire += 1;
        t
    }

    /// Submit a read burst (IDMA read).  Returns the tag, or `None` when
    /// the read-control channel is full (the core retries next cycle).
    pub fn submit_read(&mut self, vaddr: u64, len: u32, user: u16, plm_addr: u32) -> Option<u32> {
        if !self.rd_ctrl.ready() {
            return None;
        }
        assert!(len <= self.cfg.max_burst_bytes, "burst {len} exceeds max");
        assert!(plm_addr + len <= self.cfg.plm_bytes, "read overflows PLM");
        let tag = self.alloc_tag();
        self.rd_ctrl.push(ReadCtrl { vaddr, len, word_bytes: 4, user, plm_addr, tag });
        Some(tag)
    }

    /// Submit a write burst (IDMA write).  Returns the tag, or `None` when
    /// the write-control channel is full.
    pub fn submit_write(&mut self, vaddr: u64, len: u32, user: u16, plm_addr: u32) -> Option<u32> {
        if !self.wr_ctrl.ready() {
            return None;
        }
        assert!(len <= self.cfg.max_burst_bytes, "burst {len} exceeds max");
        assert!(plm_addr + len <= self.cfg.plm_bytes, "write overflows PLM");
        let tag = self.alloc_tag();
        self.wr_ctrl.push(WriteCtrl { vaddr, len, word_bytes: 4, user, plm_addr, tag });
        Some(tag)
    }

    /// Is transaction `tag` complete?  [`TAG_NONE`] is always complete.
    pub fn is_done(&self, tag: u32) -> bool {
        tag == TAG_NONE || self.done.contains(tag)
    }

    /// Any DMA/P2P activity still outstanding?
    pub fn quiescent(&self) -> bool {
        self.rd_ctrl.is_empty()
            && self.wr_ctrl.is_empty()
            && self.rd_remaining.is_empty()
            && self.wr_remaining.is_empty()
            && self.p2p_rd_outstanding == 0
            && self.p2p.pending_bursts() == 0
            && self.delayed.is_empty()
    }

    /// Reset per-invocation state (called on a new CMD start).
    pub fn reset_invocation(&mut self) {
        self.done.clear();
        self.next_tag = 0;
        self.p2p.reset();
        self.p2p_rd.clear();
        self.p2p_rd_pos.clear();
        self.p2p_rd_outstanding = 0;
        self.retry_q.clear();
    }

    /// The latched blackhole diagnosis, if a request exhausted its retries.
    pub fn fault(&self) -> Option<&str> {
        self.fault.as_deref()
    }

    /// Would a tick do anything right now?  (Fast path for idle sockets;
    /// message handling and invocation starts are driven by the tile.)
    pub fn needs_tick(&self) -> bool {
        !self.rd_ctrl.is_empty()
            || !self.wr_ctrl.is_empty()
            || self.p2p.pending_bursts() > 0
            || !self.delayed.is_empty()
            || !self.out.is_empty()
            || (self.cfg.retry_timeout > 0
                && self.fault.is_none()
                && (!self.retry_q.is_empty() || self.p2p_rd_outstanding > 0))
    }

    /// Handle a NoC message addressed to this socket.  `plm` is the
    /// accelerator's private local memory.
    pub fn handle_msg(&mut self, msg: &Message, plm: &mut [u8]) {
        match msg.kind {
            MsgKind::DmaReadRsp { tag, slot } if slot == self.slot => {
                let Some(&(txn, plm_addr, len)) = self.mem_rd_sub.get(&tag) else {
                    // Duplicate answer to a retried read (the original and
                    // the re-sent request both got through): drop it.  On a
                    // healthy mesh an unknown sub-tag is a protocol bug.
                    assert!(self.cfg.retry_timeout > 0, "unknown DMA read sub-tag");
                    self.stats.stale_drops += 1;
                    return;
                };
                self.mem_rd_sub.remove(&tag);
                self.clear_retry(tag);
                assert_eq!(msg.payload.len() as u32, len, "short DMA read");
                plm[plm_addr as usize..(plm_addr + len) as usize]
                    .copy_from_slice(&msg.payload);
                self.stats.dma_read_bytes += len as u64;
                let rem = self.rd_remaining.get_mut(&txn).expect("txn");
                *rem -= len;
                if *rem == 0 {
                    self.rd_remaining.remove(&txn);
                    self.done.insert(txn);
                }
            }
            MsgKind::DmaWriteAck { tag, slot } if slot == self.slot => {
                let Some(txn) = self.mem_wr_sub.remove(&tag) else {
                    // Duplicate ack to a retried write sub-request: drop it.
                    assert!(self.cfg.retry_timeout > 0, "unknown write ack");
                    self.stats.stale_drops += 1;
                    return;
                };
                self.clear_retry(tag);
                let rem = self.wr_remaining.get_mut(&txn).expect("unknown write txn");
                *rem -= 1;
                if *rem == 0 {
                    self.wr_remaining.remove(&txn);
                    self.done.insert(txn);
                }
            }
            MsgKind::P2pReq { len, prod_slot, cons_slot, resume } if prod_slot == self.slot => {
                self.p2p.on_request(msg.src, cons_slot, len, resume);
            }
            MsgKind::P2pData { seq, prod_slot } => {
                if !cons_participates(&msg.dests, msg.cons_slots, self.coord, self.slot) {
                    return;
                }
                let key = (msg.src, prod_slot);
                // With the replay window armed, `seq` carries the payload's
                // stream offset; the legacy path fills pulls in arrival
                // order and must stay byte-identical.
                let offset_tagged = self.cfg.replay_window > 0;
                let mut moff = seq as u64;
                let q = self.p2p_rd.entry(key).or_default();
                let mut off = 0usize;
                while off < msg.payload.len() {
                    let Some(txn) = q.front_mut() else {
                        if self.cfg.retry_timeout > 0 || offset_tagged {
                            // Over-delivery from a re-requested pull whose
                            // original data also arrived: drop the excess.
                            self.stats.stale_drops += (msg.payload.len() - off) as u64;
                            break;
                        }
                        panic!(
                            "P2P data beyond outstanding requests at {:?}.{} from {:?}",
                            self.coord, self.slot, key
                        );
                    };
                    if offset_tagged {
                        let expect = txn.base + txn.received as u64;
                        if moff > expect {
                            // A gap: an earlier chunk was lost (or is
                            // straggling on a longer post-reroute path).
                            // Taking these bytes would mis-assemble the
                            // stream, so drop them — the stalled pull's
                            // re-request resumes at `expect` and the
                            // producer's ring replays the gap in order.
                            self.stats.stale_drops += (msg.payload.len() - off) as u64;
                            break;
                        }
                        if moff < expect {
                            // Stale overlap: bytes a replay (or the late
                            // original it duplicated) already delivered.
                            let skip = ((expect - moff) as usize).min(msg.payload.len() - off);
                            self.stats.stale_drops += skip as u64;
                            off += skip;
                            moff += skip as u64;
                            continue;
                        }
                    }
                    let want = (txn.len - txn.received) as usize;
                    let take = want.min(msg.payload.len() - off);
                    let dst = (txn.plm_addr + txn.received) as usize;
                    plm[dst..dst + take].copy_from_slice(&msg.payload[off..off + take]);
                    txn.received += take as u32;
                    off += take;
                    moff += take as u64;
                    self.stats.p2p_read_bytes += take as u64;
                    if txn.received == txn.len {
                        self.done.insert(txn.tag);
                        q.pop_front();
                        self.p2p_rd_outstanding -= 1;
                    }
                }
            }
            _ => {}
        }
    }

    /// One socket cycle: accept at most one read-control and one
    /// write-control beat, progress the P2P unit, release delayed sends.
    ///
    /// The returned [`Wake`] is the socket's self-driven schedule: `Busy`
    /// while control beats remain queued, `Sleeping` until the earliest
    /// TLB-delayed send, `Parked` otherwise — including when P2P bursts
    /// wait for consumer credit, since credit only arrives as a `P2pReq`
    /// delivery (which unparks the tile).  Outstanding DMA/P2P reads and
    /// write acks likewise complete only through deliveries.
    pub fn tick(&mut self, now: u64, plm: &mut [u8]) -> Wake {
        // Accept one read-control beat.
        if let Some(rc) = self.rd_ctrl.pop() {
            self.stats.bursts += 1;
            if rc.user == 0 {
                self.issue_mem_read(now, rc);
            } else {
                let (prod, prod_slot) = self
                    .regs
                    .lookup_src(rc.user)
                    .unwrap_or_else(|| panic!("source LUT entry {} not set", rc.user));
                let deadline = if self.cfg.retry_timeout > 0 {
                    now + self.cfg.retry_timeout as u64
                } else {
                    u64::MAX
                };
                let pos = self.p2p_rd_pos.entry((prod, prod_slot)).or_insert(0);
                let base = *pos;
                *pos += rc.len as u64;
                self.p2p_rd
                    .entry((prod, prod_slot))
                    .or_default()
                    .push_back(P2pRead {
                        tag: rc.tag,
                        plm_addr: rc.plm_addr,
                        len: rc.len,
                        received: 0,
                        base,
                        deadline,
                        tries: 0,
                        last_seen: 0,
                    });
                self.p2p_rd_outstanding += 1;
                let kind = MsgKind::P2pReq {
                    len: rc.len,
                    prod_slot,
                    cons_slot: self.slot,
                    resume: RESUME_NONE,
                };
                self.out.push((Plane::DmaReq, Message::ctrl(self.coord, prod, kind)));
            }
        }
        // Accept one write-control beat.
        if let Some(wc) = self.wr_ctrl.pop() {
            self.stats.bursts += 1;
            let data = plm[wc.plm_addr as usize..(wc.plm_addr + wc.len) as usize].to_vec();
            if wc.user == 0 {
                self.issue_mem_write(now, wc, data);
            } else {
                self.p2p.submit_burst(Arc::new(data), wc.user, wc.tag);
            }
        }
        // Producer-side P2P progress.
        let mut sent = Vec::new();
        let tags = self.p2p.tick(self.coord, self.slot, self.mcast_capacity, &mut sent);
        for m in sent {
            self.out.push((Plane::DmaRsp, m));
        }
        // Per-consumer byte accounting lives in the unit (distinct dest
        // coords under-count when two consumer slots share a tile).
        self.stats.p2p_write_bytes = self.p2p.bytes_sent;
        self.stats.replayed_bytes = self.p2p.replayed_bytes;
        // A tag completing *here* (after the core's tick this cycle) may
        // unblock a Wdma spin: stay busy one cycle so the core observes
        // it, exactly when the full-scan reference would.
        let completed_tags = !tags.is_empty();
        for t in tags {
            self.done.insert(t);
        }
        // Release TLB-delayed messages.
        if !self.delayed.is_empty() {
            let mut i = 0;
            while i < self.delayed.len() {
                if self.delayed[i].0 <= now {
                    let (_, plane, msg) = self.delayed.swap_remove(i);
                    self.out.push((plane, msg));
                } else {
                    i += 1;
                }
            }
        }
        // Bounded retry: re-send timed-out sub-requests (degraded meshes
        // only — `retry_timeout == 0` skips all of this).
        if self.cfg.retry_timeout > 0 && self.fault.is_none() {
            self.tick_retries(now);
        }
        if completed_tags || !self.rd_ctrl.is_empty() || !self.wr_ctrl.is_empty() {
            return Wake::Busy; // one control beat accepted per cycle
        }
        let mut next = self.delayed.iter().map(|d| d.0).min();
        if self.cfg.retry_timeout > 0 && self.fault.is_none() {
            let retry_next = self
                .retry_q
                .iter()
                .map(|e| e.deadline)
                .chain(self.p2p_rd.values().flatten().map(|t| t.deadline))
                .filter(|&d| d != u64::MAX)
                .min();
            next = match (next, retry_next) {
                (Some(a), Some(b)) => Some(a.min(b)),
                (a, b) => a.or(b),
            };
        }
        match next {
            Some(ready) => Wake::at(now, ready),
            None => Wake::Parked,
        }
    }

    /// Re-send sub-requests whose response deadline has passed; after
    /// `max_retries` unanswered sends, latch a blackhole fault naming the
    /// stuck transaction and stop retrying (the quiesce watchdog reports
    /// it).  Only called when `retry_timeout > 0`.
    #[cold]
    fn tick_retries(&mut self, now: u64) {
        let timeout = self.cfg.retry_timeout as u64;
        // DMA read/write sub-requests: each wire either completes (its
        // entry is removed on response) or times out and is re-sent.
        let mut i = 0;
        while i < self.retry_q.len() {
            if self.retry_q[i].deadline > now {
                i += 1;
                continue;
            }
            if self.retry_q[i].tries >= self.cfg.max_retries {
                let e = self.retry_q.swap_remove(i);
                self.set_fault(format!(
                    "{:?}.{}: DMA sub-request wire {} to {:?} unanswered after {} retries",
                    self.coord,
                    self.slot,
                    e.wire,
                    e.msg.dests.iter().next().unwrap_or(self.mem_tile),
                    e.tries,
                ));
                continue;
            }
            let e = &mut self.retry_q[i];
            e.tries += 1;
            e.deadline = now + timeout;
            self.stats.retries += 1;
            self.out.push((e.plane, e.msg.clone()));
            i += 1;
        }
        // P2P pulls: only the stream head is in flight; progress re-arms
        // the deadline, so only a genuinely stalled stream re-requests the
        // remainder (duplicate deliveries are dropped by `handle_msg`).
        // Keys are sorted so re-request order is deterministic.
        let mut keys: Vec<(Coord, u8)> = self.p2p_rd.keys().copied().collect();
        keys.sort_unstable();
        for key in keys {
            let (prod, prod_slot) = key;
            let Some(q) = self.p2p_rd.get_mut(&key) else { continue };
            let Some(t) = q.front_mut() else { continue };
            if t.received > t.last_seen {
                t.last_seen = t.received;
                t.deadline = now + timeout;
                continue;
            }
            if t.deadline > now {
                continue;
            }
            if t.tries >= self.cfg.max_retries {
                t.deadline = u64::MAX;
                let fault = format!(
                    "{:?}.{}: P2P pull of {} bytes from {:?}.{} stalled at {}/{} after {} \
                     re-requests",
                    self.coord, self.slot, t.len, prod, prod_slot, t.received, t.len, t.tries,
                );
                self.set_fault(fault);
                continue;
            }
            t.tries += 1;
            t.deadline = now + timeout;
            // The re-request names the exact stream offset to resume from;
            // a replay-buffering producer retransmits from there, a plain
            // producer (`replay_window == 0`) treats it as a credit add.
            let kind = MsgKind::P2pReq {
                len: t.len - t.received,
                prod_slot,
                cons_slot: self.slot,
                resume: (t.base + t.received as u64) as u32,
            };
            self.stats.retries += 1;
            self.out.push((Plane::DmaReq, Message::ctrl(self.coord, prod, kind)));
        }
    }

    /// Latch the first fault diagnosis (later ones add no information).
    fn set_fault(&mut self, cause: String) {
        if self.fault.is_none() {
            self.fault = Some(cause);
        }
    }

    /// Drop the retry entry for a completed wire, if retry is armed.
    fn clear_retry(&mut self, wire: u32) {
        if self.cfg.retry_timeout == 0 {
            return;
        }
        if let Some(i) = self.retry_q.iter().position(|e| e.wire == wire) {
            self.retry_q.swap_remove(i);
        }
    }

    /// Queue a DMA sub-request for sending (after `penalty` cycles when a
    /// TLB walk delayed it) and arm its retry timer when retry is enabled.
    fn push_req(&mut self, now: u64, penalty: u32, msg: Message, wire: u32) {
        if self.cfg.retry_timeout > 0 {
            self.retry_q.push(RetryEntry {
                wire,
                deadline: now + penalty as u64 + self.cfg.retry_timeout as u64,
                tries: 0,
                plane: Plane::DmaReq,
                msg: msg.clone(),
            });
        }
        if penalty == 0 {
            self.out.push((Plane::DmaReq, msg));
        } else {
            self.delayed.push((now + penalty as u64, Plane::DmaReq, msg));
        }
    }

    fn issue_mem_read(&mut self, now: u64, rc: ReadCtrl) {
        self.rd_remaining.insert(rc.tag, rc.len);
        let mut vaddr = rc.vaddr;
        let mut plm_addr = rc.plm_addr;
        let mut left = rc.len;
        let mut penalty = 0u32;
        while left > 0 {
            let chunk = left.min(self.tlb.page_remaining(vaddr));
            let (phys, miss) = self.tlb.translate(vaddr).expect("unmapped accelerator vaddr");
            penalty += miss;
            let wire = self.alloc_wire();
            self.mem_rd_sub.insert(wire, (rc.tag, plm_addr, chunk));
            let kind = MsgKind::DmaReadReq { addr: phys, len: chunk, tag: wire, slot: self.slot };
            let msg = Message::ctrl(self.coord, self.mem_tile, kind);
            self.push_req(now, penalty, msg, wire);
            vaddr += chunk as u64;
            plm_addr += chunk;
            left -= chunk;
        }
    }

    fn issue_mem_write(&mut self, now: u64, wc: WriteCtrl, data: Vec<u8>) {
        let mut vaddr = wc.vaddr;
        let mut off = 0u32;
        let mut left = wc.len;
        let mut subs = 0u32;
        let mut penalty = 0u32;
        while left > 0 {
            let chunk = left.min(self.tlb.page_remaining(vaddr));
            let (phys, miss) = self.tlb.translate(vaddr).expect("unmapped accelerator vaddr");
            penalty += miss;
            let payload = Arc::new(data[off as usize..(off + chunk) as usize].to_vec());
            // Each sub-request carries its own wire tag (not the txn tag)
            // so acks — and retried acks — match one sub exactly.
            let wire = self.alloc_wire();
            self.mem_wr_sub.insert(wire, wc.tag);
            let kind =
                MsgKind::DmaWriteReq { addr: phys, len: chunk, tag: wire, slot: self.slot };
            let msg = Message::data(self.coord, self.mem_tile, kind, payload);
            self.push_req(now, penalty, msg, wire);
            self.stats.dma_write_bytes += chunk as u64;
            vaddr += chunk as u64;
            off += chunk;
            left -= chunk;
            subs += 1;
        }
        self.wr_remaining.insert(wc.tag, subs);
    }

    /// Send the invocation-complete interrupt to the CPU tile.
    pub fn send_irq(&mut self) {
        let kind = MsgKind::Irq { acc: self.acc_id };
        self.out.push((Plane::Misc, Message::ctrl(self.coord, self.cpu_tile, kind)));
    }

    /// Drain queued outgoing messages (the tile injects them into the NoC).
    pub fn drain_out(&mut self) -> Vec<(Plane, Message)> {
        std::mem::take(&mut self.out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AccConfig;

    fn socket() -> Socket {
        let mut s =
            Socket::new((1, 1), 0, 3, AccConfig::default(), (0, 3), (0, 0), 16);
        s.tlb.map_linear(0x10000, 1 << 20);
        s
    }

    #[test]
    fn mem_read_roundtrip() {
        let mut s = socket();
        let mut plm = vec![0u8; 64 << 10];
        let tag = s.submit_read(0, 256, 0, 128).unwrap();
        assert!(!s.is_done(tag));
        s.tick(0, &mut plm);
        let out = s.drain_out();
        assert_eq!(out.len(), 1);
        let (plane, req) = &out[0];
        assert_eq!(*plane, Plane::DmaReq);
        let MsgKind::DmaReadReq { addr, len, tag: wire, slot } = req.kind else {
            panic!("expected read req")
        };
        assert_eq!((addr, len, slot), (0x10000, 256, 0));
        // Fake the memory response.
        let data: Vec<u8> = (0..=255u8).collect();
        let rsp = Message::data(
            (0, 3),
            (1, 1),
            MsgKind::DmaReadRsp { tag: wire, slot: 0 },
            Arc::new(data.clone()),
        );
        s.handle_msg(&rsp, &mut plm);
        assert!(s.is_done(tag));
        assert_eq!(&plm[128..384], &data[..]);
    }

    #[test]
    fn mem_write_waits_for_ack() {
        let mut s = socket();
        let mut plm = vec![7u8; 64 << 10];
        let tag = s.submit_write(4096, 512, 0, 0).unwrap();
        s.tick(0, &mut plm);
        let out = s.drain_out();
        assert_eq!(out.len(), 1);
        let MsgKind::DmaWriteReq { addr, len, tag: wire, .. } = out[0].1.kind else { panic!() };
        assert_eq!((addr, len), (0x10000 + 4096, 512));
        assert_eq!(out[0].1.payload.len(), 512);
        assert!(!s.is_done(tag));
        // The ack echoes the request's wire tag, not the txn tag.
        let ack = Message::ctrl((0, 3), (1, 1), MsgKind::DmaWriteAck { tag: wire, slot: 0 });
        s.handle_msg(&ack, &mut plm);
        assert!(s.is_done(tag));
    }

    #[test]
    fn page_crossing_read_splits() {
        let mut s = socket();
        let mut plm = vec![0u8; 64 << 10];
        // Page size 64 KiB: a 4 KiB read starting 1 KiB before the boundary.
        let vaddr = (64 << 10) - 1024;
        let tag = s.submit_read(vaddr, 4096, 0, 0).unwrap();
        s.tick(0, &mut plm);
        let out = s.drain_out();
        assert_eq!(out.len(), 2, "split at page boundary");
        // Complete both halves.
        for (_, req) in out {
            let MsgKind::DmaReadReq { len, tag: wire, .. } = req.kind else { panic!() };
            let rsp = Message::data(
                (0, 3),
                (1, 1),
                MsgKind::DmaReadRsp { tag: wire, slot: 0 },
                Arc::new(vec![1u8; len as usize]),
            );
            s.handle_msg(&rsp, &mut plm);
        }
        assert!(s.is_done(tag));
    }

    #[test]
    fn p2p_read_sends_length_carrying_request() {
        let mut s = socket();
        let mut plm = vec![0u8; 64 << 10];
        s.regs.write(regs::regno::SRC_LUT + 2, pack_src((2, 2), 1));
        let tag = s.submit_read(0, 1024, 2, 256).unwrap();
        s.tick(0, &mut plm);
        let out = s.drain_out();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].1.dests.as_slice(), &[(2, 2)]);
        let MsgKind::P2pReq { len, prod_slot, cons_slot, resume } = out[0].1.kind else {
            panic!()
        };
        assert_eq!((len, prod_slot, cons_slot, resume), (1024, 1, 0, RESUME_NONE));
        // Data arrives (possibly split): two 512-byte messages.
        for i in 0..2u32 {
            let mut m = Message::data(
                (2, 2),
                (1, 1),
                MsgKind::P2pData { seq: i, prod_slot: 1 },
                Arc::new(vec![i as u8 + 1; 512]),
            );
            m.cons_slots = p2p::encode_cons_slots(&[(1, 1)], &[((1, 1), 0)]);
            assert!(!s.is_done(tag));
            s.handle_msg(&m, &mut plm);
        }
        assert!(s.is_done(tag));
        assert_eq!(plm[256], 1);
        assert_eq!(plm[256 + 512], 2);
    }

    #[test]
    fn p2p_write_completes_on_send() {
        let mut s = socket();
        let mut plm = vec![9u8; 64 << 10];
        let tag = s.submit_write(0, 2048, 1, 0).unwrap();
        s.tick(0, &mut plm);
        assert!(!s.is_done(tag), "no consumer request yet");
        // Consumer pulls.
        let req = Message::ctrl(
            (0, 1),
            (1, 1),
            MsgKind::P2pReq { len: 2048, prod_slot: 0, cons_slot: 0, resume: RESUME_NONE },
        );
        s.handle_msg(&req, &mut plm);
        s.tick(1, &mut plm);
        assert!(s.is_done(tag));
        let out = s.drain_out();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].0, Plane::DmaRsp);
        assert_eq!(out[0].1.payload.len(), 2048);
    }

    #[test]
    fn mixed_mode_per_burst() {
        // The flexible-P2P headline: one invocation mixing memory reads and
        // P2P reads at burst granularity.
        let mut s = socket();
        let mut plm = vec![0u8; 64 << 10];
        s.regs.write(regs::regno::SRC_LUT + 1, pack_src((2, 0), 0));
        let t_mem = s.submit_read(0, 128, 0, 0).unwrap();
        let t_p2p = s.submit_read(0, 128, 1, 128).unwrap();
        s.tick(0, &mut plm);
        s.tick(1, &mut plm);
        let out = s.drain_out();
        assert_eq!(out.len(), 2);
        assert!(matches!(out[0].1.kind, MsgKind::DmaReadReq { .. }));
        assert!(matches!(out[1].1.kind, MsgKind::P2pReq { .. }));
        assert!(!s.is_done(t_mem) && !s.is_done(t_p2p));
    }

    #[test]
    fn tag_none_always_done() {
        let s = socket();
        assert!(s.is_done(TAG_NONE));
    }

    fn retry_socket(timeout: u32, max_retries: u32) -> Socket {
        let cfg = AccConfig { retry_timeout: timeout, max_retries, ..AccConfig::default() };
        let mut s = Socket::new((1, 1), 0, 3, cfg, (0, 3), (0, 0), 16);
        s.tlb.map_linear(0x10000, 1 << 20);
        s
    }

    #[test]
    fn lost_read_is_resent_and_completes() {
        let mut s = retry_socket(10, 3);
        let mut plm = vec![0u8; 64 << 10];
        let tag = s.submit_read(0, 64, 0, 0).unwrap();
        s.tick(0, &mut plm);
        let first = s.drain_out();
        assert_eq!(first.len(), 1);
        // Pretend the request vanished on a dead link.  At the deadline the
        // socket re-sends the identical message.
        let w = s.tick(10, &mut plm);
        let resent = s.drain_out();
        assert_eq!(resent.len(), 1, "timed-out sub-request re-sent");
        assert_eq!(s.stats.retries, 1);
        assert!(!matches!(w, crate::sched::Wake::Parked), "armed retry keeps a deadline");
        let MsgKind::DmaReadReq { tag: wire, len, .. } = resent[0].1.kind else { panic!() };
        assert_eq!(resent[0].1.kind, first[0].1.kind, "retry is byte-identical");
        let rsp = Message::data(
            (0, 3),
            (1, 1),
            MsgKind::DmaReadRsp { tag: wire, slot: 0 },
            Arc::new(vec![5; len as usize]),
        );
        s.handle_msg(&rsp, &mut plm);
        assert!(s.is_done(tag) && s.quiescent() && s.fault().is_none());
        // A straggling duplicate of the original response is dropped.
        s.handle_msg(&rsp, &mut plm);
        assert_eq!(s.stats.stale_drops, 1);
    }

    #[test]
    fn exhausted_retries_latch_a_fault() {
        let mut s = retry_socket(5, 2);
        let mut plm = vec![0u8; 64 << 10];
        s.submit_write(0, 64, 0, 0).unwrap();
        s.tick(0, &mut plm);
        s.drain_out();
        let mut now = 0;
        while s.fault().is_none() && now < 100 {
            now += 5;
            s.tick(now, &mut plm);
            s.drain_out();
        }
        let cause = s.fault().expect("fault latched after retries exhausted");
        assert!(cause.contains("unanswered after 2 retries"), "got: {cause}");
        assert_eq!(s.stats.retries, 2);
        assert!(!s.quiescent(), "a blackholed txn never completes");
        assert!(matches!(s.tick(now + 5, &mut plm), crate::sched::Wake::Parked));
    }

    #[test]
    fn stalled_p2p_pull_rerequests_remainder() {
        let mut s = retry_socket(8, 3);
        let mut plm = vec![0u8; 64 << 10];
        s.regs.write(regs::regno::SRC_LUT + 2, pack_src((2, 2), 1));
        s.submit_read(0, 1024, 2, 0).unwrap();
        s.tick(0, &mut plm);
        let out = s.drain_out();
        assert!(matches!(out[0].1.kind, MsgKind::P2pReq { len: 1024, .. }));
        // Half the stream arrives, then the link dies.
        let mut m = Message::data(
            (2, 2),
            (1, 1),
            MsgKind::P2pData { seq: 0, prod_slot: 1 },
            Arc::new(vec![3u8; 512]),
        );
        m.cons_slots = p2p::encode_cons_slots(&[(1, 1)], &[((1, 1), 0)]);
        s.handle_msg(&m, &mut plm);
        // First post-progress tick re-arms the deadline instead of retrying.
        s.tick(9, &mut plm);
        assert!(s.drain_out().is_empty(), "progress re-arms the timer");
        // No further progress: the socket re-requests only the remainder.
        s.tick(17, &mut plm);
        let out = s.drain_out();
        assert_eq!(out.len(), 1);
        let MsgKind::P2pReq { len, resume, .. } = out[0].1.kind else { panic!() };
        assert_eq!(len, 512, "re-request asks for the missing bytes only");
        assert_eq!(resume, 512, "re-request names the exact resume offset");
        assert_eq!(s.stats.retries, 1);
    }

    #[test]
    fn second_pull_resumes_at_the_stream_offset() {
        // Stream offsets are cumulative per producer: a stall in the second
        // pull resumes past the first pull's bytes, not at its own start.
        let mut s = retry_socket(8, 3);
        let mut plm = vec![0u8; 64 << 10];
        s.regs.write(regs::regno::SRC_LUT + 2, pack_src((2, 2), 1));
        s.submit_read(0, 256, 2, 0).unwrap();
        s.tick(0, &mut plm);
        s.drain_out();
        let mut m = Message::data(
            (2, 2),
            (1, 1),
            MsgKind::P2pData { seq: 0, prod_slot: 1 },
            Arc::new(vec![1u8; 256]),
        );
        m.cons_slots = p2p::encode_cons_slots(&[(1, 1)], &[((1, 1), 0)]);
        s.handle_msg(&m, &mut plm);
        s.submit_read(0, 256, 2, 256).unwrap();
        s.tick(1, &mut plm);
        s.drain_out();
        // The second pull never delivers: its re-request resumes at 256.
        s.tick(10, &mut plm);
        let out = s.drain_out();
        assert_eq!(out.len(), 1);
        let MsgKind::P2pReq { len, resume, .. } = out[0].1.kind else { panic!() };
        assert_eq!((len, resume), (256, 256));
    }

    fn replay_socket(timeout: u32, max_retries: u32, window: u32) -> Socket {
        let cfg = AccConfig {
            retry_timeout: timeout,
            max_retries,
            replay_window: window,
            ..AccConfig::default()
        };
        let mut s = Socket::new((1, 1), 0, 3, cfg, (0, 3), (0, 0), 16);
        s.tlb.map_linear(0x10000, 1 << 20);
        s
    }

    fn p2p_data(seq: u32, payload: Vec<u8>) -> Message {
        let mut m = Message::data(
            (2, 2),
            (1, 1),
            MsgKind::P2pData { seq, prod_slot: 1 },
            Arc::new(payload),
        );
        m.cons_slots = p2p::encode_cons_slots(&[(1, 1)], &[((1, 1), 0)]);
        m
    }

    #[test]
    fn armed_consumer_drops_gapped_data_instead_of_misassembling() {
        // A mid-stream chunk is lost but a later chunk still arrives (it
        // rerouted around the kill).  Without offset tags the later bytes
        // would silently land at the earlier offset; with the window armed
        // the gap is detected, the chunk dropped, and the stalled pull's
        // re-request recovers the stream in order.
        let mut s = replay_socket(8, 3, 4096);
        let mut plm = vec![0u8; 64 << 10];
        s.regs.write(regs::regno::SRC_LUT + 2, pack_src((2, 2), 1));
        let tag = s.submit_read(0, 1024, 2, 0).unwrap();
        s.tick(0, &mut plm);
        s.drain_out();
        // Chunk [0, 512) is lost; chunk [512, 1024) arrives first.
        s.handle_msg(&p2p_data(512, vec![2u8; 512]), &mut plm);
        assert_eq!(s.stats.stale_drops, 512, "gapped chunk dropped, not placed");
        assert_eq!(s.stats.p2p_read_bytes, 0);
        // The stalled pull re-requests from offset 0...
        s.tick(9, &mut plm);
        let out = s.drain_out();
        assert_eq!(out.len(), 1);
        let MsgKind::P2pReq { len, resume, .. } = out[0].1.kind else { panic!() };
        assert_eq!((len, resume), (1024, 0));
        // ...and the producer's replay delivers the whole stream in order.
        s.handle_msg(&p2p_data(0, vec![1u8; 512]), &mut plm);
        s.handle_msg(&p2p_data(512, vec![2u8; 512]), &mut plm);
        assert!(s.is_done(tag));
        assert_eq!(&plm[..512], &[1u8; 512][..]);
        assert_eq!(&plm[512..1024], &[2u8; 512][..]);
        assert!(s.fault().is_none());
    }

    #[test]
    fn armed_consumer_skips_duplicate_bytes_a_replay_already_delivered() {
        // The original chunk was only delayed, not lost: after the replay
        // fills the stream, the straggler's overlap is skipped while any
        // genuinely new tail bytes are still taken.
        let mut s = replay_socket(8, 3, 4096);
        let mut plm = vec![0u8; 64 << 10];
        s.regs.write(regs::regno::SRC_LUT + 2, pack_src((2, 2), 1));
        let tag = s.submit_read(0, 1024, 2, 0).unwrap();
        s.tick(0, &mut plm);
        s.drain_out();
        s.handle_msg(&p2p_data(0, vec![1u8; 512]), &mut plm);
        // The replayed copy of [0, 512) straggles in again.
        s.handle_msg(&p2p_data(0, vec![1u8; 512]), &mut plm);
        assert_eq!(s.stats.stale_drops, 512, "duplicate overlap skipped");
        assert!(!s.is_done(tag));
        s.handle_msg(&p2p_data(512, vec![2u8; 512]), &mut plm);
        assert!(s.is_done(tag));
        assert_eq!(&plm[..512], &[1u8; 512][..]);
        assert_eq!(&plm[512..1024], &[2u8; 512][..]);
    }

    #[test]
    fn quiescent_lifecycle() {
        let mut s = socket();
        let mut plm = vec![0u8; 64 << 10];
        assert!(s.quiescent());
        s.submit_read(0, 64, 0, 0).unwrap();
        assert!(!s.quiescent());
        s.tick(0, &mut plm);
        let out = s.drain_out();
        let MsgKind::DmaReadReq { tag: wire, len, .. } = out[0].1.kind else { panic!() };
        let rsp = Message::data(
            (0, 3),
            (1, 1),
            MsgKind::DmaReadRsp { tag: wire, slot: 0 },
            Arc::new(vec![0; len as usize]),
        );
        s.handle_msg(&rsp, &mut plm);
        assert!(s.quiescent());
    }
}
