//! Socket configuration registers.
//!
//! The host programs an invocation by writing these registers over the misc
//! NoC plane (each write is an uncached store crossing the NoC — this is
//! where the invocation overhead the paper talks about comes from).  The
//! register file includes the **source lookup table** of the updated
//! accelerator interface: read-channel `user` values 1..N index this table,
//! which virtualizes P2P sources as `(tile coord, socket slot)` pairs so
//! accelerator programs are placement-independent.

/// Register numbers (the high nibble of the wire-level register id selects
/// the socket slot; see [`split_reg`]).
pub mod regno {
    /// Write 1 to start the invocation.
    pub const CMD: u16 = 0x00;
    /// 0 = idle, 1 = running, 2 = done.
    pub const STATUS: u16 = 0x01;
    /// Generic argument registers visible to the accelerator program.
    pub const ARG0: u16 = 0x10; // ..ARG7 = 0x17
    /// Source LUT base: entry k lives at SRC_LUT + k (k = 1..15).
    pub const SRC_LUT: u16 = 0x20; // ..0x2F
}

/// Split a wire register id into `(slot, regno)`.
pub fn split_reg(reg: u16) -> (u8, u16) {
    ((reg >> 12) as u8, reg & 0x0FFF)
}

/// Build a wire register id from `(slot, regno)`.
pub fn make_reg(slot: u8, regno: u16) -> u16 {
    ((slot as u16) << 12) | (regno & 0x0FFF)
}

/// Pack a source-LUT entry value: 8-bit fields for each coordinate
/// component (covers the full `u8` coordinate range, so meshes past 8x8
/// need no repacking) and the socket slot.
pub fn pack_src(coord: (u8, u8), slot: u8) -> u64 {
    ((coord.0 as u64) << 16) | ((coord.1 as u64) << 8) | slot as u64
}

/// Unpack a source-LUT entry value.
pub fn unpack_src(v: u64) -> ((u8, u8), u8) {
    ((((v >> 16) & 0xFF) as u8, ((v >> 8) & 0xFF) as u8), (v & 0xFF) as u8)
}

/// Invocation status values.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Status {
    Idle = 0,
    Running = 1,
    Done = 2,
}

/// The socket register file.
#[derive(Debug, Clone)]
pub struct Regs {
    /// Status register.
    pub status: Status,
    /// Start pulse pending (consumed by the tile at the next tick).
    pub start_pending: bool,
    /// ARG0..ARG7, copied into accelerator registers r1..r8 at start.
    pub args: [u64; 8],
    /// Source lookup table (index 0 unused: user==0 means memory).
    pub src_lut: [u64; 16],
}

impl Default for Regs {
    fn default() -> Self {
        Self { status: Status::Idle, start_pending: false, args: [0; 8], src_lut: [0; 16] }
    }
}

impl Regs {
    /// Apply a register write; unknown registers are ignored (RTL drops
    /// writes to holes in the address map).
    pub fn write(&mut self, regno: u16, val: u64) {
        match regno {
            regno::CMD => {
                if val & 1 != 0 {
                    self.start_pending = true;
                }
            }
            r if (regno::ARG0..regno::ARG0 + 8).contains(&r) => {
                self.args[(r - regno::ARG0) as usize] = val;
            }
            r if (regno::SRC_LUT..regno::SRC_LUT + 16).contains(&r) => {
                self.src_lut[(r - regno::SRC_LUT) as usize] = val;
            }
            _ => {}
        }
    }

    /// Read a register.
    pub fn read(&self, regno: u16) -> u64 {
        match regno {
            regno::STATUS => self.status as u64,
            r if (regno::ARG0..regno::ARG0 + 8).contains(&r) => {
                self.args[(r - regno::ARG0) as usize]
            }
            r if (regno::SRC_LUT..regno::SRC_LUT + 16).contains(&r) => {
                self.src_lut[(r - regno::SRC_LUT) as usize]
            }
            _ => 0,
        }
    }

    /// Resolve a read-channel `user` index through the source LUT.
    pub fn lookup_src(&self, user: u16) -> Option<((u8, u8), u8)> {
        if user == 0 || user as usize >= self.src_lut.len() {
            return None;
        }
        Some(unpack_src(self.src_lut[user as usize]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reg_id_split_roundtrip() {
        let r = make_reg(1, regno::CMD);
        assert_eq!(split_reg(r), (1, regno::CMD));
        let r = make_reg(0, regno::SRC_LUT + 5);
        assert_eq!(split_reg(r), (0, regno::SRC_LUT + 5));
    }

    #[test]
    fn src_pack_roundtrip() {
        for c in [(0u8, 0u8), (2, 3), (7, 7), (15, 9), (15, 15)] {
            for s in [0u8, 1] {
                assert_eq!(unpack_src(pack_src(c, s)), (c, s));
            }
        }
    }

    #[test]
    fn cmd_start_pulse() {
        let mut r = Regs::default();
        assert!(!r.start_pending);
        r.write(regno::CMD, 1);
        assert!(r.start_pending);
        r.write(regno::CMD, 0);
        assert!(r.start_pending, "writing 0 does not cancel a pending start");
    }

    #[test]
    fn args_and_lut() {
        let mut r = Regs::default();
        r.write(regno::ARG0 + 3, 42);
        assert_eq!(r.read(regno::ARG0 + 3), 42);
        r.write(regno::SRC_LUT + 2, pack_src((1, 3), 1));
        assert_eq!(r.lookup_src(2), Some(((1, 3), 1)));
        assert_eq!(r.lookup_src(0), None, "user==0 is memory, not a source");
    }

    #[test]
    fn unknown_regs_ignored() {
        let mut r = Regs::default();
        r.write(0x0FFF, 99);
        assert_eq!(r.read(0x0FFF), 0);
    }
}
