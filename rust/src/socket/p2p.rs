//! Producer-side P2P / multicast unit.
//!
//! ESP's P2P is *pull-based* to satisfy the consumption assumption (messages
//! put on the NoC are always consumed, preventing message-dependent
//! deadlock): consumers send requests, and the producer only injects data
//! that consumers have asked for.  The paper's enhancements implemented
//! here:
//!
//! - requests carry a **length**, so producer and consumer burst shapes may
//!   differ (only total bytes per transaction must match) — the unit keeps a
//!   per-consumer *credit* of requested bytes;
//! - a write burst with `user == n >= 2` waits until `n` distinct consumers
//!   have joined the transaction, then sends **one multicast message** whose
//!   header carries all destination coordinates.  A transaction whose
//!   distinct destination *tiles* exceed the header capacity (possible past
//!   the paper's operating points, e.g. unpacked fan-outs on big meshes)
//!   serializes into one message per destination group instead.

use std::collections::VecDeque;
use std::sync::Arc;

use crate::noc::{Coord, DestList, Message, MsgKind, RESUME_NONE};

/// A consumer that has sent at least one pull request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Consumer {
    /// Consumer tile.
    pub coord: Coord,
    /// Consumer socket slot on that tile.
    pub slot: u8,
    /// Outstanding requested bytes not yet served.
    pub credit: u64,
}

/// A write burst waiting for consumer credit.
#[derive(Debug)]
struct PendingBurst {
    data: Arc<Vec<u8>>,
    ndests: u16,
    tag: u32,
    /// Bytes already sent (partial sends against available credit).
    sent: usize,
}

/// Bounded retransmission history for one consumer (parallel to
/// [`P2pUnit::consumers`]); only maintained when the replay window is on.
#[derive(Debug, Default)]
struct ReplayRing {
    /// The most recent `window` bytes streamed to this consumer.
    buf: VecDeque<u8>,
    /// Stream offset of the first buffered byte.
    start: u64,
    /// Resume offset of the replay currently queued for emission.  A
    /// repeated re-request at the same offset before the queued replay
    /// goes out is absorbed (one retransmission serves both); the guard
    /// clears at emission, so a re-request arriving a full timeout later —
    /// the replay itself was lost — retransmits again.  Duplicates are
    /// harmless either way: consumers skip already-delivered offsets.
    last_resume: Option<u64>,
}

impl ReplayRing {
    /// Stream offset one past the last byte streamed to this consumer.
    fn sent_total(&self) -> u64 {
        self.start + self.buf.len() as u64
    }
}

/// Producer-side state for one socket.
#[derive(Debug, Default)]
pub struct P2pUnit {
    /// Consumers in arrival order; the first `ndests` form the transaction.
    consumers: Vec<Consumer>,
    bursts: VecDeque<PendingBurst>,
    seq: u32,
    /// Replay window in bytes buffered per consumer; 0 disables replay
    /// entirely (the default: re-requests fall back to plain credit adds,
    /// byte-identical to the pre-replay unit).
    window: u32,
    /// Per-consumer replay rings, parallel to `consumers`.
    rings: Vec<ReplayRing>,
    /// Retransmissions awaiting emission on the next tick, with the stream
    /// offset each one resumes at.
    replays: Vec<(Coord, u8, u64, Vec<u8>)>,
    /// Stats: bytes sent via P2P/multicast.
    pub bytes_sent: u64,
    /// Stats: multicast messages (>= 2 dests) sent.
    pub multicasts: u64,
    /// Stats: bytes retransmitted from replay rings.
    pub replayed_bytes: u64,
    /// Stats: re-requests whose resume offset predated the ring — recovery
    /// impossible, so the consumer's retry budget latches the diagnosis.
    pub window_exceeded: u64,
}

/// Encode the per-destination slot participation mask: bit `2*i + slot` is
/// set when `(dests[i], slot)` receives the message.
pub fn encode_cons_slots(dests: &[Coord], pairs: &[(Coord, u8)]) -> u32 {
    let mut mask = 0u32;
    for &(c, s) in pairs {
        let i = dests.iter().position(|&d| d == c).expect("consumer coord in dest list");
        mask |= 1 << (2 * i + s as usize);
    }
    mask
}

/// Does `(coord, slot)` participate in a message with `dests`/`cons_slots`?
pub fn cons_participates(dests: &DestList, cons_slots: u32, coord: Coord, slot: u8) -> bool {
    dests
        .as_slice()
        .iter()
        .position(|&d| d == coord)
        .is_some_and(|i| cons_slots & (1 << (2 * i + slot as usize)) != 0)
}

impl P2pUnit {
    /// A unit with an armed replay window of `window` bytes per consumer.
    pub fn with_window(window: u32) -> Self {
        Self { window, ..Self::default() }
    }

    /// Replay window (bytes buffered per consumer; 0 = replay disabled).
    pub fn window(&self) -> u32 {
        self.window
    }

    /// Record a consumer pull request of `len` bytes.  `resume` is
    /// [`RESUME_NONE`] on a fresh pull (plain credit add); a retransmission
    /// request instead carries the consumer's exact stream offset, and with
    /// the replay window armed the unit resends `[resume, sent_total)` from
    /// the ring and *replaces* the consumer's credit with the unsent
    /// remainder — the resume request supersedes whatever stale credit the
    /// lost original left behind.
    pub fn on_request(&mut self, coord: Coord, slot: u8, len: u32, resume: u32) {
        let i = match self.consumers.iter().position(|c| c.coord == coord && c.slot == slot) {
            Some(i) => i,
            None => {
                self.consumers.push(Consumer { coord, slot, credit: 0 });
                self.rings.push(ReplayRing::default());
                self.consumers.len() - 1
            }
        };
        if resume == RESUME_NONE || self.window == 0 {
            // Fresh pull, or replay disabled: the legacy credit-only path
            // (byte-identical to the pre-replay unit either way).
            self.consumers[i].credit += len as u64;
            return;
        }
        let ring = &mut self.rings[i];
        let resume = resume as u64;
        if resume < ring.start {
            // The lost bytes already fell out of the bounded window:
            // recovery is impossible.  Grant no credit — the consumer's
            // retry budget exhausts and latches the precise diagnosis
            // instead of the stream silently resuming with wrong bytes.
            self.window_exceeded += 1;
            return;
        }
        let sent_total = ring.sent_total();
        debug_assert!(resume <= sent_total, "consumer resumed past the stream head");
        let have = sent_total.saturating_sub(resume);
        if have > 0 && ring.last_resume != Some(resume) {
            ring.last_resume = Some(resume);
            let off = (resume - ring.start) as usize;
            let bytes: Vec<u8> = ring.buf.iter().skip(off).copied().collect();
            self.replayed_bytes += bytes.len() as u64;
            self.replays.push((coord, slot, resume, bytes));
        }
        self.consumers[i].credit = (len as u64).saturating_sub(have);
    }

    /// Queue a write burst for `ndests` consumers (tag completes once the
    /// whole burst has been sent).
    pub fn submit_burst(&mut self, data: Arc<Vec<u8>>, ndests: u16, tag: u32) {
        assert!(ndests >= 1, "P2P burst needs at least one destination");
        self.bursts.push_back(PendingBurst { data, ndests, tag, sent: 0 });
    }

    /// Try to send queued bursts (in order).  A burst larger than the
    /// consumers' outstanding credit is sent **partially** — required for
    /// the flexible burst-shape enhancement: a 4 KB producer burst against
    /// a consumer pulling 1 KB at a time must flow chunk by chunk, not
    /// wait for four outstanding requests (which would deadlock once the
    /// consumer's request window is smaller than the producer's burst).
    /// Appends outgoing messages and returns the tags of bursts fully sent.
    pub fn tick(
        &mut self,
        self_coord: Coord,
        self_slot: u8,
        mcast_capacity: usize,
        out: &mut Vec<Message>,
    ) -> Vec<u32> {
        let mut done = Vec::new();
        // Retransmissions go out first: they are strictly older stream
        // bytes than anything the credit loop below can emit.  Replays only
        // exist with the window armed, so `seq` carries the stream offset
        // the retransmission resumes at.
        for (coord, slot, resume, bytes) in self.replays.drain(..) {
            if let Some(i) =
                self.consumers.iter().position(|c| c.coord == coord && c.slot == slot)
            {
                self.rings[i].last_resume = None;
            }
            let kind = MsgKind::P2pData { seq: resume as u32, prod_slot: self_slot };
            self.seq += 1;
            out.push(Message {
                src: self_coord,
                dests: DestList::unicast(coord),
                kind,
                cons_slots: encode_cons_slots(&[coord], &[(coord, slot)]),
                payload: Arc::new(bytes),
            });
        }
        while let Some(front) = self.bursts.front() {
            let n = front.ndests as usize;
            if self.consumers.len() < n {
                break; // waiting for more consumers to join (paper §3)
            }
            let remaining = front.data.len() - front.sent;
            let credit =
                self.consumers[..n].iter().map(|c| c.credit).min().unwrap_or(0) as usize;
            let chunk = remaining.min(credit);
            if chunk == 0 {
                break; // head-of-line burst lacks credit; preserve order
            }
            // Distinct destination tiles (two slots on one tile share the
            // single delivered copy).
            let mut dests: Vec<Coord> = Vec::new();
            let mut pairs: Vec<(Coord, u8)> = Vec::new();
            for c in &self.consumers[..n] {
                if !dests.contains(&c.coord) {
                    dests.push(c.coord);
                }
                pairs.push((c.coord, c.slot));
            }
            for c in &mut self.consumers[..n] {
                c.credit -= chunk as u64;
            }
            self.bytes_sent += (chunk * n) as u64;
            let front = self.bursts.front_mut().unwrap();
            let payload: Arc<Vec<u8>> = if chunk == front.data.len() {
                front.data.clone()
            } else {
                Arc::new(front.data[front.sent..front.sent + chunk].to_vec())
            };
            front.sent += chunk;
            let mut stream_off = 0u64;
            if self.window > 0 {
                // With the window armed the outgoing `seq` field carries
                // this chunk's stream offset, shared by every participating
                // consumer — all n rings advance in lockstep (consumers
                // join before any byte flows and every chunk appends to all
                // of them), which the assert pins: a producer invocation
                // that mixed fan-out widths would desynchronize the rings,
                // and one offset per message could no longer be exact.
                stream_off = self.rings.first().map_or(0, |r| r.sent_total());
                assert!(
                    self.rings[..n].iter().all(|r| r.sent_total() == stream_off),
                    "replay requires lockstep consumer streams (uniform fan-out per invocation)"
                );
                // Append the chunk to every participating consumer's ring,
                // trimming the front to the bounded window.
                for ring in &mut self.rings[..n] {
                    ring.buf.extend(payload.iter().copied());
                    let excess = ring.buf.len().saturating_sub(self.window as usize);
                    if excess > 0 {
                        ring.buf.drain(..excess);
                        ring.start += excess as u64;
                    }
                }
            }
            // One header encodes at most `mcast_capacity` destination
            // tiles.  A transaction spanning more tiles serializes into one
            // message per destination group — the producer socket replays
            // the burst per group, as the RTL would — so an over-capacity
            // fan-out degrades instead of being unsendable.  (Every Fig. 6
            // configuration fits one group; extra messages only appear
            // past the paper's operating points.)
            for group in dests.chunks(mcast_capacity.max(1)) {
                let group_pairs: Vec<(Coord, u8)> =
                    pairs.iter().copied().filter(|(c, _)| group.contains(c)).collect();
                let cons_slots = encode_cons_slots(group, &group_pairs);
                if group.len() >= 2 {
                    self.multicasts += 1;
                }
                // Armed: `seq` is the chunk's stream offset (exact consumer
                // placement); off: the legacy per-unit message counter.
                let seq = if self.window > 0 { stream_off as u32 } else { self.seq };
                let kind = MsgKind::P2pData { seq, prod_slot: self_slot };
                self.seq += 1;
                out.push(Message {
                    src: self_coord,
                    dests: DestList::from_slice(group),
                    kind,
                    payload: payload.clone(),
                    cons_slots,
                });
            }
            if front.sent == front.data.len() {
                done.push(front.tag);
                self.bursts.pop_front();
            }
        }
        done
    }

    /// Reset transaction state at invocation end (cumulative statistics
    /// survive, like `bytes_sent`).
    pub fn reset(&mut self) {
        self.consumers.clear();
        self.bursts.clear();
        self.rings.clear();
        self.replays.clear();
        self.seq = 0;
    }

    /// Per-consumer replay forensics: `(coord, slot, buffered bytes, next
    /// stream offset)` for every joined consumer (quiesce-watchdog dump).
    pub fn replay_state(&self) -> Vec<(Coord, u8, usize, u64)> {
        self.consumers
            .iter()
            .zip(&self.rings)
            .map(|(c, r)| (c.coord, c.slot, r.buf.len(), r.sent_total()))
            .collect()
    }

    /// Consumers currently joined.
    pub fn consumer_count(&self) -> usize {
        self.consumers.len()
    }

    /// Bursts waiting for credit.
    pub fn pending_bursts(&self) -> usize {
        self.bursts.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn burst(n: usize) -> Arc<Vec<u8>> {
        Arc::new(vec![0xAB; n])
    }

    #[test]
    fn unicast_waits_for_request_then_sends() {
        let mut u = P2pUnit::default();
        let mut out = Vec::new();
        u.submit_burst(burst(1024), 1, 7);
        assert!(u.tick((0, 0), 0, 16, &mut out).is_empty(), "no consumer yet");
        u.on_request((1, 1), 0, 1024, RESUME_NONE);
        let done = u.tick((0, 0), 0, 16, &mut out);
        assert_eq!(done, vec![7]);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].dests.as_slice(), &[(1, 1)]);
    }

    #[test]
    fn multicast_waits_for_all_n_consumers() {
        let mut u = P2pUnit::default();
        let mut out = Vec::new();
        u.submit_burst(burst(512), 3, 1);
        u.on_request((0, 1), 0, 512, RESUME_NONE);
        u.on_request((1, 0), 0, 512, RESUME_NONE);
        assert!(u.tick((0, 0), 0, 16, &mut out).is_empty(), "only 2 of 3 joined");
        u.on_request((2, 2), 1, 512, RESUME_NONE);
        let done = u.tick((0, 0), 0, 16, &mut out);
        assert_eq!(done, vec![1]);
        assert_eq!(out[0].dests.len(), 3);
        assert_eq!(u.multicasts, 1);
    }

    #[test]
    fn flexible_lengths_accumulate_credit() {
        // Consumer requests 2x2KB; producer writes 4x1KB bursts: all flow.
        let mut u = P2pUnit::default();
        let mut out = Vec::new();
        u.on_request((1, 1), 0, 2048, RESUME_NONE);
        for t in 0..4 {
            u.submit_burst(burst(1024), 1, t);
        }
        let done = u.tick((0, 0), 0, 16, &mut out);
        assert_eq!(done, vec![0, 1], "only 2KB of credit so far");
        u.on_request((1, 1), 0, 2048, RESUME_NONE);
        let done = u.tick((0, 0), 0, 16, &mut out);
        assert_eq!(done, vec![2, 3]);
        assert_eq!(out.len(), 4);
    }

    #[test]
    fn producer_larger_bursts_than_consumer() {
        // Producer writes 1x4KB; consumer pulls 1KB at a time: the burst
        // flows in partial chunks against available credit (the tag only
        // completes at the end).
        let mut u = P2pUnit::default();
        let mut out = Vec::new();
        u.submit_burst(burst(4096), 1, 9);
        for _ in 0..3 {
            u.on_request((2, 0), 1, 1024, RESUME_NONE);
            assert!(u.tick((0, 0), 0, 16, &mut out).is_empty(), "not fully sent yet");
        }
        u.on_request((2, 0), 1, 1024, RESUME_NONE);
        assert_eq!(u.tick((0, 0), 0, 16, &mut out), vec![9]);
        assert_eq!(out.len(), 4, "four 1KB chunks");
        assert!(out.iter().all(|m| m.payload.len() == 1024));
    }

    #[test]
    fn same_tile_two_slots_single_dest_coord() {
        let mut u = P2pUnit::default();
        let mut out = Vec::new();
        u.submit_burst(burst(256), 2, 0);
        u.on_request((1, 2), 0, 256, RESUME_NONE);
        u.on_request((1, 2), 1, 256, RESUME_NONE);
        u.tick((0, 0), 0, 16, &mut out);
        assert_eq!(out[0].dests.as_slice(), &[(1, 2)], "coords deduplicated");
        // Both slots participate.
        assert!(cons_participates(&out[0].dests, out[0].cons_slots, (1, 2), 0));
        assert!(cons_participates(&out[0].dests, out[0].cons_slots, (1, 2), 1));
        assert!(!cons_participates(&out[0].dests, out[0].cons_slots, (0, 1), 0));
    }

    #[test]
    fn transaction_uses_first_n_requesters() {
        let mut u = P2pUnit::default();
        let mut out = Vec::new();
        u.on_request((0, 1), 0, 128, RESUME_NONE);
        u.on_request((0, 2), 0, 128, RESUME_NONE);
        u.on_request((2, 2), 0, 128, RESUME_NONE); // late third consumer: not in n=2 txn
        u.submit_burst(burst(128), 2, 0);
        u.tick((0, 0), 0, 16, &mut out);
        assert_eq!(out[0].dests.as_slice(), &[(0, 1), (0, 2)]);
    }

    #[test]
    fn over_capacity_transaction_serializes_into_groups() {
        // 5 consumers on 5 distinct tiles against a 2-tile header: the
        // burst goes out as 3 messages (2+2+1 tiles), each consumer
        // participating in exactly one of them, full payload each.
        let mut u = P2pUnit::default();
        let mut out = Vec::new();
        let tiles = [(0u8, 1u8), (0, 2), (1, 0), (1, 1), (1, 2)];
        for &t in &tiles {
            u.on_request(t, 0, 256, RESUME_NONE);
        }
        u.submit_burst(burst(256), 5, 3);
        let done = u.tick((0, 0), 0, 2, &mut out);
        assert_eq!(done, vec![3], "tag completes once the whole burst is out");
        assert_eq!(out.len(), 3);
        assert_eq!(
            out.iter().map(|m| m.dests.len()).collect::<Vec<_>>(),
            vec![2, 2, 1]
        );
        for &t in &tiles {
            let covering: Vec<_> = out
                .iter()
                .filter(|m| cons_participates(&m.dests, m.cons_slots, t, 0))
                .collect();
            assert_eq!(covering.len(), 1, "tile {t:?} covered exactly once");
            assert_eq!(covering[0].payload.len(), 256);
        }
        assert_eq!(u.multicasts, 2, "the 1-tile trailer group is not a multicast");
    }

    #[test]
    fn reset_clears_state() {
        let mut u = P2pUnit::default();
        u.on_request((0, 1), 0, 128, RESUME_NONE);
        u.submit_burst(burst(128), 1, 0);
        u.reset();
        assert_eq!(u.consumer_count(), 0);
        assert_eq!(u.pending_bursts(), 0);
        assert!(u.replay_state().is_empty());
    }

    #[test]
    fn resume_replays_lost_bytes_from_the_ring() {
        let mut u = P2pUnit::with_window(4096);
        let mut out = Vec::new();
        u.on_request((1, 1), 0, 1024, RESUME_NONE);
        u.submit_burst(burst(1024), 1, 7);
        assert_eq!(u.tick((0, 0), 0, 16, &mut out), vec![7]);
        assert_eq!(out.len(), 1);
        // The message is lost in flight; the consumer re-requests the full
        // remainder from its exact stream offset.
        out.clear();
        u.on_request((1, 1), 0, 1024, 0);
        u.tick((0, 0), 0, 16, &mut out);
        assert_eq!(out.len(), 1, "replay goes out as one unicast message");
        assert_eq!(out[0].payload.len(), 1024);
        assert_eq!(out[0].dests.as_slice(), &[(1, 1)]);
        assert!(cons_participates(&out[0].dests, out[0].cons_slots, (1, 1), 0));
        assert_eq!((u.replayed_bytes, u.window_exceeded), (1024, 0));
    }

    #[test]
    fn repeated_resume_does_not_double_deliver() {
        let mut u = P2pUnit::with_window(4096);
        let mut out = Vec::new();
        u.on_request((1, 1), 0, 512, RESUME_NONE);
        u.submit_burst(burst(512), 1, 1);
        u.tick((0, 0), 0, 16, &mut out);
        out.clear();
        u.on_request((1, 1), 0, 512, 0);
        u.on_request((1, 1), 0, 512, 0); // retry fired again before delivery
        u.tick((0, 0), 0, 16, &mut out);
        assert_eq!(out.len(), 1, "one replay despite two identical re-requests");
        assert_eq!(u.replayed_bytes, 512);
    }

    #[test]
    fn a_lost_replay_is_retransmitted_on_the_next_resume() {
        // The absorb guard clears once a replay is emitted: a re-request a
        // full timeout later means the replay itself died on the mesh, and
        // the ring must serve it again (consumers drop any duplicate).
        let mut u = P2pUnit::with_window(4096);
        let mut out = Vec::new();
        u.on_request((1, 1), 0, 512, RESUME_NONE);
        u.submit_burst(burst(512), 1, 1);
        u.tick((0, 0), 0, 16, &mut out);
        out.clear();
        u.on_request((1, 1), 0, 512, 0);
        u.tick((0, 0), 0, 16, &mut out);
        assert_eq!(out.len(), 1);
        out.clear();
        u.on_request((1, 1), 0, 512, 0); // the replay was lost too
        u.tick((0, 0), 0, 16, &mut out);
        assert_eq!(out.len(), 1, "second replay after the first was lost");
        assert_eq!(u.replayed_bytes, 1024);
    }

    #[test]
    fn resume_before_the_window_counts_exceeded_and_grants_nothing() {
        let mut u = P2pUnit::with_window(256); // window smaller than the stream
        let mut out = Vec::new();
        u.on_request((1, 1), 0, 1024, RESUME_NONE);
        u.submit_burst(burst(1024), 1, 3);
        u.tick((0, 0), 0, 16, &mut out);
        out.clear();
        u.on_request((1, 1), 0, 1024, 0); // offset 0 fell out of the ring
        u.tick((0, 0), 0, 16, &mut out);
        assert!(out.is_empty(), "no replay, no fresh credit");
        assert_eq!((u.replayed_bytes, u.window_exceeded), (0, 1));
    }

    #[test]
    fn resume_with_replay_disabled_is_a_plain_credit_add() {
        // The pre-replay behavior, byte-identical: a resume-carrying
        // re-request on a window-0 unit just adds credit, so the producer
        // streams its *next* bytes (the latched-corruption path).
        let mut u = P2pUnit::default();
        let mut out = Vec::new();
        u.on_request((1, 1), 0, 512, RESUME_NONE);
        u.submit_burst(burst(1024), 1, 5);
        u.tick((0, 0), 0, 16, &mut out);
        assert_eq!(out.len(), 1);
        u.on_request((1, 1), 0, 512, 0); // resume ignored: window off
        assert_eq!(u.tick((0, 0), 0, 16, &mut out), vec![5]);
        assert_eq!(out.len(), 2, "next chunk, not a replay");
        assert_eq!(u.replayed_bytes, 0);
    }

    #[test]
    fn mid_stream_resume_replays_exact_bytes_and_replaces_credit() {
        // Stream four distinct 256-byte bursts; the delivery of the last
        // two is lost.  Resuming at offset 512 replays exactly their bytes
        // from the ring and grants no fresh credit (512 asked, 512 had).
        let mut u = P2pUnit::with_window(1024);
        let mut out = Vec::new();
        u.on_request((2, 2), 0, 1024, RESUME_NONE);
        for t in 0..4u32 {
            u.submit_burst(Arc::new(vec![t as u8; 256]), 1, t);
        }
        u.tick((0, 0), 0, 16, &mut out);
        assert_eq!(out.len(), 4);
        out.clear();
        u.on_request((2, 2), 0, 512, 512);
        u.tick((0, 0), 0, 16, &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].payload.len(), 512);
        assert_eq!(out[0].payload[..256], [2u8; 256][..]);
        assert_eq!(out[0].payload[256..], [3u8; 256][..]);
        assert_eq!(u.replay_state(), vec![((2, 2), 0, 1024, 1024)]);
        assert_eq!(u.replayed_bytes, 512);
    }

    fn msg_seq(m: &Message) -> u32 {
        match m.kind {
            MsgKind::P2pData { seq, .. } => seq,
            _ => panic!("unexpected kind"),
        }
    }

    #[test]
    fn armed_sends_tag_data_with_stream_offsets() {
        // With the window armed, `seq` is the chunk's stream offset — the
        // consumer-side placement key that makes loss detectable — and a
        // replay carries the offset it resumes at, not a fresh counter.
        let mut u = P2pUnit::with_window(4096);
        let mut out = Vec::new();
        u.on_request((1, 1), 0, 1024, RESUME_NONE);
        for t in 0..2 {
            u.submit_burst(burst(256), 1, t);
        }
        u.tick((0, 0), 0, 16, &mut out);
        assert_eq!(out.iter().map(msg_seq).collect::<Vec<_>>(), vec![0, 256]);
        out.clear();
        u.on_request((1, 1), 0, 256, 256); // second chunk lost: resume at 256
        u.tick((0, 0), 0, 16, &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(msg_seq(&out[0]), 256, "replay tagged with its resume offset");
    }

    #[test]
    fn armed_multicast_shares_one_stream_offset() {
        // Both consumers' rings advance in lockstep, so the single
        // multicast header's offset is exact for each of them.
        let mut u = P2pUnit::with_window(4096);
        let mut out = Vec::new();
        u.on_request((0, 1), 0, 512, RESUME_NONE);
        u.on_request((1, 0), 0, 512, RESUME_NONE);
        for t in 0..2 {
            u.submit_burst(burst(256), 2, t);
        }
        u.tick((0, 0), 0, 16, &mut out);
        assert_eq!(out.len(), 2);
        assert_eq!(out.iter().map(msg_seq).collect::<Vec<_>>(), vec![0, 256]);
        assert_eq!(out[1].dests.len(), 2);
    }
}
