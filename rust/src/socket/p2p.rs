//! Producer-side P2P / multicast unit.
//!
//! ESP's P2P is *pull-based* to satisfy the consumption assumption (messages
//! put on the NoC are always consumed, preventing message-dependent
//! deadlock): consumers send requests, and the producer only injects data
//! that consumers have asked for.  The paper's enhancements implemented
//! here:
//!
//! - requests carry a **length**, so producer and consumer burst shapes may
//!   differ (only total bytes per transaction must match) — the unit keeps a
//!   per-consumer *credit* of requested bytes;
//! - a write burst with `user == n >= 2` waits until `n` distinct consumers
//!   have joined the transaction, then sends **one multicast message** whose
//!   header carries all destination coordinates.  A transaction whose
//!   distinct destination *tiles* exceed the header capacity (possible past
//!   the paper's operating points, e.g. unpacked fan-outs on big meshes)
//!   serializes into one message per destination group instead.

use std::collections::VecDeque;
use std::sync::Arc;

use crate::noc::{Coord, DestList, Message, MsgKind};

/// A consumer that has sent at least one pull request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Consumer {
    /// Consumer tile.
    pub coord: Coord,
    /// Consumer socket slot on that tile.
    pub slot: u8,
    /// Outstanding requested bytes not yet served.
    pub credit: u64,
}

/// A write burst waiting for consumer credit.
#[derive(Debug)]
struct PendingBurst {
    data: Arc<Vec<u8>>,
    ndests: u16,
    tag: u32,
    /// Bytes already sent (partial sends against available credit).
    sent: usize,
}

/// Producer-side state for one socket.
#[derive(Debug, Default)]
pub struct P2pUnit {
    /// Consumers in arrival order; the first `ndests` form the transaction.
    consumers: Vec<Consumer>,
    bursts: VecDeque<PendingBurst>,
    seq: u32,
    /// Stats: bytes sent via P2P/multicast.
    pub bytes_sent: u64,
    /// Stats: multicast messages (>= 2 dests) sent.
    pub multicasts: u64,
}

/// Encode the per-destination slot participation mask: bit `2*i + slot` is
/// set when `(dests[i], slot)` receives the message.
pub fn encode_cons_slots(dests: &[Coord], pairs: &[(Coord, u8)]) -> u32 {
    let mut mask = 0u32;
    for &(c, s) in pairs {
        let i = dests.iter().position(|&d| d == c).expect("consumer coord in dest list");
        mask |= 1 << (2 * i + s as usize);
    }
    mask
}

/// Does `(coord, slot)` participate in a message with `dests`/`cons_slots`?
pub fn cons_participates(dests: &DestList, cons_slots: u32, coord: Coord, slot: u8) -> bool {
    dests
        .as_slice()
        .iter()
        .position(|&d| d == coord)
        .is_some_and(|i| cons_slots & (1 << (2 * i + slot as usize)) != 0)
}

impl P2pUnit {
    /// Record a consumer pull request of `len` bytes.
    pub fn on_request(&mut self, coord: Coord, slot: u8, len: u32) {
        if let Some(c) =
            self.consumers.iter_mut().find(|c| c.coord == coord && c.slot == slot)
        {
            c.credit += len as u64;
        } else {
            self.consumers.push(Consumer { coord, slot, credit: len as u64 });
        }
    }

    /// Queue a write burst for `ndests` consumers (tag completes once the
    /// whole burst has been sent).
    pub fn submit_burst(&mut self, data: Arc<Vec<u8>>, ndests: u16, tag: u32) {
        assert!(ndests >= 1, "P2P burst needs at least one destination");
        self.bursts.push_back(PendingBurst { data, ndests, tag, sent: 0 });
    }

    /// Try to send queued bursts (in order).  A burst larger than the
    /// consumers' outstanding credit is sent **partially** — required for
    /// the flexible burst-shape enhancement: a 4 KB producer burst against
    /// a consumer pulling 1 KB at a time must flow chunk by chunk, not
    /// wait for four outstanding requests (which would deadlock once the
    /// consumer's request window is smaller than the producer's burst).
    /// Appends outgoing messages and returns the tags of bursts fully sent.
    pub fn tick(
        &mut self,
        self_coord: Coord,
        self_slot: u8,
        mcast_capacity: usize,
        out: &mut Vec<Message>,
    ) -> Vec<u32> {
        let mut done = Vec::new();
        while let Some(front) = self.bursts.front() {
            let n = front.ndests as usize;
            if self.consumers.len() < n {
                break; // waiting for more consumers to join (paper §3)
            }
            let remaining = front.data.len() - front.sent;
            let credit =
                self.consumers[..n].iter().map(|c| c.credit).min().unwrap_or(0) as usize;
            let chunk = remaining.min(credit);
            if chunk == 0 {
                break; // head-of-line burst lacks credit; preserve order
            }
            // Distinct destination tiles (two slots on one tile share the
            // single delivered copy).
            let mut dests: Vec<Coord> = Vec::new();
            let mut pairs: Vec<(Coord, u8)> = Vec::new();
            for c in &self.consumers[..n] {
                if !dests.contains(&c.coord) {
                    dests.push(c.coord);
                }
                pairs.push((c.coord, c.slot));
            }
            for c in &mut self.consumers[..n] {
                c.credit -= chunk as u64;
            }
            self.bytes_sent += (chunk * n) as u64;
            let front = self.bursts.front_mut().unwrap();
            let payload: Arc<Vec<u8>> = if chunk == front.data.len() {
                front.data.clone()
            } else {
                Arc::new(front.data[front.sent..front.sent + chunk].to_vec())
            };
            front.sent += chunk;
            // One header encodes at most `mcast_capacity` destination
            // tiles.  A transaction spanning more tiles serializes into one
            // message per destination group — the producer socket replays
            // the burst per group, as the RTL would — so an over-capacity
            // fan-out degrades instead of being unsendable.  (Every Fig. 6
            // configuration fits one group; extra messages only appear
            // past the paper's operating points.)
            for group in dests.chunks(mcast_capacity.max(1)) {
                let group_pairs: Vec<(Coord, u8)> =
                    pairs.iter().copied().filter(|(c, _)| group.contains(c)).collect();
                let cons_slots = encode_cons_slots(group, &group_pairs);
                if group.len() >= 2 {
                    self.multicasts += 1;
                }
                let kind = MsgKind::P2pData { seq: self.seq, prod_slot: self_slot };
                self.seq += 1;
                out.push(Message {
                    src: self_coord,
                    dests: DestList::from_slice(group),
                    kind,
                    payload: payload.clone(),
                    cons_slots,
                });
            }
            if front.sent == front.data.len() {
                done.push(front.tag);
                self.bursts.pop_front();
            }
        }
        done
    }

    /// Reset transaction state at invocation end.
    pub fn reset(&mut self) {
        self.consumers.clear();
        self.bursts.clear();
        self.seq = 0;
    }

    /// Consumers currently joined.
    pub fn consumer_count(&self) -> usize {
        self.consumers.len()
    }

    /// Bursts waiting for credit.
    pub fn pending_bursts(&self) -> usize {
        self.bursts.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn burst(n: usize) -> Arc<Vec<u8>> {
        Arc::new(vec![0xAB; n])
    }

    #[test]
    fn unicast_waits_for_request_then_sends() {
        let mut u = P2pUnit::default();
        let mut out = Vec::new();
        u.submit_burst(burst(1024), 1, 7);
        assert!(u.tick((0, 0), 0, 16, &mut out).is_empty(), "no consumer yet");
        u.on_request((1, 1), 0, 1024);
        let done = u.tick((0, 0), 0, 16, &mut out);
        assert_eq!(done, vec![7]);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].dests.as_slice(), &[(1, 1)]);
    }

    #[test]
    fn multicast_waits_for_all_n_consumers() {
        let mut u = P2pUnit::default();
        let mut out = Vec::new();
        u.submit_burst(burst(512), 3, 1);
        u.on_request((0, 1), 0, 512);
        u.on_request((1, 0), 0, 512);
        assert!(u.tick((0, 0), 0, 16, &mut out).is_empty(), "only 2 of 3 joined");
        u.on_request((2, 2), 1, 512);
        let done = u.tick((0, 0), 0, 16, &mut out);
        assert_eq!(done, vec![1]);
        assert_eq!(out[0].dests.len(), 3);
        assert_eq!(u.multicasts, 1);
    }

    #[test]
    fn flexible_lengths_accumulate_credit() {
        // Consumer requests 2x2KB; producer writes 4x1KB bursts: all flow.
        let mut u = P2pUnit::default();
        let mut out = Vec::new();
        u.on_request((1, 1), 0, 2048);
        for t in 0..4 {
            u.submit_burst(burst(1024), 1, t);
        }
        let done = u.tick((0, 0), 0, 16, &mut out);
        assert_eq!(done, vec![0, 1], "only 2KB of credit so far");
        u.on_request((1, 1), 0, 2048);
        let done = u.tick((0, 0), 0, 16, &mut out);
        assert_eq!(done, vec![2, 3]);
        assert_eq!(out.len(), 4);
    }

    #[test]
    fn producer_larger_bursts_than_consumer() {
        // Producer writes 1x4KB; consumer pulls 1KB at a time: the burst
        // flows in partial chunks against available credit (the tag only
        // completes at the end).
        let mut u = P2pUnit::default();
        let mut out = Vec::new();
        u.submit_burst(burst(4096), 1, 9);
        for _ in 0..3 {
            u.on_request((2, 0), 1, 1024);
            assert!(u.tick((0, 0), 0, 16, &mut out).is_empty(), "not fully sent yet");
        }
        u.on_request((2, 0), 1, 1024);
        assert_eq!(u.tick((0, 0), 0, 16, &mut out), vec![9]);
        assert_eq!(out.len(), 4, "four 1KB chunks");
        assert!(out.iter().all(|m| m.payload.len() == 1024));
    }

    #[test]
    fn same_tile_two_slots_single_dest_coord() {
        let mut u = P2pUnit::default();
        let mut out = Vec::new();
        u.submit_burst(burst(256), 2, 0);
        u.on_request((1, 2), 0, 256);
        u.on_request((1, 2), 1, 256);
        u.tick((0, 0), 0, 16, &mut out);
        assert_eq!(out[0].dests.as_slice(), &[(1, 2)], "coords deduplicated");
        // Both slots participate.
        assert!(cons_participates(&out[0].dests, out[0].cons_slots, (1, 2), 0));
        assert!(cons_participates(&out[0].dests, out[0].cons_slots, (1, 2), 1));
        assert!(!cons_participates(&out[0].dests, out[0].cons_slots, (0, 1), 0));
    }

    #[test]
    fn transaction_uses_first_n_requesters() {
        let mut u = P2pUnit::default();
        let mut out = Vec::new();
        u.on_request((0, 1), 0, 128);
        u.on_request((0, 2), 0, 128);
        u.on_request((2, 2), 0, 128); // late third consumer: not in n=2 txn
        u.submit_burst(burst(128), 2, 0);
        u.tick((0, 0), 0, 16, &mut out);
        assert_eq!(out[0].dests.as_slice(), &[(0, 1), (0, 2)]);
    }

    #[test]
    fn over_capacity_transaction_serializes_into_groups() {
        // 5 consumers on 5 distinct tiles against a 2-tile header: the
        // burst goes out as 3 messages (2+2+1 tiles), each consumer
        // participating in exactly one of them, full payload each.
        let mut u = P2pUnit::default();
        let mut out = Vec::new();
        let tiles = [(0u8, 1u8), (0, 2), (1, 0), (1, 1), (1, 2)];
        for &t in &tiles {
            u.on_request(t, 0, 256);
        }
        u.submit_burst(burst(256), 5, 3);
        let done = u.tick((0, 0), 0, 2, &mut out);
        assert_eq!(done, vec![3], "tag completes once the whole burst is out");
        assert_eq!(out.len(), 3);
        assert_eq!(
            out.iter().map(|m| m.dests.len()).collect::<Vec<_>>(),
            vec![2, 2, 1]
        );
        for &t in &tiles {
            let covering: Vec<_> = out
                .iter()
                .filter(|m| cons_participates(&m.dests, m.cons_slots, t, 0))
                .collect();
            assert_eq!(covering.len(), 1, "tile {t:?} covered exactly once");
            assert_eq!(covering[0].payload.len(), 256);
        }
        assert_eq!(u.multicasts, 2, "the 1-tile trailer group is not a multicast");
    }

    #[test]
    fn reset_clears_state() {
        let mut u = P2pUnit::default();
        u.on_request((0, 1), 0, 128);
        u.submit_burst(burst(128), 1, 0);
        u.reset();
        assert_eq!(u.consumer_count(), 0);
        assert_eq!(u.pending_bursts(), 0);
    }
}
