//! Deterministic fault injection: seeded plans that kill links or whole
//! routers at chosen cycles mid-simulation.
//!
//! A [`FaultPlan`] is data, not behaviour: the [`crate::coordinator::Soc`]
//! run loop applies each due [`FaultEvent`] to the NoC (rebuilding the
//! shared [`crate::noc::RouteTable`] and purging dead routers), and the
//! mesh's fault-drain pass drops the in-flight flits a dead link strands
//! (see DESIGN.md §fault model).  Everything is seeded through the crate's
//! SplitMix64 PRNG, so the same plan + scenario seed reproduces the same
//! degraded run byte-for-byte — `tests/prop_fault.rs` pins this.

use crate::noc::{Coord, Dir};
use crate::util::prng::Prng;

/// What dies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// The bidirectional link leaving router `at` in direction `dir`.
    Link {
        /// Router on one end of the link.
        at: Coord,
        /// Direction of the link from `at` (never `Local`).
        dir: Dir,
    },
    /// The whole router at `at` (all four links plus its queues).
    Router {
        /// The router to kill.
        at: Coord,
    },
}

/// One scheduled fault.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultEvent {
    /// Cycle at which the fault strikes (applied before that cycle's tick).
    pub cycle: u64,
    /// What dies.
    pub kind: FaultKind,
}

/// A deterministic schedule of faults, sorted by cycle.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// Plan from explicit events (sorted by cycle, stable order preserved
    /// for same-cycle events).
    pub fn new(mut events: Vec<FaultEvent>) -> Self {
        events.sort_by_key(|e| e.cycle);
        Self { events }
    }

    /// The empty plan (cycle-exact with no plan at all; `prop_fault` pins
    /// this).
    pub fn none() -> Self {
        Self::default()
    }

    /// Seeded link-kill storm: `links` random mesh links die at random
    /// cycles in `[window.0, window.1)`.  Victims may repeat (killing a
    /// dead link is a no-op), and any link of the `width x height` mesh is
    /// fair game — including ones whose loss cuts the mesh, in which case
    /// the run fails with a precise cause instead of completing degraded.
    pub fn link_storm(seed: u64, links: u32, width: u8, height: u8, window: (u64, u64)) -> Self {
        assert!(window.0 < window.1, "empty fault window");
        let mut rng = Prng::new(seed ^ 0xFA17_FA17_FA17_FA17);
        let mut events = Vec::with_capacity(links as usize);
        for _ in 0..links {
            let cycle = rng.range(window.0, window.1 - 1);
            // Pick an interior link: a router plus a direction that has a
            // neighbour.  East/South only — every physical link is the
            // East or South output of exactly one router, so this covers
            // all links uniformly without double-counting.
            let (at, dir) = loop {
                let y = rng.below(height as u64) as u8;
                let x = rng.below(width as u64) as u8;
                let dir = if rng.chance(0.5) { Dir::East } else { Dir::South };
                let ok = match dir {
                    Dir::East => x + 1 < width,
                    Dir::South => y + 1 < height,
                    _ => unreachable!(),
                };
                if ok {
                    break ((y, x), dir);
                }
            };
            events.push(FaultEvent { cycle, kind: FaultKind::Link { at, dir } });
        }
        Self::new(events)
    }

    /// The scheduled events, sorted by cycle.
    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    /// Number of scheduled events.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// No events scheduled?
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// One-line human summary ("2 link kills @ cycles 1200, 4807").
    pub fn describe(&self) -> String {
        if self.events.is_empty() {
            return "no faults".to_string();
        }
        let cycles: Vec<String> = self.events.iter().map(|e| e.cycle.to_string()).collect();
        let links = self
            .events
            .iter()
            .filter(|e| matches!(e.kind, FaultKind::Link { .. }))
            .count();
        let routers = self.events.len() - links;
        let mut what = Vec::new();
        if links > 0 {
            what.push(format!("{links} link kill{}", if links == 1 { "" } else { "s" }));
        }
        if routers > 0 {
            what.push(format!("{routers} router kill{}", if routers == 1 { "" } else { "s" }));
        }
        format!("{} @ cycles {}", what.join(" + "), cycles.join(", "))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn storm_is_deterministic_and_in_window() {
        let a = FaultPlan::link_storm(7, 4, 8, 8, (1000, 5000));
        let b = FaultPlan::link_storm(7, 4, 8, 8, (1000, 5000));
        assert_eq!(a, b, "same seed, same plan");
        assert_eq!(a.len(), 4);
        for e in a.events() {
            assert!((1000..5000).contains(&e.cycle));
            let FaultKind::Link { at, dir } = e.kind else { panic!("storm kills links only") };
            match dir {
                Dir::East => assert!(at.1 + 1 < 8),
                Dir::South => assert!(at.0 + 1 < 8),
                d => panic!("unexpected storm direction {d:?}"),
            }
        }
        assert_ne!(a, FaultPlan::link_storm(8, 4, 8, 8, (1000, 5000)), "seeds differ");
    }

    #[test]
    fn events_sort_by_cycle() {
        let p = FaultPlan::new(vec![
            FaultEvent { cycle: 90, kind: FaultKind::Router { at: (1, 1) } },
            FaultEvent { cycle: 10, kind: FaultKind::Link { at: (0, 0), dir: Dir::East } },
        ]);
        assert_eq!(p.events()[0].cycle, 10);
        assert_eq!(p.events()[1].cycle, 90);
        assert!(p.describe().contains("1 link kill + 1 router kill"));
    }

    #[test]
    fn empty_plan() {
        assert!(FaultPlan::none().is_empty());
        assert_eq!(FaultPlan::none().describe(), "no faults");
    }
}
