//! Dimension-ordered (XY) routing with lookahead, plus the multicast
//! destination-partitioning step.
//!
//! ESP routes X first, then Y: this guarantees the absence of routing
//! deadlock (no turn cycles).  *Lookahead* routing in the RTL computes the
//! next hop's output port one hop early so a flit spends a single cycle per
//! router; we model that by charging one cycle per hop.  For multicast, the
//! paper replicates the lookahead logic per destination — here
//! [`partition_dests`] computes every destination's direction in parallel
//! (one pass) and splits the destination list into per-output-port branches.

use super::flit::{Coord, DestList, Dir};

/// XY output direction from `cur` towards `dest` (X resolved first).
pub fn xy_dir(cur: Coord, dest: Coord) -> Dir {
    let (cy, cx) = cur;
    let (dy, dx) = dest;
    if dx > cx {
        Dir::East
    } else if dx < cx {
        Dir::West
    } else if dy > cy {
        Dir::South
    } else if dy < cy {
        Dir::North
    } else {
        Dir::Local
    }
}

/// Number of hops between two tiles under XY routing.
pub fn hop_count(a: Coord, b: Coord) -> u32 {
    (a.0 as i32 - b.0 as i32).unsigned_abs() + (a.1 as i32 - b.1 as i32).unsigned_abs()
}

/// Split a destination list by the output port each destination takes from
/// `cur`.  Returns `(directions_present_bitmask, per-port lists)`; this is
/// the fork decision of the multicast router, materialized.  The mesh hot
/// path uses the allocation-free [`branch_mask`] instead; this form remains
/// for analysis tools and the equivalence tests.
pub fn partition_dests(cur: Coord, dests: &DestList) -> (u8, [DestList; 5]) {
    let mut out: [DestList; 5] = Default::default();
    let mut mask = 0u8;
    for d in dests.iter() {
        let dir = xy_dir(cur, d);
        out[dir.idx()].push(d);
        mask |= 1 << dir.idx();
    }
    (mask, out)
}

/// True when tile `p` lies on the XY route from `src` to `dst`: first along
/// row `src.0` from column `src.1` to `dst.1`, then along column `dst.1`
/// from row `src.0` to `dst.0`.
#[inline]
pub fn on_xy_path(src: Coord, dst: Coord, p: Coord) -> bool {
    let between = |a: u8, b: u8, c: u8| (b.min(c)..=b.max(c)).contains(&a);
    (p.0 == src.0 && between(p.1, src.1, dst.1)) || (p.1 == dst.1 && between(p.0, src.0, dst.0))
}

/// Output-port mask a header flit of packet `(src, dests)` claims at router
/// `cur`, without materializing per-branch destination lists.
///
/// XY routing is deterministic, so the multicast replication tree is fixed
/// at injection time: the destination subset of the branch passing through
/// `cur` is exactly the destinations whose XY route visits `cur`, and the
/// fork decision at `cur` is their per-destination next-hop directions.
/// This is bit-for-bit the mask [`partition_dests`] computes on the carried
/// subset in the seed model (see `prop_mesh_equiv`), with O(dests) work and
/// zero copying per hop.
pub fn branch_mask(cur: Coord, src: Coord, dests: &DestList) -> u8 {
    let mut mask = 0u8;
    for d in dests.iter() {
        if on_xy_path(src, d, cur) {
            mask |= 1 << xy_dir(cur, d).idx();
        }
    }
    mask
}

/// Coordinate of the neighbour in direction `d` (None at mesh edge).
pub fn neighbor(cur: Coord, d: Dir, width: u8, height: u8) -> Option<Coord> {
    let (y, x) = cur;
    match d {
        Dir::North if y > 0 => Some((y - 1, x)),
        Dir::South if y + 1 < height => Some((y + 1, x)),
        Dir::East if x + 1 < width => Some((y, x + 1)),
        Dir::West if x > 0 => Some((y, x - 1)),
        Dir::Local => Some(cur),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn x_before_y() {
        assert_eq!(xy_dir((0, 0), (2, 2)), Dir::East);
        assert_eq!(xy_dir((0, 2), (2, 2)), Dir::South);
        assert_eq!(xy_dir((2, 2), (0, 0)), Dir::West);
        assert_eq!(xy_dir((2, 0), (0, 0)), Dir::North);
        assert_eq!(xy_dir((1, 1), (1, 1)), Dir::Local);
    }

    #[test]
    fn hops() {
        assert_eq!(hop_count((0, 0), (2, 3)), 5);
        assert_eq!(hop_count((1, 1), (1, 1)), 0);
    }

    #[test]
    fn partition_groups_by_dir() {
        let dests = DestList::from_slice(&[(0, 2), (2, 2), (1, 0), (1, 1)]);
        let (mask, parts) = partition_dests((1, 1), &dests);
        // (0,2) and (2,2) both go East first (x resolves before y).
        assert_eq!(parts[Dir::East.idx()].as_slice(), &[(0, 2), (2, 2)]);
        assert_eq!(parts[Dir::West.idx()].as_slice(), &[(1, 0)]);
        assert_eq!(parts[Dir::Local.idx()].as_slice(), &[(1, 1)]);
        assert_eq!(mask.count_ones(), 3);
    }

    #[test]
    fn on_path_covers_row_then_column() {
        // Route (1,0) -> (2,3): row 1 cols 0..=3, then col 3 rows 1..=2.
        for p in [(1, 0), (1, 1), (1, 2), (1, 3), (2, 3)] {
            assert!(on_xy_path((1, 0), (2, 3), p), "{p:?} should be on path");
        }
        for p in [(0, 0), (2, 0), (2, 1), (2, 2), (0, 3)] {
            assert!(!on_xy_path((1, 0), (2, 3), p), "{p:?} should be off path");
        }
        assert!(on_xy_path((1, 1), (1, 1), (1, 1)), "self route");
    }

    #[test]
    fn branch_mask_matches_partition_along_the_tree() {
        // Walk the replication tree the carried-list model would build and
        // check the derived mask agrees with partition_dests at every node.
        fn walk(cur: Coord, src: Coord, carried: &DestList, full: &DestList, w: u8, h: u8) {
            let (mask, parts) = partition_dests(cur, carried);
            assert_eq!(branch_mask(cur, src, full), mask, "at {cur:?}");
            for d in Dir::ALL {
                if d == Dir::Local || mask & (1 << d.idx()) == 0 {
                    continue;
                }
                let next = neighbor(cur, d, w, h).unwrap();
                walk(next, src, &parts[d.idx()], full, w, h);
            }
        }
        let dests = DestList::from_slice(&[(0, 2), (2, 2), (1, 0), (1, 1), (2, 0), (0, 0)]);
        walk((1, 1), (1, 1), &dests, &dests, 3, 3);
        walk((0, 0), (0, 0), &dests, &dests, 3, 3);
    }

    #[test]
    fn neighbor_edges() {
        assert_eq!(neighbor((0, 0), Dir::North, 3, 3), None);
        assert_eq!(neighbor((0, 0), Dir::West, 3, 3), None);
        assert_eq!(neighbor((0, 0), Dir::South, 3, 3), Some((1, 0)));
        assert_eq!(neighbor((2, 2), Dir::East, 3, 3), None);
        assert_eq!(neighbor((1, 1), Dir::Local, 3, 3), Some((1, 1)));
    }
}
