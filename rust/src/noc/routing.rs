//! Dimension-ordered routing with lookahead, plus the multicast
//! destination-partitioning step.
//!
//! ESP's baseline routes X first, then Y: this guarantees the absence of
//! routing deadlock (no turn cycles).  *Lookahead* routing in the RTL
//! computes the next hop's output port one hop early so a flit spends a
//! single cycle per router; we model that by charging one cycle per hop.
//! For multicast, the paper replicates the lookahead logic per destination —
//! here [`partition_dests`] computes every destination's direction in
//! parallel (one pass) and splits the destination list into per-output-port
//! branches.
//!
//! Planes may now route under different [`Orientation`]s (DESIGN.md
//! §routing orientations): YX resolves Y first (column-then-row), and the
//! *flipped* variants mirror the fault-table tie-break preference while
//! sharing their cousin's minimal paths — on a bidirectional mesh,
//! coordinate-flipped dimension-ordered routing traverses exactly the links
//! of the unflipped regime, so only XY vs YX are path-distinct.  Every
//! orientation is a single dimension-ordered policy per plane, hence
//! deadlock-free; planes share no links, so mixing orientations *across*
//! planes is safe.

use super::flit::{Coord, DestList, Dir};

/// Per-plane routing orientation: which dimension resolves first, and (for
/// the flipped variants) which way the fault-table tie-breaks lean.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Orientation {
    /// X first, then Y — the paper's baseline (and the byte-exact legacy).
    #[default]
    Xy,
    /// Y first, then X — the path-distinct alternative.
    Yx,
    /// XY paths with mirrored fault-table tie-breaks.
    FlippedXy,
    /// YX paths with mirrored fault-table tie-breaks.
    FlippedYx,
}

impl Orientation {
    /// Every orientation, in code order.
    pub const ALL: [Orientation; 4] =
        [Orientation::Xy, Orientation::Yx, Orientation::FlippedXy, Orientation::FlippedYx];

    /// Stable short code (JSON fields, CLI flags, bench point names).
    pub fn code(self) -> &'static str {
        match self {
            Orientation::Xy => "xy",
            Orientation::Yx => "yx",
            Orientation::FlippedXy => "flipped_xy",
            Orientation::FlippedYx => "flipped_yx",
        }
    }

    /// Parse a [`code`](Self::code) back into an orientation.
    pub fn from_code(code: &str) -> Option<Self> {
        Orientation::ALL.into_iter().find(|o| o.code() == code)
    }

    /// Closed-form output direction from `cur` towards `dest`.  The
    /// flipped variants share their cousin's minimal paths (see module
    /// doc), so only the first-resolved dimension matters here.
    #[inline]
    pub fn dir(self, cur: Coord, dest: Coord) -> Dir {
        match self {
            Orientation::Xy | Orientation::FlippedXy => xy_dir(cur, dest),
            Orientation::Yx | Orientation::FlippedYx => yx_dir(cur, dest),
        }
    }

    /// True when tile `p` lies on this orientation's route from `src` to
    /// `dst`.
    #[inline]
    pub fn on_path(self, src: Coord, dst: Coord, p: Coord) -> bool {
        match self {
            Orientation::Xy | Orientation::FlippedXy => on_xy_path(src, dst, p),
            Orientation::Yx | Orientation::FlippedYx => on_yx_path(src, dst, p),
        }
    }

    /// Fault-table tie-break order: when the preferred dimension-ordered
    /// step is dead or not downhill, the BFS picks the first downhill
    /// direction in this order.  XY keeps the legacy order (byte-exact
    /// with pre-orientation tables); the others mirror it so detour load
    /// spreads instead of piling onto the same fallback links.
    pub fn fallback(self) -> [Dir; 4] {
        match self {
            Orientation::Xy => [Dir::North, Dir::South, Dir::East, Dir::West],
            Orientation::Yx => [Dir::West, Dir::East, Dir::South, Dir::North],
            Orientation::FlippedXy => [Dir::South, Dir::North, Dir::West, Dir::East],
            Orientation::FlippedYx => [Dir::East, Dir::West, Dir::North, Dir::South],
        }
    }
}

/// XY output direction from `cur` towards `dest` (X resolved first).
pub fn xy_dir(cur: Coord, dest: Coord) -> Dir {
    let (cy, cx) = cur;
    let (dy, dx) = dest;
    if dx > cx {
        Dir::East
    } else if dx < cx {
        Dir::West
    } else if dy > cy {
        Dir::South
    } else if dy < cy {
        Dir::North
    } else {
        Dir::Local
    }
}

/// YX output direction from `cur` towards `dest` (Y resolved first).
pub fn yx_dir(cur: Coord, dest: Coord) -> Dir {
    let (cy, cx) = cur;
    let (dy, dx) = dest;
    if dy > cy {
        Dir::South
    } else if dy < cy {
        Dir::North
    } else if dx > cx {
        Dir::East
    } else if dx < cx {
        Dir::West
    } else {
        Dir::Local
    }
}

/// Number of hops between two tiles under any dimension-ordered routing
/// (both orientations take minimal Manhattan paths).
pub fn hop_count(a: Coord, b: Coord) -> u32 {
    (a.0 as i32 - b.0 as i32).unsigned_abs() + (a.1 as i32 - b.1 as i32).unsigned_abs()
}

/// Split a destination list by the output port each destination takes from
/// `cur` under orientation `o`.  Returns `(directions_present_bitmask,
/// per-port lists)`; this is the fork decision of the multicast router,
/// materialized.  The mesh hot path uses the allocation-free
/// [`oriented_branch_mask`] instead; this form remains for analysis tools
/// and the equivalence tests.
pub fn partition_dests_oriented(
    o: Orientation,
    cur: Coord,
    dests: &DestList,
) -> (u8, [DestList; 5]) {
    let mut out: [DestList; 5] = Default::default();
    let mut mask = 0u8;
    for d in dests.iter() {
        let dir = o.dir(cur, d);
        out[dir.idx()].push(d);
        mask |= 1 << dir.idx();
    }
    (mask, out)
}

/// [`partition_dests_oriented`] under the baseline XY orientation.
pub fn partition_dests(cur: Coord, dests: &DestList) -> (u8, [DestList; 5]) {
    partition_dests_oriented(Orientation::Xy, cur, dests)
}

/// True when tile `p` lies on the XY route from `src` to `dst`: first along
/// row `src.0` from column `src.1` to `dst.1`, then along column `dst.1`
/// from row `src.0` to `dst.0`.
#[inline]
pub fn on_xy_path(src: Coord, dst: Coord, p: Coord) -> bool {
    let between = |a: u8, b: u8, c: u8| (b.min(c)..=b.max(c)).contains(&a);
    (p.0 == src.0 && between(p.1, src.1, dst.1)) || (p.1 == dst.1 && between(p.0, src.0, dst.0))
}

/// True when tile `p` lies on the YX route from `src` to `dst`: first along
/// column `src.1` from row `src.0` to `dst.0`, then along row `dst.0` from
/// column `src.1` to `dst.1`.
#[inline]
pub fn on_yx_path(src: Coord, dst: Coord, p: Coord) -> bool {
    let between = |a: u8, b: u8, c: u8| (b.min(c)..=b.max(c)).contains(&a);
    (p.1 == src.1 && between(p.0, src.0, dst.0)) || (p.0 == dst.0 && between(p.1, src.1, dst.1))
}

/// Output-port mask a header flit of packet `(src, dests)` claims at router
/// `cur` under orientation `o`, without materializing per-branch
/// destination lists.
///
/// Dimension-ordered routing is deterministic, so the multicast replication
/// tree is fixed at injection time: the destination subset of the branch
/// passing through `cur` is exactly the destinations whose route visits
/// `cur`, and the fork decision at `cur` is their per-destination next-hop
/// directions.  This is bit-for-bit the mask [`partition_dests_oriented`]
/// computes on the carried subset in the seed model (see
/// `prop_mesh_equiv`), with O(dests) work and zero copying per hop.
pub fn oriented_branch_mask(o: Orientation, cur: Coord, src: Coord, dests: &DestList) -> u8 {
    let mut mask = 0u8;
    for d in dests.iter() {
        if o.on_path(src, d, cur) {
            mask |= 1 << o.dir(cur, d).idx();
        }
    }
    mask
}

/// [`oriented_branch_mask`] under the baseline XY orientation.
pub fn branch_mask(cur: Coord, src: Coord, dests: &DestList) -> u8 {
    oriented_branch_mask(Orientation::Xy, cur, src, dests)
}

/// Coordinate of the neighbour in direction `d` (None at mesh edge).
pub fn neighbor(cur: Coord, d: Dir, width: u8, height: u8) -> Option<Coord> {
    let (y, x) = cur;
    match d {
        Dir::North if y > 0 => Some((y - 1, x)),
        Dir::South if y + 1 < height => Some((y + 1, x)),
        Dir::East if x + 1 < width => Some((y, x + 1)),
        Dir::West if x > 0 => Some((y, x - 1)),
        Dir::Local => Some(cur),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn x_before_y() {
        assert_eq!(xy_dir((0, 0), (2, 2)), Dir::East);
        assert_eq!(xy_dir((0, 2), (2, 2)), Dir::South);
        assert_eq!(xy_dir((2, 2), (0, 0)), Dir::West);
        assert_eq!(xy_dir((2, 0), (0, 0)), Dir::North);
        assert_eq!(xy_dir((1, 1), (1, 1)), Dir::Local);
    }

    #[test]
    fn y_before_x() {
        assert_eq!(yx_dir((0, 0), (2, 2)), Dir::South);
        assert_eq!(yx_dir((2, 0), (2, 2)), Dir::East);
        assert_eq!(yx_dir((2, 2), (0, 0)), Dir::North);
        assert_eq!(yx_dir((0, 2), (0, 0)), Dir::West);
        assert_eq!(yx_dir((1, 1), (1, 1)), Dir::Local);
    }

    #[test]
    fn orientation_codes_roundtrip() {
        for o in Orientation::ALL {
            assert_eq!(Orientation::from_code(o.code()), Some(o));
        }
        assert_eq!(Orientation::from_code("zigzag"), None);
        assert_eq!(Orientation::default(), Orientation::Xy);
    }

    #[test]
    fn flipped_variants_share_their_cousins_paths() {
        for cy in 0..4u8 {
            for cx in 0..4u8 {
                for dy in 0..4u8 {
                    for dx in 0..4u8 {
                        let (c, d) = ((cy, cx), (dy, dx));
                        assert_eq!(Orientation::FlippedXy.dir(c, d), xy_dir(c, d));
                        assert_eq!(Orientation::FlippedYx.dir(c, d), yx_dir(c, d));
                        assert_eq!(
                            Orientation::FlippedXy.on_path(c, d, (dy, cx)),
                            on_xy_path(c, d, (dy, cx))
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn fallback_orders_cover_all_directions() {
        for o in Orientation::ALL {
            let mut mask = 0u8;
            for d in o.fallback() {
                mask |= 1 << d.idx();
            }
            assert_eq!(mask.count_ones(), 4, "{o:?}: fallback must name each mesh direction once");
        }
    }

    #[test]
    fn hops() {
        assert_eq!(hop_count((0, 0), (2, 3)), 5);
        assert_eq!(hop_count((1, 1), (1, 1)), 0);
    }

    #[test]
    fn partition_groups_by_dir() {
        let dests = DestList::from_slice(&[(0, 2), (2, 2), (1, 0), (1, 1)]);
        let (mask, parts) = partition_dests((1, 1), &dests);
        // (0,2) and (2,2) both go East first (x resolves before y).
        assert_eq!(parts[Dir::East.idx()].as_slice(), &[(0, 2), (2, 2)]);
        assert_eq!(parts[Dir::West.idx()].as_slice(), &[(1, 0)]);
        assert_eq!(parts[Dir::Local.idx()].as_slice(), &[(1, 1)]);
        assert_eq!(mask.count_ones(), 3);
    }

    #[test]
    fn yx_partition_groups_by_row_first() {
        let dests = DestList::from_slice(&[(0, 2), (2, 2), (1, 0), (1, 1)]);
        let (mask, parts) = partition_dests_oriented(Orientation::Yx, (1, 1), &dests);
        // (0,2) goes North first, (2,2) South first (y resolves before x).
        assert_eq!(parts[Dir::North.idx()].as_slice(), &[(0, 2)]);
        assert_eq!(parts[Dir::South.idx()].as_slice(), &[(2, 2)]);
        assert_eq!(parts[Dir::West.idx()].as_slice(), &[(1, 0)]);
        assert_eq!(parts[Dir::Local.idx()].as_slice(), &[(1, 1)]);
        assert_eq!(mask.count_ones(), 4);
    }

    #[test]
    fn on_path_covers_row_then_column() {
        // Route (1,0) -> (2,3): row 1 cols 0..=3, then col 3 rows 1..=2.
        for p in [(1, 0), (1, 1), (1, 2), (1, 3), (2, 3)] {
            assert!(on_xy_path((1, 0), (2, 3), p), "{p:?} should be on path");
        }
        for p in [(0, 0), (2, 0), (2, 1), (2, 2), (0, 3)] {
            assert!(!on_xy_path((1, 0), (2, 3), p), "{p:?} should be off path");
        }
        assert!(on_xy_path((1, 1), (1, 1), (1, 1)), "self route");
    }

    #[test]
    fn yx_on_path_covers_column_then_row() {
        // YX route (1,0) -> (2,3): col 0 rows 1..=2, then row 2 cols 0..=3.
        for p in [(1, 0), (2, 0), (2, 1), (2, 2), (2, 3)] {
            assert!(on_yx_path((1, 0), (2, 3), p), "{p:?} should be on path");
        }
        for p in [(0, 0), (1, 1), (1, 2), (1, 3), (0, 3)] {
            assert!(!on_yx_path((1, 0), (2, 3), p), "{p:?} should be off path");
        }
        assert!(on_yx_path((1, 1), (1, 1), (1, 1)), "self route");
    }

    #[test]
    fn branch_mask_matches_partition_along_the_tree() {
        // Walk the replication tree the carried-list model would build and
        // check the derived mask agrees with partition_dests at every node,
        // for every orientation.
        fn walk(
            o: Orientation,
            cur: Coord,
            src: Coord,
            carried: &DestList,
            full: &DestList,
            w: u8,
            h: u8,
        ) {
            let (mask, parts) = partition_dests_oriented(o, cur, carried);
            assert_eq!(oriented_branch_mask(o, cur, src, full), mask, "{o:?} at {cur:?}");
            for d in Dir::ALL {
                if d == Dir::Local || mask & (1 << d.idx()) == 0 {
                    continue;
                }
                let next = neighbor(cur, d, w, h).unwrap();
                walk(o, next, src, &parts[d.idx()], full, w, h);
            }
        }
        let dests = DestList::from_slice(&[(0, 2), (2, 2), (1, 0), (1, 1), (2, 0), (0, 0)]);
        for o in Orientation::ALL {
            walk(o, (1, 1), (1, 1), &dests, &dests, 3, 3);
            walk(o, (0, 0), (0, 0), &dests, &dests, 3, 3);
        }
    }

    #[test]
    fn neighbor_edges() {
        assert_eq!(neighbor((0, 0), Dir::North, 3, 3), None);
        assert_eq!(neighbor((0, 0), Dir::West, 3, 3), None);
        assert_eq!(neighbor((0, 0), Dir::South, 3, 3), Some((1, 0)));
        assert_eq!(neighbor((2, 2), Dir::East, 3, 3), None);
        assert_eq!(neighbor((1, 1), Dir::Local, 3, 3), Some((1, 1)));
    }
}
