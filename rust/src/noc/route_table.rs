//! Table-driven routing: a precomputed next-hop table per mesh that
//! reproduces dimension-ordered routing bit-exactly on a healthy mesh and
//! routes *around* harvested routers and dead links on a degraded one.
//!
//! Every table carries an [`Orientation`] (DESIGN.md §routing
//! orientations) and has two regimes:
//!
//! - **Pristine** ([`RouteTable::closed_form`], with [`RouteTable::xy`]
//!   the legacy XY shorthand): no table memory at all — every query
//!   delegates to the closed-form [`super::routing`] primitives, so the
//!   no-fault hot path is byte-for-byte the seed model (this is the
//!   "zero-cost when healthy" invariant of DESIGN.md §fault model).
//! - **Materialized** ([`RouteTable::build_oriented`], with
//!   [`RouteTable::build`] the XY shorthand): an `n x n` next-hop array
//!   computed by per-destination BFS over the live subgraph.  Ties between
//!   equally short next hops prefer the orientation's dimension-ordered
//!   direction and then its [`Orientation::fallback`] order, so a
//!   materialized table with *nothing* dead is bit-identical to its
//!   closed form (property-tested in `tests/prop_fault.rs` and
//!   `tests/prop_orientation.rs`), and a degraded table deviates only
//!   where a route must detour.
//!
//! Multicast re-partitioning falls out of determinism: the next hop
//! depends only on `(current, destination)`, so each destination's path
//! from the packet's origin is unique and the branch set at any router is
//! recomputable from the interned `(origin, dests)` pair — exactly the
//! contract [`super::routing::oriented_branch_mask`] established.
//! Destinations that are unreachable on the current table simply
//! contribute no branch (the mesh counts them as dropped at injection).

use super::flit::{Coord, DestList, Dir};
use super::routing::{neighbor, oriented_branch_mask, Orientation};

/// Next-hop sentinel: no live path from this router to that destination.
const UNREACHABLE: u8 = 0xFF;

/// Distance sentinel for the BFS.
const INF: u32 = u32::MAX;

/// Per-mesh routing table (shared read-only across planes of the same
/// orientation).
#[derive(Debug, Clone)]
pub struct RouteTable {
    width: u8,
    height: u8,
    /// Routing orientation this table was derived under.
    orient: Orientation,
    /// `None` = pristine closed-form fast path; `Some` = materialized.
    deg: Option<Degraded>,
}

/// The materialized form: next hops plus the dead sets they were built
/// from (the mesh consults these to drain flits into a dead link).
#[derive(Debug, Clone)]
struct Degraded {
    /// `next[cur * n + dest]`: [`Dir`] index, or [`UNREACHABLE`].
    next: Box<[u8]>,
    /// Dead (harvested or killed) routers.
    dead_router: Box<[bool]>,
    /// Per-router bitmask of dead *output* links (dir-index bits 0..4).
    dead_out: Box<[u8]>,
    /// Any router or link actually dead?  (A materialized table over a
    /// fully healthy mesh routes exactly like XY and has no faults.)
    faulted: bool,
}

impl RouteTable {
    /// Pristine XY table for a `width x height` mesh — the legacy
    /// shorthand for [`closed_form`](Self::closed_form) under
    /// [`Orientation::Xy`].
    pub fn xy(width: u8, height: u8) -> Self {
        Self::closed_form(Orientation::Xy, width, height)
    }

    /// Pristine table for a `width x height` mesh under `orient` (no
    /// memory, no faults; every query is the closed-form primitive).
    pub fn closed_form(orient: Orientation, width: u8, height: u8) -> Self {
        Self { width, height, orient, deg: None }
    }

    /// [`build_oriented`](Self::build_oriented) under the baseline XY
    /// orientation (the legacy constructor; call sites that predate
    /// orientations keep their byte-exact behavior through it).
    pub fn build(
        width: u8,
        height: u8,
        dead_routers: &[Coord],
        dead_links: &[(Coord, Dir)],
    ) -> Self {
        Self::build_oriented(Orientation::Xy, width, height, dead_routers, dead_links)
    }

    /// Materialize the table for a mesh with the given dead routers and
    /// dead links, under orientation `orient`.  Links are physical
    /// (bidirectional): killing `(c, East)` also kills the neighbour's
    /// West output.  A dead router implies all four of its links are dead.
    pub fn build_oriented(
        orient: Orientation,
        width: u8,
        height: u8,
        dead_routers: &[Coord],
        dead_links: &[(Coord, Dir)],
    ) -> Self {
        let n = width as usize * height as usize;
        let at = |c: Coord| c.0 as usize * width as usize + c.1 as usize;
        let mut dead_router = vec![false; n].into_boxed_slice();
        for &c in dead_routers {
            dead_router[at(c)] = true;
        }
        let mut dead_out = vec![0u8; n].into_boxed_slice();
        let mut kill = |c: Coord, d: Dir| {
            if let Some(nb) = neighbor(c, d, width, height) {
                if d != Dir::Local {
                    dead_out[at(c)] |= 1 << d.idx();
                    dead_out[at(nb)] |= 1 << d.opposite().idx();
                }
            }
        };
        for &(c, d) in dead_links {
            kill(c, d);
        }
        for y in 0..height {
            for x in 0..width {
                if dead_router[at((y, x))] {
                    for d in [Dir::North, Dir::South, Dir::East, Dir::West] {
                        kill((y, x), d);
                    }
                }
            }
        }
        let faulted = !dead_routers.is_empty() || dead_out.iter().any(|&m| m != 0);

        // Per-destination BFS over the live subgraph.  Links are
        // symmetric, so the BFS tree from `dest` gives every router's
        // distance to `dest`; the next hop is any neighbour one step
        // closer, preferring the orientation's dimension-ordered direction
        // (bit-exact with the closed form when healthy) and then its
        // fallback order.
        let mut next = vec![UNREACHABLE; n * n].into_boxed_slice();
        let mut dist = vec![INF; n];
        let mut queue = Vec::with_capacity(n);
        for dy in 0..height {
            for dx in 0..width {
                let dest = (dy, dx);
                let di = at(dest);
                if dead_router[di] {
                    continue;
                }
                dist.iter_mut().for_each(|d| *d = INF);
                dist[di] = 0;
                queue.clear();
                queue.push(dest);
                let mut head = 0;
                while head < queue.len() {
                    let c = queue[head];
                    head += 1;
                    for d in [Dir::North, Dir::South, Dir::East, Dir::West] {
                        if dead_out[at(c)] & (1 << d.idx()) != 0 {
                            continue;
                        }
                        let Some(nb) = neighbor(c, d, width, height) else { continue };
                        if dead_router[at(nb)] || dist[at(nb)] != INF {
                            continue;
                        }
                        dist[at(nb)] = dist[at(c)] + 1;
                        queue.push(nb);
                    }
                }
                for cy in 0..height {
                    for cx in 0..width {
                        let cur = (cy, cx);
                        let ci = at(cur);
                        if dead_router[ci] || dist[ci] == INF {
                            continue;
                        }
                        if cur == dest {
                            next[ci * n + di] = Dir::Local.idx() as u8;
                            continue;
                        }
                        let step_down = |dir: Dir| {
                            if dead_out[ci] & (1 << dir.idx()) != 0 {
                                return false;
                            }
                            neighbor(cur, dir, width, height)
                                .is_some_and(|nb| dist[at(nb)] == dist[ci] - 1)
                        };
                        let pref = orient.dir(cur, dest);
                        let pick = if step_down(pref) {
                            pref
                        } else {
                            *orient
                                .fallback()
                                .iter()
                                .find(|&&d| step_down(d))
                                .expect("BFS-reachable router must have a downhill neighbour")
                        };
                        next[ci * n + di] = pick.idx() as u8;
                    }
                }
            }
        }
        let deg = Some(Degraded { next, dead_router, dead_out, faulted });
        Self { width, height, orient, deg }
    }

    /// Mesh width.
    pub fn width(&self) -> u8 {
        self.width
    }

    /// Mesh height.
    pub fn height(&self) -> u8 {
        self.height
    }

    /// The orientation this table routes under.
    pub fn orientation(&self) -> Orientation {
        self.orient
    }

    /// Any dead router or link in this table?
    pub fn has_faults(&self) -> bool {
        self.deg.as_ref().is_some_and(|d| d.faulted)
    }

    /// Is router `c` dead (harvested or killed)?
    #[inline]
    pub fn router_dead(&self, c: Coord) -> bool {
        match &self.deg {
            None => false,
            Some(d) => d.dead_router[self.at(c)],
        }
    }

    /// Is the output link of router `c` in direction `d` dead?  `Local`
    /// ports never die (ejection is internal to the tile).
    #[inline]
    pub fn link_dead(&self, c: Coord, d: Dir) -> bool {
        match &self.deg {
            None => false,
            Some(deg) => d != Dir::Local && deg.dead_out[self.at(c)] & (1 << d.idx()) != 0,
        }
    }

    /// Next-hop direction from `cur` towards `dest` (`Local` when
    /// `cur == dest`), or `None` when no live path exists.
    #[inline]
    pub fn dir(&self, cur: Coord, dest: Coord) -> Option<Dir> {
        match &self.deg {
            None => Some(self.orient.dir(cur, dest)),
            Some(deg) => {
                let n = self.width as usize * self.height as usize;
                match deg.next[self.at(cur) * n + self.at(dest)] {
                    UNREACHABLE => None,
                    d => Some(Dir::ALL[d as usize]),
                }
            }
        }
    }

    /// Can traffic injected at `src` reach `dest` on this table?
    pub fn reachable(&self, src: Coord, dest: Coord) -> bool {
        src == dest || self.dir(src, dest).is_some_and(|d| d != Dir::Local)
    }

    /// Output-port mask the header flit of packet `(origin, dests)` claims
    /// at router `cur` — the table-driven counterpart of
    /// [`super::routing::oriented_branch_mask`].  Destinations whose path
    /// does not visit `cur` (or that are unreachable) contribute nothing.
    pub fn branch_mask(&self, cur: Coord, origin: Coord, dests: &DestList) -> u8 {
        if self.deg.is_none() {
            return oriented_branch_mask(self.orient, cur, origin, dests);
        }
        let mut mask = 0u8;
        let cap = self.width as u32 * self.height as u32;
        for dest in dests.iter() {
            // Walk origin's (unique) table path; if it visits `cur`, the
            // branch for `dest` leaves through `cur`'s next hop.
            let mut c = origin;
            let mut hops = 0u32;
            loop {
                if c == cur {
                    if let Some(d) = self.dir(cur, dest) {
                        mask |= 1 << d.idx();
                    }
                    break;
                }
                if c == dest {
                    break;
                }
                match self.dir(c, dest) {
                    Some(d) if d != Dir::Local => {
                        c = neighbor(c, d, self.width, self.height)
                            .expect("route table never routes off the mesh edge");
                    }
                    _ => break,
                }
                hops += 1;
                if hops > cap {
                    break; // defensive: a (never expected) routing loop
                }
            }
        }
        mask
    }

    #[inline]
    fn at(&self, c: Coord) -> usize {
        c.0 as usize * self.width as usize + c.1 as usize
    }
}

#[cfg(test)]
mod tests {
    use super::super::routing::{
        branch_mask as xy_branch_mask, partition_dests, xy_dir, yx_dir,
    };
    use super::*;

    #[test]
    fn pristine_delegates_to_xy() {
        let t = RouteTable::xy(4, 3);
        assert!(!t.has_faults());
        for cy in 0..3 {
            for cx in 0..4 {
                for dy in 0..3 {
                    for dx in 0..4 {
                        let (c, d) = ((cy, cx), (dy, dx));
                        assert_eq!(t.dir(c, d), Some(xy_dir(c, d)));
                        assert!(t.reachable(c, d));
                    }
                }
            }
        }
    }

    #[test]
    fn materialized_clean_table_is_bit_exact_xy() {
        for (w, h) in [(2u8, 2u8), (4, 3), (5, 5), (8, 8)] {
            let t = RouteTable::build(w, h, &[], &[]);
            assert!(!t.has_faults(), "nothing dead");
            for cy in 0..h {
                for cx in 0..w {
                    for dy in 0..h {
                        for dx in 0..w {
                            let (c, d) = ((cy, cx), (dy, dx));
                            assert_eq!(t.dir(c, d), Some(xy_dir(c, d)), "{c:?}->{d:?}");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn closed_form_orientations_delegate() {
        let yx = RouteTable::closed_form(Orientation::Yx, 4, 3);
        assert_eq!(yx.orientation(), Orientation::Yx);
        assert!(!yx.has_faults());
        for cy in 0..3 {
            for cx in 0..4 {
                for dy in 0..3 {
                    for dx in 0..4 {
                        let (c, d) = ((cy, cx), (dy, dx));
                        assert_eq!(yx.dir(c, d), Some(yx_dir(c, d)));
                    }
                }
            }
        }
    }

    #[test]
    fn materialized_clean_tables_are_bit_exact_per_orientation() {
        // The orientation-preferred tie-break makes every clean
        // materialized table reproduce its closed form exactly — the
        // flipped variants included (their preferred step is always live
        // on a healthy mesh, so the mirrored fallback never engages).
        for orient in Orientation::ALL {
            for (w, h) in [(2u8, 2u8), (4, 3), (6, 6)] {
                let t = RouteTable::build_oriented(orient, w, h, &[], &[]);
                let cf = RouteTable::closed_form(orient, w, h);
                assert!(!t.has_faults(), "{orient:?}: nothing dead");
                for cy in 0..h {
                    for cx in 0..w {
                        for dy in 0..h {
                            for dx in 0..w {
                                let (c, d) = ((cy, cx), (dy, dx));
                                assert_eq!(t.dir(c, d), cf.dir(c, d), "{orient:?} {c:?}->{d:?}");
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn flipped_tie_breaks_spread_detours() {
        // Kill the center of a 3x3.  (1,0)->(1,2) must detour: XY's
        // fallback order goes North first, flipped-XY mirrors it South —
        // same hop count, opposite side of the dead router.  Likewise the
        // column route (0,1)->(2,1) under YX detours West, flipped-YX East.
        let by = |o: Orientation| RouteTable::build_oriented(o, 3, 3, &[(1, 1)], &[]);
        assert_eq!(by(Orientation::Xy).dir((1, 0), (1, 2)), Some(Dir::North));
        assert_eq!(by(Orientation::FlippedXy).dir((1, 0), (1, 2)), Some(Dir::South));
        assert_eq!(by(Orientation::Yx).dir((0, 1), (2, 1)), Some(Dir::West));
        assert_eq!(by(Orientation::FlippedYx).dir((0, 1), (2, 1)), Some(Dir::East));
    }

    #[test]
    fn materialized_clean_branch_mask_matches_partition() {
        let t = RouteTable::build(3, 3, &[], &[]);
        let dests = DestList::from_slice(&[(0, 2), (2, 2), (1, 0), (1, 1), (2, 0), (0, 0)]);
        for cy in 0..3 {
            for cx in 0..3 {
                let cur = (cy, cx);
                assert_eq!(
                    t.branch_mask(cur, (1, 1), &dests),
                    xy_branch_mask(cur, (1, 1), &dests),
                    "at {cur:?}"
                );
                // And against the materialized seed partitioner along the
                // actual replication tree rooted at the origin.
                if cur == (1, 1) {
                    let (mask, _) = partition_dests(cur, &dests);
                    assert_eq!(t.branch_mask(cur, cur, &dests), mask);
                }
            }
        }
    }

    #[test]
    fn routes_detour_around_a_dead_router() {
        // Kill the center of a 3x3: (0,0) -> (0,2) still goes straight,
        // but (1,0) -> (1,2) must detour around (1,1).
        let t = RouteTable::build(3, 3, &[(1, 1)], &[]);
        assert!(t.has_faults());
        assert!(t.router_dead((1, 1)));
        assert_eq!(t.dir((0, 0), (0, 2)), Some(Dir::East));
        let first = t.dir((1, 0), (1, 2)).unwrap();
        assert_ne!(first, Dir::East, "East leads into the dead router");
        // Walk the full path and assert it never touches the dead router.
        let mut c = (1, 0);
        let mut hops = 0;
        while c != (1, 2) {
            let d = t.dir(c, (1, 2)).unwrap();
            c = neighbor(c, d, 3, 3).unwrap();
            assert_ne!(c, (1, 1), "path crosses the dead router");
            hops += 1;
            assert!(hops <= 9, "path too long");
        }
        assert_eq!(hops, 4, "detour is the shortest live path");
        // The dead router itself is unreachable, with a diagnostic `None`.
        assert_eq!(t.dir((0, 0), (1, 1)), None);
        assert!(!t.reachable((0, 0), (1, 1)));
    }

    #[test]
    fn dead_link_is_symmetric_and_detoured() {
        let t = RouteTable::build(3, 1, &[], &[((0, 0), Dir::East)]);
        assert!(t.link_dead((0, 0), Dir::East));
        assert!(t.link_dead((0, 1), Dir::West), "links die in both directions");
        // A 1-row mesh has no detour: the far side becomes unreachable.
        assert!(!t.reachable((0, 0), (0, 2)));
        assert!(t.reachable((0, 1), (0, 2)));
        // On a 2-row mesh the same cut detours through the second row.
        let t2 = RouteTable::build(3, 2, &[], &[((0, 0), Dir::East)]);
        assert!(t2.reachable((0, 0), (0, 2)));
        let mut c = (0, 0);
        let mut hops = 0;
        while c != (0, 2) {
            let d = t2.dir(c, (0, 2)).unwrap();
            assert!(!t2.link_dead(c, d), "route crosses the dead link");
            c = neighbor(c, d, 3, 2).unwrap();
            hops += 1;
            assert!(hops <= 6);
        }
        assert_eq!(hops, 4);
    }

    #[test]
    fn harvested_row_keeps_the_rest_connected() {
        // One dead row mid-mesh: everything above/below it detours...
        // no — a full dead row *disconnects* top from bottom.  That is the
        // diagnostic the config validator surfaces; check the table agrees.
        let dead: Vec<Coord> = (0..4).map(|x| (1, x)).collect();
        let t = RouteTable::build(4, 3, &dead, &[]);
        assert!(!t.reachable((0, 0), (2, 0)), "full dead row cuts the mesh");
        // A dead row with one survivor keeps it connected through the gap.
        let mostly: Vec<Coord> = (1..4).map(|x| (1, x)).collect();
        let t2 = RouteTable::build(4, 3, &mostly, &[]);
        assert!(t2.reachable((0, 3), (2, 3)));
        let mut c = (0, 3);
        let mut hops = 0;
        while c != (2, 3) {
            let d = t2.dir(c, (2, 3)).unwrap();
            c = neighbor(c, d, 4, 3).unwrap();
            assert!(!t2.router_dead(c));
            hops += 1;
            assert!(hops <= 12);
        }
        assert_eq!(hops, 8, "down through the (1,0) gap and back");
    }

    #[test]
    fn unreachable_dest_contributes_no_branch() {
        let t = RouteTable::build(3, 1, &[], &[((0, 0), Dir::East)]);
        // Multicast from (0,0) to both sides of the cut: only the live
        // destination gets a branch.
        let dests = DestList::from_slice(&[(0, 0), (0, 2)]);
        let mask = t.branch_mask((0, 0), (0, 0), &dests);
        assert_eq!(mask, 1 << Dir::Local.idx(), "only the local delivery survives");
    }
}
