//! A single physical NoC plane: 2D mesh of routers + tile inject/eject
//! boundaries, advanced one cycle at a time.
//!
//! The tick is plan/apply: first every *active* router (immutable pass)
//! decides which input ports win which output ports this cycle — including
//! multicast forks that claim several output ports at once — then all
//! planned moves commit.  Flits are stamped with their arrival cycle so a
//! flit traverses at most one router per cycle, giving the ESP NoC's
//! one-cycle-per-hop (lookahead) timing.
//!
//! The scheduler is **activity-driven**: per-cycle cost scales with
//! in-flight traffic, not mesh area.  A sorted worklist of routers with
//! queued flits drives the plan pass (an idle 8x8 plane costs ~nothing), a
//! second worklist drives injection, `planned` scratch is cleared only
//! where it was dirtied, and the round-robin pointer — identical across
//! routers in the seed model — is a single mesh-level counter.  Messages
//! are interned once in a [`PacketSlab`] and flits carry only a `u32`
//! packet id; the scheduling order (ascending router index) matches the
//! seed's full-mesh scan exactly, so results are cycle-for-cycle identical
//! (asserted by `tests/prop_mesh_equiv.rs`).

use std::collections::VecDeque;
use std::sync::Arc;

use super::flit::{Coord, DestList, Dir, Flit, Message, PktId};
use super::route_table::RouteTable;
use super::router::{Move, Router, Slot, MAX_QUEUE_DEPTH};
use super::routing::neighbor;
use crate::telemetry::MeshTelemetry;

/// Static parameters of one plane.
#[derive(Debug, Clone, Copy)]
pub struct MeshParams {
    /// Mesh width (columns).
    pub width: u8,
    /// Mesh height (rows).
    pub height: u8,
    /// Payload bytes carried per body flit (= NoC bitwidth / 8).
    pub flit_bytes: u32,
    /// Input-queue depth per router port, in flits (<= [`MAX_QUEUE_DEPTH`]).
    pub queue_depth: usize,
}

impl MeshParams {
    fn n(&self) -> usize {
        self.width as usize * self.height as usize
    }
}

/// Packetizer state for one tile's injection port.
#[derive(Debug, Default)]
struct Inject {
    /// Packets waiting to be serialized onto the local input port.
    queue: VecDeque<PktId>,
    /// (packet, next flit index, total flits) currently streaming.
    cur: Option<(PktId, u32, u32)>,
}

impl Inject {
    fn pending(&self) -> bool {
        self.cur.is_some() || !self.queue.is_empty()
    }
}

/// In-flight messages, interned once per packet.  Flits address entries by
/// [`PktId`]; the `Arc<Message>` is cloned only at ejection (and never
/// per hop).  An entry is freed when its last live tail copy ejects —
/// multicast forks duplicate tail flits, so the entry keeps a tail
/// refcount; wormhole ordering guarantees every body flit of a branch
/// ejects before that branch's tail.
#[derive(Debug, Default)]
struct PacketSlab {
    entries: Vec<Option<PktEntry>>,
    free: Vec<PktId>,
    /// Per-slot generation, bumped every time a freed slot is reused: an
    /// `(id, generation)` pair names a packet unambiguously even after its
    /// slab slot was recycled, which the fault drain relies on to decide
    /// whether a wormhole allocation's packet still exists.
    gens: Vec<u32>,
}

#[derive(Debug)]
struct PktEntry {
    msg: Arc<Message>,
    /// Tile the packet was injected at — the root of its route tree.
    /// Routing derives from this, not `msg.src`: the seed model routed
    /// purely from the injection point, and a caller may (in principle)
    /// stamp a `src` that differs from where it injects.
    origin: Coord,
    /// Live tail-flit copies of this packet in the network.
    tails: u32,
}

impl PacketSlab {
    fn insert(&mut self, msg: Arc<Message>, origin: Coord) -> PktId {
        let e = PktEntry { msg, origin, tails: 1 };
        if let Some(i) = self.free.pop() {
            debug_assert!(self.entries[i as usize].is_none());
            self.entries[i as usize] = Some(e);
            self.gens[i as usize] = self.gens[i as usize].wrapping_add(1);
            i
        } else {
            self.entries.push(Some(e));
            self.gens.push(0);
            (self.entries.len() - 1) as PktId
        }
    }

    /// Current generation of slot `pkt` (pair it with the id to name the
    /// packet across slot recycling).
    #[inline]
    fn gen(&self, pkt: PktId) -> u32 {
        self.gens[pkt as usize]
    }

    /// Does the packet named by `(pkt, gen)` still exist?
    #[inline]
    fn live(&self, pkt: PktId, gen: u32) -> bool {
        self.gens[pkt as usize] == gen && self.entries[pkt as usize].is_some()
    }

    /// Is slot `pkt` occupied at all?  (Cannot see across recycling — the
    /// fault drain uses this only to keep a freed slot from being routed.)
    #[inline]
    fn slot_live(&self, pkt: PktId) -> bool {
        self.entries[pkt as usize].is_some()
    }

    #[inline]
    fn msg(&self, pkt: PktId) -> &Arc<Message> {
        &self.entries[pkt as usize].as_ref().expect("live packet").msg
    }

    /// `(injection origin, destination list)` — the route tree's key.
    #[inline]
    fn route(&self, pkt: PktId) -> (Coord, &DestList) {
        let e = self.entries[pkt as usize].as_ref().expect("live packet");
        (e.origin, &e.msg.dests)
    }

    /// A fork duplicated the packet's tail flit into `n` extra copies.
    fn add_tails(&mut self, pkt: PktId, n: u32) {
        self.entries[pkt as usize].as_mut().expect("live packet").tails += n;
    }

    /// Eject one tail copy, returning the message; the slot is freed (and
    /// the `Arc` handed over rather than cloned) on the last one.
    fn eject_tail(&mut self, pkt: PktId) -> Arc<Message> {
        let e = self.entries[pkt as usize].as_mut().expect("live packet");
        e.tails -= 1;
        if e.tails == 0 {
            let e = self.entries[pkt as usize].take().unwrap();
            self.free.push(pkt);
            e.msg
        } else {
            e.msg.clone()
        }
    }

    /// Drop one tail copy without delivering (fault path); the entry is
    /// freed when this was the last live copy, so dropped packets never
    /// leak slab slots.
    fn drop_tail(&mut self, pkt: PktId) {
        let e = self.entries[pkt as usize].as_mut().expect("live packet");
        e.tails -= 1;
        if e.tails == 0 {
            self.entries[pkt as usize] = None;
            self.free.push(pkt);
        }
    }
}

/// A sorted worklist of router/tile indices with O(1) membership.  The
/// plan pass must visit routers in ascending index order (downstream
/// buffer reservations are first-come-first-served within a cycle, so
/// iteration order is observable), hence sorted insertion rather than an
/// unordered bag.
#[derive(Debug, Default)]
struct ActiveSet {
    list: Vec<u32>,
    member: Vec<bool>,
}

impl ActiveSet {
    fn with_len(n: usize) -> Self {
        Self { list: Vec::new(), member: vec![false; n] }
    }

    #[inline]
    fn insert(&mut self, i: u32) {
        if !self.member[i as usize] {
            self.member[i as usize] = true;
            let pos = self.list.binary_search(&i).unwrap_err();
            self.list.insert(pos, i);
        }
    }

    /// Worklist drained? (test-only invariant probe)
    #[cfg(test)]
    fn is_empty(&self) -> bool {
        self.list.is_empty()
    }

    /// Drop entries failing `keep`, preserving order.
    fn prune(&mut self, mut keep: impl FnMut(u32) -> bool) {
        let member = &mut self.member;
        self.list.retain(|&i| {
            let k = keep(i);
            if !k {
                member[i as usize] = false;
            }
            k
        });
    }
}

/// Per-plane statistics.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct MeshStats {
    /// Flit-hops: one per flit per output port traversal.
    pub flit_hops: u64,
    /// Messages fully delivered (tail ejected) to a tile.
    pub delivered: u64,
    /// Flits injected from tiles.
    pub injected: u64,
    /// Cycles in which at least one flit moved.
    pub busy_cycles: u64,
    /// Flits dropped by fault injection (stranded on a dead link or purged
    /// from a killed router).  Always 0 on a healthy mesh.
    pub dropped_flits: u64,
    /// Messages dropped whole: injected with no reachable destination, or
    /// still queued for injection inside a killed router.
    pub dropped_msgs: u64,
    /// Truncated wormhole allocations retired by the fault drain's
    /// downstream walk — each one a router port that PR-5 would have left
    /// wedged for the rest of the run.  Always 0 on a healthy mesh.
    pub drained_worms: u64,
}

/// One NoC plane.
pub struct Mesh {
    p: MeshParams,
    routers: Vec<Router>,
    inject: Vec<Inject>,
    eject: Vec<VecDeque<Arc<Message>>>,
    /// In-flight messages, addressed by the flits' packet ids.
    pkts: PacketSlab,
    /// Scratch: planned pushes into each router input port this cycle.
    planned: Vec<[u8; 5]>,
    /// Router indices whose `planned` entry is dirty (cleared after apply,
    /// so idle regions of the mesh are never touched).
    planned_dirty: Vec<u32>,
    /// Routers with queued flits, ascending (the activity worklist).
    active: ActiveSet,
    /// Routers holding wormhole allocations (`in_branches != 0`), tracked
    /// only while `faulted`: a truncated worm's holder can drain to zero
    /// occupancy and fall off `active`, so the fault drain needs its own
    /// worklist to find — and free — wedged ports.  Populated by a full
    /// sweep when a fault installs and incrementally at head allocation
    /// thereafter; empty (and untouched) on healthy meshes.
    held: ActiveSet,
    /// Tiles with messages queued or streaming at the injection port.
    inj_active: ActiveSet,
    /// Shared round-robin arbitration offset: in the seed model every
    /// router's pointer starts at 0 and rotates once per non-idle tick, so
    /// they are always equal — one counter replaces N.
    rr: u8,
    /// Items in flight: flits in router/branch queues + messages waiting
    /// to inject.  O(1) idle detection and an early-out for idle planes.
    work: u64,
    /// Tiles a message fully ejected at during the most recent tick — the
    /// SoC scheduler drains this to unpark delivery targets.  Cleared at
    /// the top of every tick; may contain duplicates.
    delivered: Vec<Coord>,
    /// Reused plan scratch (avoids two allocations per active cycle).
    scratch_drains: Vec<(u32, u8)>,
    scratch_moves: Vec<Move>,
    /// Routing table, shared read-only with planes of the same
    /// orientation.  Pristine closed-form (XY by default) unless a harvest
    /// mask or fault plan changed the live topology.
    table: Arc<RouteTable>,
    /// Cached `table.has_faults()`: the single test that gates every fault
    /// check, so the healthy hot path pays one predictable branch and the
    /// fault layer allocates nothing (DESIGN.md §fault model).
    faulted: bool,
    /// Congestion telemetry sink, allocated only when armed via
    /// [`Mesh::set_telemetry`].  Mirrors the `faulted` gating contract:
    /// `None` costs the hot path a predictable branch per recording site
    /// and results stay byte-identical (DESIGN.md §telemetry).
    telem: Option<Box<MeshTelemetry>>,
    /// Stats for this plane.
    pub stats: MeshStats,
}

impl Mesh {
    /// Build an idle mesh.
    pub fn new(p: MeshParams) -> Self {
        assert!(
            (1..=MAX_QUEUE_DEPTH).contains(&p.queue_depth),
            "queue_depth {} outside 1..={MAX_QUEUE_DEPTH}",
            p.queue_depth
        );
        let n = p.n();
        let mut routers = Vec::with_capacity(n);
        for y in 0..p.height {
            for x in 0..p.width {
                routers.push(Router::new((y, x)));
            }
        }
        Self {
            p,
            routers,
            inject: (0..n).map(|_| Inject::default()).collect(),
            eject: (0..n).map(|_| VecDeque::new()).collect(),
            pkts: PacketSlab::default(),
            planned: vec![[0; 5]; n],
            planned_dirty: Vec::new(),
            active: ActiveSet::with_len(n),
            held: ActiveSet::with_len(n),
            inj_active: ActiveSet::with_len(n),
            rr: 0,
            work: 0,
            delivered: Vec::new(),
            scratch_drains: Vec::new(),
            scratch_moves: Vec::new(),
            table: Arc::new(RouteTable::xy(p.width, p.height)),
            faulted: false,
            telem: None,
            stats: MeshStats::default(),
        }
    }

    /// Install a (shared) routing table.  The [`super::planes::Noc`] calls
    /// this when a harvest mask or fault event changes the live topology,
    /// or when the plane is assigned a non-default orientation.
    pub fn set_route_table(&mut self, table: Arc<RouteTable>) {
        assert_eq!((table.width(), table.height()), (self.p.width, self.p.height));
        self.faulted = table.has_faults();
        self.table = table;
        if self.faulted {
            // Seed the allocation-holder worklist with every worm granted
            // before the fault existed; later grants insert incrementally.
            for i in 0..self.routers.len() {
                if self.routers[i].in_branches.iter().any(|&m| m != 0) {
                    self.held.insert(i as u32);
                }
            }
        }
    }

    /// The routing table currently in force.
    pub fn route_table(&self) -> &RouteTable {
        &self.table
    }

    /// Arm (or disarm) congestion telemetry.  Arming allocates zeroed
    /// counters; disarming frees them and returns the plane to the
    /// allocation-free hot path.  Counters never influence arbitration,
    /// so simulation results are identical either way
    /// (`tests/prop_telemetry.rs`).
    pub fn set_telemetry(&mut self, on: bool) {
        self.telem = if on { Some(Box::new(MeshTelemetry::new(self.p.n()))) } else { None };
    }

    /// The live congestion counters, when telemetry is armed.
    pub fn telemetry(&self) -> Option<&MeshTelemetry> {
        self.telem.as_deref()
    }

    /// Plane parameters.
    pub fn params(&self) -> &MeshParams {
        &self.p
    }

    #[inline]
    fn idx(&self, c: Coord) -> usize {
        c.0 as usize * self.p.width as usize + c.1 as usize
    }

    /// Queue a message for injection at `tile`.  Protocol layers self-limit
    /// (consumption assumption); the injection queue itself is unbounded but
    /// serializes at one flit per cycle.
    pub fn send(&mut self, tile: Coord, msg: Message) {
        debug_assert!(!msg.dests.is_empty(), "message with no destinations");
        let i = self.idx(tile);
        if self.faulted
            && (self.table.router_dead(tile)
                || !msg.dests.iter().any(|d| self.table.reachable(tile, d)))
        {
            // Injecting at a dead router, or no destination is reachable:
            // the message can never arrive.  Drop it whole — the protocol
            // layer's retry timeout surfaces the loss with a precise cause.
            self.stats.dropped_msgs += 1;
            return;
        }
        let pkt = self.pkts.insert(Arc::new(msg), tile);
        self.inject[i].queue.push_back(pkt);
        self.inj_active.insert(i as u32);
        self.work += 1;
    }

    /// Pop the next fully-delivered message at `tile`, if any.
    pub fn recv(&mut self, tile: Coord) -> Option<Arc<Message>> {
        let i = self.idx(tile);
        self.eject[i].pop_front()
    }

    /// Peek whether `tile` has a delivered message waiting.
    pub fn has_rx(&self, tile: Coord) -> bool {
        !self.eject[self.idx(tile)].is_empty()
    }

    /// True when no flit or pending injection remains anywhere (O(1)).
    pub fn is_idle(&self) -> bool {
        self.work == 0
    }

    /// Items in flight (queued flits + pending injections) — the plane's
    /// activity level, used by [`super::planes::Noc`] to decide whether
    /// thread fan-out is worth it this cycle.
    pub fn in_flight(&self) -> u64 {
        self.work
    }

    /// Per-router forwarded-flit counters (for utilization reports).
    pub fn router_loads(&self) -> Vec<(Coord, u64)> {
        self.routers.iter().map(|r| (r.coord, r.flits_forwarded)).collect()
    }

    /// Tiles that had a message fully delivered during the most recent
    /// [`Mesh::tick`] (duplicates possible; cleared by the next tick or
    /// by [`Mesh::clear_delivered`]).
    pub fn delivered_tiles(&self) -> &[Coord] {
        &self.delivered
    }

    /// Consume the delivery record.  [`super::planes::Noc`] clears after
    /// draining because an idle plane is skipped by the parallel tick and
    /// would otherwise keep re-reporting its last active cycle.
    pub fn clear_delivered(&mut self) {
        self.delivered.clear();
    }

    /// Advance one cycle.
    pub fn tick(&mut self, now: u64) {
        if !self.delivered.is_empty() {
            self.delivered.clear();
        }
        if self.work == 0 {
            return; // idle plane: nothing can move
        }
        if self.faulted {
            self.fault_drain();
            if self.work == 0 {
                return; // the drain consumed the last in-flight flits
            }
        }
        let mut moved = false;

        // --- Injection: stream one flit per pending tile into the local
        // input port (worklist of tiles with queued/streaming messages).
        for k in 0..self.inj_active.list.len() {
            let i = self.inj_active.list[k] as usize;
            if self.routers[i].inq[Dir::Local.idx()].len() >= self.p.queue_depth {
                continue;
            }
            if self.inject[i].cur.is_none() {
                if let Some(pkt) = self.inject[i].queue.pop_front() {
                    let total = self.pkts.msg(pkt).flit_count(self.p.flit_bytes);
                    self.inject[i].cur = Some((pkt, 0, total));
                }
            }
            if let Some((pkt, next, total)) = self.inject[i].cur.take() {
                let flit = Flit::new(pkt, next, total);
                self.routers[i].inq[Dir::Local.idx()].push(Slot { flit, arrived: now });
                self.stats.injected += 1;
                self.work += 1; // flit enters the network
                self.routers[i].occupancy += 1;
                self.active.insert(i as u32);
                moved = true;
                if next + 1 < total {
                    self.inject[i].cur = Some((pkt, next + 1, total));
                } else {
                    self.work -= 1; // message fully streamed out of inject
                }
            }
        }
        let inject = &self.inject;
        self.inj_active.prune(|i| inject[i as usize].pending());

        // --- Plan: per active router — first drain replication buffers
        // toward their output ports, then arbitrate input ports.
        let mut drains = std::mem::take(&mut self.scratch_drains);
        let mut moves = std::mem::take(&mut self.scratch_moves);
        drains.clear();
        moves.clear();
        // Heads orphaned by a topology change (faulted meshes only; stays
        // unallocated — and unpushed — on the healthy path).
        let mut fault_drops: Vec<(u32, u8)> = Vec::new();
        // Heads of truncated worms: the tail was dropped upstream and the
        // slab entry freed, so the packet can neither be routed nor ever
        // complete.  Their queued run is dropped at apply time (faulted
        // meshes only; empty and unallocated on the healthy path).
        let mut dead_heads: Vec<(u32, u8)> = Vec::new();
        for wi in 0..self.active.list.len() {
            let r = self.active.list[wi] as usize;
            let router = &self.routers[r];
            if router.occupancy == 0 {
                continue; // drained earlier; pruned at end of tick
            }
            let mut out_busy = [false; 5];
            // Output-port allocations claimed by heads earlier in this
            // cycle's arbitration (forks don't occupy the link yet, so
            // out_busy alone cannot serialize them).
            let mut claimed = [false; 5];
            // Output ports an eligible flit failed to advance through
            // this cycle (telemetry only: recorded at the end of the
            // router's turn when armed, a dead bitmask otherwise).
            // Stalls attribute to the *egress* port the flit wanted — so
            // hotspot dominant-port labels name the contended link under
            // any routing orientation — except a body flit whose head was
            // not yet granted, where no egress is known yet and the input
            // port stands in.
            let mut stalled: u8 = 0;
            // 1. Replication-buffer drains (forked packets): one flit per
            //    output port per cycle, subject to downstream space.
            for d in Dir::ALL {
                let o = d.idx();
                let Some(sf) = router.branch_q[o].front() else { continue };
                if sf.arrived >= now {
                    continue;
                }
                if d != Dir::Local {
                    if self.faulted && self.table.link_dead(router.coord, d) {
                        continue; // dead link: the fault drain purges this buffer
                    }
                    let nc = neighbor(router.coord, d, self.p.width, self.p.height)
                        .expect("fork branch routes off mesh edge");
                    let ni = self.idx(nc);
                    let np = d.opposite().idx();
                    if self.routers[ni].inq[np].len() + self.planned[ni][np] as usize
                        >= self.p.queue_depth
                    {
                        stalled |= 1 << o; // downstream backpressure
                        continue;
                    }
                    self.planned[ni][np] += 1;
                    self.planned_dirty.push(ni as u32);
                }
                out_busy[o] = true;
                drains.push((r as u32, o as u8));
            }
            // 2. Input arbitration.
            for k in 0..5 {
                let in_port = (self.rr as usize + k) % 5;
                let Some(sf) = router.inq[in_port].front() else { continue };
                if sf.arrived >= now {
                    continue; // arrived this cycle; eligible next cycle
                }
                let flit = sf.flit;
                let is_fork_body = !flit.is_head() && router.in_buffered[in_port];
                let mask = if flit.is_head() {
                    debug_assert_eq!(router.in_branches[in_port], 0, "head while allocated");
                    if self.faulted && !self.pkts.slot_live(flit.pkt) {
                        dead_heads.push((r as u32, in_port as u8));
                        continue;
                    }
                    let (origin, dests) = self.pkts.route(flit.pkt);
                    self.table.branch_mask(router.coord, origin, dests)
                } else {
                    router.in_branches[in_port]
                };
                if mask == 0 {
                    if self.faulted && flit.is_head() {
                        // The table changed under this packet: no
                        // destination is reachable from here any more.
                        fault_drops.push((r as u32, in_port as u8));
                    } else {
                        // Body flit whose head was not yet granted — wait.
                        stalled |= 1 << in_port;
                    }
                    continue;
                }
                let is_fork = mask.count_ones() > 1 || is_fork_body;
                if is_fork {
                    // Fork path: the header claims every branch port's
                    // allocation; flits then copy into the replication
                    // buffers unconditionally (the buffers absorb
                    // backpressure, keeping the dependency graph acyclic).
                    if flit.is_head() {
                        let mut clash: u8 = 0;
                        for d in Dir::ALL {
                            let o = d.idx();
                            if mask & (1 << o) != 0
                                && (router.out_alloc[o].is_some() || claimed[o])
                            {
                                clash |= 1 << o;
                            }
                        }
                        if clash != 0 {
                            // Branch ports held by another packet: charge
                            // the stall to the contended egress ports.
                            stalled |= clash;
                            continue;
                        }
                        for o in 0..5 {
                            if mask & (1 << o) != 0 {
                                claimed[o] = true;
                            }
                        }
                    }
                    moves.push(Move { router: r as u32, in_port: in_port as u8, out_mask: mask });
                    continue;
                }
                // Direct (unicast continuation) path: single output port.
                let o = mask.trailing_zeros() as usize;
                let d = Dir::ALL[o];
                if out_busy[o] || (flit.is_head() && (router.out_alloc[o].is_some() || claimed[o]))
                {
                    stalled |= 1 << o; // lost output-port arbitration
                    continue;
                }
                if d != Dir::Local {
                    let Some(nc) = neighbor(router.coord, d, self.p.width, self.p.height)
                    else {
                        panic!(
                            "route off mesh edge at {:?} dir {:?} (pkt {} injected at {:?})",
                            router.coord,
                            d,
                            flit.pkt,
                            self.pkts.route(flit.pkt).0
                        );
                    };
                    let ni = self.idx(nc);
                    let np = d.opposite().idx();
                    if self.routers[ni].inq[np].len() + self.planned[ni][np] as usize
                        >= self.p.queue_depth
                    {
                        stalled |= 1 << o; // downstream backpressure
                        continue;
                    }
                    self.planned[ni][np] += 1;
                    self.planned_dirty.push(ni as u32);
                }
                out_busy[o] = true;
                if flit.is_head() {
                    claimed[o] = true;
                }
                moves.push(Move { router: r as u32, in_port: in_port as u8, out_mask: mask });
            }
            // Record the router's stalled ports, at most once per tick —
            // which is what keeps per-router stall <= elapsed cycles.
            if stalled != 0 {
                if let Some(t) = self.telem.as_deref_mut() {
                    t.note_stalls(r, stalled);
                }
            }
        }

        // --- Apply: replication-buffer drains.
        for &(r, o) in &drains {
            let (r, o) = (r as usize, o as usize);
            let Slot { flit, .. } =
                self.routers[r].branch_q[o].pop_front().expect("planned drain");
            self.work -= 1;
            self.routers[r].occupancy -= 1;
            let coord = self.routers[r].coord;
            self.routers[r].flits_forwarded += 1;
            self.stats.flit_hops += 1;
            let d = Dir::ALL[o];
            if d == Dir::Local {
                if flit.is_tail() {
                    let msg = self.pkts.eject_tail(flit.pkt);
                    self.eject[r].push_back(msg);
                    self.stats.delivered += 1;
                    self.delivered.push(coord);
                }
            } else {
                let nc = neighbor(coord, d, self.p.width, self.p.height).unwrap();
                let ni = self.idx(nc);
                self.routers[ni].inq[d.opposite().idx()].push(Slot { flit, arrived: now });
                self.work += 1;
                self.routers[ni].occupancy += 1;
                self.active.insert(ni as u32);
            }
            if flit.is_tail() {
                // Branch complete: release the output port.
                self.routers[r].out_alloc[o] = None;
            }
            moved = true;
        }

        // --- Apply: input-port moves.
        for m in &moves {
            let r = m.router as usize;
            let in_port = m.in_port as usize;
            let Slot { flit, .. } = self.routers[r].inq[in_port].pop().expect("planned flit");
            self.work -= 1;
            self.routers[r].occupancy -= 1;
            let coord = self.routers[r].coord;
            let is_head = flit.is_head();
            let is_tail = flit.is_tail();
            let is_fork = m.out_mask.count_ones() > 1 || self.routers[r].in_buffered[in_port];
            if is_fork {
                // Copy into every branch's replication buffer.
                let mut copies = 0u32;
                for o in 0..5 {
                    if m.out_mask & (1 << o) == 0 {
                        continue;
                    }
                    self.routers[r].branch_q[o].push_back(Slot { flit, arrived: now });
                    self.work += 1;
                    self.routers[r].occupancy += 1;
                    copies += 1;
                }
                if is_tail && copies > 1 {
                    self.pkts.add_tails(flit.pkt, copies - 1);
                }
                if is_head && copies > 1 {
                    if let Some(t) = self.telem.as_deref_mut() {
                        t.forks[r] += 1; // one multicast fork event
                    }
                }
                let router = &mut self.routers[r];
                if is_head {
                    for o in 0..5 {
                        if m.out_mask & (1 << o) != 0 {
                            router.out_alloc[o] = Some(in_port as u8);
                        }
                    }
                    if !is_tail {
                        router.in_branches[in_port] = m.out_mask;
                        router.in_buffered[in_port] = true;
                        router.in_pkt[in_port] = flit.pkt;
                        router.in_pkt_gen[in_port] = self.pkts.gen(flit.pkt);
                        if self.faulted {
                            self.held.insert(m.router);
                        }
                    }
                } else if is_tail {
                    router.in_branches[in_port] = 0;
                    router.in_buffered[in_port] = false;
                }
                moved = true;
                continue;
            }
            // Direct move.
            let o = m.out_mask.trailing_zeros() as usize;
            let d = Dir::ALL[o];
            self.routers[r].flits_forwarded += 1;
            self.stats.flit_hops += 1;
            if d == Dir::Local {
                if is_tail {
                    // Deliver the whole message at tail-ejection time.
                    let msg = self.pkts.eject_tail(flit.pkt);
                    self.eject[r].push_back(msg);
                    self.stats.delivered += 1;
                    self.delivered.push(coord);
                }
            } else {
                let nc = neighbor(coord, d, self.p.width, self.p.height).unwrap();
                let ni = self.idx(nc);
                self.routers[ni].inq[d.opposite().idx()].push(Slot { flit, arrived: now });
                self.work += 1;
                self.routers[ni].occupancy += 1;
                self.active.insert(ni as u32);
            }
            // Wormhole allocation bookkeeping.
            let router = &mut self.routers[r];
            if is_head && !is_tail {
                router.in_branches[in_port] = m.out_mask;
                router.out_alloc[o] = Some(in_port as u8);
                router.in_pkt[in_port] = flit.pkt;
                router.in_pkt_gen[in_port] = self.pkts.gen(flit.pkt);
                if self.faulted {
                    self.held.insert(m.router);
                }
            } else if is_tail && !is_head {
                router.in_branches[in_port] = 0;
                router.out_alloc[o] = None;
            }
            moved = true;
        }

        // --- Apply: fault drops (orphaned heads whose destinations all
        // became unreachable when the route table changed mid-flight).
        for &(r, p) in &fault_drops {
            let (r, p) = (r as usize, p as usize);
            let Slot { flit, .. } = self.routers[r].inq[p].pop().expect("planned drop");
            self.work -= 1;
            self.routers[r].occupancy -= 1;
            self.stats.dropped_flits += 1;
            if flit.is_tail() {
                self.pkts.drop_tail(flit.pkt);
            } else {
                // The doomed packet's body flits follow; drain them too.
                self.routers[r].in_dropping[p] = true;
            }
        }

        // --- Apply: dead heads.  The worm's tail died upstream, so its
        // run ends wherever its flits stop — drop exactly that run (unlike
        // `fault_drops` there is no tail to drain up to, so `in_dropping`
        // would eat the successor packet).
        for &(r, p) in &dead_heads {
            let (r, p) = (r as usize, p as usize);
            let pkt = self.routers[r].inq[p].front().expect("planned dead head").flit.pkt;
            self.drop_worm_run(r, p, pkt);
        }

        // Return the scratch buffers for the next cycle.
        self.scratch_drains = drains;
        self.scratch_moves = moves;
        // Clear only the planned entries this cycle dirtied.
        for i in self.planned_dirty.drain(..) {
            self.planned[i as usize] = [0; 5];
        }
        // Telemetry occupancy sample: integrate post-move queue occupancy
        // over this tick's active routers (idle routers contribute 0).
        if let Some(t) = self.telem.as_deref_mut() {
            t.active_ticks += 1;
            for &i in &self.active.list {
                t.occ_sum[i as usize] += self.routers[i as usize].occupancy as u64;
            }
        }
        // Drop drained routers from the worklist.
        let routers = &self.routers;
        self.active.prune(|i| routers[i as usize].occupancy > 0);
        // Rotate arbitration priority (shared by all routers).
        self.rr = (self.rr + 1) % 5;
        if moved {
            self.stats.busy_cycles += 1;
        }
    }

    /// Sweep state stranded by a topology change: purge replication buffers
    /// aimed at dead links, strip dead directions from live branch
    /// allocations, drain the doomed remainder of packets whose head was
    /// dropped — then retire truncated worms end to end.  Allocations now
    /// carry the owning packet's `(id, generation)`, so the holder sweep
    /// can tell a wedged port (its packet is gone from the slab, or its
    /// feeding link died with nothing left queued) from a healthy one, and
    /// a released worm is *walked downstream* along its held output ports,
    /// freeing every router past the failure in the same pass — PR-5 left
    /// them wedged for the rest of the run (`drained_worms` counts the
    /// releases).  Runs once per tick while `faulted`; cost scales with
    /// the active and holder worklists, and a steady-state degraded mesh
    /// pays only the scan.  DESIGN.md §fault recovery documents the walk's
    /// legality argument and the one remaining (benign) aliasing residual.
    #[cold]
    fn fault_drain(&mut self) {
        let table = Arc::clone(&self.table);
        for wi in 0..self.active.list.len() {
            let r = self.active.list[wi] as usize;
            let coord = self.routers[r].coord;
            // 1. Replication buffers pointing into a dead link can never
            //    drain: drop their contents and release the output port.
            for d in Dir::ALL {
                let o = d.idx();
                if d == Dir::Local || !table.link_dead(coord, d) {
                    continue;
                }
                while let Some(Slot { flit, .. }) = self.routers[r].branch_q[o].pop_front() {
                    self.work -= 1;
                    self.routers[r].occupancy -= 1;
                    self.stats.dropped_flits += 1;
                    if flit.is_tail() {
                        self.pkts.drop_tail(flit.pkt);
                    }
                }
                self.routers[r].out_alloc[o] = None;
            }
            let router = &mut self.routers[r];
            for p in 0..5 {
                // 2. Strip dead directions from live branch allocations so
                //    body flits stop heading toward the dead link.
                let mask = router.in_branches[p];
                if mask != 0 {
                    let mut dead_bits = 0u8;
                    for d in Dir::ALL {
                        let o = d.idx();
                        if d != Dir::Local && mask & (1 << o) != 0 && table.link_dead(coord, d)
                        {
                            dead_bits |= 1 << o;
                        }
                    }
                    if dead_bits != 0 {
                        let live = mask & !dead_bits;
                        router.in_branches[p] = live;
                        if live == 0 {
                            // Every branch died: the rest of the packet is
                            // doomed; drain it as it arrives.
                            router.in_buffered[p] = false;
                            router.in_dropping[p] = true;
                        }
                    }
                }
                // 3. An input port fed by a dead link with no allocation
                //    left can still carry stale drop/buffer flags; clear
                //    them so the port is reusable.  Ports that *do* still
                //    hold an allocation are handled by the holder sweep
                //    below, which also walks the worm's downstream remains.
                if p != Dir::Local.idx()
                    && table.link_dead(coord, Dir::ALL[p])
                    && router.inq[p].is_empty()
                    && router.in_branches[p] == 0
                    && (router.in_buffered[p] || router.in_dropping[p])
                {
                    router.in_buffered[p] = false;
                    router.in_dropping[p] = false;
                }
                // 4. Drain the doomed remainder of a packet whose head was
                //    dropped, up to and including its tail flit.
                while router.in_dropping[p] {
                    let Some(Slot { flit, .. }) = router.inq[p].pop() else { break };
                    self.work -= 1;
                    router.occupancy -= 1;
                    self.stats.dropped_flits += 1;
                    if flit.is_tail() {
                        self.pkts.drop_tail(flit.pkt);
                        router.in_dropping[p] = false;
                    }
                }
            }
        }
        // 5. Holder sweep: every router holding a wormhole allocation is on
        //    the `held` worklist.  An allocation is orphaned when its
        //    packet is gone from the slab (generation-checked, so a
        //    recycled id cannot alias) or when its feeding link died with
        //    nothing left queued — the worm was truncated and no tail will
        //    ever arrive to release it.  Releasing seeds a breadth-first
        //    walk along the worm's held output ports, retiring the same
        //    packet's allocations (and stray queued runs) in every router
        //    downstream of the failure.
        let mut walk: VecDeque<(usize, usize, PktId, u32)> = VecDeque::new();
        for wi in 0..self.held.list.len() {
            let r = self.held.list[wi] as usize;
            let coord = self.routers[r].coord;
            for p in 0..5 {
                if self.routers[r].in_branches[p] == 0 {
                    continue;
                }
                let (pkt, gen) = (self.routers[r].in_pkt[p], self.routers[r].in_pkt_gen[p]);
                let starved = p != Dir::Local.idx()
                    && table.link_dead(coord, Dir::ALL[p])
                    && self.routers[r].inq[p].is_empty();
                if starved || !self.pkts.live(pkt, gen) {
                    self.release_worm(r, p, pkt, &mut walk);
                }
            }
        }
        while let Some((r, p, pkt, gen)) = walk.pop_front() {
            if self.routers[r].in_branches[p] != 0 {
                if self.routers[r].in_pkt[p] == pkt && self.routers[r].in_pkt_gen[p] == gen {
                    self.release_worm(r, p, pkt, &mut walk);
                }
            } else {
                // No allocation yet: the worm's flits are merely queued
                // here (its head never won arbitration).  Drop the run.
                self.drop_worm_run(r, p, pkt);
            }
        }
        // Routers the drain emptied fall off the worklists here rather than
        // at end-of-tick, so the plan pass never visits them.
        let routers = &self.routers;
        self.held.prune(|i| routers[i as usize].in_branches.iter().any(|&m| m != 0));
        self.active.prune(|i| routers[i as usize].occupancy > 0);
    }

    /// Drop the contiguous run of `pkt`'s flits at the front of input
    /// queue `p` of router `r`.  A head flit is legal only at the first
    /// position — a later flit with the same id but the head bit set is a
    /// *successor* packet on a recycled slab slot and must survive.
    #[cold]
    fn drop_worm_run(&mut self, r: usize, p: usize, pkt: PktId) {
        let mut first = true;
        while let Some(s) = self.routers[r].inq[p].front() {
            let f = s.flit;
            if f.pkt != pkt || (f.is_head() && !first) {
                break;
            }
            first = false;
            self.routers[r].inq[p].pop();
            self.work -= 1;
            self.routers[r].occupancy -= 1;
            self.stats.dropped_flits += 1;
            if f.is_tail() {
                self.pkts.drop_tail(pkt);
            }
        }
    }

    /// Retire the truncated worm holding input port `p` of router `r`:
    /// drop its queued run, purge its copies from the replication buffers
    /// (they are always the *last* run in each branch queue — the worm is
    /// dead, so nothing appends behind it), free the output ports it held,
    /// and push each held direction's downstream endpoint onto `walk` so
    /// the caller retires the rest of the worm.  One release == one
    /// `drained_worms` count.
    #[cold]
    fn release_worm(
        &mut self,
        r: usize,
        p: usize,
        pkt: PktId,
        walk: &mut VecDeque<(usize, usize, PktId, u32)>,
    ) {
        let gen = self.routers[r].in_pkt_gen[p];
        let held = self.routers[r].in_branches[p];
        self.drop_worm_run(r, p, pkt);
        let coord = self.routers[r].coord;
        for o in 0..5 {
            if held & (1 << o) == 0 {
                continue;
            }
            while let Some(s) = self.routers[r].branch_q[o].back() {
                if s.flit.pkt != pkt {
                    break;
                }
                let f = s.flit;
                self.routers[r].branch_q[o].pop_back();
                self.work -= 1;
                self.routers[r].occupancy -= 1;
                self.stats.dropped_flits += 1;
                if f.is_tail() {
                    self.pkts.drop_tail(pkt);
                }
            }
            if self.routers[r].out_alloc[o] == Some(p as u8) {
                self.routers[r].out_alloc[o] = None;
            }
            let d = Dir::ALL[o];
            if d != Dir::Local {
                if let Some(nc) = neighbor(coord, d, self.p.width, self.p.height) {
                    walk.push_back((self.idx(nc), d.opposite().idx(), pkt, gen));
                }
            }
        }
        let router = &mut self.routers[r];
        router.in_branches[p] = 0;
        router.in_buffered[p] = false;
        router.in_dropping[p] = false;
        self.stats.drained_worms += 1;
    }

    /// A fault killed the router at `c`: purge everything queued inside it
    /// (flits in input and replication queues, messages waiting to inject)
    /// and reset its wormhole state.  [`super::planes::Noc`] calls this
    /// *after* installing the updated route table, so later sends at the
    /// tile are dropped by [`Mesh::send`] and neighbours stop routing here.
    pub fn kill_router(&mut self, c: Coord) {
        let i = self.idx(c);
        // Messages waiting at (or streaming into) the local port die with
        // the router.
        if let Some((pkt, _, _)) = self.inject[i].cur.take() {
            self.work -= 1; // the message token held while streaming
            self.stats.dropped_msgs += 1;
            self.pkts.drop_tail(pkt); // its tail flit was never created
        }
        while let Some(pkt) = self.inject[i].queue.pop_front() {
            self.work -= 1;
            self.stats.dropped_msgs += 1;
            self.pkts.drop_tail(pkt);
        }
        // Queued flits are lost.
        for p in 0..5 {
            while let Some(Slot { flit, .. }) = self.routers[i].inq[p].pop() {
                self.work -= 1;
                self.stats.dropped_flits += 1;
                if flit.is_tail() {
                    self.pkts.drop_tail(flit.pkt);
                }
            }
            while let Some(Slot { flit, .. }) = self.routers[i].branch_q[p].pop_front() {
                self.work -= 1;
                self.stats.dropped_flits += 1;
                if flit.is_tail() {
                    self.pkts.drop_tail(flit.pkt);
                }
            }
        }
        let router = &mut self.routers[i];
        router.occupancy = 0;
        router.out_alloc = [None; 5];
        router.in_branches = [0; 5];
        router.in_buffered = [false; 5];
        router.in_dropping = [false; 5];
    }

    /// Routers with queued flits and their occupancy (watchdog forensics).
    pub fn occupied_routers(&self) -> Vec<(Coord, u32)> {
        self.routers
            .iter()
            .filter(|r| r.occupancy > 0)
            .map(|r| (r.coord, r.occupancy))
            .collect()
    }

    /// Find the oldest queued flit in the plane and describe where it is
    /// stuck.  Forensics for the quiesce watchdog — scans every router, so
    /// never called on the simulation hot path.
    pub fn oldest_stall(&self) -> Option<StallProbe> {
        let mut best: Option<StallProbe> = None;
        for r in &self.routers {
            if r.occupancy == 0 {
                continue;
            }
            for d in Dir::ALL {
                let p = d.idx();
                let older = |best: &Option<StallProbe>, s: &Slot| match best {
                    None => true,
                    Some(b) => s.arrived < b.arrived,
                };
                if let Some(s) = r.inq[p].front() {
                    if older(&best, s) {
                        best = Some(self.probe(r, d, false, s));
                    }
                }
                if let Some(s) = r.branch_q[p].front() {
                    if older(&best, s) {
                        best = Some(self.probe(r, d, true, s));
                    }
                }
            }
        }
        best
    }

    /// Describe one stuck flit (see [`Mesh::oldest_stall`]).
    fn probe(&self, r: &Router, port: Dir, in_branch_buf: bool, s: &Slot) -> StallProbe {
        let (origin, dests) = self.pkts.route(s.flit.pkt);
        let next = if in_branch_buf {
            Some(port)
        } else {
            let mask = if s.flit.is_head() {
                self.table.branch_mask(r.coord, origin, dests)
            } else {
                r.in_branches[port.idx()]
            };
            if mask == 0 {
                None
            } else {
                Some(Dir::ALL[mask.trailing_zeros() as usize])
            }
        };
        let next_dead =
            matches!(next, Some(d) if d != Dir::Local && self.table.link_dead(r.coord, d));
        StallProbe {
            at: r.coord,
            port,
            in_branch_buf,
            arrived: s.arrived,
            head: s.flit.is_head(),
            origin,
            dest: dests.iter().next().unwrap_or(origin),
            ndests: dests.len(),
            next,
            next_dead,
        }
    }
}

/// Where the oldest queued flit in a plane is stuck — built by
/// [`Mesh::oldest_stall`] for the quiesce watchdog's forensic dump.
#[derive(Debug, Clone)]
pub struct StallProbe {
    /// Router holding the flit.
    pub at: Coord,
    /// Port it waits in: the input direction, or the output direction when
    /// `in_branch_buf`.
    pub port: Dir,
    /// Waiting in a replication (branch) buffer rather than an input queue.
    pub in_branch_buf: bool,
    /// Cycle the flit entered this queue.
    pub arrived: u64,
    /// Head flit?  (a waiting head lost arbitration; a waiting body is a
    /// stalled wormhole)
    pub head: bool,
    /// Tile the packet was injected at.
    pub origin: Coord,
    /// First destination of the packet (representative).
    pub dest: Coord,
    /// Total destinations of the packet.
    pub ndests: usize,
    /// Output direction the flit wants next, if determinable.
    pub next: Option<Dir>,
    /// The wanted next hop crosses a dead link (blackhole signature).
    pub next_dead: bool,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::flit::{MsgKind, RESUME_NONE};

    fn mesh3x3() -> Mesh {
        Mesh::new(MeshParams { width: 3, height: 3, flit_bytes: 32, queue_depth: 4 })
    }

    fn run_until_idle(m: &mut Mesh, max: u64) -> u64 {
        let mut t = 0;
        while !m.is_idle() {
            m.tick(t);
            t += 1;
            assert!(t < max, "mesh did not drain in {max} cycles");
        }
        t
    }

    #[test]
    fn unicast_single_flit_delivery() {
        let mut m = mesh3x3();
        let req = MsgKind::P2pReq { len: 4, prod_slot: 0, cons_slot: 0, resume: RESUME_NONE };
        m.send((0, 0), Message::ctrl((0, 0), (2, 2), req));
        run_until_idle(&mut m, 100);
        let got = m.recv((2, 2)).expect("delivered");
        assert_eq!(got.src, (0, 0));
        assert!(matches!(got.kind, MsgKind::P2pReq { len: 4, prod_slot: 0, cons_slot: 0, .. }));
        assert!(m.recv((2, 2)).is_none());
    }

    #[test]
    fn payload_arrives_intact() {
        let mut m = mesh3x3();
        let data: Vec<u8> = (0..200u16).map(|i| i as u8).collect();
        m.send(
            (1, 0),
            Message::data(
                (1, 0),
                (1, 2),
                MsgKind::P2pData { seq: 7, prod_slot: 0 },
                Arc::new(data.clone()),
            ),
        );
        run_until_idle(&mut m, 200);
        let got = m.recv((1, 2)).expect("delivered");
        assert_eq!(*got.payload, data);
        assert!(matches!(got.kind, MsgKind::P2pData { seq: 7, prod_slot: 0 }));
    }

    #[test]
    fn self_send_delivers_locally() {
        let mut m = mesh3x3();
        m.send((1, 1), Message::ctrl((1, 1), (1, 1), MsgKind::Irq { acc: 3 }));
        run_until_idle(&mut m, 50);
        assert!(m.recv((1, 1)).is_some());
    }

    #[test]
    fn multicast_reaches_every_destination_once() {
        let mut m = mesh3x3();
        let dests = DestList::from_slice(&[(0, 2), (2, 2), (2, 0), (1, 1)]);
        let payload: Vec<u8> = (0..128u8).collect();
        m.send(
            (0, 0),
            Message::multicast(
                (0, 0),
                dests,
                MsgKind::P2pData { seq: 0, prod_slot: 0 },
                Arc::new(payload.clone()),
            ),
        );
        run_until_idle(&mut m, 300);
        for c in dests.iter() {
            let got = m.recv(c).unwrap_or_else(|| panic!("no delivery at {c:?}"));
            assert_eq!(*got.payload, payload);
            assert!(m.recv(c).is_none(), "duplicate delivery at {c:?}");
        }
        // Non-destinations see nothing.
        assert!(m.recv((0, 1)).is_none());
        assert!(m.recv((2, 1)).is_none());
    }

    #[test]
    fn multicast_cheaper_than_serial_unicasts() {
        // Same data to 4 dests: one multicast must use fewer flit-hops than
        // 4 unicasts (the shared prefix is traversed once).
        let payload = Arc::new(vec![0u8; 512]);
        let dests = [(2, 2), (2, 1), (2, 0), (0, 2)];

        let mut mc = mesh3x3();
        mc.send(
            (0, 0),
            Message::multicast(
                (0, 0),
                DestList::from_slice(&dests),
                MsgKind::P2pData { seq: 0, prod_slot: 0 },
                payload.clone(),
            ),
        );
        run_until_idle(&mut mc, 1000);

        let mut uc = mesh3x3();
        for &d in &dests {
            let kind = MsgKind::P2pData { seq: 0, prod_slot: 0 };
            uc.send((0, 0), Message::data((0, 0), d, kind, payload.clone()));
        }
        run_until_idle(&mut uc, 2000);

        assert!(
            mc.stats.flit_hops < uc.stats.flit_hops,
            "multicast {} hops !< unicast {} hops",
            mc.stats.flit_hops,
            uc.stats.flit_hops
        );
    }

    #[test]
    fn one_cycle_per_hop_when_uncontended() {
        let mut m = mesh3x3();
        // (0,0) -> (0,2): 2 hops, single-flit message.
        let req = MsgKind::P2pReq { len: 0, prod_slot: 0, cons_slot: 0, resume: RESUME_NONE };
        m.send((0, 0), Message::ctrl((0, 0), (0, 2), req));
        let mut t = 0;
        let mut delivered_at = None;
        while delivered_at.is_none() && t < 50 {
            m.tick(t);
            t += 1;
            if m.has_rx((0, 2)) {
                delivered_at = Some(t);
            }
        }
        // inject(1) + router (0,0) + (0,1) + (0,2)-eject: ~4-5 cycles.
        let d = delivered_at.expect("delivered");
        assert!(d <= 6, "took {d} cycles for 2 hops");
    }

    #[test]
    fn wormhole_packets_do_not_interleave_per_link() {
        let mut m = mesh3x3();
        // Two multi-flit packets from the same source to the same dest:
        // delivery order must match send order and both arrive intact.
        for seq in 0..2u32 {
            m.send(
                (0, 0),
                Message::data(
                    (0, 0),
                    (2, 2),
                    MsgKind::P2pData { seq, prod_slot: 0 },
                    Arc::new(vec![seq as u8; 160]),
                ),
            );
        }
        run_until_idle(&mut m, 500);
        let a = m.recv((2, 2)).unwrap();
        let b = m.recv((2, 2)).unwrap();
        assert!(matches!(a.kind, MsgKind::P2pData { seq: 0, prod_slot: 0 }));
        assert!(matches!(b.kind, MsgKind::P2pData { seq: 1, .. }));
        assert!(a.payload.iter().all(|&x| x == 0));
        assert!(b.payload.iter().all(|&x| x == 1));
    }

    #[test]
    fn contended_output_serializes_but_delivers_all() {
        let mut m = mesh3x3();
        // Three senders target the same column destination.
        for (i, src) in [(0u8, 0u8), (1, 0), (2, 0)].into_iter().enumerate() {
            m.send(
                src,
                Message::data(
                    src,
                    (1, 2),
                    MsgKind::P2pData { seq: i as u32, prod_slot: 0 },
                    Arc::new(vec![i as u8; 96]),
                ),
            );
        }
        run_until_idle(&mut m, 1000);
        let mut got = Vec::new();
        while let Some(msg) = m.recv((1, 2)) {
            got.push(msg);
        }
        assert_eq!(got.len(), 3);
    }

    #[test]
    fn backpressure_never_drops_flits() {
        // Tiny queues + many packets: everything still arrives.
        let mut m = Mesh::new(MeshParams { width: 3, height: 3, flit_bytes: 8, queue_depth: 2 });
        for i in 0..10u32 {
            m.send(
                (0, 0),
                Message::data(
                    (0, 0),
                    (2, 2),
                    MsgKind::P2pData { seq: i, prod_slot: 0 },
                    Arc::new(vec![0; 64]),
                ),
            );
        }
        run_until_idle(&mut m, 5000);
        let mut n = 0;
        while m.recv((2, 2)).is_some() {
            n += 1;
        }
        assert_eq!(n, 10);
    }

    #[test]
    fn stats_count_hops_and_deliveries() {
        let mut m = mesh3x3();
        let req = MsgKind::P2pReq { len: 1, prod_slot: 0, cons_slot: 0, resume: RESUME_NONE };
        m.send((0, 0), Message::ctrl((0, 0), (0, 1), req));
        run_until_idle(&mut m, 100);
        assert_eq!(m.stats.delivered, 1);
        assert!(m.stats.flit_hops >= 2); // at least src router + dest eject
        assert!(m.stats.injected >= 1);
    }

    #[test]
    fn packet_slab_recycles_after_delivery() {
        // After a full drain no interned packet may leak: the slab's free
        // list must cover every slot it ever allocated.
        let mut m = mesh3x3();
        for round in 0..3 {
            let dests = DestList::from_slice(&[(0, 2), (2, 2), (2, 0)]);
            m.send(
                (0, 0),
                Message::multicast(
                    (0, 0),
                    dests,
                    MsgKind::P2pData { seq: round, prod_slot: 0 },
                    Arc::new(vec![round as u8; 100]),
                ),
            );
            m.send((1, 1), Message::ctrl((1, 1), (0, 0), MsgKind::Irq { acc: round as u16 }));
            run_until_idle(&mut m, 2000);
        }
        assert!(m.pkts.entries.iter().all(|e| e.is_none()), "slab entry leaked");
        assert_eq!(m.pkts.free.len(), m.pkts.entries.len());
        // Deliveries all arrived.
        assert_eq!(m.stats.delivered, 3 * 4);
    }

    #[test]
    fn worklist_empties_when_mesh_drains() {
        let mut m = mesh3x3();
        m.send(
            (0, 0),
            Message::data(
                (0, 0),
                (2, 2),
                MsgKind::P2pData { seq: 0, prod_slot: 0 },
                Arc::new(vec![0; 256]),
            ),
        );
        run_until_idle(&mut m, 1000);
        assert!(m.active.is_empty(), "active worklist not drained");
        assert!(m.inj_active.is_empty(), "inject worklist not drained");
        assert!(m.active.member.iter().all(|&b| !b));
        // Ticking an idle mesh is free and changes nothing.
        let hops = m.stats.flit_hops;
        m.tick(10_000);
        assert_eq!(m.stats.flit_hops, hops);
    }

    #[test]
    fn delivered_tiles_track_tail_ejections_per_tick() {
        let mut m = mesh3x3();
        m.send((0, 0), Message::ctrl((0, 0), (2, 2), MsgKind::Irq { acc: 1 }));
        let mut seen = Vec::new();
        for t in 0..50 {
            m.tick(t);
            seen.extend(m.delivered_tiles().iter().copied());
            if m.is_idle() {
                break;
            }
        }
        assert_eq!(seen, vec![(2, 2)], "exactly one delivery, at the destination");
        // A later tick clears the record even on an idle mesh.
        m.tick(100);
        assert!(m.delivered_tiles().is_empty());
    }

    #[test]
    fn routes_from_injection_tile_not_src_field() {
        // A caller may stamp a `src` that differs from where it injects;
        // routing must follow the injection point (as the seed model did).
        let mut m = mesh3x3();
        let mut msg = Message::ctrl((2, 2), (1, 1), MsgKind::Irq { acc: 9 });
        msg.src = (2, 2); // explicit: src field disagrees with inject tile
        m.send((0, 0), msg);
        run_until_idle(&mut m, 100);
        let got = m.recv((1, 1)).expect("delivered");
        assert_eq!(got.src, (2, 2), "src field preserved verbatim");
    }

    #[test]
    #[should_panic(expected = "queue_depth")]
    fn rejects_oversized_queue_depth() {
        let p =
            MeshParams { width: 2, height: 2, flit_bytes: 8, queue_depth: MAX_QUEUE_DEPTH + 1 };
        Mesh::new(p);
    }

    #[test]
    fn routes_around_dead_link_and_delivers() {
        // Kill the (1,0)-(1,1) link before any traffic: the table detours
        // and the message still arrives, with nothing dropped.
        let mut m = mesh3x3();
        m.set_route_table(Arc::new(RouteTable::build(3, 3, &[], &[((1, 0), Dir::East)])));
        m.send(
            (1, 0),
            Message::data(
                (1, 0),
                (1, 2),
                MsgKind::P2pData { seq: 0, prod_slot: 0 },
                Arc::new(vec![7u8; 96]),
            ),
        );
        run_until_idle(&mut m, 500);
        let got = m.recv((1, 2)).expect("delivered around the dead link");
        assert!(got.payload.iter().all(|&x| x == 7));
        assert_eq!(m.stats.dropped_flits, 0);
        assert_eq!(m.stats.dropped_msgs, 0);
    }

    #[test]
    fn send_with_no_reachable_dest_is_dropped_whole() {
        // Cut (0,0) off completely on a 1x3 mesh: the send is dropped at
        // injection and the mesh stays idle (no wedged flits).
        let mut m = Mesh::new(MeshParams { width: 3, height: 1, flit_bytes: 32, queue_depth: 4 });
        m.set_route_table(Arc::new(RouteTable::build(3, 1, &[], &[((0, 0), Dir::East)])));
        m.send((0, 0), Message::ctrl((0, 0), (0, 2), MsgKind::Irq { acc: 1 }));
        assert!(m.is_idle(), "dropped at injection, nothing in flight");
        assert_eq!(m.stats.dropped_msgs, 1);
        assert!(m.recv((0, 2)).is_none());
    }

    #[test]
    fn mid_flight_link_kill_drops_packet_and_mesh_drains() {
        // Start a long packet (0,0)->(0,2), then cut the (0,1)-(0,2) link
        // while it is in flight.  The stranded flits are dropped, the slab
        // does not leak, and the mesh still drains to idle.
        let mut m = Mesh::new(MeshParams { width: 3, height: 1, flit_bytes: 8, queue_depth: 4 });
        m.send(
            (0, 0),
            Message::data(
                (0, 0),
                (0, 2),
                MsgKind::P2pData { seq: 0, prod_slot: 0 },
                Arc::new(vec![0u8; 256]),
            ),
        );
        for t in 0..5 {
            m.tick(t);
        }
        m.set_route_table(Arc::new(RouteTable::build(3, 1, &[], &[((0, 1), Dir::East)])));
        let mut t = 5;
        while !m.is_idle() {
            m.tick(t);
            t += 1;
            assert!(t < 1000, "faulted mesh did not drain");
        }
        assert!(m.stats.dropped_flits > 0, "stranded flits must be counted");
        assert!(m.pkts.entries.iter().all(|e| e.is_none()), "slab entry leaked");
    }

    #[test]
    fn killed_router_purges_queues_and_counts_drops() {
        let mut m = mesh3x3();
        // Two messages: one waiting to inject at the doomed router, one in
        // flight through the mesh.
        m.send(
            (1, 1),
            Message::data(
                (1, 1),
                (2, 2),
                MsgKind::P2pData { seq: 0, prod_slot: 0 },
                Arc::new(vec![0u8; 128]),
            ),
        );
        m.tick(0); // stream a flit or two
        m.tick(1);
        m.set_route_table(Arc::new(RouteTable::build(3, 3, &[(1, 1)], &[])));
        m.kill_router((1, 1));
        assert_eq!(m.routers[m.idx((1, 1))].queued(), 0, "router not purged");
        let mut t = 2;
        while !m.is_idle() {
            m.tick(t);
            t += 1;
            assert!(t < 1000, "mesh did not drain after router kill");
        }
        assert!(m.stats.dropped_flits + m.stats.dropped_msgs > 0);
        assert!(m.pkts.entries.iter().all(|e| e.is_none()), "slab entry leaked");
        // Sends at the dead tile are now dropped outright.
        let before = m.stats.dropped_msgs;
        m.send((1, 1), Message::ctrl((1, 1), (0, 0), MsgKind::Irq { acc: 1 }));
        assert_eq!(m.stats.dropped_msgs, before + 1);
    }

    #[test]
    fn oldest_stall_names_the_blackholed_hop() {
        // Wedge a packet against a dead link (queue it, then kill the only
        // path while its flits sit waiting): after the drain, nothing
        // remains; before the drain runs, the probe names the dead hop.
        let mut m = Mesh::new(MeshParams { width: 3, height: 1, flit_bytes: 8, queue_depth: 4 });
        m.send(
            (0, 0),
            Message::data(
                (0, 0),
                (0, 2),
                MsgKind::P2pData { seq: 0, prod_slot: 0 },
                Arc::new(vec![0u8; 64]),
            ),
        );
        for t in 0..3 {
            m.tick(t);
        }
        m.set_route_table(Arc::new(RouteTable::build(3, 1, &[], &[((0, 1), Dir::East)])));
        let probe = m.oldest_stall().expect("flits in flight");
        assert!(probe.arrived < 3);
        assert_eq!(probe.origin, (0, 0));
        // Whatever flit is oldest, the probe pins a concrete router + port.
        assert!(probe.at.1 <= 1, "stall is upstream of the cut");
    }

    #[test]
    fn drain_walk_retires_downstream_wedge_and_reopens_routers() {
        // Sever a long worm mid-stream: the routers *downstream* of the
        // cut hold wormhole allocations whose tail died upstream.  PR-5's
        // drain only released the port adjacent to the dead link; the
        // holder sweep + walk must now retire the whole severed segment so
        // the mesh drains and the far routers accept fresh traffic.
        let mut m = Mesh::new(MeshParams { width: 4, height: 1, flit_bytes: 8, queue_depth: 4 });
        m.send(
            (0, 0),
            Message::data(
                (0, 0),
                (0, 3),
                MsgKind::P2pData { seq: 0, prod_slot: 0 },
                Arc::new(vec![9u8; 256]),
            ),
        );
        // Stream until the worm spans the whole row (head allocated at
        // every hop), then cut it between (0,1) and (0,2).
        for t in 0..8 {
            m.tick(t);
        }
        m.set_route_table(Arc::new(RouteTable::build(4, 1, &[], &[((0, 1), Dir::East)])));
        let mut t = 8;
        while !m.is_idle() {
            m.tick(t);
            t += 1;
            assert!(t < 1000, "severed worm wedged the mesh");
        }
        assert!(m.stats.drained_worms > 0, "downstream wedge was not drained");
        assert!(m.stats.dropped_flits > 0);
        assert!(m.pkts.entries.iter().all(|e| e.is_none()), "slab entry leaked");
        assert!(m.routers.iter().all(|r| r.in_branches.iter().all(|&b| b == 0)));
        assert!(m.held.is_empty() && m.active.is_empty());
        // Routers past the cut are back in service: (0,2) -> (0,3), which
        // never touches the dead link, must deliver.
        m.send(
            (0, 2),
            Message::data(
                (0, 2),
                (0, 3),
                MsgKind::P2pData { seq: 1, prod_slot: 0 },
                Arc::new(vec![5u8; 64]),
            ),
        );
        run_until_idle(&mut m, 1000);
        let got = m.recv((0, 3)).expect("post-drain delivery through the severed segment");
        assert!(matches!(got.kind, MsgKind::P2pData { seq: 1, .. }));
        assert!(got.payload.iter().all(|&x| x == 5));
    }

    #[test]
    fn slab_generations_distinguish_recycled_slots() {
        // The drain tells a truncated worm from a successor reusing its
        // slab slot by the (id, generation) pair; reuse must bump it.
        let mut m = mesh3x3();
        m.send(
            (0, 0),
            Message::ctrl(
                (0, 0),
                (2, 2),
                MsgKind::P2pReq { len: 4, prod_slot: 0, cons_slot: 0, resume: RESUME_NONE },
            ),
        );
        run_until_idle(&mut m, 100);
        let g0 = m.pkts.gen(0);
        assert!(!m.pkts.slot_live(0), "delivered packet must leave the slab");
        m.send(
            (0, 0),
            Message::ctrl(
                (0, 0),
                (2, 2),
                MsgKind::P2pReq { len: 4, prod_slot: 0, cons_slot: 0, resume: RESUME_NONE },
            ),
        );
        run_until_idle(&mut m, 100);
        assert_eq!(m.pkts.gen(0), g0.wrapping_add(1), "slot reuse must bump the generation");
        assert!(!m.pkts.live(0, g0), "a stale (id, generation) pair must read dead");
    }
}
