//! A single physical NoC plane: 2D mesh of routers + tile inject/eject
//! boundaries, advanced one cycle at a time.
//!
//! The tick is plan/apply: first every router (immutable pass) decides which
//! input ports win which output ports this cycle — including multicast forks
//! that claim several output ports at once — then all planned moves commit.
//! Flits are stamped with their arrival cycle so a flit traverses at most
//! one router per cycle, giving the ESP NoC's one-cycle-per-hop (lookahead)
//! timing.

use std::collections::VecDeque;
use std::sync::Arc;

use super::flit::{Coord, Dir, Flit, Message};
#[cfg(test)]
use super::flit::DestList;
use super::router::{Move, Router, StampedFlit};
use super::routing::{neighbor, partition_dests};

/// Static parameters of one plane.
#[derive(Debug, Clone, Copy)]
pub struct MeshParams {
    /// Mesh width (columns).
    pub width: u8,
    /// Mesh height (rows).
    pub height: u8,
    /// Payload bytes carried per body flit (= NoC bitwidth / 8).
    pub flit_bytes: u32,
    /// Input-queue depth per router port, in flits.
    pub queue_depth: usize,
}

impl MeshParams {
    fn n(&self) -> usize {
        self.width as usize * self.height as usize
    }
}

/// Packetizer state for one tile's injection port.
#[derive(Debug, Default)]
struct Inject {
    /// Messages waiting to be serialized onto the local input port.
    queue: VecDeque<Arc<Message>>,
    /// (message, next flit index, total flits) currently streaming.
    cur: Option<(Arc<Message>, u32, u32)>,
}

/// Per-plane statistics.
#[derive(Debug, Default, Clone)]
pub struct MeshStats {
    /// Flit-hops: one per flit per output port traversal.
    pub flit_hops: u64,
    /// Messages fully delivered (tail ejected) to a tile.
    pub delivered: u64,
    /// Flits injected from tiles.
    pub injected: u64,
    /// Cycles in which at least one flit moved.
    pub busy_cycles: u64,
}

/// One NoC plane.
pub struct Mesh {
    p: MeshParams,
    routers: Vec<Router>,
    inject: Vec<Inject>,
    eject: Vec<VecDeque<Arc<Message>>>,
    /// Scratch: planned pushes into each router input port this cycle.
    planned: Vec<[u8; 5]>,
    /// Items in flight: flits in router/branch queues + messages waiting
    /// to inject.  O(1) idle detection and an early-out for idle planes.
    work: u64,
    /// Reused plan scratch (avoids two allocations per active cycle).
    scratch_drains: Vec<(usize, usize)>,
    scratch_moves: Vec<Move>,
    /// Messages queued or streaming at injection ports.
    inject_msgs: u64,
    /// Stats for this plane.
    pub stats: MeshStats,
}

impl Mesh {
    /// Build an idle mesh.
    pub fn new(p: MeshParams) -> Self {
        let n = p.n();
        let mut routers = Vec::with_capacity(n);
        for y in 0..p.height {
            for x in 0..p.width {
                routers.push(Router::new((y, x)));
            }
        }
        Self {
            p,
            routers,
            inject: (0..n).map(|_| Inject::default()).collect(),
            eject: (0..n).map(|_| VecDeque::new()).collect(),
            planned: vec![[0; 5]; n],
            work: 0,
            scratch_drains: Vec::new(),
            scratch_moves: Vec::new(),
            inject_msgs: 0,
            stats: MeshStats::default(),
        }
    }

    /// Plane parameters.
    pub fn params(&self) -> &MeshParams {
        &self.p
    }

    #[inline]
    fn idx(&self, c: Coord) -> usize {
        c.0 as usize * self.p.width as usize + c.1 as usize
    }

    /// Queue a message for injection at `tile`.  Protocol layers self-limit
    /// (consumption assumption); the injection queue itself is unbounded but
    /// serializes at one flit per cycle.
    pub fn send(&mut self, tile: Coord, msg: Message) {
        debug_assert!(!msg.dests.is_empty(), "message with no destinations");
        let i = self.idx(tile);
        self.inject[i].queue.push_back(Arc::new(msg));
        self.work += 1;
        self.inject_msgs += 1;
    }

    /// Pop the next fully-delivered message at `tile`, if any.
    pub fn recv(&mut self, tile: Coord) -> Option<Arc<Message>> {
        let i = self.idx(tile);
        self.eject[i].pop_front()
    }

    /// Peek whether `tile` has a delivered message waiting.
    pub fn has_rx(&self, tile: Coord) -> bool {
        !self.eject[self.idx(tile)].is_empty()
    }

    /// True when no flit or pending injection remains anywhere (O(1)).
    pub fn is_idle(&self) -> bool {
        self.work == 0
    }

    /// Per-router forwarded-flit counters (for utilization reports).
    pub fn router_loads(&self) -> Vec<(Coord, u64)> {
        self.routers.iter().map(|r| (r.coord, r.flits_forwarded)).collect()
    }

    /// Advance one cycle.
    pub fn tick(&mut self, now: u64) {
        if self.work == 0 {
            return; // idle plane: nothing can move
        }
        self.planned.iter_mut().for_each(|p| *p = [0; 5]);
        let mut moved = false;

        // --- Injection: stream one flit per tile into the local input port.
        if self.inject_msgs > 0 {
            for i in 0..self.routers.len() {
                let depth_ok =
                    self.routers[i].inq[Dir::Local.idx()].len() < self.p.queue_depth;
                if !depth_ok {
                    continue;
                }
                let inj = &mut self.inject[i];
                if inj.cur.is_none() {
                    if let Some(msg) = inj.queue.pop_front() {
                        let total = msg.flit_count(self.p.flit_bytes);
                        inj.cur = Some((msg, 0, total));
                    }
                }
                if let Some((msg, next, total)) = inj.cur.take() {
                    let flit = Flit::of_message(&msg, next, total);
                    self.routers[i].inq[Dir::Local.idx()]
                        .push_back(StampedFlit { flit, arrived: now });
                    self.stats.injected += 1;
                    self.work += 1; // flit enters the network
                    self.routers[i].occupancy += 1;
                    moved = true;
                    if next + 1 < total {
                        inj.cur = Some((msg, next + 1, total));
                    } else {
                        self.work -= 1; // message fully streamed out of inject
                        self.inject_msgs -= 1;
                    }
                }
            }
        }

        // --- Plan: per router — first drain replication buffers toward
        // their output ports, then arbitrate input ports.
        let mut drains = std::mem::take(&mut self.scratch_drains);
        let mut moves = std::mem::take(&mut self.scratch_moves);
        drains.clear();
        moves.clear();
        for r in 0..self.routers.len() {
            let router = &self.routers[r];
            if router.occupancy == 0 {
                continue; // nothing queued at this router
            }
            let mut out_busy = [false; 5];
            // Output-port allocations claimed by heads earlier in this
            // cycle's arbitration (forks don't occupy the link yet, so
            // out_busy alone cannot serialize them).
            let mut claimed = [false; 5];
            // 1. Replication-buffer drains (forked packets): one flit per
            //    output port per cycle, subject to downstream space.
            for d in Dir::ALL {
                let o = d.idx();
                let Some(sf) = router.branch_q[o].front() else { continue };
                if sf.arrived >= now {
                    continue;
                }
                if d != Dir::Local {
                    let nc = neighbor(router.coord, d, self.p.width, self.p.height)
                        .expect("fork branch routes off mesh edge");
                    let ni = self.idx(nc);
                    let np = d.opposite().idx();
                    if self.routers[ni].inq[np].len() + self.planned[ni][np] as usize
                        >= self.p.queue_depth
                    {
                        continue;
                    }
                    self.planned[ni][np] += 1;
                }
                out_busy[o] = true;
                drains.push((r, o));
            }
            // 2. Input arbitration.
            for k in 0..5 {
                let in_port = (router.rr as usize + k) % 5;
                let Some(sf) = router.inq[in_port].front() else { continue };
                if sf.arrived >= now {
                    continue; // arrived this cycle; eligible next cycle
                }
                let flit = &sf.flit;
                let is_fork_body = !flit.is_head && router.in_buffered[in_port];
                let (mask, branch_dests) = if flit.is_head {
                    debug_assert_eq!(router.in_branches[in_port], 0, "head while allocated");
                    partition_dests(router.coord, &flit.dests)
                } else {
                    (router.in_branches[in_port], Default::default())
                };
                if mask == 0 {
                    // Body flit whose head was not yet granted: wait.
                    continue;
                }
                let is_fork = mask.count_ones() > 1 || is_fork_body;
                if is_fork {
                    // Fork path: the header claims every branch port's
                    // allocation; flits then copy into the replication
                    // buffers unconditionally (the buffers absorb
                    // backpressure, keeping the dependency graph acyclic).
                    if flit.is_head {
                        let clash = Dir::ALL.iter().any(|d| {
                            let o = d.idx();
                            mask & (1 << o) != 0
                                && (router.out_alloc[o].is_some() || claimed[o])
                        });
                        if clash {
                            continue; // a branch port is held by another packet
                        }
                        for o in 0..5 {
                            if mask & (1 << o) != 0 {
                                claimed[o] = true;
                            }
                        }
                    }
                    moves.push(Move { router: r, in_port, out_mask: mask, branch_dests });
                    continue;
                }
                // Direct (unicast continuation) path: single output port.
                let o = mask.trailing_zeros() as usize;
                let d = Dir::ALL[o];
                if out_busy[o] {
                    continue;
                }
                if flit.is_head && (router.out_alloc[o].is_some() || claimed[o]) {
                    continue;
                }
                if d != Dir::Local {
                    let Some(nc) = neighbor(router.coord, d, self.p.width, self.p.height)
                    else {
                        panic!(
                            "route off mesh edge at {:?} dir {:?} (dests {:?})",
                            router.coord,
                            d,
                            flit.dests.as_slice()
                        );
                    };
                    let ni = self.idx(nc);
                    let np = d.opposite().idx();
                    if self.routers[ni].inq[np].len() + self.planned[ni][np] as usize
                        >= self.p.queue_depth
                    {
                        continue;
                    }
                    self.planned[ni][np] += 1;
                }
                out_busy[o] = true;
                if flit.is_head {
                    claimed[o] = true;
                }
                moves.push(Move { router: r, in_port, out_mask: mask, branch_dests });
            }
        }

        // --- Apply: replication-buffer drains.
        for &(r, o) in &drains {
            let StampedFlit { flit, .. } =
                self.routers[r].branch_q[o].pop_front().expect("planned drain");
            self.work -= 1;
            self.routers[r].occupancy -= 1;
            let coord = self.routers[r].coord;
            self.routers[r].flits_forwarded += 1;
            self.stats.flit_hops += 1;
            let d = Dir::ALL[o];
            if d == Dir::Local {
                if flit.is_tail {
                    self.eject[r].push_back(flit.msg.clone());
                    self.stats.delivered += 1;
                }
            } else {
                let nc = neighbor(coord, d, self.p.width, self.p.height).unwrap();
                let ni = self.idx(nc);
                self.routers[ni].inq[d.opposite().idx()]
                    .push_back(StampedFlit { flit: flit.clone(), arrived: now });
                self.work += 1;
                self.routers[ni].occupancy += 1;
            }
            if flit.is_tail {
                // Branch complete: release the output port.
                self.routers[r].out_alloc[o] = None;
            }
            moved = true;
        }

        // --- Apply: input-port moves.
        for m in &moves {
            let StampedFlit { flit, .. } =
                self.routers[m.router].inq[m.in_port].pop_front().expect("planned flit");
            self.work -= 1;
            self.routers[m.router].occupancy -= 1;
            let coord = self.routers[m.router].coord;
            let is_head = flit.is_head;
            let is_tail = flit.is_tail;
            let is_fork =
                m.out_mask.count_ones() > 1 || self.routers[m.router].in_buffered[m.in_port];
            if is_fork {
                // Copy into every branch's replication buffer.
                for d in Dir::ALL {
                    let o = d.idx();
                    if m.out_mask & (1 << o) == 0 {
                        continue;
                    }
                    let mut fwd = flit.clone();
                    if is_head {
                        fwd.dests = m.branch_dests[o];
                    }
                    self.routers[m.router].branch_q[o]
                        .push_back(StampedFlit { flit: fwd, arrived: now });
                    self.work += 1;
                    self.routers[m.router].occupancy += 1;
                }
                let router = &mut self.routers[m.router];
                if is_head {
                    for o in 0..5 {
                        if m.out_mask & (1 << o) != 0 {
                            router.out_alloc[o] = Some(m.in_port as u8);
                        }
                    }
                    if !is_tail {
                        router.in_branches[m.in_port] = m.out_mask;
                        router.in_buffered[m.in_port] = true;
                    }
                } else if is_tail {
                    router.in_branches[m.in_port] = 0;
                    router.in_buffered[m.in_port] = false;
                }
                moved = true;
                continue;
            }
            // Direct move.
            let o = m.out_mask.trailing_zeros() as usize;
            let d = Dir::ALL[o];
            self.routers[m.router].flits_forwarded += 1;
            self.stats.flit_hops += 1;
            if d == Dir::Local {
                if is_tail {
                    // Deliver the whole message at tail-ejection time.
                    self.eject[m.router].push_back(flit.msg.clone());
                    self.stats.delivered += 1;
                }
            } else {
                let nc = neighbor(coord, d, self.p.width, self.p.height).unwrap();
                let ni = self.idx(nc);
                let mut fwd = flit.clone();
                if is_head {
                    fwd.dests = m.branch_dests[o];
                }
                self.routers[ni].inq[d.opposite().idx()]
                    .push_back(StampedFlit { flit: fwd, arrived: now });
                self.work += 1;
                self.routers[ni].occupancy += 1;
            }
            // Wormhole allocation bookkeeping.
            let router = &mut self.routers[m.router];
            if is_head && !is_tail {
                router.in_branches[m.in_port] = m.out_mask;
                router.out_alloc[o] = Some(m.in_port as u8);
            } else if is_tail && !is_head {
                router.in_branches[m.in_port] = 0;
                router.out_alloc[o] = None;
            }
            moved = true;
        }

        // Return the scratch buffers for the next cycle.
        self.scratch_drains = drains;
        self.scratch_moves = moves;
        // Rotate arbitration priority.
        for r in &mut self.routers {
            r.rr = (r.rr + 1) % 5;
        }
        if moved {
            self.stats.busy_cycles += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::flit::MsgKind;

    fn mesh3x3() -> Mesh {
        Mesh::new(MeshParams { width: 3, height: 3, flit_bytes: 32, queue_depth: 4 })
    }

    fn run_until_idle(m: &mut Mesh, max: u64) -> u64 {
        let mut t = 0;
        while !m.is_idle() {
            m.tick(t);
            t += 1;
            assert!(t < max, "mesh did not drain in {max} cycles");
        }
        t
    }

    #[test]
    fn unicast_single_flit_delivery() {
        let mut m = mesh3x3();
        m.send((0, 0), Message::ctrl((0, 0), (2, 2), MsgKind::P2pReq { len: 4, prod_slot: 0, cons_slot: 0 }));
        run_until_idle(&mut m, 100);
        let got = m.recv((2, 2)).expect("delivered");
        assert_eq!(got.src, (0, 0));
        assert!(matches!(got.kind, MsgKind::P2pReq { len: 4, prod_slot: 0, cons_slot: 0 }));
        assert!(m.recv((2, 2)).is_none());
    }

    #[test]
    fn payload_arrives_intact() {
        let mut m = mesh3x3();
        let data: Vec<u8> = (0..200u16).map(|i| i as u8).collect();
        m.send(
            (1, 0),
            Message::data((1, 0), (1, 2), MsgKind::P2pData { seq: 7, prod_slot: 0 }, Arc::new(data.clone())),
        );
        run_until_idle(&mut m, 200);
        let got = m.recv((1, 2)).expect("delivered");
        assert_eq!(*got.payload, data);
        assert!(matches!(got.kind, MsgKind::P2pData { seq: 7, prod_slot: 0 }));
    }

    #[test]
    fn self_send_delivers_locally() {
        let mut m = mesh3x3();
        m.send((1, 1), Message::ctrl((1, 1), (1, 1), MsgKind::Irq { acc: 3 }));
        run_until_idle(&mut m, 50);
        assert!(m.recv((1, 1)).is_some());
    }

    #[test]
    fn multicast_reaches_every_destination_once() {
        let mut m = mesh3x3();
        let dests = DestList::from_slice(&[(0, 2), (2, 2), (2, 0), (1, 1)]);
        let payload: Vec<u8> = (0..128u8).collect();
        m.send(
            (0, 0),
            Message::multicast(
                (0, 0),
                dests,
                MsgKind::P2pData { seq: 0, prod_slot: 0 },
                Arc::new(payload.clone()),
            ),
        );
        run_until_idle(&mut m, 300);
        for c in dests.iter() {
            let got = m.recv(c).unwrap_or_else(|| panic!("no delivery at {c:?}"));
            assert_eq!(*got.payload, payload);
            assert!(m.recv(c).is_none(), "duplicate delivery at {c:?}");
        }
        // Non-destinations see nothing.
        assert!(m.recv((0, 1)).is_none());
        assert!(m.recv((2, 1)).is_none());
    }

    #[test]
    fn multicast_cheaper_than_serial_unicasts() {
        // Same data to 4 dests: one multicast must use fewer flit-hops than
        // 4 unicasts (the shared prefix is traversed once).
        let payload = Arc::new(vec![0u8; 512]);
        let dests = [(2, 2), (2, 1), (2, 0), (0, 2)];

        let mut mc = mesh3x3();
        mc.send(
            (0, 0),
            Message::multicast(
                (0, 0),
                DestList::from_slice(&dests),
                MsgKind::P2pData { seq: 0, prod_slot: 0 },
                payload.clone(),
            ),
        );
        run_until_idle(&mut mc, 1000);

        let mut uc = mesh3x3();
        for &d in &dests {
            uc.send((0, 0), Message::data((0, 0), d, MsgKind::P2pData { seq: 0, prod_slot: 0 }, payload.clone()));
        }
        run_until_idle(&mut uc, 2000);

        assert!(
            mc.stats.flit_hops < uc.stats.flit_hops,
            "multicast {} hops !< unicast {} hops",
            mc.stats.flit_hops,
            uc.stats.flit_hops
        );
    }

    #[test]
    fn one_cycle_per_hop_when_uncontended() {
        let mut m = mesh3x3();
        // (0,0) -> (0,2): 2 hops, single-flit message.
        m.send((0, 0), Message::ctrl((0, 0), (0, 2), MsgKind::P2pReq { len: 0, prod_slot: 0, cons_slot: 0 }));
        let mut t = 0;
        let mut delivered_at = None;
        while delivered_at.is_none() && t < 50 {
            m.tick(t);
            t += 1;
            if m.has_rx((0, 2)) {
                delivered_at = Some(t);
            }
        }
        // inject(1) + router (0,0) + (0,1) + (0,2)-eject: ~4-5 cycles.
        let d = delivered_at.expect("delivered");
        assert!(d <= 6, "took {d} cycles for 2 hops");
    }

    #[test]
    fn wormhole_packets_do_not_interleave_per_link() {
        let mut m = mesh3x3();
        // Two multi-flit packets from the same source to the same dest:
        // delivery order must match send order and both arrive intact.
        for seq in 0..2u32 {
            m.send(
                (0, 0),
                Message::data(
                    (0, 0),
                    (2, 2),
                    MsgKind::P2pData { seq, prod_slot: 0 },
                    Arc::new(vec![seq as u8; 160]),
                ),
            );
        }
        run_until_idle(&mut m, 500);
        let a = m.recv((2, 2)).unwrap();
        let b = m.recv((2, 2)).unwrap();
        assert!(matches!(a.kind, MsgKind::P2pData { seq: 0, prod_slot: 0 }));
        assert!(matches!(b.kind, MsgKind::P2pData { seq: 1, .. }));
        assert!(a.payload.iter().all(|&x| x == 0));
        assert!(b.payload.iter().all(|&x| x == 1));
    }

    #[test]
    fn contended_output_serializes_but_delivers_all() {
        let mut m = mesh3x3();
        // Three senders target the same column destination.
        for (i, src) in [(0u8, 0u8), (1, 0), (2, 0)].into_iter().enumerate() {
            m.send(
                src,
                Message::data(
                    src,
                    (1, 2),
                    MsgKind::P2pData { seq: i as u32, prod_slot: 0 },
                    Arc::new(vec![i as u8; 96]),
                ),
            );
        }
        run_until_idle(&mut m, 1000);
        let mut got = Vec::new();
        while let Some(msg) = m.recv((1, 2)) {
            got.push(msg);
        }
        assert_eq!(got.len(), 3);
    }

    #[test]
    fn backpressure_never_drops_flits() {
        // Tiny queues + many packets: everything still arrives.
        let mut m = Mesh::new(MeshParams { width: 3, height: 3, flit_bytes: 8, queue_depth: 2 });
        for i in 0..10u32 {
            m.send(
                (0, 0),
                Message::data((0, 0), (2, 2), MsgKind::P2pData { seq: i, prod_slot: 0 }, Arc::new(vec![0; 64])),
            );
        }
        run_until_idle(&mut m, 5000);
        let mut n = 0;
        while m.recv((2, 2)).is_some() {
            n += 1;
        }
        assert_eq!(n, 10);
    }

    #[test]
    fn stats_count_hops_and_deliveries() {
        let mut m = mesh3x3();
        m.send((0, 0), Message::ctrl((0, 0), (0, 1), MsgKind::P2pReq { len: 1, prod_slot: 0, cons_slot: 0 }));
        run_until_idle(&mut m, 100);
        assert_eq!(m.stats.delivered, 1);
        assert!(m.stats.flit_hops >= 2); // at least src router + dest eject
        assert!(m.stats.injected >= 1);
    }
}
