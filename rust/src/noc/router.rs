//! The 5-port wormhole router with multicast fork support.
//!
//! Modeled after the ESP NoC router: per-port input queues, dimension-ordered
//! routing with lookahead (1 cycle per hop), round-robin arbitration, and —
//! the paper's enhancement — the ability to forward a packet to **multiple
//! output ports in parallel** when a multicast destination list splits.
//!
//! Wormhole semantics: a header flit allocates every output port its branch
//! needs (all-or-nothing, which keeps the fork deadlock-free); body flits
//! stream behind it; the tail releases the ports.
//!
//! Hot-path layout: input queues are fixed-capacity **inline ring buffers**
//! ([`PortQ`]) of 16-byte [`Slot`]s, so steady-state traffic touches no heap
//! and router state stays cache-resident.  Arbitration priority is shared by
//! the whole plane (all routers rotate in lock-step in the seed model), so
//! the `rr` counter lives on the mesh, not here.

use std::collections::VecDeque;

use super::flit::{Coord, Flit, PktId};

/// Hard capacity of a [`PortQ`]; `MeshParams::queue_depth` must not exceed
/// it (checked at mesh construction).  16 covers every configuration the
/// paper sweeps (the RTL uses depths 2–8).
pub const MAX_QUEUE_DEPTH: usize = 16;

/// A flit waiting in a queue, stamped with its arrival cycle so a flit
/// cannot traverse two routers in one cycle.
#[derive(Debug, Clone, Copy, Default)]
pub struct Slot {
    pub flit: Flit,
    pub arrived: u64,
}

/// Fixed-capacity inline ring buffer for one input port.  Replaces the
/// seed's per-port `VecDeque<StampedFlit>`: no allocation ever, O(1)
/// push/pop, capacity bounded by [`MAX_QUEUE_DEPTH`] (the *logical* bound is
/// `queue_depth`, enforced by the mesh's backpressure accounting before any
/// push).
#[derive(Debug, Clone)]
pub struct PortQ {
    slots: [Slot; MAX_QUEUE_DEPTH],
    head: u8,
    len: u8,
}

impl PortQ {
    /// Empty queue.
    pub fn new() -> Self {
        Self { slots: [Slot::default(); MAX_QUEUE_DEPTH], head: 0, len: 0 }
    }

    /// Flits currently queued.
    #[inline]
    pub fn len(&self) -> usize {
        self.len as usize
    }

    /// No flits queued?
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The oldest queued slot, if any.
    #[inline]
    pub fn front(&self) -> Option<&Slot> {
        if self.len == 0 {
            None
        } else {
            Some(&self.slots[self.head as usize])
        }
    }

    /// Append a slot.  The mesh's depth accounting guarantees space; a
    /// violation is a scheduler bug, and it must fail loudly in release
    /// builds too — a wrapped ring would silently corrupt queued flits,
    /// where the seed's `VecDeque` would merely have grown.
    #[inline]
    pub fn push(&mut self, s: Slot) {
        assert!((self.len as usize) < MAX_QUEUE_DEPTH, "PortQ overflow");
        let tail = (self.head as usize + self.len as usize) % MAX_QUEUE_DEPTH;
        self.slots[tail] = s;
        self.len += 1;
    }

    /// Remove and return the oldest slot.
    #[inline]
    pub fn pop(&mut self) -> Option<Slot> {
        if self.len == 0 {
            return None;
        }
        let s = self.slots[self.head as usize];
        self.head = ((self.head as usize + 1) % MAX_QUEUE_DEPTH) as u8;
        self.len -= 1;
        Some(s)
    }
}

impl Default for PortQ {
    fn default() -> Self {
        Self::new()
    }
}

/// Per-router state.  The mesh drives the plan/apply cycle; the router is a
/// passive state holder plus small helpers.
///
/// Multicast forks use per-output **replication buffers** (`branch_q`):
/// synchronized-branch wormhole forking is deadlock-prone (two crossing
/// multicasts can hold-and-wait each other's branch ports — Lin & Ni), so
/// a granted fork copies flits into per-branch queues that drain toward
/// their output ports independently.  The input queue always drains, which
/// keeps the channel-dependency graph acyclic (plain dimension-ordered
/// wormhole for every branch); total buffering is bounded by the
/// pull-based consumption assumption — hence `branch_q` stays a growable
/// `VecDeque` (of 16-byte slots) while the input queues are inline rings.
#[derive(Debug)]
pub struct Router {
    /// This router's coordinate.
    pub coord: Coord,
    /// Input queue per port (N,S,E,W,Local).
    pub inq: [PortQ; 5],
    /// Wormhole allocation: output port -> input port currently holding it.
    pub out_alloc: [Option<u8>; 5],
    /// Output-port mask held by each input port (multicast branch set).
    pub in_branches: [u8; 5],
    /// True when input port `i` holds a *buffered* (forked) packet.
    pub in_buffered: [bool; 5],
    /// True when input port `i` is draining a doomed packet: its head was
    /// dropped by fault injection, so the remaining flits (through the
    /// tail) are discarded as they arrive.  Never set on a healthy mesh.
    pub in_dropping: [bool; 5],
    /// Packet whose head allocated through input port `i` (valid while
    /// `in_branches[i] != 0`).  Slab slots are recycled, so the id is
    /// paired with the slab generation below: together they name the worm
    /// exactly, which is what lets the fault drain retire allocations
    /// orphaned by an upstream truncation (DESIGN.md §fault recovery).
    pub in_pkt: [PktId; 5],
    /// Slab generation of `in_pkt[i]` at allocation time.
    pub in_pkt_gen: [u32; 5],
    /// Replication buffer per output port (forked packets only).
    pub branch_q: [VecDeque<Slot>; 5],
    /// Flits currently queued here (inq + branch_q), kept incrementally so
    /// the mesh's activity worklist can skip idle routers.
    pub occupancy: u32,
    /// Cumulative flits forwarded (stats).
    pub flits_forwarded: u64,
}

impl Router {
    /// Fresh router at `coord`.
    pub fn new(coord: Coord) -> Self {
        Self {
            coord,
            inq: Default::default(),
            out_alloc: [None; 5],
            in_branches: [0; 5],
            in_buffered: [false; 5],
            in_dropping: [false; 5],
            in_pkt: [0; 5],
            in_pkt_gen: [0; 5],
            branch_q: Default::default(),
            occupancy: 0,
            flits_forwarded: 0,
        }
    }

    /// Total queued flits (cross-check for `occupancy`).
    pub fn queued(&self) -> usize {
        self.inq.iter().map(|q| q.len()).sum::<usize>()
            + self.branch_q.iter().map(|q| q.len()).sum::<usize>()
    }
}

/// One planned movement: input port `in_port` of router `router` forwards
/// its front flit to every output port in `out_mask`.  Branch destination
/// subsets are not materialized — downstream routers re-derive them from
/// the interned message (see [`super::routing::branch_mask`]).
#[derive(Debug, Clone, Copy)]
pub struct Move {
    pub router: u32,
    pub in_port: u8,
    pub out_mask: u8,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_buffer_wraps_and_orders() {
        let mut q = PortQ::new();
        assert!(q.is_empty() && q.front().is_none() && q.pop().is_none());
        // Fill / drain across the wrap point several times.
        let mut next_push = 0u32;
        let mut next_pop = 0u32;
        for round in 0..5 {
            let n = 3 + round * 2;
            for _ in 0..n.min(MAX_QUEUE_DEPTH) {
                q.push(Slot { flit: Flit::new(next_push, 1, 3), arrived: next_push as u64 });
                next_push += 1;
            }
            assert_eq!(q.len(), n.min(MAX_QUEUE_DEPTH));
            assert_eq!(q.front().unwrap().flit.pkt, next_pop);
            for _ in 0..n.min(MAX_QUEUE_DEPTH) {
                let s = q.pop().unwrap();
                assert_eq!(s.flit.pkt, next_pop);
                assert_eq!(s.arrived, next_pop as u64);
                next_pop += 1;
            }
            assert!(q.is_empty());
        }
    }

    #[test]
    fn ring_buffer_full_capacity() {
        let mut q = PortQ::new();
        for i in 0..MAX_QUEUE_DEPTH as u32 {
            q.push(Slot { flit: Flit::new(i, 0, 1), arrived: 0 });
        }
        assert_eq!(q.len(), MAX_QUEUE_DEPTH);
        for i in 0..MAX_QUEUE_DEPTH as u32 {
            assert_eq!(q.pop().unwrap().flit.pkt, i);
        }
    }

    #[test]
    fn slot_is_compact() {
        assert!(std::mem::size_of::<Slot>() <= 24);
    }

    #[test]
    fn fresh_router_is_idle() {
        let r = Router::new((1, 2));
        assert_eq!(r.coord, (1, 2));
        assert_eq!(r.queued(), 0);
        assert_eq!(r.occupancy, 0);
        assert!(r.out_alloc.iter().all(|a| a.is_none()));
    }
}
