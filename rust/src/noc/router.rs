//! The 5-port wormhole router with multicast fork support.
//!
//! Modeled after the ESP NoC router: per-port input queues, dimension-ordered
//! routing with lookahead (1 cycle per hop), round-robin arbitration, and —
//! the paper's enhancement — the ability to forward a packet to **multiple
//! output ports in parallel** when a multicast destination list splits.
//!
//! Wormhole semantics: a header flit allocates every output port its branch
//! needs (all-or-nothing, which keeps the fork deadlock-free); body flits
//! stream behind it; the tail releases the ports.

use std::collections::VecDeque;

use super::flit::{Coord, DestList, Flit};

/// A flit waiting in an input queue, stamped with its arrival cycle so a
/// flit cannot traverse two routers in one cycle.
#[derive(Debug, Clone)]
pub struct StampedFlit {
    pub flit: Flit,
    pub arrived: u64,
}

/// Per-router state.  The mesh drives the plan/apply cycle; the router is a
/// passive state holder plus small helpers.
///
/// Multicast forks use per-output **replication buffers** (`branch_q`):
/// synchronized-branch wormhole forking is deadlock-prone (two crossing
/// multicasts can hold-and-wait each other's branch ports — Lin & Ni), so
/// a granted fork copies flits into per-branch queues that drain toward
/// their output ports independently.  The input queue always drains, which
/// keeps the channel-dependency graph acyclic (plain dimension-ordered
/// wormhole for every branch); total buffering is bounded by the
/// pull-based consumption assumption.
#[derive(Debug)]
pub struct Router {
    /// This router's coordinate.
    pub coord: Coord,
    /// Input queue per port (N,S,E,W,Local).
    pub inq: [VecDeque<StampedFlit>; 5],
    /// Wormhole allocation: output port -> input port currently holding it.
    pub out_alloc: [Option<u8>; 5],
    /// Output-port mask held by each input port (multicast branch set).
    pub in_branches: [u8; 5],
    /// True when input port `i` holds a *buffered* (forked) packet.
    pub in_buffered: [bool; 5],
    /// Replication buffer per output port (forked packets only).
    pub branch_q: [VecDeque<StampedFlit>; 5],
    /// Round-robin arbitration pointer.
    pub rr: u8,
    /// Flits currently queued here (inq + branch_q), kept incrementally so
    /// the mesh can skip idle routers.
    pub occupancy: u32,
    /// Cumulative flits forwarded (stats).
    pub flits_forwarded: u64,
}

impl Router {
    /// Fresh router at `coord`.
    pub fn new(coord: Coord) -> Self {
        Self {
            coord,
            inq: Default::default(),
            out_alloc: [None; 5],
            in_branches: [0; 5],
            in_buffered: [false; 5],
            branch_q: Default::default(),
            rr: 0,
            occupancy: 0,
            flits_forwarded: 0,
        }
    }

    /// Total queued flits (for idle detection).
    pub fn queued(&self) -> usize {
        self.inq.iter().map(|q| q.len()).sum::<usize>()
            + self.branch_q.iter().map(|q| q.len()).sum::<usize>()
    }
}

/// One planned movement: input port `in_port` of router `router` forwards
/// its front flit to every output port in `out_mask`; `branch_dests[o]`
/// holds the destination subset for the header copy sent through port `o`.
#[derive(Debug, Clone)]
pub struct Move {
    pub router: usize,
    pub in_port: usize,
    pub out_mask: u8,
    pub branch_dests: [DestList; 5],
}
