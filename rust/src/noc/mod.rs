//! The ESP-style multi-plane 2D-mesh NoC with the paper's multicast
//! extension.
//!
//! - [`flit`]: messages, flits, destination lists, header-capacity math.
//! - [`routing`]: dimension-ordered XY + lookahead, multicast partitioning.
//! - [`router`]/[`mesh`]: the wormhole router and one physical plane.
//! - [`planes`]: the six-plane bundle (3 coherence, 2 DMA, 1 misc).

pub mod flit;
pub mod mesh;
pub mod planes;
pub mod router;
pub mod routing;

pub use flit::{header_dest_capacity, CohOp, Coord, DestList, Dir, Flit, Message, MsgKind,
               MAX_DESTS};
pub use mesh::{Mesh, MeshParams, MeshStats};
pub use planes::{Noc, Plane, NUM_PLANES};
pub use routing::{hop_count, partition_dests, xy_dir};
