//! The ESP-style multi-plane 2D-mesh NoC with the paper's multicast
//! extension.
//!
//! - [`flit`]: messages, flits, destination lists, header-capacity math.
//! - [`routing`]: dimension-ordered XY/YX + lookahead, multicast
//!   partitioning, per-plane [`Orientation`]s.
//! - [`route_table`]: precomputed next hops (closed-form-exact when
//!   healthy, fault-avoiding on harvested/degraded meshes).
//! - [`router`]/[`mesh`]: the wormhole router and one physical plane.
//! - [`planes`]: the six-plane bundle (3 coherence, 2 DMA, 1 misc).
//!
//! The mesh scheduler is activity-driven (worklists of busy routers, inline
//! ring port queues, slab-interned messages with 12-byte flits) while
//! staying cycle-for-cycle identical to the straightforward full-scan
//! model; `DESIGN.md` documents the invariants and
//! `tests/prop_mesh_equiv.rs` enforces the equivalence.

pub mod flit;
pub mod mesh;
pub mod planes;
pub mod route_table;
pub mod router;
pub mod routing;

pub use flit::{bits_per_dest, coord_component_bits, header_dest_capacity,
               header_dest_capacity_for, header_meta_bits, CohOp, Coord, DestList, Dir, Flit,
               Message, MsgKind, PktId, MAX_DESTS, RESUME_NONE};
pub use mesh::{Mesh, MeshParams, MeshStats, StallProbe};
pub use planes::{Noc, Plane, TickMode, NUM_PLANES};
pub use route_table::RouteTable;
pub use router::MAX_QUEUE_DEPTH;
pub use routing::{branch_mask, hop_count, on_xy_path, on_yx_path, oriented_branch_mask,
                  partition_dests, partition_dests_oriented, xy_dir, yx_dir, Orientation};
