//! The multi-plane NoC bundle.
//!
//! ESP uses multiple *physical* planes instead of virtual channels, which is
//! what makes the single-cycle lookahead hop possible and breaks
//! message-dependent deadlock by construction: requests and responses (and
//! the three coherence message classes) never share a network.  We keep
//! ESP's six planes and assignment.

use std::sync::Arc;

use super::flit::{Coord, Dir, Message};
use super::mesh::{Mesh, MeshParams, MeshStats, StallProbe};
use super::route_table::RouteTable;
use super::routing::Orientation;
use crate::telemetry::PlaneTelemetry;

/// Plane indices (fixed, as in ESP).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Plane {
    /// Coherence requests (GetS/GetM/PutM).
    CohReq = 0,
    /// Coherence forwards (FwdGetS/FwdGetM/Inv).
    CohFwd = 1,
    /// Coherence responses (Data/InvAck/PutAck).
    CohRsp = 2,
    /// DMA + P2P requests.
    DmaReq = 3,
    /// DMA + P2P responses (bulk data).
    DmaRsp = 4,
    /// Misc: config registers, interrupts.
    Misc = 5,
}

/// Number of physical planes.
pub const NUM_PLANES: usize = 6;

impl Plane {
    /// All planes, index order.
    pub const ALL: [Plane; NUM_PLANES] =
        [Plane::CohReq, Plane::CohFwd, Plane::CohRsp, Plane::DmaReq, Plane::DmaRsp, Plane::Misc];

    /// Plane index.
    #[inline]
    pub fn idx(self) -> usize {
        self as usize
    }
}

/// How [`Noc::tick`] advances the six planes.
///
/// The planes share no state — each [`Mesh`] owns its routers, queues,
/// packet slab, and stats, and tiles only touch the NoC between ticks — so
/// a cycle may advance them concurrently without changing a single bit of
/// the outcome (`tests/prop_noc_parallel.rs` pins this).  Fanning out
/// costs a scoped-thread spawn per busy plane, so it only pays off when
/// several planes carry substantial in-flight traffic; `Auto` applies that
/// heuristic, and `Sequential` remains the always-correct fallback.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TickMode {
    /// One plane after another on the calling thread.
    Sequential,
    /// Every busy plane on its own scoped thread, unconditionally.
    Parallel,
    /// Fan out only when at least [`PAR_MIN_PLANES`] planes each carry at
    /// least [`PAR_MIN_PLANE_WORK`] in-flight items.
    #[default]
    Auto,
}

impl TickMode {
    /// Config-file code ("sequential", "parallel", "auto").
    pub fn code(self) -> &'static str {
        match self {
            TickMode::Sequential => "sequential",
            TickMode::Parallel => "parallel",
            TickMode::Auto => "auto",
        }
    }

    /// Parse a config-file code.
    pub fn from_code(s: &str) -> Option<Self> {
        Some(match s {
            "sequential" => TickMode::Sequential,
            "parallel" => TickMode::Parallel,
            "auto" => TickMode::Auto,
            _ => return None,
        })
    }
}

/// `Auto` threshold: minimum in-flight items per plane before the plane
/// counts as worth a thread (a plane ticks in well under a thread-spawn's
/// cost below this).
pub const PAR_MIN_PLANE_WORK: u64 = 512;

/// `Auto` threshold: minimum number of heavily-busy planes before
/// [`Noc::tick`] fans out.
pub const PAR_MIN_PLANES: usize = 2;

/// The six-plane NoC.
pub struct Noc {
    meshes: Vec<Mesh>,
    mode: TickMode,
    /// Per-plane routing orientation ([`Plane::ALL`] order).  Planes with
    /// the same orientation share one route-table [`Arc`].
    orients: [Orientation; NUM_PLANES],
    /// Accumulated dead routers (harvest mask + router-kill faults).  The
    /// per-orientation route tables shared across the six planes are
    /// rebuilt from these sets on every change.
    dead_routers: Vec<Coord>,
    /// Accumulated dead links (link-kill faults).
    dead_links: Vec<(Coord, Dir)>,
}

impl Noc {
    /// Build all planes with identical parameters ([`TickMode::Auto`],
    /// every plane [`Orientation::Xy`]).
    pub fn new(p: MeshParams) -> Self {
        Self {
            meshes: (0..NUM_PLANES).map(|_| Mesh::new(p)).collect(),
            mode: TickMode::Auto,
            orients: [Orientation::Xy; NUM_PLANES],
            dead_routers: Vec::new(),
            dead_links: Vec::new(),
        }
    }

    /// Install the route tables matching the current orientations and
    /// dead sets on every plane: closed-form (zero-memory) when nothing is
    /// dead, BFS-materialized otherwise.  Distinct orientations get
    /// distinct tables; planes sharing an orientation share one [`Arc`]
    /// (the materialization is O(n^2), so it runs once per orientation,
    /// not once per plane).
    fn install_tables(&mut self) {
        let p = *self.params();
        let pristine = self.dead_routers.is_empty() && self.dead_links.is_empty();
        let mut tables: Vec<(Orientation, Arc<RouteTable>)> = Vec::with_capacity(2);
        for i in 0..NUM_PLANES {
            let o = self.orients[i];
            let table = match tables.iter().find(|(t, _)| *t == o) {
                Some((_, t)) => t.clone(),
                None => {
                    let t = Arc::new(if pristine {
                        RouteTable::closed_form(o, p.width, p.height)
                    } else {
                        RouteTable::build_oriented(
                            o,
                            p.width,
                            p.height,
                            &self.dead_routers,
                            &self.dead_links,
                        )
                    });
                    tables.push((o, t.clone()));
                    t
                }
            };
            self.meshes[i].set_route_table(table);
        }
    }

    /// Assign each plane its routing orientation and install the matching
    /// tables.  Call before traffic, alongside
    /// [`set_harvest`](Self::set_harvest).
    pub fn set_orientations(&mut self, orients: [Orientation; NUM_PLANES]) {
        self.orients = orients;
        self.install_tables();
    }

    /// Per-plane routing orientations ([`Plane::ALL`] order).
    pub fn orientations(&self) -> [Orientation; NUM_PLANES] {
        self.orients
    }

    /// Disable a set of routers up front (harvest mask).  Applied before
    /// any traffic: tiles on the mask are never scheduled, injected at, or
    /// routed through.
    pub fn set_harvest(&mut self, dead: &[Coord]) {
        if dead.is_empty() {
            return;
        }
        self.dead_routers.extend_from_slice(dead);
        self.install_tables();
    }

    /// Kill the (bidirectional) link leaving `at` in direction `dir`:
    /// routes detour from the next cycle on, and each plane's fault drain
    /// drops whatever the cut strands.
    pub fn kill_link(&mut self, at: Coord, dir: Dir) {
        assert!(dir != Dir::Local, "Local ports cannot die");
        self.dead_links.push((at, dir));
        self.install_tables();
    }

    /// Kill the router at `at`: all four links die, and everything queued
    /// inside it (on every plane) is purged.
    pub fn kill_router(&mut self, at: Coord) {
        self.dead_routers.push(at);
        self.install_tables();
        for m in &mut self.meshes {
            m.kill_router(at);
        }
    }

    /// Plane 0's route table.  Orientations may differ across planes, but
    /// the dead sets never do, so liveness/reachability queries
    /// ([`RouteTable::router_dead`], [`RouteTable::reachable`]) answer for
    /// every plane.
    pub fn route_table(&self) -> &RouteTable {
        self.meshes[0].route_table()
    }

    /// Flits + messages dropped by fault injection, summed across planes.
    pub fn dropped_total(&self) -> u64 {
        self.meshes.iter().map(|m| m.stats.dropped_flits + m.stats.dropped_msgs).sum()
    }

    /// The oldest stuck flit across all planes, with the plane it is on
    /// (quiesce-watchdog forensics).
    pub fn oldest_stall(&self) -> Option<(Plane, StallProbe)> {
        let mut best: Option<(Plane, StallProbe)> = None;
        for (i, m) in self.meshes.iter().enumerate() {
            if let Some(p) = m.oldest_stall() {
                let older = match &best {
                    None => true,
                    Some((_, b)) => p.arrived < b.arrived,
                };
                if older {
                    best = Some((Plane::ALL[i], p));
                }
            }
        }
        best
    }

    /// Occupied routers per plane (quiesce-watchdog forensics).
    pub fn occupied_routers(&self, plane: Plane) -> Vec<(Coord, u32)> {
        self.meshes[plane.idx()].occupied_routers()
    }

    /// Select how [`Noc::tick`] schedules the planes.
    pub fn set_tick_mode(&mut self, mode: TickMode) {
        self.mode = mode;
    }

    /// Current plane-scheduling mode.
    pub fn tick_mode(&self) -> TickMode {
        self.mode
    }

    /// Plane parameters.
    pub fn params(&self) -> &MeshParams {
        self.meshes[0].params()
    }

    /// Inject `msg` at `tile` on `plane`.
    pub fn send(&mut self, plane: Plane, tile: Coord, msg: Message) {
        self.meshes[plane.idx()].send(tile, msg);
    }

    /// Pop a delivered message at `tile` on `plane`.
    pub fn recv(&mut self, plane: Plane, tile: Coord) -> Option<std::sync::Arc<Message>> {
        self.meshes[plane.idx()].recv(tile)
    }

    /// Any message waiting at `tile` on `plane`?
    pub fn has_rx(&self, plane: Plane, tile: Coord) -> bool {
        self.meshes[plane.idx()].has_rx(tile)
    }

    /// Advance every plane one cycle (scheduling per [`TickMode`]; the
    /// result is identical in every mode).
    pub fn tick(&mut self, now: u64) {
        let parallel = match self.mode {
            TickMode::Sequential => false,
            TickMode::Parallel => true,
            TickMode::Auto => {
                self.meshes.iter().filter(|m| m.in_flight() >= PAR_MIN_PLANE_WORK).count()
                    >= PAR_MIN_PLANES
            }
        };
        if !parallel {
            for m in &mut self.meshes {
                m.tick(now);
            }
            return;
        }
        std::thread::scope(|s| {
            let mut busy = self.meshes.iter_mut().filter(|m| !m.is_idle());
            // Keep one busy plane for the calling thread; spawn the rest.
            let local = busy.next();
            for m in busy {
                s.spawn(move || m.tick(now));
            }
            if let Some(m) = local {
                m.tick(now);
            }
        });
    }

    /// True when all planes are drained.
    pub fn is_idle(&self) -> bool {
        self.meshes.iter().all(|m| m.is_idle())
    }

    /// Visit every tile that had a message fully delivered (tail ejected)
    /// during the most recent [`Noc::tick`], on any plane, consuming the
    /// record.  The SoC scheduler uses this to unpark delivery targets;
    /// duplicates are possible (several planes or messages delivering to
    /// one tile) and callers must be idempotent.  Call between ticks —
    /// the record is consumed here (a plane that goes idle is skipped by
    /// the parallel tick, so only the drain can clear it) and cleared by
    /// the plane's next tick otherwise.
    pub fn for_each_delivered(&mut self, mut f: impl FnMut(Coord)) {
        for m in &mut self.meshes {
            for &c in m.delivered_tiles() {
                f(c);
            }
            m.clear_delivered();
        }
    }

    /// Per-plane statistics snapshot.
    pub fn stats(&self) -> [MeshStats; NUM_PLANES] {
        std::array::from_fn(|i| self.meshes[i].stats.clone())
    }

    /// Whole-NoC statistics rollup (all six planes summed) — the
    /// machine-readable bench output reports these.
    pub fn stats_total(&self) -> MeshStats {
        let mut t = MeshStats::default();
        for m in &self.meshes {
            t.flit_hops += m.stats.flit_hops;
            t.delivered += m.stats.delivered;
            t.injected += m.stats.injected;
            t.busy_cycles += m.stats.busy_cycles;
            t.dropped_flits += m.stats.dropped_flits;
            t.dropped_msgs += m.stats.dropped_msgs;
            t.drained_worms += m.stats.drained_worms;
        }
        t
    }

    /// Per-router forwarded-flit loads on one plane.
    pub fn router_loads(&self, plane: Plane) -> Vec<(Coord, u64)> {
        self.meshes[plane.idx()].router_loads()
    }

    /// Arm (or disarm) congestion telemetry on every plane.  Planes share
    /// nothing, so the parallel tick needs no coordination: each mesh owns
    /// its counters.
    pub fn set_telemetry(&mut self, on: bool) {
        for m in &mut self.meshes {
            m.set_telemetry(on);
        }
    }

    /// Is telemetry armed?  (Planes are armed and disarmed together.)
    pub fn telemetry_enabled(&self) -> bool {
        self.meshes[0].telemetry().is_some()
    }

    /// Per-plane telemetry snapshot ([`Plane::ALL`] order), pairing each
    /// mesh's congestion counters with its ungated per-router forward
    /// counts.  `None` unless telemetry is armed.
    pub fn plane_telemetry(&self) -> Option<Vec<PlaneTelemetry>> {
        self.meshes[0].telemetry()?;
        Some(
            self.meshes
                .iter()
                .map(|m| {
                    let t = m.telemetry().expect("planes arm telemetry together");
                    PlaneTelemetry {
                        stall: t.stall.clone(),
                        stall_dir: t.stall_dir.clone(),
                        forwarded: m.router_loads().iter().map(|&(_, n)| n).collect(),
                        forks: t.forks.clone(),
                        occ_sum: t.occ_sum.clone(),
                        active_ticks: t.active_ticks,
                    }
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::flit::MsgKind;

    #[test]
    fn planes_are_independent() {
        let mut noc =
            Noc::new(MeshParams { width: 3, height: 3, flit_bytes: 32, queue_depth: 4 });
        let req = MsgKind::P2pReq {
            len: 8,
            prod_slot: 0,
            cons_slot: 0,
            resume: crate::noc::flit::RESUME_NONE,
        };
        noc.send(Plane::DmaReq, (0, 0), Message::ctrl((0, 0), (1, 1), req));
        noc.send(Plane::Misc, (0, 0), Message::ctrl((0, 0), (1, 1), MsgKind::Irq { acc: 0 }));
        let mut t = 0;
        while !noc.is_idle() {
            noc.tick(t);
            t += 1;
            assert!(t < 100);
        }
        assert!(matches!(noc.recv(Plane::DmaReq, (1, 1)).unwrap().kind, MsgKind::P2pReq { .. }));
        assert!(matches!(noc.recv(Plane::Misc, (1, 1)).unwrap().kind, MsgKind::Irq { .. }));
        assert!(noc.recv(Plane::CohReq, (1, 1)).is_none());
    }

    #[test]
    fn parallel_mode_matches_sequential() {
        let p = MeshParams { width: 4, height: 4, flit_bytes: 16, queue_depth: 4 };
        let run = |mode: TickMode| {
            let mut noc = Noc::new(p);
            noc.set_tick_mode(mode);
            assert_eq!(noc.tick_mode(), mode);
            for (i, plane) in Plane::ALL.iter().enumerate() {
                noc.send(
                    *plane,
                    (0, i as u8 % 4),
                    Message::data(
                        (0, i as u8 % 4),
                        (3, 3),
                        MsgKind::P2pData { seq: i as u32, prod_slot: 0 },
                        std::sync::Arc::new(vec![i as u8; 300]),
                    ),
                );
            }
            let mut t = 0;
            while !noc.is_idle() {
                noc.tick(t);
                t += 1;
                assert!(t < 1000);
            }
            let seqs: Vec<u32> = Plane::ALL
                .iter()
                .map(|&pl| match noc.recv(pl, (3, 3)).expect("delivered").kind {
                    MsgKind::P2pData { seq, .. } => seq,
                    _ => unreachable!(),
                })
                .collect();
            (t, noc.stats(), seqs)
        };
        let a = run(TickMode::Sequential);
        let b = run(TickMode::Parallel);
        let c = run(TickMode::Auto);
        assert_eq!(a, b);
        assert_eq!(a, c);
    }

    #[test]
    fn plane_indices_stable() {
        assert_eq!(Plane::CohReq.idx(), 0);
        assert_eq!(Plane::Misc.idx(), 5);
        for (i, p) in Plane::ALL.iter().enumerate() {
            assert_eq!(p.idx(), i);
        }
    }

    #[test]
    fn mixed_orientations_route_per_plane() {
        let p = MeshParams { width: 4, height: 4, flit_bytes: 16, queue_depth: 4 };
        let mut noc = Noc::new(p);
        assert_eq!(noc.orientations(), [Orientation::Xy; NUM_PLANES]);
        let mut orients = [Orientation::Xy; NUM_PLANES];
        orients[Plane::CohRsp.idx()] = Orientation::Yx;
        orients[Plane::DmaRsp.idx()] = Orientation::Yx;
        noc.set_orientations(orients);
        assert_eq!(noc.orientations(), orients);
        // Each plane got the table matching its orientation, planes
        // sharing an orientation share one Arc, and none materialized.
        for (i, pl) in Plane::ALL.iter().enumerate() {
            let t = noc.meshes[pl.idx()].route_table();
            assert_eq!(t.orientation(), orients[i], "{pl:?}");
            assert!(!t.has_faults(), "{pl:?}: pristine mesh must stay closed-form");
        }
        assert!(std::ptr::eq(
            noc.meshes[Plane::CohRsp.idx()].route_table(),
            noc.meshes[Plane::DmaRsp.idx()].route_table(),
        ));
        assert!(!std::ptr::eq(
            noc.meshes[Plane::CohReq.idx()].route_table(),
            noc.meshes[Plane::CohRsp.idx()].route_table(),
        ));
        // Both regimes deliver the same traffic (over different paths).
        for pl in [Plane::DmaReq, Plane::DmaRsp] {
            noc.send(
                pl,
                (0, 0),
                Message::data(
                    (0, 0),
                    (3, 3),
                    MsgKind::P2pData { seq: 7, prod_slot: 0 },
                    std::sync::Arc::new(vec![0u8; 200]),
                ),
            );
        }
        let mut t = 0;
        while !noc.is_idle() {
            noc.tick(t);
            t += 1;
            assert!(t < 1000);
        }
        for pl in [Plane::DmaReq, Plane::DmaRsp] {
            assert!(noc.recv(pl, (3, 3)).is_some(), "{pl:?} lost its message");
        }
    }

    #[test]
    fn mixed_orientations_survive_a_harvest_rebuild() {
        let p = MeshParams { width: 4, height: 4, flit_bytes: 16, queue_depth: 4 };
        let mut noc = Noc::new(p);
        let mut orients = [Orientation::Xy; NUM_PLANES];
        orients[Plane::CohRsp.idx()] = Orientation::Yx;
        noc.set_orientations(orients);
        noc.set_harvest(&[(1, 1)]);
        for (i, pl) in Plane::ALL.iter().enumerate() {
            let t = noc.meshes[pl.idx()].route_table();
            assert_eq!(t.orientation(), orients[i], "{pl:?}: rebuild lost the orientation");
            assert!(t.has_faults(), "{pl:?}: harvest must materialize the table");
            assert!(t.router_dead((1, 1)), "{pl:?}: dead sets are shared across planes");
        }
    }
}
