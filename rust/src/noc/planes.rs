//! The multi-plane NoC bundle.
//!
//! ESP uses multiple *physical* planes instead of virtual channels, which is
//! what makes the single-cycle lookahead hop possible and breaks
//! message-dependent deadlock by construction: requests and responses (and
//! the three coherence message classes) never share a network.  We keep
//! ESP's six planes and assignment.

use super::flit::{Coord, Message};
use super::mesh::{Mesh, MeshParams, MeshStats};

/// Plane indices (fixed, as in ESP).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Plane {
    /// Coherence requests (GetS/GetM/PutM).
    CohReq = 0,
    /// Coherence forwards (FwdGetS/FwdGetM/Inv).
    CohFwd = 1,
    /// Coherence responses (Data/InvAck/PutAck).
    CohRsp = 2,
    /// DMA + P2P requests.
    DmaReq = 3,
    /// DMA + P2P responses (bulk data).
    DmaRsp = 4,
    /// Misc: config registers, interrupts.
    Misc = 5,
}

/// Number of physical planes.
pub const NUM_PLANES: usize = 6;

impl Plane {
    /// All planes, index order.
    pub const ALL: [Plane; NUM_PLANES] =
        [Plane::CohReq, Plane::CohFwd, Plane::CohRsp, Plane::DmaReq, Plane::DmaRsp, Plane::Misc];

    /// Plane index.
    #[inline]
    pub fn idx(self) -> usize {
        self as usize
    }
}

/// The six-plane NoC.
pub struct Noc {
    meshes: Vec<Mesh>,
}

impl Noc {
    /// Build all planes with identical parameters.
    pub fn new(p: MeshParams) -> Self {
        Self { meshes: (0..NUM_PLANES).map(|_| Mesh::new(p)).collect() }
    }

    /// Plane parameters.
    pub fn params(&self) -> &MeshParams {
        self.meshes[0].params()
    }

    /// Inject `msg` at `tile` on `plane`.
    pub fn send(&mut self, plane: Plane, tile: Coord, msg: Message) {
        self.meshes[plane.idx()].send(tile, msg);
    }

    /// Pop a delivered message at `tile` on `plane`.
    pub fn recv(&mut self, plane: Plane, tile: Coord) -> Option<std::sync::Arc<Message>> {
        self.meshes[plane.idx()].recv(tile)
    }

    /// Any message waiting at `tile` on `plane`?
    pub fn has_rx(&self, plane: Plane, tile: Coord) -> bool {
        self.meshes[plane.idx()].has_rx(tile)
    }

    /// Advance every plane one cycle.
    pub fn tick(&mut self, now: u64) {
        for m in &mut self.meshes {
            m.tick(now);
        }
    }

    /// True when all planes are drained.
    pub fn is_idle(&self) -> bool {
        self.meshes.iter().all(|m| m.is_idle())
    }

    /// Per-plane statistics snapshot.
    pub fn stats(&self) -> [MeshStats; NUM_PLANES] {
        std::array::from_fn(|i| self.meshes[i].stats.clone())
    }

    /// Whole-NoC statistics rollup (all six planes summed) — the
    /// machine-readable bench output reports these.
    pub fn stats_total(&self) -> MeshStats {
        let mut t = MeshStats::default();
        for m in &self.meshes {
            t.flit_hops += m.stats.flit_hops;
            t.delivered += m.stats.delivered;
            t.injected += m.stats.injected;
            t.busy_cycles += m.stats.busy_cycles;
        }
        t
    }

    /// Per-router forwarded-flit loads on one plane.
    pub fn router_loads(&self, plane: Plane) -> Vec<(Coord, u64)> {
        self.meshes[plane.idx()].router_loads()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::flit::MsgKind;

    #[test]
    fn planes_are_independent() {
        let mut noc =
            Noc::new(MeshParams { width: 3, height: 3, flit_bytes: 32, queue_depth: 4 });
        noc.send(Plane::DmaReq, (0, 0), Message::ctrl((0, 0), (1, 1), MsgKind::P2pReq { len: 8, prod_slot: 0, cons_slot: 0 }));
        noc.send(Plane::Misc, (0, 0), Message::ctrl((0, 0), (1, 1), MsgKind::Irq { acc: 0 }));
        let mut t = 0;
        while !noc.is_idle() {
            noc.tick(t);
            t += 1;
            assert!(t < 100);
        }
        assert!(matches!(noc.recv(Plane::DmaReq, (1, 1)).unwrap().kind, MsgKind::P2pReq { .. }));
        assert!(matches!(noc.recv(Plane::Misc, (1, 1)).unwrap().kind, MsgKind::Irq { .. }));
        assert!(noc.recv(Plane::CohReq, (1, 1)).is_none());
    }

    #[test]
    fn plane_indices_stable() {
        assert_eq!(Plane::CohReq.idx(), 0);
        assert_eq!(Plane::Misc.idx(), 5);
        for (i, p) in Plane::ALL.iter().enumerate() {
            assert_eq!(p.idx(), i);
        }
    }
}
