//! Flit and message types for the multi-plane ESP NoC.
//!
//! A NoC *message* is the protocol-level unit (a DMA request, a burst of
//! data, a coherence message, ...).  Messages are packetized into *flits*:
//! one header flit carrying metadata — including the **destination list**
//! that is this paper's multicast enhancement — followed by body flits of
//! `bitwidth/8` payload bytes each.  The number of destinations encodable
//! in the header is bounded by the NoC bitwidth exactly as in the paper
//! (64-bit -> 5, 128-bit -> 14, 256-bit -> 16); see
//! [`header_dest_capacity`].

use std::sync::Arc;

/// Tile coordinate `(y, x)` in the 2D mesh.
pub type Coord = (u8, u8);

/// Output direction at a router (also identifies the 5 ports).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dir {
    North = 0,
    South = 1,
    East = 2,
    West = 3,
    Local = 4,
}

impl Dir {
    /// All five ports, index order.
    pub const ALL: [Dir; 5] = [Dir::North, Dir::South, Dir::East, Dir::West, Dir::Local];

    /// Port index (0..5).
    #[inline]
    pub fn idx(self) -> usize {
        self as usize
    }

    /// The port on the neighbouring router that a flit leaving through
    /// `self` arrives on.
    pub fn opposite(self) -> Dir {
        match self {
            Dir::North => Dir::South,
            Dir::South => Dir::North,
            Dir::East => Dir::West,
            Dir::West => Dir::East,
            Dir::Local => Dir::Local,
        }
    }
}

/// Hard cap on multicast destinations (the paper's current implementation
/// supports up to 16).
pub const MAX_DESTS: usize = 16;

/// Bits of one coordinate component spanning `0..n`: `ceil(log2(n))`, with
/// a floor of 3 — the RTL's fixed coordinate field, sized for the 8x8
/// meshes the paper prototypes.  Meshes up to 8x8 therefore share one
/// encoding (and the paper's §4 capacities); wider meshes grow the field.
pub const fn coord_component_bits(n: u8) -> u32 {
    let mut bits = 3;
    while (1u32 << bits) < n as u32 {
        bits += 1;
    }
    bits
}

/// Bits to encode one destination in the header: the `(y, x)` coordinate
/// of a `width x height` mesh plus a valid bit.  7 on meshes up to 8x8
/// (the paper's encoding), 9 on a 16x16 mesh.
pub const fn bits_per_dest(width: u8, height: u8) -> u32 {
    coord_component_bits(height) + coord_component_bits(width) + 1
}

/// Header metadata bits that do not scale with the mesh (message kind,
/// sequence / length fields) — calibrated so an 8x8 mesh reproduces the
/// paper's capacities.
pub const HEADER_FIXED_META_BITS: u32 = 23;

/// Header metadata bits for a `width x height` mesh: the fixed fields plus
/// the source coordinate.  29 on meshes up to 8x8, matching the paper.
pub const fn header_meta_bits(width: u8, height: u8) -> u32 {
    HEADER_FIXED_META_BITS + coord_component_bits(height) + coord_component_bits(width)
}

/// How many destinations a header flit of `bitwidth` bits can encode on a
/// `width x height` mesh, capped at [`MAX_DESTS`].  On meshes up to 8x8
/// this is the paper's §4 table (64 -> 5, 128 -> 14, 256 -> 16); wider
/// meshes spend more header bits per coordinate and the capacity shrinks
/// (16x16: 64 -> 3, 128 -> 10, 256 -> 16).
pub fn header_dest_capacity_for(bitwidth: u32, width: u8, height: u8) -> usize {
    let avail = bitwidth.saturating_sub(header_meta_bits(width, height));
    ((avail / bits_per_dest(width, height)) as usize).min(MAX_DESTS)
}

/// Header destination capacity in the paper's (up to 8x8) encoding:
/// 64 -> 5, 128 -> 14, 256 -> 16, matching §4.
pub fn header_dest_capacity(bitwidth: u32) -> usize {
    header_dest_capacity_for(bitwidth, 8, 8)
}

/// A fixed-capacity destination list (the multicast header extension).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DestList {
    coords: [Coord; MAX_DESTS],
    len: u8,
}

impl DestList {
    /// Empty list.
    pub const fn new() -> Self {
        Self { coords: [(0, 0); MAX_DESTS], len: 0 }
    }

    /// Single (unicast) destination.
    pub fn unicast(c: Coord) -> Self {
        let mut d = Self::new();
        d.push(c);
        d
    }

    /// Build from a slice (panics if longer than [`MAX_DESTS`]).
    pub fn from_slice(cs: &[Coord]) -> Self {
        assert!(cs.len() <= MAX_DESTS, "too many multicast destinations");
        let mut d = Self::new();
        for &c in cs {
            d.push(c);
        }
        d
    }

    /// Append a destination.
    pub fn push(&mut self, c: Coord) {
        assert!((self.len as usize) < MAX_DESTS, "DestList overflow");
        self.coords[self.len as usize] = c;
        self.len += 1;
    }

    /// Number of destinations.
    pub fn len(&self) -> usize {
        self.len as usize
    }

    /// True if no destinations.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The destinations as a slice.
    pub fn as_slice(&self) -> &[Coord] {
        &self.coords[..self.len as usize]
    }

    /// Iterate destinations.
    pub fn iter(&self) -> impl Iterator<Item = Coord> + '_ {
        self.as_slice().iter().copied()
    }
}

impl Default for DestList {
    fn default() -> Self {
        Self::new()
    }
}

/// Coherence opcodes (MESI over the three coherence planes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CohOp {
    /// Read request (wants Shared).
    GetS,
    /// Write request (wants Modified).
    GetM,
    /// Writeback of a Modified line (carries data).
    PutM,
    /// Directory -> owner: forward line to requester (who wants S).
    FwdGetS,
    /// Directory -> owner: forward line + ownership to requester.
    FwdGetM,
    /// Directory -> sharer: invalidate.
    Inv,
    /// Sharer -> requester: invalidation acknowledged.
    InvAck,
    /// Data response, Shared state.
    Data,
    /// Data response, Exclusive/Modified grant. `ack_count` pending InvAcks.
    DataM,
    /// Writeback acknowledged.
    PutAck,
}

/// Protocol-level content of a message.  `tag` fields let requesters match
/// responses to outstanding transactions; `slot` fields address one of the
/// (up to two) accelerator sockets sharing a tile's NoC port.
#[derive(Debug, Clone, PartialEq)]
pub enum MsgKind {
    /// Accelerator/CPU -> memory tile: read `len` bytes at physical `addr`.
    DmaReadReq { addr: u64, len: u32, tag: u32, slot: u8 },
    /// Accelerator/CPU -> memory tile: write payload at physical `addr`.
    DmaWriteReq { addr: u64, len: u32, tag: u32, slot: u8 },
    /// Memory tile -> requester: read data (payload attached).
    DmaReadRsp { tag: u32, slot: u8 },
    /// Memory tile -> requester: write committed.
    DmaWriteAck { tag: u32, slot: u8 },
    /// Consumer socket -> producer socket: pull request for `len` bytes
    /// (the *length-carrying* request of the flexible-P2P enhancement).
    /// `resume` is [`RESUME_NONE`] on a fresh pull; a retransmission
    /// request carries the consumer's exact stream offset instead, so a
    /// replay-buffering producer can resend the lost bytes (DESIGN.md
    /// §fault recovery).
    P2pReq { len: u32, prod_slot: u8, cons_slot: u8, resume: u32 },
    /// Producer socket -> consumer socket(s): forwarded data (payload
    /// attached).  Multicast when the header has several destinations;
    /// consumers match on `(src coord, prod_slot)`.  `seq` is a plain
    /// per-producer message counter on the legacy path; with the replay
    /// window armed (`replay_window > 0`) the producer repurposes the same
    /// header field as the payload's **stream offset**, which lets
    /// consumers place bytes exactly and drop gapped or duplicate chunks
    /// instead of mis-assembling them (DESIGN.md §fault recovery).
    P2pData { seq: u32, prod_slot: u8 },
    /// Coherence protocol message; `line` is the cache-line address.
    Coh { op: CohOp, line: u64, ack_count: u16 },
    /// CPU -> tile: configuration-register write (misc plane).  The high
    /// nibble of `reg` selects the socket slot.
    RegWrite { reg: u16, val: u64 },
    /// CPU -> tile: configuration-register read.
    RegRead { reg: u16, tag: u32 },
    /// Tile -> CPU: register read response.
    RegReadRsp { tag: u32, val: u64 },
    /// Accelerator tile -> CPU: invocation finished (`acc` = global id).
    Irq { acc: u16 },
}

/// `resume` sentinel of [`MsgKind::P2pReq`]: a fresh pull request (no
/// retransmission implied).  Stream offsets wrap far below this value in
/// practice — a single invocation moves at most `u32::MAX - 1` bytes.
pub const RESUME_NONE: u32 = u32::MAX;

/// A protocol message travelling on one NoC plane.
#[derive(Debug, Clone)]
pub struct Message {
    /// Source tile.
    pub src: Coord,
    /// Destination tile(s); more than one == multicast.
    pub dests: DestList,
    /// Protocol content.
    pub kind: MsgKind,
    /// Bulk payload bytes (empty for control messages).
    pub payload: Arc<Vec<u8>>,
    /// P2P consumer-slot participation mask: bit `2*i + slot` set when the
    /// socket `(dests[i], slot)` consumes this message (two sockets on one
    /// tile share a single delivered copy).  0 for non-P2P messages.
    pub cons_slots: u32,
}

impl Message {
    /// Control message (no payload).
    pub fn ctrl(src: Coord, dest: Coord, kind: MsgKind) -> Self {
        Self {
            src,
            dests: DestList::unicast(dest),
            kind,
            payload: Arc::new(Vec::new()),
            cons_slots: 0,
        }
    }

    /// Data-bearing message to one destination.
    pub fn data(src: Coord, dest: Coord, kind: MsgKind, payload: Arc<Vec<u8>>) -> Self {
        Self { src, dests: DestList::unicast(dest), kind, payload, cons_slots: 0 }
    }

    /// Data-bearing multicast message.
    pub fn multicast(src: Coord, dests: DestList, kind: MsgKind, payload: Arc<Vec<u8>>) -> Self {
        Self { src, dests, kind, payload, cons_slots: 0 }
    }

    /// Total flits this message occupies on a NoC with `flit_bytes`-byte
    /// flits: 1 header + ceil(payload / flit_bytes) body flits.
    pub fn flit_count(&self, flit_bytes: u32) -> u32 {
        1 + (self.payload.len() as u32).div_ceil(flit_bytes)
    }
}

/// Identifies an in-flight packet in a plane's message slab (see
/// `mesh::PacketSlab`).
pub type PktId = u32;

/// One flit in flight — 12 bytes, `Copy`, no heap references.
///
/// The seed model's flit dragged the full 34-byte [`DestList`] plus an
/// `Arc<Message>` (an atomic refcount bump per hop).  Now the message is
/// interned once per packet in the plane's slab and flits carry only the
/// `u32` packet id; the id resolves back to the `Arc<Message>` at ejection.
/// Headers no longer carry an explicit destination list either: XY routing
/// is deterministic, so the branch destination set at any router is
/// recomputed from the interned `(src, dests)` pair (see
/// [`super::routing::branch_mask`]) — body flits never needed it.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Flit {
    /// [`Flit::HEAD`] | [`Flit::TAIL`] flag bits.
    pub flags: u8,
    /// Flit sequence number within the packet (0 for the header).
    pub seq: u32,
    /// Slab id of the message this flit belongs to.
    pub pkt: PktId,
}

impl Flit {
    /// Flag bit: header flit (allocates the wormhole path).
    pub const HEAD: u8 = 1 << 0;
    /// Flag bit: tail flit (releases the path, triggers ejection).
    pub const TAIL: u8 = 1 << 1;

    /// Build the `i`-th flit (of `total`) for packet `pkt`.
    #[inline]
    pub fn new(pkt: PktId, i: u32, total: u32) -> Self {
        let mut flags = 0;
        if i == 0 {
            flags |= Self::HEAD;
        }
        if i + 1 == total {
            flags |= Self::TAIL;
        }
        Flit { flags, seq: i, pkt }
    }

    /// Header flit?
    #[inline]
    pub fn is_head(self) -> bool {
        self.flags & Self::HEAD != 0
    }

    /// Tail flit?
    #[inline]
    pub fn is_tail(self) -> bool {
        self.flags & Self::TAIL != 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_capacity_matches_paper() {
        assert_eq!(header_dest_capacity(64), 5);
        assert_eq!(header_dest_capacity(128), 14);
        assert_eq!(header_dest_capacity(256), 16); // capped at 16
        assert_eq!(header_dest_capacity(32), 0); // no room: control-only
    }

    #[test]
    fn coordinate_fields_floor_at_the_rtl_width() {
        // Every mesh up to 8x8 shares the paper's encoding.
        for n in 2u8..=8 {
            assert_eq!(coord_component_bits(n), 3, "n={n}");
        }
        for n in 9u8..=16 {
            assert_eq!(coord_component_bits(n), 4, "n={n}");
        }
        assert_eq!(bits_per_dest(8, 8), 7);
        assert_eq!(bits_per_dest(4, 3), 7, "small meshes keep the 8x8 fields");
        assert_eq!(bits_per_dest(16, 16), 9);
        assert_eq!(header_meta_bits(8, 8), 29);
        assert_eq!(header_meta_bits(16, 16), 31);
    }

    #[test]
    fn header_capacity_shrinks_on_wide_meshes() {
        // Paper numbers on every mesh up to 8x8...
        for (w, h) in [(2u8, 2u8), (4, 3), (8, 8)] {
            assert_eq!(header_dest_capacity_for(64, w, h), 5);
            assert_eq!(header_dest_capacity_for(128, w, h), 14);
            assert_eq!(header_dest_capacity_for(256, w, h), 16);
        }
        // ...and the recomputed 9-bit-destination capacities on 16x16.
        assert_eq!(header_dest_capacity_for(64, 16, 16), 3);
        assert_eq!(header_dest_capacity_for(128, 16, 16), 10);
        assert_eq!(header_dest_capacity_for(256, 16, 16), 16); // 25, capped
        // Mixed shapes size each axis's coordinate field independently.
        assert_eq!(header_dest_capacity_for(128, 16, 4), 12); // 30 meta, 8/dest
    }

    #[test]
    fn dest_list_roundtrip() {
        let cs = [(0u8, 1u8), (2, 3), (1, 1)];
        let d = DestList::from_slice(&cs);
        assert_eq!(d.len(), 3);
        assert_eq!(d.as_slice(), &cs);
        assert!(!d.is_empty());
        assert!(DestList::new().is_empty());
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn dest_list_overflow_panics() {
        let mut d = DestList::new();
        for i in 0..=MAX_DESTS {
            d.push((i as u8, 0));
        }
    }

    #[test]
    fn flit_count_includes_header() {
        let msg = Message::ctrl(
            (0, 0),
            (1, 1),
            MsgKind::P2pReq { len: 64, prod_slot: 0, cons_slot: 0, resume: RESUME_NONE },
        );
        assert_eq!(msg.flit_count(32), 1);
        let data = Message::data(
            (0, 0),
            (1, 1),
            MsgKind::P2pData { seq: 0, prod_slot: 0 },
            Arc::new(vec![0u8; 100]),
        );
        assert_eq!(data.flit_count(32), 1 + 4); // 100/32 -> 4 body flits
    }

    #[test]
    fn opposite_dirs() {
        for d in Dir::ALL {
            assert_eq!(d.opposite().opposite(), d);
        }
        assert_eq!(Dir::North.opposite(), Dir::South);
        assert_eq!(Dir::East.opposite(), Dir::West);
    }

    #[test]
    fn flit_head_tail_flags() {
        let msg = Message::data(
            (0, 0),
            (1, 1),
            MsgKind::P2pData { seq: 0, prod_slot: 0 },
            Arc::new(vec![0u8; 64]),
        );
        let total = msg.flit_count(32);
        assert_eq!(total, 3);
        let f0 = Flit::new(7, 0, total);
        let f1 = Flit::new(7, 1, total);
        let f2 = Flit::new(7, 2, total);
        assert!(f0.is_head() && !f0.is_tail());
        assert!(!f1.is_head() && !f1.is_tail());
        assert!(!f2.is_head() && f2.is_tail());
        assert_eq!((f0.pkt, f2.seq), (7, 2));
    }

    #[test]
    fn flit_is_small_and_copy() {
        // The whole point of the slim flit: it must stay pocket-sized so
        // ring-buffer slots are cache-friendly.
        assert!(std::mem::size_of::<Flit>() <= 12);
        let f = Flit::new(0, 0, 1);
        let g = f; // Copy, no clone needed
        assert_eq!(f, g);
        assert!(f.is_head() && f.is_tail()); // single-flit packet
    }
}
