//! NoC-router area model (Fig. 4 of the paper).
//!
//! The paper synthesizes the ESP router with Cadence Genus at 12 nm,
//! sweeping the NoC bitwidth and the maximum number of multicast
//! destinations.  We cannot run Genus, so this is a **component-level
//! analytic model calibrated to the paper's published anchors**:
//!
//! - 64-bit baseline router (no multicast): 3620 um^2
//! - 128-bit: 6230 um^2; 256-bit: 11520 um^2 ("roughly proportional ...
//!   much of the router area is occupied by the input queues")
//! - adding one multicast destination costs ~200 um^2 on average
//!   (replicated lookahead routing logic + wider header handling)
//! - the number of encodable destinations is bounded by the header
//!   capacity: 64-bit -> 5, 128-bit -> 14, 256-bit -> 16 (cap).
//!
//! The model decomposes the router into input queues (scale with
//! bitwidth x ports x depth), crossbar (bitwidth x ports^2), base control
//! (constant), and per-destination multicast logic (lookahead replica +
//! fork control), then fits the free coefficients to the anchors.

use crate::noc::{bits_per_dest, header_dest_capacity_for};

/// Router area model parameters (um^2 at 12 nm).  The defaults reproduce
/// the paper's anchors; see [`RouterAreaModel::calibrated`].
#[derive(Debug, Clone, Copy)]
pub struct RouterAreaModel {
    /// Fixed control area independent of bitwidth (arbiters, FSMs).
    pub base: f64,
    /// Area per bit of datapath width: input queues (5 ports x depth).
    pub per_bit_queue: f64,
    /// Area per bit of datapath width: crossbar + output muxes.
    pub per_bit_xbar: f64,
    /// Area per supported multicast destination (replicated lookahead
    /// route computation + header-rewrite logic).
    pub per_dest: f64,
}

impl RouterAreaModel {
    /// Coefficients fitted to the paper's Fig. 4 anchors.
    ///
    /// Queues + crossbar scale linearly in bitwidth; solving
    /// `base + k * 64 = 3620` and `base + k * 256 = 11520` gives
    /// `k = 41.15 um^2/bit`, `base = 986 um^2` (the 128-bit point lands at
    /// 6253 um^2 vs the paper's 6230, within 0.4%).
    pub fn calibrated() -> Self {
        let k = (11520.0 - 3620.0) / (256.0 - 64.0); // 41.145..
        Self {
            base: 3620.0 - k * 64.0,
            per_bit_queue: k * 0.8, // queues dominate, per the paper
            per_bit_xbar: k * 0.2,
            per_dest: 200.0,
        }
    }

    /// Area (um^2) of a router with `bitwidth`-bit flits supporting up to
    /// `max_dests` multicast destinations (0 = no multicast support), in
    /// the paper's synthesized (up to 8x8) coordinate encoding.  Returns
    /// `None` when `max_dests` exceeds what the header can encode.
    pub fn area(&self, bitwidth: u32, max_dests: usize) -> Option<f64> {
        self.area_for_mesh(bitwidth, max_dests, 8, 8)
    }

    /// Area for a router of a `width x height` mesh.  The per-destination
    /// logic (lookahead replica + header handling) scales with the
    /// destination field width, so wider meshes pay `bits_per_dest / 7` of
    /// the calibrated 8x8 per-destination cost, and the capacity bound uses
    /// that mesh's header encoding.
    pub fn area_for_mesh(
        &self,
        bitwidth: u32,
        max_dests: usize,
        width: u8,
        height: u8,
    ) -> Option<f64> {
        if max_dests > header_dest_capacity_for(bitwidth, width, height) {
            return None;
        }
        let bits = bitwidth as f64;
        let dest_scale = bits_per_dest(width, height) as f64 / bits_per_dest(8, 8) as f64;
        Some(
            self.base
                + (self.per_bit_queue + self.per_bit_xbar) * bits
                + self.per_dest * dest_scale * max_dests as f64,
        )
    }

    /// Relative overhead of multicast support vs the no-multicast baseline.
    pub fn overhead(&self, bitwidth: u32, max_dests: usize) -> Option<f64> {
        self.overhead_for_mesh(bitwidth, max_dests, 8, 8)
    }

    /// [`RouterAreaModel::overhead`] for a `width x height` mesh.
    pub fn overhead_for_mesh(
        &self,
        bitwidth: u32,
        max_dests: usize,
        width: u8,
        height: u8,
    ) -> Option<f64> {
        let base = self.area_for_mesh(bitwidth, 0, width, height)?;
        Some(self.area_for_mesh(bitwidth, max_dests, width, height)? / base - 1.0)
    }
}

impl Default for RouterAreaModel {
    fn default() -> Self {
        Self::calibrated()
    }
}

/// One row of the Fig. 4 sweep.
#[derive(Debug, Clone, Copy)]
pub struct AreaPoint {
    /// NoC bitwidth.
    pub bitwidth: u32,
    /// Maximum multicast destinations.
    pub max_dests: usize,
    /// Post-"synthesis" area, um^2.
    pub area_um2: f64,
    /// Overhead vs the same-bitwidth baseline.
    pub overhead: f64,
}

/// Regenerate the Fig. 4 sweep: bitwidths x destination counts (skipping
/// configurations the header cannot encode, as the paper does).
pub fn fig4_sweep() -> Vec<AreaPoint> {
    fig4_sweep_for_mesh(8, 8)
}

/// The Fig. 4 sweep for a `width x height` mesh's coordinate encoding
/// (narrower NoCs lose destination capacity on wide meshes, and each
/// destination costs proportionally more routing logic).
pub fn fig4_sweep_for_mesh(width: u8, height: u8) -> Vec<AreaPoint> {
    let model = RouterAreaModel::calibrated();
    let mut points = Vec::new();
    for bitwidth in [64u32, 128, 256] {
        for max_dests in 0..=16usize {
            if let Some(area_um2) = model.area_for_mesh(bitwidth, max_dests, width, height) {
                points.push(AreaPoint {
                    bitwidth,
                    max_dests,
                    area_um2,
                    overhead: model
                        .overhead_for_mesh(bitwidth, max_dests, width, height)
                        .unwrap(),
                });
            }
        }
    }
    points
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_paper_baselines() {
        let m = RouterAreaModel::calibrated();
        let a64 = m.area(64, 0).unwrap();
        let a128 = m.area(128, 0).unwrap();
        let a256 = m.area(256, 0).unwrap();
        assert!((a64 - 3620.0).abs() < 1.0, "{a64}");
        assert!((a128 - 6230.0).abs() < 60.0, "{a128} within 1% of 6230");
        assert!((a256 - 11520.0).abs() < 1.0, "{a256}");
    }

    #[test]
    fn per_dest_cost_is_200() {
        let m = RouterAreaModel::calibrated();
        let d = m.area(128, 10).unwrap() - m.area(128, 9).unwrap();
        assert!((d - 200.0).abs() < 1e-9);
    }

    #[test]
    fn overhead_percentages_match_paper() {
        // "5.5%, 3.2%, and 1.7% of the 64/128/256-bit baseline routers"
        // is the overhead of ONE destination's 200 um^2.
        let m = RouterAreaModel::calibrated();
        assert!((200.0 / m.area(64, 0).unwrap() - 0.055).abs() < 0.001);
        assert!((200.0 / m.area(128, 0).unwrap() - 0.032).abs() < 0.001);
        assert!((200.0 / m.area(256, 0).unwrap() - 0.017).abs() < 0.001);
    }

    #[test]
    fn thirty_percent_claim() {
        // "The 64-, 128-, 256-bit routers can support 4, 8, 16 dests with
        // less than a 30% increase of area."
        let m = RouterAreaModel::calibrated();
        assert!(m.overhead(64, 4).unwrap() < 0.30);
        assert!(m.overhead(128, 8).unwrap() < 0.30);
        assert!(m.overhead(256, 16).unwrap() < 0.30);
    }

    #[test]
    fn header_capacity_enforced() {
        let m = RouterAreaModel::calibrated();
        assert!(m.area(64, 5).is_some());
        assert!(m.area(64, 6).is_none(), "64-bit headers encode at most 5");
        assert!(m.area(128, 14).is_some());
        assert!(m.area(128, 15).is_none());
        assert!(m.area(256, 16).is_some());
    }

    #[test]
    fn sweep_covers_all_encodable_points() {
        let pts = fig4_sweep();
        // 64-bit: 0..=5 (6), 128-bit: 0..=14 (15), 256-bit: 0..=16 (17).
        assert_eq!(pts.len(), 6 + 15 + 17);
        assert!(pts.iter().all(|p| p.area_um2 > 0.0));
    }

    #[test]
    fn wide_mesh_sweep_uses_the_9bit_encoding() {
        let pts = fig4_sweep_for_mesh(16, 16);
        // 64-bit: 0..=3 (4), 128-bit: 0..=10 (11), 256-bit: 0..=16 (17).
        assert_eq!(pts.len(), 4 + 11 + 17);
        let m = RouterAreaModel::calibrated();
        // A destination costs 9/7 of the 8x8 cost on a 16x16 mesh.
        let d = m.area_for_mesh(256, 1, 16, 16).unwrap()
            - m.area_for_mesh(256, 0, 16, 16).unwrap();
        assert!((d - 200.0 * 9.0 / 7.0).abs() < 1e-9, "{d}");
        // The no-multicast baselines are mesh-independent.
        assert_eq!(m.area_for_mesh(128, 0, 16, 16), m.area(128, 0));
        // Capacity gating follows the wide encoding.
        assert!(m.area_for_mesh(64, 4, 16, 16).is_none(), "64-bit encodes 3 on 16x16");
        assert!(m.area_for_mesh(64, 3, 16, 16).is_some());
    }
}
