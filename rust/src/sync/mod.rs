//! Coherence-based accelerator synchronization (paper §3, *Accelerator
//! Synchronization*).
//!
//! Rather than a bespoke mechanism, a **portion of the accelerator's
//! dataset is reserved for synchronization words** accessed through the
//! coherent path (the socket's optional L2 participating in MESI), while
//! bulk transfers keep using the DMA engine.  A producer *sets* a flag
//! with a coherent store; a consumer *spins* on a coherent load — after
//! the first read the flag lives in the consumer's cache in Shared state,
//! so spinning is free until the producer's store invalidates it, at which
//! point exactly one re-fetch observes the new value.  This is both lower
//! latency than an IRQ round-trip through the host and fully decentralized.
//!
//! [`FlagRegion`] carves flag words out of a dataset; [`FlagOps`] adapts a
//! [`CacheCtl`] for flag polling/setting.

use crate::coherence::CacheCtl;

/// Layout helper: the reserved synchronization region of a dataset.
#[derive(Debug, Clone, Copy)]
pub struct FlagRegion {
    /// Physical base of the reserved region.
    pub base: u64,
    /// Flags are one cache line apart to avoid false sharing.
    pub stride: u32,
    /// Number of flag slots.
    pub slots: u32,
}

impl FlagRegion {
    /// Reserve `slots` flags at `base`, one per `line_bytes`.
    pub fn new(base: u64, slots: u32, line_bytes: u32) -> Self {
        Self { base, stride: line_bytes, slots }
    }

    /// Physical address of flag `i`.
    pub fn addr(&self, i: u32) -> u64 {
        assert!(i < self.slots, "flag index {i} out of range {}", self.slots);
        self.base + (i as u64) * self.stride as u64
    }

    /// Total bytes reserved.
    pub fn bytes(&self) -> u64 {
        self.slots as u64 * self.stride as u64
    }
}

/// Flag operations over a cache controller.  All operations are
/// *non-blocking*: they return `None`/`false` while the coherence
/// transaction is in flight and the caller retries next cycle (exactly
/// what a spinning accelerator or host does).
pub struct FlagOps;

impl FlagOps {
    /// Try to read flag at `addr`; `None` while the line is being fetched.
    ///
    /// Polls go through [`CacheCtl::peek_load`]: re-reading an unchanged
    /// resident flag must not touch LRU order or hit counters, so a
    /// spinning poll is architecturally a no-op — which is what lets the
    /// SoC scheduler *park* a spinner and stay cycle-identical to the
    /// poll-every-cycle reference model (DESIGN.md §SoC scheduler).
    pub fn poll(cache: &mut CacheCtl, addr: u64) -> Option<u64> {
        cache.peek_load(addr)
    }

    /// Try to set flag at `addr`; `false` while ownership is acquired.
    pub fn set(cache: &mut CacheCtl, addr: u64, val: u64) -> bool {
        cache.store(addr, val)
    }

    /// Convenience: has the flag reached `expect`?  (One poll step.)
    pub fn test(cache: &mut CacheCtl, addr: u64, expect: u64) -> bool {
        matches!(cache.peek_load(addr), Some(v) if v == expect)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn region_layout_avoids_false_sharing() {
        let r = FlagRegion::new(0x1000, 4, 64);
        assert_eq!(r.addr(0), 0x1000);
        assert_eq!(r.addr(3), 0x10C0);
        assert_eq!(r.bytes(), 256);
        // Distinct flags never share a line.
        for i in 0..3 {
            assert_ne!(r.addr(i) / 64, r.addr(i + 1) / 64);
        }
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn out_of_range_flag_panics() {
        FlagRegion::new(0, 2, 64).addr(2);
    }
}
