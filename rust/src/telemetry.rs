//! Gated telemetry: per-router congestion counters and per-tile cycle
//! breakdowns (DESIGN.md §telemetry).
//!
//! Aggregate per-plane flit counts say a scenario got *slower*; telemetry
//! says *where* — which router, on which plane, stalled for how many
//! cycles, dominated by which port.  The subsystem is strictly opt-in
//! (`SocConfig::telemetry` / `espsim … --telemetry OUT.json`): with the
//! flag off no counter memory is allocated and simulation results are
//! byte-identical to a build that never heard of telemetry
//! (`tests/prop_telemetry.rs` pins this, the same zero-cost contract
//! `prop_fault.rs` pins for the fault layer).  Counters are *observers*
//! only — they never feed back into arbitration, so telemetry-on runs
//! produce the same cycles/flit statistics as telemetry-off runs.
//!
//! Three layers:
//!
//! - [`MeshTelemetry`] — the live per-plane sink owned by each
//!   `noc::Mesh` (stall cycles + per-port stall detail, multicast fork
//!   events, occupancy integral).
//! - [`TileTelemetry`] — the live per-tile busy/sleeping/parked tracker
//!   owned by `Soc`, fed by the [`crate::sched::Wake`] state each tile
//!   reports from its tick.  It records only *transitions* (O(changes),
//!   not O(cycles)), so the worklist scheduler's idle-cycle fast-forward
//!   needs no special casing: a gap spent `Parked` is one interval.
//! - [`TelemetryReport`] — the immutable snapshot threaded through
//!   `coordinator::scenario::Outcome` into the CLI heatmap dump.

use std::collections::BTreeMap;

use anyhow::{ensure, Result};

use crate::sched::Wake;
use crate::util::Json;

/// JSON plane keys, indexed by `noc::Plane::idx()`.
pub const PLANE_NAMES: [&str; 6] = ["coh_req", "coh_fwd", "coh_rsp", "dma_req", "dma_rsp", "misc"];

/// JSON port keys, indexed by `noc::Dir::idx()`.
pub const PORT_NAMES: [&str; 5] = ["north", "south", "east", "west", "local"];

/// Schema tag stamped on every telemetry dump document.
pub const SCHEMA: &str = "espsim-telemetry-v1";

/// Hotspots listed per scenario in the JSON dump.
pub const TOP_HOTSPOTS: usize = 8;

/// Live congestion counters for one plane's mesh, parallel to the router
/// array.  A router is *stalled* on a cycle when at least one of its
/// ports held an eligible flit (arrived, in front of its queue) that the
/// plan pass could not advance — so `stall[r] <= elapsed cycles` by
/// construction, while `stall_dir[r]` attributes the same cycles per
/// port and may sum higher (several ports can block at once).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MeshTelemetry {
    /// Cycles with >=1 stalled port, per router.
    pub stall: Vec<u64>,
    /// Stalled cycles per port (Dir::idx() order), per router.
    pub stall_dir: Vec<[u64; 5]>,
    /// Multicast fork events (head flit replicated to >1 output), per router.
    pub forks: Vec<u64>,
    /// Sum over sampled ticks of the router's total queue occupancy.
    pub occ_sum: Vec<u64>,
    /// Ticks the plane did real work (the occupancy sample count).
    pub active_ticks: u64,
}

impl MeshTelemetry {
    /// Zeroed counters for an `n`-router mesh.
    pub fn new(n: usize) -> Self {
        Self {
            stall: vec![0; n],
            stall_dir: vec![[0; 5]; n],
            forks: vec![0; n],
            occ_sum: vec![0; n],
            active_ticks: 0,
        }
    }

    /// Record one stalled tick for router `r`; `mask` has bit `p` set for
    /// each stalled port (Dir::idx() order).  Called at most once per
    /// router per tick, which is what keeps `stall[r]` <= elapsed cycles.
    #[inline]
    pub fn note_stalls(&mut self, r: usize, mask: u8) {
        self.stall[r] += 1;
        let dirs = &mut self.stall_dir[r];
        for (p, d) in dirs.iter_mut().enumerate() {
            *d += ((mask >> p) & 1) as u64;
        }
    }
}

/// Snapshot of one plane's counters, plus the ungated per-router forward
/// count (`Router::flits_forwarded`) the grids reconcile against.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PlaneTelemetry {
    /// Cycles with >=1 stalled port, per router.
    pub stall: Vec<u64>,
    /// Stalled cycles per port (Dir::idx() order), per router.
    pub stall_dir: Vec<[u64; 5]>,
    /// Flits forwarded per router; grid total equals the plane's
    /// `flit_hops` (pinned by `tests/prop_telemetry.rs`).
    pub forwarded: Vec<u64>,
    /// Multicast fork events per router.
    pub forks: Vec<u64>,
    /// Occupancy integral per router over the plane's active ticks.
    pub occ_sum: Vec<u64>,
    /// Ticks the plane did real work.
    pub active_ticks: u64,
}

/// Per-tile cycle breakdown: how the run's cycles split across the
/// PR-4 wake states.  Invariant: `busy + sleeping + parked` equals the
/// elapsed cycles of the snapshot.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct TileCycles {
    /// Cycles the tile demanded a tick next cycle ([`Wake::Busy`]).
    pub busy: u64,
    /// Cycles spent waiting on a timed event ([`Wake::Sleeping`]).
    pub sleeping: u64,
    /// Cycles spent waiting on a delivery ([`Wake::Parked`]).
    pub parked: u64,
}

/// Live per-tile wake-state tracker.  `note` is called with the `Wake` a
/// tile reported from its tick and charges the interval since the last
/// *transition* to the previous state, so cost is proportional to state
/// changes.  Tiles start `Busy` at cycle 0 (matching the scheduler's
/// all-busy reset).
#[derive(Debug, Clone)]
pub struct TileTelemetry {
    cycles: Vec<TileCycles>,
    state: Vec<u8>, // 0 = busy, 1 = sleeping, 2 = parked
    since: Vec<u64>,
}

impl TileTelemetry {
    /// Tracker for `n` tiles, all considered busy from cycle 0.
    pub fn new(n: usize) -> Self {
        Self { cycles: vec![TileCycles::default(); n], state: vec![0; n], since: vec![0; n] }
    }

    /// Note tile `i`'s wake state after its tick at cycle `now`.
    #[inline]
    pub fn note(&mut self, i: usize, now: u64, wake: Wake) {
        let code = match wake {
            Wake::Busy => 0,
            Wake::Sleeping { .. } => 1,
            Wake::Parked => 2,
        };
        if code != self.state[i] {
            self.charge(i, now);
            self.state[i] = code;
        }
    }

    fn charge(&mut self, i: usize, now: u64) {
        let dt = now - self.since[i];
        let c = &mut self.cycles[i];
        match self.state[i] {
            0 => c.busy += dt,
            1 => c.sleeping += dt,
            _ => c.parked += dt,
        }
        self.since[i] = now;
    }

    /// Closed breakdown at cycle `end`: every still-open interval is
    /// charged to its current state, so each tile's fields sum to `end`.
    pub fn snapshot(&self, end: u64) -> Vec<TileCycles> {
        (0..self.cycles.len())
            .map(|i| {
                let mut c = self.cycles[i];
                let dt = end.saturating_sub(self.since[i]);
                match self.state[i] {
                    0 => c.busy += dt,
                    1 => c.sleeping += dt,
                    _ => c.parked += dt,
                }
                c
            })
            .collect()
    }
}

/// One hotspot row: a (plane, router) pair ranked by stalled cycles.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Hotspot {
    /// Plane index (`PLANE_NAMES` order).
    pub plane: usize,
    /// Router mesh coordinate.
    pub x: u8,
    /// Router mesh coordinate.
    pub y: u8,
    /// Cycles the router had >=1 stalled port.
    pub stall: u64,
    /// Port contributing the most stalled cycles (`PORT_NAMES` index).
    pub dominant_dir: usize,
}

/// Immutable telemetry snapshot for one finished run: per-plane counter
/// grids plus the per-tile cycle breakdown, all row-major over a
/// `width x height` mesh.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TelemetryReport {
    /// Mesh width (routers per row).
    pub width: u8,
    /// Mesh height.
    pub height: u8,
    /// Elapsed cycles at snapshot time.
    pub cycles: u64,
    /// One entry per plane, `PLANE_NAMES` order.
    pub planes: Vec<PlaneTelemetry>,
    /// One entry per router position, row-major.
    pub tiles: Vec<TileCycles>,
}

impl TelemetryReport {
    /// Total stalled router-cycles across all planes.
    pub fn total_stall(&self) -> u64 {
        self.planes.iter().map(|p| p.stall.iter().sum::<u64>()).sum()
    }

    /// The single worst router's stalled cycles (any plane).
    pub fn max_router_stall(&self) -> u64 {
        self.planes.iter().flat_map(|p| p.stall.iter().copied()).max().unwrap_or(0)
    }

    /// Total multicast fork events across all planes.
    pub fn total_forks(&self) -> u64 {
        self.planes.iter().map(|p| p.forks.iter().sum::<u64>()).sum()
    }

    /// The top `n` stalled (plane, router) pairs, most-stalled first;
    /// ties break toward the lower plane then router index so the order
    /// is deterministic.  Routers with zero stall never appear.
    pub fn hotspots(&self, n: usize) -> Vec<Hotspot> {
        let w = self.width as usize;
        let mut all: Vec<Hotspot> = Vec::new();
        for (pi, p) in self.planes.iter().enumerate() {
            for (r, &stall) in p.stall.iter().enumerate() {
                if stall == 0 {
                    continue;
                }
                let dirs = &p.stall_dir[r];
                let dominant_dir =
                    (0..5).max_by_key(|&d| (dirs[d], std::cmp::Reverse(d))).unwrap_or(0);
                all.push(Hotspot {
                    plane: pi,
                    x: (r % w) as u8,
                    y: (r / w) as u8,
                    stall,
                    dominant_dir,
                });
            }
        }
        all.sort_by_key(|h| (std::cmp::Reverse(h.stall), h.plane, h.y, h.x));
        all.truncate(n);
        all
    }

    /// The dump-file JSON for one scenario: mesh-shaped grids per plane,
    /// the tile breakdown grids, and the top-N hotspot table.  All keys
    /// live in `BTreeMap`s, so the byte serialization is deterministic —
    /// the CI gate `cmp`s two independent runs.
    pub fn to_json(&self) -> Json {
        let (w, h) = (self.width as usize, self.height as usize);
        let grid = |vals: &[u64]| -> Json {
            let row = |y: usize| {
                Json::Arr(vals[y * w..(y + 1) * w].iter().map(|&v| Json::from(v)).collect())
            };
            Json::Arr((0..h).map(row).collect())
        };
        let mut planes = BTreeMap::new();
        for (pi, p) in self.planes.iter().enumerate() {
            let mut m = BTreeMap::new();
            m.insert("stall".to_string(), grid(&p.stall));
            m.insert("forwarded".to_string(), grid(&p.forwarded));
            m.insert("forks".to_string(), grid(&p.forks));
            m.insert("occupancy_sum".to_string(), grid(&p.occ_sum));
            m.insert("active_ticks".to_string(), Json::from(p.active_ticks));
            planes.insert(PLANE_NAMES[pi].to_string(), Json::Obj(m));
        }
        let pick = |f: fn(&TileCycles) -> u64| -> Vec<u64> { self.tiles.iter().map(f).collect() };
        let mut tiles = BTreeMap::new();
        tiles.insert("busy".to_string(), grid(&pick(|c| c.busy)));
        tiles.insert("sleeping".to_string(), grid(&pick(|c| c.sleeping)));
        tiles.insert("parked".to_string(), grid(&pick(|c| c.parked)));
        let hotspots = Json::Arr(
            self.hotspots(TOP_HOTSPOTS)
                .into_iter()
                .map(|hs| {
                    let mut m = BTreeMap::new();
                    m.insert("plane".to_string(), Json::from(PLANE_NAMES[hs.plane]));
                    m.insert("x".to_string(), Json::from(hs.x as u64));
                    m.insert("y".to_string(), Json::from(hs.y as u64));
                    m.insert("stall".to_string(), Json::from(hs.stall));
                    m.insert("dir".to_string(), Json::from(PORT_NAMES[hs.dominant_dir]));
                    Json::Obj(m)
                })
                .collect(),
        );
        let mut doc = BTreeMap::new();
        doc.insert("width".to_string(), Json::from(self.width as u64));
        doc.insert("height".to_string(), Json::from(self.height as u64));
        doc.insert("cycles".to_string(), Json::from(self.cycles));
        doc.insert("planes".to_string(), Json::Obj(planes));
        doc.insert("tiles".to_string(), Json::Obj(tiles));
        doc.insert("hotspots".to_string(), hotspots);
        Json::Obj(doc)
    }
}

/// Assemble the top-level dump document from per-scenario reports
/// (`point` name -> [`TelemetryReport::to_json`]).
pub fn dump_document(entries: impl IntoIterator<Item = (String, Json)>) -> Json {
    let mut doc = BTreeMap::new();
    doc.insert("schema".to_string(), Json::from(SCHEMA));
    doc.insert("scenarios".to_string(), Json::Obj(entries.into_iter().collect()));
    Json::Obj(doc)
}

/// Validate a telemetry dump document against the v1 schema: every grid
/// is mesh-shaped, every counter a non-negative integer, per-router
/// stall bounded by elapsed cycles, each tile's breakdown sums to the
/// elapsed cycles, and the hotspot table sorted non-increasing with
/// in-range coordinates.  `espsim telemetry-check` (and the CI gate
/// behind it) is a thin wrapper over this.
pub fn validate_document(doc: &Json) -> Result<()> {
    ensure!(doc.req("schema")?.as_str()? == SCHEMA, "unknown telemetry schema");
    let scenarios = doc.req("scenarios")?.as_obj()?;
    for (name, s) in scenarios {
        validate_scenario(s).map_err(|e| e.context(format!("scenario {name:?}")))?;
    }
    Ok(())
}

fn validate_scenario(s: &Json) -> Result<()> {
    let w = s.req("width")?.as_u64()? as usize;
    let h = s.req("height")?.as_u64()? as usize;
    let cycles = s.req("cycles")?.as_u64()?;
    ensure!(w >= 1 && h >= 1, "degenerate mesh {w}x{h}");
    let grid = |g: &Json, what: &str, max: Option<u64>| -> Result<Vec<u64>> {
        let rows = g.as_arr()?;
        ensure!(rows.len() == h, "{what}: {} rows, mesh height {h}", rows.len());
        let mut flat = Vec::with_capacity(w * h);
        for row in rows {
            let row = row.as_arr()?;
            ensure!(row.len() == w, "{what}: {} cols, mesh width {w}", row.len());
            for v in row {
                let v = v.as_u64().map_err(|e| e.context(format!("{what} entry")))?;
                if let Some(max) = max {
                    ensure!(v <= max, "{what} entry {v} exceeds bound {max}");
                }
                flat.push(v);
            }
        }
        Ok(flat)
    };
    let planes = s.req("planes")?;
    for pname in PLANE_NAMES {
        let p = planes.req(pname)?;
        grid(p.req("stall")?, "stall", Some(cycles))
            .map_err(|e| e.context(format!("plane {pname}")))?;
        for key in ["forwarded", "forks", "occupancy_sum"] {
            grid(p.req(key)?, key, None).map_err(|e| e.context(format!("plane {pname}")))?;
        }
        let active = p.req("active_ticks")?.as_u64()?;
        ensure!(active <= cycles, "plane {pname}: active_ticks {active} > cycles {cycles}");
    }
    let tiles = s.req("tiles")?;
    let busy = grid(tiles.req("busy")?, "tiles.busy", Some(cycles))?;
    let sleeping = grid(tiles.req("sleeping")?, "tiles.sleeping", Some(cycles))?;
    let parked = grid(tiles.req("parked")?, "tiles.parked", Some(cycles))?;
    for i in 0..busy.len() {
        let sum = busy[i] + sleeping[i] + parked[i];
        ensure!(
            sum == cycles,
            "tile {i}: busy+sleeping+parked = {sum}, expected elapsed cycles {cycles}"
        );
    }
    let hotspots = s.req("hotspots")?.as_arr()?;
    let mut prev = u64::MAX;
    for hs in hotspots {
        let stall = hs.req("stall")?.as_u64()?;
        ensure!(stall <= prev, "hotspots not sorted by stall (… {prev}, {stall} …)");
        ensure!(stall <= cycles, "hotspot stall {stall} > cycles {cycles}");
        prev = stall;
        let plane = hs.req("plane")?.as_str()?;
        ensure!(PLANE_NAMES.contains(&plane), "unknown hotspot plane {plane:?}");
        let dir = hs.req("dir")?.as_str()?;
        ensure!(PORT_NAMES.contains(&dir), "unknown hotspot dir {dir:?}");
        let x = hs.req("x")?.as_u64()? as usize;
        let y = hs.req("y")?.as_u64()? as usize;
        ensure!(x < w && y < h, "hotspot ({x},{y}) outside {w}x{h} mesh");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tile_tracker_charges_transitions_and_closes_open_intervals() {
        let mut t = TileTelemetry::new(2);
        // Tile 0: busy [0,10), sleeping [10,25), busy [25,..).
        t.note(0, 4, Wake::Busy); // no transition, no charge
        t.note(0, 10, Wake::Sleeping { until: 25 });
        t.note(0, 25, Wake::Busy);
        // Tile 1: parked from cycle 3 onward.
        t.note(1, 3, Wake::Parked);
        let snap = t.snapshot(40);
        assert_eq!(snap[0], TileCycles { busy: 25, sleeping: 15, parked: 0 });
        assert_eq!(snap[1], TileCycles { busy: 3, sleeping: 0, parked: 37 });
        // The snapshot is virtual: the tracker can keep going and
        // snapshot again later.
        let later = t.snapshot(50);
        assert_eq!(later[0].busy, 35);
    }

    #[test]
    fn stall_mask_counts_router_once_and_ports_individually() {
        let mut m = MeshTelemetry::new(4);
        m.note_stalls(2, 0b00101); // north + east
        m.note_stalls(2, 0b00100); // east again
        assert_eq!(m.stall[2], 2);
        assert_eq!(m.stall_dir[2], [1, 0, 2, 0, 0]);
        assert_eq!(m.stall[0], 0);
    }

    fn report_2x2() -> TelemetryReport {
        let n = 4;
        let mut planes = Vec::new();
        for pi in 0..PLANE_NAMES.len() {
            let mut p = PlaneTelemetry {
                stall: vec![0; n],
                stall_dir: vec![[0; 5]; n],
                forwarded: vec![1; n],
                forks: vec![0; n],
                occ_sum: vec![0; n],
                active_ticks: 5,
            };
            if pi == 3 {
                // dma_req: router 1 heavily stalled toward west.
                p.stall[1] = 9;
                p.stall_dir[1] = [0, 0, 2, 7, 0];
                p.stall[2] = 3;
                p.stall_dir[2] = [3, 0, 0, 0, 0];
            }
            planes.push(p);
        }
        TelemetryReport {
            width: 2,
            height: 2,
            cycles: 10,
            planes,
            tiles: vec![TileCycles { busy: 4, sleeping: 5, parked: 1 }; n],
        }
    }

    #[test]
    fn hotspots_rank_by_stall_with_dominant_port() {
        let r = report_2x2();
        let hs = r.hotspots(10);
        assert_eq!(hs.len(), 2);
        assert_eq!((hs[0].plane, hs[0].x, hs[0].y, hs[0].stall), (3, 1, 0, 9));
        assert_eq!(PORT_NAMES[hs[0].dominant_dir], "west");
        assert_eq!((hs[1].x, hs[1].y, hs[1].stall), (0, 1, 3));
        assert_eq!(PORT_NAMES[hs[1].dominant_dir], "north");
        assert_eq!(r.total_stall(), 12);
        assert_eq!(r.max_router_stall(), 9);
    }

    #[test]
    fn dump_document_roundtrips_and_validates() {
        let doc = dump_document(vec![("shuffle_2x2".to_string(), report_2x2().to_json())]);
        validate_document(&doc).unwrap();
        let reparsed = Json::parse(&doc.to_string()).unwrap();
        validate_document(&reparsed).unwrap();
        assert_eq!(reparsed.to_string(), doc.to_string());
    }

    #[test]
    fn validator_rejects_malformed_dumps() {
        let good = dump_document(vec![("s".to_string(), report_2x2().to_json())]);
        // Wrong grid shape: claim a 3-wide mesh.
        let mut bad = good.clone();
        if let Json::Obj(doc) = &mut bad {
            let s = doc.get_mut("scenarios").unwrap();
            if let Json::Obj(m) = s {
                if let Json::Obj(sc) = m.get_mut("s").unwrap() {
                    sc.insert("width".to_string(), Json::from(3u64));
                }
            }
        }
        assert!(validate_document(&bad).is_err());
        // Stall above elapsed cycles.
        let mut r = report_2x2();
        r.planes[3].stall[1] = r.cycles + 1;
        let bad = dump_document(vec![("s".to_string(), r.to_json())]);
        assert!(validate_document(&bad).is_err());
        // Tile breakdown that does not sum to the elapsed cycles.
        let mut r = report_2x2();
        r.tiles[0].busy += 1;
        let bad = dump_document(vec![("s".to_string(), r.to_json())]);
        assert!(validate_document(&bad).is_err());
        // Unknown schema tag.
        let mut bad = good.clone();
        if let Json::Obj(doc) = &mut bad {
            doc.insert("schema".to_string(), Json::from("espsim-telemetry-v0"));
        }
        assert!(validate_document(&bad).is_err());
    }
}
