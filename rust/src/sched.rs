//! Wake states for the activity-driven SoC scheduler.
//!
//! Every schedulable component ([`crate::tile::Tile`] and, below it,
//! [`crate::socket::Socket`] and [`crate::accel::AccCore`]) reports a
//! [`Wake`] from its tick: what the scheduler must do for the component's
//! *next* tick to be indistinguishable from ticking it every cycle.  The
//! three states form a lattice ordered by urgency
//! (`Busy` ≺ `Sleeping { until }` ≺ `Parked`, earlier-demand wins), and
//! [`Wake::earliest`] is the meet — an aggregate (a tile with two sockets,
//! a socket plus its core) is as urgent as its most urgent part.
//!
//! The contract a `Wake` value asserts:
//!
//! - [`Wake::Busy`]: the next cycle's tick may make progress on its own —
//!   tick me again next cycle.
//! - [`Wake::Sleeping`]: every tick before `until` is a provable no-op
//!   *unless a message is delivered to me first*; tick me at `until` (or
//!   at delivery, whichever comes first).
//! - [`Wake::Parked`]: every future tick is a provable no-op until a
//!   message is delivered to me; don't tick me at all.
//!
//! "Provable no-op" means: no NoC traffic, no architectural state change,
//! and no statistics change *observable through
//! [`crate::coordinator::Report`]*.  One exemption: spin-retry counters
//! (`CoreStats::dma_stall_cycles`) count *executed* retries, which is
//! scheduler-dependent by design (see DESIGN.md §SoC scheduler).  Flag
//! polls need no exemption — they go through `CacheCtl::peek_load`, which
//! leaves LRU order and hit counters untouched, so a skipped re-poll is
//! architecturally invisible even under cache eviction pressure.
//!
//! Deliveries always win: the [`crate::coordinator::Soc`] loop unparks a
//! tile the cycle after any message ejects at it, so a `Sleeping`/`Parked`
//! component never needs to predict message arrival — only its own timed
//! events (DMA/DRAM latency, datapath busy windows, host delays).

/// What a component needs from the scheduler after a tick.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Wake {
    /// Tick me next cycle.
    Busy,
    /// Timed event pending: tick me at `until` (a delivery may wake me
    /// earlier).  Invariant: `until` is strictly in the future.
    Sleeping {
        /// Absolute cycle of the component's next self-driven event.
        until: u64,
    },
    /// Waiting on an external stimulus: tick me only after a delivery.
    Parked,
}

impl Wake {
    /// Wake at absolute cycle `at`: [`Wake::Busy`] when `at` is this or
    /// next cycle (the scheduler ticks at `now + 1` anyway), otherwise
    /// [`Wake::Sleeping`].
    #[inline]
    pub fn at(now: u64, at: u64) -> Wake {
        if at <= now + 1 {
            Wake::Busy
        } else {
            Wake::Sleeping { until: at }
        }
    }

    /// The meet of two wake states: the earlier demand wins.
    #[inline]
    pub fn earliest(self, other: Wake) -> Wake {
        match (self, other) {
            (Wake::Busy, _) | (_, Wake::Busy) => Wake::Busy,
            (Wake::Sleeping { until: a }, Wake::Sleeping { until: b }) => {
                Wake::Sleeping { until: a.min(b) }
            }
            (s @ Wake::Sleeping { .. }, Wake::Parked) => s,
            (Wake::Parked, s @ Wake::Sleeping { .. }) => s,
            (Wake::Parked, Wake::Parked) => Wake::Parked,
        }
    }
}

/// How [`crate::coordinator::Soc::run`] schedules tile ticks.  Both modes
/// are cycle-for-cycle identical (`tests/prop_soc_sched.rs` pins this);
/// `FullScan` is retained as the executable reference model and the
/// ablation baseline.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SchedMode {
    /// Tick every tile every cycle (the seed model).
    FullScan,
    /// Tile worklists + wake-queue + idle-cycle fast-forward.
    #[default]
    Worklist,
}

impl SchedMode {
    /// Config-file code ("full_scan", "worklist").
    pub fn code(self) -> &'static str {
        match self {
            SchedMode::FullScan => "full_scan",
            SchedMode::Worklist => "worklist",
        }
    }

    /// Parse a config-file code.
    pub fn from_code(s: &str) -> Option<Self> {
        Some(match s {
            "full_scan" => SchedMode::FullScan,
            "worklist" => SchedMode::Worklist,
            _ => return None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn at_collapses_imminent_wakes_to_busy() {
        assert_eq!(Wake::at(10, 10), Wake::Busy);
        assert_eq!(Wake::at(10, 11), Wake::Busy);
        assert_eq!(Wake::at(10, 12), Wake::Sleeping { until: 12 });
    }

    #[test]
    fn earliest_is_the_lattice_meet() {
        let s5 = Wake::Sleeping { until: 5 };
        let s9 = Wake::Sleeping { until: 9 };
        assert_eq!(Wake::Busy.earliest(Wake::Parked), Wake::Busy);
        assert_eq!(s9.earliest(Wake::Busy), Wake::Busy);
        assert_eq!(s5.earliest(s9), s5);
        assert_eq!(s9.earliest(s5), s5);
        assert_eq!(Wake::Parked.earliest(s9), s9);
        assert_eq!(Wake::Parked.earliest(Wake::Parked), Wake::Parked);
    }

    #[test]
    fn sched_mode_codes_roundtrip() {
        for m in [SchedMode::FullScan, SchedMode::Worklist] {
            assert_eq!(SchedMode::from_code(m.code()), Some(m));
        }
        assert_eq!(SchedMode::from_code("bogus"), None);
        assert_eq!(SchedMode::default(), SchedMode::Worklist);
    }
}
