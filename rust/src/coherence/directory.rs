//! Full-map blocking directory, embedded in the LLC of the memory tile.
//!
//! Per line the directory is Invalid (memory owns), Shared (a sharer
//! list), or Owned (one cache holds E/M).  A line with an outstanding
//! owner-downgrade (GetS hitting Owned) is *busy*: further requests queue
//! until the copyback arrives, which serializes the racy cases.  Forward
//! and invalidate messages carry the **requester** as their source
//! coordinate so the responding cache can target acknowledgements
//! directly, as in ESP's directory protocol.

use std::collections::{HashMap, VecDeque};
use std::sync::Arc;

use crate::noc::{CohOp, Coord, Message, MsgKind, Plane};

/// Directory state for one line (absent from the map = Invalid).
#[derive(Debug, Clone, PartialEq, Eq)]
enum DirLine {
    /// Clean copies at these caches; memory is current.
    Shared(Vec<Coord>),
    /// One cache holds the line Exclusive/Modified.
    Owned(Coord),
}

/// An in-flight owner downgrade.
#[derive(Debug)]
struct BusyToken {
    old_owner: Coord,
    requester: Coord,
}

/// The directory controller.
pub struct Directory {
    /// Memory-tile coordinate (this controller's home).
    pub coord: Coord,
    line_bytes: usize,
    states: HashMap<u64, DirLine>,
    busy: HashMap<u64, BusyToken>,
    queued: HashMap<u64, VecDeque<Message>>,
    out: Vec<(Plane, Message)>,
    /// Stats: requests served / forwards issued / invalidations issued.
    pub requests: u64,
    /// Stats.
    pub forwards: u64,
    /// Stats.
    pub invalidations: u64,
}

impl Directory {
    /// Empty directory.
    pub fn new(coord: Coord, line_bytes: u32) -> Self {
        Self {
            coord,
            line_bytes: line_bytes as usize,
            states: HashMap::new(),
            busy: HashMap::new(),
            queued: HashMap::new(),
            out: Vec::new(),
            requests: 0,
            forwards: 0,
            invalidations: 0,
        }
    }

    fn read_line(&self, dram: &[u8], laddr: u64) -> Vec<u8> {
        let a = laddr as usize;
        dram[a..a + self.line_bytes].to_vec()
    }

    fn write_line(&self, dram: &mut [u8], laddr: u64, data: &[u8]) {
        let a = laddr as usize;
        dram[a..a + self.line_bytes].copy_from_slice(data);
    }

    fn send_data(&mut self, to: Coord, laddr: u64, op: CohOp, acks: u16, data: Vec<u8>) {
        let kind = MsgKind::Coh { op, line: laddr, ack_count: acks };
        self.out.push((Plane::CohRsp, Message::data(self.coord, to, kind, Arc::new(data))));
    }

    /// Handle one coherence message; `dram` is the backing store.
    pub fn handle_msg(&mut self, msg: &Message, dram: &mut [u8]) {
        let MsgKind::Coh { op, line: laddr, ack_count } = msg.kind else { return };
        // Copybacks resolve busy lines; everything else queues when busy.
        let is_copyback = op == CohOp::PutM && ack_count == 1;
        if self.busy.contains_key(&laddr) && !is_copyback {
            self.queued.entry(laddr).or_default().push_back(msg.clone());
            return;
        }
        match op {
            CohOp::GetS => {
                self.requests += 1;
                match self.states.get(&laddr).cloned() {
                    None => {
                        // Sole reader: grant Exclusive (the E of MESI).
                        let data = self.read_line(dram, laddr);
                        self.send_data(msg.src, laddr, CohOp::DataM, 0, data);
                        self.states.insert(laddr, DirLine::Owned(msg.src));
                    }
                    Some(DirLine::Shared(mut sharers)) => {
                        let data = self.read_line(dram, laddr);
                        self.send_data(msg.src, laddr, CohOp::Data, 0, data);
                        if !sharers.contains(&msg.src) {
                            sharers.push(msg.src);
                        }
                        self.states.insert(laddr, DirLine::Shared(sharers));
                    }
                    Some(DirLine::Owned(owner)) => {
                        if owner == msg.src {
                            // Owner silently dropped E and re-reads.
                            let data = self.read_line(dram, laddr);
                            self.send_data(msg.src, laddr, CohOp::DataM, 0, data);
                        } else {
                            // Downgrade the owner; block until copyback.
                            self.forwards += 1;
                            let kind =
                                MsgKind::Coh { op: CohOp::FwdGetS, line: laddr, ack_count: 0 };
                            // src = requester so the owner can reply directly.
                            self.out.push((
                                Plane::CohFwd,
                                Message::ctrl(msg.src, owner, kind),
                            ));
                            self.busy.insert(
                                laddr,
                                BusyToken { old_owner: owner, requester: msg.src },
                            );
                        }
                    }
                }
            }
            CohOp::GetM => {
                self.requests += 1;
                match self.states.get(&laddr).cloned() {
                    None => {
                        let data = self.read_line(dram, laddr);
                        self.send_data(msg.src, laddr, CohOp::DataM, 0, data);
                        self.states.insert(laddr, DirLine::Owned(msg.src));
                    }
                    Some(DirLine::Shared(sharers)) => {
                        let others: Vec<Coord> =
                            sharers.iter().copied().filter(|&c| c != msg.src).collect();
                        for &s in &others {
                            self.invalidations += 1;
                            let kind = MsgKind::Coh { op: CohOp::Inv, line: laddr, ack_count: 0 };
                            // src = requester: sharers ack the requester.
                            self.out.push((Plane::CohFwd, Message::ctrl(msg.src, s, kind)));
                        }
                        let data = self.read_line(dram, laddr);
                        self.send_data(msg.src, laddr, CohOp::DataM, others.len() as u16, data);
                        self.states.insert(laddr, DirLine::Owned(msg.src));
                    }
                    Some(DirLine::Owned(owner)) => {
                        if owner == msg.src {
                            // Silent E drop followed by a write miss.
                            let data = self.read_line(dram, laddr);
                            self.send_data(msg.src, laddr, CohOp::DataM, 0, data);
                        } else {
                            self.forwards += 1;
                            let kind =
                                MsgKind::Coh { op: CohOp::FwdGetM, line: laddr, ack_count: 0 };
                            self.out.push((Plane::CohFwd, Message::ctrl(msg.src, owner, kind)));
                            self.states.insert(laddr, DirLine::Owned(msg.src));
                        }
                    }
                }
            }
            CohOp::PutM if is_copyback => {
                // Copyback from a FwdGetS downgrade: memory becomes current,
                // the line is Shared by {old owner, requester}.
                self.write_line(dram, laddr, &msg.payload);
                let token = self.busy.remove(&laddr).expect("copyback without busy token");
                debug_assert_eq!(token.old_owner, msg.src);
                self.states.insert(
                    laddr,
                    DirLine::Shared(vec![token.old_owner, token.requester]),
                );
                // Replay queued requests in order.
                if let Some(mut q) = self.queued.remove(&laddr) {
                    while let Some(m) = q.pop_front() {
                        self.handle_msg(&m, dram);
                        if self.busy.contains_key(&laddr) {
                            // Re-blocked: requeue the rest.
                            if !q.is_empty() {
                                self.queued.entry(laddr).or_default().extend(q.drain(..));
                            }
                            break;
                        }
                    }
                }
            }
            CohOp::PutM => {
                // Eviction writeback.  Only the current owner's data counts;
                // stale Puts (ownership already moved) are acked and dropped.
                if self.states.get(&laddr) == Some(&DirLine::Owned(msg.src)) {
                    self.write_line(dram, laddr, &msg.payload);
                    self.states.remove(&laddr);
                }
                let kind = MsgKind::Coh { op: CohOp::PutAck, line: laddr, ack_count: 0 };
                self.out.push((Plane::CohFwd, Message::ctrl(self.coord, msg.src, kind)));
            }
            _ => panic!("directory received response {op:?}"),
        }
    }

    /// Drain outgoing messages (the memory tile injects them with LLC
    /// latency).
    pub fn drain_out(&mut self) -> Vec<(Plane, Message)> {
        std::mem::take(&mut self.out)
    }

    /// Any busy lines (diagnostics)?
    pub fn quiescent(&self) -> bool {
        self.busy.is_empty() && self.queued.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gets(src: Coord, line: u64) -> Message {
        Message::ctrl(src, (0, 0), MsgKind::Coh { op: CohOp::GetS, line, ack_count: 0 })
    }

    fn getm(src: Coord, line: u64) -> Message {
        Message::ctrl(src, (0, 0), MsgKind::Coh { op: CohOp::GetM, line, ack_count: 0 })
    }

    #[test]
    fn cold_gets_grants_exclusive() {
        let mut d = Directory::new((0, 0), 64);
        let mut dram = vec![0u8; 4096];
        dram[0] = 0x77;
        d.handle_msg(&gets((1, 1), 0), &mut dram);
        let out = d.drain_out();
        assert_eq!(out.len(), 1);
        let MsgKind::Coh { op, ack_count, .. } = out[0].1.kind else { panic!() };
        assert_eq!(op, CohOp::DataM, "sole reader gets E");
        assert_eq!(ack_count, 0);
        assert_eq!(out[0].1.payload[0], 0x77);
    }

    #[test]
    fn second_reader_triggers_downgrade_and_blocks() {
        let mut d = Directory::new((0, 0), 64);
        let mut dram = vec![0u8; 4096];
        d.handle_msg(&gets((1, 1), 0), &mut dram);
        d.drain_out();
        d.handle_msg(&gets((2, 2), 0), &mut dram);
        let out = d.drain_out();
        assert_eq!(out.len(), 1);
        let MsgKind::Coh { op, .. } = out[0].1.kind else { panic!() };
        assert_eq!(op, CohOp::FwdGetS);
        assert_eq!(out[0].1.src, (2, 2), "forward carries the requester");
        assert_eq!(out[0].1.dests.as_slice(), &[(1, 1)]);
        assert!(!d.quiescent());
        // A third request queues while busy.
        d.handle_msg(&gets((0, 1), 0), &mut dram);
        assert!(d.drain_out().is_empty());
        // Copyback resolves and replays the queued request.
        let mut cb = Message::data(
            (1, 1),
            (0, 0),
            MsgKind::Coh { op: CohOp::PutM, line: 0, ack_count: 1 },
            Arc::new(vec![9u8; 64]),
        );
        cb.src = (1, 1);
        d.handle_msg(&cb, &mut dram);
        assert_eq!(dram[0], 9, "copyback updates memory");
        let out = d.drain_out();
        assert_eq!(out.len(), 1, "queued GetS replayed");
        assert!(d.quiescent());
    }

    #[test]
    fn getm_invalidates_sharers() {
        let mut d = Directory::new((0, 0), 64);
        let mut dram = vec![0u8; 4096];
        // Two sharers: first E-grant, downgrade via copyback, second share.
        d.handle_msg(&gets((1, 1), 64), &mut dram);
        d.drain_out();
        d.handle_msg(&gets((2, 2), 64), &mut dram);
        d.drain_out();
        let cb = Message::data(
            (1, 1),
            (0, 0),
            MsgKind::Coh { op: CohOp::PutM, line: 64, ack_count: 1 },
            Arc::new(vec![0u8; 64]),
        );
        d.handle_msg(&cb, &mut dram);
        d.drain_out();
        // Now (3,3) writes.
        d.handle_msg(&getm((3, 3), 64), &mut dram);
        let out = d.drain_out();
        let invs: Vec<_> = out
            .iter()
            .filter(|(_, m)| {
                matches!(m.kind, MsgKind::Coh { op: CohOp::Inv, .. })
            })
            .collect();
        assert_eq!(invs.len(), 2);
        for (_, m) in &invs {
            assert_eq!(m.src, (3, 3), "Inv carries requester for direct acks");
        }
        let datam = out
            .iter()
            .find(|(_, m)| matches!(m.kind, MsgKind::Coh { op: CohOp::DataM, .. }))
            .unwrap();
        let MsgKind::Coh { ack_count, .. } = datam.1.kind else { panic!() };
        assert_eq!(ack_count, 2);
    }

    #[test]
    fn stale_putm_is_acked_but_ignored() {
        let mut d = Directory::new((0, 0), 64);
        let mut dram = vec![0u8; 4096];
        d.handle_msg(&getm((1, 1), 0), &mut dram);
        d.drain_out();
        d.handle_msg(&getm((2, 2), 0), &mut dram); // ownership moves (FwdGetM)
        d.drain_out();
        // Old owner's eviction PutM arrives late.
        let put = Message::data(
            (1, 1),
            (0, 0),
            MsgKind::Coh { op: CohOp::PutM, line: 0, ack_count: 0 },
            Arc::new(vec![5u8; 64]),
        );
        d.handle_msg(&put, &mut dram);
        assert_eq!(dram[0], 0, "stale data not written");
        let out = d.drain_out();
        assert!(matches!(out[0].1.kind, MsgKind::Coh { op: CohOp::PutAck, .. }));
    }
}
