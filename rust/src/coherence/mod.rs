//! MESI coherence over the three coherence NoC planes.
//!
//! ESP optionally instantiates an L2 in the accelerator socket, letting the
//! accelerator participate in the system's MESI protocol.  The paper's
//! synchronization proposal (§3, *Accelerator Synchronization*) reserves a
//! small portion of the dataset for **coherent** flag words while bulk data
//! uses DMA — so this module implements a compact but complete MESI:
//!
//! - [`CacheCtl`]: an L1/L2 cache controller (stable states I/S/E/M, the
//!   transient states needed for loads, stores, upgrades and evictions).
//! - [`Directory`]: a full-map **blocking** directory embedded in the LLC:
//!   a line with an outstanding transaction queues subsequent requests,
//!   which sidesteps most protocol races; the eviction/forward race is
//!   handled with an eviction buffer on the cache side.
//!
//! Message classes ride dedicated physical planes (requests on
//! [`Plane::CohReq`], forwards on [`Plane::CohFwd`], responses on
//! [`Plane::CohRsp`]), which breaks message-dependent deadlock exactly as
//! in ESP.
//!
//! **Scheduler contract** (DESIGN.md §SoC scheduler): both controllers
//! are purely message-driven — no timed state, every transition caused by
//! a `handle_msg` or an explicit load/store/evict call — and all their
//! cross-tile effects ride the three coherence planes.  That is what lets
//! a tile holding one *park* while a transaction is in flight or while a
//! spinner's flag line sits cached: the state it waits on can only change
//! via a delivery (data grant, InvAck, the `Inv` a producer's flag store
//! triggers), and every delivery unparks its destination tile.

pub mod directory;

pub use directory::Directory;

use std::collections::{HashMap, VecDeque};
use std::sync::Arc;

use crate::noc::{CohOp, Coord, Message, MsgKind, Plane};

/// Stable MESI states.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mesi {
    Modified,
    Exclusive,
    Shared,
}

/// A cached line.
#[derive(Debug, Clone)]
struct Line {
    state: Mesi,
    data: Vec<u8>,
}

/// An in-flight transaction at the cache.
#[derive(Debug)]
struct Pending {
    /// Store to apply once writable (word offset in line, value).
    store: Option<(usize, u64)>,
    /// InvAcks still expected (GetM); may go negative transiently when
    /// acks arrive before the directory's count.
    acks_needed: i32,
    /// Directory ack-count received?
    count_known: bool,
    /// Data received?
    data: Option<Vec<u8>>,
    /// Granted state when complete.
    grant: Mesi,
}

/// One cache controller (CPU L1 or accelerator-socket L2).
pub struct CacheCtl {
    /// This cache's tile (coherence endpoint id).
    pub coord: Coord,
    dir_tile: Coord,
    line_bytes: usize,
    capacity_lines: usize,
    lines: HashMap<u64, Line>,
    lru: VecDeque<u64>,
    pending: HashMap<u64, Pending>,
    /// Forwards/invalidations that arrived while their line's transaction
    /// was still in flight; replayed at completion.
    deferred: HashMap<u64, Vec<Message>>,
    /// Lines mid-writeback, kept until PutAck so forwards can be served.
    evicting: HashMap<u64, Vec<u8>>,
    out: Vec<(Plane, Message)>,
    /// Stats: hits / misses / writebacks / forwards served.
    pub hits: u64,
    /// Stats.
    pub misses: u64,
    /// Stats.
    pub writebacks: u64,
    /// Stats.
    pub forwards_served: u64,
}

impl CacheCtl {
    /// Build a cache of `capacity_bytes` with `line_bytes` lines.
    pub fn new(coord: Coord, dir_tile: Coord, capacity_bytes: u32, line_bytes: u32) -> Self {
        Self {
            coord,
            dir_tile,
            line_bytes: line_bytes as usize,
            capacity_lines: (capacity_bytes / line_bytes).max(2) as usize,
            lines: HashMap::new(),
            lru: VecDeque::new(),
            pending: HashMap::new(),
            deferred: HashMap::new(),
            evicting: HashMap::new(),
            out: Vec::new(),
            hits: 0,
            misses: 0,
            writebacks: 0,
            forwards_served: 0,
        }
    }

    fn line_of(&self, addr: u64) -> (u64, usize) {
        let line = addr & !(self.line_bytes as u64 - 1);
        (line, (addr - line) as usize)
    }

    fn touch(&mut self, line: u64) {
        if let Some(p) = self.lru.iter().position(|&l| l == line) {
            self.lru.remove(p);
        }
        self.lru.push_back(line);
    }

    fn maybe_evict(&mut self) {
        while self.lines.len() >= self.capacity_lines {
            let Some(victim) = self.lru.pop_front() else { break };
            if self.pending.contains_key(&victim) {
                self.lru.push_back(victim); // never evict a pending line
                continue;
            }
            let line = self.lines.remove(&victim).expect("lru tracks lines");
            match line.state {
                Mesi::Modified => {
                    self.writebacks += 1;
                    self.evicting.insert(victim, line.data.clone());
                    let kind = MsgKind::Coh { op: CohOp::PutM, line: victim, ack_count: 0 };
                    self.out.push((
                        Plane::CohReq,
                        Message::data(self.coord, self.dir_tile, kind, Arc::new(line.data)),
                    ));
                }
                // E and S evict silently (clean); the directory's sharer
                // list goes stale, which Inv/InvAck tolerates.
                Mesi::Exclusive | Mesi::Shared => {}
            }
        }
    }

    /// Coherent load of the 8-byte word at `addr`.  Returns the value on a
    /// hit; on a miss, starts a GetS and returns `None` (retry later).
    pub fn load(&mut self, addr: u64) -> Option<u64> {
        let (laddr, off) = self.line_of(addr);
        if let Some(line) = self.lines.get(&laddr) {
            let mut w = [0u8; 8];
            w.copy_from_slice(&line.data[off..off + 8]);
            self.hits += 1;
            self.touch(laddr);
            return Some(u64::from_le_bytes(w));
        }
        if !self.pending.contains_key(&laddr) {
            self.misses += 1;
            self.pending.insert(
                laddr,
                Pending {
                    store: None,
                    acks_needed: 0,
                    count_known: true,
                    data: None,
                    grant: Mesi::Shared,
                },
            );
            let kind = MsgKind::Coh { op: CohOp::GetS, line: laddr, ack_count: 0 };
            self.out.push((Plane::CohReq, Message::ctrl(self.coord, self.dir_tile, kind)));
        }
        None
    }

    /// Coherent load for spin-polling: reads a resident line **without**
    /// refreshing its LRU position or hit counter, so re-polling an
    /// unchanged flag is architecturally invisible — the property that
    /// lets the SoC scheduler park a spinner without diverging from the
    /// poll-every-cycle reference even under eviction pressure.  A miss
    /// falls back to the ordinary [`CacheCtl::load`] path (starting a
    /// GetS on the first call).
    pub fn peek_load(&mut self, addr: u64) -> Option<u64> {
        let (laddr, off) = self.line_of(addr);
        if let Some(line) = self.lines.get(&laddr) {
            let mut w = [0u8; 8];
            w.copy_from_slice(&line.data[off..off + 8]);
            return Some(u64::from_le_bytes(w));
        }
        self.load(addr)
    }

    /// Coherent store of the 8-byte word at `addr`.  Returns `true` when
    /// the store is performed; on a miss/upgrade, starts a GetM and returns
    /// `false` (retry later).
    pub fn store(&mut self, addr: u64, val: u64) -> bool {
        let (laddr, off) = self.line_of(addr);
        if let Some(line) = self.lines.get_mut(&laddr) {
            match line.state {
                Mesi::Modified | Mesi::Exclusive => {
                    line.data[off..off + 8].copy_from_slice(&val.to_le_bytes());
                    line.state = Mesi::Modified; // E -> M silently
                    self.hits += 1;
                    self.touch(laddr);
                    return true;
                }
                Mesi::Shared => {} // upgrade needed
            }
        }
        if !self.pending.contains_key(&laddr) {
            self.misses += 1;
            self.pending.insert(
                laddr,
                Pending {
                    store: Some((off, val)),
                    acks_needed: 0,
                    count_known: false,
                    data: None,
                    grant: Mesi::Modified,
                },
            );
            let kind = MsgKind::Coh { op: CohOp::GetM, line: laddr, ack_count: 0 };
            self.out.push((Plane::CohReq, Message::ctrl(self.coord, self.dir_tile, kind)));
        } else if let Some(p) = self.pending.get_mut(&laddr) {
            // Fold the store into the outstanding transaction if it is
            // (or upgrades to) a write transaction.
            if p.grant == Mesi::Modified && p.store.is_none() {
                p.store = Some((off, val));
            }
        }
        false
    }

    /// Is any transaction outstanding?
    pub fn quiescent(&self) -> bool {
        self.pending.is_empty() && self.evicting.is_empty() && self.deferred.is_empty()
    }

    fn try_complete(&mut self, laddr: u64) {
        let Some(p) = self.pending.get(&laddr) else { return };
        if p.data.is_none() || !p.count_known || p.acks_needed > 0 {
            return;
        }
        let p = self.pending.remove(&laddr).unwrap();
        let mut data = p.data.unwrap();
        let mut state = p.grant;
        if let Some((off, val)) = p.store {
            data[off..off + 8].copy_from_slice(&val.to_le_bytes());
            state = Mesi::Modified;
        }
        self.maybe_evict();
        self.lines.insert(laddr, Line { state, data });
        self.touch(laddr);
        // Serve forwards that raced ahead of our data grant.
        if let Some(msgs) = self.deferred.remove(&laddr) {
            for m in msgs {
                self.handle_msg(&m);
            }
        }
    }

    /// Handle a coherence message addressed to this cache.
    pub fn handle_msg(&mut self, msg: &Message) {
        let MsgKind::Coh { op, line: laddr, ack_count } = msg.kind else { return };
        // A forward or invalidation can overtake the data grant of our own
        // outstanding transaction (the directory does not block on GetM):
        // defer it until the transaction completes.
        if matches!(op, CohOp::FwdGetS | CohOp::FwdGetM | CohOp::Inv)
            && self.pending.contains_key(&laddr)
        {
            self.deferred.entry(laddr).or_default().push(msg.clone());
            return;
        }
        match op {
            CohOp::Data | CohOp::DataM => {
                let grant = if op == CohOp::Data { Mesi::Shared } else { Mesi::Exclusive };
                let p = self.pending.get_mut(&laddr).expect("data without transaction");
                p.data = Some(msg.payload.to_vec());
                if op == CohOp::DataM {
                    p.acks_needed += ack_count as i32;
                    p.count_known = true;
                    p.grant = Mesi::Exclusive;
                } else if p.grant != Mesi::Modified {
                    p.grant = grant;
                }
                self.try_complete(laddr);
            }
            CohOp::InvAck => {
                let p = self.pending.get_mut(&laddr).expect("ack without transaction");
                p.acks_needed -= 1;
                self.try_complete(laddr);
            }
            CohOp::Inv => {
                // Invalidate (silently tolerate a stale sharer-list Inv) and
                // ack the *requester* (msg carries it as src).
                self.lines.remove(&laddr);
                let kind = MsgKind::Coh { op: CohOp::InvAck, line: laddr, ack_count: 0 };
                self.out.push((Plane::CohRsp, Message::ctrl(self.coord, msg.src, kind)));
            }
            CohOp::FwdGetS => {
                // Requester in src.  Serve from line or eviction buffer;
                // downgrade to Shared and send a copy to the directory.
                let data = if let Some(line) = self.lines.get_mut(&laddr) {
                    line.state = Mesi::Shared;
                    line.data.clone()
                } else if let Some(d) = self.evicting.get(&laddr) {
                    d.clone()
                } else {
                    panic!("FwdGetS for line {laddr:#x} not held at {:?}", self.coord)
                };
                self.forwards_served += 1;
                let kind = MsgKind::Coh { op: CohOp::Data, line: laddr, ack_count: 0 };
                self.out.push((
                    Plane::CohRsp,
                    Message::data(self.coord, msg.src, kind, Arc::new(data.clone())),
                ));
                // Copy back to the directory so memory is current.
                let kind = MsgKind::Coh { op: CohOp::PutM, line: laddr, ack_count: 1 };
                self.out.push((
                    Plane::CohRsp,
                    Message::data(self.coord, self.dir_tile, kind, Arc::new(data)),
                ));
            }
            CohOp::FwdGetM => {
                let data = if let Some(line) = self.lines.remove(&laddr) {
                    line.data
                } else if let Some(d) = self.evicting.get(&laddr) {
                    d.clone()
                } else {
                    panic!("FwdGetM for line {laddr:#x} not held at {:?}", self.coord)
                };
                self.forwards_served += 1;
                let kind = MsgKind::Coh { op: CohOp::DataM, line: laddr, ack_count: 0 };
                let rsp = Message::data(self.coord, msg.src, kind, Arc::new(data));
                self.out.push((Plane::CohRsp, rsp));
            }
            CohOp::PutAck => {
                self.evicting.remove(&laddr);
            }
            CohOp::GetS | CohOp::GetM | CohOp::PutM => {
                panic!("request {op:?} delivered to a cache controller");
            }
        }
    }

    /// Drain outgoing coherence messages.
    pub fn drain_out(&mut self) -> Vec<(Plane, Message)> {
        std::mem::take(&mut self.out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Single-cache harness driving CacheCtl against Directory + memory.
    struct World {
        caches: Vec<CacheCtl>,
        dir: Directory,
        dram: Vec<u8>,
    }

    impl World {
        fn new(n: usize) -> Self {
            let caches =
                (0..n).map(|i| CacheCtl::new((1, i as u8), (0, 0), 4096, 64)).collect();
            Self { caches, dir: Directory::new((0, 0), 64), dram: vec![0u8; 1 << 16] }
        }

        /// Deliver all in-flight messages until quiescent (zero-latency NoC).
        fn settle(&mut self) {
            for _ in 0..1000 {
                let mut msgs: Vec<(Plane, Message)> = Vec::new();
                for c in &mut self.caches {
                    msgs.extend(c.drain_out());
                }
                msgs.extend(self.dir.drain_out());
                if msgs.is_empty() {
                    return;
                }
                for (_, m) in msgs {
                    for d in m.dests.iter() {
                        if d == (0, 0) {
                            self.dir.handle_msg(&m, &mut self.dram);
                        } else {
                            let c = self
                                .caches
                                .iter_mut()
                                .find(|c| c.coord == d)
                                .expect("dest cache");
                            c.handle_msg(&m);
                        }
                    }
                }
            }
            panic!("coherence did not settle");
        }

        fn load(&mut self, c: usize, addr: u64) -> u64 {
            for _ in 0..100 {
                if let Some(v) = self.caches[c].load(addr) {
                    return v;
                }
                self.settle();
            }
            panic!("load did not complete");
        }

        fn store(&mut self, c: usize, addr: u64, val: u64) {
            for _ in 0..100 {
                if self.caches[c].store(addr, val) {
                    return;
                }
                self.settle();
            }
            panic!("store did not complete");
        }
    }

    #[test]
    fn cold_load_returns_memory_value() {
        let mut w = World::new(1);
        w.dram[64..72].copy_from_slice(&0xDEAD_BEEFu64.to_le_bytes());
        assert_eq!(w.load(0, 64), 0xDEAD_BEEF);
        // Second load hits.
        let h = w.caches[0].hits;
        assert_eq!(w.load(0, 64), 0xDEAD_BEEF);
        assert!(w.caches[0].hits > h);
    }

    #[test]
    fn peek_load_reads_without_touching_lru_or_stats() {
        let mut w = World::new(1);
        w.dram[0..8].copy_from_slice(&7u64.to_le_bytes());
        assert_eq!(w.load(0, 0), 7, "fill the line");
        let hits = w.caches[0].hits;
        let lru = w.caches[0].lru.clone();
        for _ in 0..10 {
            assert_eq!(w.caches[0].peek_load(0), Some(7));
        }
        assert_eq!(w.caches[0].hits, hits, "peek must not count hits");
        assert_eq!(w.caches[0].lru, lru, "peek must not reorder the LRU");
        // A missing line falls back to the ordinary load path.
        assert_eq!(w.caches[0].peek_load(4096), None);
        w.settle();
        assert_eq!(w.caches[0].peek_load(4096), Some(0));
    }

    #[test]
    fn store_then_load_same_cache() {
        let mut w = World::new(1);
        w.store(0, 128, 42);
        assert_eq!(w.load(0, 128), 42);
    }

    #[test]
    fn producer_consumer_flag() {
        // The paper's sync pattern: producer sets a flag, consumer spins.
        let mut w = World::new(2);
        assert_eq!(w.load(1, 0), 0, "consumer sees flag clear");
        w.store(0, 0, 1); // producer sets (invalidates consumer's copy)
        assert_eq!(w.load(1, 0), 1, "consumer re-fetches and sees flag set");
    }

    #[test]
    fn write_write_transfer() {
        let mut w = World::new(3);
        w.store(0, 256, 7);
        w.store(1, 256, 8);
        w.store(2, 256, 9);
        assert_eq!(w.load(0, 256), 9);
        assert_eq!(w.load(1, 256), 9);
    }

    #[test]
    fn read_sharers_then_writer_invalidates() {
        let mut w = World::new(4);
        w.dram[0..8].copy_from_slice(&5u64.to_le_bytes());
        for c in 0..3 {
            assert_eq!(w.load(c, 0), 5);
        }
        w.store(3, 0, 6);
        for c in 0..3 {
            assert_eq!(w.load(c, 0), 6, "cache {c} sees the new value");
        }
    }

    #[test]
    fn exclusive_grant_on_sole_reader() {
        let mut w = World::new(2);
        w.load(0, 512);
        // Store without further traffic means we got E (silent E->M).
        let misses_before = w.caches[0].misses;
        w.store(0, 512, 1);
        assert_eq!(w.caches[0].misses, misses_before, "E->M upgrade is silent");
    }

    #[test]
    fn eviction_writes_back_dirty_data() {
        let mut w = World::new(1);
        // Cache holds 4096/64 = 64 lines; write 70 distinct lines.
        for i in 0..70u64 {
            w.store(0, i * 64, i + 1);
        }
        w.settle();
        assert!(w.caches[0].writebacks > 0);
        // Evicted values must be recoverable (from dram via directory).
        for i in 0..70u64 {
            assert_eq!(w.load(0, i * 64), i + 1, "line {i}");
        }
    }

    #[test]
    fn distinct_words_same_line() {
        let mut w = World::new(2);
        w.store(0, 0, 1);
        w.store(1, 8, 2); // same line, different word
        assert_eq!(w.load(0, 0), 1);
        assert_eq!(w.load(0, 8), 2);
        assert_eq!(w.load(1, 0), 1);
    }
}
