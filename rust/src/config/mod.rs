//! SoC configuration: grid shape, tile map, NoC parameters, memory system,
//! accelerator socket parameters, and host-cost model.
//!
//! Configs are plain structs with hand-rolled JSON encode/decode (the
//! offline build has no serde; see [`crate::util::json`]) and are
//! validated before a [`crate::coordinator::Soc`] is assembled.  The
//! defaults reproduce the paper's evaluation platform: a 3x4 mesh with one
//! CPU, one memory, one I/O tile and nine accelerator tiles hosting up to
//! two accelerators each (the paper's 17 traffic generators), a 256-bit
//! NoC, and multicast up to 16 destinations.

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::util::Json;

use crate::noc::{header_dest_capacity_for, Coord, Orientation, RouteTable, TickMode,
                 MAX_DESTS, MAX_QUEUE_DEPTH, NUM_PLANES};

/// Largest supported mesh edge.  Coordinates stay `u8`, but the header
/// destination encoding (see [`crate::noc::flit::bits_per_dest`]) and the
/// source-LUT packing are validated up to this bound.
pub const MAX_MESH_DIM: u8 = 16;

/// What occupies one mesh tile.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TileKind {
    /// Host CPU (invocation issue + IRQ handling).
    Cpu,
    /// Memory tile: LLC + directory + DRAM channel.
    Mem,
    /// I/O tile (boot, peripherals; a NoC endpoint but mostly idle here).
    Io,
    /// Accelerator tile hosting `accs` accelerator sockets (1 or 2).
    Acc {
        /// Number of accelerator instances sharing this tile's NoC port.
        accs: u8,
    },
    /// Empty/spare tile.
    Empty,
}

impl TileKind {
    /// Short config-file code ("cpu", "mem", "io", "acc1", "acc2", "empty").
    pub fn code(&self) -> &'static str {
        match self {
            TileKind::Cpu => "cpu",
            TileKind::Mem => "mem",
            TileKind::Io => "io",
            TileKind::Acc { accs: 1 } => "acc1",
            TileKind::Acc { .. } => "acc2",
            TileKind::Empty => "empty",
        }
    }

    /// Parse a config-file code.
    pub fn from_code(s: &str) -> Result<Self> {
        Ok(match s {
            "cpu" => TileKind::Cpu,
            "mem" => TileKind::Mem,
            "io" => TileKind::Io,
            "acc1" => TileKind::Acc { accs: 1 },
            "acc2" => TileKind::Acc { accs: 2 },
            "empty" => TileKind::Empty,
            _ => bail!("unknown tile kind {s:?}"),
        })
    }
}

/// Apply a `u64` field from a JSON object if present.
fn set_u64(j: &Json, key: &str, mut set: impl FnMut(u64)) -> Result<()> {
    if let Some(v) = j.get(key) {
        set(v.as_u64().map_err(|e| anyhow!("{key}: {e}"))?);
    }
    Ok(())
}

/// NoC parameters.
#[derive(Debug, Clone, Copy)]
pub struct NocConfig {
    /// Flit bitwidth (64 / 128 / 256 in the paper).
    pub bitwidth: u32,
    /// Router input-queue depth, flits.
    pub queue_depth: usize,
    /// Maximum multicast destinations this SoC enables (further bounded by
    /// the header capacity of `bitwidth`).
    pub max_mcast_dests: usize,
    /// How `Noc::tick` schedules the six planes (sequential, parallel, or
    /// auto thread fan-out); results are identical in every mode.
    pub tick_mode: TickMode,
    /// Per-plane routing orientation ([`crate::noc::Plane::ALL`] order).
    /// All-XY by default — the paper's baseline and the byte-exact legacy.
    pub orientations: [Orientation; NUM_PLANES],
}

impl Default for NocConfig {
    fn default() -> Self {
        Self {
            bitwidth: 256,
            queue_depth: 4,
            max_mcast_dests: MAX_DESTS,
            tick_mode: TickMode::Auto,
            orientations: [Orientation::Xy; NUM_PLANES],
        }
    }
}

/// Memory-tile parameters.
#[derive(Debug, Clone, Copy)]
pub struct MemConfig {
    /// DRAM size in bytes (backing store).
    pub dram_bytes: u64,
    /// DRAM access latency, cycles.
    pub dram_latency: u32,
    /// LLC capacity in bytes (0 disables the LLC).
    pub llc_bytes: u64,
    /// LLC associativity.
    pub llc_ways: u16,
    /// LLC hit latency, cycles.
    pub llc_latency: u32,
    /// Cache-line bytes (also the coherence granularity).
    pub line_bytes: u32,
    /// New memory requests accepted per cycle (ingress bandwidth).
    pub requests_per_cycle: u32,
    /// DRAM channel bandwidth, bytes per NoC cycle.
    pub channel_bytes_per_cycle: u32,
    /// Route DMA through the LLC.  ESP's non-coherent DMA mode (the one the
    /// paper's traffic generators use) goes directly to external memory, so
    /// the default is `false`; the LLC still backs the coherence directory.
    pub dma_through_llc: bool,
}

impl Default for MemConfig {
    fn default() -> Self {
        Self {
            dram_bytes: 64 << 20,
            dram_latency: 100,
            llc_bytes: 512 << 10,
            llc_ways: 8,
            llc_latency: 12,
            line_bytes: 64,
            requests_per_cycle: 1,
            channel_bytes_per_cycle: 16,
            dma_through_llc: false,
        }
    }
}

/// Accelerator-socket parameters.
#[derive(Debug, Clone, Copy)]
pub struct AccConfig {
    /// Private local memory per accelerator, bytes.
    pub plm_bytes: u32,
    /// Maximum DMA burst, bytes (the paper's traffic generator: 4 KB).
    pub max_burst_bytes: u32,
    /// TLB entries.
    pub tlb_entries: u16,
    /// Page size for the accelerator's virtual buffer.
    pub page_bytes: u32,
    /// Instantiate the optional private L2 (enables fully-coherent mode
    /// and coherence-based synchronization).
    pub l2_enabled: bool,
    /// L2 capacity, bytes.
    pub l2_bytes: u32,
    /// Datapath throughput: words processed per cycle once running.
    pub dp_words_per_cycle: u32,
    /// Cycles a socket waits for a DMA sub-response or P2P data before
    /// re-sending the request.  0 disables retry entirely (the default:
    /// a healthy NoC never drops, so the machinery must cost nothing).
    /// Degraded-mode runs enable it so link kills surface as bounded
    /// retries instead of silent hangs.
    pub retry_timeout: u32,
    /// Resends attempted per request before the socket declares the
    /// destination blackholed and parks with a fault diagnosis.
    pub max_retries: u32,
    /// Bytes of recently streamed P2P data the producer buffers per
    /// consumer for retransmission.  0 disables replay entirely (the
    /// default: the healthy hot path must stay byte-identical); recovery
    /// runs set it so a resume-carrying re-request replays the lost bytes
    /// instead of corrupting the stream.
    pub replay_window: u32,
}

impl Default for AccConfig {
    fn default() -> Self {
        Self {
            plm_bytes: 64 << 10,
            max_burst_bytes: 4 << 10,
            tlb_entries: 32,
            page_bytes: 64 << 10,
            l2_enabled: false,
            l2_bytes: 32 << 10,
            dp_words_per_cycle: 8,
            retry_timeout: 0,
            max_retries: 3,
            replay_window: 0,
        }
    }
}

/// Host (CPU tile) software-cost model.
#[derive(Debug, Clone, Copy)]
pub struct HostConfig {
    /// Cycles of software work to prepare one accelerator invocation
    /// (driver call, argument marshalling) before the register writes.
    pub invocation_overhead: u32,
    /// Cycles to service one interrupt.
    pub irq_overhead: u32,
    /// Cycles between consecutive uncached register writes.
    pub reg_write_gap: u32,
    /// Register writes needed to configure one invocation.
    pub reg_writes_per_invocation: u32,
}

impl Default for HostConfig {
    fn default() -> Self {
        Self {
            invocation_overhead: 200,
            irq_overhead: 150,
            reg_write_gap: 4,
            reg_writes_per_invocation: 12,
        }
    }
}

/// Full SoC description.
#[derive(Debug, Clone)]
pub struct SocConfig {
    /// Mesh columns.
    pub width: u8,
    /// Mesh rows.
    pub height: u8,
    /// Row-major tile map (`width * height` entries).
    pub tiles: Vec<TileKind>,
    /// NoC parameters.
    pub noc: NocConfig,
    /// Memory system.
    pub mem: MemConfig,
    /// Accelerator sockets.
    pub acc: AccConfig,
    /// Host cost model.
    pub host: HostConfig,
    /// Harvest mask: tiles whose router (and tile) are disabled — the
    /// partial-good floorplan of a chip with manufacturing defects.
    /// Harvested tiles are never scheduled, injected at, or routed
    /// *through*; CPU/Mem/IO tiles must survive (validated).
    pub harvest: Vec<Coord>,
    /// Arm the telemetry subsystem: per-router congestion counters on
    /// every NoC plane plus the per-tile busy/sleeping/parked cycle
    /// breakdown (DESIGN.md §telemetry).  Off by default — the hot path
    /// then allocates nothing and results are byte-identical to a
    /// telemetry-free build (`tests/prop_telemetry.rs`).
    pub telemetry: bool,
}

impl SocConfig {
    /// The paper's evaluation platform (Fig. 5): 3 rows x 4 columns, CPU +
    /// Mem + IO + 9 accelerator tiles with two sockets each (up to 18
    /// accelerators; the paper uses 17).
    pub fn paper_3x4() -> Self {
        let mut tiles = vec![TileKind::Acc { accs: 2 }; 12];
        tiles[0] = TileKind::Cpu; // (0,0)
        tiles[3] = TileKind::Mem; // (0,3)
        tiles[8] = TileKind::Io; // (2,0)
        Self {
            width: 4,
            height: 3,
            tiles,
            noc: NocConfig::default(),
            mem: MemConfig::default(),
            acc: AccConfig::default(),
            host: HostConfig::default(),
            harvest: Vec::new(),
            telemetry: false,
        }
    }

    /// A small 3x3 SoC (Fig. 1 of the paper): CPU, Mem, IO + 6 single-socket
    /// accelerator tiles.
    pub fn small_3x3() -> Self {
        let mut tiles = vec![TileKind::Acc { accs: 1 }; 9];
        tiles[0] = TileKind::Cpu;
        tiles[2] = TileKind::Mem;
        tiles[6] = TileKind::Io;
        Self {
            width: 3,
            height: 3,
            tiles,
            noc: NocConfig::default(),
            mem: MemConfig::default(),
            acc: AccConfig::default(),
            host: HostConfig::default(),
            harvest: Vec::new(),
            telemetry: false,
        }
    }

    /// A scaled platform: `width x height` mesh with CPU at (0,0), memory
    /// at (0, width-1), I/O at (height-1, 0), and `acc_tiles` dual-socket
    /// accelerator tiles spread evenly over the remaining positions (the
    /// rest stay empty, as a sparsely-populated agile SoC floorplan would).
    pub fn scaled_mesh(width: u8, height: u8, acc_tiles: usize) -> Self {
        assert!(width >= 3 && height >= 3, "scaled mesh needs room for cpu/mem/io");
        let n = width as usize * height as usize;
        let mut tiles = vec![TileKind::Empty; n];
        let cpu = 0;
        let mem = width as usize - 1;
        let io = n - width as usize;
        tiles[cpu] = TileKind::Cpu;
        tiles[mem] = TileKind::Mem;
        tiles[io] = TileKind::Io;
        let free: Vec<usize> =
            (0..n).filter(|&i| i != cpu && i != mem && i != io).collect();
        assert!(acc_tiles <= free.len(), "mesh too small for {acc_tiles} accelerator tiles");
        for k in 0..acc_tiles {
            tiles[free[k * free.len() / acc_tiles]] = TileKind::Acc { accs: 2 };
        }
        Self {
            width,
            height,
            tiles,
            noc: NocConfig::default(),
            mem: MemConfig::default(),
            acc: AccConfig::default(),
            host: HostConfig::default(),
            harvest: Vec::new(),
            telemetry: false,
        }
    }

    /// The 8x8 scenario platform: 12 dual-socket accelerator tiles (24
    /// sockets) spread over an 8x8 mesh with the default memory system —
    /// big enough for every builtin scenario pattern (rings, shuffles,
    /// fan-outs) while staying on the paper's coordinate encoding (meshes
    /// up to 8x8 share the paper's header capacities).
    pub fn scaled_8x8() -> Self {
        Self::scaled_mesh(8, 8, 12)
    }

    /// The 16x16 evaluation platform for the wide Fig. 6 sweeps: 17
    /// dual-socket accelerator tiles (34 sockets — producer + up to 32
    /// packed consumers + spare) and a memory system scaled up with the
    /// mesh (wider DRAM channel, doubled ingress, 256 MiB backing store so
    /// 32 consumers x 4 MiB output regions fit).
    pub fn scaled_16x16() -> Self {
        let mut cfg = Self::scaled_mesh(16, 16, 17);
        cfg.mem.dram_bytes = 256 << 20;
        cfg.mem.channel_bytes_per_cycle = 64;
        cfg.mem.requests_per_cycle = 2;
        cfg
    }

    /// Load a JSON config file.
    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {}", path.display()))?;
        let cfg = Self::from_json(&text).with_context(|| format!("parse {}", path.display()))?;
        cfg.validate()?;
        Ok(cfg)
    }

    /// Parse a config from JSON text.  Missing sections fall back to the
    /// defaults, so config files only need to state what they change.
    pub fn from_json(text: &str) -> Result<Self> {
        let j = Json::parse(text)?;
        let mut cfg = Self::paper_3x4();
        if let Some(v) = j.get("width") {
            cfg.width = v.as_u64()? as u8;
        }
        if let Some(v) = j.get("height") {
            cfg.height = v.as_u64()? as u8;
        }
        if let Some(tiles) = j.get("tiles") {
            cfg.tiles = tiles
                .as_arr()?
                .iter()
                .map(|t| TileKind::from_code(t.as_str()?))
                .collect::<Result<Vec<_>>>()?;
        }
        if let Some(n) = j.get("noc") {
            set_u64(n, "bitwidth", |v| cfg.noc.bitwidth = v as u32)?;
            set_u64(n, "queue_depth", |v| cfg.noc.queue_depth = v as usize)?;
            set_u64(n, "max_mcast_dests", |v| cfg.noc.max_mcast_dests = v as usize)?;
            if let Some(m) = n.get("tick_mode") {
                let s = m.as_str()?;
                cfg.noc.tick_mode = TickMode::from_code(s)
                    .ok_or_else(|| anyhow!("unknown tick_mode {s:?}"))?;
            }
            if let Some(o) = n.get("orientations") {
                let arr = o.as_arr()?;
                ensure!(
                    arr.len() == NUM_PLANES,
                    "orientations must list one code per plane ({NUM_PLANES})"
                );
                for (i, v) in arr.iter().enumerate() {
                    let s = v.as_str()?;
                    cfg.noc.orientations[i] = Orientation::from_code(s)
                        .ok_or_else(|| anyhow!("unknown orientation {s:?}"))?;
                }
            }
        }
        if let Some(m) = j.get("mem") {
            set_u64(m, "dram_bytes", |v| cfg.mem.dram_bytes = v)?;
            set_u64(m, "dram_latency", |v| cfg.mem.dram_latency = v as u32)?;
            set_u64(m, "llc_bytes", |v| cfg.mem.llc_bytes = v)?;
            set_u64(m, "llc_ways", |v| cfg.mem.llc_ways = v as u16)?;
            set_u64(m, "llc_latency", |v| cfg.mem.llc_latency = v as u32)?;
            set_u64(m, "line_bytes", |v| cfg.mem.line_bytes = v as u32)?;
            set_u64(m, "requests_per_cycle", |v| cfg.mem.requests_per_cycle = v as u32)?;
            set_u64(m, "channel_bytes_per_cycle", |v| {
                cfg.mem.channel_bytes_per_cycle = v as u32
            })?;
            if let Some(b) = m.get("dma_through_llc") {
                cfg.mem.dma_through_llc = b.as_bool()?;
            }
        }
        if let Some(a) = j.get("acc") {
            set_u64(a, "plm_bytes", |v| cfg.acc.plm_bytes = v as u32)?;
            set_u64(a, "max_burst_bytes", |v| cfg.acc.max_burst_bytes = v as u32)?;
            set_u64(a, "tlb_entries", |v| cfg.acc.tlb_entries = v as u16)?;
            set_u64(a, "page_bytes", |v| cfg.acc.page_bytes = v as u32)?;
            set_u64(a, "l2_bytes", |v| cfg.acc.l2_bytes = v as u32)?;
            set_u64(a, "dp_words_per_cycle", |v| cfg.acc.dp_words_per_cycle = v as u32)?;
            set_u64(a, "retry_timeout", |v| cfg.acc.retry_timeout = v as u32)?;
            set_u64(a, "max_retries", |v| cfg.acc.max_retries = v as u32)?;
            set_u64(a, "replay_window", |v| cfg.acc.replay_window = v as u32)?;
            if let Some(b) = a.get("l2_enabled") {
                cfg.acc.l2_enabled = b.as_bool()?;
            }
        }
        if let Some(h) = j.get("harvest") {
            cfg.harvest = h
                .as_arr()?
                .iter()
                .map(|c| {
                    let pair = c.as_arr()?;
                    ensure!(pair.len() == 2, "harvest entry must be [y, x]");
                    Ok((pair[0].as_u64()? as u8, pair[1].as_u64()? as u8))
                })
                .collect::<Result<Vec<Coord>>>()?;
        }
        if let Some(b) = j.get("telemetry") {
            cfg.telemetry = b.as_bool()?;
        }
        if let Some(h) = j.get("host") {
            set_u64(h, "invocation_overhead", |v| cfg.host.invocation_overhead = v as u32)?;
            set_u64(h, "irq_overhead", |v| cfg.host.irq_overhead = v as u32)?;
            set_u64(h, "reg_write_gap", |v| cfg.host.reg_write_gap = v as u32)?;
            set_u64(h, "reg_writes_per_invocation", |v| {
                cfg.host.reg_writes_per_invocation = v as u32
            })?;
        }
        Ok(cfg)
    }

    /// Serialize to JSON (parseable by [`SocConfig::from_json`]).
    pub fn to_json(&self) -> String {
        use std::collections::BTreeMap;
        let obj = |pairs: Vec<(&str, Json)>| {
            Json::Obj(
                pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>(),
            )
        };
        obj(vec![
            ("width", Json::from(self.width as u64)),
            ("height", Json::from(self.height as u64)),
            ("tiles", Json::Arr(self.tiles.iter().map(|t| Json::from(t.code())).collect())),
            (
                "noc",
                obj(vec![
                    ("bitwidth", Json::from(self.noc.bitwidth as u64)),
                    ("queue_depth", Json::from(self.noc.queue_depth as u64)),
                    ("max_mcast_dests", Json::from(self.noc.max_mcast_dests as u64)),
                    ("tick_mode", Json::from(self.noc.tick_mode.code())),
                    (
                        "orientations",
                        Json::Arr(
                            self.noc.orientations.iter().map(|o| Json::from(o.code())).collect(),
                        ),
                    ),
                ]),
            ),
            (
                "mem",
                obj(vec![
                    ("dram_bytes", Json::from(self.mem.dram_bytes)),
                    ("dram_latency", Json::from(self.mem.dram_latency as u64)),
                    ("llc_bytes", Json::from(self.mem.llc_bytes)),
                    ("llc_ways", Json::from(self.mem.llc_ways as u64)),
                    ("llc_latency", Json::from(self.mem.llc_latency as u64)),
                    ("line_bytes", Json::from(self.mem.line_bytes as u64)),
                    ("requests_per_cycle", Json::from(self.mem.requests_per_cycle as u64)),
                    (
                        "channel_bytes_per_cycle",
                        Json::from(self.mem.channel_bytes_per_cycle as u64),
                    ),
                    ("dma_through_llc", Json::from(self.mem.dma_through_llc)),
                ]),
            ),
            (
                "acc",
                obj(vec![
                    ("plm_bytes", Json::from(self.acc.plm_bytes as u64)),
                    ("max_burst_bytes", Json::from(self.acc.max_burst_bytes as u64)),
                    ("tlb_entries", Json::from(self.acc.tlb_entries as u64)),
                    ("page_bytes", Json::from(self.acc.page_bytes as u64)),
                    ("l2_enabled", Json::from(self.acc.l2_enabled)),
                    ("l2_bytes", Json::from(self.acc.l2_bytes as u64)),
                    ("dp_words_per_cycle", Json::from(self.acc.dp_words_per_cycle as u64)),
                    ("retry_timeout", Json::from(self.acc.retry_timeout as u64)),
                    ("max_retries", Json::from(self.acc.max_retries as u64)),
                    ("replay_window", Json::from(self.acc.replay_window as u64)),
                ]),
            ),
            (
                "harvest",
                Json::Arr(
                    self.harvest
                        .iter()
                        .map(|&(y, x)| {
                            Json::Arr(vec![Json::from(y as u64), Json::from(x as u64)])
                        })
                        .collect(),
                ),
            ),
            ("telemetry", Json::from(self.telemetry)),
            (
                "host",
                obj(vec![
                    ("invocation_overhead", Json::from(self.host.invocation_overhead as u64)),
                    ("irq_overhead", Json::from(self.host.irq_overhead as u64)),
                    ("reg_write_gap", Json::from(self.host.reg_write_gap as u64)),
                    (
                        "reg_writes_per_invocation",
                        Json::from(self.host.reg_writes_per_invocation as u64),
                    ),
                ]),
            ),
        ])
        .to_string()
    }

    /// Effective multicast destination bound: min(user cap, header capacity
    /// for this mesh's coordinate encoding).
    pub fn mcast_capacity(&self) -> usize {
        self.noc
            .max_mcast_dests
            .min(header_dest_capacity_for(self.noc.bitwidth, self.width, self.height))
    }

    /// Payload bytes per flit.
    pub fn flit_bytes(&self) -> u32 {
        self.noc.bitwidth / 8
    }

    /// Coordinate of tile index `i` (row-major).
    pub fn coord_of(&self, i: usize) -> Coord {
        ((i / self.width as usize) as u8, (i % self.width as usize) as u8)
    }

    /// Tile index of coordinate `c`.
    pub fn index_of(&self, c: Coord) -> usize {
        c.0 as usize * self.width as usize + c.1 as usize
    }

    /// Coordinate of the (single) memory tile.
    pub fn mem_tile(&self) -> Coord {
        let i = self
            .tiles
            .iter()
            .position(|t| matches!(t, TileKind::Mem))
            .expect("validated config has a Mem tile");
        self.coord_of(i)
    }

    /// Coordinate of the (single) CPU tile.
    pub fn cpu_tile(&self) -> Coord {
        let i = self
            .tiles
            .iter()
            .position(|t| matches!(t, TileKind::Cpu))
            .expect("validated config has a Cpu tile");
        self.coord_of(i)
    }

    /// Most accelerator sockets sharing one tile's NoC port (1 or 2; 1 on
    /// a platform with no accelerator tiles).  Bounds how many consumers
    /// can share one multicast destination tile.
    pub fn max_sockets_per_tile(&self) -> usize {
        self.tiles
            .iter()
            .map(|t| match t {
                TileKind::Acc { accs } => *accs as usize,
                _ => 0,
            })
            .max()
            .unwrap_or(0)
            .max(1)
    }

    /// `(tile coord, slot)` of every *live* accelerator socket, in a
    /// stable order.  Sockets on harvested tiles do not exist: they are
    /// never scheduled and never assigned scenario roles.
    pub fn acc_sockets(&self) -> Vec<(Coord, u8)> {
        let mut v = Vec::new();
        for (i, t) in self.tiles.iter().enumerate() {
            if self.is_harvested(self.coord_of(i)) {
                continue;
            }
            if let TileKind::Acc { accs } = t {
                for s in 0..*accs {
                    v.push((self.coord_of(i), s));
                }
            }
        }
        v
    }

    /// Is tile `c` on the harvest mask (disabled)?
    pub fn is_harvested(&self, c: Coord) -> bool {
        self.harvest.contains(&c)
    }

    /// Harvest mesh rows (convenience for the degraded-mode sweeps):
    /// every tile of each row in `rows` is disabled except CPU/Mem/IO
    /// tiles (which must survive) and a single *bridge* tile at column 0,
    /// which keeps the mesh halves connected — the realistic partial-good
    /// floorplan, where a defect row loses its compute but one router
    /// column still stitches the fabric together.  Push coordinates onto
    /// `harvest` directly for full-row (disconnecting) kills.
    pub fn harvest_rows(&mut self, rows: &[u8]) {
        for &y in rows {
            assert!(y < self.height, "harvest row {y} outside mesh height {}", self.height);
            for x in 0..self.width {
                let c = (y, x);
                let keep = x == 0
                    || matches!(
                        self.tiles[self.index_of(c)],
                        TileKind::Cpu | TileKind::Mem | TileKind::Io
                    );
                if !keep && !self.harvest.contains(&c) {
                    self.harvest.push(c);
                }
            }
        }
    }

    /// Validate structural invariants.
    pub fn validate(&self) -> Result<()> {
        ensure!(self.width >= 2 && self.height >= 2, "mesh must be at least 2x2");
        ensure!(
            self.width <= MAX_MESH_DIM && self.height <= MAX_MESH_DIM,
            "mesh edges capped at {MAX_MESH_DIM} (header coordinate encoding)"
        );
        ensure!(
            self.tiles.len() == self.width as usize * self.height as usize,
            "tile map has {} entries for a {}x{} mesh",
            self.tiles.len(),
            self.width,
            self.height
        );
        let count = |f: fn(&TileKind) -> bool| self.tiles.iter().filter(|t| f(t)).count();
        ensure!(count(|t| matches!(t, TileKind::Cpu)) == 1, "exactly one CPU tile");
        ensure!(count(|t| matches!(t, TileKind::Mem)) == 1, "exactly one Mem tile");
        ensure!(
            matches!(self.noc.bitwidth, 64 | 128 | 256),
            "NoC bitwidth must be 64, 128, or 256"
        );
        ensure!(self.noc.queue_depth >= 2, "queue depth >= 2 for wormhole progress");
        ensure!(
            self.noc.queue_depth <= MAX_QUEUE_DEPTH,
            "queue depth <= {MAX_QUEUE_DEPTH} (router port queues are inline rings)"
        );
        ensure!(self.noc.max_mcast_dests <= MAX_DESTS, "multicast cap is {MAX_DESTS}");
        for t in &self.tiles {
            if let TileKind::Acc { accs } = t {
                ensure!(*accs >= 1 && *accs <= 2, "1 or 2 accelerators per tile");
            }
        }
        ensure!(self.acc.max_burst_bytes <= self.acc.plm_bytes / 2, "PLM must fit 2 bursts");
        ensure!(self.mem.line_bytes.is_power_of_two(), "line size power of two");
        ensure!(self.acc.page_bytes.is_power_of_two(), "page size power of two");

        // Harvest mask: in bounds, never a CPU/Mem/IO tile, and the
        // surviving endpoints must still reach each other (a mask that
        // cuts the mesh is a config error, caught here with a concrete
        // example pair rather than a hung simulation).
        for &c in &self.harvest {
            ensure!(
                c.0 < self.height && c.1 < self.width,
                "harvested tile {c:?} outside the {}x{} mesh",
                self.width,
                self.height
            );
            let kind = self.tiles[self.index_of(c)];
            ensure!(
                !matches!(kind, TileKind::Cpu | TileKind::Mem | TileKind::Io),
                "cannot harvest the {} tile at {c:?}",
                kind.code()
            );
        }
        if !self.harvest.is_empty() {
            let table = RouteTable::build(self.width, self.height, &self.harvest, &[]);
            let mut live: Vec<Coord> = vec![self.cpu_tile(), self.mem_tile()];
            live.extend(self.acc_sockets().iter().map(|&(c, _)| c));
            live.dedup();
            for &a in &live {
                for &b in &live {
                    ensure!(
                        table.reachable(a, b),
                        "harvest mask disconnects the mesh: no live route from \
                         {a:?} to {b:?} (disable fewer tiles or a different row)"
                    );
                }
            }
        }
        Ok(())
    }
}

impl Default for SocConfig {
    fn default() -> Self {
        Self::paper_3x4()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_platform_validates() {
        let c = SocConfig::paper_3x4();
        c.validate().unwrap();
        assert_eq!(c.acc_sockets().len(), 18); // paper uses 17 of them
        assert_eq!(c.mcast_capacity(), 16);
        assert_eq!(c.mem_tile(), (0, 3));
        assert_eq!(c.cpu_tile(), (0, 0));
    }

    #[test]
    fn small_platform_validates() {
        let c = SocConfig::small_3x3();
        c.validate().unwrap();
        assert_eq!(c.acc_sockets().len(), 6);
    }

    #[test]
    fn scaled_8x8_validates_with_paper_encoding() {
        let c = SocConfig::scaled_8x8();
        c.validate().unwrap();
        assert_eq!(c.acc_sockets().len(), 24);
        // 8x8 stays on the paper's 3-bit coordinate floor, so the header
        // capacities match the paper platform exactly.
        assert_eq!(c.mcast_capacity(), SocConfig::paper_3x4().mcast_capacity());
    }

    #[test]
    fn scaled_16x16_validates() {
        let c = SocConfig::scaled_16x16();
        c.validate().unwrap();
        assert_eq!(c.acc_sockets().len(), 34, "producer + 32 packed consumers + spare");
        assert_eq!(c.mem_tile(), (0, 15));
        assert_eq!(c.cpu_tile(), (0, 0));
        // 9-bit destinations shrink the narrow-NoC capacities...
        let mut c64 = c.clone();
        c64.noc.bitwidth = 64;
        assert_eq!(c64.mcast_capacity(), 3);
        let mut c128 = c.clone();
        c128.noc.bitwidth = 128;
        assert_eq!(c128.mcast_capacity(), 10);
        // ...while 256-bit still reaches the paper's 16-destination cap.
        assert_eq!(c.mcast_capacity(), 16);
    }

    #[test]
    fn scaled_mesh_spread_is_deterministic() {
        let a = SocConfig::scaled_mesh(12, 9, 10);
        let b = SocConfig::scaled_mesh(12, 9, 10);
        a.validate().unwrap();
        assert_eq!(a.tiles, b.tiles);
        assert_eq!(a.acc_sockets().len(), 20);
    }

    #[test]
    fn rejects_meshes_beyond_the_coordinate_bound() {
        let mut c = SocConfig::scaled_mesh(16, 16, 4);
        c.validate().unwrap();
        c.width = 17;
        c.tiles = vec![TileKind::Empty; 17 * 16];
        c.tiles[0] = TileKind::Cpu;
        c.tiles[1] = TileKind::Mem;
        assert!(c.validate().is_err());
    }

    #[test]
    fn tick_mode_roundtrips_through_json() {
        use crate::noc::TickMode;
        let mut c = SocConfig::paper_3x4();
        c.noc.tick_mode = TickMode::Parallel;
        let c2 = SocConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.noc.tick_mode, TickMode::Parallel);
        assert_eq!(
            SocConfig::from_json("{}").unwrap().noc.tick_mode,
            TickMode::Auto,
            "default stays auto"
        );
        assert!(SocConfig::from_json(r#"{"noc": {"tick_mode": "bogus"}}"#).is_err());
    }

    #[test]
    fn orientations_roundtrip_through_json() {
        let mut c = SocConfig::paper_3x4();
        c.noc.orientations[2] = Orientation::Yx;
        c.noc.orientations[4] = Orientation::FlippedYx;
        let c2 = SocConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.noc.orientations, c.noc.orientations);
        assert_eq!(
            SocConfig::from_json("{}").unwrap().noc.orientations,
            [Orientation::Xy; NUM_PLANES],
            "absent field defaults to all-XY"
        );
        assert!(SocConfig::from_json(r#"{"noc": {"orientations": ["zigzag"]}}"#).is_err());
        let short = r#"{"noc": {"orientations": ["xy", "yx"]}}"#;
        assert!(SocConfig::from_json(short).is_err(), "must name every plane");
    }

    #[test]
    fn bitwidth_bounds_multicast() {
        let mut c = SocConfig::paper_3x4();
        c.noc.bitwidth = 64;
        assert_eq!(c.mcast_capacity(), 5);
        c.noc.bitwidth = 128;
        assert_eq!(c.mcast_capacity(), 14);
    }

    #[test]
    fn json_roundtrip() {
        let mut c = SocConfig::paper_3x4();
        c.noc.bitwidth = 128;
        c.mem.dma_through_llc = true;
        c.acc.l2_enabled = true;
        c.host.irq_overhead = 77;
        let j = c.to_json();
        let c2 = SocConfig::from_json(&j).unwrap();
        assert_eq!(c2.width, c.width);
        assert_eq!(c2.tiles, c.tiles);
        assert_eq!(c2.noc.bitwidth, 128);
        assert!(c2.mem.dma_through_llc);
        assert!(c2.acc.l2_enabled);
        assert_eq!(c2.host.irq_overhead, 77);
    }

    #[test]
    fn partial_json_uses_defaults() {
        let c = SocConfig::from_json(r#"{"noc": {"bitwidth": 64}}"#).unwrap();
        assert_eq!(c.noc.bitwidth, 64);
        assert_eq!(c.width, 4, "rest defaults to the paper platform");
    }

    #[test]
    fn telemetry_flag_roundtrips_and_defaults_off() {
        assert!(!SocConfig::paper_3x4().telemetry, "telemetry is opt-in");
        assert!(!SocConfig::from_json("{}").unwrap().telemetry);
        let mut c = SocConfig::paper_3x4();
        c.telemetry = true;
        assert!(SocConfig::from_json(&c.to_json()).unwrap().telemetry);
        assert!(SocConfig::from_json(r#"{"telemetry": true}"#).unwrap().telemetry);
        assert!(SocConfig::from_json(r#"{"telemetry": 1}"#).is_err(), "must be a bool");
    }

    #[test]
    fn tile_codes_roundtrip() {
        for t in [
            TileKind::Cpu,
            TileKind::Mem,
            TileKind::Io,
            TileKind::Acc { accs: 1 },
            TileKind::Acc { accs: 2 },
            TileKind::Empty,
        ] {
            assert_eq!(TileKind::from_code(t.code()).unwrap(), t);
        }
        assert!(TileKind::from_code("bogus").is_err());
    }

    #[test]
    fn rejects_bad_configs() {
        let mut c = SocConfig::paper_3x4();
        c.tiles[5] = TileKind::Cpu; // second CPU
        assert!(c.validate().is_err());

        let mut c = SocConfig::paper_3x4();
        c.noc.bitwidth = 96;
        assert!(c.validate().is_err());

        let mut c = SocConfig::paper_3x4();
        c.tiles.pop();
        assert!(c.validate().is_err());
    }

    #[test]
    fn harvest_roundtrips_and_validates() {
        let mut c = SocConfig::scaled_16x16();
        c.harvest_rows(&[7]);
        assert_eq!(c.harvest.len(), 15, "row 7 dies except the column-0 bridge");
        c.validate().unwrap_or_else(|e| panic!("one harvested row must validate: {e}"));
        assert!(c.is_harvested((7, 3)));
        assert!(!c.is_harvested((7, 0)), "bridge tile survives");
        assert!(!c.is_harvested((6, 3)));
        let c2 = SocConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.harvest, c.harvest);
        // Sockets on the dead row vanish from the stable socket order.
        assert!(c.acc_sockets().iter().all(|&(t, _)| t.0 != 7));
        assert!(c.acc_sockets().len() < SocConfig::scaled_16x16().acc_sockets().len());
    }

    #[test]
    fn harvest_rejects_protected_and_disconnecting_masks() {
        let mut c = SocConfig::paper_3x4();
        c.harvest.push((0, 0)); // the CPU tile
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("cannot harvest"), "got: {err}");

        let mut c = SocConfig::paper_3x4();
        c.harvest.push((9, 9));
        assert!(c.validate().unwrap_err().to_string().contains("outside"));

        // Harvest every neighbour of the CPU corner: the mesh is cut and
        // the diagnostic names a concrete unreachable pair.
        let mut c = SocConfig::paper_3x4();
        c.harvest.push((0, 1));
        c.harvest.push((1, 0));
        c.harvest.push((1, 1));
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("disconnects"), "got: {err}");
    }

    #[test]
    fn retry_config_roundtrips() {
        let mut c = SocConfig::paper_3x4();
        assert_eq!(c.acc.retry_timeout, 0, "retry off by default");
        assert_eq!(c.acc.replay_window, 0, "replay off by default");
        c.acc.retry_timeout = 4096;
        c.acc.max_retries = 5;
        c.acc.replay_window = 1 << 16;
        let c2 = SocConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.acc.retry_timeout, 4096);
        assert_eq!(c2.acc.max_retries, 5);
        assert_eq!(c2.acc.replay_window, 1 << 16);
        assert_eq!(SocConfig::from_json("{}").unwrap().acc.replay_window, 0);
    }

    #[test]
    fn coord_index_roundtrip() {
        let c = SocConfig::paper_3x4();
        for i in 0..12 {
            assert_eq!(c.index_of(c.coord_of(i)), i);
        }
    }
}
