//! The I/O tile: boot/peripheral endpoint.  In the paper's evaluation SoC
//! it takes no part in the measured dataflows; here it sinks (and counts)
//! whatever reaches it so the consumption assumption holds at every NoC
//! endpoint.

use crate::noc::{Coord, Noc, Plane};
use crate::sched::Wake;

/// The I/O tile.
pub struct IoTile {
    /// Tile coordinate.
    pub coord: Coord,
    /// Messages sunk per plane.
    pub sunk: [u64; crate::noc::NUM_PLANES],
}

impl IoTile {
    /// Build.
    pub fn new(coord: Coord) -> Self {
        Self { coord, sunk: [0; crate::noc::NUM_PLANES] }
    }

    /// Drain every plane.  Purely reactive: only a delivery gives the
    /// next tick anything to do.
    pub fn tick(&mut self, _now: u64, noc: &mut Noc) -> Wake {
        for p in Plane::ALL {
            while noc.recv(p, self.coord).is_some() {
                self.sunk[p.idx()] += 1;
            }
        }
        Wake::Parked
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::{MeshParams, Message, MsgKind};

    #[test]
    fn sinks_everything() {
        let mut noc = Noc::new(MeshParams { width: 2, height: 2, flit_bytes: 32, queue_depth: 4 });
        let mut io = IoTile::new((1, 1));
        noc.send(Plane::Misc, (0, 0), Message::ctrl((0, 0), (1, 1), MsgKind::Irq { acc: 0 }));
        for t in 0..50 {
            noc.tick(t);
            io.tick(t, &mut noc);
        }
        assert_eq!(io.sunk[Plane::Misc.idx()], 1);
        assert!(noc.is_idle());
    }
}
