//! The memory tile: LLC + coherence directory + DRAM channel.
//!
//! DMA requests (plane [`Plane::DmaReq`]) probe the LLC per line; a burst
//! with misses pays the DRAM latency and occupies the DRAM channel for the
//! missing lines, which is the **shared-memory bottleneck** the paper's
//! baseline suffers: N consumers reading the same producer output serialize
//! behind this tile's ingress and channel bandwidth.  Coherence requests
//! (plane [`Plane::CohReq`]) go to the embedded [`Directory`].

use std::collections::VecDeque;
use std::sync::Arc;

use crate::coherence::Directory;
use crate::config::MemConfig;
use crate::noc::{Coord, Message, MsgKind, Noc, Plane};
use crate::sched::Wake;

/// Set-associative LLC metadata (data lives in the DRAM array; the LLC
/// tracks presence + dirtiness for timing).
#[derive(Debug)]
struct Llc {
    /// Per-set line addresses, LRU order (front = oldest); parallel dirty bits.
    sets: Vec<VecDeque<(u64, bool)>>,
    ways: usize,
    line_bytes: u64,
}

impl Llc {
    fn new(capacity: u64, ways: u16, line_bytes: u32) -> Self {
        let lines = (capacity / line_bytes as u64).max(1);
        let sets = (lines / ways.max(1) as u64).max(1) as usize;
        Self {
            sets: (0..sets).map(|_| VecDeque::new()).collect(),
            ways: ways.max(1) as usize,
            line_bytes: line_bytes as u64,
        }
    }

    fn set_of(&self, line: u64) -> usize {
        ((line / self.line_bytes) % self.sets.len() as u64) as usize
    }

    /// Probe (and LRU-refresh) a line.
    fn probe(&mut self, line: u64) -> bool {
        let s = self.set_of(line);
        if let Some(p) = self.sets[s].iter().position(|&(l, _)| l == line) {
            let e = self.sets[s].remove(p).unwrap();
            self.sets[s].push_back(e);
            true
        } else {
            false
        }
    }

    /// Insert a line; returns true when a dirty victim was evicted.
    fn insert(&mut self, line: u64, dirty: bool) -> bool {
        let s = self.set_of(line);
        if let Some(p) = self.sets[s].iter().position(|&(l, _)| l == line) {
            let mut e = self.sets[s].remove(p).unwrap();
            e.1 |= dirty;
            self.sets[s].push_back(e);
            return false;
        }
        let mut evicted_dirty = false;
        if self.sets[s].len() >= self.ways {
            if let Some((_, d)) = self.sets[s].pop_front() {
                evicted_dirty = d;
            }
        }
        self.sets[s].push_back((line, dirty));
        evicted_dirty
    }
}

/// Memory-tile statistics.
#[derive(Debug, Default, Clone)]
pub struct MemStats {
    /// DMA read requests served.
    pub reads: u64,
    /// DMA write requests served.
    pub writes: u64,
    /// Bytes read / written via DMA.
    pub read_bytes: u64,
    /// Bytes written.
    pub write_bytes: u64,
    /// LLC line hits / misses (DMA path).
    pub llc_hits: u64,
    /// LLC misses.
    pub llc_misses: u64,
    /// Cycles the DRAM channel was occupied.
    pub dram_busy_cycles: u64,
}

/// The memory tile.
pub struct MemTile {
    /// Tile coordinate.
    pub coord: Coord,
    cfg: MemConfig,
    /// Backing store (also the coherence home memory).
    pub dram: Vec<u8>,
    llc: Llc,
    /// Coherence directory.
    pub dir: Directory,
    /// Delayed outgoing responses: (ready cycle, plane, message).
    jobs: Vec<(u64, Plane, Message)>,
    /// DRAM channel free-at cycle (bandwidth model).
    dram_free: u64,
    /// Statistics.
    pub stats: MemStats,
}

impl MemTile {
    /// Build with zeroed DRAM.
    pub fn new(coord: Coord, cfg: MemConfig) -> Self {
        Self {
            coord,
            dram: vec![0u8; cfg.dram_bytes as usize],
            llc: Llc::new(cfg.llc_bytes, cfg.llc_ways, cfg.line_bytes),
            dir: Directory::new(coord, cfg.line_bytes),
            jobs: Vec::new(),
            dram_free: 0,
            stats: MemStats::default(),
            cfg,
        }
    }

    /// Probe the LLC for every line a `[addr, addr+len)` access touches;
    /// returns the cycle the access completes, charging latency + DRAM
    /// channel occupancy.  With `dma_through_llc == false` (ESP's
    /// non-coherent DMA, the paper's configuration) every DMA access goes
    /// straight to the DRAM channel.
    fn access(&mut self, now: u64, addr: u64, len: u32, write: bool) -> u64 {
        let bpc = self.cfg.channel_bytes_per_cycle.max(1) as u64;
        if !self.cfg.dma_through_llc || self.cfg.llc_bytes == 0 {
            // Pipelined DRAM channel: transfer serializes, latency overlaps.
            let start = now.max(self.dram_free);
            let transfer = (len as u64).div_ceil(bpc);
            self.dram_free = start + transfer;
            self.stats.dram_busy_cycles += transfer;
            self.stats.llc_misses += 1;
            return start + self.cfg.dram_latency as u64 + transfer;
        }
        let line = self.cfg.line_bytes as u64;
        let first = addr / line;
        let last = (addr + len as u64 - 1) / line;
        let mut misses = 0u64;
        let mut dirty_evictions = 0u64;
        for l in first..=last {
            if self.llc.probe(l * line) {
                self.stats.llc_hits += 1;
            } else {
                self.stats.llc_misses += 1;
                misses += 1;
                if self.llc.insert(l * line, write) {
                    dirty_evictions += 1;
                }
            }
        }
        let mut ready = now + self.cfg.llc_latency as u64;
        if misses > 0 {
            // Serialize the missing lines on the DRAM channel.
            let start = now.max(self.dram_free);
            let busy = (misses + dirty_evictions) * line / bpc;
            self.dram_free = start + busy;
            self.stats.dram_busy_cycles += busy;
            ready = start + self.cfg.dram_latency as u64 + busy;
        }
        ready
    }

    /// Advance one cycle: accept requests, progress the directory, emit
    /// ready responses.  Wake state: bounded ingress (DMA requests beyond
    /// `requests_per_cycle`, the one-per-cycle directory port) keeps the
    /// tile busy while a backlog waits; otherwise it sleeps until the
    /// earliest delayed response and parks when none is pending.
    pub fn tick(&mut self, now: u64, noc: &mut Noc) -> Wake {
        // Accept DMA requests (bounded ingress).
        for _ in 0..self.cfg.requests_per_cycle {
            let Some(msg) = noc.recv(Plane::DmaReq, self.coord) else { break };
            match msg.kind {
                MsgKind::DmaReadReq { addr, len, tag, slot } => {
                    self.stats.reads += 1;
                    self.stats.read_bytes += len as u64;
                    let ready = self.access(now, addr, len, false);
                    let a = addr as usize;
                    let payload = Arc::new(self.dram[a..a + len as usize].to_vec());
                    let rsp = Message::data(
                        self.coord,
                        msg.src,
                        MsgKind::DmaReadRsp { tag, slot },
                        payload,
                    );
                    self.jobs.push((ready, Plane::DmaRsp, rsp));
                }
                MsgKind::DmaWriteReq { addr, len, tag, slot } => {
                    self.stats.writes += 1;
                    self.stats.write_bytes += len as u64;
                    debug_assert_eq!(msg.payload.len(), len as usize);
                    let a = addr as usize;
                    self.dram[a..a + len as usize].copy_from_slice(&msg.payload);
                    let ready = self.access(now, addr, len, true);
                    let ack =
                        Message::ctrl(self.coord, msg.src, MsgKind::DmaWriteAck { tag, slot });
                    self.jobs.push((ready, Plane::DmaRsp, ack));
                }
                _ => {}
            }
        }
        // Coherence requests -> directory (one per cycle, blocking dir).
        if let Some(msg) = noc.recv(Plane::CohReq, self.coord) {
            self.dir.handle_msg(&msg, &mut self.dram);
        }
        // Responses routed back to the directory (copybacks ride CohRsp).
        while let Some(msg) = noc.recv(Plane::CohRsp, self.coord) {
            self.dir.handle_msg(&msg, &mut self.dram);
        }
        for (plane, m) in self.dir.drain_out() {
            self.jobs.push((now + self.cfg.llc_latency as u64, plane, m));
        }
        // Emit ready jobs.
        let mut i = 0;
        while i < self.jobs.len() {
            if self.jobs[i].0 <= now {
                let (_, plane, msg) = self.jobs.swap_remove(i);
                noc.send(plane, self.coord, msg);
            } else {
                i += 1;
            }
        }
        if noc.has_rx(Plane::DmaReq, self.coord) || noc.has_rx(Plane::CohReq, self.coord) {
            return Wake::Busy; // ingress backlog beyond this cycle's bound
        }
        match self.jobs.iter().map(|j| j.0).min() {
            Some(ready) => Wake::at(now, ready),
            None => Wake::Parked,
        }
    }

    /// Outstanding delayed responses (for idle detection).
    pub fn busy(&self) -> bool {
        !self.jobs.is_empty() || !self.dir.quiescent()
    }

    /// Backdoor: host/launcher writes initial data into DRAM.
    pub fn write_backdoor(&mut self, addr: u64, data: &[u8]) {
        let a = addr as usize;
        self.dram[a..a + data.len()].copy_from_slice(data);
    }

    /// Backdoor: read DRAM (result checking).
    pub fn read_backdoor(&self, addr: u64, len: usize) -> &[u8] {
        let a = addr as usize;
        &self.dram[a..a + len]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::MeshParams;

    fn world() -> (MemTile, Noc) {
        let cfg = MemConfig { dram_bytes: 1 << 20, ..MemConfig::default() };
        (
            MemTile::new((0, 0), cfg),
            Noc::new(MeshParams { width: 2, height: 2, flit_bytes: 32, queue_depth: 4 }),
        )
    }

    fn run(mem: &mut MemTile, noc: &mut Noc, cycles: u64) {
        for t in 0..cycles {
            mem.tick(t, noc);
            noc.tick(t);
        }
    }

    #[test]
    fn read_returns_dram_contents() {
        let (mut mem, mut noc) = world();
        mem.write_backdoor(0x100, &[1, 2, 3, 4]);
        noc.send(
            Plane::DmaReq,
            (1, 1),
            Message::ctrl(
                (1, 1),
                (0, 0),
                MsgKind::DmaReadReq { addr: 0x100, len: 4, tag: 9, slot: 1 },
            ),
        );
        run(&mut mem, &mut noc, 300);
        let rsp = noc.recv(Plane::DmaRsp, (1, 1)).expect("response");
        assert!(matches!(rsp.kind, MsgKind::DmaReadRsp { tag: 9, slot: 1 }));
        assert_eq!(&rsp.payload[..], &[1, 2, 3, 4]);
    }

    #[test]
    fn write_commits_and_acks() {
        let (mut mem, mut noc) = world();
        noc.send(
            Plane::DmaReq,
            (0, 1),
            Message::data(
                (0, 1),
                (0, 0),
                MsgKind::DmaWriteReq { addr: 0x40, len: 8, tag: 2, slot: 0 },
                Arc::new(vec![7u8; 8]),
            ),
        );
        run(&mut mem, &mut noc, 300);
        assert!(matches!(
            noc.recv(Plane::DmaRsp, (0, 1)).expect("ack").kind,
            MsgKind::DmaWriteAck { tag: 2, slot: 0 }
        ));
        assert_eq!(mem.read_backdoor(0x40, 8), &[7u8; 8]);
    }

    #[test]
    fn llc_hit_faster_than_miss() {
        // LLC effects only apply in the coherent-DMA configuration.
        let cfg =
            MemConfig { dram_bytes: 1 << 20, dma_through_llc: true, ..MemConfig::default() };
        let mut mem = MemTile::new((0, 0), cfg);
        // Cold read (miss): latency >= dram_latency.
        let t_miss = mem.access(0, 0, 64, false);
        assert!(t_miss >= 100);
        // Hot read (hit): llc latency only.
        let t_hit = mem.access(1000, 0, 64, false);
        assert_eq!(t_hit, 1000 + mem.cfg.llc_latency as u64);
    }

    #[test]
    fn dram_channel_serializes_misses() {
        let (mut mem, _noc) = world();
        // Two concurrent 4 KB cold reads: second waits on the channel.
        let r1 = mem.access(0, 0x10000, 4096, false);
        let r2 = mem.access(0, 0x20000, 4096, false);
        assert!(r2 > r1, "channel occupancy serializes: {r1} then {r2}");
    }

    #[test]
    fn working_set_beyond_llc_misses_again() {
        let cfg =
            MemConfig { dram_bytes: 1 << 20, dma_through_llc: true, ..MemConfig::default() };
        let mut mem = MemTile::new((0, 0), cfg);
        // Fill far beyond 512 KB of distinct lines, then re-touch the start.
        for i in 0..(768 << 10) / 64u64 {
            mem.access(i, i * 64, 64, false);
        }
        let h = mem.stats.llc_hits;
        mem.access(0, 0, 64, false);
        assert_eq!(mem.stats.llc_hits, h, "start of the sweep was evicted");
    }
}
