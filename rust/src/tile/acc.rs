//! The accelerator tile: up to two `(socket, core, PLM)` triples sharing
//! one NoC port, plus the optional private L2 for the fully-coherent /
//! synchronization path.
//!
//! Message routing inside the tile:
//! - `DmaReadRsp`/`DmaWriteAck` -> the socket whose `slot` matches;
//! - `P2pReq` -> the *producer* socket (`prod_slot`);
//! - `P2pData` -> every socket (each checks its participation bit — two
//!   consumers on one tile share the single delivered multicast copy);
//! - `RegWrite`/`RegRead` -> register file of the addressed slot;
//! - coherence planes -> the shared L2.

use crate::accel::{AccCore, CoreState};
use crate::coherence::CacheCtl;
use crate::config::{AccConfig, SocConfig};
use crate::noc::{Coord, Message, MsgKind, Noc, Plane};
use crate::sched::Wake;
use crate::socket::{split_reg, Socket, Status};

/// The accelerator tile.
pub struct AccTile {
    /// Tile coordinate.
    pub coord: Coord,
    /// Sockets (one per slot).
    pub sockets: Vec<Socket>,
    /// Cores (parallel to `sockets`).
    pub cores: Vec<AccCore>,
    /// Private local memories (parallel to `sockets`).
    pub plms: Vec<Vec<u8>>,
    /// Optional private L2 (coherent mode / synchronization).
    pub l2: Option<CacheCtl>,
    /// Invocation spans: (acc id, start cycle, end cycle).
    pub invocation_log: Vec<(u16, u64, u64)>,
    started_at: Vec<u64>,
}

impl AccTile {
    /// Build a tile with `slots` sockets; `first_acc_id` numbers them.
    pub fn new(coord: Coord, slots: u8, first_acc_id: u16, soc: &SocConfig) -> Self {
        let acc: AccConfig = soc.acc;
        let mem = soc.mem_tile();
        let cpu = soc.cpu_tile();
        let mut sockets = Vec::new();
        let mut cores = Vec::new();
        let mut plms = Vec::new();
        for s in 0..slots {
            let mut sock = Socket::new(
                coord,
                s,
                first_acc_id + s as u16,
                acc,
                mem,
                cpu,
                soc.mcast_capacity(),
            );
            sock.set_tlb_miss_penalty(soc.mem.dram_latency);
            sockets.push(sock);
            cores.push(AccCore::new());
            plms.push(vec![0u8; acc.plm_bytes as usize]);
        }
        let l2 = acc
            .l2_enabled
            .then(|| CacheCtl::new(coord, mem, acc.l2_bytes, soc.mem.line_bytes));
        Self {
            coord,
            sockets,
            cores,
            plms,
            l2,
            invocation_log: Vec::new(),
            started_at: vec![0; slots as usize],
        }
    }

    /// Advance one cycle.  The tile's [`Wake`] is the meet of its parts:
    /// each slot contributes the earlier of its core's and socket's wake
    /// (a fully idle slot contributes `Parked`), and the shared L2 is
    /// purely message-driven, so it never needs a timed wake — every
    /// coherence transition it waits on arrives as a delivery on the
    /// coherence planes, which unparks the tile.
    pub fn tick(&mut self, now: u64, noc: &mut Noc) -> Wake {
        // ---- Route incoming messages.
        while let Some(msg) = noc.recv(Plane::DmaRsp, self.coord) {
            match msg.kind {
                MsgKind::DmaReadRsp { slot, .. } | MsgKind::DmaWriteAck { slot, .. } => {
                    let s = slot as usize;
                    self.sockets[s].handle_msg(&msg, &mut self.plms[s]);
                }
                MsgKind::P2pData { .. } => {
                    for s in 0..self.sockets.len() {
                        self.sockets[s].handle_msg(&msg, &mut self.plms[s]);
                    }
                }
                _ => {}
            }
        }
        while let Some(msg) = noc.recv(Plane::DmaReq, self.coord) {
            if let MsgKind::P2pReq { prod_slot, .. } = msg.kind {
                let s = prod_slot as usize;
                self.sockets[s].handle_msg(&msg, &mut self.plms[s]);
            }
        }
        while let Some(msg) = noc.recv(Plane::Misc, self.coord) {
            match msg.kind {
                MsgKind::RegWrite { reg, val } => {
                    let (slot, regno) = split_reg(reg);
                    self.sockets[slot as usize].regs.write(regno, val);
                }
                MsgKind::RegRead { reg, tag } => {
                    let (slot, regno) = split_reg(reg);
                    let val = self.sockets[slot as usize].regs.read(regno);
                    let rsp = Message::ctrl(self.coord, msg.src, MsgKind::RegReadRsp { tag, val });
                    noc.send(Plane::Misc, self.coord, rsp);
                }
                _ => {}
            }
        }
        if let Some(l2) = &mut self.l2 {
            while let Some(msg) = noc.recv(Plane::CohRsp, self.coord) {
                l2.handle_msg(&msg);
            }
            while let Some(msg) = noc.recv(Plane::CohFwd, self.coord) {
                l2.handle_msg(&msg);
            }
            for (plane, m) in l2.drain_out() {
                noc.send(plane, self.coord, m);
            }
        }

        // ---- Per-slot pipeline.
        let mut wake = Wake::Parked;
        for s in 0..self.sockets.len() {
            let (socket, core, plm) =
                (&mut self.sockets[s], &mut self.cores[s], &mut self.plms[s]);
            // Fast path: fully idle slot with nothing pending.
            if core.state() == CoreState::Idle
                && !socket.regs.start_pending
                && !socket.needs_tick()
            {
                continue;
            }
            // Start pulse?
            if socket.regs.start_pending && core.state() == CoreState::Idle {
                socket.regs.start_pending = false;
                socket.regs.status = Status::Running;
                socket.reset_invocation();
                core.start(&socket.regs.args);
                self.started_at[s] = now;
            }
            let core_wake = core.tick(now, socket, plm);
            let socket_wake = socket.tick(now, plm);
            let mut slot_wake = core_wake.earliest(socket_wake);
            // Completion: program done and every transfer drained.
            if core.state() == CoreState::Finished && socket.quiescent() {
                socket.regs.status = Status::Done;
                socket.send_irq();
                core.acknowledge_finish();
                self.invocation_log.push((socket.acc_id, self.started_at[s], now));
                slot_wake = Wake::Parked; // idle until the next start pulse
            }
            for (plane, m) in socket.drain_out() {
                noc.send(plane, self.coord, m);
            }
            wake = wake.earliest(slot_wake);
        }
        wake
    }

    /// All cores idle and sockets drained?
    pub fn idle(&self) -> bool {
        self.cores.iter().all(|c| c.state() == CoreState::Idle)
            && self.sockets.iter().all(|s| s.quiescent())
            && self.l2.as_ref().is_none_or(|l| l.quiescent())
    }
}
