//! The host CPU tile.
//!
//! Runs a *host script* — the software side of accelerator invocations:
//! driver overhead, uncached register writes over the misc plane, IRQ
//! waits, and (for the coherence-based path) flag set/spin operations
//! through a private L1 participating in MESI.  The per-operation costs
//! come from [`crate::config::HostConfig`]; they are what makes small
//! transfers overhead-dominated in Fig. 6.

use std::collections::{HashSet, VecDeque};

use crate::coherence::CacheCtl;
use crate::config::HostConfig;
use crate::noc::{Coord, Message, MsgKind, Noc, Plane};
use crate::sched::Wake;
use crate::sync::FlagOps;

/// One host operation.
#[derive(Debug, Clone)]
pub enum HostOp {
    /// Spin for `0` cycles (software work, driver overhead).
    Delay(u64),
    /// Uncached register write to a tile (misc plane).
    WriteReg { tile: Coord, reg: u16, val: u64 },
    /// Block until the IRQs of all listed accelerators have arrived.
    WaitIrqs(Vec<u16>),
    /// Coherent store of a synchronization flag.
    SetFlag { addr: u64, val: u64 },
    /// Spin on a coherent load until the flag equals `val`.
    WaitFlag { addr: u64, val: u64 },
}

/// CPU-tile statistics.
#[derive(Debug, Default, Clone)]
pub struct CpuStats {
    /// Register writes issued.
    pub reg_writes: u64,
    /// IRQs serviced.
    pub irqs: u64,
    /// (acc id, cycle) of each IRQ arrival.
    pub irq_log: Vec<(u16, u64)>,
    /// Cycle the script finished.
    pub done_at: Option<u64>,
}

/// The host CPU tile.
pub struct CpuTile {
    /// Tile coordinate.
    pub coord: Coord,
    cfg: HostConfig,
    script: VecDeque<HostOp>,
    busy_until: u64,
    last_now: u64,
    irqs: HashSet<u16>,
    /// Private L1 (MESI participant) for flag synchronization.
    pub l1: CacheCtl,
    /// Statistics.
    pub stats: CpuStats,
}

impl CpuTile {
    /// Build an idle CPU at `coord`; `mem_tile` is the directory home.
    pub fn new(coord: Coord, mem_tile: Coord, cfg: HostConfig, line_bytes: u32) -> Self {
        Self {
            coord,
            cfg,
            script: VecDeque::new(),
            busy_until: 0,
            last_now: 0,
            irqs: HashSet::new(),
            l1: CacheCtl::new(coord, mem_tile, 32 << 10, line_bytes),
            stats: CpuStats::default(),
        }
    }

    /// Load (append) a host script.
    pub fn push_script(&mut self, ops: impl IntoIterator<Item = HostOp>) {
        self.script.extend(ops);
        self.stats.done_at = None;
    }

    /// Script finished (including the trailing busy time)?
    pub fn done(&self) -> bool {
        self.script.is_empty() && self.last_now >= self.busy_until
    }

    /// Advance one cycle.  The returned [`Wake`] tells the SoC scheduler
    /// when the next tick can do anything: a busy window sleeps until it
    /// ends, a blocked wait (IRQs not yet arrived, flag transaction in
    /// flight, flag cached with the wrong value) parks until a delivery —
    /// an IRQ, a coherence response, or the invalidation the producer's
    /// flag store triggers.
    pub fn tick(&mut self, now: u64, noc: &mut Noc) -> Wake {
        self.last_now = now;
        // IRQs and coherence traffic are serviced even while busy.
        while let Some(msg) = noc.recv(Plane::Misc, self.coord) {
            if let MsgKind::Irq { acc } = msg.kind {
                self.irqs.insert(acc);
                self.stats.irqs += 1;
                self.stats.irq_log.push((acc, now));
            }
        }
        while let Some(msg) = noc.recv(Plane::CohRsp, self.coord) {
            self.l1.handle_msg(&msg);
        }
        while let Some(msg) = noc.recv(Plane::CohFwd, self.coord) {
            self.l1.handle_msg(&msg);
        }
        for (plane, m) in self.l1.drain_out() {
            noc.send(plane, self.coord, m);
        }

        if now < self.busy_until {
            return Wake::at(now, self.busy_until);
        }
        let Some(op) = self.script.front() else {
            if self.stats.done_at.is_none() {
                self.stats.done_at = Some(now);
            }
            return Wake::Parked;
        };
        match op {
            HostOp::Delay(d) => {
                self.busy_until = now + d;
                self.script.pop_front();
                Wake::at(now, self.busy_until)
            }
            HostOp::WriteReg { tile, reg, val } => {
                let kind = MsgKind::RegWrite { reg: *reg, val: *val };
                noc.send(Plane::Misc, self.coord, Message::ctrl(self.coord, *tile, kind));
                self.stats.reg_writes += 1;
                self.busy_until = now + self.cfg.reg_write_gap as u64;
                self.script.pop_front();
                Wake::at(now, self.busy_until)
            }
            HostOp::WaitIrqs(accs) => {
                if accs.iter().all(|a| self.irqs.contains(a)) {
                    let n = accs.len() as u64;
                    for a in accs.clone() {
                        self.irqs.remove(&a);
                    }
                    self.busy_until = now + self.cfg.irq_overhead as u64 * n;
                    self.script.pop_front();
                    Wake::at(now, self.busy_until)
                } else {
                    Wake::Parked
                }
            }
            HostOp::SetFlag { addr, val } => {
                let done = FlagOps::set(&mut self.l1, *addr, *val);
                if done {
                    self.script.pop_front();
                }
                for (plane, m) in self.l1.drain_out() {
                    noc.send(plane, self.coord, m);
                }
                if done {
                    Wake::Busy
                } else {
                    Wake::Parked
                }
            }
            HostOp::WaitFlag { addr, val } => {
                let done = FlagOps::poll(&mut self.l1, *addr) == Some(*val);
                if done {
                    self.script.pop_front();
                }
                for (plane, m) in self.l1.drain_out() {
                    noc.send(plane, self.coord, m);
                }
                if done {
                    Wake::Busy
                } else {
                    Wake::Parked
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::MeshParams;

    fn world() -> (CpuTile, Noc) {
        (
            CpuTile::new((0, 0), (0, 1), HostConfig::default(), 64),
            Noc::new(MeshParams { width: 2, height: 2, flit_bytes: 32, queue_depth: 4 }),
        )
    }

    #[test]
    fn reg_writes_cross_the_noc() {
        let (mut cpu, mut noc) = world();
        cpu.push_script([
            HostOp::WriteReg { tile: (1, 1), reg: 5, val: 42 },
            HostOp::WriteReg { tile: (1, 1), reg: 6, val: 43 },
        ]);
        for t in 0..100 {
            cpu.tick(t, &mut noc);
            noc.tick(t);
        }
        assert!(cpu.done());
        assert_eq!(cpu.stats.reg_writes, 2);
        let m1 = noc.recv(Plane::Misc, (1, 1)).expect("first write");
        assert!(matches!(m1.kind, MsgKind::RegWrite { reg: 5, val: 42 }));
        assert!(noc.recv(Plane::Misc, (1, 1)).is_some());
    }

    #[test]
    fn reg_write_gap_paces_the_host() {
        let (mut cpu, mut noc) = world();
        cpu.push_script((0..4).map(|i| HostOp::WriteReg { tile: (1, 0), reg: i, val: 0 }));
        let mut finish = 0;
        for t in 0..200 {
            cpu.tick(t, &mut noc);
            noc.tick(t);
            if cpu.done() && finish == 0 {
                finish = t;
            }
        }
        assert!(finish >= 3 * HostConfig::default().reg_write_gap as u64);
    }

    #[test]
    fn wait_irqs_blocks_until_all_arrive() {
        let (mut cpu, mut noc) = world();
        cpu.push_script([HostOp::WaitIrqs(vec![3, 4])]);
        for t in 0..50 {
            cpu.tick(t, &mut noc);
            noc.tick(t);
        }
        assert!(!cpu.done());
        noc.send(Plane::Misc, (1, 1), Message::ctrl((1, 1), (0, 0), MsgKind::Irq { acc: 3 }));
        noc.send(Plane::Misc, (1, 0), Message::ctrl((1, 0), (0, 0), MsgKind::Irq { acc: 4 }));
        for t in 50..2000 {
            cpu.tick(t, &mut noc);
            noc.tick(t);
        }
        assert!(cpu.done());
        assert_eq!(cpu.stats.irqs, 2);
    }

    #[test]
    fn delay_costs_cycles() {
        let (mut cpu, mut noc) = world();
        cpu.push_script([HostOp::Delay(100)]);
        let mut t = 0;
        while !cpu.done() {
            cpu.tick(t, &mut noc);
            t += 1;
            assert!(t < 1000);
        }
        assert!(t >= 100);
    }
}
