//! Tile implementations: CPU (host), memory (LLC + directory + DRAM), I/O,
//! and accelerator tiles, all driven cycle-by-cycle by the coordinator.

pub mod acc;
pub mod cpu;
pub mod io;
pub mod mem;

pub use acc::AccTile;
pub use cpu::{CpuTile, HostOp};
pub use io::IoTile;
pub use mem::{MemStats, MemTile};

use crate::noc::Noc;
use crate::sched::Wake;

/// One mesh tile.
pub enum Tile {
    /// Host CPU.
    Cpu(CpuTile),
    /// Memory tile.
    Mem(MemTile),
    /// I/O tile.
    Io(IoTile),
    /// Accelerator tile.
    Acc(AccTile),
    /// Unpopulated.
    Empty,
}

impl Tile {
    /// Advance this tile one cycle.  Returns the tile's [`Wake`] state:
    /// when (absent a delivery) its next tick can do anything at all —
    /// the contract the SoC worklist scheduler runs on (see
    /// [`crate::sched`] and DESIGN.md §SoC scheduler).
    pub fn tick(&mut self, now: u64, noc: &mut Noc) -> Wake {
        match self {
            Tile::Cpu(t) => t.tick(now, noc),
            Tile::Mem(t) => t.tick(now, noc),
            Tile::Io(t) => t.tick(now, noc),
            Tile::Acc(t) => t.tick(now, noc),
            Tile::Empty => Wake::Parked,
        }
    }

    /// Is the tile quiescent?
    pub fn idle(&self) -> bool {
        match self {
            Tile::Cpu(t) => t.done(),
            Tile::Mem(t) => !t.busy(),
            Tile::Io(_) | Tile::Empty => true,
            Tile::Acc(t) => t.idle(),
        }
    }
}
