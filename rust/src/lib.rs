//! # espsim — generalized on-chip communication for programmable accelerators
//!
//! A cycle-level reproduction of *"Towards Generalized On-Chip Communication
//! for Programmable Accelerators in Heterogeneous Architectures"* (Zuckerman
//! et al., 2024): the ESP tiled-SoC architecture with the paper's five
//! enhancements —
//!
//! 1. **flexible P2P** (per-burst communication-mode switching, length-carrying
//!    requests so producer/consumer burst shapes may differ),
//! 2. a **multicast NoC** (destination lists in the header flit, replicated
//!    lookahead routing, multi-port forks),
//! 3. **coherence-based accelerator synchronization** on top of MESI,
//! 4. the updated 4-channel latency-insensitive **accelerator interface** with
//!    `user` fields (read source / write destination count), and
//! 5. the **IDMA/CDMA ISA extension** for programmable accelerators.
//!
//! The accelerator datapath can run *real* compute: AOT-compiled JAX/Pallas
//! stages loaded through PJRT (see [`runtime`]), so an end-to-end NN pipeline
//! mapped on the simulated SoC produces numerics verified against the jax
//! oracle.  See `DESIGN.md` for the paper→module map and `EXPERIMENTS.md` for
//! the reproduced figures.

// Cycle-level simulator code is index-coupled by nature (parallel arrays of
// routers/ports/tiles addressed by the same indices), and the in-tree JSON
// substrate predates these lints; keep the pragmatic allows crate-wide so
// `clippy -D warnings` guards the lints we do care about.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::inherent_to_string)]
#![allow(clippy::too_many_arguments)]
#![allow(clippy::type_complexity)]

pub mod accel;
pub mod area;
pub mod coherence;
pub mod config;
pub mod coordinator;
pub mod fault;
pub mod noc;
pub mod runtime;
pub mod sched;
pub mod socket;
pub mod sync;
pub mod telemetry;
pub mod tile;
pub mod util;

pub use config::SocConfig;
pub use coordinator::{App, QuiesceError, QuiesceKind, Soc};
pub use fault::{FaultEvent, FaultKind, FaultPlan};
