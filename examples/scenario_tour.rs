//! Tour of the declarative scenario registry: every builtin communication
//! pattern (P2P chain, multicast fan-out, scatter-gather, all-to-all
//! shuffle, halo exchange, coherence-barrier pipeline) run against its
//! DMA-only baseline, with per-plane NoC traffic broken out — the
//! "generalized communication" claim of the paper as one table.
//!
//! ```text
//! cargo run --release --example scenario_tour [-- --mesh16] [-- --paper]
//! ```

use espsim::coordinator::scenario::{builtin_scenarios, Platform};
use espsim::noc::Plane;
use espsim::util::bench::{fmt_secs, time_once, Table};

fn main() -> anyhow::Result<()> {
    let mesh16 = std::env::args().any(|a| a == "--mesh16");
    let paper = std::env::args().any(|a| a == "--paper");
    let platform = match (mesh16, paper) {
        (true, _) => Platform::Mesh16x16,
        (false, true) => Platform::Paper3x4,
        (false, false) => Platform::Mesh8x8,
    };
    println!("== scenario tour on {} ==\n", platform.code());
    let headers =
        ["scenario", "optimized", "dma-only", "speedup", "dma-KiB", "p2p-KiB", "coh-flits", "wall"];
    let t = Table::new(&headers, &[20, 11, 11, 8, 8, 8, 10, 9]);
    for s in builtin_scenarios(platform) {
        let (outcome, wall) = time_once(|| s.run());
        let o = outcome?;
        let coh_flits: u64 = [Plane::CohReq, Plane::CohFwd, Plane::CohRsp]
            .iter()
            .map(|p| o.plane_flits[p.idx()])
            .sum();
        t.row(&[
            s.name.clone(),
            format!("{}", o.cycles),
            format!("{}", o.baseline_cycles),
            format!("{:.2}x", o.speedup()),
            format!("{}", o.dma_bytes >> 10),
            format!("{}", o.p2p_bytes >> 10),
            format!("{coh_flits}"),
            fmt_secs(wall),
        ]);
    }
    println!(
        "\nspeedup = DMA-only staging cycles / optimized (P2P + multicast + coherent-flag)\n\
         cycles; coh-flits light up only where coherence-based synchronization runs."
    );
    Ok(())
}
