//! ISA demo: the paper's IDMA/CDMA extension in action.
//!
//! Hand-writes an accelerator program that (1) kicks off an asynchronous
//! DMA load, (2) polls it with CDMA while doing scalar work (the paper's
//! "initiate a DMA to load data, do some computation, query whether the
//! load is complete"), (3) runs the identity datapath, and (4) stores the
//! result — then round-trips every instruction through the 64-bit
//! encoding to show the RoCC-style wire format.
//!
//! ```text
//! cargo run --release --example isa_demo
//! ```

use espsim::accel::{decode, encode, DpCall, DpKind, Instr};
use espsim::config::SocConfig;
use espsim::coordinator::{App, Invocation, ProgramKind, Soc};
use espsim::socket::DmaDir;

fn main() -> anyhow::Result<()> {
    // The program, in assembly form.  r1.. hold operands set via Seti.
    let program = vec![
        // operands: vaddr=r4, plm=r5, len=r6, user=r7 (0 = memory DMA)
        Instr::Seti { rd: 4, imm: 0x10_0000 }, // source vaddr
        Instr::Seti { rd: 5, imm: 0 },         // PLM offset
        Instr::Seti { rd: 6, imm: 4096 },      // one 4 KB burst
        Instr::Seti { rd: 7, imm: 0 },         // user = memory
        // IDMA returns a tag in r8; the transfer runs asynchronously.
        Instr::Idma { rd: 8, dir: DmaDir::Read, vaddr: 4, plm: 5, len: 6, user: 7 },
        // Overlap: count to 100 in r9 while the DMA flies, sampling CDMA
        // into r10 (so the final value shows the overlap happened).
        Instr::Seti { rd: 9, imm: 0 },
        Instr::Seti { rd: 11, imm: 100 },
        Instr::Cdma { rd: 10, tag: 8 },
        Instr::Addi { rd: 9, ra: 9, imm: 1 },
        Instr::Blt { ra: 9, rb: 11, off: -2 },
        // Join on the tag, then run the datapath (identity over the burst).
        Instr::Wdma { tag: 8 },
        Instr::RunDp { call: 0 },
        Instr::Wdp,
        // Store the datapath output (PLM 8192) back to memory.
        Instr::Seti { rd: 4, imm: 0x20_0000 },
        Instr::Seti { rd: 5, imm: 8192 },
        Instr::Idma { rd: 8, dir: DmaDir::Write, vaddr: 4, plm: 5, len: 6, user: 7 },
        Instr::Wdma { tag: 8 },
        Instr::Done,
    ];

    println!("{:>3}  {:>18}  decoded", "pc", "encoding");
    for (pc, &i) in program.iter().enumerate() {
        let w = encode(i);
        assert_eq!(decode(w), Some(i), "wire format must round-trip");
        println!("{pc:>3}  {w:#018x}  {i:?}");
    }

    // Run it on a small SoC.
    let mut soc = Soc::new(SocConfig::small_3x3())?;
    let data: Vec<u8> = (0..4096u32).map(|i| (i % 251) as u8).collect();
    soc.write_mem(0x10_0000, &data);
    let mut inv = Invocation::tgen(
        0,
        espsim::accel::TgenArgs {
            total_bytes: 0,
            burst_bytes: 1,
            rd_user: 0,
            wr_user: 0,
            vaddr_in: 0,
            vaddr_out: 0,
        },
    );
    inv.program = ProgramKind::Custom(program);
    inv.args = [0; 8];
    inv.dp_calls = vec![DpCall {
        kind: DpKind::Identity,
        inputs: vec![(0, 4096)],
        out_offset: 8192,
        cycles: 4096 / 4 / 8, // stream at 8 words/cycle
    }];
    App::new().phase(vec![inv]).launch(&mut soc)?;
    let cycles = soc.run(1_000_000)?;

    anyhow::ensure!(soc.read_mem(0x20_0000, 4096) == data, "identity datapath corrupted data");
    let report = soc.report();
    println!("\nran in {cycles} cycles; invocation span: {:?}", report.invocations);
    println!("the CDMA polling loop overlapped ~100 scalar iterations with the DMA flight");
    Ok(())
}
