//! End-to-end driver: a real NN pipeline mapped onto the simulated SoC,
//! with **actual compute** — every accelerator datapath executes the
//! AOT-compiled JAX/Pallas stage via PJRT, and the final logits are
//! verified against the python-side oracle.
//!
//! This is the paper's motivating example made concrete ("a neural-network
//! accelerator fetching model parameters from memory and a previous
//! layer's outputs from another accelerator"):
//!
//! ```text
//!            mem --x,w0--> [acc0: stage0 relu(xW0+b0)]
//!                               | multicast (user=4)
//!            +------------+-----+------+------------+
//!            v            v            v            v
//!        [acc1:head0] [acc2:head1] [acc3:head2] [acc4:head3]   (wh from mem)
//!            | P2P        | P2P        | P2P        | P2P
//!            +------------+-----+------+------------+
//!                               v  strided 256-B pulls (flexible P2P!)
//!                      [acc5: combiner catWc+bc] --DMA--> mem
//! ```
//!
//! Run variants: `multicast` (above) vs `memory` (every edge through
//! DRAM, three phases).  Reports cycles, throughput at the paper's 78 MHz,
//! and verifies numerics.
//!
//! ```text
//! make artifacts && cargo run --release --example nn_pipeline
//! ```

use std::sync::Arc;

use espsim::accel::{matmul_cycles, stage_program, DpCall, DpKind, Instr, TgenArgs, Xfer};
use espsim::config::SocConfig;
use espsim::coordinator::{App, Invocation, ProgramKind, Soc};
use espsim::runtime::{Executable, Runtime};

// DRAM layout (f32 tensors as little-endian bytes).
const X: u64 = 0x0010_0000;
const W0: u64 = 0x0020_0000;
const B0: u64 = 0x0030_0000;
const WH: u64 = 0x0040_0000; // + h * 0x10_0000
const BH: u64 = 0x0080_0000; // + h * 0x10_0000
const WC: u64 = 0x00C0_0000;
const BC: u64 = 0x00D0_0000;
const Y_MEM: u64 = 0x0100_0000; // staging (memory variant only)
const H_MEM: u64 = 0x0110_0000; // + h * 0x10_0000
const OUT: u64 = 0x0200_0000;

struct Pipeline {
    rt: Runtime,
    stage0: Arc<Executable>,
    head: Arc<Executable>,
    comb: Arc<Executable>,
    batch: usize,
    d_in: usize,
    d_hid: usize,
    n_heads: usize,
    d_head: usize,
    d_out: usize,
}

impl Pipeline {
    fn load() -> anyhow::Result<Self> {
        let rt = Runtime::open(Runtime::default_dir())?;
        let m = rt.manifest().pipeline.clone();
        Ok(Self {
            stage0: rt.load("stage0_linear_relu")?,
            head: rt.load("stage_head")?,
            comb: rt.load("stage_combiner")?,
            batch: m.batch,
            d_in: m.d_in,
            d_hid: m.d_hid,
            n_heads: m.n_heads,
            d_head: m.d_head,
            d_out: m.d_out,
            rt,
        })
    }

    fn tensor_bytes(&self, name: &str) -> anyhow::Result<Vec<u8>> {
        Ok(self.rt.load_f32_tensor(name)?.iter().flat_map(|f| f.to_le_bytes()).collect())
    }

    fn preload(&self, soc: &mut Soc) -> anyhow::Result<()> {
        soc.write_mem(X, &self.tensor_bytes("input_x")?);
        soc.write_mem(W0, &self.tensor_bytes("w0")?);
        soc.write_mem(B0, &self.tensor_bytes("b0")?);
        for h in 0..self.n_heads {
            soc.write_mem(WH + h as u64 * 0x10_0000, &self.tensor_bytes(&format!("wh{h}"))?);
            soc.write_mem(BH + h as u64 * 0x10_0000, &self.tensor_bytes(&format!("bh{h}"))?);
        }
        soc.write_mem(WC, &self.tensor_bytes("wc")?);
        soc.write_mem(BC, &self.tensor_bytes("bc")?);
        Ok(())
    }

    fn soc(&self) -> anyhow::Result<Soc> {
        let mut cfg = SocConfig::small_3x3();
        cfg.acc.plm_bytes = 1 << 20;
        cfg.acc.max_burst_bytes = 16 << 10;
        let mut soc = Soc::new(cfg)?;
        self.preload(&mut soc)?;
        Ok(soc)
    }

    /// Custom-program invocation helper.
    fn custom(acc: u16, prog: Vec<Instr>, dp: Vec<DpCall>) -> Invocation {
        let mut inv = Invocation::tgen(
            acc,
            TgenArgs {
                total_bytes: 0,
                burst_bytes: 1,
                rd_user: 0,
                wr_user: 0,
                vaddr_in: 0,
                vaddr_out: 0,
            },
        );
        inv.program = ProgramKind::Custom(prog);
        inv.args = [0; 8];
        inv.dp_calls = dp;
        inv
    }

    /// Byte sizes of the pipeline tensors.
    fn sizes(&self) -> (u32, u32, u32, u32, u32, u32, u32, u32, u32) {
        let f = 4u32;
        (
            (self.batch * self.d_in) as u32 * f,      // x
            (self.d_in * self.d_hid) as u32 * f,      // w0
            self.d_hid as u32 * f,                    // b0
            (self.batch * self.d_hid) as u32 * f,     // y
            (self.d_hid * self.d_head) as u32 * f,    // wh
            self.d_head as u32 * f,                   // bh
            (self.batch * self.d_head) as u32 * f,    // head out
            (self.n_heads * self.d_head * self.d_out) as u32 * f, // wc
            self.d_out as u32 * f,                    // bc
        )
    }

    /// Build the multicast/P2P app (single phase, pull-synchronized).
    fn multicast_app(&self) -> Vec<Invocation> {
        let (xs, w0s, b0s, ys, whs, bhs, hs, wcs, bcs) = self.sizes();
        let flops = 256; // MXU-estimate flops/cycle
        let mut invs = Vec::new();
        // acc0: stage0.  PLM: x@0, w0@xs, b0@xs+w0s, y after.
        let y_off = xs + w0s + b0s;
        invs.push(Self::custom(
            0,
            stage_program(
                &[
                    Xfer { vaddr: X, plm: 0, len: xs, user: 0 },
                    Xfer { vaddr: W0, plm: xs, len: w0s, user: 0 },
                    Xfer { vaddr: B0, plm: xs + w0s, len: b0s, user: 0 },
                ],
                &[0],
                // Multicast y to the 4 heads (write user = 4).
                &[Xfer { vaddr: 0, plm: y_off, len: ys, user: self.n_heads as u16 }],
                16 << 10,
            ),
            vec![DpCall {
                kind: DpKind::Xla(self.stage0.clone()),
                inputs: vec![(0, xs), (xs, w0s), (xs + w0s, b0s)],
                out_offset: y_off,
                cycles: matmul_cycles(
                    self.batch as u64,
                    self.d_in as u64,
                    self.d_hid as u64,
                    flops,
                ),
            }],
        ));
        // acc1..4: heads.  PLM: y@0, wh@ys, bh@ys+whs, out after.
        for h in 0..self.n_heads {
            let out_off = ys + whs + bhs;
            invs.push(
                Self::custom(
                    (1 + h) as u16,
                    stage_program(
                        &[
                            Xfer { vaddr: 0, plm: 0, len: ys, user: 1 }, // pull y from acc0
                            Xfer { vaddr: WH + h as u64 * 0x10_0000, plm: ys, len: whs, user: 0 },
                            Xfer {
                                vaddr: BH + h as u64 * 0x10_0000,
                                plm: ys + whs,
                                len: bhs,
                                user: 0,
                            },
                        ],
                        &[0],
                        // Unicast P2P to the combiner.
                        &[Xfer { vaddr: 0, plm: out_off, len: hs, user: 1 }],
                        16 << 10,
                    ),
                    vec![DpCall {
                        kind: DpKind::Xla(self.head.clone()),
                        inputs: vec![(0, ys), (ys, whs), (ys + whs, bhs)],
                        out_offset: out_off,
                        cycles: matmul_cycles(
                            self.batch as u64,
                            self.d_hid as u64,
                            self.d_head as u64,
                            flops,
                        ),
                    }],
                )
                .with_src(1, 0),
            );
        }
        // acc5: combiner.  cat layout (batch, n_heads*d_head): strided
        // 256-byte pulls interleave the four sources row by row — the
        // flexible-P2P enhancement at work (consumer bursts differ from the
        // producers' single 8 KB write).
        let row = (self.d_head * 4) as u32; // bytes per head-row
        let cat = (self.batch as u32) * row * self.n_heads as u32;
        let mut reads = Vec::new();
        for b in 0..self.batch as u32 {
            for h in 0..self.n_heads as u32 {
                reads.push(Xfer {
                    vaddr: 0,
                    plm: b * row * self.n_heads as u32 + h * row,
                    len: row,
                    user: (1 + h) as u16,
                });
            }
        }
        reads.push(Xfer { vaddr: WC, plm: cat, len: wcs, user: 0 });
        reads.push(Xfer { vaddr: BC, plm: cat + wcs, len: bcs, user: 0 });
        let out_off = cat + wcs + bcs;
        let out_len = (self.batch * self.d_out * 4) as u32;
        let mut comb = Self::custom(
            (1 + self.n_heads) as u16,
            stage_program(
                &reads,
                &[0],
                &[Xfer { vaddr: OUT, plm: out_off, len: out_len, user: 0 }],
                16 << 10,
            ),
            vec![DpCall {
                kind: DpKind::Xla(self.comb.clone()),
                inputs: vec![(0, cat), (cat, wcs), (cat + wcs, bcs)],
                out_offset: out_off,
                cycles: matmul_cycles(
                    self.batch as u64,
                    (self.n_heads * self.d_head) as u64,
                    self.d_out as u64,
                    flops,
                ),
            }],
        );
        for h in 0..self.n_heads {
            comb = comb.with_src((1 + h) as u16, (1 + h) as u16);
        }
        invs.push(comb);
        invs
    }

    /// Build the all-through-memory app (three phases).
    fn memory_app(&self) -> (Vec<Invocation>, Vec<Invocation>, Vec<Invocation>) {
        let (xs, w0s, b0s, ys, whs, bhs, hs, wcs, bcs) = self.sizes();
        let flops = 256;
        let y_off = xs + w0s + b0s;
        let stage0 = Self::custom(
            0,
            stage_program(
                &[
                    Xfer { vaddr: X, plm: 0, len: xs, user: 0 },
                    Xfer { vaddr: W0, plm: xs, len: w0s, user: 0 },
                    Xfer { vaddr: B0, plm: xs + w0s, len: b0s, user: 0 },
                ],
                &[0],
                &[Xfer { vaddr: Y_MEM, plm: y_off, len: ys, user: 0 }],
                16 << 10,
            ),
            vec![DpCall {
                kind: DpKind::Xla(self.stage0.clone()),
                inputs: vec![(0, xs), (xs, w0s), (xs + w0s, b0s)],
                out_offset: y_off,
                cycles: matmul_cycles(
                    self.batch as u64,
                    self.d_in as u64,
                    self.d_hid as u64,
                    flops,
                ),
            }],
        );
        let mut heads = Vec::new();
        for h in 0..self.n_heads {
            let out_off = ys + whs + bhs;
            heads.push(Self::custom(
                (1 + h) as u16,
                stage_program(
                    &[
                        Xfer { vaddr: Y_MEM, plm: 0, len: ys, user: 0 },
                        Xfer { vaddr: WH + h as u64 * 0x10_0000, plm: ys, len: whs, user: 0 },
                        Xfer { vaddr: BH + h as u64 * 0x10_0000, plm: ys + whs, len: bhs, user: 0 },
                    ],
                    &[0],
                    &[Xfer { vaddr: H_MEM + h as u64 * 0x10_0000, plm: out_off, len: hs, user: 0 }],
                    16 << 10,
                ),
                vec![DpCall {
                    kind: DpKind::Xla(self.head.clone()),
                    inputs: vec![(0, ys), (ys, whs), (ys + whs, bhs)],
                    out_offset: out_off,
                    cycles: matmul_cycles(
                        self.batch as u64,
                        self.d_hid as u64,
                        self.d_head as u64,
                        flops,
                    ),
                }],
            ));
        }
        let row = (self.d_head * 4) as u32;
        let cat = (self.batch as u32) * row * self.n_heads as u32;
        let mut reads = Vec::new();
        for b in 0..self.batch as u32 {
            for h in 0..self.n_heads as u32 {
                reads.push(Xfer {
                    vaddr: H_MEM + h as u64 * 0x10_0000 + (b * row) as u64,
                    plm: b * row * self.n_heads as u32 + h * row,
                    len: row,
                    user: 0,
                });
            }
        }
        reads.push(Xfer { vaddr: WC, plm: cat, len: wcs, user: 0 });
        reads.push(Xfer { vaddr: BC, plm: cat + wcs, len: bcs, user: 0 });
        let out_off = cat + wcs + bcs;
        let out_len = (self.batch * self.d_out * 4) as u32;
        let comb = Self::custom(
            (1 + self.n_heads) as u16,
            stage_program(
                &reads,
                &[0],
                &[Xfer { vaddr: OUT, plm: out_off, len: out_len, user: 0 }],
                16 << 10,
            ),
            vec![DpCall {
                kind: DpKind::Xla(self.comb.clone()),
                inputs: vec![(0, cat), (cat, wcs), (cat + wcs, bcs)],
                out_offset: out_off,
                cycles: matmul_cycles(
                    self.batch as u64,
                    (self.n_heads * self.d_head) as u64,
                    self.d_out as u64,
                    flops,
                ),
            }],
        );
        (vec![stage0], heads, vec![comb])
    }

    fn verify(&self, soc: &mut Soc) -> anyhow::Result<f32> {
        let expected = self.rt.load_f32_tensor("expected_out")?;
        let got_bytes = soc.read_mem(OUT, expected.len() * 4);
        let got: Vec<f32> = got_bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let max_err =
            got.iter().zip(&expected).map(|(a, b)| (a - b).abs()).fold(0f32, f32::max);
        anyhow::ensure!(max_err < 1e-3, "logits diverge from jax oracle: max err {max_err}");
        Ok(max_err)
    }
}

fn main() -> anyhow::Result<()> {
    let p = Pipeline::load()?;
    println!(
        "pipeline: batch={} d_in={} d_hid={} heads={}x{} d_out={}",
        p.batch, p.d_in, p.d_hid, p.n_heads, p.d_head, p.d_out
    );

    // --- multicast/P2P mapping: one phase, pull-synchronized.
    let mut soc = p.soc()?;
    App::new().phase(p.multicast_app()).launch(&mut soc)?;
    let mc_cycles = soc.run(100_000_000)?;
    let err = p.verify(&mut soc)?;
    println!("\n[multicast/P2P]  {mc_cycles} cycles, logits verified (max err {err:.2e})");
    for (acc, s, e) in &soc.report().invocations {
        println!("  acc{acc}: [{s:>7} .. {e:>7}] {:>7} cy", e - s);
    }

    // --- memory-staged mapping: three phases.
    let mut soc = p.soc()?;
    let (ph1, ph2, ph3) = p.memory_app();
    App::new().phase(ph1).phase(ph2).phase(ph3).launch(&mut soc)?;
    let mem_cycles = soc.run(100_000_000)?;
    let err = p.verify(&mut soc)?;
    println!("\n[memory-staged]  {mem_cycles} cycles, logits verified (max err {err:.2e})");

    // --- headline numbers at the paper's 78 MHz FPGA clock.
    let hz = 78.0e6;
    println!("\nbatch-{} inference latency:", p.batch);
    println!(
        "  multicast/P2P: {:.1} us  ({:.0} inferences/s)",
        mc_cycles as f64 / hz * 1e6,
        p.batch as f64 * hz / mc_cycles as f64
    );
    println!(
        "  memory-staged: {:.1} us  ({:.0} inferences/s)",
        mem_cycles as f64 / hz * 1e6,
        p.batch as f64 * hz / mem_cycles as f64
    );
    println!("  speedup: {:.2}x", mem_cycles as f64 / mc_cycles as f64);
    Ok(())
}
