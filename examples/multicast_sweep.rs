//! Fig. 6 reproduction driver: sweep consumers x data size, print the
//! speedup grid of multicast P2P over the shared-memory baseline, plus the
//! concurrent-baseline variant discussed in EXPERIMENTS.md.
//!
//! `--mesh16` sweeps the scaled 16x16 platform instead (consumers packed
//! two per tile up to 32, transfers out to 4 MB).
//!
//! ```text
//! cargo run --release --example multicast_sweep [-- --quick] [-- --mesh16]
//! ```

use espsim::coordinator::experiments::{
    extended_consumer_counts, extended_data_sizes, paper_consumer_counts, paper_data_sizes,
    quick_data_sizes, quick_extended_data_sizes, run_fig6_point, Fig6Options,
};

fn sweep(
    title: &str,
    opts: &Fig6Options,
    consumers: &[usize],
    sizes: &[u32],
) -> anyhow::Result<()> {
    println!("\n=== {title} ===");
    print!("{:>10} |", "bytes");
    for &n in consumers {
        print!(" {:>6}", format!("N={n}"));
    }
    println!();
    println!("{}", "-".repeat(12 + 7 * consumers.len()));
    for &bytes in sizes {
        print!("{bytes:>10} |");
        for &n in consumers {
            let p = run_fig6_point(n, bytes, opts)?;
            print!(" {:>5.2}x", p.speedup());
        }
        println!();
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let mesh16 = std::env::args().any(|a| a == "--mesh16");

    if mesh16 {
        let sizes = if quick { quick_extended_data_sizes() } else { extended_data_sizes() };
        let opts = Fig6Options::mesh_16x16();
        sweep(
            "scaled sweep: 16x16 mesh, consumers packed 2/tile (up to 32)",
            &opts,
            &extended_consumer_counts(),
            &sizes,
        )?;
        println!(
            "\n32 consumers share 16 destination tiles: one multicast per burst \
             still covers every consumer (two sockets per tile share the copy)"
        );
        return Ok(());
    }

    let sizes = if quick { quick_data_sizes() } else { paper_data_sizes() };

    // Paper configuration: sequential baseline invocations (Linux driver
    // serializes) — reproduces Fig. 6's trends.
    let opts = Fig6Options::default();
    sweep(
        "Fig. 6: multicast speedup (sequential baseline, as in the paper)",
        &opts,
        &paper_consumer_counts(),
        &sizes,
    )?;

    // Ablation: fully concurrent baseline (idealized host).
    let conc = Fig6Options { baseline_sequential: false, ..Fig6Options::default() };
    sweep("ablation: concurrent-baseline host", &conc, &paper_consumer_counts(), &sizes)?;

    println!(
        "\npaper anchors: 1 consumer/4KB -> 1.72x; 16 consumers/4KB -> 2.20x; \
         max 3.03x at 16 consumers/1MB (plateau at 1MB)"
    );
    Ok(())
}
