//! Fig. 6 reproduction driver: sweep consumers x data size, print the
//! speedup grid of multicast P2P over the shared-memory baseline, plus the
//! concurrent-baseline variant discussed in EXPERIMENTS.md.
//!
//! ```text
//! cargo run --release --example multicast_sweep [-- --quick]
//! ```

use espsim::coordinator::experiments::{
    paper_consumer_counts, paper_data_sizes, run_fig6_point, Fig6Options,
};

fn sweep(title: &str, opts: &Fig6Options, sizes: &[u32]) -> anyhow::Result<()> {
    println!("\n=== {title} ===");
    print!("{:>10} |", "bytes");
    for n in paper_consumer_counts() {
        print!(" {:>6}", format!("N={n}"));
    }
    println!();
    println!("{}", "-".repeat(12 + 7 * paper_consumer_counts().len()));
    for &bytes in sizes {
        print!("{bytes:>10} |");
        for &n in &paper_consumer_counts() {
            let p = run_fig6_point(n, bytes, opts)?;
            print!(" {:>5.2}x", p.speedup());
        }
        println!();
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let sizes = if quick {
        vec![4 << 10, 64 << 10]
    } else {
        paper_data_sizes()
    };

    // Paper configuration: sequential baseline invocations (Linux driver
    // serializes) — reproduces Fig. 6's trends.
    let opts = Fig6Options::default();
    sweep("Fig. 6: multicast speedup (sequential baseline, as in the paper)", &opts, &sizes)?;

    // Ablation: fully concurrent baseline (idealized host).
    let mut conc = Fig6Options::default();
    conc.baseline_sequential = false;
    sweep("ablation: concurrent-baseline host", &conc, &sizes)?;

    println!(
        "\npaper anchors: 1 consumer/4KB -> 1.72x; 16 consumers/4KB -> 2.20x; \
         max 3.03x at 16 consumers/1MB (plateau at 1MB)"
    );
    Ok(())
}
