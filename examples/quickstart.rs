//! Quickstart: build the paper's 3x4 SoC, stream 64 KB through a producer
//! and a consumer twice — once through shared memory, once over direct
//! P2P — and print the cycle counts and a statistics report.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use espsim::accel::traffic_gen::TgenArgs;
use espsim::config::SocConfig;
use espsim::coordinator::{App, Invocation, Soc};

const IN: u64 = 0x10_0000;
const MID: u64 = 0x40_0000;
const OUT: u64 = 0x80_0000;
const TOTAL: u32 = 64 << 10;

fn input() -> Vec<u8> {
    (0..TOTAL as u64).map(|i| (i * 131) as u8).collect()
}

fn through_memory() -> anyhow::Result<u64> {
    let mut soc = Soc::new(SocConfig::paper_3x4())?;
    soc.write_mem(IN, &input());
    let producer = Invocation::tgen(
        0,
        TgenArgs {
            total_bytes: TOTAL,
            burst_bytes: 4096,
            rd_user: 0, // read from memory
            wr_user: 0, // write to memory
            vaddr_in: IN,
            vaddr_out: MID,
        },
    );
    let consumer = Invocation::tgen(
        1,
        TgenArgs {
            total_bytes: TOTAL,
            burst_bytes: 4096,
            rd_user: 0,
            wr_user: 0,
            vaddr_in: MID,
            vaddr_out: OUT,
        },
    );
    // Two phases: the consumer starts only after the producer's IRQ.
    App::new().phase(vec![producer]).phase(vec![consumer]).launch(&mut soc)?;
    let cycles = soc.run(10_000_000)?;
    anyhow::ensure!(soc.read_mem(OUT, TOTAL as usize) == input(), "data corrupted");
    println!("--- shared-memory report ---\n{}", soc.report().table());
    Ok(cycles)
}

fn through_p2p() -> anyhow::Result<u64> {
    let mut soc = Soc::new(SocConfig::paper_3x4())?;
    soc.write_mem(IN, &input());
    let producer = Invocation::tgen(
        0,
        TgenArgs {
            total_bytes: TOTAL,
            burst_bytes: 4096,
            rd_user: 0,
            wr_user: 1, // unicast P2P: wait for one consumer's pulls
            vaddr_in: IN,
            vaddr_out: 0,
        },
    );
    let consumer = Invocation::tgen(
        1,
        TgenArgs {
            total_bytes: TOTAL,
            burst_bytes: 4096,
            rd_user: 1, // pull from source-LUT entry 1
            wr_user: 0,
            vaddr_in: 0,
            vaddr_out: OUT,
        },
    )
    .with_src(1, 0); // LUT[1] = accelerator 0 (virtualized placement)
    // One phase: the pull-based P2P protocol synchronizes the pair.
    App::new().phase(vec![producer, consumer]).launch(&mut soc)?;
    let cycles = soc.run(10_000_000)?;
    anyhow::ensure!(soc.read_mem(OUT, TOTAL as usize) == input(), "data corrupted");
    println!("--- P2P report ---\n{}", soc.report().table());
    Ok(cycles)
}

fn main() -> anyhow::Result<()> {
    let mem = through_memory()?;
    let p2p = through_p2p()?;
    println!("shared-memory: {mem} cycles");
    println!("direct P2P:    {p2p} cycles");
    println!("speedup:       {:.2}x", mem as f64 / p2p as f64);
    Ok(())
}
