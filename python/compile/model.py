"""L2: jax stage functions for the accelerator pipeline.

Each *stage* is the compute of one programmable-accelerator invocation in
the rust simulator: the host DMAs/forwards a stage's inputs into the
accelerator, the datapath runs the stage's compiled HLO, and the outputs
are written back / forwarded P2P / multicast.  Stages call the L1 Pallas
kernels so the kernels lower into the same HLO artifact.

The default pipeline (see ``aot.py`` and ``examples/nn_pipeline.rs``) is a
4-stage MLP with a multicast fan-out, mirroring the paper's motivating
example ("a neural-network accelerator fetching model parameters from
memory and a previous layer's outputs from another accelerator"):

    stage0: x(B,256)  -> relu(x W0 + b0)          (B,256)   [multicast to 4 heads]
    head h: y(B,256)  -> relu(y Wh + bh)          (B,64)    [P2P to combiner]
    comb:   cat(B,256)-> softmax(cat Wc + bc)     (B,128)   [DMA to memory]

plus the traffic-generator identity stage used by the Fig. 6 workloads.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.identity import identity_kernel
from .kernels.softmax import softmax_kernel
from .kernels.matmul import linear_kernel
from .kernels import ref

# Pipeline dimensions (small enough to AOT + simulate quickly; block-aligned).
BATCH = 32
D_IN = 256
D_HID = 256
N_HEADS = 4
D_HEAD = 64
D_OUT = 128  # combiner output width (logits padded to a burst multiple)


def stage_linear_relu(x: jax.Array, w: jax.Array, b: jax.Array):
    """Hidden stage: relu(x @ w + b) via the Pallas datapath kernel."""
    return (linear_kernel(x, w, b, activation="relu"),)


def stage_linear(x: jax.Array, w: jax.Array, b: jax.Array):
    """Output stage: x @ w + b (no activation)."""
    return (linear_kernel(x, w, b, activation="none"),)


def stage_combiner(x: jax.Array, w: jax.Array, b: jax.Array):
    """Classifier head: softmax(x @ w + b) — the pipeline's final stage."""
    return (softmax_kernel(linear_kernel(x, w, b, activation="none")),)


def stage_head(x: jax.Array, w: jax.Array, b: jax.Array):
    """One parallel 'head': narrow relu linear, block sizes shrunk to fit."""
    return (linear_kernel(x, w, b, activation="relu", block_n=64),)


def stage_identity(x: jax.Array):
    """Traffic-generator stage: stream x through the datapath unchanged."""
    return (identity_kernel(x),)


def init_params(seed: int = 0):
    """Deterministic pipeline parameters (shared with the rust launcher)."""
    keys = jax.random.split(jax.random.PRNGKey(seed), 3 + N_HEADS)
    p = {
        "w0": jax.random.normal(keys[0], (D_IN, D_HID), jnp.float32) * 0.05,
        "b0": jnp.zeros((D_HID,), jnp.float32),
        "wc": jax.random.normal(keys[1], (N_HEADS * D_HEAD, D_OUT), jnp.float32) * 0.05,
        "bc": jnp.zeros((D_OUT,), jnp.float32),
    }
    for h in range(N_HEADS):
        p[f"wh{h}"] = jax.random.normal(keys[3 + h - 1], (D_HID, D_HEAD), jnp.float32) * 0.05
        p[f"bh{h}"] = jnp.zeros((D_HEAD,), jnp.float32)
    return p


def pipeline_reference(x: jax.Array, params: dict) -> jax.Array:
    """Full-pipeline oracle in pure jnp (no Pallas): what the SoC must compute."""
    y = ref.linear_ref(x, params["w0"], params["b0"], activation="relu")
    heads = [
        ref.linear_ref(y, params[f"wh{h}"], params[f"bh{h}"], activation="relu")
        for h in range(N_HEADS)
    ]
    cat = jnp.concatenate(heads, axis=1)
    return ref.softmax_ref(ref.linear_ref(cat, params["wc"], params["bc"], activation="none"))


def pipeline_kernels(x: jax.Array, params: dict) -> jax.Array:
    """Full pipeline through the Pallas stage functions (for pytest)."""
    (y,) = stage_linear_relu(x, params["w0"], params["b0"])
    heads = [stage_head(y, params[f"wh{h}"], params[f"bh{h}"])[0] for h in range(N_HEADS)]
    cat = jnp.concatenate(heads, axis=1)
    return stage_combiner(cat, params["wc"], params["bc"])[0]
