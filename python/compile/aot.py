"""AOT lowering driver: jax/Pallas stages -> artifacts/*.hlo.txt (+ data).

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids, so text round-trips cleanly (see /opt/xla-example).

Besides the HLO artifacts this also emits, into the same directory:
  - ``manifest.json``     — artifact name -> input/output shapes + dtypes,
                            consumed by ``rust/src/runtime``;
  - ``*.f32``             — little-endian f32 parameter/input/expected-output
                            tensors for the end-to-end ``nn_pipeline`` example,
                            so rust feeds the exact data the oracle saw.

Python runs ONCE at build time (``make artifacts``); the rust binary is
self-contained afterwards.
"""

from __future__ import annotations

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


# name -> (stage fn, example arg specs)
ARTIFACTS = {
    "stage0_linear_relu": (
        model.stage_linear_relu,
        [_spec((model.BATCH, model.D_IN)), _spec((model.D_IN, model.D_HID)), _spec((model.D_HID,))],
    ),
    "stage_head": (
        model.stage_head,
        [_spec((model.BATCH, model.D_HID)), _spec((model.D_HID, model.D_HEAD)), _spec((model.D_HEAD,))],
    ),
    "stage_combiner": (
        model.stage_combiner,
        [
            _spec((model.BATCH, model.N_HEADS * model.D_HEAD)),
            _spec((model.N_HEADS * model.D_HEAD, model.D_OUT)),
            _spec((model.D_OUT,)),
        ],
    ),
    "tgen_identity": (model.stage_identity, [_spec((1024,))]),
}


def lower_artifacts(out_dir: pathlib.Path) -> dict:
    manifest = {"artifacts": {}, "pipeline": {}}
    for name, (fn, specs) in ARTIFACTS.items():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = out_dir / f"{name}.hlo.txt"
        path.write_text(text)
        out_shapes = [list(s.shape) for s in jax.eval_shape(fn, *specs)]
        manifest["artifacts"][name] = {
            "file": path.name,
            "inputs": [{"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs],
            "outputs": [{"shape": s, "dtype": "float32"} for s in out_shapes],
        }
        print(f"  {name}: {len(text)} chars -> {path.name}")
    return manifest


def dump_pipeline_data(out_dir: pathlib.Path, manifest: dict, seed: int = 0) -> None:
    """Parameters, input batch, and oracle output for examples/nn_pipeline.rs."""
    params = model.init_params(seed)
    x = jax.random.normal(jax.random.PRNGKey(seed + 100), (model.BATCH, model.D_IN), jnp.float32)
    expected = model.pipeline_reference(x, params)

    tensors = {"input_x": x, "expected_out": expected, **params}
    for name, arr in tensors.items():
        np.asarray(arr, dtype=np.float32).tofile(out_dir / f"{name}.f32")
    manifest["pipeline"] = {
        "batch": model.BATCH,
        "d_in": model.D_IN,
        "d_hid": model.D_HID,
        "n_heads": model.N_HEADS,
        "d_head": model.D_HEAD,
        "d_out": model.D_OUT,
        "tensors": {name: list(np.shape(arr)) for name, arr in tensors.items()},
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/model.hlo.txt",
                    help="marker artifact path; all artifacts go to its directory")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out).parent
    out_dir.mkdir(parents=True, exist_ok=True)
    print(f"lowering {len(ARTIFACTS)} artifacts -> {out_dir}")
    manifest = lower_artifacts(out_dir)
    dump_pipeline_data(out_dir, manifest, args.seed)
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    # Marker file so the Makefile's stamp-based no-op check works.
    pathlib.Path(args.out).write_text((out_dir / "stage0_linear_relu.hlo.txt").read_text())
    print("aot done")


if __name__ == "__main__":
    main()
