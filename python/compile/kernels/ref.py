"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground truth for pytest/hypothesis; they intentionally avoid
Pallas, blocking, and any clever layout so a bug in the kernels cannot be
mirrored here.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def linear_ref(x: jax.Array, w: jax.Array, b: jax.Array, *, activation: str = "relu") -> jax.Array:
    """``act(x @ w + b)`` in f32, matching ``linear_kernel``'s contract."""
    out = (
        jnp.dot(
            x.astype(jnp.float32),
            w.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        + b.astype(jnp.float32)
    )
    if activation == "relu":
        out = jnp.maximum(out, 0.0)
    elif activation == "gelu":
        out = jax.nn.gelu(out)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation!r}")
    return out


def identity_ref(x: jax.Array) -> jax.Array:
    return x


def softmax_ref(x, *, axis: int = -1):
    """Numerically-stable softmax in f32 (jax.nn.softmax, pinned to f32)."""
    return jax.nn.softmax(x.astype(jnp.float32), axis=axis)
