"""Row-softmax Pallas kernel (numerically stable).

Completes the classifier head of the example pipeline: the combiner stage
fuses ``logits = cat @ Wc + bc`` with ``softmax(logits)`` so the SoC's
final DMA write-back carries probabilities.  One grid step processes a
block of rows; the full feature dimension stays resident in VMEM (the
row-wise max/sum reductions need it), which is the standard TPU softmax
blocking for feature widths that fit VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _softmax_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    o_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def softmax_kernel(x: jax.Array, *, block_rows: int = 8) -> jax.Array:
    """Row-wise softmax over the last axis of a 2-D array; returns f32."""
    rows, cols = x.shape
    block_rows = min(block_rows, rows)
    if rows % block_rows:
        raise ValueError(f"rows {rows} not divisible by block_rows {block_rows}")
    return pl.pallas_call(
        _softmax_kernel,
        grid=(rows // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=True,
    )(x)
