"""Streaming identity (copy) Pallas kernel — the traffic generator's datapath.

The paper's traffic-generator accelerator "performs the identity function,
i.e. it writes the same data as output that it receives as input", with a
4 KB maximum burst.  The kernel streams the input through VMEM in
burst-sized blocks (1024 f32 words == 4 KB), mirroring the accelerator's
PLM ping-pong: one grid step == one burst through the datapath.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 4 KB of f32 words — the paper's traffic-generator burst size.
BURST_WORDS = 1024


def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


@functools.partial(jax.jit, static_argnames=("block",))
def identity_kernel(x: jax.Array, *, block: int = BURST_WORDS) -> jax.Array:
    """Copy a 1-D array through VMEM in burst-sized blocks."""
    (n,) = x.shape
    block = min(block, n)
    if n % block:
        raise ValueError(f"length {n} not divisible by burst block {block}")
    return pl.pallas_call(
        _copy_kernel,
        grid=(n // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=True,
    )(x)
