"""Blocked matmul + bias + activation Pallas kernel.

This is the datapath of the *programmable accelerator* in the paper's
sense: the accelerator's PLM corresponds to VMEM blocks (one DMA burst ==
one HBM->VMEM block fetch), and the compute targets an MXU-shaped systolic
matmul.  The grid iterates output blocks (bm, bn); the K reduction runs as
the innermost grid dimension accumulating in-place into the resident
output block, which expresses the same burst-granular producer/consumer
overlap the paper gets from ping-pong PLM banks.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): block shapes default
to multiples of the f32 TPU tiling (8, 128); accumulation is f32 even for
bf16 inputs, as on the MXU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _linear_block_kernel(x_ref, w_ref, b_ref, o_ref, *, activation: str, k_steps: int):
    """One (bm, bn) output block; grid dim 2 walks the K blocks.

    The output block is resident across the K steps (its index map ignores
    k), so we accumulate partial products into it in f32 and apply
    bias/activation on the last step.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    # MXU-shaped partial product, f32 accumulation regardless of input dtype.
    o_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == k_steps - 1)
    def _epilogue():
        out = o_ref[...] + b_ref[...].astype(jnp.float32)
        if activation == "relu":
            out = jnp.maximum(out, 0.0)
        elif activation == "gelu":
            out = jax.nn.gelu(out)
        elif activation != "none":
            raise ValueError(f"unknown activation {activation!r}")
        o_ref[...] = out


@functools.partial(
    jax.jit, static_argnames=("activation", "block_m", "block_n", "block_k")
)
def linear_kernel(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    activation: str = "relu",
    block_m: int = 32,
    block_n: int = 128,
    block_k: int = 128,
) -> jax.Array:
    """``act(x @ w + b)`` as a blocked Pallas kernel; returns f32.

    Shapes: x (M, K), w (K, N), b (N,).  Block sizes are clamped to the
    dims; after clamping, M, K, N must be divisible by the block sizes
    (the accelerator's PLM is burst-granular; the rust-side launcher
    always pads datasets to burst multiples).
    """
    m, k = x.shape
    k2, n = w.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: x {x.shape} vs w {w.shape}")
    if b.shape != (n,):
        raise ValueError(f"bias shape {b.shape} != ({n},)")
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    if m % block_m or n % block_n or k % block_k:
        raise ValueError(
            f"dims ({m},{k},{n}) not divisible by blocks ({block_m},{block_k},{block_n})"
        )
    k_steps = k // block_k
    grid = (m // block_m, n // block_n, k_steps)

    kernel = functools.partial(
        _linear_block_kernel, activation=activation, k_steps=k_steps
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((block_n,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w, b)
