"""Pallas softmax kernel vs jax.nn.softmax oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.softmax import softmax_kernel
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _check(rows, cols, dtype=jnp.float32, scale=1.0, **kw):
    x = jax.random.normal(jax.random.PRNGKey(rows * 31 + cols), (rows, cols)) * scale
    x = x.astype(dtype)
    got = softmax_kernel(x, **kw)
    want = ref.softmax_ref(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)
    # Rows sum to one.
    np.testing.assert_allclose(np.asarray(got).sum(axis=-1), 1.0, rtol=1e-5)


class TestSoftmaxDirected:
    def test_single_block(self):
        _check(8, 16)

    def test_multi_block_rows(self):
        _check(32, 128)

    def test_pipeline_shape(self):
        _check(32, 128)  # (BATCH, D_OUT)

    def test_large_magnitudes_stable(self):
        # exp would overflow without the max subtraction.
        _check(16, 64, scale=100.0)

    def test_bf16_input(self):
        x = (jax.random.normal(jax.random.PRNGKey(0), (8, 32)) * 3).astype(jnp.bfloat16)
        got = softmax_kernel(x)
        assert got.dtype == jnp.float32
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(ref.softmax_ref(x)), rtol=2e-2, atol=2e-3
        )

    def test_rejects_nondivisible_rows(self):
        with pytest.raises(ValueError, match="not divisible"):
            softmax_kernel(jnp.zeros((10, 16)), block_rows=8)

    def test_row_block_clamps(self):
        _check(4, 16, block_rows=8)


class TestSoftmaxHypothesis:
    @settings(max_examples=20, deadline=None)
    @given(
        rows=st.sampled_from([8, 16, 32, 64]),
        cols=st.sampled_from([8, 32, 128, 256]),
        scale=st.sampled_from([0.1, 1.0, 10.0]),
    )
    def test_matches_ref(self, rows, cols, scale):
        _check(rows, cols, scale=scale)

    @settings(max_examples=10, deadline=None)
    @given(rows=st.sampled_from([16, 32]), block=st.sampled_from([4, 8, 16]))
    def test_block_invariance(self, rows, block):
        _check(rows, 64, block_rows=block)
