"""L2 stage/pipeline functions: shapes + full-pipeline kernel-vs-oracle."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model

jax.config.update("jax_platform_name", "cpu")


def _params_and_input(seed=0):
    params = model.init_params(seed)
    x = jax.random.normal(jax.random.PRNGKey(seed + 100), (model.BATCH, model.D_IN))
    return params, x


class TestStageShapes:
    def test_stage0(self):
        params, x = _params_and_input()
        (y,) = model.stage_linear_relu(x, params["w0"], params["b0"])
        assert y.shape == (model.BATCH, model.D_HID)

    def test_head(self):
        params, x = _params_and_input()
        (y,) = model.stage_linear_relu(x, params["w0"], params["b0"])
        (h,) = model.stage_head(y, params["wh0"], params["bh0"])
        assert h.shape == (model.BATCH, model.D_HEAD)

    def test_combiner(self):
        params, _ = _params_and_input()
        cat = jnp.zeros((model.BATCH, model.N_HEADS * model.D_HEAD))
        (out,) = model.stage_linear(cat, params["wc"], params["bc"])
        assert out.shape == (model.BATCH, model.D_OUT)

    def test_identity_stage(self):
        x = jnp.arange(2048, dtype=jnp.float32)
        (y,) = model.stage_identity(x)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


class TestPipeline:
    def test_kernels_match_reference(self):
        params, x = _params_and_input()
        got = model.pipeline_kernels(x, params)
        want = model.pipeline_reference(x, params)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)

    def test_reference_deterministic(self):
        p1, x1 = _params_and_input(3)
        p2, x2 = _params_and_input(3)
        np.testing.assert_array_equal(
            np.asarray(model.pipeline_reference(x1, p1)),
            np.asarray(model.pipeline_reference(x2, p2)),
        )

    def test_params_cover_all_heads(self):
        params = model.init_params()
        for h in range(model.N_HEADS):
            assert params[f"wh{h}"].shape == (model.D_HID, model.D_HEAD)
            assert params[f"bh{h}"].shape == (model.D_HEAD,)

    def test_relu_active(self):
        # The pipeline must actually clip below zero somewhere (guards
        # against an activation that silently became a no-op).
        params, x = _params_and_input()
        y = model.pipeline_reference(x, params)
        pre = jnp.dot(x, params["w0"]) + params["b0"]
        assert (np.asarray(pre) < 0).any()
        assert np.isfinite(np.asarray(y)).all()
