"""AOT path: every artifact lowers to parseable HLO text; manifest + data
files are complete and consistent with the model dims."""

import json
import pathlib

import jax
import numpy as np
import pytest

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.lower_artifacts(out)
    aot.dump_pipeline_data(out, manifest, seed=0)
    (out / "manifest.json").write_text(json.dumps(manifest))
    return out, manifest


class TestArtifacts:
    def test_all_artifacts_emitted(self, built):
        out, manifest = built
        for name, entry in manifest["artifacts"].items():
            path = out / entry["file"]
            assert path.exists(), name
            text = path.read_text()
            # HLO text sanity: module header + an entry computation.
            assert text.startswith("HloModule"), name
            assert "ENTRY" in text, name

    def test_no_custom_calls(self, built):
        # interpret=True must lower pallas to plain HLO ops — a Mosaic
        # custom-call would be unloadable by the CPU PJRT client.
        out, manifest = built
        for entry in manifest["artifacts"].values():
            assert "custom-call" not in (out / entry["file"]).read_text()

    def test_manifest_shapes_match_model(self, built):
        _, manifest = built
        a = manifest["artifacts"]
        assert a["stage0_linear_relu"]["inputs"][0]["shape"] == [model.BATCH, model.D_IN]
        assert a["stage0_linear_relu"]["outputs"][0]["shape"] == [model.BATCH, model.D_HID]
        assert a["stage_head"]["outputs"][0]["shape"] == [model.BATCH, model.D_HEAD]
        assert a["stage_combiner"]["outputs"][0]["shape"] == [model.BATCH, model.D_OUT]
        assert a["tgen_identity"]["inputs"][0]["shape"] == [1024]

    def test_pipeline_data_files(self, built):
        out, manifest = built
        for name, shape in manifest["pipeline"]["tensors"].items():
            path = out / f"{name}.f32"
            assert path.exists(), name
            n = np.fromfile(path, dtype=np.float32).size
            assert n == int(np.prod(shape)), name

    def test_expected_out_matches_reference(self, built):
        out, manifest = built
        shape = manifest["pipeline"]["tensors"]["expected_out"]
        expected = np.fromfile(out / "expected_out.f32", dtype=np.float32).reshape(shape)
        params = model.init_params(0)
        x = np.fromfile(out / "input_x.f32", dtype=np.float32).reshape(
            manifest["pipeline"]["tensors"]["input_x"]
        )
        want = np.asarray(model.pipeline_reference(jax.numpy.asarray(x), params))
        np.testing.assert_allclose(expected, want, rtol=1e-6)
