"""Pallas linear kernel vs pure-jnp oracle — the core correctness signal.

Hypothesis sweeps shapes (block-aligned and clamped), dtypes, activations,
and block configurations; every case asserts allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.matmul import linear_kernel
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def _check(m, k, n, dtype, activation, **blocks):
    k0, k1, k2 = jax.random.split(jax.random.PRNGKey(m * 7 + k * 3 + n), 3)
    x = _rand(k0, (m, k), dtype)
    w = _rand(k1, (k, n), dtype)
    b = _rand(k2, (n,), dtype)
    got = linear_kernel(x, w, b, activation=activation, **blocks)
    want = ref.linear_ref(x, w, b, activation=activation)
    assert got.dtype == jnp.float32
    # Split-K accumulation order differs from a single dot: f32 needs a
    # slightly loose tolerance, bf16 a much looser one.
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


class TestLinearKernelDirected:
    def test_single_block(self):
        _check(8, 16, 16, jnp.float32, "relu")

    def test_multi_block_m(self):
        _check(64, 128, 128, jnp.float32, "relu")

    def test_multi_block_all_dims(self):
        _check(64, 256, 256, jnp.float32, "relu")

    def test_no_activation(self):
        _check(32, 128, 128, jnp.float32, "none")

    def test_gelu(self):
        _check(32, 128, 128, jnp.float32, "gelu")

    def test_bf16_inputs_f32_accumulate(self):
        _check(32, 256, 128, jnp.bfloat16, "relu")

    def test_pipeline_shapes_stage0(self):
        _check(32, 256, 256, jnp.float32, "relu")

    def test_pipeline_shapes_head(self):
        _check(32, 256, 64, jnp.float32, "relu", block_n=64)

    def test_pipeline_shapes_combiner(self):
        _check(32, 256, 128, jnp.float32, "none")

    def test_narrow_blocks(self):
        _check(16, 32, 32, jnp.float32, "relu", block_m=8, block_n=16, block_k=16)

    def test_rejects_contraction_mismatch(self):
        x = jnp.zeros((8, 16))
        w = jnp.zeros((32, 8))
        b = jnp.zeros((8,))
        with pytest.raises(ValueError, match="contraction mismatch"):
            linear_kernel(x, w, b)

    def test_rejects_bad_bias(self):
        x = jnp.zeros((8, 16))
        w = jnp.zeros((16, 8))
        b = jnp.zeros((16,))
        with pytest.raises(ValueError, match="bias shape"):
            linear_kernel(x, w, b)

    def test_rejects_nondivisible(self):
        x = jnp.zeros((8, 24))
        w = jnp.zeros((24, 8))
        b = jnp.zeros((8,))
        with pytest.raises(ValueError, match="not divisible"):
            linear_kernel(x, w, b, block_k=16)

    def test_rejects_unknown_activation(self):
        x = jnp.zeros((8, 8))
        w = jnp.zeros((8, 8))
        b = jnp.zeros((8,))
        with pytest.raises(ValueError, match="unknown activation"):
            linear_kernel(x, w, b, activation="tanh")


# Block-aligned dims: multiples of 8/16 keep interpret-mode runtime sane.
dims_m = st.sampled_from([8, 16, 32, 64])
dims_k = st.sampled_from([16, 32, 64, 128, 256])
dims_n = st.sampled_from([16, 64, 128, 256])


class TestLinearKernelHypothesis:
    @settings(max_examples=25, deadline=None)
    @given(m=dims_m, k=dims_k, n=dims_n,
           activation=st.sampled_from(["relu", "none", "gelu"]))
    def test_matches_ref_f32(self, m, k, n, activation):
        _check(m, k, n, jnp.float32, activation)

    @settings(max_examples=10, deadline=None)
    @given(m=dims_m, k=dims_k, n=dims_n)
    def test_matches_ref_bf16(self, m, k, n):
        _check(m, k, n, jnp.bfloat16, "relu")

    @settings(max_examples=15, deadline=None)
    @given(m=st.sampled_from([16, 32, 64]),
           bm=st.sampled_from([8, 16, 32]),
           bn=st.sampled_from([16, 32, 64]),
           bk=st.sampled_from([16, 32, 64]))
    def test_block_shape_invariance(self, m, bm, bn, bk):
        # Result must not depend on the chosen blocking.
        _check(m, 64, 64, jnp.float32, "relu", block_m=bm, block_n=bn, block_k=bk)
