"""Streaming identity kernel (traffic-generator datapath) vs oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.identity import BURST_WORDS, identity_kernel
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


class TestIdentityDirected:
    def test_single_burst(self):
        x = jnp.arange(BURST_WORDS, dtype=jnp.float32)
        np.testing.assert_array_equal(np.asarray(identity_kernel(x)), np.asarray(x))

    def test_multi_burst(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (4 * BURST_WORDS,))
        np.testing.assert_array_equal(
            np.asarray(identity_kernel(x)), np.asarray(ref.identity_ref(x))
        )

    def test_short_array_clamps_block(self):
        x = jnp.arange(64, dtype=jnp.float32)
        np.testing.assert_array_equal(np.asarray(identity_kernel(x)), np.asarray(x))

    def test_rejects_nondivisible(self):
        x = jnp.zeros((BURST_WORDS + 3,))
        with pytest.raises(ValueError, match="not divisible"):
            identity_kernel(x)

    def test_int_dtype(self):
        x = jnp.arange(256, dtype=jnp.int32)
        got = identity_kernel(x)
        assert got.dtype == jnp.int32
        np.testing.assert_array_equal(np.asarray(got), np.asarray(x))


class TestIdentityHypothesis:
    @settings(max_examples=20, deadline=None)
    @given(bursts=st.integers(1, 8),
           dtype=st.sampled_from([jnp.float32, jnp.bfloat16, jnp.int32]))
    def test_roundtrip(self, bursts, dtype):
        n = bursts * BURST_WORDS
        x = jnp.arange(n).astype(dtype)
        got = identity_kernel(x)
        assert got.dtype == dtype
        np.testing.assert_array_equal(np.asarray(got), np.asarray(x))

    @settings(max_examples=20, deadline=None)
    @given(n=st.sampled_from([32, 64, 128, 512]), block=st.sampled_from([16, 32, 64]))
    def test_custom_blocks(self, n, block):
        if n % block:
            return
        x = jnp.arange(n, dtype=jnp.float32)
        np.testing.assert_array_equal(np.asarray(identity_kernel(x, block=block)), np.asarray(x))
